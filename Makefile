# Build-time entry points.  The Rust side is plain cargo (workspace root
# is this directory); `make artifacts` runs the Python AOT bridge that
# lowers the parametrized Pallas kernels to artifacts/*.hlo.txt +
# manifest.json (requires JAX; the Rust NativeEngine also runs synthetic
# manifests without it).

.PHONY: artifacts test rust-test python-test

artifacts:
	cd python && python3 -m compile.aot --out ../artifacts --groups all

rust-test:
	cargo build --release && cargo test -q

python-test:
	python3 -m pytest python/tests -q

test: rust-test python-test
