# Build-time entry points.  The Rust side is plain cargo (workspace root
# is this directory); `make artifacts` runs the Python AOT bridge that
# lowers the parametrized Pallas kernels to artifacts/*.hlo.txt +
# manifest.json (requires JAX; the Rust NativeEngine also runs synthetic
# manifests without it).

.PHONY: artifacts test rust-test python-test tune bench-smoke

artifacts:
	cd python && python3 -m compile.aot --out ../artifacts --groups all

rust-test:
	cargo build --release && cargo test -q

python-test:
	python3 -m pytest python/tests -q

test: rust-test python-test

# Measured per-host tuner sweep, quick grid — exactly what CI's
# tune-smoke job runs.  Writes reports/tuning_host.json (the selection
# DB NativeEngine consults at plan time) and reports/BENCH_ci.json
# (tuned-vs-default GFLOP/s per problem).  Drop --quick for the full
# grid (and the modeled device-zoo demo).
tune:
	cargo run --release --example tune_device -- --quick --out reports

# Offline bench smoke: modeled paper figures plus the measured host
# BlockedParams x threads sweeps (reports/*_host_sweep.csv).  No JAX
# artifacts needed; the artifact-backed sections skip gracefully.
bench-smoke:
	cargo bench --bench rust_blas
	cargo bench --bench gemm_roofline
	cargo bench --bench conv_sweep
