# Build-time entry points.  The Rust side is plain cargo (workspace root
# is this directory); `make artifacts` runs the Python AOT bridge that
# lowers the parametrized Pallas kernels to artifacts/*.hlo.txt +
# manifest.json (requires JAX; the Rust NativeEngine also runs synthetic
# manifests without it).

.PHONY: artifacts test rust-test python-test tune tune-exhaustive \
	tune-merge bench-smoke docs serve-smoke

artifacts:
	cd python && python3 -m compile.aot --out ../artifacts --groups all

rust-test:
	cargo build --release && cargo test -q

python-test:
	python3 -m pytest python/tests -q

test: rust-test python-test

# Measured per-host tuner sweep, quick grid, model-guided search (the
# default: --search guided --budget 8; see docs/TUNING.md "Search
# strategies").  Writes reports/tuning_host.json (the selection DB
# NativeEngine consults at plan time, each entry annotated with its
# search provenance) and reports/BENCH_ci.json (tuned-vs-default
# GFLOP/s and points_measured per problem).  Drop --quick for the full
# grid (and the modeled device-zoo demo).
tune:
	cargo run --release --example tune_device -- --quick --out reports

# The exhaustive ground-truth baseline CI's tune-smoke job compares the
# guided search against (>= 10x fewer measured points at equal-or-better
# tuned GFLOP/s).
tune-exhaustive:
	cargo run --release --example tune_device -- --quick \
		--search exhaustive --out reports_ex

# Exercise the selection-DB merge flag end to end: sweep once, then
# sweep again folding the first run's DB back in (--merge migrates any
# legacy blocked/conv_native entries to the unified gemm_point /
# conv_point schema and keeps the faster entry per key).  CI's
# tune-smoke job runs the same fold after its main sweep.
tune-merge:
	cargo run --release --example tune_device -- --quick --out reports
	cp reports/tuning_host.json reports/tuning_prev.json
	cargo run --release --example tune_device -- --quick --out reports \
		--merge reports/tuning_prev.json

# Offline bench smoke: modeled paper figures plus the measured host
# BlockedParams x threads sweeps (reports/*_host_sweep.csv) and the
# serving contention sweep (reports/serving_contention.csv).  No JAX
# artifacts needed; the artifact-backed sections skip gracefully.
bench-smoke:
	cargo bench --bench rust_blas
	cargo bench --bench gemm_roofline
	cargo bench --bench conv_sweep
	cargo bench --bench serving_contention

# Documentation gate — exactly what CI's docs job runs: rustdoc with
# warnings as errors (missing_docs is enforced crate-wide) plus the
# markdown cross-reference check over docs/*.md and ROADMAP.md.
docs:
	RUSTDOCFLAGS="-D warnings" cargo doc --no-deps
	python3 scripts/check_doc_links.py

# Serving scale-out smoke — exactly what CI's serve-smoke job runs:
# 8 closed-loop clients over the synthetic zoo, serial kernels, and the
# assertion that pool(2) throughput >= the single-actor baseline; then
# the phase-shift scenario (traffic drifts onto a badly tuned shape
# class, the pool's latency accounting ranks it hot, an online re-tune
# epoch-swaps a verified-better DB into the live pool) asserting the
# re-tuned throughput recovers >= 0.9x of the steady phase.
serve-smoke:
	cargo run --release --example serve_loadgen -- --smoke --out reports
	cargo run --release --example serve_loadgen -- --phase-shift \
		--assert-recovery 0.9 --out reports
