#!/usr/bin/env python3
"""Check that the repo's markdown docs stay coherent.

Two classes of check, both cheap and dependency-free (CI `docs` job):

1. Every relative markdown link in docs/*.md and ROADMAP.md resolves to
   a file that exists (external URLs are skipped).
2. The canonical docs exist and are actually referenced from the places
   the repo promises they are (ROADMAP.md and the crate docs in
   rust/src/lib.rs) — so the architecture/tuning docs cannot silently
   fall out of the entry points.
"""

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
FILES = sorted(ROOT.glob("docs/*.md")) + [ROOT / "ROADMAP.md"]
# [text](target) with an optional #anchor; bare URLs are not links.
LINK = re.compile(r"\[[^\]]*\]\(([^)#\s]+)(#[^)]*)?\)")

bad = []

for md in FILES:
    if not md.exists():
        bad.append(f"missing markdown file: {md.relative_to(ROOT)}")
        continue
    for match in LINK.finditer(md.read_text()):
        target = match.group(1)
        if re.match(r"[a-z][a-z0-9+.-]*://", target):
            continue  # external URL: out of scope for an offline check
        resolved = (md.parent / target).resolve()
        if not resolved.exists():
            bad.append(
                f"{md.relative_to(ROOT)}: broken link -> {target}"
            )

for required in ("docs/ARCHITECTURE.md", "docs/TUNING.md"):
    if not (ROOT / required).exists():
        bad.append(f"missing required doc: {required}")

# Tolerate missing files here: their absence is already reported above
# (or is its own finding below), and a clean report beats a traceback.
roadmap_path = ROOT / "ROADMAP.md"
lib_path = ROOT / "rust" / "src" / "lib.rs"
roadmap = roadmap_path.read_text() if roadmap_path.exists() else ""
lib_rs = lib_path.read_text() if lib_path.exists() else ""
for needle, haystack, where in (
    ("docs/ARCHITECTURE.md", roadmap, "ROADMAP.md"),
    ("docs/TUNING.md", roadmap, "ROADMAP.md"),
    ("docs/ARCHITECTURE.md", lib_rs, "rust/src/lib.rs crate docs"),
    ("docs/TUNING.md", lib_rs, "rust/src/lib.rs crate docs"),
):
    if needle not in haystack:
        bad.append(f"{where} no longer references {needle}")

if bad:
    print("\n".join(bad))
    sys.exit(1)
print(f"OK: {len(FILES)} markdown files checked, all references resolve")
