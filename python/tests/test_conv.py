"""Tiled direct convolution kernel vs XLA reference."""

import pytest

pytest.importorskip("jax", reason="JAX/Pallas is required for the kernel tests")
pytest.importorskip("hypothesis", reason="hypothesis is required for the property tests")

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.configs import ConvConfig
from compile.kernels import conv2d, conv2d_naive, ref

jax.config.update("jax_platform_name", "cpu")

TOL = dict(rtol=2e-4, atol=2e-4)


def _rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


class TestConvWindows:
    """Every window/stride/padding combination from Tables 3 & 4."""

    @pytest.mark.parametrize("window,stride,padding", [
        (1, 1, "SAME"),   # ResNet pointwise
        (3, 1, "SAME"),   # VGG / ResNet 3x3
        (3, 2, "SAME"),   # ResNet downsampling 3x3
        (7, 2, "VALID"),  # ResNet stem on the pre-padded 230x230 input
        (5, 1, "SAME"),
        (1, 2, "SAME"),
    ])
    def test_window_stride(self, window, stride, padding):
        x = _rand(0, (2, 15, 15, 8))
        f = _rand(1, (window, window, 8, 12))
        cfg = ConvConfig(tile_h=2, tile_w=2)
        out = conv2d(x, f, config=cfg, stride=stride, padding=padding)
        r = ref.conv2d_ref(x, f, stride=stride, padding=padding)
        assert out.shape == r.shape
        np.testing.assert_allclose(out, r, **TOL)


class TestConvTiles:
    """Tile size is a pure performance knob — results must be identical."""

    @pytest.mark.parametrize("tile", [(1, 1), (1, 4), (4, 1), (2, 2),
                                      (3, 3), (4, 5), (5, 4), (7, 7)])
    def test_tile_sweep(self, tile):
        x = _rand(0, (1, 14, 14, 4))
        f = _rand(1, (3, 3, 4, 8))
        cfg = ConvConfig(tile_h=tile[0], tile_w=tile[1])
        out = conv2d(x, f, config=cfg)
        np.testing.assert_allclose(out, ref.conv2d_ref(x, f), **TOL)

    @pytest.mark.parametrize("vec_c,vec_k", [(1, 1), (2, 2), (4, 2), (4, 4)])
    def test_vector_widths_inert(self, vec_c, vec_k):
        """vec_c/vec_k shape the hardware mapping, not the math."""
        x = _rand(0, (1, 8, 8, 4))
        f = _rand(1, (3, 3, 4, 8))
        base = conv2d(x, f, config=ConvConfig(tile_h=2, tile_w=2))
        out = conv2d(x, f, config=ConvConfig(tile_h=2, tile_w=2,
                                             vec_c=vec_c, vec_k=vec_k))
        np.testing.assert_allclose(out, base, rtol=0, atol=0)

    def test_vec_must_divide_channels(self):
        x = _rand(0, (1, 8, 8, 3))
        f = _rand(1, (3, 3, 3, 8))
        with pytest.raises(ValueError, match="vector widths"):
            conv2d(x, f, config=ConvConfig(vec_c=2))

    def test_block_k_splits_features(self):
        x = _rand(0, (1, 8, 8, 4))
        f = _rand(1, (3, 3, 4, 16))
        out = conv2d(x, f, config=ConvConfig(tile_h=2, tile_w=2, block_k=4))
        np.testing.assert_allclose(out, ref.conv2d_ref(x, f), **TOL)

    def test_block_k_must_divide(self):
        x = _rand(0, (1, 8, 8, 4))
        f = _rand(1, (3, 3, 4, 16))
        with pytest.raises(ValueError, match="block_k"):
            conv2d(x, f, config=ConvConfig(block_k=5))

    def test_tile_larger_than_output_clamps(self):
        x = _rand(0, (1, 4, 4, 4))
        f = _rand(1, (3, 3, 4, 8))
        out = conv2d(x, f, config=ConvConfig(tile_h=16, tile_w=16))
        np.testing.assert_allclose(out, ref.conv2d_ref(x, f), **TOL)


class TestConvNaive:
    def test_naive_matches_tiled(self):
        """Algorithm 1 (one output element per thread) is the 1x1 tile."""
        x = _rand(0, (1, 6, 6, 4))
        f = _rand(1, (3, 3, 4, 8))
        naive = conv2d_naive(x, f)
        tiled = conv2d(x, f, config=ConvConfig(tile_h=3, tile_w=3))
        np.testing.assert_allclose(naive, tiled, **TOL)
        np.testing.assert_allclose(naive, ref.conv2d_ref(x, f), **TOL)


class TestConvErrors:
    def test_rect_window_rejected(self):
        with pytest.raises(ValueError, match="square"):
            conv2d(_rand(0, (1, 8, 8, 4)), _rand(1, (3, 5, 4, 8)))

    def test_channel_mismatch_rejected(self):
        with pytest.raises(ValueError, match="channel"):
            conv2d(_rand(0, (1, 8, 8, 4)), _rand(1, (3, 3, 5, 8)))

    def test_bad_padding_rejected(self):
        with pytest.raises(ValueError, match="padding"):
            conv2d(_rand(0, (1, 8, 8, 4)), _rand(1, (3, 3, 4, 8)),
                   padding="CIRCULAR")


class TestConvProperty:
    @settings(max_examples=20, deadline=None)
    @given(
        h=st.integers(4, 20), w=st.integers(4, 20),
        c=st.sampled_from([1, 3, 4, 8]), k=st.sampled_from([1, 4, 8]),
        window=st.sampled_from([1, 3, 5]), stride=st.sampled_from([1, 2]),
        tile_h=st.integers(1, 4), tile_w=st.integers(1, 4),
    )
    def test_random_configs(self, h, w, c, k, window, stride, tile_h, tile_w):
        x = _rand(h * 31 + w, (1, h, w, c))
        f = _rand(c * 5 + k, (window, window, c, k))
        cfg = ConvConfig(tile_h=tile_h, tile_w=tile_w)
        out = conv2d(x, f, config=cfg, stride=stride)
        r = ref.conv2d_ref(x, f, stride=stride)
        assert out.shape == r.shape
        np.testing.assert_allclose(out, r, **TOL)

    @settings(max_examples=10, deadline=None)
    @given(scale=st.floats(0.125, 8.0))
    def test_linearity(self, scale):
        """conv(s*x) == s*conv(x): catches accumulation-order bugs."""
        x = _rand(0, (1, 8, 8, 4))
        f = _rand(1, (3, 3, 4, 8))
        cfg = ConvConfig(tile_h=2, tile_w=2)
        np.testing.assert_allclose(
            conv2d(scale * x, f, config=cfg),
            scale * conv2d(x, f, config=cfg), rtol=1e-3, atol=1e-3)
