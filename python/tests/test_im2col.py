"""im2col/GEMM-backed convolution vs XLA reference (paper §4)."""

import pytest

pytest.importorskip("jax", reason="JAX/Pallas is required for the kernel tests")
pytest.importorskip("hypothesis", reason="hypothesis is required for the property tests")

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.configs import GemmConfig
from compile.kernels import conv2d_im2col, im2col, ref

jax.config.update("jax_platform_name", "cpu")

TOL = dict(rtol=2e-4, atol=2e-4)


def _rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


class TestIm2col:
    def test_patch_shape(self):
        x = _rand(0, (2, 10, 12, 3))
        cols = im2col(x, 3, 1, "SAME")
        assert cols.shape == (2 * 10 * 12, 3 * 3 * 3)

    def test_patch_shape_strided(self):
        x = _rand(0, (1, 8, 8, 4))
        cols = im2col(x, 3, 2, "SAME")
        assert cols.shape == (4 * 4, 36)

    def test_patch_values_center_tap(self):
        """The center tap of a 3x3 SAME patch matrix is the input itself."""
        x = _rand(0, (1, 6, 6, 2))
        cols = im2col(x, 3, 1, "SAME")
        cols = cols.reshape(6 * 6, 9, 2)
        center = cols[:, 4, :].reshape(6, 6, 2)
        np.testing.assert_allclose(center, x[0], rtol=0, atol=0)

    def test_valid_padding(self):
        x = _rand(0, (1, 9, 9, 2))
        cols = im2col(x, 3, 1, "VALID")
        assert cols.shape == (7 * 7, 18)


class TestConvIm2col:
    @pytest.mark.parametrize("window,stride,padding", [
        (1, 1, "SAME"), (3, 1, "SAME"), (3, 2, "SAME"), (7, 2, "VALID"),
    ])
    def test_matches_reference(self, window, stride, padding):
        x = _rand(0, (2, 15, 15, 8))
        f = _rand(1, (window, window, 8, 12))
        out = conv2d_im2col(x, f, stride=stride, padding=padding)
        r = ref.conv2d_ref(x, f, stride=stride, padding=padding)
        assert out.shape == r.shape
        np.testing.assert_allclose(out, r, **TOL)

    def test_gemm_config_inert(self):
        x = _rand(0, (1, 8, 8, 4))
        f = _rand(1, (3, 3, 4, 8))
        a = conv2d_im2col(x, f, gemm_config=GemmConfig.parse("4x4_8x8_loc"))
        b = conv2d_im2col(x, f,
                          gemm_config=GemmConfig.parse("8x4_8x16_noloc"))
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)

    def test_pointwise_fast_path(self):
        """1x1/s1 im2col must be a pure reshape (same numbers as GEMM)."""
        x = _rand(0, (2, 7, 7, 16))
        f = _rand(1, (1, 1, 16, 32))
        out = conv2d_im2col(x, f)
        r = ref.conv2d_ref(x, f)
        np.testing.assert_allclose(out, r, **TOL)

    @settings(max_examples=15, deadline=None)
    @given(h=st.integers(3, 16), w=st.integers(3, 16),
           c=st.sampled_from([1, 4]), k=st.sampled_from([1, 8]),
           window=st.sampled_from([1, 3]), stride=st.sampled_from([1, 2]))
    def test_property(self, h, w, c, k, window, stride):
        x = _rand(h * 13 + w, (1, h, w, c))
        f = _rand(7, (window, window, c, k))
        out = conv2d_im2col(x, f, stride=stride)
        np.testing.assert_allclose(
            out, ref.conv2d_ref(x, f, stride=stride), **TOL)
