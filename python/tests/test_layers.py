"""Network layer tables (paper Tables 3 & 4) and L2 layer graphs."""

import pytest

pytest.importorskip("jax", reason="JAX/Pallas is required for the kernel tests")

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.configs import (ConvAlgorithm, ConvConfig, GemmConfig,
                             LayerSpec, RESNET_LAYERS, VGG_LAYERS)
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

TOL = dict(rtol=2e-4, atol=2e-4)


class TestVggTable:
    """Paper Table 3."""

    def test_layer_count(self):
        assert len(VGG_LAYERS) == 9

    def test_all_3x3_stride1(self):
        assert all(l.window == 3 and l.stride == 1 for l in VGG_LAYERS)

    @pytest.mark.parametrize("name,out", [
        ("conv1_1", (224, 224, 64)), ("conv2_1", (112, 112, 128)),
        ("conv3_2", (56, 56, 256)), ("conv4_2", (28, 28, 512)),
        ("conv5_1", (14, 14, 512)),
    ])
    def test_output_shapes(self, name, out):
        layer = next(l for l in VGG_LAYERS if l.name == name)
        assert (layer.out_h, layer.out_w, layer.out_c) == out


class TestResnetTable:
    """Paper Table 4."""

    def test_layer_count(self):
        assert len(RESNET_LAYERS) == 26

    def test_stem(self):
        stem = RESNET_LAYERS[0]
        assert (stem.window, stem.stride) == (7, 2)
        assert (stem.in_h, stem.in_w, stem.in_c) == (230, 230, 3)
        assert (stem.out_h, stem.out_w, stem.out_c) == (112, 112, 64)

    @pytest.mark.parametrize("name,out", [
        ("conv2_5", (28, 28, 64)),   # 3x3/s2 SAME: 56 -> 28
        ("conv3_7", (14, 14, 128)),
        ("conv4_7", (7, 7, 256)),
        ("conv5_2", (7, 7, 2048)),
    ])
    def test_output_shapes(self, name, out):
        layer = next(l for l in RESNET_LAYERS if l.name == name)
        assert (layer.out_h, layer.out_w, layer.out_c) == out

    def test_pointwise_majority(self):
        """ResNet is dominated by 1x1 convolutions — the GEMM-bound case
        the paper's §5.3 discussion hinges on."""
        ones = sum(1 for l in RESNET_LAYERS if l.window == 1)
        assert ones == 18  # 18 of 26 distinct layers are pointwise


class TestFlops:
    def test_flops_formula(self):
        l = LayerSpec("t", 3, 1, 8, 8, 4, 16)
        assert l.flops(batch=2) == 2 * 2 * 8 * 8 * 16 * 3 * 3 * 4

    def test_flops_scale_with_batch(self):
        l = VGG_LAYERS[0]
        assert l.flops(batch=4) == 4 * l.flops(batch=1)


def _scaled(layer: LayerSpec, hw: int = 14) -> LayerSpec:
    """Shrink a layer spatially (channels intact) for interpreter speed."""
    if layer.padding == "VALID":
        hw = hw + layer.window - layer.stride
    return dataclasses.replace(layer, in_h=hw, in_w=hw)


class TestLayerFn:
    """L2 graphs produce reference numerics for every algorithm."""

    @pytest.mark.parametrize("alg", [ConvAlgorithm.TILED,
                                     ConvAlgorithm.IM2COL,
                                     ConvAlgorithm.WINOGRAD])
    def test_vgg_layer(self, alg):
        layer = _scaled(dataclasses.replace(VGG_LAYERS[0], name="t"))
        cfg = ConvConfig(tile_h=2, tile_w=2, algorithm=alg)
        fn, specs = model.layer_fn(layer, batch=1, config=cfg)
        args = [jax.random.normal(jax.random.PRNGKey(i), s.shape, s.dtype)
                for i, s in enumerate(specs)]
        (out,) = fn(*args)
        expected = jnp.maximum(
            ref.conv2d_ref(args[0], args[1], stride=1) + args[2], 0.0)
        np.testing.assert_allclose(out, expected, rtol=2e-3, atol=2e-3)

    @pytest.mark.parametrize("idx", [0, 1, 3, 5])  # stem, 1x1, 3x3, 3x3/s2
    def test_resnet_layers(self, idx):
        layer = _scaled(RESNET_LAYERS[idx])
        cfg = ConvConfig(tile_h=2, tile_w=2, algorithm=ConvAlgorithm.TILED)
        fn, specs = model.layer_fn(layer, batch=1, config=cfg,
                                   fuse_relu=False)
        args = [jax.random.normal(jax.random.PRNGKey(i), s.shape, s.dtype)
                for i, s in enumerate(specs)]
        (out,) = fn(*args)
        expected = ref.conv2d_ref(args[0], args[1], stride=layer.stride,
                                  padding=layer.padding)
        assert out.shape == expected.shape
        np.testing.assert_allclose(out, expected, **TOL)

    def test_xla_variant_matches(self):
        layer = _scaled(RESNET_LAYERS[2])
        fn, specs = model.layer_fn_xla(layer, batch=1)
        args = [jax.random.normal(jax.random.PRNGKey(i), s.shape, s.dtype)
                for i, s in enumerate(specs)]
        (out,) = fn(*args)
        expected = jnp.maximum(
            ref.conv2d_ref(args[0], args[1]) + args[2], 0.0)
        np.testing.assert_allclose(out, expected, **TOL)

    def test_winograd_rejected_for_non_3x3(self):
        layer = _scaled(RESNET_LAYERS[1])  # 1x1
        cfg = ConvConfig(algorithm=ConvAlgorithm.WINOGRAD)
        fn, specs = model.layer_fn(layer, batch=1, config=cfg)
        args = [jnp.zeros(s.shape, s.dtype) for s in specs]
        with pytest.raises(ValueError, match="winograd"):
            fn(*args)


class TestGemmFn:
    def test_gemm_fn(self):
        fn, specs = model.gemm_fn(32, 24, 16, config=GemmConfig())
        a = jax.random.normal(jax.random.PRNGKey(0), specs[0].shape)
        b = jax.random.normal(jax.random.PRNGKey(1), specs[1].shape)
        (out,) = fn(a, b)
        np.testing.assert_allclose(out, ref.gemm_ref(a, b), **TOL)

    def test_gemm_fn_xla_native(self):
        fn, specs = model.gemm_fn(32, 24, 16, config=GemmConfig(),
                                  xla_native=True)
        a = jax.random.normal(jax.random.PRNGKey(0), specs[0].shape)
        b = jax.random.normal(jax.random.PRNGKey(1), specs[1].shape)
        (out,) = fn(a, b)
        np.testing.assert_allclose(out, ref.gemm_ref(a, b), **TOL)

    def test_gemm_fn_with_c(self):
        fn, specs = model.gemm_fn(16, 16, 16, config=GemmConfig(),
                                  alpha=1.5, beta=0.5, with_c=True)
        args = [jax.random.normal(jax.random.PRNGKey(i), s.shape)
                for i, s in enumerate(specs)]
        (out,) = fn(*args)
        np.testing.assert_allclose(
            out, ref.gemm_ref(*args, alpha=1.5, beta=0.5), **TOL)
