"""Shared pytest setup for the kernel test suites.

Puts ``python/`` on ``sys.path`` so ``from compile...`` imports work no
matter which directory pytest is invoked from.

Availability guards live in the test modules themselves: each
``test_*.py`` opens with ``pytest.importorskip("jax")`` (and
``"hypothesis"`` where used) *before* its heavy imports, so on machines
without the JAX/Pallas stack ``pytest python/tests -q`` reports the
modules as skipped instead of erroring at collection.
"""

import os
import sys

sys.path.insert(
    0, os.path.abspath(os.path.join(os.path.dirname(__file__), os.pardir))
)
