"""GEMM Pallas kernel vs pure-jnp oracle — the core correctness signal.

The paper's central claim is that the *parametrization never changes the
mathematics*: every configuration of the kernel family must agree with the
reference.  Hypothesis sweeps shapes and configurations.
"""

import pytest

pytest.importorskip("jax", reason="JAX/Pallas is required for the kernel tests")
pytest.importorskip("hypothesis", reason="hypothesis is required for the property tests")

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.configs import DEFAULT_CACHE_LINE_ELEMS, GemmConfig, TABLE2_CONFIGS
from compile.kernels import gemm, gemm_batched, ref

jax.config.update("jax_platform_name", "cpu")

TOL = dict(rtol=2e-4, atol=2e-4)


def _rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


class TestGemmConfigs:
    """Every Table-2 configuration computes the same product."""

    @pytest.mark.parametrize("cfg", TABLE2_CONFIGS, ids=lambda c: c.name)
    def test_table2_config(self, cfg):
        a, b = _rand(0, (96, 48)), _rand(1, (48, 64))
        out = gemm(a, b, config=cfg)
        np.testing.assert_allclose(out, ref.gemm_ref(a, b), **TOL)

    @pytest.mark.parametrize("cfg", TABLE2_CONFIGS, ids=lambda c: c.name)
    def test_table2_config_alpha_beta(self, cfg):
        a, b, c = _rand(0, (80, 40)), _rand(1, (40, 56)), _rand(2, (80, 56))
        out = gemm(a, b, c, config=cfg, alpha=1.5, beta=-0.5)
        np.testing.assert_allclose(
            out, ref.gemm_ref(a, b, c, alpha=1.5, beta=-0.5), **TOL)

    def test_double_buffer_config_same_result(self):
        """double_buffer is a schedule hint, never a numerics change."""
        a, b = _rand(0, (64, 64)), _rand(1, (64, 64))
        base = GemmConfig.parse("8x4_8x16_loc")
        db = GemmConfig.parse("8x4_8x16_loc_db")
        np.testing.assert_allclose(
            gemm(a, b, config=base), gemm(a, b, config=db), rtol=0, atol=0)


class TestGemmOps:
    @pytest.mark.parametrize("ta,tb", [(False, False), (True, False),
                                       (False, True), (True, True)])
    def test_transposes(self, ta, tb):
        m, n, k = 72, 56, 40
        a = _rand(0, (k, m) if ta else (m, k))
        b = _rand(1, (n, k) if tb else (k, n))
        c = _rand(2, (m, n))
        out = gemm(a, b, c, alpha=2.0, beta=1.0, trans_a=ta, trans_b=tb)
        np.testing.assert_allclose(
            ref.gemm_ref(a, b, c, alpha=2.0, beta=1.0, trans_a=ta,
                         trans_b=tb), out, **TOL)

    def test_beta_without_c_raises(self):
        a, b = _rand(0, (8, 8)), _rand(1, (8, 8))
        with pytest.raises(ValueError, match="beta"):
            gemm(a, b, beta=0.5)

    def test_contraction_mismatch_raises(self):
        with pytest.raises(ValueError, match="contraction"):
            gemm(_rand(0, (8, 9)), _rand(1, (8, 8)))

    def test_beta_only(self):
        """alpha=0 reduces to a scaled copy of C."""
        a, b, c = _rand(0, (32, 16)), _rand(1, (16, 24)), _rand(2, (32, 24))
        out = gemm(a, b, c, alpha=0.0, beta=3.0)
        np.testing.assert_allclose(out, 3.0 * c, **TOL)

    def test_identity(self):
        eye = jnp.eye(48, dtype=jnp.float32)
        b = _rand(1, (48, 32))
        np.testing.assert_allclose(gemm(eye, b), b, **TOL)


class TestGemmShapes:
    """Padding correctness: sizes that are not block multiples."""

    @pytest.mark.parametrize("m,n,k", [
        (1, 1, 1), (7, 5, 3), (33, 65, 17), (64, 64, 64),
        (100, 50, 70), (129, 127, 65),
    ])
    def test_odd_shapes(self, m, n, k):
        a, b = _rand(0, (m, k)), _rand(1, (k, n))
        np.testing.assert_allclose(gemm(a, b), ref.gemm_ref(a, b), **TOL)

    @settings(max_examples=25, deadline=None)
    @given(
        m=st.integers(1, 150), n=st.integers(1, 150), k=st.integers(1, 100),
        rt_m=st.sampled_from([1, 2, 4, 8]), rt_n=st.sampled_from([1, 2, 4, 8]),
        wg_r=st.sampled_from([2, 4, 8]), wg_c=st.sampled_from([2, 4, 8]),
        use_local=st.booleans(),
    )
    def test_property_shapes_and_configs(self, m, n, k, rt_m, rt_n, wg_r,
                                         wg_c, use_local):
        cfg = GemmConfig(rt_m=rt_m, rt_n=rt_n, wg_r=wg_r, wg_c=wg_c,
                         use_local=use_local)
        a, b = _rand(m * 7 + n, (m, k)), _rand(k * 3 + 1, (k, n))
        out = gemm(a, b, config=cfg)
        np.testing.assert_allclose(out, ref.gemm_ref(a, b), **TOL)


class TestGemmBatched:
    @pytest.mark.parametrize("g,m,n,k", [(1, 16, 16, 16), (4, 33, 29, 17),
                                         (16, 8, 8, 8), (3, 100, 20, 50)])
    def test_batched(self, g, m, n, k):
        a, b = _rand(0, (g, m, k)), _rand(1, (g, k, n))
        np.testing.assert_allclose(
            gemm_batched(a, b), ref.gemm_batched_ref(a, b), **TOL)

    def test_batched_mismatch_raises(self):
        with pytest.raises(ValueError, match="batched"):
            gemm_batched(_rand(0, (2, 8, 8)), _rand(1, (3, 8, 8)))


class TestConfigSchema:
    def test_parse_roundtrip(self):
        for cfg in TABLE2_CONFIGS:
            assert GemmConfig.parse(cfg.name) == cfg

    def test_parse_rejects_garbage(self):
        for bad in ["", "4x4", "4x4_8x8_bogus"]:
            with pytest.raises(ValueError):
                GemmConfig.parse(bad)

    def test_table2_registers_column(self):
        """Paper Table 2 'Registers' column."""
        regs = {c.name: c.registers for c in TABLE2_CONFIGS}
        assert regs["4x4_8x8_loc"] == 16
        assert regs["4x4_16x16_loc"] == 16
        assert regs["8x4_8x16_loc"] == 32
        assert regs["8x2_4x16_loc"] == 16
        assert regs["8x4_8x16_noloc"] == 32
        assert regs["8x4_4x8_noloc"] == 32
        assert regs["4x4_8x8_noloc"] == 16

    def test_table2_workgroup_column(self):
        """Paper Table 2 'Work group' column."""
        wgs = {c.name: c.work_group for c in TABLE2_CONFIGS}
        assert wgs["4x4_8x8_loc"] == 64
        assert wgs["4x4_16x16_loc"] == 256
        assert wgs["8x4_8x16_loc"] == 128
        assert wgs["8x2_4x16_loc"] == 64
        assert wgs["8x4_8x16_noloc"] == 128
        assert wgs["8x4_4x8_noloc"] == 32
        assert wgs["4x4_8x8_noloc"] == 64

    def test_table2_localmem_column(self):
        """Paper Table 2 'Local mem' column (KiB of f32 elements)."""
        x = DEFAULT_CACHE_LINE_ELEMS
        kib = {c.name: c.local_mem_elems(x) * 4 / 1024 for c in TABLE2_CONFIGS}
        assert kib["4x4_8x8_loc"] == 8
        assert kib["4x4_16x16_loc"] == 16
        assert kib["8x4_8x16_loc"] == 16
        assert kib["8x2_4x16_loc"] == 8
        assert kib["8x4_8x16_noloc"] == 0
        assert kib["8x4_4x8_noloc"] == 0
        assert kib["4x4_8x8_noloc"] == 0

    def test_double_buffer_doubles_local_mem(self):
        base = GemmConfig.parse("8x4_8x16_loc")
        db = GemmConfig.parse("8x4_8x16_loc_db")
        assert db.local_mem_elems() == 2 * base.local_mem_elems()
