"""AOT pipeline tests: manifest integrity and HLO round-trip."""

import pytest

pytest.importorskip("jax", reason="JAX/Pallas is required for the kernel tests")

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, manifests
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


class TestManifests:
    def test_no_duplicate_names(self):
        entries = manifests.all_entries()
        assert len({e.name for e in entries}) == len(entries)

    def test_group_selection(self):
        core = manifests.select(["core"])
        assert core and all("core" in e.groups for e in core)
        assert len(manifests.select(["all"])) == len(manifests.all_entries())

    def test_gemm_group_covers_table2(self):
        gemm = manifests.select(["gemm"])
        cfgs = {e.gemm_config.name for e in gemm if e.impl == "pallas"}
        assert len(cfgs) == 7  # every Table-2 config is measured

    def test_every_shape_has_vendor_baseline(self):
        gemm = manifests.select(["gemm"])
        shapes_pallas = {(e.m, e.n, e.k) for e in gemm if e.impl == "pallas"}
        shapes_xla = {(e.m, e.n, e.k) for e in gemm if e.impl == "xla"}
        assert shapes_pallas == shapes_xla

    def test_winograd_only_on_3x3_s1(self):
        conv = [e for e in manifests.select(["conv"])
                if e.conv_config is not None
                and e.conv_config.algorithm.value == "winograd"]
        assert conv, "expected winograd entries"
        for e in conv:
            assert e.layer.window == 3 and e.layer.stride == 1

    def test_conv_entries_carry_large_block_gemm(self):
        """Measured im2col/winograd conv artifacts must use the
        large-macro-tile GEMM (interpret-mode grid economy; see
        EXPERIMENTS.md §Perf L2)."""
        for e in manifests.select(["conv"]):
            if e.impl == "pallas":
                assert e.conv_gemm_config is manifests.CONV_GEMM
        assert manifests.CONV_GEMM.block_m == 128
        assert manifests.CONV_GEMM.block_n == 128

    def test_scaled_layers_tagged(self):
        conv = manifests.select(["conv"])
        for e in conv:
            if e.impl == "pallas" and e.layer is not None:
                assert max(e.layer.in_h, e.layer.in_w) <= 62
                if e.scaled_from is not None:
                    assert "x" in e.scaled_from


class TestLowering:
    def test_build_entry_metadata(self):
        e = manifests.core_entries()[0]  # quickstart_gemm
        fn, specs, meta = aot.build_entry(e)
        assert meta["name"] == "quickstart_gemm"
        assert meta["flops"] == 2 * 64 ** 3
        assert [tuple(i["shape"]) for i in meta["inputs"]] == [
            (64, 64), (64, 64)]

    def test_hlo_text_roundtrip(self, tmp_path):
        """Lower quickstart, then re-execute the HLO via jax and compare."""
        e = manifests.core_entries()[0]
        meta, built = aot.lower_entry(e, str(tmp_path))
        assert built
        path = tmp_path / meta["file"]
        text = path.read_text()
        assert text.startswith("HloModule")
        assert meta["outputs"][0]["shape"] == [64, 64]

        # The HLO-text parse+compile+execute path is exercised end-to-end on
        # the Rust side (rust/tests); here we check numerics of the lowered
        # function itself.
        fn, specs, _ = aot.build_entry(e)
        a = jax.random.normal(jax.random.PRNGKey(0), specs[0].shape)
        b = jax.random.normal(jax.random.PRNGKey(1), specs[1].shape)
        (out,) = jax.jit(fn)(a, b)
        np.testing.assert_allclose(out, ref.gemm_ref(a, b),
                                   rtol=2e-4, atol=2e-4)

    def test_constants_never_elided(self, tmp_path):
        """Regression: the default HLO printer elides array constants as
        `{...}`, which the Rust parser silently reads as zeros.  The
        Winograd artifact carries constant transform matrices, so its HLO
        must contain no elided constants."""
        e = next(x for x in manifests.core_entries()
                 if x.name == "test_conv_wino")
        meta, _ = aot.lower_entry(e, str(tmp_path))
        text = (tmp_path / meta["file"]).read_text()
        assert "constant({...})" not in text
        assert "{...}" not in text

    def test_incremental_build_skips(self, tmp_path):
        e = manifests.core_entries()[0]
        _, built1 = aot.lower_entry(e, str(tmp_path))
        _, built2 = aot.lower_entry(e, str(tmp_path))
        assert built1 and not built2

    def test_build_writes_manifest(self, tmp_path):
        metas = aot.build(str(tmp_path), ["core"], verbose=False)
        m = json.loads((tmp_path / "manifest.json").read_text())
        assert m["version"] == aot.MANIFEST_VERSION
        assert len(m["artifacts"]) == len(metas)
        for art in m["artifacts"]:
            assert (tmp_path / art["file"]).exists()
            assert art["flops"] > 0
