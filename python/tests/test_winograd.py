"""Winograd F(m x m, 3 x 3) convolution vs XLA reference (paper §4.1.2)."""

import pytest

pytest.importorskip("jax", reason="JAX/Pallas is required for the kernel tests")
pytest.importorskip("hypothesis", reason="hypothesis is required for the property tests")

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.configs import ConvAlgorithm, ConvConfig, GemmConfig
from compile.kernels import conv2d_winograd, ref, transform_matrices, winograd_flops

jax.config.update("jax_platform_name", "cpu")

# Winograd trades flops for numerical headroom; F(4,3) in particular has
# larger transform constants, so the tolerance is looser than direct conv.
TOL = dict(rtol=2e-3, atol=2e-3)


def _rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


def _wcfg(m):
    return ConvConfig(algorithm=ConvAlgorithm.WINOGRAD, wino_m=m)


class TestTransformMatrices:
    @pytest.mark.parametrize("m", [2, 4])
    def test_transform_correctness_1d(self, m):
        """A^T [ (B^T d) * (G g) ] == conv1d(d, g) for all unit vectors.

        This is the defining identity of the Cook-Toom/Winograd transform;
        checking it on a basis checks it everywhere (bilinearity).
        """
        bt, g, at = transform_matrices(m)
        alpha = m + 2
        for di in range(alpha):
            for gi in range(3):
                d = np.zeros(alpha, np.float32); d[di] = 1.0
                ker = np.zeros(3, np.float32); ker[gi] = 1.0
                out = at @ ((bt @ d) * (g @ ker))
                expected = np.array(
                    [sum(d[o + j] * ker[j] for j in range(3))
                     for o in range(m)], np.float32)
                np.testing.assert_allclose(out, expected, rtol=1e-5,
                                           atol=1e-5)

    def test_unsupported_m_raises(self):
        with pytest.raises(ValueError, match="Winograd tile"):
            transform_matrices(3)


class TestWinogradConv:
    @pytest.mark.parametrize("m", [2, 4])
    @pytest.mark.parametrize("hw", [(4, 4), (8, 8), (14, 14), (7, 9)])
    def test_matches_reference(self, m, hw):
        x = _rand(0, (2, hw[0], hw[1], 4))
        f = _rand(1, (3, 3, 4, 8))
        out = conv2d_winograd(x, f, config=_wcfg(m))
        r = ref.conv2d_ref(x, f, stride=1, padding="SAME")
        assert out.shape == r.shape
        np.testing.assert_allclose(out, r, **TOL)

    @pytest.mark.parametrize("m", [2, 4])
    def test_gemm_config_inert(self, m):
        """The batched-GEMM parametrization must not change results."""
        x = _rand(0, (1, 8, 8, 4))
        f = _rand(1, (3, 3, 4, 8))
        a = conv2d_winograd(x, f, config=_wcfg(m),
                            gemm_config=GemmConfig.parse("4x4_8x8_loc"))
        b = conv2d_winograd(x, f, config=_wcfg(m),
                            gemm_config=GemmConfig.parse("8x4_4x8_noloc"))
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)

    def test_non_3x3_rejected(self):
        with pytest.raises(ValueError, match="3x3"):
            conv2d_winograd(_rand(0, (1, 8, 8, 4)), _rand(1, (5, 5, 4, 8)),
                            config=_wcfg(2))

    def test_channel_mismatch_rejected(self):
        with pytest.raises(ValueError, match="channel"):
            conv2d_winograd(_rand(0, (1, 8, 8, 4)), _rand(1, (3, 3, 5, 8)),
                            config=_wcfg(2))

    @settings(max_examples=10, deadline=None)
    @given(h=st.integers(4, 16), w=st.integers(4, 16),
           c=st.sampled_from([1, 4]), k=st.sampled_from([1, 8]),
           m=st.sampled_from([2, 4]))
    def test_property_shapes(self, h, w, c, k, m):
        x = _rand(h * 17 + w, (1, h, w, c))
        f = _rand(3, (3, 3, c, k))
        out = conv2d_winograd(x, f, config=_wcfg(m))
        np.testing.assert_allclose(
            out, ref.conv2d_ref(x, f, stride=1, padding="SAME"), **TOL)


class TestWinogradFlops:
    def test_flop_reduction(self):
        """Paper: Winograd cuts op count "to as little as 30%".

        F(4x4, 3x3): 36 multiplies per 16 outputs vs 144 direct -> 25%
        (plus transforms); F(2x2, 3x3): 16 vs 36 -> 44%.
        """
        n, h, w, c, k = 1, 56, 56, 64, 64
        direct = 2 * n * h * w * k * 9 * c
        f2 = winograd_flops(n, h, w, c, k, 2)
        f4 = winograd_flops(n, h, w, c, k, 4)
        assert f2 / direct == pytest.approx(16 / 36, rel=0.01)
        assert f4 / direct == pytest.approx(36 / 144, rel=0.01)
