fn main() {}
