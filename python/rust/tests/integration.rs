// filled in later
