"""Kernel configuration schema shared between the Python compile path and the
Rust coordinator.

This mirrors the paper's template-parameter space:

* ``GemmConfig`` — SYCL-BLAS §3.1 GEMM parameters.  A configuration string
  ``hxw_rxc[_loc|_noloc][_db]`` matches the paper's Table 2 naming:
  ``h x w`` is the register tile computed per "thread" and ``r x c`` the
  work-group shape.  The Pallas block computed per grid cell is therefore
  ``(h*r) x (w*c)``.
* ``ConvConfig`` — SYCL-DNN §4.1 tiled-convolution parameters: output tile
  shape and channel vector widths.

The JSON emitted by :func:`to_json` is the wire format consumed by
``rust/src/config`` (serde) — field names must stay in sync.
"""

from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass
from enum import Enum
from typing import List, Optional, Tuple

# Number of f32 elements staged per panel row/column — "X" in the paper's
# local-memory size formula `h*r*X + X*w*c` (§5.2).  Back-solving Table 2
# (e.g. 4x4_8x8_loc -> 8 KiB means 64*X*4 bytes = 8192) gives X = 32, i.e.
# a 128-byte staging granularity (two 64-byte cache lines per fetch).
DEFAULT_CACHE_LINE_ELEMS = 32


@dataclass(frozen=True)
class GemmConfig:
    """Parameters of the blocked GEMM kernel (paper §3.1.1).

    Attributes:
        rt_m, rt_n: register tile per thread (``h x w`` in the paper).
        wg_r, wg_c: work-group thread grid (``r x c``).
        block_k:    k'-panel depth staged per iteration (cache-line elems).
        use_local:  stage A/B panels through local memory (``_loc``).
        double_buffer: double the local-memory staging buffers to overlap
            loads of tile *i+1* with compute on tile *i* (§3.1.2).
    """

    rt_m: int = 4
    rt_n: int = 4
    wg_r: int = 8
    wg_c: int = 8
    block_k: int = DEFAULT_CACHE_LINE_ELEMS
    use_local: bool = True
    double_buffer: bool = False

    @property
    def block_m(self) -> int:
        return self.rt_m * self.wg_r

    @property
    def block_n(self) -> int:
        return self.rt_n * self.wg_c

    @property
    def registers(self) -> int:
        """Accumulator registers per thread (paper Table 2 'Registers')."""
        return self.rt_m * self.rt_n

    @property
    def work_group(self) -> int:
        """Threads per work-group (paper Table 2 'Work group')."""
        return self.wg_r * self.wg_c

    def local_mem_elems(self, cache_line_elems: int = DEFAULT_CACHE_LINE_ELEMS) -> int:
        """Local-memory footprint in data elements.

        Paper §5.2: for configuration ``hxw_rxc`` the footprint is
        ``h*r*X + X*w*c`` where X is the cache-line element count; doubled
        when double buffering.
        """
        if not self.use_local:
            return 0
        x = cache_line_elems
        elems = self.rt_m * self.wg_r * x + x * self.rt_n * self.wg_c
        return 2 * elems if self.double_buffer else elems

    @property
    def name(self) -> str:
        tag = "loc" if self.use_local else "noloc"
        db = "_db" if self.double_buffer else ""
        return f"{self.rt_m}x{self.rt_n}_{self.wg_r}x{self.wg_c}_{tag}{db}"

    @staticmethod
    def parse(name: str) -> "GemmConfig":
        """Parse a paper-style config string such as ``8x4_8x16_loc``."""
        parts = name.split("_")
        if len(parts) < 2:
            raise ValueError(f"bad gemm config string: {name!r}")
        rt = parts[0].split("x")
        wg = parts[1].split("x")
        use_local = True
        double_buffer = False
        for p in parts[2:]:
            if p == "loc":
                use_local = True
            elif p == "noloc":
                use_local = False
            elif p == "db":
                double_buffer = True
            else:
                raise ValueError(f"bad gemm config suffix {p!r} in {name!r}")
        return GemmConfig(
            rt_m=int(rt[0]),
            rt_n=int(rt[1]),
            wg_r=int(wg[0]),
            wg_c=int(wg[1]),
            use_local=use_local,
            double_buffer=double_buffer,
        )


#: The seven SYCL-BLAS configurations evaluated in the paper (Table 2).
TABLE2_CONFIGS: Tuple[GemmConfig, ...] = (
    GemmConfig.parse("4x4_8x8_loc"),
    GemmConfig.parse("4x4_16x16_loc"),
    GemmConfig.parse("8x4_8x16_loc"),
    GemmConfig.parse("8x2_4x16_loc"),
    GemmConfig.parse("8x4_8x16_noloc"),
    GemmConfig.parse("8x4_4x8_noloc"),
    GemmConfig.parse("4x4_8x8_noloc"),
)


class ConvAlgorithm(str, Enum):
    """Convolution algorithms provided by the library (paper §4.1)."""

    NAIVE = "naive"  # one output element per thread (tile 1x1)
    TILED = "tiled"  # §4.1.1 tiled direct convolution
    IM2COL = "im2col"  # lower to GEMM via im2col (BLAS-backed path)
    WINOGRAD = "winograd"  # §4.1.2 Winograd/Cook-Toom fast convolution


@dataclass(frozen=True)
class ConvConfig:
    """Parameters of the tiled direct convolution kernel (paper §4.1.1).

    Attributes:
        tile_h, tile_w: output elements computed per thread.
        vec_c: input-channel vector width (vector loads of the input).
        vec_k: output-channel (feature) vector width (vector stores).
        block_k: output channels computed per grid cell; ``0`` = all.
        algorithm: which convolution algorithm this config drives.
        wino_m: Winograd output-tile size m for F(m x m, 3 x 3).
    """

    tile_h: int = 1
    tile_w: int = 1
    vec_c: int = 1
    vec_k: int = 1
    block_k: int = 0
    algorithm: ConvAlgorithm = ConvAlgorithm.TILED
    wino_m: int = 2

    @property
    def name(self) -> str:
        if self.algorithm == ConvAlgorithm.WINOGRAD:
            return f"wino{self.wino_m}_v{self.vec_c}x{self.vec_k}"
        base = f"{self.algorithm.value}_{self.tile_h}x{self.tile_w}_v{self.vec_c}x{self.vec_k}"
        return base

    @staticmethod
    def naive() -> "ConvConfig":
        return ConvConfig(tile_h=1, tile_w=1, vec_c=1, vec_k=1, algorithm=ConvAlgorithm.NAIVE)


@dataclass(frozen=True)
class LayerSpec:
    """One convolution layer (paper Tables 3 & 4).

    ``padding`` follows the paper's conventions: VGG/ResNet internal layers
    use SAME padding (spatial size preserved for stride 1, halved and
    rounded up for stride 2); ResNet's first 7x7/s2 layer is listed with a
    pre-padded 230x230 input and uses VALID padding.
    """

    name: str
    window: int
    stride: int
    in_h: int
    in_w: int
    in_c: int
    out_c: int
    padding: str = "SAME"  # "SAME" | "VALID"

    @property
    def out_h(self) -> int:
        if self.padding == "SAME":
            return -(-self.in_h // self.stride)
        return (self.in_h - self.window) // self.stride + 1

    @property
    def out_w(self) -> int:
        if self.padding == "SAME":
            return -(-self.in_w // self.stride)
        return (self.in_w - self.window) // self.stride + 1

    def flops(self, batch: int = 1) -> int:
        """Multiply-add FLOPs (2 * madds) for the direct convolution."""
        return (
            2
            * batch
            * self.out_h
            * self.out_w
            * self.out_c
            * self.window
            * self.window
            * self.in_c
        )


#: VGG-16 distinct convolution layers (paper Table 3).
VGG_LAYERS: Tuple[LayerSpec, ...] = (
    LayerSpec("conv1_1", 3, 1, 224, 224, 3, 64),
    LayerSpec("conv1_2", 3, 1, 224, 224, 64, 64),
    LayerSpec("conv2_1", 3, 1, 112, 112, 64, 128),
    LayerSpec("conv2_2", 3, 1, 112, 112, 128, 128),
    LayerSpec("conv3_1", 3, 1, 56, 56, 128, 256),
    LayerSpec("conv3_2", 3, 1, 56, 56, 256, 256),
    LayerSpec("conv4_1", 3, 1, 28, 28, 256, 512),
    LayerSpec("conv4_2", 3, 1, 28, 28, 512, 512),
    LayerSpec("conv5_1", 3, 1, 14, 14, 512, 512),
)

#: ResNet-50 distinct convolution layers (paper Table 4).
RESNET_LAYERS: Tuple[LayerSpec, ...] = (
    LayerSpec("conv1_1", 7, 2, 230, 230, 3, 64, padding="VALID"),
    LayerSpec("conv2_1", 1, 1, 56, 56, 64, 256),
    LayerSpec("conv2_2", 1, 1, 56, 56, 64, 64),
    LayerSpec("conv2_3", 3, 1, 56, 56, 64, 64),
    LayerSpec("conv2_4", 1, 1, 56, 56, 256, 64),
    LayerSpec("conv2_5", 3, 2, 56, 56, 64, 64),
    LayerSpec("conv3_1", 1, 1, 28, 28, 64, 256),
    LayerSpec("conv3_2", 1, 1, 28, 28, 256, 512),
    LayerSpec("conv3_3", 1, 1, 28, 28, 256, 128),
    LayerSpec("conv3_4", 3, 1, 28, 28, 128, 128),
    LayerSpec("conv3_5", 1, 1, 28, 28, 128, 512),
    LayerSpec("conv3_6", 1, 1, 28, 28, 512, 128),
    LayerSpec("conv3_7", 3, 2, 28, 28, 128, 128),
    LayerSpec("conv4_1", 1, 1, 14, 14, 128, 512),
    LayerSpec("conv4_2", 1, 1, 14, 14, 512, 1024),
    LayerSpec("conv4_3", 1, 1, 14, 14, 512, 256),
    LayerSpec("conv4_4", 3, 1, 14, 14, 256, 256),
    LayerSpec("conv4_5", 1, 1, 14, 14, 256, 1024),
    LayerSpec("conv4_6", 1, 1, 14, 14, 1024, 256),
    LayerSpec("conv4_7", 3, 2, 14, 14, 256, 256),
    LayerSpec("conv5_1", 1, 1, 7, 7, 256, 1024),
    LayerSpec("conv5_2", 1, 1, 7, 7, 1024, 2048),
    LayerSpec("conv5_3", 1, 1, 7, 7, 1024, 512),
    LayerSpec("conv5_4", 3, 1, 7, 7, 512, 512),
    LayerSpec("conv5_5", 1, 1, 7, 7, 512, 2048),
    LayerSpec("conv5_6", 1, 1, 7, 7, 2048, 512),
)


def _dataclass_to_dict(obj):
    d = dataclasses.asdict(obj)
    for k, v in d.items():
        if isinstance(v, Enum):
            d[k] = v.value
    return d


def to_json(obj) -> str:
    """Serialize a config dataclass to the Rust-compatible JSON schema."""
    return json.dumps(_dataclass_to_dict(obj), sort_keys=True)


def layer_dict(layer: LayerSpec, batch: int = 1) -> dict:
    d = _dataclass_to_dict(layer)
    d["out_h"] = layer.out_h
    d["out_w"] = layer.out_w
    d["flops"] = layer.flops(batch)
    return d
