"""Layer-2 JAX compute graphs: convolution layers and network segments.

These are the functions that get AOT-lowered to HLO artifacts.  Each one
composes Layer-1 Pallas kernels with (cheap, XLA-fused) glue: algorithm
dispatch, bias + ReLU epilogues, and multi-layer segments.  Python only
ever runs at build time; the Rust coordinator executes the lowered HLO.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .configs import ConvAlgorithm, ConvConfig, GemmConfig, LayerSpec
from .kernels.gemm import gemm as _gemm
from .kernels.conv import conv2d as _conv2d
from .kernels.im2col import conv2d_im2col as _conv2d_im2col
from .kernels.winograd import conv2d_winograd as _conv2d_winograd
from .kernels import ref as ref_kernels


def gemm_op(a, b, c=None, *, config: GemmConfig = GemmConfig(),
            alpha: float = 1.0, beta: float = 0.0,
            trans_a: bool = False, trans_b: bool = False,
            interpret: bool = True):
    """The BLAS GEMM entry point lowered into artifacts."""
    return _gemm(a, b, c, config=config, alpha=alpha, beta=beta,
                     trans_a=trans_a, trans_b=trans_b, interpret=interpret)


def gemm_op_xla(a, b, c=None, *, alpha: float = 1.0, beta: float = 0.0,
                trans_a: bool = False, trans_b: bool = False):
    """Vendor-baseline GEMM: XLA's native dot (the clBLAST stand-in)."""
    return ref_kernels.gemm_ref(a, b, c, alpha=alpha, beta=beta,
                                trans_a=trans_a, trans_b=trans_b)


def conv_layer(x, f, *, config: ConvConfig, stride: int = 1,
               padding: str = "SAME", gemm_config: GemmConfig = GemmConfig(),
               interpret: bool = True):
    """Algorithm-dispatched convolution layer (paper §4.1)."""
    alg = config.algorithm
    if alg in (ConvAlgorithm.TILED, ConvAlgorithm.NAIVE):
        return _conv2d(x, f, config=config, stride=stride,
                           padding=padding, interpret=interpret)
    if alg == ConvAlgorithm.IM2COL:
        return _conv2d_im2col(x, f, config=config,
                                    gemm_config=gemm_config, stride=stride,
                                    padding=padding, interpret=interpret)
    if alg == ConvAlgorithm.WINOGRAD:
        if not ref_kernels.winograd_domain_ok(f.shape[0], stride):
            raise ValueError("winograd requires 3x3 stride-1")
        return _conv2d_winograd(x, f, config=config,
                                        gemm_config=gemm_config,
                                        interpret=interpret)
    raise ValueError(f"unknown algorithm {alg}")


def conv_layer_xla(x, f, *, stride: int = 1, padding: str = "SAME"):
    """Vendor-baseline convolution: XLA's native conv lowering."""
    return ref_kernels.conv2d_ref(x, f, stride=stride, padding=padding)


def conv_bias_relu(x, f, bias, *, config: ConvConfig, stride: int = 1,
                   padding: str = "SAME",
                   gemm_config: GemmConfig = GemmConfig(),
                   interpret: bool = True):
    """Conv + bias + ReLU, the fused inference epilogue used by networks."""
    y = conv_layer(x, f, config=config, stride=stride, padding=padding,
                   gemm_config=gemm_config, interpret=interpret)
    return jnp.maximum(y + bias, 0.0)


def layer_fn(layer: LayerSpec, batch: int, *, config: ConvConfig,
             gemm_config: GemmConfig = GemmConfig(), fuse_relu: bool = True,
             interpret: bool = True):
    """Build the jittable function + example args for one Table-3/4 layer."""
    x_spec = jax.ShapeDtypeStruct(
        (batch, layer.in_h, layer.in_w, layer.in_c), jnp.float32)
    f_spec = jax.ShapeDtypeStruct(
        (layer.window, layer.window, layer.in_c, layer.out_c), jnp.float32)
    b_spec = jax.ShapeDtypeStruct((layer.out_c,), jnp.float32)

    if fuse_relu:
        def fn(x, f, b):
            return (conv_bias_relu(x, f, b, config=config,
                                   stride=layer.stride,
                                   padding=layer.padding,
                                   gemm_config=gemm_config,
                                   interpret=interpret),)
        return fn, (x_spec, f_spec, b_spec)

    def fn(x, f):
        return (conv_layer(x, f, config=config, stride=layer.stride,
                           padding=layer.padding, gemm_config=gemm_config,
                           interpret=interpret),)
    return fn, (x_spec, f_spec)


def layer_fn_xla(layer: LayerSpec, batch: int, *, fuse_relu: bool = True):
    """Vendor-baseline variant of :func:`layer_fn`."""
    x_spec = jax.ShapeDtypeStruct(
        (batch, layer.in_h, layer.in_w, layer.in_c), jnp.float32)
    f_spec = jax.ShapeDtypeStruct(
        (layer.window, layer.window, layer.in_c, layer.out_c), jnp.float32)
    b_spec = jax.ShapeDtypeStruct((layer.out_c,), jnp.float32)
    if fuse_relu:
        def fn(x, f, b):
            y = conv_layer_xla(x, f, stride=layer.stride,
                               padding=layer.padding)
            return (jnp.maximum(y + b, 0.0),)
        return fn, (x_spec, f_spec, b_spec)

    def fn(x, f):
        return (conv_layer_xla(x, f, stride=layer.stride,
                               padding=layer.padding),)
    return fn, (x_spec, f_spec)


def gemm_fn(m: int, n: int, k: int, *, config: GemmConfig,
            alpha: float = 1.0, beta: float = 0.0, with_c: bool = False,
            xla_native: bool = False, interpret: bool = True):
    """Build the jittable GEMM + example args for an (M, N, K) problem."""
    a_spec = jax.ShapeDtypeStruct((m, k), jnp.float32)
    b_spec = jax.ShapeDtypeStruct((k, n), jnp.float32)
    if with_c:
        c_spec = jax.ShapeDtypeStruct((m, n), jnp.float32)

        def fn(a, b, c):
            if xla_native:
                return (gemm_op_xla(a, b, c, alpha=alpha, beta=beta),)
            return (gemm_op(a, b, c, config=config, alpha=alpha, beta=beta,
                            interpret=interpret),)
        return fn, (a_spec, b_spec, c_spec)

    def fn(a, b):
        if xla_native:
            return (gemm_op_xla(a, b, alpha=alpha),)
        return (gemm_op(a, b, config=config, alpha=alpha,
                        interpret=interpret),)
    return fn, (a_spec, b_spec)
