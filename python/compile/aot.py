"""AOT artifact builder: lower every manifest entry to HLO text.

Interchange format is HLO *text*, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the Rust side's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage (from ``python/``)::

    python -m compile.aot --out ../artifacts --groups all

Python runs exactly once, here; after this the Rust binary is
self-contained.  Incremental: entries whose artifact already exists are
skipped unless ``--force``.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import List, Optional, Tuple

import jax

from . import manifests, model
from .configs import ConvAlgorithm, GemmConfig, layer_dict
from .kernels.winograd import winograd_flops

MANIFEST_VERSION = 1


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe path)."""
    from jax._src.lib import xla_client as xc

    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True is load-bearing: the default printer
    # elides array constants as `{...}`, which the Rust side's HLO parser
    # silently reads back as ZEROS (found the hard way via the Winograd
    # transform matrices).
    return comp.as_hlo_text(print_large_constants=True)


def _gemm_flops(e: manifests.ManifestEntry) -> int:
    flops = 2 * e.m * e.n * e.k
    if e.with_c:
        flops += 3 * e.m * e.n  # alpha*AB + beta*C epilogue
    return flops


def _conv_flops(e: manifests.ManifestEntry) -> int:
    layer = e.layer
    if (e.conv_config is not None
            and e.conv_config.algorithm == ConvAlgorithm.WINOGRAD):
        return winograd_flops(e.batch, layer.out_h, layer.out_w,
                              layer.in_c, layer.out_c, e.conv_config.wino_m)
    return layer.flops(e.batch)


def build_entry(e: manifests.ManifestEntry):
    """Return (fn, arg_specs, metadata) for one manifest entry."""
    if e.kind == "gemm":
        fn, specs = model.gemm_fn(
            e.m, e.n, e.k, config=e.gemm_config or GemmConfig(),
            alpha=e.alpha, beta=e.beta, with_c=e.with_c,
            xla_native=(e.impl == "xla"))
        meta = {
            "m": e.m, "n": e.n, "k": e.k,
            "alpha": e.alpha, "beta": e.beta,
            "config": e.gemm_config.name if e.gemm_config else None,
            "flops": _gemm_flops(e),
            "bytes": 4 * (e.m * e.k + e.k * e.n + e.m * e.n
                          + (e.m * e.n if e.with_c else 0)),
        }
    elif e.kind == "conv":
        if e.impl == "xla":
            fn, specs = model.layer_fn_xla(e.layer, e.batch,
                                           fuse_relu=e.fuse_relu)
            cfg_name = None
            alg = "xla"
        else:
            fn, specs = model.layer_fn(e.layer, e.batch,
                                       config=e.conv_config,
                                       gemm_config=(e.conv_gemm_config
                                                    or GemmConfig()),
                                       fuse_relu=e.fuse_relu)
            cfg_name = e.conv_config.name
            alg = e.conv_config.algorithm.value
        layer = e.layer
        in_bytes = 4 * e.batch * layer.in_h * layer.in_w * layer.in_c
        f_bytes = 4 * layer.window ** 2 * layer.in_c * layer.out_c
        out_bytes = 4 * e.batch * layer.out_h * layer.out_w * layer.out_c
        meta = {
            "layer": layer_dict(layer, e.batch),
            "batch": e.batch,
            "config": cfg_name,
            "gemm_config": (e.conv_gemm_config.name
                            if e.conv_gemm_config else None),
            "algorithm": alg,
            "fuse_relu": e.fuse_relu,
            "scaled_from": e.scaled_from,
            "flops": _conv_flops(e),
            "bytes": in_bytes + f_bytes + out_bytes,
        }
    else:
        raise ValueError(f"unknown kind {e.kind}")

    meta.update({
        "name": e.name,
        "kind": e.kind,
        "impl": e.impl,
        "groups": list(e.groups),
        "file": f"{e.name}.hlo.txt",
        "inputs": [{"shape": list(s.shape), "dtype": s.dtype.name}
                   for s in specs],
    })
    return fn, specs, meta


def lower_entry(e: manifests.ManifestEntry, out_dir: str,
                force: bool = False) -> Tuple[dict, bool]:
    """Lower one entry; returns (metadata, was_built)."""
    fn, specs, meta = build_entry(e)
    path = os.path.join(out_dir, meta["file"])
    if os.path.exists(path) and not force:
        return meta, False
    lowered = jax.jit(fn).lower(*specs)
    text = to_hlo_text(lowered)
    # Record output shapes from the lowered computation.
    out_avals = lowered.out_info
    meta["outputs"] = [{"shape": list(o.shape), "dtype": str(o.dtype)}
                       for o in jax.tree_util.tree_leaves(out_avals)]
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        f.write(text)
    os.replace(tmp, path)
    return meta, True


def build(out_dir: str, groups: List[str], force: bool = False,
          verbose: bool = True) -> List[dict]:
    os.makedirs(out_dir, exist_ok=True)
    entries = manifests.select(groups)
    metas = []
    t_all = time.time()
    for i, e in enumerate(entries):
        t0 = time.time()
        meta, built = lower_entry(e, out_dir, force=force)
        metas.append(meta)
        if verbose:
            status = "built" if built else "cached"
            print(f"[{i + 1}/{len(entries)}] {e.name}: {status} "
                  f"({time.time() - t0:.1f}s)", flush=True)
    manifest = {
        "version": MANIFEST_VERSION,
        "groups": groups,
        "artifacts": metas,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    if verbose:
        print(f"wrote {len(metas)} artifact entries "
              f"in {time.time() - t_all:.1f}s -> {out_dir}/manifest.json")
    return metas


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out", default="../artifacts")
    p.add_argument("--groups", default="all",
                   help="comma-separated: core,gemm,conv,network,all")
    p.add_argument("--force", action="store_true")
    args = p.parse_args(argv)
    build(args.out, args.groups.split(","), force=args.force)
    return 0


if __name__ == "__main__":
    sys.exit(main())
