"""Parametrized blocked GEMM Pallas kernel (paper §3.1).

The kernel computes ``C = alpha * OP_a(A) @ OP_b(B) + beta * C`` for
column-agnostic row-major arrays, parametrized by a :class:`GemmConfig`
exactly as the paper's SYCL kernel is parametrized by C++ template
arguments:

* The Pallas output block per grid cell is ``block_m x block_n`` =
  ``(rt_m * wg_r) x (rt_n * wg_c)`` — the work-group's tile of C
  (paper Fig. 1b).  The register tile / work-group split within the block
  does not change the mathematics, only the hardware mapping; it is what
  the Rust performance model reasons about.
* ``use_local`` selects the HBM->VMEM staging schedule: ``_loc`` stages
  A/B panels in ``block_k``-deep slices (the local-memory tiles of
  Fig. 1b), ``_noloc`` streams the whole K panel per grid cell (relying on
  the cache, as on Mali G-71).
* ``double_buffer`` is a pipelining hint; under ``interpret=True`` it does
  not change the emitted schedule, but it doubles the modeled local-memory
  footprint (see ``configs.GemmConfig.local_mem_elems``) and the Rust
  performance model's latency-hiding term.

Arbitrary (non-multiple) M/N/K are handled by zero-padding to block
multiples and slicing the result; zero padding is exact for the ``alpha``
term and ``beta`` acts only on the unpadded C region.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..configs import GemmConfig


def _gemm_kernel(a_ref, b_ref, c_ref, o_ref, *, k_steps, alpha, beta,
                 trans_a, trans_b, acc_dtype):
    """One (i, j, s) grid step: accumulate an A-slab x B-slab product.

    The k grid dimension is innermost, so ``o_ref`` for a fixed (i, j) is
    revisited across s = 0..k_steps-1 and used as the accumulator — this is
    the register-resident C_ij of paper §3.1.2 ("C_ij is stored in
    registers during the entire operation").
    """
    s = pl.program_id(2)

    a = a_ref[...]
    b = b_ref[...]
    if trans_a:
        a = a.T
    if trans_b:
        b = b.T
    prod = jax.lax.dot(a, b, preferred_element_type=acc_dtype)

    @pl.when(s == 0)
    def _init():
        o_ref[...] = prod.astype(o_ref.dtype)

    @pl.when(s != 0)
    def _accum():
        o_ref[...] += prod.astype(o_ref.dtype)

    @pl.when(s == k_steps - 1)
    def _epilogue():
        o_ref[...] = alpha * o_ref[...] + beta * c_ref[...]


def _pad2(x, m0, m1):
    p0 = (-x.shape[0]) % m0
    p1 = (-x.shape[1]) % m1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


def gemm(a: jax.Array, b: jax.Array, c: Optional[jax.Array] = None,
         *, config: GemmConfig = GemmConfig(), alpha: float = 1.0,
         beta: float = 0.0, trans_a: bool = False, trans_b: bool = False,
         interpret: bool = True) -> jax.Array:
    """Blocked GEMM: ``alpha * OP_a(a) @ OP_b(b) + beta * c``.

    Args:
        a: ``(M, K)`` (or ``(K, M)`` when ``trans_a``).
        b: ``(K, N)`` (or ``(N, K)`` when ``trans_b``).
        c: ``(M, N)`` accumulator input; required when ``beta != 0``.
        config: the kernel parametrization (register tile, work-group,
            local-memory schedule).
        interpret: run the Pallas interpreter (required for CPU PJRT).

    Returns:
        ``(M, N)`` result with the dtype of ``a``.
    """
    m = a.shape[1] if trans_a else a.shape[0]
    k = a.shape[0] if trans_a else a.shape[1]
    kb = b.shape[1] if trans_b else b.shape[0]
    n = b.shape[0] if trans_b else b.shape[1]
    if k != kb:
        raise ValueError(f"contraction mismatch: {k} vs {kb}")
    if c is None:
        if beta != 0.0:
            raise ValueError("beta != 0 requires c")
        c = jnp.zeros((m, n), a.dtype)

    bm, bn = config.block_m, config.block_n
    # _noloc streams the whole K panel per grid cell; _loc stages
    # cache-line-deep k-slices (the local-memory tiles of Fig. 1b).
    bk = k if not config.use_local else min(config.block_k, k)

    ap = _pad2(a, bk if trans_a else bm, bm if trans_a else bk)
    bp = _pad2(b, bn if trans_b else bk, bk if trans_b else bn)
    cp = _pad2(c, bm, bn)
    mp = cp.shape[0]
    np_ = cp.shape[1]
    kp = ap.shape[0] if trans_a else ap.shape[1]
    k_steps = kp // bk

    a_spec = (
        pl.BlockSpec((bk, bm), lambda i, j, s: (s, i))
        if trans_a
        else pl.BlockSpec((bm, bk), lambda i, j, s: (i, s))
    )
    b_spec = (
        pl.BlockSpec((bn, bk), lambda i, j, s: (j, s))
        if trans_b
        else pl.BlockSpec((bk, bn), lambda i, j, s: (s, j))
    )

    kernel = functools.partial(
        _gemm_kernel,
        k_steps=k_steps,
        alpha=float(alpha),
        beta=float(beta),
        trans_a=trans_a,
        trans_b=trans_b,
        acc_dtype=jnp.float32,
    )
    out = pl.pallas_call(
        kernel,
        grid=(mp // bm, np_ // bn, k_steps),
        in_specs=[
            a_spec,
            b_spec,
            pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), a.dtype),
        interpret=interpret,
    )(ap, bp, cp)
    return out[:m, :n]


def _batched_kernel(a_ref, b_ref, o_ref, *, k_steps, acc_dtype):
    s = pl.program_id(3)
    prod = jax.lax.dot(
        a_ref[0], b_ref[0], preferred_element_type=acc_dtype
    ).astype(o_ref.dtype)[None]

    @pl.when(s == 0)
    def _init():
        o_ref[...] = prod

    @pl.when(s != 0)
    def _accum():
        o_ref[...] += prod


def gemm_batched(a: jax.Array, b: jax.Array, *,
                 config: GemmConfig = GemmConfig(),
                 interpret: bool = True) -> jax.Array:
    """Batched GEMM ``(G, M, K) @ (G, K, N) -> (G, M, N)``.

    This is the batched multiply at the heart of the Winograd path
    (paper §4.1.2): one independent small GEMM per transform matrix, all
    sharing a single kernel launch with the batch as the leading grid dim.
    """
    g, m, k = a.shape
    g2, k2, n = b.shape
    if g != g2 or k != k2:
        raise ValueError(f"batched shape mismatch: {a.shape} vs {b.shape}")

    bm = min(config.block_m, m) if m >= 8 else m
    bn = min(config.block_n, n) if n >= 8 else n
    bk = k if not config.use_local else min(config.block_k, k)

    pm, pn, pk = (-m) % bm, (-n) % bn, (-k) % bk
    ap = jnp.pad(a, ((0, 0), (0, pm), (0, pk))) if (pm or pk) else a
    bp = jnp.pad(b, ((0, 0), (0, pk), (0, pn))) if (pk or pn) else b
    mp, kp, np_ = m + pm, k + pk, n + pn
    k_steps = kp // bk

    out = pl.pallas_call(
        functools.partial(_batched_kernel, k_steps=k_steps,
                          acc_dtype=jnp.float32),
        grid=(g, mp // bm, np_ // bn, k_steps),
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda gi, i, j, s: (gi, i, s)),
            pl.BlockSpec((1, bk, bn), lambda gi, i, j, s: (gi, s, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda gi, i, j, s: (gi, i, j)),
        out_shape=jax.ShapeDtypeStruct((g, mp, np_), a.dtype),
        interpret=interpret,
    )(ap, bp)
    return out[:, :m, :n]
