"""Winograd fast convolution F(m x m, 3 x 3) (paper §4.1.2).

Lavin & Gray's formulation: the input is split into overlapping
``(m+2) x (m+2)`` tiles; input and filter are transformed
(``V = B^T d B``, ``U = G g G^T``), the convolution becomes
``(m+2)^2`` independent *batched matrix multiplies* ``M_ij = V_ij U_ij``
of shape ``(tiles, C) x (C, K)``, and the output transform
``Y = A^T M A`` recovers ``m x m`` output tiles.

The tile size ``m`` is the parametrization knob the paper discusses:
larger ``m`` gives more data reuse and fewer flops per output, but more
intermediate matrices each of smaller size — harder to keep a device busy —
and more registers per thread.  We provide F(2x2, 3x3) and F(4x4, 3x3).

The batched multiply — the bulk of the compute — goes through the
parametrized Pallas GEMM (``gemm.gemm_batched``), so the GEMM configuration
chosen by the tuner applies here too, exactly as SYCL-DNN's Winograd path
leans on SYCL-BLAS (paper §4.1.2 last paragraph).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ConvConfig, GemmConfig
from .gemm import gemm_batched as _gemm_batched

# F(2x2, 3x3): alpha = 4.
_BT_2 = np.array(
    [
        [1, 0, -1, 0],
        [0, 1, 1, 0],
        [0, -1, 1, 0],
        [0, 1, 0, -1],
    ],
    np.float32,
)
_G_2 = np.array(
    [
        [1, 0, 0],
        [0.5, 0.5, 0.5],
        [0.5, -0.5, 0.5],
        [0, 0, 1],
    ],
    np.float32,
)
_AT_2 = np.array(
    [
        [1, 1, 1, 0],
        [0, 1, -1, -1],
    ],
    np.float32,
)

# F(4x4, 3x3): alpha = 6 (Lavin & Gray, CVPR'16).
_BT_4 = np.array(
    [
        [4, 0, -5, 0, 1, 0],
        [0, -4, -4, 1, 1, 0],
        [0, 4, -4, -1, 1, 0],
        [0, -2, -1, 2, 1, 0],
        [0, 2, -1, -2, 1, 0],
        [0, 4, 0, -5, 0, 1],
    ],
    np.float32,
)
_G_4 = np.array(
    [
        [1 / 4, 0, 0],
        [-1 / 6, -1 / 6, -1 / 6],
        [-1 / 6, 1 / 6, -1 / 6],
        [1 / 24, 1 / 12, 1 / 6],
        [1 / 24, -1 / 12, 1 / 6],
        [0, 0, 1],
    ],
    np.float32,
)
_AT_4 = np.array(
    [
        [1, 1, 1, 1, 1, 0],
        [0, 1, -1, 2, -2, 0],
        [0, 1, 1, 4, 4, 0],
        [0, 1, -1, 8, -8, 1],
    ],
    np.float32,
)

_TRANSFORMS = {2: (_BT_2, _G_2, _AT_2), 4: (_BT_4, _G_4, _AT_4)}


def transform_matrices(m: int) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Return ``(B^T, G, A^T)`` for F(m x m, 3 x 3)."""
    if m not in _TRANSFORMS:
        raise ValueError(f"unsupported Winograd tile m={m}; choose 2 or 4")
    return _TRANSFORMS[m]


def winograd_flops(n: int, h: int, w: int, c: int, k: int, m: int) -> int:
    """Multiply-add flops of the batched-GEMM stage (transform flops excluded).

    The paper quotes the Winograd op-count reduction "to as little as 30%";
    this is the number our benchmarks use for the effective-gigaflops
    normalization (figures report *convolution* flops / time, as the paper
    does, so a faster algorithm shows as higher effective gigaflops).
    """
    alpha = m + 2
    tiles = -(-h // m) * (-(-w // m)) * n
    return 2 * alpha * alpha * tiles * c * k


def extract_tiles(x: jax.Array, m: int) -> jax.Array:
    """Split a SAME-padded NHWC input into overlapping Winograd tiles.

    Returns ``(alpha, alpha, N, Ht, Wt, C)`` where
    ``tiles[xi, nu, n, th, tw, c] = x_pad[n, th*m + xi, tw*m + nu, c]``.
    """
    n, h, w, c = x.shape
    alpha = m + 2
    ht = -(-h // m)
    wt = -(-w // m)
    # SAME padding for 3x3/s1 is 1 on each side; additionally round the
    # spatial dims up to tile multiples.
    xp = jnp.pad(x, ((0, 0), (1, m * ht + 2 - h - 1), (1, m * wt + 2 - w - 1), (0, 0)))

    rows = []
    for xi in range(alpha):
        cols = []
        for nu in range(alpha):
            sl = jax.lax.slice(
                xp,
                (0, xi, nu, 0),
                (n, xi + (ht - 1) * m + 1, nu + (wt - 1) * m + 1, c),
                (1, m, m, 1),
            )
            cols.append(sl)
        rows.append(jnp.stack(cols, axis=0))
    return jnp.stack(rows, axis=0)  # (alpha, alpha, N, Ht, Wt, C)


def conv2d_winograd(x: jax.Array, f: jax.Array, *,
                    config: ConvConfig = ConvConfig(),
                    gemm_config: GemmConfig = GemmConfig(),
                    interpret: bool = True) -> jax.Array:
    """Winograd convolution for 3x3 stride-1 SAME layers.

    Args:
        x: ``(N, H, W, C)`` input.
        f: ``(3, 3, C, K)`` filter.
        config: ``wino_m`` selects F(2x2,3x3) or F(4x4,3x3).
        gemm_config: parametrization of the batched-multiply stage.
    """
    n, h, w, c = x.shape
    r, s, cf, k = f.shape
    if (r, s) != (3, 3):
        raise ValueError("winograd path requires a 3x3 filter")
    if c != cf:
        raise ValueError(f"channel mismatch: {c} vs {cf}")
    m = config.wino_m
    bt, g, at = (jnp.asarray(t) for t in transform_matrices(m))
    alpha = m + 2
    ht = -(-h // m)
    wt = -(-w // m)

    d = extract_tiles(x, m)  # (alpha, alpha, N, Ht, Wt, C)
    # Input transform V = B^T d B over the two tile axes.
    v = jnp.einsum("ia,jb,abntwc->ijntwc", bt, bt, d)
    # Filter transform U = G g G^T.
    u = jnp.einsum("ia,jb,abck->ijck", g, g, f)

    # Batched multiply: alpha^2 matrices of (N*Ht*Wt, C) x (C, K).
    v2 = v.reshape(alpha * alpha, n * ht * wt, c)
    u2 = u.reshape(alpha * alpha, c, k)
    mm = _gemm_batched(v2, u2, config=gemm_config, interpret=interpret)
    mm = mm.reshape(alpha, alpha, n, ht, wt, k)

    # Output transform Y = A^T M A.
    y = jnp.einsum("ia,jb,abntwk->ntiwjk", at, at, mm)
    # (N, Ht, m, Wt, m, K) -> (N, Ht*m, Wt*m, K), crop to the true output.
    y = y.reshape(n, ht * m, wt * m, k)
    return y[:, :h, :w, :].astype(x.dtype)
