"""Parametrized tiled direct 2D convolution Pallas kernel (paper §4.1.1).

Layouts follow the paper (§4.1): input ``NHWC``, filter ``RSCK`` (HWIO),
output ``NHWK``.  The kernel is parametrized by a :class:`ConvConfig`:

* ``tile_h x tile_w`` — the output tile computed per grid cell ("per
  thread" in the paper).  Adjacent output elements share overlapping input
  windows, so a larger tile re-uses each loaded input element more times
  and reduces total bytes read (paper Fig. 3's x-axis).
* ``vec_c`` / ``vec_k`` — input/output channel vector widths.  They
  constrain the channel blocking (``C % vec_c == 0``, ``K % vec_k == 0``)
  and determine the register footprint the Rust model estimates (Fig. 2);
  under the interpreter they are numerically inert — the paper's own point
  is that parameters move performance, never semantics.
* ``block_k`` — output channels computed per grid cell (0 = all of K),
  the analogue of splitting feature maps across work-groups.

The input is zero-padded up front so every in-kernel load is static-shape
and in-bounds; strides are handled with static strided slices, so a single
kernel serves every layer of Tables 3 & 4 (1x1, 3x3/s1, 3x3/s2, 7x7/s2).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..configs import ConvConfig


def _same_pads(size: int, window: int, stride: int) -> Tuple[int, int]:
    """TF-style SAME padding (matches lax.conv 'SAME')."""
    out = -(-size // stride)
    total = max((out - 1) * stride + window - size, 0)
    return total // 2, total - total // 2


def _conv_kernel(x_ref, f_ref, o_ref, *, tile_h, tile_w, stride, window,
                 in_c, block_k, acc_dtype):
    """Compute one (1, tile_h, tile_w, block_k) output tile.

    The input lives un-blocked in ANY memory space; each grid cell loads
    its (overlapping) halo patch with a dynamic slice — the Pallas
    expression of the paper's "each thread loads the input slice it
    requires", with the tile overlap providing the data reuse.
    """
    n = pl.program_id(0)
    th = pl.program_id(1)
    tw = pl.program_id(2)
    ko = pl.program_id(3)

    patch_h = (tile_h - 1) * stride + window
    patch_w = (tile_w - 1) * stride + window
    patch = x_ref[
        n,
        pl.dslice(th * tile_h * stride, patch_h),
        pl.dslice(tw * tile_w * stride, patch_w),
        :,
    ]
    fblk = f_ref[:, :, :, pl.dslice(ko * block_k, block_k)]

    acc = jnp.zeros((tile_h * tile_w, block_k), acc_dtype)
    # R and S are static — this unrolls into `window**2` small matmuls of
    # shape (tile_h*tile_w, C) x (C, block_k), the MXU-friendly form of
    # Algorithm 1's inner loops.
    for r in range(window):
        for s in range(window):
            win = jax.lax.slice(
                patch,
                (r, s, 0),
                (r + (tile_h - 1) * stride + 1,
                 s + (tile_w - 1) * stride + 1,
                 in_c),
                (stride, stride, 1),
            )
            acc += jax.lax.dot(
                win.reshape(tile_h * tile_w, in_c),
                fblk[r, s],
                preferred_element_type=acc_dtype,
            )
    o_ref[...] = acc.reshape(1, tile_h, tile_w, block_k).astype(o_ref.dtype)


def conv2d(x: jax.Array, f: jax.Array, *, config: ConvConfig = ConvConfig(),
           stride: int = 1, padding: str = "SAME",
           interpret: bool = True) -> jax.Array:
    """Tiled direct convolution.

    Args:
        x: input ``(N, H, W, C)``.
        f: filter ``(R, S, C, K)`` with R == S.
        config: tile/vector parametrization.
        stride: spatial stride (same in h and w).
        padding: ``"SAME"`` or ``"VALID"``.

    Returns:
        ``(N, out_h, out_w, K)`` output, dtype of ``x``.
    """
    n, h, w, c = x.shape
    r, s, cf, k = f.shape
    if r != s:
        raise ValueError(f"only square windows supported, got {r}x{s}")
    if c != cf:
        raise ValueError(f"channel mismatch: input {c} vs filter {cf}")
    if c % config.vec_c or k % config.vec_k:
        raise ValueError(
            f"vector widths must divide channels: C={c}%{config.vec_c}, "
            f"K={k}%{config.vec_k}"
        )

    if padding == "SAME":
        ph = _same_pads(h, r, stride)
        pw = _same_pads(w, s, stride)
        out_h = -(-h // stride)
        out_w = -(-w // stride)
    elif padding == "VALID":
        ph = pw = (0, 0)
        out_h = (h - r) // stride + 1
        out_w = (w - s) // stride + 1
    else:
        raise ValueError(f"bad padding {padding!r}")

    tile_h = min(config.tile_h, out_h)
    tile_w = min(config.tile_w, out_w)
    block_k = config.block_k if config.block_k else k
    block_k = min(block_k, k)
    if k % block_k:
        raise ValueError(f"block_k {block_k} must divide K={k}")

    # Pad: front = SAME/VALID conv padding; back additionally rounds the
    # output up to a tile multiple and guarantees the last tile's halo
    # patch stays in bounds.
    th_ct = -(-out_h // tile_h)
    tw_ct = -(-out_w // tile_w)
    need_h = (th_ct * tile_h - 1) * stride + r
    need_w = (tw_ct * tile_w - 1) * stride + s
    xp = jnp.pad(
        x,
        (
            (0, 0),
            (ph[0], max(ph[1], need_h - h - ph[0])),
            (pw[0], max(pw[1], need_w - w - pw[0])),
            (0, 0),
        ),
    )

    out = pl.pallas_call(
        functools.partial(
            _conv_kernel,
            tile_h=tile_h,
            tile_w=tile_w,
            stride=stride,
            window=r,
            in_c=c,
            block_k=block_k,
            acc_dtype=jnp.float32,
        ),
        grid=(n, th_ct, tw_ct, k // block_k),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec(
            (1, tile_h, tile_w, block_k),
            lambda ni, i, j, ko: (ni, i, j, ko),
        ),
        out_shape=jax.ShapeDtypeStruct(
            (n, th_ct * tile_h, tw_ct * tile_w, k), x.dtype
        ),
        interpret=interpret,
    )(xp, f)
    return out[:, :out_h, :out_w, :]


def conv2d_naive(x: jax.Array, f: jax.Array, *, stride: int = 1,
                 padding: str = "SAME", interpret: bool = True) -> jax.Array:
    """Paper Algorithm 1: one output element per thread (tile 1x1).

    This is the 0.29-TFLOP baseline of Fig. 3 — every thread re-loads its
    full input window with zero cross-thread reuse.
    """
    cfg = ConvConfig(tile_h=1, tile_w=1)
    return conv2d(x, f, config=cfg, stride=stride, padding=padding,
                  interpret=interpret)
