"""im2col convolution: lower conv2d onto the parametrized GEMM (paper §4).

This is the "matrix multiplies supplied by a BLAS implementation" path:
SYCL-DNN defers to SYCL-BLAS for GEMM-backed convolutions.  Here the patch
matrix is built with static strided slices (one per filter tap, so the
layout is fully explicit) and multiplied by the reshaped filter through
``gemm.gemm`` — the GEMM configuration tunes this conv path too.

For 1x1 stride-1 convolutions im2col is a pure reshape, which is why the
paper's ResNet benchmarks (dominated by 1x1 layers) favour a good GEMM
over specialized conv kernels.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs import ConvConfig, GemmConfig
from .gemm import gemm as _gemm
from .conv import _same_pads


def im2col(x: jax.Array, window: int, stride: int,
           padding: str = "SAME") -> jax.Array:
    """Extract conv patches: ``(N, H, W, C) -> (N*out_h*out_w, R*S*C)``.

    Column order is ``(r, s, c)`` row-major, matching a ``(R, S, C, K)``
    filter reshaped to ``(R*S*C, K)``.
    """
    n, h, w, c = x.shape
    r = s = window
    if padding == "SAME":
        ph = _same_pads(h, r, stride)
        pw = _same_pads(w, s, stride)
        out_h = -(-h // stride)
        out_w = -(-w // stride)
    elif padding == "VALID":
        ph = pw = (0, 0)
        out_h = (h - r) // stride + 1
        out_w = (w - s) // stride + 1
    else:
        raise ValueError(f"bad padding {padding!r}")
    xp = jnp.pad(x, ((0, 0), ph, pw, (0, 0)))

    taps = []
    for ri in range(r):
        for si in range(s):
            sl = jax.lax.slice(
                xp,
                (0, ri, si, 0),
                (n, ri + (out_h - 1) * stride + 1,
                 si + (out_w - 1) * stride + 1, c),
                (1, stride, stride, 1),
            )
            taps.append(sl)  # (N, out_h, out_w, C)
    # (R*S, N, out_h, out_w, C) -> (N, out_h, out_w, R*S, C)
    patches = jnp.stack(taps, axis=0).transpose(1, 2, 3, 0, 4)
    return patches.reshape(n * out_h * out_w, r * s * c)


def conv2d_im2col(x: jax.Array, f: jax.Array, *,
                  config: ConvConfig = ConvConfig(),
                  gemm_config: GemmConfig = GemmConfig(),
                  stride: int = 1, padding: str = "SAME",
                  interpret: bool = True) -> jax.Array:
    """GEMM-backed convolution via im2col."""
    del config  # conv tiling params do not apply on this path
    n, h, w, c = x.shape
    r, s, cf, k = f.shape
    if c != cf:
        raise ValueError(f"channel mismatch: {c} vs {cf}")
    if padding == "SAME":
        out_h = -(-h // stride)
        out_w = -(-w // stride)
    else:
        out_h = (h - r) // stride + 1
        out_w = (w - s) // stride + 1

    if (r, s, stride) == (1, 1, 1) and padding == "SAME":
        # 1x1/s1: im2col is a pure reshape — the GEMM-dominated ResNet case.
        cols = x.reshape(n * h * w, c)
    else:
        cols = im2col(x, r, stride, padding)
    fm = f.reshape(r * s * c, k)
    out = _gemm(cols, fm, config=gemm_config, interpret=interpret)
    return out.reshape(n, out_h, out_w, k).astype(x.dtype)
