"""Pure-jnp correctness oracles for every kernel in this package.

These are the ground truth the pytest suite checks the Pallas kernels
against (``assert_allclose``), and the "hand-tuned vendor library" stand-in
on the host: XLA's native ``dot`` / ``conv_general_dilated`` lowerings are
the best-tuned implementations available on this hardware, playing the role
clBLAST / ARM Compute Library / MKL-DNN play in the paper's comparisons.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def gemm_ref(a: jax.Array, b: jax.Array, c: Optional[jax.Array] = None, *,
             alpha: float = 1.0, beta: float = 0.0,
             trans_a: bool = False, trans_b: bool = False) -> jax.Array:
    """Reference GEMM: ``alpha * OP_a(a) @ OP_b(b) + beta * c``."""
    op_a = a.T if trans_a else a
    op_b = b.T if trans_b else b
    out = alpha * jnp.matmul(op_a, op_b)
    if c is not None and beta != 0.0:
        out = out + beta * c
    return out.astype(a.dtype)


def gemm_batched_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """Reference batched GEMM ``(G, M, K) @ (G, K, N)``."""
    return jnp.einsum("gmk,gkn->gmn", a, b).astype(a.dtype)


def conv2d_ref(x: jax.Array, f: jax.Array, *, stride: int = 1,
               padding: str = "SAME") -> jax.Array:
    """Reference NHWC x RSCK convolution via XLA's native lowering."""
    return jax.lax.conv_general_dilated(
        x,
        f,
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    ).astype(x.dtype)


def winograd_domain_ok(window: int, stride: int) -> bool:
    """Winograd applies to 3x3 stride-1 convolutions only (paper §4.1.2)."""
    return window == 3 and stride == 1
