"""Layer-1 Pallas kernels: parametrized GEMM and convolution.

Every kernel is a *family* of instantiations indexed by a configuration
object (``configs.GemmConfig`` / ``configs.ConvConfig``) — the Pallas
analogue of the paper's C++-template-parametrized SYCL kernels.
"""

from .gemm import gemm, gemm_batched
from .conv import conv2d, conv2d_naive
from .im2col import conv2d_im2col, im2col
from .winograd import conv2d_winograd, transform_matrices, winograd_flops
from . import ref

__all__ = [
    "gemm",
    "gemm_batched",
    "conv2d",
    "conv2d_naive",
    "conv2d_im2col",
    "im2col",
    "conv2d_winograd",
    "transform_matrices",
    "winograd_flops",
    "ref",
]
