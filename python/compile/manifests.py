"""Artifact build manifests: which kernel instantiations get AOT-compiled.

Each entry names one HLO artifact — one (operation, shape, configuration)
instantiation of a parametrized kernel, exactly as the paper's SYCL library
instantiates one OpenCL kernel per template-parameter combination.  The
Rust coordinator discovers artifacts through the ``manifest.json`` this
module describes.

Groups:

* ``core``      — quickstart + the artifacts integration tests need.
* ``gemm``      — the measured GEMM sweep (Fig. 4/5 anchor points):
                  Table-2 configurations x bench shapes + vendor baseline.
* ``conv``      — representative Table-3/4 layers x algorithms (Fig. 6-9
                  anchor points) + vendor baseline.
* ``network``   — per-layer artifacts for the end-to-end network driver.

Interpret-mode Pallas lowers to a serial XLA while-loop, so huge spatial
grids execute slowly on the host; layers whose measured variant would be
impractically slow are *spatially scaled* (channels untouched — they, not
the spatial extent, determine the GEMM/conv regime) and tagged with
``scaled_from`` so reports normalize by the scaled flop count.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from .configs import (ConvAlgorithm, ConvConfig, GemmConfig, LayerSpec,
                      RESNET_LAYERS, TABLE2_CONFIGS, VGG_LAYERS)

#: GEMM problem sizes measured on the host (anchors for the Fig. 4/5 sweeps).
GEMM_BENCH_SHAPES: Tuple[Tuple[int, int, int], ...] = (
    (64, 64, 64),
    (256, 256, 256),
    (512, 512, 512),
    (1024, 1024, 64),
    (64, 64, 1024),
)

#: GEMM configuration backing measured im2col/winograd conv artifacts.
#: Large blocks keep the interpret-mode grid small (128x128 macro-tiles ->
#: tens of grid steps instead of tens of thousands), which is what makes
#: the measured conv sweep tractable on the host.
CONV_GEMM = GemmConfig(rt_m=8, rt_n=8, wg_r=16, wg_c=16, block_k=64)

#: Conv configurations measured per layer ("SYCL-DNN" side of Fig. 6-9).
CONV_TILE = ConvConfig(tile_h=2, tile_w=2, vec_c=1, vec_k=1,
                       algorithm=ConvAlgorithm.TILED)
CONV_TILE_4x4 = ConvConfig(tile_h=4, tile_w=4, vec_c=1, vec_k=1,
                           algorithm=ConvAlgorithm.TILED)
CONV_IM2COL = ConvConfig(algorithm=ConvAlgorithm.IM2COL)
CONV_WINO = ConvConfig(algorithm=ConvAlgorithm.WINOGRAD, wino_m=2)

#: Max spatial extent measured through the interpreter per algorithm.
_MAX_HW_PALLAS = 56


@dataclass(frozen=True)
class ManifestEntry:
    """One artifact to build.  ``params`` are kind-specific."""

    name: str
    kind: str  # "gemm" | "conv"
    impl: str  # "pallas" | "xla"
    groups: Tuple[str, ...]
    # GEMM params
    m: int = 0
    n: int = 0
    k: int = 0
    gemm_config: Optional[GemmConfig] = None
    alpha: float = 1.0
    beta: float = 0.0
    with_c: bool = False
    # Conv params
    layer: Optional[LayerSpec] = None
    batch: int = 1
    conv_config: Optional[ConvConfig] = None
    conv_gemm_config: Optional[GemmConfig] = None
    fuse_relu: bool = False
    scaled_from: Optional[str] = None


def _scale_layer(layer: LayerSpec, max_hw: int) -> Tuple[LayerSpec, Optional[str]]:
    """Clamp a layer's spatial extent for interpreter-speed measurement."""
    if layer.in_h <= max_hw and layer.in_w <= max_hw:
        return layer, None
    scaled = dataclasses.replace(layer, in_h=max_hw, in_w=max_hw)
    return scaled, f"{layer.in_h}x{layer.in_w}"


def gemm_entries() -> List[ManifestEntry]:
    entries: List[ManifestEntry] = []
    for (m, n, k) in GEMM_BENCH_SHAPES:
        for cfg in TABLE2_CONFIGS:
            entries.append(ManifestEntry(
                name=f"gemm_{m}x{n}x{k}_{cfg.name}",
                kind="gemm", impl="pallas", groups=("gemm",),
                m=m, n=n, k=k, gemm_config=cfg))
        entries.append(ManifestEntry(
            name=f"gemm_{m}x{n}x{k}_xla",
            kind="gemm", impl="xla", groups=("gemm",),
            m=m, n=n, k=k, gemm_config=GemmConfig()))
    return entries


#: Representative layers measured per algorithm (cover every regime in
#: Tables 3/4: stem 7x7/s2, pointwise 1x1, 3x3/s1 at several widths,
#: 3x3/s2 downsampling).
CONV_BENCH_LAYERS: Tuple[Tuple[str, LayerSpec], ...] = tuple(
    [("vgg", l) for l in VGG_LAYERS if l.name in
     ("conv1_1", "conv3_1", "conv4_2", "conv5_1")] +
    [("resnet", l) for l in RESNET_LAYERS if l.name in
     ("conv1_1", "conv2_2", "conv2_3", "conv2_5", "conv3_2", "conv4_4",
      "conv5_2", "conv5_4")]
)


def conv_entries() -> List[ManifestEntry]:
    entries: List[ManifestEntry] = []
    for net, layer in CONV_BENCH_LAYERS:
        base = f"{net}_{layer.name}"
        # Vendor baseline at full size (XLA conv executes fast).
        entries.append(ManifestEntry(
            name=f"conv_{base}_xla", kind="conv", impl="xla",
            groups=("conv", "network"), layer=layer, batch=1))
        scaled, src = _scale_layer(layer, _MAX_HW_PALLAS)
        algs: List[Tuple[str, ConvConfig]] = [
            ("tiled2x2", CONV_TILE),
            ("tiled4x4", CONV_TILE_4x4),
            ("im2col", CONV_IM2COL),
        ]
        if layer.window == 3 and layer.stride == 1:
            algs.append(("wino2", CONV_WINO))
        for tag, ccfg in algs:
            entries.append(ManifestEntry(
                name=f"conv_{base}_{tag}", kind="conv", impl="pallas",
                groups=("conv",), layer=scaled, batch=1, conv_config=ccfg,
                conv_gemm_config=CONV_GEMM, scaled_from=src))
    return entries


def network_entries() -> List[ManifestEntry]:
    """Per-layer artifacts for the end-to-end network inference driver.

    The driver runs *every* distinct layer of both networks through the
    vendor-baseline path (fast everywhere) and through the tuned Pallas
    path where the interpreter cost is practical.
    """
    entries: List[ManifestEntry] = []
    for net, layers in (("vgg", VGG_LAYERS), ("resnet", RESNET_LAYERS)):
        for layer in layers:
            entries.append(ManifestEntry(
                name=f"net_{net}_{layer.name}_xla", kind="conv", impl="xla",
                groups=("network",), layer=layer, batch=1, fuse_relu=True))
            if max(layer.in_h, layer.in_w) <= 28 and layer.window == 1:
                # Pointwise layers lower to a single pallas GEMM — cheap
                # enough to run everywhere at full size.
                entries.append(ManifestEntry(
                    name=f"net_{net}_{layer.name}_pallas", kind="conv",
                    impl="pallas", groups=("network",), layer=layer,
                    batch=1, conv_config=CONV_IM2COL,
                    conv_gemm_config=CONV_GEMM, fuse_relu=True))
    return entries


def core_entries() -> List[ManifestEntry]:
    return [
        ManifestEntry(
            name="quickstart_gemm", kind="gemm", impl="pallas",
            groups=("core",), m=64, n=64, k=64,
            gemm_config=GemmConfig.parse("4x4_8x8_loc")),
        ManifestEntry(
            name="test_gemm_ab", kind="gemm", impl="pallas",
            groups=("core",), m=48, n=32, k=40,
            gemm_config=GemmConfig.parse("8x4_8x16_loc"),
            alpha=1.5, beta=0.5, with_c=True),
        ManifestEntry(
            name="test_conv_tiled", kind="conv", impl="pallas",
            groups=("core",),
            layer=LayerSpec("smoke", 3, 1, 14, 14, 8, 16),
            batch=2, conv_config=CONV_TILE),
        ManifestEntry(
            name="test_conv_xla", kind="conv", impl="xla", groups=("core",),
            layer=LayerSpec("smoke", 3, 1, 14, 14, 8, 16), batch=2),
        ManifestEntry(
            name="test_conv_wino", kind="conv", impl="pallas",
            groups=("core",),
            layer=LayerSpec("smoke", 3, 1, 14, 14, 8, 16),
            batch=2, conv_config=CONV_WINO),
    ]


def all_entries() -> List[ManifestEntry]:
    seen: Dict[str, ManifestEntry] = {}
    for e in core_entries() + gemm_entries() + conv_entries() + network_entries():
        if e.name in seen:
            raise ValueError(f"duplicate manifest entry {e.name}")
        seen[e.name] = e
    return list(seen.values())


def select(groups: Sequence[str]) -> List[ManifestEntry]:
    """Entries belonging to any of the requested groups ('all' = everything)."""
    entries = all_entries()
    if "all" in groups:
        return entries
    want = set(groups)
    return [e for e in entries if want & set(e.groups)]
