"""Build-time compile path: Pallas kernels, JAX layer graphs, AOT lowering.

Nothing in this package runs at request time — ``make artifacts`` lowers
all needed kernel instantiations to ``artifacts/*.hlo.txt`` once, and the
Rust coordinator executes them through PJRT.
"""
