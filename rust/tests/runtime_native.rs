//! Native-backend integration tests: the same load→plan→execute→oracle
//! flow `runtime_pjrt.rs` runs against real HLO artifacts, ported to the
//! pure-Rust [`NativeEngine`] so it runs everywhere — including the
//! offline build, where these tests are the end-to-end signal.
//!
//! Instead of requiring `make artifacts`, a small synthetic
//! `manifest.json` is generated into a temp dir; the native backend never
//! opens the HLO files, so the manifest alone fully specifies execution.

use std::path::Path;
use std::time::Duration;

use portable_kernels::blas::{
    conv2d_direct, gemm_naive, max_abs_diff, Conv2dShape,
};
use portable_kernels::coordinator::{EngineHandle, NetworkRunner};
use portable_kernels::runtime::{ArtifactStore, Backend, NativeEngine};
use portable_kernels::util::rng::XorShift;
use portable_kernels::util::tmp::TempDir;

/// A conv manifest entry (SAME padding), shared by several tests.
fn conv_entry(
    name: &str,
    groups: &str,
    layer_name: &str,
    window: u32,
    stride: u32,
    h: u32,
    c: u32,
    k: u32,
    batch: u32,
) -> String {
    let out = h.div_ceil(stride);
    let flops = 2u64
        * batch as u64
        * (out as u64) * (out as u64)
        * k as u64
        * (window as u64) * (window as u64)
        * c as u64;
    format!(
        r#"{{"name": "{name}", "kind": "conv", "impl": "native",
            "file": "{name}.hlo.txt", "flops": {flops}, "batch": {batch},
            "algorithm": "im2col", "groups": [{groups}],
            "layer": {{"name": "{layer_name}", "window": {window},
                       "stride": {stride}, "in_h": {h}, "in_w": {h},
                       "in_c": {c}, "out_c": {k}, "out_h": {out},
                       "out_w": {out}, "padding": "SAME", "flops": {flops}}},
            "inputs": [{{"shape": [{batch}, {h}, {h}, {c}], "dtype": "float32"}},
                       {{"shape": [{window}, {window}, {c}, {k}], "dtype": "float32"}}]}}"#
    )
}

/// Write the synthetic manifest this suite runs against: a quickstart
/// GEMM, an α/β epilogue GEMM, a standalone conv, and a three-layer
/// "network" group for the runner.
fn write_manifest(dir: &Path) {
    let gemm_quickstart = r#"{"name": "quickstart_gemm", "kind": "gemm",
        "impl": "native", "config": "4x4_8x8_loc",
        "file": "quickstart_gemm.hlo.txt", "flops": 524288,
        "m": 64, "n": 64, "k": 64, "alpha": 1.0, "beta": 0.0,
        "groups": ["core", "gemm"],
        "inputs": [{"shape": [64, 64], "dtype": "float32"},
                   {"shape": [64, 64], "dtype": "float32"}]}"#;
    let gemm_ab = r#"{"name": "test_gemm_ab", "kind": "gemm",
        "impl": "native", "config": "8x4_8x16_loc",
        "file": "test_gemm_ab.hlo.txt", "flops": 127488,
        "m": 48, "n": 32, "k": 40, "alpha": 1.5, "beta": 0.5,
        "groups": ["core"],
        "inputs": [{"shape": [48, 40], "dtype": "float32"},
                   {"shape": [40, 32], "dtype": "float32"},
                   {"shape": [48, 32], "dtype": "float32"}]}"#;
    let conv_smoke = conv_entry(
        "test_conv_tiled", r#""core""#, "smoke", 3, 1, 14, 8, 16, 2,
    );
    let net = [
        conv_entry(
            "net_resnet_conv1_native", r#""network""#, "conv1", 3, 1, 16, 8,
            16, 1,
        ),
        conv_entry(
            "net_resnet_conv2_native", r#""network""#, "conv2", 1, 1, 16,
            16, 32, 1,
        ),
        conv_entry(
            "net_resnet_conv3_native", r#""network""#, "conv3", 3, 2, 16,
            16, 16, 1,
        ),
    ]
    .join(",\n");
    let manifest = format!(
        r#"{{"version": 1, "groups": ["core", "gemm", "network"],
            "artifacts": [{gemm_quickstart},
                          {gemm_ab},
                          {conv_smoke},
                          {net}]}}"#
    );
    std::fs::write(dir.join("manifest.json"), manifest).unwrap();
}

fn engine() -> (TempDir, NativeEngine) {
    let dir = TempDir::new("native-integ").unwrap();
    write_manifest(dir.path());
    let store = ArtifactStore::open(dir.path()).unwrap();
    let engine = NativeEngine::new(store).unwrap();
    (dir, engine)
}

#[test]
fn quickstart_gemm_matches_rust_oracle() {
    let (_dir, mut engine) = engine();
    let meta = engine.store().get("quickstart_gemm").unwrap().clone();
    let (m, n, k) = (
        meta.m.unwrap() as usize,
        meta.n.unwrap() as usize,
        meta.k.unwrap() as usize,
    );
    let mut rng = XorShift::new(3);
    let a = rng.f32_vec(m * k);
    let b = rng.f32_vec(k * n);
    let out = engine.run("quickstart_gemm", &[a.clone(), b.clone()]).unwrap();
    let expected = gemm_naive(&a, &b, m, n, k);
    assert!(max_abs_diff(&out.outputs[0], &expected) < 1e-3);
}

#[test]
fn gemm_with_alpha_beta_epilogue() {
    let (_dir, mut engine) = engine();
    // test_gemm_ab: 48x32x40, alpha=1.5, beta=0.5, with C input.
    let meta = engine.store().get("test_gemm_ab").unwrap().clone();
    let (m, n, k) = (48usize, 32usize, 40usize);
    assert_eq!(meta.m, Some(48));
    assert_eq!(meta.alpha, Some(1.5));
    let mut rng = XorShift::new(4);
    let a = rng.f32_vec(m * k);
    let b = rng.f32_vec(k * n);
    let c = rng.f32_vec(m * n);
    let out = engine
        .run("test_gemm_ab", &[a.clone(), b.clone(), c.clone()])
        .unwrap();
    let ab = gemm_naive(&a, &b, m, n, k);
    let expected: Vec<f32> = ab
        .iter()
        .zip(&c)
        .map(|(x, y)| 1.5 * x + 0.5 * y)
        .collect();
    assert!(max_abs_diff(&out.outputs[0], &expected) < 1e-3);
}

/// The parametrization-is-semantics-free claim on the native runtime: the
/// im2col-lowered conv agrees with the direct (quadruple-loop) oracle.
#[test]
fn conv_agrees_with_direct_oracle() {
    let (_dir, mut engine) = engine();
    let inputs = engine.synth_inputs("test_conv_tiled", 77).unwrap();
    let meta = engine.store().get("test_conv_tiled").unwrap();
    assert_eq!(
        meta.inputs.iter().map(|s| s.elems()).collect::<Vec<_>>(),
        inputs.iter().map(|v| v.len()).collect::<Vec<_>>(),
        "synthesized input shapes"
    );
    let out = engine.run("test_conv_tiled", &inputs).unwrap();
    let shape = Conv2dShape::same(2, 14, 14, 8, 16, 3, 1);
    let expected = conv2d_direct(&inputs[0], &inputs[1], &shape);
    assert!(max_abs_diff(&out.outputs[0], &expected) < 1e-2);
    assert_eq!(out.outputs[0].len(), shape.output_elems());
}

#[test]
fn plan_cache_hits() {
    let (_dir, mut engine) = engine();
    assert_eq!(engine.cached(), 0);
    engine.warm("quickstart_gemm").unwrap();
    assert_eq!(engine.cached(), 1);
    engine.warm("quickstart_gemm").unwrap();
    assert_eq!(engine.cached(), 1, "second warm must hit the cache");
    let inputs = engine.synth_inputs("quickstart_gemm", 5).unwrap();
    engine.run("quickstart_gemm", &inputs).unwrap();
    assert_eq!(engine.cached(), 1);
}

#[test]
fn engine_rejects_bad_inputs() {
    let (_dir, mut engine) = engine();
    // Wrong arity.
    assert!(engine.run("quickstart_gemm", &[vec![0.0; 64 * 64]]).is_err());
    // Wrong element count.
    assert!(engine
        .run("quickstart_gemm", &[vec![0.0; 7], vec![0.0; 64 * 64]])
        .is_err());
    // Unknown artifact.
    assert!(engine.run("no_such_artifact", &[]).is_err());
}

#[test]
fn engine_actor_serves_concurrent_callers() {
    let dir = TempDir::new("native-actor").unwrap();
    write_manifest(dir.path());
    // spawn_with pins the backend to NativeEngine regardless of the
    // build's default (this suite must pass under --features pjrt too).
    let store = ArtifactStore::open(dir.path()).unwrap();
    let (handle, join) =
        EngineHandle::spawn_with(move || NativeEngine::new(store)).unwrap();
    let mut threads = Vec::new();
    for t in 0..4 {
        let h = handle.clone();
        threads.push(std::thread::spawn(move || {
            let inputs = h.synth_inputs("quickstart_gemm", t).unwrap();
            for _ in 0..3 {
                let out = h.run("quickstart_gemm", inputs.clone()).unwrap();
                assert_eq!(out.outputs[0].len(), 64 * 64);
            }
        }));
    }
    for t in threads {
        t.join().unwrap();
    }
    let stats = handle.stats().unwrap();
    assert_eq!(stats.runs, 12);
    assert_eq!(stats.cached_executables, 1);
    handle.shutdown();
    join.join().unwrap();
}

#[test]
fn network_runner_executes_native_stack() {
    let dir = TempDir::new("native-net").unwrap();
    write_manifest(dir.path());
    let store = ArtifactStore::open(dir.path()).unwrap();
    let actor_store = store.clone();
    let (handle, join) =
        EngineHandle::spawn_with(move || NativeEngine::new(actor_store))
            .unwrap();
    let runner = NetworkRunner::new(handle.clone());
    let report = runner.run_network(&store, "resnet", "native", 2).unwrap();
    assert_eq!(report.layers.len(), 3, "all synthetic network layers");
    assert!(report.total_flops > 0);
    assert!(report.total_time_s > 0.0);
    for l in &report.layers {
        assert!(l.elapsed_s > 0.0, "{}", l.layer);
        assert!(l.gflops.is_finite(), "{}", l.layer);
    }
    // Unknown implementation is a loud error, not an empty report.
    assert!(runner.run_network(&store, "resnet", "pjrt-only", 1).is_err());
    handle.shutdown();
    join.join().unwrap();
}

/// Timing discipline: best-of-N never exceeds a single-run time by much.
#[test]
fn run_timed_takes_minimum() {
    let (_dir, mut engine) = engine();
    let inputs = engine.synth_inputs("quickstart_gemm", 9).unwrap();
    let (out, best) =
        engine.run_timed("quickstart_gemm", &inputs, 5).unwrap();
    assert_eq!(out.elapsed, best);
    let single = engine.run("quickstart_gemm", &inputs).unwrap().elapsed;
    // Not a strict inequality in general, but best-of-5 should not be
    // dramatically slower than any observed run.
    assert!(best <= single.max(Duration::from_micros(1)) * 16);
}
