//! PJRT integration tests: real HLO-text load + compile + execute against
//! the artifacts built by `make artifacts`, with numerics checked against
//! the pure-Rust oracle.
//!
//! Feature-gated: the `xla` crate is unavailable offline, so this file
//! only compiles under `--features pjrt`.  The same flow runs against the
//! native backend unconditionally in `runtime_native.rs`.
//!
//! These tests require `artifacts/manifest.json`; they are skipped (with a
//! loud message) when it is absent so `cargo test` works pre-`make`.
#![cfg(feature = "pjrt")]

use std::path::{Path, PathBuf};

use portable_kernels::blas::{gemm_naive, max_abs_diff};
use portable_kernels::coordinator::{EngineHandle, NetworkRunner};
use portable_kernels::runtime::{ArtifactStore, Backend, Engine};
use portable_kernels::util::rng::XorShift;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIPPED: run `make artifacts` first");
        None
    }
}

#[test]
fn quickstart_gemm_matches_rust_oracle() {
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = Engine::new(ArtifactStore::open(&dir).unwrap()).unwrap();
    let meta = engine.store().get("quickstart_gemm").unwrap().clone();
    let (m, n, k) = (
        meta.m.unwrap() as usize,
        meta.n.unwrap() as usize,
        meta.k.unwrap() as usize,
    );
    let mut rng = XorShift::new(3);
    let a = rng.f32_vec(m * k);
    let b = rng.f32_vec(k * n);
    let out = engine.run("quickstart_gemm", &[a.clone(), b.clone()]).unwrap();
    let expected = gemm_naive(&a, &b, m, n, k);
    assert!(max_abs_diff(&out.outputs[0], &expected) < 1e-3);
}

#[test]
fn gemm_with_alpha_beta_epilogue() {
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = Engine::new(ArtifactStore::open(&dir).unwrap()).unwrap();
    // test_gemm_ab: 48x32x40, alpha=1.5, beta=0.5, with C input.
    let meta = engine.store().get("test_gemm_ab").unwrap().clone();
    let (m, n, k) = (48usize, 32usize, 40usize);
    assert_eq!(meta.m, Some(48));
    let mut rng = XorShift::new(4);
    let a = rng.f32_vec(m * k);
    let b = rng.f32_vec(k * n);
    let c = rng.f32_vec(m * n);
    let out = engine
        .run("test_gemm_ab", &[a.clone(), b.clone(), c.clone()])
        .unwrap();
    let ab = gemm_naive(&a, &b, m, n, k);
    let expected: Vec<f32> = ab
        .iter()
        .zip(&c)
        .map(|(x, y)| 1.5 * x + 0.5 * y)
        .collect();
    assert!(max_abs_diff(&out.outputs[0], &expected) < 1e-3);
}

/// The parametrization-is-semantics-free claim, measured end-to-end on
/// the real runtime: the Pallas tiled conv, the Winograd conv, and XLA's
/// native conv all produce the same numbers.
#[test]
fn conv_algorithms_agree_through_pjrt() {
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = Engine::new(ArtifactStore::open(&dir).unwrap()).unwrap();
    let names = ["test_conv_tiled", "test_conv_wino", "test_conv_xla"];
    let inputs = engine.synth_inputs(names[0], 77).unwrap();
    let mut outs = Vec::new();
    for name in names {
        let meta = engine.store().get(name).unwrap();
        assert_eq!(
            meta.inputs.iter().map(|s| s.elems()).collect::<Vec<_>>(),
            inputs.iter().map(|v| v.len()).collect::<Vec<_>>(),
            "{name} input shapes"
        );
        outs.push(engine.run(name, &inputs).unwrap().outputs[0].clone());
    }
    assert!(max_abs_diff(&outs[0], &outs[2]) < 1e-2, "tiled vs xla");
    assert!(max_abs_diff(&outs[1], &outs[2]) < 1e-2, "wino vs xla");
}

#[test]
fn executable_cache_hits() {
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = Engine::new(ArtifactStore::open(&dir).unwrap()).unwrap();
    assert_eq!(engine.cached(), 0);
    engine.warm("quickstart_gemm").unwrap();
    assert_eq!(engine.cached(), 1);
    engine.warm("quickstart_gemm").unwrap();
    assert_eq!(engine.cached(), 1, "second warm must hit the cache");
    let inputs = engine.synth_inputs("quickstart_gemm", 5).unwrap();
    engine.run("quickstart_gemm", &inputs).unwrap();
    assert_eq!(engine.cached(), 1);
}

#[test]
fn engine_rejects_bad_inputs() {
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = Engine::new(ArtifactStore::open(&dir).unwrap()).unwrap();
    // Wrong arity.
    assert!(engine.run("quickstart_gemm", &[vec![0.0; 64 * 64]]).is_err());
    // Wrong element count.
    assert!(engine
        .run("quickstart_gemm", &[vec![0.0; 7], vec![0.0; 64 * 64]])
        .is_err());
    // Unknown artifact.
    assert!(engine.run("no_such_artifact", &[]).is_err());
}

#[test]
fn engine_actor_serves_concurrent_callers() {
    let Some(dir) = artifacts_dir() else { return };
    let (handle, join) = EngineHandle::spawn(&dir).unwrap();
    let mut threads = Vec::new();
    for t in 0..4 {
        let h = handle.clone();
        threads.push(std::thread::spawn(move || {
            let inputs = h.synth_inputs("quickstart_gemm", t).unwrap();
            for _ in 0..3 {
                let out = h.run("quickstart_gemm", inputs.clone()).unwrap();
                assert_eq!(out.outputs[0].len(), 64 * 64);
            }
        }));
    }
    for t in threads {
        t.join().unwrap();
    }
    let stats = handle.stats().unwrap();
    assert_eq!(stats.runs, 12);
    assert_eq!(stats.cached_executables, 1);
    handle.shutdown();
    join.join().unwrap();
}

#[test]
fn network_runner_executes_resnet_xla_stack() {
    let Some(dir) = artifacts_dir() else { return };
    let store = ArtifactStore::open(&dir).unwrap();
    let (handle, join) = EngineHandle::spawn(&dir).unwrap();
    let runner = NetworkRunner::new(handle.clone());
    let report = runner.run_network(&store, "resnet", "xla", 1).unwrap();
    assert_eq!(report.layers.len(), 26, "all Table-4 layers");
    assert!(report.total_gflops() > 0.0);
    for l in &report.layers {
        assert!(l.gflops > 0.0, "{}", l.layer);
        assert!(l.elapsed_s > 0.0);
    }
    handle.shutdown();
    join.join().unwrap();
}

/// Timing discipline: best-of-N never exceeds a single-run time.
#[test]
fn run_timed_takes_minimum() {
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = Engine::new(ArtifactStore::open(&dir).unwrap()).unwrap();
    let inputs = engine.synth_inputs("quickstart_gemm", 9).unwrap();
    let (_, best) = engine.run_timed("quickstart_gemm", &inputs, 5).unwrap();
    let single = engine.run("quickstart_gemm", &inputs).unwrap().elapsed;
    // Not a strict inequality in general, but best-of-5 should not be
    // dramatically slower than any observed run.
    assert!(best <= single * 3);
}
