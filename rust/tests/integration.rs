//! Cross-module integration tests (no PJRT required): device zoo ->
//! performance model -> tuner -> selection DB -> harness reports.

use portable_kernels::config::{ConvAlgorithm, ConvConfig, GemmConfig};
use portable_kernels::device::{all_devices, device_by_name};
use portable_kernels::harness::{
    fig_conv, fig_gemm, fig_network, fig_registers, tables,
};
use portable_kernels::nn::{network_layers, resnet50_layers, vgg16_layers};
use portable_kernels::perfmodel::{
    conv_estimate, gemm_estimate, vendor_conv, ConvProblem, GemmProblem,
    VendorLib,
};
use portable_kernels::tuner::{
    tune_conv, tune_gemm, ExhaustiveSearch, SelectionDb, SelectionKey,
};
use portable_kernels::util::tmp::TempDir;

/// The paper's end-to-end tuning workflow: tune every network layer for
/// every Table-1 device, persist the DB, reload it, and verify lookups.
#[test]
fn full_tuning_workflow_roundtrip() {
    let mut db = SelectionDb::new();
    let devices = ["mali-g71", "r9-nano", "i7-6700k-cpu"];
    for dev_id in devices {
        let dev = device_by_name(dev_id).unwrap();
        for layer in resnet50_layers().iter().take(6) {
            let r = tune_conv(&dev, layer, 1, &ExhaustiveSearch).unwrap();
            assert!(r.gflops > 0.0);
            db.put(
                SelectionKey::conv(
                    dev_id, layer.window, layer.stride, layer.in_h,
                    layer.in_w, layer.in_c, layer.out_c, 1,
                ),
                r.config,
                r.gflops,
            );
        }
    }
    let dir = TempDir::new("integ-db").unwrap();
    let path = dir.path().join("db.json");
    db.save(&path).unwrap();
    let loaded = SelectionDb::load(&path).unwrap();
    assert_eq!(loaded.len(), db.len());
    // Lookups work for every stored key.
    for dev_id in devices {
        let stem = &resnet50_layers()[0];
        let (cfg, g) = loaded
            .get::<ConvConfig>(&SelectionKey::conv(
                dev_id, stem.window, stem.stride, stem.in_h, stem.in_w,
                stem.in_c, stem.out_c, 1,
            ))
            .unwrap();
        assert!(g > 0.0);
        cfg.validate().unwrap();
    }
}

/// Portability headline: per-device winners differ, and each device's
/// winner beats the other device's winner *on its own hardware*.
#[test]
fn cross_device_specialization_pays() {
    let p = GemmProblem::new(1024, 1024, 1024);
    let mali = device_by_name("mali-g71").unwrap();
    let amd = device_by_name("r9-nano").unwrap();
    let mali_win = tune_gemm(&mali, p, &ExhaustiveSearch).unwrap().config;
    let amd_win = tune_gemm(&amd, p, &ExhaustiveSearch).unwrap().config;
    assert_ne!(mali_win, amd_win);

    let on = |dev, cfg: &GemmConfig| {
        gemm_estimate(dev, p, cfg).map(|e| e.gflops).unwrap_or(0.0)
    };
    assert!(on(&mali, &mali_win) >= on(&mali, &amd_win));
    assert!(on(&amd, &amd_win) >= on(&amd, &mali_win));
}

/// Every figure/table generator renders without panicking and is
/// structurally sound (CSV round-trip width).
#[test]
fn all_reports_render() {
    let reports = vec![
        tables::table1(),
        tables::table2(),
        tables::table3(),
        tables::table4(),
        fig_registers::fig2(),
        fig_conv::fig3(),
        fig_gemm::fig4b(),
        fig_gemm::fig4c(),
        fig_gemm::fig5_regions(),
        fig_network::fig_network("resnet", "hikey960").unwrap(),
        fig_network::fig_network("vgg", "i7-6700k").unwrap(),
    ];
    for r in reports {
        let text = r.render();
        assert!(text.contains("=="), "{}", r.title);
        let csv = r.to_csv();
        let cols = csv.lines().next().unwrap().split(',').count();
        for line in csv.lines().skip(1) {
            // Quoted cells never contain commas in our reports.
            assert_eq!(line.split(',').count(), cols, "{}", r.title);
        }
        assert_eq!(csv.lines().count(), r.rows.len() + 1);
    }
}

/// The tuned configuration's estimate is reproducible: tune -> re-evaluate
/// - > same number.
#[test]
fn tuned_scores_are_reproducible() {
    let dev = device_by_name("uhd630").unwrap();
    let layer = &vgg16_layers()[4]; // conv3_1
    let r = tune_conv(&dev, layer, 4, &ExhaustiveSearch).unwrap();
    // Re-evaluating the winner with the same tuned GEMM config must give
    // the same score the tuner reported (tune_conv tunes gemm first).
    let (gm, gn, gk) = layer.im2col_gemm(4);
    let gemm_cfg = tune_gemm(&dev, GemmProblem::new(gm, gn, gk), &ExhaustiveSearch)
        .unwrap()
        .config;
    let e = conv_estimate(
        &dev,
        &ConvProblem::new(layer.clone(), 4),
        &r.config,
        &gemm_cfg,
    )
    .unwrap();
    assert!((e.gflops - r.gflops).abs() < 1e-9);
}

/// Winograd only ever wins where it is legal, across the whole table.
#[test]
fn winograd_selections_respect_domain() {
    for dev in all_devices() {
        for layer in resnet50_layers() {
            let r = tune_conv(&dev, &layer, 1, &ExhaustiveSearch).unwrap();
            if r.config.algorithm == ConvAlgorithm::Winograd {
                assert_eq!(layer.window, 3, "{} {}", dev.id, layer.name);
                assert_eq!(layer.stride, 1, "{} {}", dev.id, layer.name);
            }
        }
    }
}

/// Network-level sanity on the modeled testbeds (Figs. 6-9 shapes):
/// per-layer winners vary by layer type on the HiKey GPU.
#[test]
fn network_tuning_is_layer_dependent() {
    let dev = device_by_name("mali-g71").unwrap();
    let mut algs = std::collections::HashSet::new();
    for layer in network_layers("resnet").unwrap() {
        let r = tune_conv(&dev, &layer, 1, &ExhaustiveSearch).unwrap();
        algs.insert(r.config.algorithm);
    }
    assert!(
        algs.len() >= 2,
        "expected multiple algorithms across ResNet layers, got {algs:?}"
    );
}

/// The vendor curves respect the same roofline the model does.
#[test]
fn vendor_curves_bounded_by_roofline() {
    for dev in all_devices() {
        for layer in vgg16_layers() {
            for lib in [
                VendorLib::ArmClOpenCl,
                VendorLib::ArmClNeon,
                VendorLib::MklDnn,
            ] {
                let g = vendor_conv(&dev, lib, &layer, 1);
                // Winograd-normalized 3x3 curves may exceed the direct
                // roofline by at most the F(2,3) flop reduction (2.25x).
                let cap = dev.roofline_gflops(layer.intensity(1)) * 2.25;
                assert!(g <= cap + 1e-9, "{} {lib:?} {g}", dev.id);
            }
        }
    }
}

/// Config spaces and validation interact sanely: every config the default
/// spaces emit validates, and every Table-2 config is feasible somewhere.
#[test]
fn spaces_and_feasibility() {
    let devs = all_devices();
    for cfg in GemmConfig::table2() {
        let feasible_somewhere = devs.iter().any(|d| {
            gemm_estimate(d, GemmProblem::new(256, 256, 256), &cfg).is_ok()
        });
        assert!(feasible_somewhere, "{}", cfg.name());
    }
    for c in portable_kernels::config::conv_space(3, 1) {
        c.validate().unwrap();
    }
}

/// ConvConfig naive == tiled 1x1 for the model, as for the kernels.
#[test]
fn naive_is_one_by_one_tile() {
    let dev = device_by_name("r9-nano").unwrap();
    let p = ConvProblem::new(
        portable_kernels::nn::ConvLayer::same("t", 3, 1, 28, 28, 64, 64),
        1,
    );
    let naive = conv_estimate(&dev, &p, &ConvConfig::naive(),
                              &GemmConfig::default()).unwrap();
    let tiled11 = conv_estimate(&dev, &p, &ConvConfig::tiled(1, 1, 1, 1),
                                &GemmConfig::default()).unwrap();
    assert!((naive.gflops - tiled11.gflops).abs() < 1e-9);
}
