//! Serving-layer integration tests: the multi-actor [`EnginePool`]
//! driving the real `NativeEngine` over synthetic manifests — routing
//! determinism, shared tuning, network serving, batched flushes, and
//! graceful shutdown.  (Backpressure and panic containment are unit
//! tested inside `coordinator::pool` with a controllable mock backend;
//! here everything executes real kernels.)

use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

use portable_kernels::blas::BlockedParams;
use portable_kernels::coordinator::{
    BatchPolicy, Batcher, EngineClient, EngineHandle, EnginePool,
    NetworkRunner, PoolConfig,
};
use portable_kernels::error::Error;
use portable_kernels::runtime::{
    ArtifactStore, Backend, NativeEngine, HOST_DEVICE,
};
use portable_kernels::tuner::{SelectionDb, SelectionKey};
use portable_kernels::util::tmp::TempDir;

/// One synthetic square GEMM manifest entry.
fn gemm_entry(name: &str, m: usize) -> String {
    let flops = 2 * (m as u64).pow(3);
    format!(
        r#"{{"name": "{name}", "kind": "gemm", "impl": "native",
            "file": "{name}.hlo.txt", "flops": {flops},
            "m": {m}, "n": {m}, "k": {m}, "groups": ["gemm"],
            "inputs": [{{"shape": [{m}, {m}], "dtype": "float32"}},
                       {{"shape": [{m}, {m}], "dtype": "float32"}}]}}"#
    )
}

/// One synthetic SAME-padded conv manifest entry.
fn conv_entry(name: &str, layer: &str, h: u32, c: u32, k: u32) -> String {
    let flops = 2u64 * (h as u64) * (h as u64) * (k as u64) * 9 * (c as u64);
    format!(
        r#"{{"name": "{name}", "kind": "conv", "impl": "native",
            "file": "{name}.hlo.txt", "flops": {flops}, "batch": 1,
            "algorithm": "im2col", "groups": ["network"],
            "layer": {{"name": "{layer}", "window": 3, "stride": 1,
                       "in_h": {h}, "in_w": {h}, "in_c": {c}, "out_c": {k},
                       "out_h": {h}, "out_w": {h}, "padding": "SAME",
                       "flops": {flops}}},
            "inputs": [{{"shape": [1, {h}, {h}, {c}], "dtype": "float32"}},
                       {{"shape": [3, 3, {c}, {k}], "dtype": "float32"}}]}}"#
    )
}

/// Twelve small GEMM artifacts (`zoo_g0`..`zoo_g11`) plus a three-layer
/// synthetic network — enough distinct keys that the ring spreads them
/// over every actor of a small pool.
fn write_zoo(dir: &Path) {
    let mut entries: Vec<String> = (0..12)
        .map(|i| gemm_entry(&format!("zoo_g{i}"), 16 + 4 * i))
        .collect();
    entries.push(conv_entry("net_tiny_conv1_native", "conv1", 12, 4, 8));
    entries.push(conv_entry("net_tiny_conv2_native", "conv2", 12, 8, 8));
    entries.push(conv_entry("net_tiny_conv3_native", "conv3", 12, 8, 4));
    std::fs::write(
        dir.join("manifest.json"),
        format!(
            r#"{{"version": 1, "artifacts": [{}]}}"#,
            entries.join(",\n")
        ),
    )
    .unwrap();
}

fn zoo_pool(actors: usize) -> (TempDir, ArtifactStore, EnginePool) {
    let dir = TempDir::new("serving").unwrap();
    write_zoo(dir.path());
    let store = ArtifactStore::open(dir.path()).unwrap();
    let actor_store = store.clone();
    let config = PoolConfig { actors, queue_depth: 64, spill_depth: 64, ..Default::default() };
    let pool = EnginePool::spawn_with(config, move |_| {
        NativeEngine::new(actor_store.clone())
    })
    .unwrap();
    (dir, store, pool)
}

#[test]
fn routing_is_per_artifact_and_stable() {
    let (_dir, _store, pool) = zoo_pool(3);
    let names: Vec<String> = (0..12).map(|i| format!("zoo_g{i}")).collect();

    // Same artifact -> same actor, every time the question is asked.
    let homes: Vec<usize> = names
        .iter()
        .map(|n| pool.route_of(n).expect("healthy pool routes everything"))
        .collect();
    for (name, home) in names.iter().zip(&homes) {
        for _ in 0..5 {
            assert_eq!(pool.route_of(name), Some(*home), "{name} moved");
        }
    }
    // The ring spreads 12 keys over all 3 actors (verified property of
    // the hash; deterministic for these names).
    let mut distinct = homes.clone();
    distinct.sort_unstable();
    distinct.dedup();
    assert_eq!(distinct.len(), 3, "homes: {homes:?}");

    // Execution follows the routing decision: run everything, then
    // check per-actor run counts add up and every actor worked.
    for name in &names {
        let inputs = pool.synth_inputs(name, 7).unwrap();
        for _ in 0..3 {
            let out = pool.run(name, inputs.clone()).unwrap();
            assert!(!out.outputs[0].is_empty());
        }
    }
    let mut total = 0;
    for idx in 0..pool.actors() {
        let s = pool.actor_stats(idx).unwrap();
        assert!(s.runs > 0, "actor {idx} never ran anything");
        // Plans cached on the owning actor only: each actor planned
        // exactly the artifacts routed to it.
        let owned = homes.iter().filter(|&&h| h == idx).count();
        assert_eq!(s.cached_executables, owned, "actor {idx}");
        total += s.runs;
    }
    assert_eq!(total, 12 * 3);
    pool.shutdown();
}

#[test]
fn pool_results_match_a_direct_engine_bit_for_bit() {
    let (_dir, store, pool) = zoo_pool(2);
    let mut direct = NativeEngine::new(store).unwrap();
    for name in ["zoo_g0", "zoo_g5", "zoo_g11"] {
        let inputs = pool.synth_inputs(name, 42).unwrap();
        let via_pool = pool.run(name, inputs.clone()).unwrap();
        let via_direct = direct.run(name, &inputs).unwrap();
        assert_eq!(
            via_pool.outputs, via_direct.outputs,
            "{name}: pooled execution must be the same computation"
        );
    }
    pool.shutdown();
}

#[test]
fn every_actor_plans_with_the_shared_tuning_db() {
    let dir = TempDir::new("serving-tuned").unwrap();
    write_zoo(dir.path());
    let store = ArtifactStore::open(dir.path()).unwrap();

    // All zoo GEMMs are < 64 so they share the 64^3 problem class; one
    // tuned entry covers the lot.
    let tuned = BlockedParams { bm: 8, bn: 8, bk: 8, mr: 2, nr: 2, threads: 1 };
    let mut db = SelectionDb::new();
    db.put(
        SelectionKey::gemm(HOST_DEVICE, 16, 16, 16),
        portable_kernels::config::GemmPoint::scalar(tuned),
        9.0,
    );
    let shared = Arc::new(db);

    // The constructor runs on each actor thread and *proves* the shared
    // DB is consulted there: any actor planning with the wrong params
    // fails the whole spawn.
    let config = PoolConfig { actors: 3, ..Default::default() };
    let actor_store = store.clone();
    let check = Arc::clone(&shared);
    let pool = EnginePool::spawn_with(config, move |idx| {
        let mut e = NativeEngine::with_shared_tuning(
            actor_store.clone(),
            Arc::clone(&check),
        );
        let got = e.planned_params("zoo_g0")?;
        if got != tuned {
            return Err(Error::Runtime(format!(
                "actor {idx} planned {} instead of the tuned {}",
                got.name(),
                tuned.name()
            )));
        }
        Ok(e)
    })
    .unwrap();
    assert_eq!(pool.healthy_actors(), 3);
    let inputs = pool.synth_inputs("zoo_g3", 5).unwrap();
    assert!(pool.run("zoo_g3", inputs).is_ok());
    pool.shutdown();

    // The convenience constructor wires the same sharing.
    let pool = EnginePool::native_tuned(
        store,
        shared,
        PoolConfig { actors: 2, ..Default::default() },
    )
    .unwrap();
    assert_eq!(pool.healthy_actors(), 2);
    pool.shutdown();
}

#[test]
fn warm_at_spawn_prewarms_every_artifact_on_its_home_actor() {
    let dir = TempDir::new("serving-warm").unwrap();
    write_zoo(dir.path());
    let store = ArtifactStore::open(dir.path()).unwrap();
    let actor_store = store.clone();
    let config = PoolConfig {
        actors: 3,
        warm_at_spawn: true,
        ..Default::default()
    };
    let pool = EnginePool::spawn_with(config, move |_| {
        NativeEngine::new(actor_store.clone())
    })
    .unwrap();

    // Before ANY request: every artifact is already planned, and planned
    // on exactly its ring-home actor — first requests never pay
    // plan-compile latency, and caches are never duplicated.
    let names: Vec<String> = store.iter().map(|m| m.name.clone()).collect();
    let mut owned = vec![0usize; pool.actors()];
    for name in &names {
        owned[pool.route_of(name).unwrap()] += 1;
    }
    let mut cached_total = 0;
    for idx in 0..pool.actors() {
        let cached = pool.actor_stats(idx).unwrap().cached_executables;
        assert_eq!(
            cached, owned[idx],
            "actor {idx}: warm fan-out cached {cached} plans but owns \
             {} artifacts",
            owned[idx]
        );
        cached_total += cached;
    }
    assert_eq!(cached_total, store.len(), "every artifact pre-warmed");

    // Explicit re-warm is idempotent.
    assert_eq!(pool.prewarm().unwrap(), store.len());
    for idx in 0..pool.actors() {
        assert_eq!(
            pool.actor_stats(idx).unwrap().cached_executables,
            owned[idx]
        );
    }
    pool.shutdown();

    // Without the flag, spawn leaves caches cold (the pre-existing
    // behavior stays the default).
    let (_dir2, _store2, cold) = zoo_pool(2);
    for idx in 0..cold.actors() {
        assert_eq!(cold.actor_stats(idx).unwrap().cached_executables, 0);
    }
    cold.shutdown();
}

#[test]
fn network_stack_serves_from_the_pool() {
    let (_dir, store, pool) = zoo_pool(2);
    let runner = NetworkRunner::new(&pool);
    let report = runner.run_network(&store, "tiny", "native", 2).unwrap();
    assert_eq!(report.layers.len(), 3, "all synthetic network layers");
    assert!(report.total_flops > 0);
    assert!(report.total_time_s > 0.0);

    // Same stack through a single actor: identical layer set (the pool
    // changes the serving shape, not the work).
    let single_store = store.clone();
    let (handle, join) =
        EngineHandle::spawn_with(move || NativeEngine::new(single_store))
            .unwrap();
    let single = NetworkRunner::new(handle.clone());
    let single_report =
        single.run_network(&store, "tiny", "native", 2).unwrap();
    assert_eq!(
        report.layers.iter().map(|l| &l.artifact).collect::<Vec<_>>(),
        single_report.layers.iter().map(|l| &l.artifact).collect::<Vec<_>>()
    );
    assert_eq!(report.total_flops, single_report.total_flops);
    handle.shutdown();
    let _ = join.join();
    pool.shutdown();
}

#[test]
fn batcher_flushes_groups_through_the_pool() {
    let (_dir, _store, pool) = zoo_pool(2);
    let mut batcher: Batcher<Vec<Vec<f32>>> = Batcher::new(BatchPolicy {
        max_batch: 4,
        max_delay: Duration::ZERO, // everything is always due
    });
    // A bursty interleaved client over two artifacts.
    let a_inputs = pool.synth_inputs("zoo_g1", 3).unwrap();
    let b_inputs = pool.synth_inputs("zoo_g2", 3).unwrap();
    for i in 0..9 {
        if i % 3 == 2 {
            batcher.push("zoo_g2", b_inputs.clone());
        } else {
            batcher.push("zoo_g1", a_inputs.clone());
        }
    }
    let flushed = batcher.flush_due(&pool, Instant::now());
    assert!(batcher.is_empty(), "flush_due must flush everything due");
    let served: usize = flushed.iter().map(|(_, r)| r.len()).sum();
    assert_eq!(served, 9);
    for (artifact, results) in &flushed {
        for r in results {
            let out = r.as_ref().unwrap_or_else(|e| {
                panic!("{artifact} failed in a flushed group: {e}")
            });
            assert!(!out.outputs[0].is_empty());
        }
    }
    assert_eq!(pool.stats().runs, 9);
    pool.shutdown();
}

#[test]
fn shutdown_serves_every_accepted_request() {
    let (_dir, _store, pool) = zoo_pool(2);
    let mut tickets = Vec::new();
    for i in 0..16 {
        let name = format!("zoo_g{}", i % 12);
        let inputs = pool.synth_inputs(&name, i as u64).unwrap();
        tickets.push(pool.submit_run(&name, inputs).unwrap());
    }
    // Close the queues while requests may still be pending: everything
    // accepted must still be served, never dropped.
    pool.shutdown();
    for (i, t) in tickets.into_iter().enumerate() {
        let out = t.wait().unwrap_or_else(|e| {
            panic!("request {i} dropped during graceful shutdown: {e}")
        });
        assert!(!out.outputs[0].is_empty());
    }
}
