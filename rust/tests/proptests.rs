//! Property-based tests over seeded random generators (the offline
//! environment has no proptest crate; `util::rng::XorShift` provides the
//! deterministic generators, and every case prints its inputs on failure).

use portable_kernels::blas::{
    gemm_blocked, gemm_blocked_ex, gemm_blocked_isa, gemm_i8_blocked_isa,
    gemm_i8_dequant, gemm_i8_dequant_ex, gemm_naive, gemm_workspace,
    max_abs_diff, quantize_slice, BlockedParams, Dtype, Isa, Pack,
    QuantParams, MICRO_KERNEL_SHAPES,
};
use portable_kernels::config::{ConvConfig, ConvPoint, GemmConfig, GemmPoint};
use portable_kernels::coordinator::{BatchPolicy, Batcher};
use portable_kernels::device::{all_devices, DeviceSpec};
use portable_kernels::nn::ConvLayer;
use portable_kernels::perfmodel::{
    conv_estimate, conv_regs, gemm_estimate, ConvProblem, GemmProblem,
};
use portable_kernels::tuner::{tune_gemm, ExhaustiveSearch};
use portable_kernels::util::json;
use portable_kernels::util::rng::XorShift;
use portable_kernels::util::scratch::Scratch;

const CASES: usize = 60;

// ---- tolerance-aware conformance bounds ----
//
// Each conv algorithm conforms to the direct oracle within an
// algorithm-specific bound, because the algorithms do different
// arithmetic:
//
// * tiled direct reorders nothing per output — bit-exact (rtol 0);
// * Winograd F(2×2, 3×3) evaluates at points {0, ±1}: transform entries
//   are 0/±1/±½, so the transform-domain round-trip loses only a couple
//   of ULPs per accumulation — 1e-3 relative is generous;
// * Winograd F(4×4, 3×3) evaluates at points {0, ±1, ±2}: transform
//   entries reach 8 (Aᵀ) and 5 (Bᵀ), and the 6×6 congruences both
//   amplify intermediates and cancel them back down, so the error bound
//   derives as roughly |Bᵀ|·|B|·|Aᵀ|·|A| ≈ 10× the F(2×2) conditioning —
//   one order of magnitude looser, 1e-2 relative.
const TOL_TILED: f32 = 0.0;
const TOL_WINO2: f32 = 1e-3;
const TOL_WINO4: f32 = 1e-2;

/// Assert element-wise closeness under a *relative* bound:
/// `|a - e| <= rtol * max(|e|, 1)` — the `max(|e|, 1)` floor keeps the
/// bound meaningful around zero-valued outputs.  `rtol == 0` demands
/// exact equality (the tiled-direct contract).
fn assert_close_rel(actual: &[f32], expected: &[f32], rtol: f32, what: &str) {
    assert_eq!(actual.len(), expected.len(), "{what}: length mismatch");
    for (i, (a, e)) in actual.iter().zip(expected).enumerate() {
        let bound = rtol * e.abs().max(1.0);
        let diff = (a - e).abs();
        assert!(
            diff <= bound,
            "{what}: element {i}: {a} vs {e} (|diff| {diff} > {bound})"
        );
    }
}

fn random_gemm_config(rng: &mut XorShift) -> GemmConfig {
    GemmConfig {
        rt_m: *rng.choose(&[1, 2, 4, 8, 16]),
        rt_n: *rng.choose(&[1, 2, 4, 8, 16]),
        wg_r: *rng.choose(&[2, 4, 8, 16]),
        wg_c: *rng.choose(&[2, 4, 8, 16]),
        block_k: *rng.choose(&[8, 16, 32, 64]),
        use_local: rng.below(2) == 0,
        double_buffer: rng.below(2) == 0,
    }
}

fn random_device(rng: &mut XorShift) -> DeviceSpec {
    let devs = all_devices();
    devs[rng.below(devs.len() as u64) as usize].clone()
}

/// Config-string round-trip for arbitrary configurations.
#[test]
fn prop_gemm_config_roundtrip() {
    let mut rng = XorShift::new(101);
    for case in 0..CASES {
        let cfg = random_gemm_config(&mut rng);
        let parsed = GemmConfig::parse(&cfg.name())
            .unwrap_or_else(|e| panic!("case {case}: {} -> {e}", cfg.name()));
        // block_k is not encoded in the name; compare the rest.
        assert_eq!(
            (parsed.rt_m, parsed.rt_n, parsed.wg_r, parsed.wg_c,
             parsed.use_local, parsed.double_buffer),
            (cfg.rt_m, cfg.rt_n, cfg.wg_r, cfg.wg_c, cfg.use_local,
             cfg.double_buffer),
            "case {case}"
        );
    }
}

/// The model never exceeds the device roofline, for any (device, config,
/// problem) triple.
#[test]
fn prop_model_bounded_by_roofline() {
    let mut rng = XorShift::new(202);
    for case in 0..CASES {
        let dev = random_device(&mut rng);
        let cfg = random_gemm_config(&mut rng);
        let p = GemmProblem::new(
            rng.range(1, 2048),
            rng.range(1, 2048),
            rng.range(1, 2048),
        );
        if let Ok(e) = gemm_estimate(&dev, p, &cfg) {
            let roof = dev.roofline_gflops(e.intensity);
            assert!(
                e.gflops <= roof * 1.0001,
                "case {case}: {} {} {:?}: {} > {roof}",
                dev.id, cfg.name(), p, e.gflops
            );
            assert!(e.time_s > 0.0 && e.gflops.is_finite());
        }
    }
}

/// Estimates are deterministic (pure function of inputs).
#[test]
fn prop_model_deterministic() {
    let mut rng = XorShift::new(303);
    for _ in 0..CASES {
        let dev = random_device(&mut rng);
        let cfg = random_gemm_config(&mut rng);
        let p = GemmProblem::new(rng.range(8, 512), rng.range(8, 512), rng.range(8, 512));
        let a = gemm_estimate(&dev, p, &cfg).map(|e| e.gflops);
        let b = gemm_estimate(&dev, p, &cfg).map(|e| e.gflops);
        match (a, b) {
            (Ok(x), Ok(y)) => assert_eq!(x, y),
            (Err(_), Err(_)) => {}
            other => panic!("non-deterministic feasibility: {other:?}"),
        }
    }
}

/// Exhaustive tuning returns the argmax: no feasible config in the space
/// scores higher than the winner.
#[test]
fn prop_tuner_returns_argmax() {
    let mut rng = XorShift::new(404);
    for case in 0..8 {
        let dev = random_device(&mut rng);
        let p = GemmProblem::new(
            rng.range(32, 1024),
            rng.range(32, 1024),
            rng.range(32, 1024),
        );
        let win = tune_gemm(&dev, p, &ExhaustiveSearch).unwrap();
        for cfg in portable_kernels::config::gemm_space() {
            if let Ok(e) = gemm_estimate(&dev, p, &cfg) {
                assert!(
                    e.gflops <= win.gflops + 1e-9,
                    "case {case}: {} beats winner {} on {}",
                    cfg.name(), win.config.name(), dev.id
                );
            }
        }
    }
}

/// Blocked host GEMM equals the naive oracle for arbitrary shapes and
/// blocking parameters.
#[test]
fn prop_blocked_gemm_correct() {
    let mut rng = XorShift::new(505);
    for case in 0..30 {
        let m = rng.range(1, 96) as usize;
        let n = rng.range(1, 96) as usize;
        let k = rng.range(1, 96) as usize;
        let a = rng.f32_vec(m * k);
        let b = rng.f32_vec(k * n);
        let params = BlockedParams {
            bm: rng.range(1, 64) as usize,
            bn: rng.range(1, 64) as usize,
            bk: rng.range(1, 64) as usize,
            mr: rng.range(1, 8) as usize,
            nr: rng.range(1, 16) as usize,
            threads: rng.range(0, 4) as usize,
        };
        let expected = gemm_naive(&a, &b, m, n, k);
        let got = gemm_blocked(&a, &b, m, n, k, &params);
        assert!(
            max_abs_diff(&expected, &got) < 1e-3,
            "case {case}: {m}x{n}x{k} {params:?}"
        );
    }
}

/// Blocked GEMM on deliberately ragged edges: shapes constructed so that
/// `m % mr != 0` and `n % nr != 0` (the partial register tiles) and
/// `k < bk` (a single short k-panel) all occur together, across sampled
/// `BlockedParams`.  These are exactly the strips the packed micro-kernel
/// zero-pads; a bug there shows up only off the aligned fast path.
#[test]
fn prop_blocked_gemm_ragged_edges() {
    let mut rng = XorShift::new(1111);
    for case in 0..30 {
        let mr = rng.range(2, 8) as usize;
        let nr = rng.range(2, 16) as usize;
        // q whole strips plus a ragged remainder in [1, mr).
        let m = rng.range(0, 3) as usize * mr + rng.range(1, mr as u64 - 1).max(1) as usize;
        let n = rng.range(0, 3) as usize * nr + rng.range(1, nr as u64 - 1).max(1) as usize;
        // k strictly below the panel depth: one short panel.
        let bk = rng.range(8, 64) as usize;
        let k = rng.range(1, bk as u64 - 1) as usize;
        let params = BlockedParams {
            bm: rng.range(1, 64) as usize,
            bn: rng.range(1, 64) as usize,
            bk,
            mr,
            nr,
            threads: 1,
        };
        assert!(m % mr != 0, "case {case}: m={m} mr={mr}");
        assert!(n % nr != 0, "case {case}: n={n} nr={nr}");
        assert!(k < bk, "case {case}: k={k} bk={bk}");
        let a = rng.f32_vec(m * k);
        let b = rng.f32_vec(k * n);
        let expected = gemm_naive(&a, &b, m, n, k);
        let got = gemm_blocked(&a, &b, m, n, k, &params);
        assert!(
            max_abs_diff(&expected, &got) < 1e-3,
            "case {case}: {m}x{n}x{k} {params:?}"
        );
    }
}

/// Degenerate dimensions: every combination of `m == 1`, `n == 1`,
/// `k == 1` (vector-vector, outer-product, and scalar-ish GEMMs) must
/// still agree with the oracle under sampled blocking parameters.
#[test]
fn prop_blocked_gemm_degenerate_dims() {
    let mut rng = XorShift::new(2222);
    for case in 0..24 {
        // Cycle through the degenerate corner assignments.
        let m = if case % 2 == 0 { 1 } else { rng.range(2, 48) as usize };
        let n = if (case / 2) % 2 == 0 { 1 } else { rng.range(2, 48) as usize };
        let k = if (case / 4) % 2 == 0 { 1 } else { rng.range(2, 48) as usize };
        let params = BlockedParams {
            bm: rng.range(1, 32) as usize,
            bn: rng.range(1, 32) as usize,
            bk: rng.range(1, 32) as usize,
            mr: rng.range(1, 8) as usize,
            nr: rng.range(1, 16) as usize,
            threads: 1,
        };
        let a = rng.f32_vec(m * k);
        let b = rng.f32_vec(k * n);
        let expected = gemm_naive(&a, &b, m, n, k);
        let got = gemm_blocked(&a, &b, m, n, k, &params);
        assert!(
            max_abs_diff(&expected, &got) < 1e-3,
            "case {case}: {m}x{n}x{k} {params:?}"
        );
    }
}

/// Micro-tile raggedness specifically: fix awkward micro-tiles against
/// block sizes that do not divide them, sweeping the monomorphized
/// (4x8, 8x8, 8x16, 4x16) and generic kernel paths.
#[test]
fn prop_blocked_gemm_all_kernel_paths() {
    let mut rng = XorShift::new(3333);
    for &(mr, nr) in &[(4usize, 8usize), (8, 8), (8, 16), (4, 16), (3, 5), (1, 1)] {
        for _ in 0..4 {
            let m = rng.range(1, 70) as usize;
            let n = rng.range(1, 70) as usize;
            let k = rng.range(1, 70) as usize;
            let params = BlockedParams {
                bm: rng.range(1, 48) as usize,
                bn: rng.range(1, 48) as usize,
                bk: rng.range(1, 48) as usize,
                mr,
                nr,
                threads: 1,
            };
            let a = rng.f32_vec(m * k);
            let b = rng.f32_vec(k * n);
            let expected = gemm_naive(&a, &b, m, n, k);
            let got = gemm_blocked(&a, &b, m, n, k, &params);
            assert!(
                max_abs_diff(&expected, &got) < 1e-3,
                "{m}x{n}x{k} {params:?}"
            );
        }
    }
}

/// Parallel blocked GEMM is BIT-identical (not approximately equal) to
/// the serial path, for ragged and degenerate shapes, across thread
/// counts — including threads far above the number of macro-tile bands.
/// Each worker owns a disjoint band of C rows and runs the exact serial
/// per-band code, so this is an equality the design guarantees, and the
/// test that keeps it guaranteed.
#[test]
fn prop_parallel_gemm_bit_identical_to_serial() {
    let mut rng = XorShift::new(5555);
    for case in 0..20 {
        // Mix ragged (m % mr != 0), degenerate (dim == 1), and
        // multi-band (m > bm) shapes.
        let m = match case % 4 {
            0 => 1,
            1 => rng.range(2, 24) as usize,
            _ => rng.range(24, 160) as usize,
        };
        let n = if case % 5 == 0 { 1 } else { rng.range(1, 64) as usize };
        let k = if case % 7 == 0 { 1 } else { rng.range(1, 64) as usize };
        let params = BlockedParams {
            bm: rng.range(1, 32) as usize,
            bn: rng.range(1, 32) as usize,
            bk: rng.range(1, 32) as usize,
            mr: rng.range(1, 8) as usize,
            nr: rng.range(1, 16) as usize,
            threads: 1,
        };
        let a = rng.f32_vec(m * k);
        let b = rng.f32_vec(k * n);
        let serial = gemm_blocked(&a, &b, m, n, k, &params);
        for threads in [2usize, 3, 8] {
            let par = gemm_blocked(
                &a,
                &b,
                m,
                n,
                k,
                &BlockedParams { threads, ..params },
            );
            assert!(
                serial == par,
                "case {case}: threads={threads} diverged at {m}x{n}x{k} \
                 {params:?} (max diff {})",
                max_abs_diff(&serial, &par)
            );
        }
    }
}

/// Parallel im2col conv is bit-identical to the serial path on ragged
/// and degenerate shapes, threads ∈ {2, 3, 8} — including thread counts
/// above the number of output rows (single-pixel outputs).
#[test]
fn prop_parallel_conv_bit_identical_to_serial() {
    use portable_kernels::blas::{conv2d_im2col, Conv2dShape};
    let mut rng = XorShift::new(6666);
    for case in 0..12 {
        let window = *rng.choose(&[1usize, 3, 5]);
        let stride = *rng.choose(&[1usize, 2]);
        let batch = rng.range(1, 3) as usize;
        let h = rng.range(1, 13).max(window as u64) as usize;
        let w = rng.range(1, 13).max(window as u64) as usize;
        let c = rng.range(1, 9) as usize;
        let kc = rng.range(1, 9) as usize;
        let s = Conv2dShape::same(batch, h, w, c, kc, window, stride);
        let x = rng.f32_vec(s.input_elems());
        let f = rng.f32_vec(s.filter_elems());
        let params = BlockedParams {
            bm: rng.range(1, 24) as usize,
            bn: rng.range(1, 24) as usize,
            bk: rng.range(1, 24) as usize,
            mr: rng.range(1, 8) as usize,
            nr: rng.range(1, 16) as usize,
            threads: 1,
        };
        let serial = conv2d_im2col(&x, &f, &s, &params);
        for threads in [2usize, 3, 8] {
            let par = conv2d_im2col(
                &x,
                &f,
                &s,
                &BlockedParams { threads, ..params },
            );
            assert!(
                serial == par,
                "case {case}: threads={threads} diverged on {s:?} {params:?}"
            );
        }
    }
}

/// The tolerance-aware conv conformance suite: every algorithm family
/// conforms to the *direct* oracle within its documented bound
/// ([`TOL_TILED`] / [`TOL_WINO2`] / [`TOL_WINO4`]) on ragged/degenerate
/// 3×3-stride-1 shapes — the shapes where all of them run natively —
/// and each algorithm is BIT-identical across thread counts (threads ∈
/// {2, 8} vs serial), for both `wino_m` tile sizes.  This is the native
/// counterpart of the paper's "the algorithm is a parameter, not a
/// semantic" claim, with the numerics contract stated per algorithm.
#[test]
fn prop_conv_algorithms_agree_on_winograd_domain() {
    use portable_kernels::blas::{
        conv2d_direct, conv2d_im2col, conv2d_tiled, conv2d_winograd,
        Conv2dShape,
    };
    let mut rng = XorShift::new(7777);
    for case in 0..12 {
        // Force degenerate corners through the cycle: single-row,
        // single-column, single-channel, and batch-of-one shapes all
        // occur (SAME pads, so any spatial size is legal for 3x3/s1).
        // h/w from 1 (sub-tile, fully ragged) through sizes that leave
        // partial tiles for both m=2 and m=4.
        let h = match case % 4 {
            0 => 1,
            1 => 2,
            _ => rng.range(3, 12) as usize,
        };
        let w = match case % 3 {
            0 => 1,
            _ => rng.range(2, 12) as usize,
        };
        let c = if case % 5 == 0 { 1 } else { rng.range(1, 8) as usize };
        let k = if case % 7 == 0 { 1 } else { rng.range(1, 8) as usize };
        let batch = rng.range(1, 3) as usize;
        let s = Conv2dShape::same(batch, h, w, c, k, 3, 1);
        let x = rng.f32_vec(s.input_elems());
        let f = rng.f32_vec(s.filter_elems());
        let params = BlockedParams {
            bm: rng.range(1, 24) as usize,
            bn: rng.range(1, 24) as usize,
            bk: rng.range(1, 24) as usize,
            mr: rng.range(1, 8) as usize,
            nr: rng.range(1, 16) as usize,
            threads: 1,
        };
        let tile = ConvConfig::tiled(
            rng.range(1, 5) as u32,
            rng.range(1, 5) as u32,
            *rng.choose(&[1u32, 2, 4]),
            *rng.choose(&[1u32, 2, 4]),
        );
        let oracle = conv2d_direct(&x, &f, &s);

        // Tiled direct: same arithmetic as the oracle — bit-exact.
        let tiled = conv2d_tiled(&x, &f, &s, &tile, 1);
        assert_close_rel(
            &tiled,
            &oracle,
            TOL_TILED,
            &format!("case {case}: tiled {} on {s:?}", tile.name()),
        );
        // im2col: the lowered GEMM accumulates in a different order but
        // never transforms — the F(2×2) bound covers it comfortably.
        let im2col = conv2d_im2col(&x, &f, &s, &params);
        assert_close_rel(
            &im2col,
            &oracle,
            TOL_WINO2,
            &format!("case {case}: im2col on {s:?}"),
        );
        // Both Winograd tile sizes, each within its documented bound.
        for (m, tol) in [(2usize, TOL_WINO2), (4, TOL_WINO4)] {
            let wino = conv2d_winograd(&x, &f, &s, m, &params, Isa::Scalar);
            assert_close_rel(
                &wino,
                &oracle,
                tol,
                &format!("case {case}: winograd F({m}x{m}) on {s:?}"),
            );
            // Threaded runs are bit-identical to serial for each m.
            for threads in [2usize, 8] {
                let tp = BlockedParams { threads, ..params };
                assert!(
                    conv2d_winograd(&x, &f, &s, m, &tp, Isa::Scalar) == wino,
                    "case {case}: winograd F({m}x{m}) threads={threads} \
                     diverged on {s:?}"
                );
            }
        }
        // Threaded runs of the non-Winograd algorithms too.
        for threads in [2usize, 8] {
            assert!(
                conv2d_tiled(&x, &f, &s, &tile, threads) == tiled,
                "case {case}: tiled threads={threads} diverged on {s:?}"
            );
            assert!(
                conv2d_im2col(
                    &x,
                    &f,
                    &s,
                    &BlockedParams { threads, ..params }
                ) == im2col,
                "case {case}: im2col threads={threads} diverged on {s:?}"
            );
        }
    }
}

/// Generic kernel-space storage (`SelectionDb::put`/`get` over any
/// `P: KernelSpace`) round-trips arbitrary GEMM points (every ISA
/// value, including ones this host cannot run — storage is
/// host-independent; only *plans* degrade) and conv points through
/// JSON save/load, bit-exactly.
#[test]
fn prop_selection_db_points_roundtrip_via_disk() {
    use portable_kernels::config::ConvAlgorithm;
    use portable_kernels::tuner::{SelectionDb, SelectionKey};
    use portable_kernels::util::tmp::TempDir;

    let mut rng = XorShift::new(4242);
    let dir = TempDir::new("prop-seldb").unwrap();
    for case in 0..40 {
        let mut db = SelectionDb::new();
        // A random GEMM point: registry micro-tile, any ISA.
        let &(mr, nr) =
            rng.choose(MICRO_KERNEL_SHAPES);
        let gp = GemmPoint {
            params: BlockedParams {
                bm: rng.range(1, 128) as usize,
                bn: rng.range(1, 128) as usize,
                bk: rng.range(1, 128) as usize,
                mr,
                nr,
                threads: rng.range(0, 8) as usize,
            },
            isa: *rng.choose(&Isa::all()),
            dtype: *rng.choose(&Dtype::all()),
            pack: *rng.choose(&Pack::all()),
        };
        let gkey = SelectionKey::gemm(
            "prop-host",
            rng.range(1, 2048),
            rng.range(1, 2048),
            rng.range(1, 2048),
        );
        let g_gf = rng.range(1, 1_000_000) as f64 / 100.0;
        db.put(gkey.clone(), gp, g_gf);

        // A random conv point: any algorithm family, legal wino_m.
        let algorithm = *rng.choose(&[
            ConvAlgorithm::Im2col,
            ConvAlgorithm::Tiled,
            ConvAlgorithm::Winograd,
            ConvAlgorithm::Naive,
        ]);
        let cp = ConvPoint {
            config: ConvConfig {
                tile_h: rng.range(1, 8) as u32,
                tile_w: rng.range(1, 8) as u32,
                vec_c: *rng.choose(&[1u32, 2, 4]),
                vec_k: *rng.choose(&[1u32, 2, 4, 16]),
                block_k: rng.range(0, 4) as u32,
                algorithm,
                wino_m: *rng.choose(&[2u32, 4]),
            },
            blocked: BlockedParams {
                bm: rng.range(1, 64) as usize,
                bn: rng.range(1, 64) as usize,
                bk: rng.range(1, 64) as usize,
                mr: rng.range(1, 16) as usize,
                nr: rng.range(1, 16) as usize,
                threads: rng.range(0, 4) as usize,
            },
            isa: *rng.choose(&Isa::all()),
            // The i8 dtype is only legal on im2col conv points
            // (ConvPoint::validate); storage round-trips re-validate on
            // decode, so the sampler respects the same rule.
            dtype: if algorithm == ConvAlgorithm::Im2col {
                *rng.choose(&Dtype::all())
            } else {
                Dtype::F32
            },
            // Packed-B lowering is only legal on the GEMM-lowered
            // algorithms (ConvPoint::validate); same sampler rule.
            pack: if matches!(
                algorithm,
                ConvAlgorithm::Im2col | ConvAlgorithm::Winograd
            ) {
                *rng.choose(&Pack::all())
            } else {
                Pack::A
            },
        };
        let ckey = SelectionKey::conv(
            "prop-host",
            *rng.choose(&[1u32, 3, 5]),
            *rng.choose(&[1u32, 2]),
            rng.range(1, 64) as u32,
            rng.range(1, 64) as u32,
            rng.range(1, 64) as u32,
            rng.range(1, 64) as u32,
            rng.range(1, 8) as u32,
        );
        let c_gf = rng.range(1, 1_000_000) as f64 / 100.0;
        db.put(ckey.clone(), cp, c_gf);

        let path = dir.path().join(format!("case{case}.json"));
        db.save(&path).unwrap();
        let loaded = SelectionDb::load(&path)
            .unwrap_or_else(|e| panic!("case {case}: {e}"));
        assert_eq!(
            loaded.get::<GemmPoint>(&gkey),
            Some((gp, g_gf)),
            "case {case}: gemm point diverged"
        );
        assert_eq!(
            loaded.get::<ConvPoint>(&ckey),
            Some((cp, c_gf)),
            "case {case}: conv point diverged"
        );
        // Cross-space lookups stay clean: measured points never answer
        // modeled-space lookups.
        assert!(loaded.get::<GemmConfig>(&gkey).is_none(), "case {case}");
        assert!(loaded.get::<ConvConfig>(&ckey).is_none(), "case {case}");
        assert_eq!(loaded.len(), 2, "case {case}");
    }
}

/// Legacy `blocked` / `conv_native` DB fixtures load through the
/// migrate-on-lookup path and plan *identically* to what those entries
/// always meant: the stored blocking (scalar micro-kernel) for GEMM, the
/// stored algorithm + blocking for conv.
#[test]
fn prop_legacy_db_fixtures_plan_identically() {
    use portable_kernels::runtime::{ArtifactStore, NativeEngine};
    use portable_kernels::tuner::SelectionDb;
    use portable_kernels::util::tmp::TempDir;

    let mut rng = XorShift::new(9090);
    let dir = TempDir::new("prop-legacy").unwrap();
    std::fs::write(
        dir.path().join("manifest.json"),
        r#"{"version": 1, "artifacts": [
          {"name": "g24", "kind": "gemm", "impl": "pallas",
           "file": "g24.hlo.txt", "flops": 27648,
           "m": 24, "n": 24, "k": 24, "groups": ["gemm"],
           "inputs": [{"shape": [24, 24], "dtype": "float32"},
                      {"shape": [24, 24], "dtype": "float32"}]},
          {"name": "c8", "kind": "conv", "impl": "pallas",
           "file": "c8.hlo.txt", "flops": 36864, "batch": 1,
           "groups": ["conv"],
           "layer": {"name": "c8", "window": 3, "stride": 1,
                     "in_h": 8, "in_w": 8, "in_c": 2, "out_c": 4,
                     "out_h": 8, "out_w": 8, "padding": "SAME",
                     "flops": 36864},
           "inputs": [{"shape": [1, 8, 8, 2], "dtype": "float32"},
                      {"shape": [3, 3, 2, 4], "dtype": "float32"}]}
        ]}"#,
    )
    .unwrap();
    let store = ArtifactStore::open(dir.path()).unwrap();

    for case in 0..20 {
        // Random legal legacy entries, written as raw pre-unification
        // JSON (threads sometimes absent — the pre-threads schema).
        let (bm, bn, bk) = (
            rng.range(1, 64),
            rng.range(1, 64),
            rng.range(1, 64),
        );
        let (mr, nr) = (rng.range(1, 16), rng.range(1, 16));
        let threads = rng.range(0, 4);
        let with_threads = rng.below(2) == 0;
        let threads_field = if with_threads {
            format!(r#", "threads": {threads}"#)
        } else {
            String::new()
        };
        let algorithm =
            *rng.choose(&["im2col", "tiled", "winograd"]);
        let legacy = format!(
            r#"{{"host::gemm_64x64x64": {{"kind": "blocked",
                "gflops": 2.0,
                "config": {{"bm": {bm}, "bn": {bn}, "bk": {bk},
                            "mr": {mr}, "nr": {nr}{threads_field}}}}},
                "host::conv_3x3s1_8x8x2k4b1": {{"kind": "conv_native",
                "gflops": 3.0, "algorithm": "{algorithm}",
                "config": {{"tile_h": 2, "tile_w": 2, "vec_c": 1,
                            "vec_k": 4, "block_k": 0,
                            "algorithm": "{algorithm}", "wino_m": 2}},
                "blocked": {{"bm": {bm}, "bn": {bn}, "bk": {bk},
                             "mr": {mr}, "nr": {nr}{threads_field}}}}}}}"#,
        );
        let path = dir.path().join(format!("legacy{case}.json"));
        std::fs::write(&path, &legacy).unwrap();
        let db = SelectionDb::load(&path)
            .unwrap_or_else(|e| panic!("case {case}: {e}\n{legacy}"));
        let mut e = NativeEngine::with_tuning(store.clone(), db);

        let want = BlockedParams {
            bm: bm as usize,
            bn: bn as usize,
            bk: bk as usize,
            mr: mr as usize,
            nr: nr as usize,
            threads: if with_threads { threads as usize } else { 0 },
        };
        // GEMM: those params, scalar micro-kernel — exactly what the
        // blocked entry always meant.
        assert_eq!(e.planned_params("g24").unwrap(), want, "case {case}");
        let planned = e.planned_gemm("g24").unwrap().unwrap();
        assert_eq!(planned.isa, Isa::Scalar, "case {case}");
        // Pre-dtype entries carry no dtype field: they migrate as f32,
        // which is the arithmetic those entries were measured under.
        assert_eq!(planned.dtype, Dtype::F32, "case {case}");
        // Pre-pack entries carry no pack field: they migrate as
        // unpacked-B (pack: a), the kernels they were measured with.
        assert_eq!(planned.pack, Pack::A, "case {case}");
        // Conv: the stored algorithm + blocking (3x3/s1 is on every
        // algorithm's domain, so no fallback applies).
        let conv = e.planned_conv("c8").unwrap().unwrap();
        assert_eq!(conv.algorithm.as_str(), algorithm, "case {case}");
        assert_eq!(e.planned_params("c8").unwrap(), want, "case {case}");
        let cpoint = e.planned_conv_point("c8").unwrap().unwrap();
        assert_eq!(cpoint.dtype, Dtype::F32, "case {case}");
        assert_eq!(cpoint.pack, Pack::A, "case {case}");
    }
}

/// Every ISA-dispatched micro-kernel agrees with the scalar kernel on
/// ragged shapes: SSE2/AVX2 bitwise (0 ULP — same operation order,
/// wider lanes), FMA within the fused-rounding accumulation tolerance
/// (1e-6 per k-step).
#[test]
fn prop_isa_micro_kernels_agree_with_scalar() {
    let mut rng = XorShift::new(6464);
    let isas = Isa::detect();
    for case in 0..16 {
        let &(mr, nr) = rng.choose(MICRO_KERNEL_SHAPES);
        // Ragged everything: partial strips, short k-panels, plus
        // degenerate single-row/col shapes on some cases.
        let m = if case % 5 == 0 { 1 } else { rng.range(2, 80) as usize };
        let n = if case % 7 == 0 { 1 } else { rng.range(2, 80) as usize };
        let k = rng.range(1, 64) as usize;
        let params = BlockedParams {
            bm: rng.range(1, 48) as usize,
            bn: rng.range(1, 48) as usize,
            bk: rng.range(1, 48) as usize,
            mr,
            nr,
            threads: *rng.choose(&[1usize, 2]),
        };
        let a = rng.f32_vec(m * k);
        let b = rng.f32_vec(k * n);
        let scalar = gemm_blocked(&a, &b, m, n, k, &params);
        for &isa in &isas {
            let got = gemm_blocked_isa(&a, &b, m, n, k, &params, isa);
            if matches!(isa, Isa::Fma | Isa::Avx512) {
                let tol = 1e-6 * k as f32;
                assert!(
                    max_abs_diff(&scalar, &got) <= tol,
                    "case {case}: fma beyond {tol} at {m}x{n}x{k} {params:?}"
                );
            } else {
                assert!(
                    scalar == got,
                    "case {case}: {isa} not bit-identical at {m}x{n}x{k} \
                     {params:?}"
                );
            }
        }
    }
}

/// Reference widening GEMM: the plain i8×i8→i32 triple loop that every
/// int8 code path must reproduce bit for bit (integer accumulation is
/// exact, so the contract is equality, never a tolerance).
fn gemm_i8_naive(a: &[i8], b: &[i8], m: usize, n: usize, k: usize) -> Vec<i32> {
    let mut c = vec![0i32; m * n];
    for i in 0..m {
        for p in 0..k {
            let av = a[i * k + p] as i32;
            for j in 0..n {
                c[i * n + j] += av * b[p * n + j] as i32;
            }
        }
    }
    c
}

/// Uniform random i8 values over the full [-128, 127] range.
fn i8_vec(rng: &mut XorShift, n: usize) -> Vec<i8> {
    (0..n).map(|_| rng.below(256) as u8 as i8).collect()
}

/// The blocked int8 GEMM is bit-exact against the naive widening i32
/// oracle on ragged shapes — partial micro-tile strips, short k-panels,
/// degenerate single-row/col problems — for every registered
/// micro-kernel shape.
#[test]
fn prop_int8_gemm_bitexact_vs_widening_oracle() {
    let mut rng = XorShift::new(8181);
    for case in 0..24 {
        let &(mr, nr) = rng.choose(MICRO_KERNEL_SHAPES);
        let m = if case % 5 == 0 { 1 } else { rng.range(2, 80) as usize };
        let n = if case % 7 == 0 { 1 } else { rng.range(2, 80) as usize };
        let k = rng.range(1, 96) as usize;
        let params = BlockedParams {
            bm: rng.range(1, 48) as usize,
            bn: rng.range(1, 48) as usize,
            bk: rng.range(1, 48) as usize,
            mr,
            nr,
            threads: 1,
        };
        let a = i8_vec(&mut rng, m * k);
        let b = i8_vec(&mut rng, k * n);
        let want = gemm_i8_naive(&a, &b, m, n, k);
        let got = gemm_i8_blocked_isa(&a, &b, m, n, k, &params, Isa::Scalar);
        assert!(
            want == got,
            "case {case}: scalar int8 differs from the widening oracle \
             at {m}x{n}x{k} {params:?}"
        );
    }
}

/// Every detected ISA's int8 kernel is 0-ULP identical to the scalar
/// widening kernel.  Unlike f32 FMA (fused rounding), the AVX2 path's
/// `_mm256_madd_epi16` partials are exact i32 — products of i8 values
/// are ≤ 128², two per lane never saturate i32 — so lane width cannot
/// change a single bit.
#[test]
fn prop_int8_simd_vs_scalar_zero_ulp() {
    let mut rng = XorShift::new(8282);
    let isas = Isa::detect();
    for case in 0..16 {
        let &(mr, nr) = rng.choose(MICRO_KERNEL_SHAPES);
        let m = rng.range(1, 96) as usize;
        let n = rng.range(1, 96) as usize;
        let k = rng.range(1, 128) as usize;
        let params = BlockedParams {
            bm: rng.range(1, 48) as usize,
            bn: rng.range(1, 48) as usize,
            bk: rng.range(1, 48) as usize,
            mr,
            nr,
            threads: 1,
        };
        let a = i8_vec(&mut rng, m * k);
        let b = i8_vec(&mut rng, k * n);
        let scalar =
            gemm_i8_blocked_isa(&a, &b, m, n, k, &params, Isa::Scalar);
        for &isa in &isas {
            let got = gemm_i8_blocked_isa(&a, &b, m, n, k, &params, isa);
            assert!(
                scalar == got,
                "case {case}: {isa} int8 not bit-identical at {m}x{n}x{k} \
                 {params:?}"
            );
        }
    }
}

/// Band-parallel int8 GEMM is bit-identical to serial for any thread
/// count: each worker owns a disjoint row-band of the output, and the
/// per-band integer accumulation never depends on scheduling order.
#[test]
fn prop_int8_threaded_bit_identical_to_serial() {
    let mut rng = XorShift::new(8383);
    for case in 0..12 {
        let &(mr, nr) = rng.choose(MICRO_KERNEL_SHAPES);
        let m = rng.range(8, 160) as usize;
        let n = rng.range(1, 96) as usize;
        let k = rng.range(1, 64) as usize;
        // Small bm forces several row bands so the parallel path
        // actually engages.
        let mut params = BlockedParams {
            bm: rng.range(1, 24) as usize,
            bn: rng.range(1, 48) as usize,
            bk: rng.range(1, 48) as usize,
            mr,
            nr,
            threads: 1,
        };
        let a = i8_vec(&mut rng, m * k);
        let b = i8_vec(&mut rng, k * n);
        let serial =
            gemm_i8_blocked_isa(&a, &b, m, n, k, &params, Isa::Scalar);
        for &threads in &[2usize, 3, 4, 8] {
            params.threads = threads;
            let par =
                gemm_i8_blocked_isa(&a, &b, m, n, k, &params, Isa::Scalar);
            assert!(
                serial == par,
                "case {case}: {threads} threads not bit-identical at \
                 {m}x{n}x{k} {params:?}"
            );
        }
    }
}

/// The quantize → int8 GEMM → dequantize round trip tracks the f32
/// oracle within the analytic bound.  Inputs live in [-0.5, 0.5), so a
/// per-element quantization error of at most scale/2 propagates through
/// each of the k products as
/// `|a||Δb| + |b̂||Δa| ≤ 0.25·sb + (0.5 + sb/2)·sa/2`, and
/// `k·(0.25·sa + 0.25·sb + sa·sb)` covers the sum with margin; the 1e-5
/// constant absorbs f32 rounding in the epilogue and the oracle itself.
#[test]
fn prop_int8_quantize_dequantize_error_bound() {
    let mut rng = XorShift::new(8484);
    for case in 0..12 {
        let &(mr, nr) = rng.choose(MICRO_KERNEL_SHAPES);
        let m = rng.range(1, 48) as usize;
        let n = rng.range(1, 48) as usize;
        let k = rng.range(1, 64) as usize;
        let params = BlockedParams {
            bm: rng.range(1, 32) as usize,
            bn: rng.range(1, 32) as usize,
            bk: rng.range(1, 32) as usize,
            mr,
            nr,
            threads: 1,
        };
        let a = rng.f32_vec(m * k);
        let b = rng.f32_vec(k * n);
        let qa = QuantParams::for_data(&a);
        let qb = QuantParams::for_data(&b);
        let aq = quantize_slice(&a, &qa);
        let bq = quantize_slice(&b, &qb);
        let got =
            gemm_i8_dequant(&aq, &bq, m, n, k, &qa, &qb, &params, Isa::Scalar);
        let oracle = gemm_naive(&a, &b, m, n, k);
        let bound = k as f32
            * (0.25 * qa.scale + 0.25 * qb.scale + qa.scale * qb.scale)
            + 1e-5;
        for (i, (g, o)) in got.iter().zip(&oracle).enumerate() {
            let diff = (g - o).abs();
            assert!(
                diff <= bound,
                "case {case}: element {i}: {g} vs {o} (|diff| {diff} > \
                 {bound}) at {m}x{n}x{k} sa={} sb={}",
                qa.scale, qb.scale
            );
        }
    }
}

/// Unified-schema DB entries written before the dtype axis existed
/// (no "dtype" field on the stored point) decode as f32 and plan
/// *identically* to a twin DB that spells `"dtype": "f32"` explicitly —
/// the migration contract for the precision axis.
#[test]
fn prop_unified_db_dtype_absent_migrates_to_f32() {
    use portable_kernels::runtime::{ArtifactStore, NativeEngine};
    use portable_kernels::tuner::SelectionDb;
    use portable_kernels::util::tmp::TempDir;

    let mut rng = XorShift::new(9191);
    let dir = TempDir::new("prop-dtype-migrate").unwrap();
    std::fs::write(
        dir.path().join("manifest.json"),
        r#"{"version": 1, "artifacts": [
          {"name": "g24", "kind": "gemm", "impl": "pallas",
           "file": "g24.hlo.txt", "flops": 27648,
           "m": 24, "n": 24, "k": 24, "groups": ["gemm"],
           "inputs": [{"shape": [24, 24], "dtype": "float32"},
                      {"shape": [24, 24], "dtype": "float32"}]},
          {"name": "c8", "kind": "conv", "impl": "pallas",
           "file": "c8.hlo.txt", "flops": 36864, "batch": 1,
           "groups": ["conv"],
           "layer": {"name": "c8", "window": 3, "stride": 1,
                     "in_h": 8, "in_w": 8, "in_c": 2, "out_c": 4,
                     "out_h": 8, "out_w": 8, "padding": "SAME",
                     "flops": 36864},
           "inputs": [{"shape": [1, 8, 8, 2], "dtype": "float32"},
                      {"shape": [3, 3, 2, 4], "dtype": "float32"}]}
        ]}"#,
    )
    .unwrap();
    let store = ArtifactStore::open(dir.path()).unwrap();

    for case in 0..12 {
        let (bm, bn, bk) =
            (rng.range(1, 64), rng.range(1, 64), rng.range(1, 64));
        let (mr, nr) = (rng.range(1, 16), rng.range(1, 16));
        let blocked = format!(
            r#""bm": {bm}, "bn": {bn}, "bk": {bk},
               "mr": {mr}, "nr": {nr}, "threads": 1"#
        );
        let conv_cfg = r#"{"tile_h": 2, "tile_w": 2, "vec_c": 1,
            "vec_k": 4, "block_k": 0, "algorithm": "im2col",
            "wino_m": 2}"#;
        let make_db = |dtype_field: &str, tag: &str| {
            let text = format!(
                r#"{{"host::gemm_64x64x64": {{"kind": "gemm_point",
                    "gflops": 2.0, "name": "x",
                    "point": {{{blocked}, "isa": "scalar"{dtype_field}}}}},
                    "host::conv_3x3s1_8x8x2k4b1": {{"kind": "conv_point",
                    "gflops": 3.0, "name": "y",
                    "point": {{"config": {conv_cfg},
                               "blocked": {{{blocked}}},
                               "isa": "scalar"{dtype_field}}}}}}}"#
            );
            let path = dir.path().join(format!("db-{tag}{case}.json"));
            std::fs::write(&path, &text).unwrap();
            SelectionDb::load(&path)
                .unwrap_or_else(|e| panic!("case {case} {tag}: {e}\n{text}"))
        };
        let mut bare = NativeEngine::with_tuning(
            store.clone(),
            make_db("", "bare"),
        );
        let mut explicit = NativeEngine::with_tuning(
            store.clone(),
            make_db(r#", "dtype": "f32""#, "explicit"),
        );

        let gp_bare = bare.planned_gemm("g24").unwrap().unwrap();
        let gp_explicit = explicit.planned_gemm("g24").unwrap().unwrap();
        assert_eq!(gp_bare.dtype, Dtype::F32, "case {case}");
        assert_eq!(gp_bare, gp_explicit, "case {case}");

        let cp_bare = bare.planned_conv_point("c8").unwrap().unwrap();
        let cp_explicit =
            explicit.planned_conv_point("c8").unwrap().unwrap();
        assert_eq!(cp_bare.dtype, Dtype::F32, "case {case}");
        assert_eq!(cp_bare, cp_explicit, "case {case}");
    }
}

/// conv register model: monotone in every parameter.
#[test]
fn prop_conv_regs_monotone() {
    let mut rng = XorShift::new(606);
    for _ in 0..CASES {
        let th = rng.range(1, 7) as u32;
        let tw = rng.range(1, 7) as u32;
        let vc = *rng.choose(&[1u32, 2, 4]);
        let vk = *rng.choose(&[1u32, 2, 4]);
        let w = *rng.choose(&[1u32, 3, 5, 7]);
        let base = conv_regs(&ConvConfig::tiled(th, tw, vc, vk), w);
        assert!(conv_regs(&ConvConfig::tiled(th + 1, tw, vc, vk), w) > base);
        assert!(conv_regs(&ConvConfig::tiled(th, tw + 1, vc, vk), w) > base);
        assert!(conv_regs(&ConvConfig::tiled(th, tw, vc * 2, vk), w) > base);
        assert!(conv_regs(&ConvConfig::tiled(th, tw, vc, vk * 2), w) > base);
    }
}

/// Conv model: increasing the tile never increases modeled *traffic*
/// (the §4.1.1 reuse argument), for stride-1 windows.
#[test]
fn prop_conv_tile_reduces_traffic() {
    let mut rng = XorShift::new(707);
    for case in 0..30 {
        let dev = random_device(&mut rng);
        let c = *rng.choose(&[16u32, 64, 128]);
        let k = *rng.choose(&[16u32, 64]);
        let hw = *rng.choose(&[14u32, 28, 56]);
        let layer = ConvLayer::same("p", 3, 1, hw, hw, c, k);
        let p = ConvProblem::new(layer, 1);
        let small = conv_estimate(&dev, &p, &ConvConfig::tiled(1, 1, 1, 1),
                                  &GemmConfig::default()).unwrap();
        let large = conv_estimate(&dev, &p, &ConvConfig::tiled(4, 4, 1, 1),
                                  &GemmConfig::default()).unwrap();
        assert!(
            large.global_bytes <= small.global_bytes,
            "case {case} on {}: {} > {}",
            dev.id, large.global_bytes, small.global_bytes
        );
    }
}

/// JSON round-trip for arbitrary machine-generated values.
#[test]
fn prop_json_roundtrip() {
    fn random_value(rng: &mut XorShift, depth: u32) -> json::Value {
        match if depth == 0 { rng.below(5) } else { rng.below(7) } {
            0 => json::Value::Null,
            1 => json::Value::Bool(rng.below(2) == 0),
            2 => json::Value::Int(rng.next_u64() as i64 >> rng.below(40)),
            3 => json::Value::Float(
                (rng.next_u64() as f64 / 1e12).floor() / 1024.0,
            ),
            4 => {
                let n = rng.below(12) as usize;
                json::Value::Str(
                    (0..n)
                        .map(|_| {
                            *rng.choose(&[
                                'a', 'b', '"', '\\', '\n', 'é', '😀', ' ',
                            ])
                        })
                        .collect(),
                )
            }
            5 => json::Value::Array(
                (0..rng.below(5)).map(|_| random_value(rng, depth - 1)).collect(),
            ),
            _ => {
                let mut o = json::Value::object();
                for i in 0..rng.below(5) {
                    o.set(&format!("k{i}"), random_value(rng, depth - 1));
                }
                o
            }
        }
    }
    let mut rng = XorShift::new(808);
    for case in 0..200 {
        let v = random_value(&mut rng, 3);
        let text = v.to_json();
        let parsed = json::parse(&text)
            .unwrap_or_else(|e| panic!("case {case}: {text} -> {e}"));
        assert_eq!(parsed, v, "case {case}: {text}");
        // Pretty round-trips too.
        assert_eq!(json::parse(&v.to_json_pretty()).unwrap(), v);
    }
}

/// Batcher invariants under random workloads: every request is delivered
/// exactly once, groups are homogeneous, relative order per artifact is
/// preserved, group sizes respect the cap.
#[test]
fn prop_batcher_invariants() {
    let mut rng = XorShift::new(909);
    for case in 0..40 {
        let max_batch = rng.range(1, 6) as usize;
        let mut b: Batcher<u64> = Batcher::new(BatchPolicy {
            max_batch,
            max_delay: std::time::Duration::from_secs(3600),
        });
        let n = rng.range(0, 60);
        let arts = ["x", "y", "z"];
        let mut expected_per_art: std::collections::HashMap<&str, Vec<u64>> =
            Default::default();
        for i in 0..n {
            let art = *rng.choose(&arts);
            b.push(art, i);
            expected_per_art.entry(art).or_default().push(i);
        }
        let mut seen_per_art: std::collections::HashMap<String, Vec<u64>> =
            Default::default();
        let mut total = 0usize;
        while let Some((art, group)) = b.pop_group() {
            assert!(!group.is_empty() && group.len() <= max_batch,
                    "case {case}");
            total += group.len();
            seen_per_art.entry(art).or_default().extend(group);
        }
        assert_eq!(total, n as usize, "case {case}");
        for (art, expected) in expected_per_art {
            assert_eq!(
                seen_per_art.get(art).map(|v| v.as_slice()).unwrap_or(&[]),
                expected.as_slice(),
                "case {case}: order broken for {art}"
            );
        }
    }
}

/// Epoch-swap consistency: concurrent readers of a [`TuningHandle`]
/// never observe a torn snapshot (the epoch and the DB travel together)
/// and never see time run backwards.  Each published DB carries a marker
/// entry whose stored gflops equals its epoch, so a mismatch between a
/// snapshot's epoch and its content is directly detectable.
///
/// [`TuningHandle`]: portable_kernels::tuner::TuningHandle
#[test]
fn prop_epoch_swap_readers_never_torn() {
    use portable_kernels::runtime::HOST_DEVICE;
    use portable_kernels::tuner::{SelectionDb, SelectionKey, TuningHandle};
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;

    let key = SelectionKey::gemm(HOST_DEVICE, 64, 64, 64);
    let marker = |epoch: u64| {
        let mut db = SelectionDb::new();
        db.put(
            key.clone(),
            GemmPoint::scalar(BlockedParams::default()),
            epoch as f64,
        );
        db
    };
    let handle = Arc::new(TuningHandle::new(marker(0)));
    let done = Arc::new(AtomicBool::new(false));

    std::thread::scope(|s| {
        let readers: Vec<_> = (0..4)
            .map(|_| {
                let handle = Arc::clone(&handle);
                let done = Arc::clone(&done);
                let key = key.clone();
                s.spawn(move || {
                    let mut last = 0u64;
                    let mut observed = 0usize;
                    loop {
                        let finishing = done.load(Ordering::Acquire);
                        let snap = handle.snapshot();
                        assert!(
                            snap.epoch >= last,
                            "epoch went backwards: {last} -> {}",
                            snap.epoch
                        );
                        last = snap.epoch;
                        let (_, gflops) = snap
                            .db
                            .get::<GemmPoint>(&key)
                            .expect("marker entry exists in every epoch");
                        assert_eq!(
                            gflops, snap.epoch as f64,
                            "torn snapshot: epoch {} carries the DB \
                             published at epoch {gflops}",
                            snap.epoch
                        );
                        observed += 1;
                        if finishing {
                            return observed;
                        }
                    }
                })
            })
            .collect();

        for epoch in 1..=50u64 {
            let published = handle.publish(marker(epoch));
            assert_eq!(published.epoch, epoch);
        }
        done.store(true, Ordering::Release);
        for r in readers {
            assert!(r.join().unwrap() > 0, "reader observed nothing");
        }
    });
}

/// Manifest with a single 96^3 GEMM artifact, shared by the fabricated
/// cost-model sweep tests.
const G96_MANIFEST: &str = r#"{"version": 1, "artifacts": [{
    "name": "g96", "kind": "gemm", "impl": "pallas",
    "file": "g96.hlo.txt", "flops": 1769472,
    "m": 96, "n": 96, "k": 96,
    "inputs": [{"shape": [96, 96], "dtype": "float32"},
               {"shape": [96, 96], "dtype": "float32"}],
    "groups": ["gemm"]}]}"#;

/// Fabricated-cost backend for the cost-model-driven tuning tests:
/// every run takes exactly `cost_ns(current point)` of "device time",
/// so search and promotion protocols can be driven through orderings
/// chosen by the test instead of wall-clock noise.
struct CostModelBackend {
    store: portable_kernels::runtime::ArtifactStore,
    point: GemmPoint,
    cost_ns: Box<dyn Fn(&GemmPoint) -> u64>,
}

impl portable_kernels::runtime::Backend for CostModelBackend {
    fn platform(&self) -> String {
        "cost-model".into()
    }

    fn store(&self) -> &portable_kernels::runtime::ArtifactStore {
        &self.store
    }

    fn warm(&mut self, _name: &str) -> portable_kernels::Result<()> {
        Ok(())
    }

    fn cached(&self) -> usize {
        0
    }

    fn run(
        &mut self,
        _name: &str,
        _inputs: &[Vec<f32>],
    ) -> portable_kernels::Result<portable_kernels::runtime::RunOutput> {
        Ok(portable_kernels::runtime::RunOutput {
            outputs: vec![vec![0.0]],
            elapsed: std::time::Duration::from_nanos((self.cost_ns)(
                &self.point,
            )),
        })
    }
}

/// Deterministic pseudo-cost jitter per point (FNV over the debug form),
/// so grid points get distinct but reproducible costs.
fn point_jitter(p: &GemmPoint, salt: u64, spread: u64) -> u64 {
    let mut h = salt ^ 0xcbf2_9ce4_8422_2325;
    for b in format!("{p:?}").bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0100_0000_01b3);
    }
    h % spread.max(1)
}

/// The online-promotion invariant, driven by a fabricated cost model:
/// a re-tune pass never installs a point that measured worse than the
/// incumbent in its verification probe.  Both directions are exercised
/// per random case: a genuinely slow incumbent is replaced (and every
/// promotion records candidate > incumbent), while a fast incumbent
/// whose *stored number* lies low — the situation serving drift creates
/// — survives: the sweep nominates a challenger, the head-to-head
/// verification rejects it, and the published DB is left untouched.
#[test]
fn prop_retune_never_promotes_worse_measured() {
    use portable_kernels::runtime::{ArtifactStore, HOST_DEVICE};
    use portable_kernels::tuner::{
        retune_pass, RetuneConfig, SelectionDb, SelectionKey, TuningHandle,
    };
    use portable_kernels::util::tmp::TempDir;
    use std::sync::Arc;

    let dir = TempDir::new("prop-retune").unwrap();
    std::fs::write(dir.path().join("manifest.json"), G96_MANIFEST).unwrap();
    let store = ArtifactStore::open(dir.path()).unwrap();
    let key = SelectionKey::gemm(HOST_DEVICE, 96, 96, 96);
    let hot = vec![key.op.clone()];
    let cfg = RetuneConfig { iters: 2, ..Default::default() };

    let mut rng = XorShift::new(1111);
    for case in 0..6 {
        let base = rng.range(100_000, 1_000_000);
        let spread = (base / 10).max(1);
        // threads: 8 keeps the incumbent out of the probe grid (the
        // config's threads axis is [1, 0]), so verification always has
        // a real head-to-head to run.
        let incumbent = GemmPoint::scalar(BlockedParams {
            bm: 8,
            bn: 8,
            bk: 8,
            mr: 2,
            nr: 2,
            threads: 8,
        });

        // Direction 1: the incumbent truly measures slow; some grid
        // point must win its probe and be promoted.
        let slow = base * rng.range(20, 100);
        let mut seed = SelectionDb::new();
        seed.put(key.clone(), incumbent, 0.01);
        let handle = TuningHandle::new(seed);
        let salt = rng.next_u64();
        let mut engine = CostModelBackend {
            store: store.clone(),
            point: GemmPoint::scalar(BlockedParams::default()),
            cost_ns: Box::new(move |p| {
                if *p == incumbent {
                    slow
                } else {
                    base + point_jitter(p, salt, spread)
                }
            }),
        };
        let pass = retune_pass(
            &mut engine,
            &handle,
            &hot,
            &cfg,
            &mut |e, p| e.point = *p,
            &mut |_, _| {},
        )
        .unwrap();
        assert_eq!(pass.probed, 1, "case {case}: g96 probed: {pass:?}");
        assert!(
            !pass.promoted.is_empty(),
            "case {case}: a slow incumbent must lose: {pass:?}"
        );
        for p in &pass.promoted {
            assert!(
                p.candidate_gflops.is_finite()
                    && p.candidate_gflops > p.incumbent_gflops,
                "case {case}: never-worse violated: {p:?}"
            );
        }
        assert_eq!(pass.epoch, Some(1), "case {case}");
        let (installed, gflops) = handle
            .snapshot()
            .db
            .get::<GemmPoint>(&key)
            .expect("promoted entry");
        assert_ne!(installed, incumbent, "case {case}");
        assert!(gflops.is_finite() && gflops > 0.0, "case {case}");

        // Direction 2: the incumbent truly measures *fast*, but its
        // stored number lies low, so the sweep nominates a challenger.
        // The verification probe must reject it and leave the
        // published DB untouched.
        let fast = (base / rng.range(3, 10)).max(1);
        let mut seed = SelectionDb::new();
        seed.put(key.clone(), incumbent, 0.0001);
        let handle = TuningHandle::new(seed);
        let before = handle.snapshot();
        let salt = rng.next_u64();
        let mut engine = CostModelBackend {
            store: store.clone(),
            point: GemmPoint::scalar(BlockedParams::default()),
            cost_ns: Box::new(move |p| {
                if *p == incumbent {
                    fast
                } else {
                    base + point_jitter(p, salt, spread)
                }
            }),
        };
        let pass = retune_pass(
            &mut engine,
            &handle,
            &hot,
            &cfg,
            &mut |e, p| e.point = *p,
            &mut |_, _| {},
        )
        .unwrap();
        assert!(
            pass.promoted.is_empty(),
            "case {case}: a faster-measuring incumbent must survive: \
             {pass:?}"
        );
        assert!(pass.rejected >= 1, "case {case}: {pass:?}");
        assert_eq!(pass.epoch, None, "case {case}");
        let after = handle.snapshot();
        assert_eq!(after.epoch, 0, "case {case}: nothing published");
        assert!(
            Arc::ptr_eq(&before.db, &after.db),
            "case {case}: rejected pass must not touch the published DB"
        );
        let (kept, kept_gflops) =
            after.db.get::<GemmPoint>(&key).expect("incumbent kept");
        assert_eq!(kept, incumbent, "case {case}");
        assert_eq!(kept_gflops, 0.0001, "case {case}");
    }
}

/// Guided search under a *truthful* cost model: fabricate run costs as
/// a monotone function of the very `rank_hint` the guided ranking
/// consults, so the model's top-ranked candidate really is the fastest
/// point.  The guided sweep must then find a winner measuring at least
/// as fast as the exhaustive sweep's — while measuring only its budget,
/// not the whole grid.
#[test]
fn prop_guided_sweep_matches_exhaustive_under_truthful_model() {
    use portable_kernels::config::{KernelSpace, Problem};
    use portable_kernels::runtime::{ArtifactStore, HOST_DEVICE};
    use portable_kernels::tuner::{
        gemm_point_grid, tune_space_sweep, ExhaustiveSearch, GuidedSearch,
        SearchStrategy, SelectionDb,
    };
    use portable_kernels::util::tmp::TempDir;

    fn truthful_cost(p: &GemmPoint) -> u64 {
        match p.rank_hint(&Problem::Gemm { m: 96, n: 96, k: 96 }) {
            Some(r) if r.is_finite() => (r * 1_000_000.0) as u64 + 1,
            _ => 10_000_000_000,
        }
    }

    let dir = TempDir::new("prop-guided-truthful").unwrap();
    std::fs::write(dir.path().join("manifest.json"), G96_MANIFEST).unwrap();
    let store = ArtifactStore::open(dir.path()).unwrap();
    let grid = gemm_point_grid(true, &[1, 2], &Isa::detect());
    let op = "gemm_96x96x96";

    let run = |strategy: &dyn SearchStrategy| {
        let mut db = SelectionDb::new();
        let mut engine = CostModelBackend {
            store: store.clone(),
            point: GemmPoint::default(),
            cost_ns: Box::new(truthful_cost),
        };
        let sweep = tune_space_sweep(
            &mut engine,
            "gemm",
            &grid,
            2,
            HOST_DEVICE,
            strategy,
            &mut |e: &mut CostModelBackend, p: &GemmPoint| e.point = *p,
            &mut db,
        )
        .unwrap();
        let (_, gf) = sweep.winners[op];
        (gf, sweep.points_measured_for(op))
    };

    let (ex_gf, ex_points) = run(&ExhaustiveSearch);
    // The pinned default may sit outside the grid (its threads value
    // need not be on the sampled axis), hence >= rather than ==.
    assert!(ex_points >= grid.len(), "{ex_points} < {}", grid.len());

    let mut rng = XorShift::new(2468);
    for case in 0..6 {
        let budget = rng.range(2, 8) as usize;
        let (gf, points) = run(&GuidedSearch { budget });
        assert!(
            points <= budget,
            "case {case}: guided measured {points} > budget {budget}"
        );
        assert!(points < ex_points, "case {case}: no pruning happened");
        assert!(
            gf + 1e-9 >= ex_gf,
            "case {case}: truthful-model guided winner {gf} GF/s lost \
             to exhaustive {ex_gf} GF/s"
        );
    }
}

/// Guided search under a *lying* cost model: run costs are uncorrelated
/// with the rank hints, and the model's favorite candidate is made the
/// slowest point of all.  The default point is pinned into every
/// strategy's proposals, so even a maximally wrong model degrades the
/// guided sweep to the measured default — never below it.
#[test]
fn prop_guided_sweep_with_lying_model_never_loses_to_the_default() {
    use portable_kernels::config::{KernelSpace, Problem};
    use portable_kernels::runtime::{ArtifactStore, HOST_DEVICE};
    use portable_kernels::tuner::{
        gemm_point_grid, tune_space_sweep, GuidedSearch, SelectionDb,
    };
    use portable_kernels::util::tmp::TempDir;

    let dir = TempDir::new("prop-guided-lying").unwrap();
    std::fs::write(dir.path().join("manifest.json"), G96_MANIFEST).unwrap();
    let store = ArtifactStore::open(dir.path()).unwrap();
    let grid = gemm_point_grid(true, &[1, 2], &Isa::detect());
    let op = "gemm_96x96x96";
    let problem = Problem::Gemm { m: 96, n: 96, k: 96 };
    // The model's favorite: the grid point it ranks fastest.
    let favorite = grid
        .iter()
        .copied()
        .min_by(|a, b| {
            a.rank_hint(&problem)
                .unwrap_or(f64::INFINITY)
                .partial_cmp(&b.rank_hint(&problem).unwrap_or(f64::INFINITY))
                .unwrap()
        })
        .unwrap();
    let default = GemmPoint::default();

    let mut rng = XorShift::new(1357);
    for case in 0..6 {
        let budget = rng.range(1, 8) as usize;
        let salt = rng.next_u64();
        let cost = move |p: &GemmPoint| -> u64 {
            if *p == favorite && favorite != default {
                // The model lies: its favorite is in truth the slowest.
                100_000_000
            } else {
                1_000_000 + point_jitter(p, salt, 900_000)
            }
        };
        let mut db = SelectionDb::new();
        let mut engine = CostModelBackend {
            store: store.clone(),
            point: default,
            cost_ns: Box::new(cost),
        };
        let sweep = tune_space_sweep(
            &mut engine,
            "gemm",
            &grid,
            2,
            HOST_DEVICE,
            &GuidedSearch { budget },
            &mut |e: &mut CostModelBackend, p: &GemmPoint| e.point = *p,
            &mut db,
        )
        .unwrap();
        let (_, gf) = sweep.winners[op];
        let default_gf = sweep
            .gflops_for(op, &default)
            .expect("the pinned default is always measured");
        assert!(
            gf + 1e-9 >= default_gf,
            "case {case}: lying-model winner {gf} GF/s measured below \
             the default {default_gf} GF/s"
        );
        assert!(
            sweep.points_measured_for(op) <= budget.max(1),
            "case {case}: budget overrun"
        );
    }
}

/// LayerSpec shape arithmetic: SAME output size matches the ceil-div
/// definition for arbitrary layer shapes, and im2col GEMM dims are
/// consistent with output size.
#[test]
fn prop_layer_shapes_consistent() {
    let mut rng = XorShift::new(1010);
    for _ in 0..CASES {
        let layer = ConvLayer::same(
            "p",
            *rng.choose(&[1u32, 3, 5, 7]),
            *rng.choose(&[1u32, 2]),
            rng.range(4, 256) as u32,
            rng.range(4, 256) as u32,
            rng.range(1, 512) as u32,
            rng.range(1, 512) as u32,
        );
        assert_eq!(layer.out_h(), layer.in_h.div_ceil(layer.stride));
        assert_eq!(layer.out_w(), layer.in_w.div_ceil(layer.stride));
        let (m, n, k) = layer.im2col_gemm(3);
        assert_eq!(m, 3 * layer.out_h() as u64 * layer.out_w() as u64);
        assert_eq!(n, layer.out_c as u64);
        assert_eq!(k, (layer.window as u64).pow(2) * layer.in_c as u64);
        // flops consistency: 2*M*N*K == direct conv flops.
        assert_eq!(2 * m * n * k, layer.flops(3));
    }
}

/// Packed-B GEMM is BIT-identical (0 ULP, not a tolerance) to the
/// unpacked path on ragged and degenerate shapes, for every detected
/// ISA, serial and threaded.  The packed micro-kernels read the same
/// `k`-major element sequence from the `nr`-interleaved panel that the
/// unpacked kernels read from the strided B, so the accumulation order —
/// and therefore every rounding decision — is unchanged; packing is a
/// layout transform, never an arithmetic one.
#[test]
fn prop_packed_b_gemm_bit_identical_to_unpacked() {
    let mut rng = XorShift::new(9191);
    let isas = Isa::detect();
    for case in 0..16 {
        let &(mr, nr) = rng.choose(MICRO_KERNEL_SHAPES);
        let m = if case % 5 == 0 { 1 } else { rng.range(2, 96) as usize };
        let n = if case % 7 == 0 { 1 } else { rng.range(2, 96) as usize };
        let k = if case % 3 == 0 { 1 } else { rng.range(2, 80) as usize };
        let params = BlockedParams {
            bm: rng.range(1, 48) as usize,
            bn: rng.range(1, 48) as usize,
            bk: rng.range(1, 48) as usize,
            mr,
            nr,
            threads: 1,
        };
        let a = rng.f32_vec(m * k);
        let b = rng.f32_vec(k * n);
        for &isa in &isas {
            let unpacked = gemm_blocked_isa(&a, &b, m, n, k, &params, isa);
            for threads in [1usize, 2, 8] {
                let p = BlockedParams { threads, ..params };
                let scratch = Scratch::new();
                scratch.prewarm(&gemm_workspace(m, n, k, &p, Pack::Ab));
                let packed = gemm_blocked_ex(
                    &a, &b, m, n, k, &p, isa, Pack::Ab, &scratch,
                );
                assert!(
                    unpacked == packed,
                    "case {case}: pack ab diverged from pack a at \
                     {m}x{n}x{k} {isa} threads={threads} {params:?} \
                     (max diff {})",
                    max_abs_diff(&unpacked, &packed)
                );
            }
        }
    }
}

/// Packed-B int8 GEMM (through the dequantizing entry point) is exactly
/// equal to the unpacked path — integer accumulation is exact and the
/// f32 epilogue is elementwise in a fixed order, so the contract is
/// equality, never a tolerance — serial and threaded, per detected ISA.
#[test]
fn prop_packed_b_int8_gemm_exact_vs_unpacked() {
    let mut rng = XorShift::new(9292);
    let isas = Isa::detect();
    for case in 0..12 {
        let &(mr, nr) = rng.choose(MICRO_KERNEL_SHAPES);
        let m = if case % 5 == 0 { 1 } else { rng.range(2, 96) as usize };
        let n = if case % 7 == 0 { 1 } else { rng.range(2, 96) as usize };
        let k = rng.range(1, 96) as usize;
        let params = BlockedParams {
            bm: rng.range(1, 48) as usize,
            bn: rng.range(1, 48) as usize,
            bk: rng.range(1, 48) as usize,
            mr,
            nr,
            threads: *rng.choose(&[1usize, 2, 8]),
        };
        let a = i8_vec(&mut rng, m * k);
        let b = i8_vec(&mut rng, k * n);
        let qa = QuantParams { scale: 1.0 / 64.0, zero_point: 3 };
        let qb = QuantParams { scale: 1.0 / 32.0, zero_point: -5 };
        for &isa in &isas {
            let unpacked = gemm_i8_dequant(
                &a, &b, m, n, k, &qa, &qb, &params, isa,
            );
            let scratch = Scratch::new();
            let packed = gemm_i8_dequant_ex(
                &a, &b, m, n, k, &qa, &qb, &params, isa, Pack::Ab, &scratch,
            );
            assert!(
                unpacked == packed,
                "case {case}: i8 pack ab diverged from pack a at \
                 {m}x{n}x{k} {isa} {params:?}"
            );
        }
    }
}

/// Arena-reuse hygiene: ONE `Scratch` shared across many calls with
/// different shapes, packs and dtypes still produces bit-identical
/// results every time — `take_*` re-zeroes recycled buffers and sizing
/// is per-checkout, so a panel or accumulator left over from a larger
/// problem can never leak stale values into a smaller one.  Also pins
/// the steady-state invariant the serving arena relies on: replaying an
/// already-seen shape performs zero growth allocations.
#[test]
fn prop_scratch_reuse_across_shapes_stays_exact() {
    let mut rng = XorShift::new(9393);
    let scratch = Scratch::new();
    let mut shapes: Vec<(usize, usize, usize, BlockedParams)> = Vec::new();
    for case in 0..24 {
        // Descending-then-ascending sizes maximize recycled-buffer
        // mismatch: small checkouts right after large ones and back.
        let (m, n, k, params) = if case >= 12 {
            shapes[23 - case].clone()
        } else {
            let s = (
                rng.range(1, 96) as usize,
                rng.range(1, 96) as usize,
                rng.range(1, 80) as usize,
                BlockedParams {
                    bm: rng.range(1, 48) as usize,
                    bn: rng.range(1, 48) as usize,
                    bk: rng.range(1, 48) as usize,
                    mr: rng.range(1, 8) as usize,
                    nr: rng.range(1, 16) as usize,
                    threads: *rng.choose(&[1usize, 2]),
                },
            );
            shapes.push(s.clone());
            s
        };
        let a = rng.f32_vec(m * k);
        let b = rng.f32_vec(k * n);
        let pack = *rng.choose(&Pack::all());
        let want = gemm_blocked_isa(&a, &b, m, n, k, &params, Isa::Scalar);
        let got = gemm_blocked_ex(
            &a, &b, m, n, k, &params, Isa::Scalar, pack, &scratch,
        );
        assert!(
            want == got,
            "case {case}: shared-arena result diverged at {m}x{n}x{k} \
             pack {pack} {params:?}"
        );
        let aq = i8_vec(&mut rng, m * k);
        let bq = i8_vec(&mut rng, k * n);
        let q = QuantParams { scale: 1.0 / 128.0, zero_point: 1 };
        let wi = gemm_i8_dequant(
            &aq, &bq, m, n, k, &q, &q, &params, Isa::Scalar,
        );
        let gi = gemm_i8_dequant_ex(
            &aq, &bq, m, n, k, &q, &q, &params, Isa::Scalar, pack, &scratch,
        );
        assert!(
            wi == gi,
            "case {case}: shared-arena i8 result diverged at {m}x{n}x{k} \
             pack {pack} {params:?}"
        );
    }
    // Steady state: prewarm a fresh arena with every shape's declared
    // worst-case workspace, then replay the whole zoo — growth past the
    // prewarm baseline would mean a `*_workspace` function under-counts
    // its kernel's take-set (the invariant serving relies on, since
    // prewarm allocations are the warmup the serve-smoke baseline
    // subtracts out).
    let replay = Scratch::new();
    for &(m, n, k, ref params) in &shapes {
        replay.prewarm(&gemm_workspace(m, n, k, params, Pack::Ab));
    }
    let warmed_grows = replay.stats().grows;
    let mut rng2 = XorShift::new(9494);
    for &(m, n, k, ref params) in &shapes {
        let a = rng2.f32_vec(m * k);
        let b = rng2.f32_vec(k * n);
        let _ = gemm_blocked_ex(
            &a, &b, m, n, k, params, Isa::Scalar, Pack::Ab, &replay,
        );
        assert_eq!(
            replay.stats().grows,
            warmed_grows,
            "prewarmed arena grew during a replayed {m}x{n}x{k} call — \
             gemm_workspace must cover the hot path's take-set"
        );
    }
}
