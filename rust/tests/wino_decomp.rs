//! Decomposition regression for the Winograd batched-GEMM lowering.
//!
//! `scripts/wino_decomposition.py` is an exact-f32 Python port of
//! `blas/winograd.rs` that computes the transform-domain products of
//! BOTH conv formulations — the old inline per-tile path (transform a
//! patch, contract channels elementwise, inverse-transform) and the new
//! scatter → batched-GEMM → gather lowering — asserts the two agree
//! **bitwise**, and pins U, V, M and the output into
//! `tests/fixtures/wino_decomp.json`.  This suite replays the corpus
//! through the real kernels and requires bit-exact agreement with the
//! fixture, so any change to the decomposition's layouts or its
//! ascending-k accumulation order (the contract `congruence()` and
//! `gemm_batched_isa` share) fails loudly instead of drifting.
//!
//! The GEMM runs with `bk` ≥ `in_c` (a single k-panel), where the
//! blocked kernel's accumulation is the same ascending-k sum the
//! fixture encodes — that is what makes bit-exactness a fair contract.

use portable_kernels::blas::{
    conv2d_winograd, gemm_batched_isa, scatter_input, transform_filters,
    BlockedParams, Conv2dShape, Isa,
};
use portable_kernels::util::json::{parse, Value};
use portable_kernels::util::rng::XorShift;

const FIXTURE: &str = include_str!("fixtures/wino_decomp.json");

fn dim(case: &Value, key: &str) -> usize {
    case.get(key)
        .and_then(Value::as_u64)
        .unwrap_or_else(|| panic!("fixture case missing {key}"))
        as usize
}

fn f32s(case: &Value, key: &str) -> Vec<f32> {
    case.get(key)
        .and_then(Value::as_array)
        .unwrap_or_else(|| panic!("fixture case missing {key}"))
        .iter()
        .map(|e| e.as_f64().expect("fixture value is a number") as f32)
        .collect()
}

fn assert_bits(got: &[f32], want: &[f32], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length mismatch");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert!(
            g.to_bits() == w.to_bits(),
            "{what}: element {i}: {g} != pinned {w} (not bit-exact)"
        );
    }
}

/// Single k-panel blocking: `bk` covers every fixture case's `in_c`,
/// so the blocked GEMM's per-element sum is the plain ascending-k
/// accumulation the fixture (and the old inline path) encode.
fn fixture_params() -> BlockedParams {
    BlockedParams { bm: 32, bn: 32, bk: 32, mr: 4, nr: 8, threads: 1 }
}

#[test]
fn decomposition_matches_the_pinned_inline_path() {
    let root = parse(FIXTURE).expect("fixture parses");
    let cases = root
        .get("cases")
        .and_then(Value::as_array)
        .expect("fixture has cases");
    assert_eq!(cases.len(), 3, "fixture corpus is the 3-case set");
    for case in cases {
        let m = dim(case, "wino_m");
        let s = Conv2dShape::same(
            dim(case, "batch"),
            dim(case, "in_h"),
            dim(case, "in_w"),
            dim(case, "in_c"),
            dim(case, "out_c"),
            3,
            1,
        );
        let label = format!(
            "m={m} b{}x{}x{}x{}->{}",
            s.batch, s.in_h, s.in_w, s.in_c, s.out_c
        );
        let x = XorShift::new(dim(case, "seed_x") as u64)
            .f32_vec(s.input_elems());
        let f = XorShift::new(dim(case, "seed_f") as u64)
            .f32_vec(s.filter_elems());

        // The filter transform: U[pos] (in_c x out_c) per position.
        let u = transform_filters(&f, &s, m);
        assert_bits(&u, &f32s(case, "u"), &format!("{label}: U"));

        // The input scatter: V[pos] (tiles x in_c) per position.
        let v = scatter_input(&x, &s, m);
        assert_bits(&v, &f32s(case, "v"), &format!("{label}: V"));

        // The transform-domain products through the real batched GEMM —
        // pinned against the OLD inline path's products (the Python
        // generator asserts inline == batched bitwise before writing).
        let t = m + 2;
        let tiles_h = s.out_h.div_ceil(m);
        let tiles = s.batch * tiles_h * s.out_w.div_ceil(m);
        let mmat = gemm_batched_isa(
            &v,
            &u,
            t * t,
            tiles,
            s.out_c,
            s.in_c,
            &fixture_params(),
            Isa::Scalar,
        );
        assert_bits(&mmat, &f32s(case, "m"), &format!("{label}: M"));

        // End to end through the public kernel (scatter + GEMM + the
        // ragged-clipping gather).
        let y = conv2d_winograd(&x, &f, &s, m, &fixture_params(), Isa::Scalar);
        assert_bits(&y, &f32s(case, "y"), &format!("{label}: Y"));
    }
}

#[test]
fn fixture_covers_both_tile_sizes_and_ragged_grids() {
    // The corpus must keep exercising the axes the regression exists
    // for: both wino_m values, a batched case, and ragged tile grids
    // (out_h not divisible by m) for each tile size family.
    let root = parse(FIXTURE).expect("fixture parses");
    let cases = root
        .get("cases")
        .and_then(Value::as_array)
        .expect("fixture has cases");
    let mut wino_ms: Vec<usize> = Vec::new();
    let mut ragged = 0usize;
    let mut batched = 0usize;
    for case in cases {
        let m = dim(case, "wino_m");
        if !wino_ms.contains(&m) {
            wino_ms.push(m);
        }
        if dim(case, "in_h") % m != 0 {
            ragged += 1;
        }
        if dim(case, "batch") > 1 {
            batched += 1;
        }
    }
    wino_ms.sort_unstable();
    assert_eq!(wino_ms, [2, 4], "both tile sizes pinned");
    assert!(ragged >= 2, "ragged tile grids pinned");
    assert!(batched >= 1, "a batched case pinned");
}
