//! # portable-kernels
//!
//! A Rust + JAX + Pallas reproduction of *"Cross-Platform Performance
//! Portability Using Highly Parametrized SYCL Kernels"* (Lawson, Goli,
//! McBain, Soutar, Sugy — Codeplay, 2019).
//!
//! The paper's thesis: write **one heavily parametrized kernel** per
//! operation (GEMM, convolution) and reduce per-device tuning to *choosing
//! the parameter combination that performs best on that hardware*.  This
//! crate is the request-path half of the three-layer reproduction
//! (`docs/ARCHITECTURE.md` in the repository walks the full
//! load→plan→tune→route→execute→oracle path with a layer diagram):
//!
//! * **Layer 1/2 (build time, Python)** — parametrized Pallas kernels and
//!   JAX layer graphs, AOT-lowered to `artifacts/*.hlo.txt` by
//!   `make artifacts`.  Python never runs at request time.
//! * **Layer 3 (this crate)** — loads the compiled artifacts and executes
//!   them through a pluggable [`runtime::Backend`], serves them from one
//!   engine actor or a routed pool ([`coordinator`]), models the paper's
//!   device zoo analytically ([`device`], [`perfmodel`]), tunes
//!   configurations per device ([`tuner`], `docs/TUNING.md`), and
//!   reproduces every table and figure of the paper's evaluation
//!   ([`harness`]).
//!
//! ## Execution backends
//!
//! The runtime is abstracted behind the [`runtime::Backend`] trait; two
//! implementations exist and everything above them (the coordinator
//! actor, the network runner, the measured tuner, the benches) is
//! backend-agnostic:
//!
//! * [`runtime::NativeEngine`] — the **default**.  Plans each manifest
//!   entry from its metadata (GEMM dims + α/β, or the conv
//!   [`runtime::LayerMeta`]) and dispatches to the pure-Rust reference
//!   kernels in [`blas`] (`gemm_blocked` with the α/β epilogue; the
//!   im2col conv path).  This is how the full
//!   load→plan→execute→oracle-check pipeline runs in the offline build,
//!   with zero external dependencies.
//! * `runtime::Engine` — the PJRT/XLA engine, gated behind the `pjrt`
//!   cargo feature because the `xla` crate it drives is not available
//!   offline (see `rust/Cargo.toml` for how to vendor it back in).
//!
//! [`runtime::DefaultEngine`] names whichever backend the build selected.
//!
//! ## Serving scale-out
//!
//! Backends are `&mut self` (and, for PJRT, non-`Sync`), so concurrency
//! lives in the [`coordinator`]: a single actor thread
//! ([`coordinator::EngineHandle`]) or a pool of them
//! ([`coordinator::EnginePool`]) with per-artifact consistent-hash
//! routing (plan caches build on exactly one actor), bounded queues with
//! explicit backpressure (`try_submit_run` returns
//! [`coordinator::SubmitError::Busy`]), least-loaded spill, and panic
//! containment (a dead actor's backlog drains onto survivors).  Both
//! shapes implement [`coordinator::EngineClient`], so the network
//! runner, the batcher, and the benches scale out unchanged;
//! `benches/serving_contention.rs` measures the resulting tension
//! between intra-engine `threads` and pool width competing for cores.
//!
//! ## Parallel execution and per-host tuning
//!
//! The host kernels are parametrized one step further than the paper's
//! device kernels: [`blas::BlockedParams`] carries a `threads` knob
//! (`0` = all cores, `1` = serial) and the kernels distribute macro-tile
//! row bands (GEMM) and batch×output-row chunks (im2col) over a
//! hand-rolled scoped thread pool ([`util::pool`]).  Every worker owns a
//! disjoint slice of the output and runs the exact serial per-chunk
//! code, so parallel results are **bit-identical** to serial — `threads`
//! is just one more axis of the parameter space.
//!
//! The convolution *algorithm* is one more axis of the same space:
//! [`blas::conv2d_native_isa`] dispatches a [`config::ConvConfig`] to
//! the im2col/GEMM lowering, the §4.1.1 tiled direct kernel, or the
//! §4.1.2 Winograd F(m×m, 3×3) kernel — its `wino_m ∈ {2, 4}` tile
//! size one more tuned axis, its transform-domain multiplies lowered
//! as `(wino_m+2)²` batched GEMMs ([`blas::gemm_batched_isa`]) so the
//! tuned GEMM stack serves every 3×3 conv — with im2col fallback off
//! an algorithm's domain, and GEMM's monomorphized `mr × nr`
//! micro-tiles come from the macro-generated
//! [`blas::MICRO_KERNEL_SHAPES`] registry shared with
//! [`config::micro_kernel_shapes`].  So is the micro-kernel
//! **ISA** ([`blas::Isa`]): each registry tile has runtime-dispatched
//! scalar/SSE2/AVX2/FMA `#[target_feature]` variants
//! ([`blas::gemm_blocked_isa`]), detected per host and degraded to
//! scalar at plan time when a tuned entry asks for an ISA the
//! executing CPU lacks — for GEMM points and conv points alike.
//!
//! The whole parameter space sits behind one abstraction,
//! [`config::KernelSpace`] — a point type ([`config::GemmPoint`]:
//! blocking × threads × ISA; [`config::ConvPoint`]: algorithm × knobs
//! (incl. `wino_m`) × blocking × ISA) plus
//! axes/validation/JSON/applicability — so storage,
//! sweeps, and plan-time resolution are written once, generically.
//! The measure→persist→plan loop closes over it:
//! [`tuner::tune_space_sweep`] times any space's grid
//! ([`tuner::gemm_point_grid`], [`tuner::conv_native_grid`]) through
//! any [`runtime::Backend`], persisting per-problem winners into a
//! [`tuner::SelectionDb`] (legacy `blocked`/`conv_native` entries
//! still load via migration shims; [`tuner::SelectionDb::merge`] folds
//! whole legacy DBs forward); a [`runtime::NativeEngine`] built with
//! `with_tuning` resolves each artifact's point — algorithm and ISA
//! included — from that DB at plan time (small untuned problems
//! default to serial threads per
//! [`runtime::SMALL_PROBLEM_FLOP_CUTOFF`]).  `cargo run --release
//! --example tune_device -- --quick` runs the whole loop (CI does, on
//! every merge, archiving the DB and a GFLOP/s summary as artifacts).
//!
//! ## Module map
//!
//! | module | role |
//! |---|---|
//! | [`config`] | kernel parameter spaces (`KernelSpace`, `GemmPoint`, `ConvPoint`, `GemmConfig`, `ConvConfig`) |
//! | [`device`] | device specifications (paper Table 1) |
//! | [`perfmodel`] | analytic performance simulator (§2.2 metrics) |
//! | [`tuner`] | configuration search + selection DB + measured tuning + the per-host `BlockedParams × threads` sweep |
//! | [`runtime`] | artifact manifest + `Backend` trait (`NativeEngine` default, PJRT `Engine` behind `pjrt`) |
//! | [`blas`] | host Rust reference kernels (GEMM + im2col conv), band-parallel via `BlockedParams::threads` |
//! | [`nn`] | VGG-16 / ResNet-50 layer tables (Tables 3 & 4) |
//! | [`coordinator`] | serving layer: engine actor + routed pool, batcher, network runner |
//! | [`harness`] | per-figure/table report generators |

#![warn(missing_docs)]

pub mod blas;
pub mod config;
pub mod coordinator;
pub mod device;
pub mod error;
pub mod harness;
pub mod nn;
pub mod perfmodel;
pub mod runtime;
pub mod tuner;
pub mod util;

pub use config::{ConvAlgorithm, ConvConfig, GemmConfig};
pub use device::DeviceSpec;
pub use error::{Error, Result};
