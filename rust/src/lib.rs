//! # portable-kernels
//!
//! A Rust + JAX + Pallas reproduction of *"Cross-Platform Performance
//! Portability Using Highly Parametrized SYCL Kernels"* (Lawson, Goli,
//! McBain, Soutar, Sugy — Codeplay, 2019).
//!
//! The paper's thesis: write **one heavily parametrized kernel** per
//! operation (GEMM, convolution) and reduce per-device tuning to *choosing
//! the parameter combination that performs best on that hardware*.  This
//! crate is the request-path half of the three-layer reproduction:
//!
//! * **Layer 1/2 (build time, Python)** — parametrized Pallas kernels and
//!   JAX layer graphs, AOT-lowered to `artifacts/*.hlo.txt` by
//!   `make artifacts`.  Python never runs at request time.
//! * **Layer 3 (this crate)** — loads and executes the compiled artifacts
//!   via PJRT ([`runtime`]), models the paper's device zoo analytically
//!   ([`device`], [`perfmodel`]), tunes configurations per device
//!   ([`tuner`]), and reproduces every table and figure of the paper's
//!   evaluation ([`harness`]).
//!
//! ## Module map
//!
//! | module | role |
//! |---|---|
//! | [`config`] | kernel parameter spaces (`GemmConfig`, `ConvConfig`) |
//! | [`device`] | device specifications (paper Table 1) |
//! | [`perfmodel`] | analytic performance simulator (§2.2 metrics) |
//! | [`tuner`] | configuration search + selection database |
//! | [`runtime`] | PJRT artifact loading & execution |
//! | [`blas`] | host Rust GEMM baselines |
//! | [`nn`] | VGG-16 / ResNet-50 layer tables (Tables 3 & 4) |
//! | [`coordinator`] | benchmark scheduler + network runner |
//! | [`harness`] | per-figure/table report generators |

pub mod blas;
pub mod config;
pub mod coordinator;
pub mod device;
pub mod error;
pub mod harness;
pub mod nn;
pub mod perfmodel;
pub mod runtime;
pub mod tuner;
pub mod util;

pub use config::{ConvAlgorithm, ConvConfig, GemmConfig};
pub use device::DeviceSpec;
pub use error::{Error, Result};
