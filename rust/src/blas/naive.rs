//! Naive triple-loop GEMM — the correctness oracle.

/// `C = A @ B` for row-major `A (m x k)`, `B (k x n)`.
pub fn gemm_naive(a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * k, "A shape mismatch");
    assert_eq!(b.len(), k * n, "B shape mismatch");
    let mut c = vec![0.0f32; m * n];
    for i in 0..m {
        for p in 0..k {
            let aip = a[i * k + p];
            let brow = &b[p * n..(p + 1) * n];
            let crow = &mut c[i * n..(i + 1) * n];
            for j in 0..n {
                crow[j] += aip * brow[j];
            }
        }
    }
    c
}
