//! The SIMD instruction-set axis of the GEMM micro-kernel space.
//!
//! The paper's thesis is that device-specific kernel *variants* should be
//! one more tunable parameter, not a rewrite.  [`Isa`] is exactly such an
//! axis on the host: each value names a micro-kernel code path compiled
//! for a specific x86-64 feature level (`#[target_feature]` variants in
//! `blas::simd`), runtime-detected with `is_x86_feature_detected!` and
//! swept by the measured tuner like any other knob.  On non-x86-64 hosts
//! only [`Isa::Scalar`] (and, on aarch64, [`Isa::Neon`]) is available;
//! everything else degrades to scalar at plan time, so a tuning DB
//! written on one machine loads anywhere.

use crate::error::{Error, Result};

/// Instruction-set variant of the GEMM register micro-kernel.
///
/// `Scalar` is the portable baseline (whatever the compiler emits for
/// plain Rust).  The SIMD variants are monomorphized per registry shape
/// behind `#[target_feature]` and dispatched at runtime; selecting one
/// that the executing host does not support is a loud panic in
/// [`gemm_blocked_isa`](super::gemm_blocked_isa) (the plan layer degrades
/// unavailable ISAs to `Scalar` before it ever gets there).
///
/// Numerics: `Sse2` and `Avx2` run the same multiply-then-add sequence as
/// `Scalar` in the same order, so their outputs are bit-identical (0 ULP).
/// `Fma` contracts each multiply-add into a fused operation with a single
/// rounding, so it agrees with scalar only to within an accumulation
/// tolerance (~1e-6 per k-step) — proptested.  `Avx512` and `Neon` are
/// *dispatch* values today: `Avx512` runs the widest kernel this crate
/// ships (the FMA f32 kernel / the AVX2 int8 kernel — no 512-bit-specific
/// bodies yet), `Neon` runs the portable scalar bodies on aarch64, so
/// both inherit the numerics of the kernel they dispatch to.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Isa {
    /// Portable scalar micro-kernel (every host).
    Scalar,
    /// SSE2-compiled micro-kernel (x86-64 baseline; bit-identical to
    /// scalar).
    Sse2,
    /// AVX2-compiled micro-kernel (256-bit lanes; bit-identical to
    /// scalar).
    Avx2,
    /// AVX2 + FMA micro-kernel (`_mm256_fmadd_ps`; fused rounding, within
    /// tolerance of scalar).
    Fma,
    /// AVX-512 Foundation hosts.  Currently dispatches the widest
    /// shipped kernel family (FMA for f32, the AVX2 widening kernel for
    /// int8) — a detection + dispatch value so DBs tuned on AVX-512
    /// hosts are representable today and 512-bit kernel bodies can land
    /// later without a schema change.
    Avx512,
    /// aarch64 NEON hosts.  Currently dispatches the portable scalar
    /// kernel bodies (bit-identical); exists so non-x86 hosts have a
    /// detected non-degenerate axis value and NEON intrinsic bodies can
    /// land without a schema change.
    Neon,
}

impl Isa {
    /// Every ISA value, in sweep/report order (scalar first).
    pub fn all() -> [Isa; 6] {
        [Isa::Scalar, Isa::Sse2, Isa::Avx2, Isa::Fma, Isa::Avx512, Isa::Neon]
    }

    /// Stable lowercase name (selection DB, reports, CLI).
    pub fn as_str(&self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Sse2 => "sse2",
            Isa::Avx2 => "avx2",
            Isa::Fma => "fma",
            Isa::Avx512 => "avx512",
            Isa::Neon => "neon",
        }
    }

    /// Whether the *executing* host can run this variant.  `Scalar` is
    /// always available; the SIMD variants require x86-64 plus the
    /// matching CPUID feature bits (checked at runtime, not compile
    /// time, so one binary serves every microarchitecture), and `Neon`
    /// requires an aarch64 host with NEON (the aarch64 baseline).
    pub fn is_available(self) -> bool {
        match self {
            Isa::Scalar => true,
            #[cfg(target_arch = "x86_64")]
            Isa::Sse2 => std::arch::is_x86_feature_detected!("sse2"),
            #[cfg(target_arch = "x86_64")]
            Isa::Avx2 => std::arch::is_x86_feature_detected!("avx2"),
            #[cfg(target_arch = "x86_64")]
            Isa::Fma => {
                std::arch::is_x86_feature_detected!("avx2")
                    && std::arch::is_x86_feature_detected!("fma")
            }
            // Avx512 dispatches the FMA/AVX2 kernel bodies today, so it
            // requires those feature bits alongside avx512f.
            #[cfg(target_arch = "x86_64")]
            Isa::Avx512 => {
                std::arch::is_x86_feature_detected!("avx512f")
                    && std::arch::is_x86_feature_detected!("avx2")
                    && std::arch::is_x86_feature_detected!("fma")
            }
            #[cfg(target_arch = "aarch64")]
            Isa::Neon => std::arch::is_aarch64_feature_detected!("neon"),
            #[cfg(not(target_arch = "x86_64"))]
            _ => false,
            #[cfg(target_arch = "x86_64")]
            Isa::Neon => false,
        }
    }

    /// The ISAs the executing host supports, in [`Isa::all`] order.
    /// Always contains at least [`Isa::Scalar`]; this is the set the
    /// tuner's grids cross with the blocking parameters.
    pub fn detect() -> Vec<Isa> {
        Self::all().into_iter().filter(|i| i.is_available()).collect()
    }
}

impl std::fmt::Display for Isa {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

impl std::str::FromStr for Isa {
    type Err = Error;
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "scalar" => Ok(Isa::Scalar),
            "sse2" => Ok(Isa::Sse2),
            "avx2" => Ok(Isa::Avx2),
            "fma" => Ok(Isa::Fma),
            "avx512" => Ok(Isa::Avx512),
            "neon" => Ok(Isa::Neon),
            other => Err(Error::Config(format!("unknown isa {other:?}"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn name_roundtrip() {
        for isa in Isa::all() {
            assert_eq!(isa.to_string().parse::<Isa>().unwrap(), isa);
        }
        assert!("avx512vnni".parse::<Isa>().is_err());
        assert!("".parse::<Isa>().is_err());
    }

    #[test]
    fn scalar_is_always_available() {
        assert!(Isa::Scalar.is_available());
        let detected = Isa::detect();
        assert!(detected.contains(&Isa::Scalar));
        // Detection is a subset of the full axis, in axis order.
        let all = Isa::all();
        let mut last = 0;
        for isa in &detected {
            let pos = all.iter().position(|a| a == isa).unwrap();
            assert!(pos >= last, "detect() out of axis order");
            last = pos;
            assert!(isa.is_available());
        }
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn x86_64_baseline_has_sse2() {
        // SSE2 is part of the x86-64 baseline; any host running this
        // test supports it, so the axis is never degenerate on x86-64.
        assert!(Isa::Sse2.is_available());
        assert!(Isa::detect().len() >= 2);
        // NEON is an aarch64 value; it must never detect on x86-64.
        assert!(!Isa::Neon.is_available());
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn avx512_implies_its_dispatch_targets() {
        // Avx512 executes the FMA/AVX2 kernel bodies, so availability
        // must never claim a host that lacks them.
        if Isa::Avx512.is_available() {
            assert!(Isa::Fma.is_available());
            assert!(Isa::Avx2.is_available());
        }
    }
}
