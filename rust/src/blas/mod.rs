//! Host Rust reference kernels: GEMM baselines and the native conv
//! algorithm family (im2col, tiled direct, Winograd).
//!
//! Three roles: (1) a pure-Rust oracle to validate backend results against
//! in integration tests, (2) the "hand-written native library" comparator
//! for the measured host benchmarks — the role MKL-DNN/ARM-CL-NEON play on
//! the paper's CPUs — and (3) the compute kernels behind
//! [`runtime::NativeEngine`](crate::runtime::NativeEngine), the default
//! (offline) execution backend.
//!
//! The convolution *algorithm* is itself a kernel parameter (paper §4.1):
//! [`conv2d_native_isa`] dispatches one [`crate::config::ConvConfig`] to
//! the im2col/GEMM lowering ([`conv2d_im2col_isa`]), the §4.1.1 tiled
//! direct kernel ([`conv2d_tiled`]), or the §4.1.2 Winograd
//! F(m×m, 3×3) kernel ([`conv2d_winograd`], `wino_m ∈ {2, 4}`, lowered
//! as scatter → `(m+2)²` transform-domain batched GEMMs → gather via
//! [`gemm_batched_isa`]), with im2col fallback for shapes an algorithm
//! cannot compute ([`native_conv_algorithm`]).  GEMM's monomorphized
//! register micro-tiles are enumerated by the macro-generated
//! [`MICRO_KERNEL_SHAPES`] registry, and each registry tile can run a
//! runtime-detected SIMD variant ([`Isa`]: scalar / SSE2 / AVX2 / FMA /
//! AVX-512 on x86-64, NEON on aarch64, dispatched by
//! [`gemm_blocked_isa`]) — a hardware axis both GEMM plans and (through
//! the lowered conv GEMMs) conv plans sweep via the unified
//! `config::KernelSpace` parameter space.  Precision is one more axis of
//! the same space ([`Dtype`]): the `int8` module carries a second,
//! quantized micro-kernel family (i8×i8→i32 widening kernels with
//! per-tensor scale/zero-point dequantize, [`gemm_i8_blocked_isa`] /
//! [`conv2d_im2col_i8`]) over the identical blocked macro-tiling,
//! thread pool, and ISA dispatch.

mod blocked;
mod conv;
mod direct;
mod int8;
mod isa;
mod naive;
#[cfg(target_arch = "x86_64")]
mod simd;
mod winograd;

pub use blocked::{
    gemm_batched_ex, gemm_batched_isa, gemm_batched_workspace,
    gemm_blocked, gemm_blocked_ex, gemm_blocked_isa, gemm_workspace,
    BlockedParams, Pack, MICRO_KERNEL_SHAPES,
};
pub use int8::{
    conv2d_im2col_i8, conv2d_im2col_i8_ex, conv2d_im2col_i8_workspace,
    gemm_i8_blocked_ex, gemm_i8_blocked_isa, gemm_i8_dequant,
    gemm_i8_dequant_ex, gemm_i8_dequant_workspace, gemm_i8_workspace,
    quantize_into, quantize_slice, Dtype, QuantParams,
    INT8_MICRO_KERNEL_SHAPES, MAX_I8_GEMM_K,
};
pub use isa::Isa;
pub use conv::{
    conv2d_direct, conv2d_im2col, conv2d_im2col_ex, conv2d_im2col_isa,
    conv2d_im2col_workspace, conv2d_native, conv2d_native_ex,
    conv2d_native_isa, conv2d_native_workspace, im2col, im2col_threaded,
    native_conv_algorithm, native_conv_algorithm_dims, Conv2dShape,
};
pub use direct::conv2d_tiled;
pub use naive::gemm_naive;
pub use winograd::{
    conv2d_winograd, conv2d_winograd_ex, conv2d_winograd_workspace,
    scatter_input, transform_filters, winograd_supports, winograd_tiles,
};

/// Max |a - b| over two equal-length slices (test helper).
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rand_vec(n: usize, seed: u64) -> Vec<f32> {
        // xorshift: deterministic, dependency-free.
        let mut s = seed.wrapping_mul(0x9E3779B97F4A7C15) | 1;
        (0..n)
            .map(|_| {
                s ^= s << 13;
                s ^= s >> 7;
                s ^= s << 17;
                ((s >> 40) as f32 / (1u64 << 24) as f32) - 0.5
            })
            .collect()
    }

    /// Parameter sets the module-level checks run under — the default
    /// plus tuned-looking serial and threaded configs, so correctness is
    /// never asserted for the default configuration alone.
    fn param_matrix() -> Vec<BlockedParams> {
        vec![
            BlockedParams::default(),
            BlockedParams { bm: 16, bn: 16, bk: 8, mr: 2, nr: 4, threads: 1 },
            BlockedParams { bm: 32, bn: 32, bk: 32, mr: 4, nr: 8, threads: 3 },
        ]
    }

    #[test]
    fn blocked_matches_naive() {
        for &(m, n, k) in &[(1, 1, 1), (17, 13, 9), (64, 64, 64), (100, 50, 70)] {
            let a = rand_vec(m * k, 1);
            let b = rand_vec(k * n, 2);
            let naive = gemm_naive(&a, &b, m, n, k);
            for params in param_matrix() {
                let blocked = gemm_blocked(&a, &b, m, n, k, &params);
                assert!(
                    max_abs_diff(&naive, &blocked) < 1e-4,
                    "mismatch at {m}x{n}x{k} under {params:?}"
                );
            }
        }
    }

    #[test]
    fn identity_times_b_is_b() {
        let n = 16;
        let mut eye = vec![0.0f32; n * n];
        for i in 0..n {
            eye[i * n + i] = 1.0;
        }
        let b = rand_vec(n * n, 3);
        for params in param_matrix() {
            let out = gemm_blocked(&eye, &b, n, n, n, &params);
            assert!(max_abs_diff(&out, &b) < 1e-6, "{params:?}");
        }
    }
}
