//! x86-64 SIMD variants of the monomorphized GEMM micro-kernel.
//!
//! Each function here is the same full-tile register micro-kernel as
//! `blocked::micro_kernel_fixed`, compiled for a specific feature level
//! via `#[target_feature]`.  The SSE2 and AVX2 variants reuse the scalar
//! body verbatim (the `#[inline(always)]` body is inlined into the
//! feature-annotated wrapper and auto-vectorized at that feature level),
//! which keeps them **bit-identical** to the scalar kernel: the multiply
//! and add sequence per accumulator element is unchanged, only the lane
//! width the compiler may use changes.  The FMA variant is written with
//! explicit `_mm256_fmadd_ps` intrinsics — a genuinely different
//! numerical contract (one rounding per multiply-add instead of two), so
//! it agrees with scalar only within an accumulation tolerance.
//!
//! Safety model: every function is `unsafe fn` because calling it on a
//! CPU without the advertised feature is undefined behavior.  The single
//! caller (`blocked::dispatch_micro_kernel`) is reached only through
//! `gemm_blocked_isa`, which asserts `Isa::is_available` on entry; the
//! plan layer additionally degrades unavailable ISAs to scalar before
//! execution, so the assert is a backstop, not the primary guard.

use super::blocked::{micro_kernel_fixed, micro_kernel_fixed_pb};

#[cfg(target_arch = "x86_64")]
use std::arch::x86_64::{
    __m128, __m256, _mm256_add_ps, _mm256_fmadd_ps, _mm256_loadu_ps,
    _mm256_set1_ps, _mm256_setzero_ps, _mm256_storeu_ps, _mm_add_ps,
    _mm_fmadd_ps, _mm_loadu_ps, _mm_set1_ps, _mm_setzero_ps, _mm_storeu_ps,
};

/// The scalar micro-kernel body compiled with SSE2 enabled (the x86-64
/// baseline).  Bit-identical to the scalar kernel by construction.
///
/// # Safety
///
/// The executing CPU must support SSE2 (always true on x86-64, checked
/// anyway by `gemm_blocked_isa`).  Slice/layout preconditions are those
/// of `micro_kernel_fixed`.
#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "sse2")]
pub(crate) unsafe fn micro_kernel_sse2<const MR: usize, const NR: usize>(
    apack: &[f32],
    b: &[f32],
    c: &mut [f32],
    n: usize,
    i: usize,
    j: usize,
    p0: usize,
    p1: usize,
) {
    micro_kernel_fixed::<MR, NR>(apack, b, c, n, i, j, p0, p1);
}

/// The scalar micro-kernel body compiled with AVX2 enabled (256-bit
/// lanes).  Bit-identical to the scalar kernel by construction.
///
/// # Safety
///
/// The executing CPU must support AVX2 (`Isa::Avx2.is_available()`).
/// Slice/layout preconditions are those of `micro_kernel_fixed`.
#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn micro_kernel_avx2<const MR: usize, const NR: usize>(
    apack: &[f32],
    b: &[f32],
    c: &mut [f32],
    n: usize,
    i: usize,
    j: usize,
    p0: usize,
    p1: usize,
) {
    micro_kernel_fixed::<MR, NR>(apack, b, c, n, i, j, p0, p1);
}

/// Explicit fused-multiply-add micro-kernel: 256-bit `_mm256_fmadd_ps`
/// lanes for `NR % 8 == 0`, 128-bit `_mm_fmadd_ps` lanes for the
/// remaining `NR % 4 == 0` registry shapes, scalar bit-fallback for
/// anything else (off the FMA domain).  Same k-loop order as scalar, but
/// each multiply-add rounds once instead of twice, so outputs agree with
/// scalar within ~`k * 1e-7`, not bitwise.
///
/// # Safety
///
/// The executing CPU must support AVX2 + FMA (`Isa::Fma.is_available()`).
/// Slice/layout preconditions are those of `micro_kernel_fixed`.
#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "avx2,fma")]
pub(crate) unsafe fn micro_kernel_fma<const MR: usize, const NR: usize>(
    apack: &[f32],
    b: &[f32],
    c: &mut [f32],
    n: usize,
    i: usize,
    j: usize,
    p0: usize,
    p1: usize,
) {
    if NR % 8 == 0 {
        // NR/8 ymm accumulators per row; the registry caps NR at 16, so
        // 2 vectors per row always suffice.
        let nv = NR / 8;
        let mut acc: [[__m256; 2]; MR] = [[_mm256_setzero_ps(); 2]; MR];
        for p in 0..(p1 - p0) {
            let brow = b.as_ptr().add((p0 + p) * n + j);
            let astrip = apack.as_ptr().add(p * MR);
            for (r, accr) in acc.iter_mut().enumerate() {
                let av = _mm256_set1_ps(*astrip.add(r));
                for (v, a) in accr.iter_mut().take(nv).enumerate() {
                    *a = _mm256_fmadd_ps(
                        av,
                        _mm256_loadu_ps(brow.add(8 * v)),
                        *a,
                    );
                }
            }
        }
        for (r, accr) in acc.iter().enumerate() {
            let crow = c.as_mut_ptr().add((i + r) * n + j);
            for (v, a) in accr.iter().take(nv).enumerate() {
                let sum =
                    _mm256_add_ps(_mm256_loadu_ps(crow.add(8 * v)), *a);
                _mm256_storeu_ps(crow.add(8 * v), sum);
            }
        }
    } else if NR % 4 == 0 {
        // Narrow registry shapes (NR = 4): 128-bit FMA lanes, NR/4 xmm
        // accumulators per row (at most 4 for any NR <= 16).
        let nv = NR / 4;
        let mut acc: [[__m128; 4]; MR] = [[_mm_setzero_ps(); 4]; MR];
        for p in 0..(p1 - p0) {
            let brow = b.as_ptr().add((p0 + p) * n + j);
            let astrip = apack.as_ptr().add(p * MR);
            for (r, accr) in acc.iter_mut().enumerate() {
                let av = _mm_set1_ps(*astrip.add(r));
                for (v, a) in accr.iter_mut().take(nv).enumerate() {
                    *a = _mm_fmadd_ps(
                        av,
                        _mm_loadu_ps(brow.add(4 * v)),
                        *a,
                    );
                }
            }
        }
        for (r, accr) in acc.iter().enumerate() {
            let crow = c.as_mut_ptr().add((i + r) * n + j);
            for (v, a) in accr.iter().take(nv).enumerate() {
                let sum = _mm_add_ps(_mm_loadu_ps(crow.add(4 * v)), *a);
                _mm_storeu_ps(crow.add(4 * v), sum);
            }
        }
    } else {
        // Off the FMA lane domain: scalar bit-fallback.
        micro_kernel_fixed::<MR, NR>(apack, b, c, n, i, j, p0, p1);
    }
}

// ---------------------------------------------------------------------
// Packed-B twins (the `pack: ab` axis).  Each variant mirrors its
// unpacked sibling exactly — the only change is where the B row for
// depth `p` lives: `bstrip[p * NR ..]` (unit stride through the packed
// panel strip) instead of `b[(p0 + p) * n + j ..]`.  Same values, same
// floating-point order, so SSE2/AVX2 stay bit-identical to scalar and
// FMA keeps its fused-rounding tolerance contract.
// ---------------------------------------------------------------------

/// Packed-B twin of [`micro_kernel_sse2`]: the scalar packed kernel
/// body compiled with SSE2 enabled.  Bit-identical by construction.
///
/// # Safety
///
/// The executing CPU must support SSE2; slice/layout preconditions are
/// those of `micro_kernel_fixed_pb`.
#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "sse2")]
pub(crate) unsafe fn micro_kernel_sse2_pb<const MR: usize, const NR: usize>(
    apack: &[f32],
    bstrip: &[f32],
    c: &mut [f32],
    n: usize,
    i: usize,
    j: usize,
    kc: usize,
) {
    micro_kernel_fixed_pb::<MR, NR>(apack, bstrip, c, n, i, j, kc);
}

/// Packed-B twin of [`micro_kernel_avx2`]: the scalar packed kernel
/// body compiled with AVX2 enabled.  Bit-identical by construction.
///
/// # Safety
///
/// The executing CPU must support AVX2; slice/layout preconditions are
/// those of `micro_kernel_fixed_pb`.
#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn micro_kernel_avx2_pb<const MR: usize, const NR: usize>(
    apack: &[f32],
    bstrip: &[f32],
    c: &mut [f32],
    n: usize,
    i: usize,
    j: usize,
    kc: usize,
) {
    micro_kernel_fixed_pb::<MR, NR>(apack, bstrip, c, n, i, j, kc);
}

/// Packed-B twin of [`micro_kernel_fma`]: identical lane structure and
/// k-loop order, but B rows load from the packed strip
/// (`bstrip + p * NR`) with unit stride — this is the kernel where
/// packing pays, since every `_mm256_loadu_ps` now hits consecutive
/// cache lines.  Agrees with the scalar packed kernel within the same
/// `~k * 1e-7` fused-rounding tolerance as the unpacked FMA kernel, and
/// is bit-identical to the *unpacked* FMA kernel (same fused op order,
/// same values).
///
/// # Safety
///
/// The executing CPU must support AVX2 + FMA; slice/layout
/// preconditions are those of `micro_kernel_fixed_pb`.
#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "avx2,fma")]
pub(crate) unsafe fn micro_kernel_fma_pb<const MR: usize, const NR: usize>(
    apack: &[f32],
    bstrip: &[f32],
    c: &mut [f32],
    n: usize,
    i: usize,
    j: usize,
    kc: usize,
) {
    if NR % 8 == 0 {
        let nv = NR / 8;
        let mut acc: [[__m256; 2]; MR] = [[_mm256_setzero_ps(); 2]; MR];
        for p in 0..kc {
            let brow = bstrip.as_ptr().add(p * NR);
            let astrip = apack.as_ptr().add(p * MR);
            for (r, accr) in acc.iter_mut().enumerate() {
                let av = _mm256_set1_ps(*astrip.add(r));
                for (v, a) in accr.iter_mut().take(nv).enumerate() {
                    *a = _mm256_fmadd_ps(
                        av,
                        _mm256_loadu_ps(brow.add(8 * v)),
                        *a,
                    );
                }
            }
        }
        for (r, accr) in acc.iter().enumerate() {
            let crow = c.as_mut_ptr().add((i + r) * n + j);
            for (v, a) in accr.iter().take(nv).enumerate() {
                let sum =
                    _mm256_add_ps(_mm256_loadu_ps(crow.add(8 * v)), *a);
                _mm256_storeu_ps(crow.add(8 * v), sum);
            }
        }
    } else if NR % 4 == 0 {
        let nv = NR / 4;
        let mut acc: [[__m128; 4]; MR] = [[_mm_setzero_ps(); 4]; MR];
        for p in 0..kc {
            let brow = bstrip.as_ptr().add(p * NR);
            let astrip = apack.as_ptr().add(p * MR);
            for (r, accr) in acc.iter_mut().enumerate() {
                let av = _mm_set1_ps(*astrip.add(r));
                for (v, a) in accr.iter_mut().take(nv).enumerate() {
                    *a = _mm_fmadd_ps(
                        av,
                        _mm_loadu_ps(brow.add(4 * v)),
                        *a,
                    );
                }
            }
        }
        for (r, accr) in acc.iter().enumerate() {
            let crow = c.as_mut_ptr().add((i + r) * n + j);
            for (v, a) in accr.iter().take(nv).enumerate() {
                let sum = _mm_add_ps(_mm_loadu_ps(crow.add(4 * v)), *a);
                _mm_storeu_ps(crow.add(4 * v), sum);
            }
        }
    } else {
        micro_kernel_fixed_pb::<MR, NR>(apack, bstrip, c, n, i, j, kc);
    }
}
