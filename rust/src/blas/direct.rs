//! Tiled direct convolution — the paper's §4.1.1 kernel family on the
//! host.  Each "work item" computes a `tile_h × tile_w` spatial tile of
//! outputs for a `vec_k`-wide block of output channels, holding the whole
//! accumulator tile live while it streams the filter taps and input
//! channels — the input-reuse structure that makes the tiled family
//! competitive with im2col without materializing a patch matrix.
//!
//! The knobs come straight from [`ConvConfig`]: `tile_h`/`tile_w` are the
//! output tile, `vec_k` the output-channel block (the accumulator width),
//! `vec_c` the input-channel inner blocking.  All knob settings compute
//! the same accumulation order per output element — ascending
//! `(r, s, c)`, exactly the order of [`conv2d_direct`] — so every tiled
//! configuration is bit-identical to the direct oracle, and the knobs
//! are pure throughput parameters the tuner sweeps.
//!
//! Parallelism: the unit is one `(batch, tile-row)` band of output rows;
//! workers own disjoint `&mut` output slices and run the exact serial
//! per-band code (bit-identical to serial, the crate discipline).
//!
//! [`conv2d_direct`]: super::conv2d_direct

use super::conv::Conv2dShape;
use crate::config::ConvConfig;
use crate::util::pool;

/// A skipped (padding) row/column entry in the hoisted index tables.
const PAD: usize = usize::MAX;

/// Hoisted per-call column table: `iw_tab[ow * win + sw]` is the input
/// column *offset* (`iw * in_c`) output column `ow` reads for filter tap
/// column `sw`, or [`PAD`] when that tap falls into padding.  Computed
/// once per call and shared read-only by every band, so the per-tap
/// stride/padding arithmetic is no longer recomputed for every
/// `(r, c, oh)` combination.
fn input_col_table(s: &Conv2dShape) -> Vec<usize> {
    let win = s.window;
    let mut iw_tab = vec![PAD; s.out_w * win];
    for ow in 0..s.out_w {
        for sw in 0..win {
            let iw = (ow * s.stride + sw) as isize - s.pad_left as isize;
            if iw >= 0 && (iw as usize) < s.in_w {
                iw_tab[ow * win + sw] = iw as usize * s.in_c;
            }
        }
    }
    iw_tab
}

/// One `(batch, tile-row)` band: output rows `[r0, r1)` of batch `b`
/// into `out_band` (pre-zeroed, `(r1 - r0) * out_w * out_c` elements).
///
/// `iw_tab` is the shared [`input_col_table`]; `xrow_tab` is this band's
/// scratch for the hoisted *row* table — `xrow_tab[(oh - r0) * win + r]`
/// holds the base index of the input row output row `oh` reads for
/// filter tap row `r` (or [`PAD`] in padding), computed once per band
/// instead of once per `(tap, channel, oh)`.  The hoist changes only
/// how indices are computed, never the ascending `(r, s, c)`
/// accumulation order, so outputs stay bit-identical to
/// [`conv2d_direct`](super::conv2d_direct).
#[allow(clippy::too_many_arguments)]
fn tiled_band(
    x: &[f32],
    f: &[f32],
    s: &Conv2dShape,
    tile_w: usize,
    kb: usize,
    cb: usize,
    b: usize,
    r0: usize,
    r1: usize,
    out_band: &mut [f32],
    acc: &mut [f32],
    iw_tab: &[usize],
    xrow_tab: &mut [usize],
) {
    let (ci, co, win) = (s.in_c, s.out_c, s.window);
    // Hoist the per-tap input row arithmetic: one entry per
    // (output row, tap row) for the whole band, reused across every
    // filter column, channel block, and output-column tile below.
    for oh in r0..r1 {
        for r in 0..win {
            let ih = (oh * s.stride + r) as isize - s.pad_top as isize;
            xrow_tab[(oh - r0) * win + r] =
                if ih >= 0 && (ih as usize) < s.in_h {
                    ((b * s.in_h + ih as usize) * s.in_w) * ci
                } else {
                    PAD
                };
        }
    }
    for ow0 in (0..s.out_w).step_by(tile_w) {
        let ow1 = (ow0 + tile_w).min(s.out_w);
        for k0 in (0..co).step_by(kb) {
            let kbe = (k0 + kb).min(co) - k0;
            acc.fill(0.0);
            // Accumulate in ascending (r, s, c) order — the direct
            // oracle's order — so every knob setting rounds identically.
            for r in 0..win {
                for sw in 0..win {
                    for c0 in (0..ci).step_by(cb) {
                        let c1 = (c0 + cb).min(ci);
                        for c in c0..c1 {
                            let f0 = ((r * win + sw) * ci + c) * co + k0;
                            let frow = &f[f0..f0 + kbe];
                            for oh in r0..r1 {
                                let xrow =
                                    xrow_tab[(oh - r0) * win + r];
                                if xrow == PAD {
                                    continue;
                                }
                                for ow in ow0..ow1 {
                                    let iw_off = iw_tab[ow * win + sw];
                                    if iw_off == PAD {
                                        continue;
                                    }
                                    let xv = x[xrow + iw_off + c];
                                    let a0 = ((oh - r0) * tile_w
                                        + (ow - ow0))
                                        * kb;
                                    for (av, fv) in acc
                                        [a0..a0 + kbe]
                                        .iter_mut()
                                        .zip(frow)
                                    {
                                        *av += xv * fv;
                                    }
                                }
                            }
                        }
                    }
                }
            }
            // Write the finished accumulator tile.
            for oh in r0..r1 {
                for ow in ow0..ow1 {
                    let a0 = ((oh - r0) * tile_w + (ow - ow0)) * kb;
                    let o0 = ((oh - r0) * s.out_w + ow) * co + k0;
                    out_band[o0..o0 + kbe]
                        .copy_from_slice(&acc[a0..a0 + kbe]);
                }
            }
        }
    }
}

/// Tiled direct convolution per `cfg` (`tile_h`/`tile_w`/`vec_c`/`vec_k`;
/// the algorithm field is ignored — dispatch happens in
/// [`conv2d_native`](super::conv2d_native)).  `threads` follows the
/// [`BlockedParams::threads`](super::BlockedParams::threads) convention.
/// Output is bit-identical to [`conv2d_direct`](super::conv2d_direct)
/// for every knob setting and thread count.
pub fn conv2d_tiled(
    x: &[f32],
    f: &[f32],
    s: &Conv2dShape,
    cfg: &ConvConfig,
    threads: usize,
) -> Vec<f32> {
    assert_eq!(x.len(), s.input_elems(), "input shape mismatch");
    assert_eq!(f.len(), s.filter_elems(), "filter shape mismatch");
    assert!(
        cfg.tile_h > 0 && cfg.tile_w > 0 && cfg.vec_c > 0 && cfg.vec_k > 0,
        "tiled conv knobs must be non-zero: {cfg:?}"
    );
    let tile_h = cfg.tile_h as usize;
    let tile_w = cfg.tile_w as usize;
    let kb = (cfg.vec_k as usize).min(s.out_c.max(1));
    let cb = cfg.vec_c as usize;
    let mut out = vec![0.0f32; s.output_elems()];
    if s.output_elems() == 0 {
        return out;
    }
    let tiles_h = s.out_h.div_ceil(tile_h);

    // Disjoint (batch, tile-row) output bands, sized for the ragged last
    // tile row of each batch.
    let mut bands: Vec<(usize, usize, usize, &mut [f32])> = Vec::new();
    {
        let mut rest: &mut [f32] = &mut out;
        for b in 0..s.batch {
            for tr in 0..tiles_h {
                let r0 = tr * tile_h;
                let r1 = (r0 + tile_h).min(s.out_h);
                let (band, tail) = std::mem::take(&mut rest)
                    .split_at_mut((r1 - r0) * s.out_w * s.out_c);
                bands.push((b, r0, r1, band));
                rest = tail;
            }
        }
        debug_assert!(rest.is_empty());
    }

    let acc_len = tile_h * tile_w * kb;
    let xrow_len = tile_h * s.window;
    // The column table is shape-only: compute once, share read-only
    // across every band and worker.
    let iw_tab = input_col_table(s);
    let workers = pool::resolve_threads(threads);
    if workers <= 1 || bands.len() <= 1 {
        let mut acc = vec![0.0f32; acc_len];
        let mut xrow_tab = vec![PAD; xrow_len];
        for (b, r0, r1, band) in bands {
            tiled_band(
                x, f, s, tile_w, kb, cb, b, r0, r1, band, &mut acc,
                &iw_tab, &mut xrow_tab,
            );
        }
    } else {
        pool::run_parallel(workers, bands, |_, (b, r0, r1, band)| {
            let mut acc = vec![0.0f32; acc_len];
            let mut xrow_tab = vec![PAD; xrow_len];
            tiled_band(
                x, f, s, tile_w, kb, cb, b, r0, r1, band, &mut acc,
                &iw_tab, &mut xrow_tab,
            );
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blas::conv2d_direct;
    use crate::util::rng::XorShift;

    fn rand(n: usize, seed: u64) -> Vec<f32> {
        XorShift::new(seed).f32_vec(n)
    }

    /// The tiled configurations the tests sweep: 1x1 (== algorithm 1,
    /// the naive kernel), square and rectangular tiles, wide and narrow
    /// channel blocks.
    fn cfg_matrix() -> Vec<ConvConfig> {
        vec![
            ConvConfig::tiled(1, 1, 1, 1),
            ConvConfig::tiled(2, 2, 1, 4),
            ConvConfig::tiled(4, 4, 4, 4),
            ConvConfig::tiled(3, 5, 2, 16), // vec_k > out_c gets clamped
            ConvConfig::tiled(5, 1, 4, 2),
        ]
    }

    #[test]
    fn every_config_is_bit_identical_to_direct() {
        for &(b, h, w, c, k, win, stride) in &[
            (2usize, 8usize, 8usize, 3usize, 4usize, 3usize, 1usize),
            (1, 9, 7, 2, 5, 3, 2),
            (1, 6, 6, 4, 4, 1, 1), // pointwise
            (2, 10, 10, 2, 3, 5, 2),
            (1, 1, 1, 4, 2, 1, 1), // single output pixel
        ] {
            let s = Conv2dShape::same(b, h, w, c, k, win, stride);
            let x = rand(s.input_elems(), 3);
            let f = rand(s.filter_elems(), 4);
            let direct = conv2d_direct(&x, &f, &s);
            for cfg in cfg_matrix() {
                let tiled = conv2d_tiled(&x, &f, &s, &cfg, 1);
                assert!(
                    direct == tiled,
                    "{} not bit-identical to direct on {s:?}",
                    cfg.name()
                );
            }
        }
    }

    #[test]
    fn valid_padding_matches_direct() {
        let s = Conv2dShape::valid(1, 12, 12, 3, 8, 5, 2);
        let x = rand(s.input_elems(), 7);
        let f = rand(s.filter_elems(), 8);
        let direct = conv2d_direct(&x, &f, &s);
        for cfg in cfg_matrix() {
            assert!(direct == conv2d_tiled(&x, &f, &s, &cfg, 1));
        }
    }

    #[test]
    fn threaded_is_bit_identical_to_serial() {
        let s = Conv2dShape::same(2, 9, 7, 3, 4, 3, 1);
        let x = rand(s.input_elems(), 9);
        let f = rand(s.filter_elems(), 10);
        for cfg in cfg_matrix() {
            let serial = conv2d_tiled(&x, &f, &s, &cfg, 1);
            for threads in [0usize, 2, 3, 8, 64] {
                let par = conv2d_tiled(&x, &f, &s, &cfg, threads);
                assert!(
                    serial == par,
                    "{} threads={threads} diverged",
                    cfg.name()
                );
            }
        }
    }

    #[test]
    fn hoisted_row_tables_stay_bit_identical_on_strided_shapes() {
        // The row-reuse hoist targets strided layers, where the old code
        // recomputed each input row index per filter tap; the hoist must
        // change timing only, never a bit of output.  Heavy coverage of
        // stride-2/3 shapes with awkward padding, every knob combination.
        for &(b, h, w, c, k, win, stride) in &[
            (1usize, 16usize, 16usize, 3usize, 8usize, 3usize, 2usize),
            (2, 15, 11, 4, 6, 5, 2),
            (1, 10, 10, 2, 4, 3, 3),
            (1, 7, 13, 5, 3, 5, 3),
            (2, 8, 8, 1, 1, 7, 2),
        ] {
            let s = Conv2dShape::same(b, h, w, c, k, win, stride);
            let x = rand(s.input_elems(), 11);
            let f = rand(s.filter_elems(), 12);
            let direct = conv2d_direct(&x, &f, &s);
            for cfg in cfg_matrix() {
                for threads in [1usize, 3] {
                    assert!(
                        direct == conv2d_tiled(&x, &f, &s, &cfg, threads),
                        "{} threads={threads} not bit-identical on {s:?}",
                        cfg.name()
                    );
                }
            }
        }
    }

    #[test]
    #[should_panic(expected = "non-zero")]
    fn zero_tile_is_a_loud_panic() {
        let s = Conv2dShape::same(1, 2, 2, 1, 1, 1, 1);
        let cfg = ConvConfig { tile_h: 0, ..Default::default() };
        conv2d_tiled(&[0.0; 4], &[0.0], &s, &cfg, 1);
    }
}
