//! Native Winograd F(m×m, 3×3) convolution, m ∈ {2, 4} — the paper's
//! §4.1.2 fast algorithm lowered onto the tuned GEMM stack.
//!
//! The Cook-Toom construction (Lavin & Gray, arXiv:1509.09308): each
//! m×m output tile is computed from a (m+2)×(m+2) input tile in the
//! transform domain — `Y = Aᵀ[(G g Gᵀ) ⊙ (Bᵀ d B)]A`.  F(2×2, 3×3)
//! replaces the 36 multiplies of a direct 2×2 output tile with 16;
//! F(4×4, 3×3) replaces 144 with 36 at a larger (but bounded) numeric
//! error, so `wino_m` is a tuned axis with an accuracy trade-off.
//!
//! This is the paper's *large-channel formulation*: instead of
//! contracting channels inline per tile, every input tile is scattered
//! into `(m+2)²` transform-domain matrices `V[pos]` of shape
//! `tiles × in_c`, the filters into `U[pos]` of shape `in_c × out_c`,
//! and the per-position multiplies run as one batched GEMM
//! `M[pos] = V[pos] @ U[pos]` through
//! [`gemm_batched_isa`](super::gemm_batched_isa) — i.e. through
//! [`gemm_blocked_isa`](super::gemm_blocked_isa) with the plan's tuned
//! blocking, `threads`, and SIMD micro-kernel [`Isa`].  That multiplies
//! the whole GEMM registry (macro-tiling × monomorphized micro-kernels
//! × ISA variants) into every 3×3 conv; no inline element-wise
//! transform-domain path remains.
//!
//! Determinism follows the crate discipline: the batched GEMM is
//! bit-identical across thread counts (disjoint `bm`-row bands), and
//! the gather parallelizes over disjoint `(batch, tile-row)` output
//! bands running the exact serial per-band code — so the whole kernel
//! is bit-identical to serial for every thread count and every
//! available ISA except FMA (which fuses rounding and agrees within an
//! accumulation tolerance).  Winograd output is *not* bit-identical to
//! im2col/direct — it is a different factorization — but agrees within
//! the per-`wino_m` bounds pinned in `tests/proptests.rs`.

use super::blocked::{
    gemm_batched_into, gemm_batched_workspace, BlockedParams, Pack,
};
use super::conv::Conv2dShape;
use super::Isa;
use crate::util::pool;
use crate::util::scratch::{Scratch, Workspace};

/// Whether the native Winograd kernel can compute this shape:
/// F(m×m, 3×3) covers 3×3 windows at stride 1 (any padding).  Delegates
/// to [`ConvAlgorithm::supports`](crate::config::ConvAlgorithm::supports)
/// so the kernel domain has exactly one definition.
pub fn winograd_supports(s: &Conv2dShape) -> bool {
    crate::config::ConvAlgorithm::Winograd
        .supports(s.window as u32, s.stride as u32)
}

// ---- the Lavin & Gray transform matrices ----
//
// F(2×2, 3×3): interpolation points {0, 1, -1}; tile t = 4.
/// F(2×2, 3×3) filter transform `G` (4×3, row-major).
const G2: [f32; 12] = [
    1.0, 0.0, 0.0, //
    0.5, 0.5, 0.5, //
    0.5, -0.5, 0.5, //
    0.0, 0.0, 1.0,
];
/// F(2×2, 3×3) input transform `Bᵀ` (4×4, row-major).
const BT2: [f32; 16] = [
    1.0, 0.0, -1.0, 0.0, //
    0.0, 1.0, 1.0, 0.0, //
    0.0, -1.0, 1.0, 0.0, //
    0.0, 1.0, 0.0, -1.0,
];
/// F(2×2, 3×3) inverse transform `Aᵀ` (2×4, row-major).
const AT2: [f32; 8] = [
    1.0, 1.0, 1.0, 0.0, //
    0.0, 1.0, -1.0, -1.0,
];

// F(4×4, 3×3): interpolation points {0, ±1, ±2}; tile t = 6.  The
// fractional G entries are exact in the const expressions below and
// round once to f32, matching the reference construction.
/// F(4×4, 3×3) filter transform `G` (6×3, row-major).
const G4: [f32; 18] = [
    0.25,
    0.0,
    0.0,
    -1.0 / 6.0,
    -1.0 / 6.0,
    -1.0 / 6.0,
    -1.0 / 6.0,
    1.0 / 6.0,
    -1.0 / 6.0,
    1.0 / 24.0,
    1.0 / 12.0,
    1.0 / 6.0,
    1.0 / 24.0,
    -1.0 / 12.0,
    1.0 / 6.0,
    0.0,
    0.0,
    1.0,
];
/// F(4×4, 3×3) input transform `Bᵀ` (6×6, row-major).
const BT4: [f32; 36] = [
    4.0, 0.0, -5.0, 0.0, 1.0, 0.0, //
    0.0, -4.0, -4.0, 1.0, 1.0, 0.0, //
    0.0, 4.0, -4.0, -1.0, 1.0, 0.0, //
    0.0, -2.0, -1.0, 2.0, 1.0, 0.0, //
    0.0, 2.0, -1.0, -2.0, 1.0, 0.0, //
    0.0, 4.0, 0.0, -5.0, 0.0, 1.0,
];
/// F(4×4, 3×3) inverse transform `Aᵀ` (4×6, row-major).
const AT4: [f32; 24] = [
    1.0, 1.0, 1.0, 1.0, 1.0, 0.0, //
    0.0, 1.0, -1.0, 2.0, -2.0, 0.0, //
    0.0, 1.0, 1.0, 4.0, 4.0, 0.0, //
    0.0, 1.0, -1.0, 8.0, -8.0, 1.0,
];

/// The (G, Bᵀ, Aᵀ) triple for output-tile size `m`.  Panics (with the
/// same `winograd F(` prefix every domain panic in this module carries)
/// when `m` has no kernel.
fn tables(m: usize) -> (&'static [f32], &'static [f32], &'static [f32]) {
    match m {
        2 => (&G2, &BT2, &AT2),
        4 => (&G4, &BT4, &AT4),
        other => panic!(
            "winograd F(mxm,3x3) supports m in {{2, 4}}, got m={other}"
        ),
    }
}

/// `out = l @ x @ lᵀ` for a row-major `lr×lc` transform matrix `l` and
/// a square `lc×lc` tile `x` — the one stencil shared by the filter
/// (`G g Gᵀ`), input (`Bᵀ d B`), and inverse (`Aᵀ M A`) transforms.
/// `tmp` holds the `lr×lc` intermediate; `out` receives `lr×lr`.
/// Accumulation order is ascending-k (pinned by the decomposition
/// fixture in `tests/wino_decomp.rs`).
fn congruence(
    l: &[f32],
    lr: usize,
    lc: usize,
    x: &[f32],
    tmp: &mut [f32],
    out: &mut [f32],
) {
    debug_assert_eq!(l.len(), lr * lc);
    debug_assert_eq!(x.len(), lc * lc);
    for i in 0..lr {
        for j in 0..lc {
            let mut acc = 0.0f32;
            for k in 0..lc {
                acc += l[i * lc + k] * x[k * lc + j];
            }
            tmp[i * lc + j] = acc;
        }
    }
    for i in 0..lr {
        for j in 0..lr {
            let mut acc = 0.0f32;
            for k in 0..lc {
                acc += tmp[i * lc + k] * l[j * lc + k];
            }
            out[i * lr + j] = acc;
        }
    }
}

/// Tile grid of the output plane under F(m×m, 3×3):
/// `(tiles_h, tiles_w) = (ceil(out_h / m), ceil(out_w / m))`.  The
/// last row/column of tiles may be ragged; the gather clips them.
pub fn winograd_tiles(s: &Conv2dShape, m: usize) -> (usize, usize) {
    (s.out_h.div_ceil(m), s.out_w.div_ceil(m))
}

/// Transform every filter once: `U[pos][c * out_c + k] = (G g_{c,k}
/// Gᵀ)[pos]` for the `(m+2)²` transform-domain positions (RSCK filter
/// layout in, position-major out).  Each `U[pos]` slice is the
/// row-major `in_c × out_c` right-hand operand of that position's GEMM.
pub fn transform_filters(f: &[f32], s: &Conv2dShape, m: usize) -> Vec<f32> {
    let t = m + 2;
    let mut u = vec![0.0f32; t * t * s.in_c * s.out_c];
    transform_filters_into(f, s, m, &mut u);
    u
}

/// [`transform_filters`] writing into a caller (arena) buffer of length
/// `(m+2)² * in_c * out_c`.  Every element is overwritten, so the
/// buffer's prior contents are irrelevant.
fn transform_filters_into(f: &[f32], s: &Conv2dShape, m: usize, u: &mut [f32]) {
    let (g_mat, _, _) = tables(m);
    let t = m + 2;
    let (ci, co) = (s.in_c, s.out_c);
    debug_assert_eq!(u.len(), t * t * ci * co);
    // t ≤ 6, so the congruence temps fit fixed stack arrays sliced to
    // size — no per-call allocation.
    let mut g = [0.0f32; 9];
    let mut tmp = [0.0f32; 18]; // t * 3
    let mut ut = [0.0f32; 36]; // t * t
    for c in 0..ci {
        for k in 0..co {
            for (tap, gv) in g.iter_mut().enumerate() {
                // f is RSCK: tap = r * 3 + sw.
                *gv = f[(tap * ci + c) * co + k];
            }
            congruence(g_mat, t, 3, &g, &mut tmp[..t * 3], &mut ut[..t * t]);
            for (pos, uv) in ut[..t * t].iter().enumerate() {
                u[pos * ci * co + c * co + k] = *uv;
            }
        }
    }
}

/// Scatter the input into the transform domain: `V[pos][tile * in_c +
/// c] = (Bᵀ d_{tile,c} B)[pos]`, where `d` is the `(m+2)×(m+2)` input
/// patch of `tile = (b * tiles_h + ty) * tiles_w + tx` (consecutive
/// tiles overlap by 2 rows/columns; out-of-bounds taps are the SAME/
/// VALID zero padding).  Each `V[pos]` slice is the row-major
/// `tiles × in_c` left-hand operand of that position's GEMM.
pub fn scatter_input(x: &[f32], s: &Conv2dShape, m: usize) -> Vec<f32> {
    let t = m + 2;
    let (tiles_h, tiles_w) = winograd_tiles(s, m);
    let tiles = s.batch * tiles_h * tiles_w;
    let mut v = vec![0.0f32; t * t * tiles * s.in_c];
    scatter_input_into(x, s, m, &mut v);
    v
}

/// [`scatter_input`] writing into a caller (arena) buffer of length
/// `(m+2)² * tiles * in_c`.  Every element is overwritten (out-of-bounds
/// taps contribute explicit zeros), so the buffer's prior contents are
/// irrelevant.
fn scatter_input_into(x: &[f32], s: &Conv2dShape, m: usize, v: &mut [f32]) {
    let (_, bt, _) = tables(m);
    let t = m + 2;
    let ci = s.in_c;
    let (tiles_h, tiles_w) = winograd_tiles(s, m);
    let tiles = s.batch * tiles_h * tiles_w;
    debug_assert_eq!(v.len(), t * t * tiles * ci);
    let mut d = [0.0f32; 36]; // t * t, t ≤ 6
    let mut tmp = [0.0f32; 36];
    let mut vt = [0.0f32; 36];
    let (d, tmp, vt) =
        (&mut d[..t * t], &mut tmp[..t * t], &mut vt[..t * t]);
    for b in 0..s.batch {
        for ty in 0..tiles_h {
            let ih0 = (m * ty) as isize - s.pad_top as isize;
            for tx in 0..tiles_w {
                let iw0 = (m * tx) as isize - s.pad_left as isize;
                let tile = (b * tiles_h + ty) * tiles_w + tx;
                for c in 0..ci {
                    for dy in 0..t {
                        let ih = ih0 + dy as isize;
                        for dx in 0..t {
                            let iw = iw0 + dx as isize;
                            d[t * dy + dx] = if ih < 0
                                || ih as usize >= s.in_h
                                || iw < 0
                                || iw as usize >= s.in_w
                            {
                                0.0
                            } else {
                                x[((b * s.in_h + ih as usize) * s.in_w
                                    + iw as usize)
                                    * ci
                                    + c]
                            };
                        }
                    }
                    congruence(bt, t, t, d, tmp, vt);
                    for (pos, vv) in vt.iter().enumerate() {
                        v[pos * tiles * ci + tile * ci + c] = *vv;
                    }
                }
            }
        }
    }
}

/// Gather one `(batch, tile-row)` band: inverse-transform the
/// transform-domain products `mmat[pos * tiles * out_c + tile * out_c
/// + k]` for batch `b`, tile row `ty` into output rows `[r0, r0 +
/// band_rows)` of `out_band` (the band's disjoint slice of the NHWK
/// output), clipping ragged bottom/right tiles.  Shared verbatim by
/// the serial and parallel paths, so the two are bit-identical by
/// construction.
#[allow(clippy::too_many_arguments)]
fn gather_band(
    mmat: &[f32],
    s: &Conv2dShape,
    m: usize,
    tiles_h: usize,
    tiles_w: usize,
    b: usize,
    ty: usize,
    r0: usize,
    out_band: &mut [f32],
    mtile: &mut [f32],
    tmp: &mut [f32],
    ytile: &mut [f32],
) {
    let (_, _, at) = tables(m);
    let t = m + 2;
    let co = s.out_c;
    let tiles = s.batch * tiles_h * tiles_w;
    for tx in 0..tiles_w {
        let tile = (b * tiles_h + ty) * tiles_w + tx;
        for k in 0..co {
            for (pos, mv) in mtile.iter_mut().enumerate() {
                *mv = mmat[pos * tiles * co + tile * co + k];
            }
            congruence(at, m, t, mtile, tmp, ytile);
            for dy in 0..m {
                let oh = m * ty + dy;
                if oh >= s.out_h {
                    break;
                }
                for dx in 0..m {
                    let ow = m * tx + dx;
                    if ow >= s.out_w {
                        break;
                    }
                    out_band[((oh - r0) * s.out_w + ow) * co + k] =
                        ytile[m * dy + dx];
                }
            }
        }
    }
}

/// Convolution by Winograd F(`wino_m`×`wino_m`, 3×3), `wino_m ∈ {2,
/// 4}`, lowered as scatter → `(wino_m+2)²` batched transform-domain
/// GEMMs → gather.  The GEMMs run through
/// [`gemm_batched_isa`](super::gemm_batched_isa) under `params` and
/// `isa` — the tuned blocking, `threads`, and SIMD micro-kernel axis of
/// the plan's `GemmPoint` ladder — so 3×3 convs inherit the whole
/// tuned GEMM stack.
///
/// Panics unless [`winograd_supports`] accepts the shape and `wino_m`
/// has a kernel — callers wanting automatic fallback go through
/// [`conv2d_native`](super::conv2d_native).  Every thread count
/// produces bit-identical output (see the module docs); `isa` must be
/// available on the executing host, exactly as for
/// [`gemm_blocked_isa`](super::gemm_blocked_isa).
pub fn conv2d_winograd(
    x: &[f32],
    f: &[f32],
    s: &Conv2dShape,
    wino_m: usize,
    params: &BlockedParams,
    isa: Isa,
) -> Vec<f32> {
    conv2d_winograd_ex(x, f, s, wino_m, params, isa, Pack::A, &Scratch::new())
}

/// [`conv2d_winograd`] with the plan's packing strategy and workspace
/// arena.  `Pack::Ab` packs each transform position's `U` panel once
/// per call and reuses it across that position's GEMM row bands; the
/// `U`/`V`/`M` transform matrices and all GEMM packing buffers check
/// out of `scratch`, so a prewarmed arena makes the call
/// allocation-free.  Bit-identical to [`conv2d_winograd`] for every
/// `pack` (the packed micro-kernels preserve accumulation order).
#[allow(clippy::too_many_arguments)]
pub fn conv2d_winograd_ex(
    x: &[f32],
    f: &[f32],
    s: &Conv2dShape,
    wino_m: usize,
    params: &BlockedParams,
    isa: Isa,
    pack: Pack,
    scratch: &Scratch,
) -> Vec<f32> {
    assert_eq!(x.len(), s.input_elems(), "input shape mismatch");
    assert_eq!(f.len(), s.filter_elems(), "filter shape mismatch");
    assert!(
        winograd_supports(s),
        "winograd F({wino_m}x{wino_m},3x3) needs window 3 / stride 1, \
         got {s:?}"
    );
    let (ci, co) = (s.in_c, s.out_c);
    let t = wino_m + 2;
    let _ = tables(wino_m); // loud domain panic before any allocation
    let mut out = vec![0.0f32; s.output_elems()];
    if s.output_elems() == 0 || ci == 0 {
        return out;
    }

    // Scatter + filter transform, then the (m+2)² batched GEMMs
    // M[pos] (tiles × co) = V[pos] (tiles × ci) @ U[pos] (ci × co).
    // U/V/M live in the arena; the _into transforms overwrite every
    // element, and take_f32 hands back zeroed storage so mmat satisfies
    // gemm_batched_into's pre-zeroed-output contract.
    let (tiles_h, tiles_w) = winograd_tiles(s, wino_m);
    let tiles = s.batch * tiles_h * tiles_w;
    let mut u = scratch.take_f32(t * t * ci * co);
    transform_filters_into(f, s, wino_m, &mut u);
    let mut v = scratch.take_f32(t * t * tiles * ci);
    scatter_input_into(x, s, wino_m, &mut v);
    let mut mmat = scratch.take_f32(t * t * tiles * co);
    gemm_batched_into(
        &v, &u, &mut mmat, t * t, tiles, co, ci, params, isa, pack, scratch,
    );
    scratch.put_f32(v);
    scratch.put_f32(u);

    // Gather: one disjoint output slice per (batch, tile-row) band.
    // Bands are `wino_m` output rows except the last of each batch when
    // out_h is ragged, so the split is computed, not chunked.
    let mut bands: Vec<(usize, usize, usize, &mut [f32])> = Vec::new();
    {
        let mut rest: &mut [f32] = &mut out;
        for b in 0..s.batch {
            for ty in 0..tiles_h {
                let r0 = wino_m * ty;
                let rows = (r0 + wino_m).min(s.out_h) - r0;
                let (band, tail) = std::mem::take(&mut rest)
                    .split_at_mut(rows * s.out_w * co);
                bands.push((b, ty, r0, band));
                rest = tail;
            }
        }
        debug_assert!(rest.is_empty());
    }

    // Per-band congruence temps are fixed stack arrays sliced to size
    // (t ≤ 6), so the gather allocates nothing on either path.
    let workers = pool::resolve_threads(params.threads);
    if workers <= 1 || bands.len() <= 1 {
        let mut mtile = [0.0f32; 36]; // t * t
        let mut tmp = [0.0f32; 24]; // wino_m * t
        let mut ytile = [0.0f32; 16]; // wino_m * wino_m
        for (b, ty, r0, band) in bands {
            gather_band(
                &mmat,
                s,
                wino_m,
                tiles_h,
                tiles_w,
                b,
                ty,
                r0,
                band,
                &mut mtile[..t * t],
                &mut tmp[..wino_m * t],
                &mut ytile[..wino_m * wino_m],
            );
        }
    } else {
        pool::run_parallel(workers, bands, |_, (b, ty, r0, band)| {
            let mut mtile = [0.0f32; 36];
            let mut tmp = [0.0f32; 24];
            let mut ytile = [0.0f32; 16];
            gather_band(
                &mmat,
                s,
                wino_m,
                tiles_h,
                tiles_w,
                b,
                ty,
                r0,
                band,
                &mut mtile[..t * t],
                &mut tmp[..wino_m * t],
                &mut ytile[..wino_m * wino_m],
            );
        });
    }
    scratch.put_f32(mmat);
    out
}

/// Worst-case arena demand of one [`conv2d_winograd_ex`] call: the
/// batched transform-domain GEMM's workspace plus the `U`/`V`/`M`
/// transform matrices.  [`Workspace::none`] for shapes or tile sizes
/// the kernel cannot compute (callers resolve fallback through
/// [`native_conv_algorithm`](super::native_conv_algorithm) before
/// sizing) and for degenerate shapes that return early.
pub fn conv2d_winograd_workspace(
    s: &Conv2dShape,
    wino_m: usize,
    params: &BlockedParams,
    pack: Pack,
) -> Workspace {
    if !winograd_supports(s) || !matches!(wino_m, 2 | 4) {
        return Workspace::none();
    }
    let (ci, co) = (s.in_c, s.out_c);
    if s.output_elems() == 0 || ci == 0 {
        return Workspace::none();
    }
    let t = wino_m + 2;
    let (tiles_h, tiles_w) = winograd_tiles(s, wino_m);
    let tiles = s.batch * tiles_h * tiles_w;
    let mut ws = gemm_batched_workspace(t * t, tiles, co, ci, params, pack);
    ws.f32_lens.push(t * t * ci * co); // U
    ws.f32_lens.push(t * t * tiles * ci); // V
    ws.f32_lens.push(t * t * tiles * co); // M
    ws
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blas::{conv2d_direct, max_abs_diff};
    use crate::util::rng::XorShift;

    fn rand(n: usize, seed: u64) -> Vec<f32> {
        XorShift::new(seed).f32_vec(n)
    }

    fn serial_params() -> BlockedParams {
        BlockedParams { threads: 1, ..BlockedParams::default() }
    }

    fn check_against_direct(s: &Conv2dShape, m: usize, seed: u64) {
        let x = rand(s.input_elems(), seed);
        let f = rand(s.filter_elems(), seed + 1);
        let direct = conv2d_direct(&x, &f, s);
        let wino =
            conv2d_winograd(&x, &f, s, m, &serial_params(), Isa::Scalar);
        // F(4×4) amplifies rounding through its larger-magnitude
        // transforms; both bounds are far above observed error (the
        // proptest suite pins the relative contract).
        let tol = if m == 2 { 1e-3 } else { 5e-3 };
        assert!(max_abs_diff(&direct, &wino) < tol, "m={m} {s:?}");
    }

    #[test]
    fn matches_direct_on_same_padding() {
        for &(b, h, w, c, k) in &[
            (1usize, 8usize, 8usize, 3usize, 4usize),
            (2, 9, 7, 2, 5),  // odd spatial: ragged bottom/right tiles
            (1, 4, 4, 8, 8),
            (3, 6, 10, 1, 1), // degenerate channels
        ] {
            for m in [2usize, 4] {
                check_against_direct(
                    &Conv2dShape::same(b, h, w, c, k, 3, 1),
                    m,
                    1,
                );
            }
        }
    }

    #[test]
    fn matches_direct_on_valid_padding() {
        // No padding: interior tiles only, plus ragged edges.
        for m in [2usize, 4] {
            check_against_direct(&Conv2dShape::valid(2, 11, 9, 3, 4, 3, 1), m, 5);
            check_against_direct(&Conv2dShape::valid(1, 3, 3, 2, 3, 3, 1), m, 6);
        }
    }

    #[test]
    fn single_pixel_output_works() {
        // VALID 3x3 on a 3x3 input: one output pixel (fully ragged tile
        // for both tile sizes).
        let s = Conv2dShape::valid(1, 3, 3, 4, 2, 3, 1);
        assert_eq!((s.out_h, s.out_w), (1, 1));
        for m in [2usize, 4] {
            check_against_direct(&s, m, 9);
        }
    }

    #[test]
    fn threaded_is_bit_identical_to_serial() {
        for &(b, h, w, c, k) in &[
            (2usize, 9usize, 7usize, 3usize, 4usize),
            (1, 1, 5, 2, 3), // out_h 1: one ragged tile row per batch
            (3, 4, 4, 1, 2),
        ] {
            let s = Conv2dShape::same(b, h, w, c, k, 3, 1);
            let x = rand(s.input_elems(), 11);
            let f = rand(s.filter_elems(), 12);
            for m in [2usize, 4] {
                let serial = conv2d_winograd(
                    &x,
                    &f,
                    &s,
                    m,
                    &serial_params(),
                    Isa::Scalar,
                );
                for threads in [0usize, 2, 3, 8, 64] {
                    let params =
                        BlockedParams { threads, ..BlockedParams::default() };
                    let par = conv2d_winograd(
                        &x,
                        &f,
                        &s,
                        m,
                        &params,
                        Isa::Scalar,
                    );
                    assert!(
                        serial == par,
                        "m={m} threads={threads} diverged on {s:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn detected_isas_agree_with_scalar() {
        // The ISA axis reaches the transform-domain GEMMs: SSE2/AVX2
        // are bit-identical to scalar, FMA within an accumulation
        // tolerance of the in_c-deep contraction.
        let s = Conv2dShape::same(2, 9, 7, 5, 4, 3, 1);
        let x = rand(s.input_elems(), 31);
        let f = rand(s.filter_elems(), 32);
        let params =
            BlockedParams { bm: 8, bn: 8, bk: 4, mr: 2, nr: 4, threads: 1 };
        for m in [2usize, 4] {
            let scalar = conv2d_winograd(&x, &f, &s, m, &params, Isa::Scalar);
            for isa in Isa::detect() {
                let got = conv2d_winograd(&x, &f, &s, m, &params, isa);
                if isa == Isa::Fma {
                    assert!(
                        max_abs_diff(&scalar, &got) <= 1e-5,
                        "m={m} fma beyond tolerance"
                    );
                } else {
                    assert!(
                        scalar == got,
                        "m={m} {isa} not bit-identical to scalar"
                    );
                }
            }
        }
    }

    #[test]
    fn scatter_layout_is_position_major() {
        // V[pos] must be the row-major (tiles × ci) GEMM operand: an
        // all-ones single-channel input puts the same transformed patch
        // in every interior tile slot of each position slice.
        let s = Conv2dShape::valid(1, 6, 6, 1, 1, 3, 1);
        let (th, tw) = winograd_tiles(&s, 2);
        assert_eq!((th, tw), (2, 2));
        let x = vec![1.0f32; s.input_elems()];
        let v = scatter_input(&x, &s, 2);
        let tiles = th * tw;
        assert_eq!(v.len(), 16 * tiles);
        for pos in 0..16 {
            let slice = &v[pos * tiles..(pos + 1) * tiles];
            for tile in 1..tiles {
                assert_eq!(
                    slice[tile], slice[0],
                    "pos {pos} tile {tile}: interior tiles must agree"
                );
            }
        }
    }

    #[test]
    fn support_predicate_matches_the_kernel_domain() {
        assert!(winograd_supports(&Conv2dShape::same(1, 8, 8, 2, 2, 3, 1)));
        assert!(!winograd_supports(&Conv2dShape::same(1, 8, 8, 2, 2, 3, 2)));
        assert!(!winograd_supports(&Conv2dShape::same(1, 8, 8, 2, 2, 1, 1)));
        assert!(!winograd_supports(&Conv2dShape::same(1, 8, 8, 2, 2, 5, 1)));
    }

    #[test]
    #[should_panic(expected = "winograd F(")]
    fn unsupported_shape_is_a_loud_panic() {
        let s = Conv2dShape::same(1, 4, 4, 1, 1, 5, 1);
        let x = vec![0.0; s.input_elems()];
        let f = vec![0.0; s.filter_elems()];
        conv2d_winograd(&x, &f, &s, 2, &serial_params(), Isa::Scalar);
    }

    #[test]
    #[should_panic(expected = "winograd F(")]
    fn unsupported_tile_size_is_a_loud_panic() {
        let s = Conv2dShape::same(1, 4, 4, 1, 1, 3, 1);
        let x = vec![0.0; s.input_elems()];
        let f = vec![0.0; s.filter_elems()];
        conv2d_winograd(&x, &f, &s, 3, &serial_params(), Isa::Scalar);
    }

    #[test]
    fn identity_like_filter_center_tap() {
        // A filter with only the center tap set to 1 for c==k passes the
        // input through (interior pixels exactly, borders via padding).
        let c = 3;
        let s = Conv2dShape::same(1, 6, 6, c, c, 3, 1);
        let x = rand(s.input_elems(), 21);
        let mut f = vec![0.0f32; s.filter_elems()];
        for ch in 0..c {
            // center tap index r * 3 + sw with r = sw = 1.
            f[(4 * c + ch) * c + ch] = 1.0;
        }
        for m in [2usize, 4] {
            let out =
                conv2d_winograd(&x, &f, &s, m, &serial_params(), Isa::Scalar);
            let tol = if m == 2 { 1e-4 } else { 1e-3 };
            assert!(max_abs_diff(&out, &x) < tol, "m={m}");
        }
    }

    #[test]
    fn packed_b_is_bit_identical_across_isas_and_threads() {
        // Pack::Ab must not perturb a single bit relative to Pack::A on
        // any detected ISA (including FMA: packed-FMA mirrors
        // unpacked-FMA's fused order) or thread count — the transform
        // GEMMs' packed micro-kernels preserve accumulation order.
        for &(b, h, w, c, k) in
            &[(2usize, 9usize, 7usize, 3usize, 4usize), (1, 4, 4, 5, 2)]
        {
            let s = Conv2dShape::same(b, h, w, c, k, 3, 1);
            let x = rand(s.input_elems(), 41);
            let f = rand(s.filter_elems(), 42);
            let params =
                BlockedParams { bm: 8, bn: 8, bk: 4, mr: 2, nr: 4, threads: 1 };
            for m in [2usize, 4] {
                for isa in Isa::detect() {
                    for threads in [1usize, 0, 3] {
                        let p = BlockedParams { threads, ..params };
                        let scratch = Scratch::new();
                        let unpacked = conv2d_winograd_ex(
                            &x, &f, &s, m, &p, isa, Pack::A, &scratch,
                        );
                        let packed = conv2d_winograd_ex(
                            &x, &f, &s, m, &p, isa, Pack::Ab, &scratch,
                        );
                        assert!(
                            unpacked == packed,
                            "m={m} {isa} threads={threads} pack diverged"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn workspace_prewarm_makes_calls_allocation_free() {
        let s = Conv2dShape::same(2, 9, 7, 3, 4, 3, 1);
        let x = rand(s.input_elems(), 51);
        let f = rand(s.filter_elems(), 52);
        let params =
            BlockedParams { bm: 8, bn: 8, bk: 4, mr: 2, nr: 4, threads: 3 };
        for m in [2usize, 4] {
            for pack in Pack::all() {
                let ws = conv2d_winograd_workspace(&s, m, &params, pack);
                assert!(ws.bytes() > 0, "m={m} {pack} sized an empty workspace");
                let scratch = Scratch::new();
                scratch.prewarm(&ws);
                let grows_before = scratch.stats().grows;
                for _ in 0..3 {
                    let _ = conv2d_winograd_ex(
                        &x, &f, &s, m, &params, Isa::Scalar, pack, &scratch,
                    );
                }
                assert_eq!(
                    scratch.stats().grows,
                    grows_before,
                    "m={m} {pack}: prewarmed arena still grew"
                );
            }
        }
        // Degenerate and unsupported shapes size to none.
        let empty = Conv2dShape::same(0, 9, 7, 3, 4, 3, 1);
        assert_eq!(
            conv2d_winograd_workspace(&empty, 2, &params, Pack::Ab).bytes(),
            0
        );
        let strided = Conv2dShape::same(1, 8, 8, 2, 2, 3, 2);
        assert_eq!(
            conv2d_winograd_workspace(&strided, 2, &params, Pack::Ab).bytes(),
            0
        );
        assert_eq!(
            conv2d_winograd_workspace(&s, 3, &params, Pack::Ab).bytes(),
            0
        );
    }
}
