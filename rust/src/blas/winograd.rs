//! Native Winograd F(2×2, 3×3) convolution — the paper's §4.1.2 fast
//! algorithm played on the host, so conv-algorithm selection (tiled vs
//! im2col vs winograd) can be *measured* natively instead of only through
//! PJRT.
//!
//! The Cook-Toom construction (Lavin & Gray, arXiv:1509.09308): each
//! 2×2 output tile is computed from a 4×4 input tile in the transform
//! domain — `Y = Aᵀ[(G g Gᵀ) ⊙ (Bᵀ d B)]A` — replacing the 36
//! multiplies of the direct 3×3 computation with 16, at the cost of the
//! (cheap, addition-only) transforms.  Filters are transformed once per
//! call; per-tile work is the input transform, a channel-contraction at
//! each of the 16 transform-domain positions, and the inverse transform.
//!
//! Parallelism follows the crate discipline: the parallel unit is one
//! `(batch, tile-row)` band of the output, each worker owns a disjoint
//! `&mut` slice and runs the exact serial per-band code, so results are
//! bit-identical to serial for every thread count.  Winograd output is
//! *not* bit-identical to im2col/direct — it is a different
//! factorization — but agrees within floating-point tolerance
//! (proptested in `tests/proptests.rs`).

use super::conv::Conv2dShape;
use crate::util::pool;

/// Whether the native Winograd kernel can compute this shape:
/// F(2×2, 3×3) covers 3×3 windows at stride 1 (any padding).  Delegates
/// to [`ConvAlgorithm::supports`](crate::config::ConvAlgorithm::supports)
/// so the kernel domain has exactly one definition.
pub fn winograd_supports(s: &Conv2dShape) -> bool {
    crate::config::ConvAlgorithm::Winograd
        .supports(s.window as u32, s.stride as u32)
}

/// Transform one 3×3 filter tap matrix `g` (for a fixed (c, k) pair) to
/// the 4×4 transform domain: `U = G g Gᵀ`.
#[inline]
fn filter_transform(g: &[f32; 9]) -> [f32; 16] {
    // t = G g (4x3), with G = [[1,0,0],[.5,.5,.5],[.5,-.5,.5],[0,0,1]].
    let mut t = [0.0f32; 12];
    for j in 0..3 {
        let (g0, g1, g2) = (g[j], g[3 + j], g[6 + j]);
        t[j] = g0;
        t[3 + j] = 0.5 * (g0 + g1 + g2);
        t[6 + j] = 0.5 * (g0 - g1 + g2);
        t[9 + j] = g2;
    }
    // U = t Gᵀ (4x4): same stencil applied along rows.
    let mut u = [0.0f32; 16];
    for r in 0..4 {
        let (t0, t1, t2) = (t[3 * r], t[3 * r + 1], t[3 * r + 2]);
        u[4 * r] = t0;
        u[4 * r + 1] = 0.5 * (t0 + t1 + t2);
        u[4 * r + 2] = 0.5 * (t0 - t1 + t2);
        u[4 * r + 3] = t2;
    }
    u
}

/// Transform one 4×4 input tile `d` to the transform domain:
/// `V = Bᵀ d B`, with `Bᵀ = [[1,0,-1,0],[0,1,1,0],[0,-1,1,0],[0,1,0,-1]]`.
#[inline]
fn input_transform(d: &[f32; 16]) -> [f32; 16] {
    // t = Bᵀ d (rows).
    let mut t = [0.0f32; 16];
    for j in 0..4 {
        let (d0, d1, d2, d3) = (d[j], d[4 + j], d[8 + j], d[12 + j]);
        t[j] = d0 - d2;
        t[4 + j] = d1 + d2;
        t[8 + j] = d2 - d1;
        t[12 + j] = d1 - d3;
    }
    // V = t B (columns): the same stencil along each row.
    let mut v = [0.0f32; 16];
    for r in 0..4 {
        let (t0, t1, t2, t3) =
            (t[4 * r], t[4 * r + 1], t[4 * r + 2], t[4 * r + 3]);
        v[4 * r] = t0 - t2;
        v[4 * r + 1] = t1 + t2;
        v[4 * r + 2] = t2 - t1;
        v[4 * r + 3] = t1 - t3;
    }
    v
}

/// Inverse-transform one 4×4 transform-domain tile `m` to the 2×2
/// output tile: `Y = Aᵀ m A`, with `Aᵀ = [[1,1,1,0],[0,1,-1,-1]]`.
#[inline]
fn output_transform(m: &[f32; 16]) -> [f32; 4] {
    // t = Aᵀ m (2x4).
    let mut t = [0.0f32; 8];
    for j in 0..4 {
        let (m0, m1, m2, m3) = (m[j], m[4 + j], m[8 + j], m[12 + j]);
        t[j] = m0 + m1 + m2;
        t[4 + j] = m1 - m2 - m3;
    }
    // Y = t A (2x2).
    let mut y = [0.0f32; 4];
    for r in 0..2 {
        let (t0, t1, t2, t3) =
            (t[4 * r], t[4 * r + 1], t[4 * r + 2], t[4 * r + 3]);
        y[2 * r] = t0 + t1 + t2;
        y[2 * r + 1] = t1 - t2 - t3;
    }
    y
}

/// Transform every filter once: `u[pos][c * out_c + k]` for the 16
/// transform-domain positions (RSCK filter layout in, position-major
/// out — the layout the per-tile channel contraction streams through).
fn transform_filters(f: &[f32], s: &Conv2dShape) -> Vec<f32> {
    let (ci, co) = (s.in_c, s.out_c);
    let mut u = vec![0.0f32; 16 * ci * co];
    let mut g = [0.0f32; 9];
    for c in 0..ci {
        for k in 0..co {
            for (tap, gv) in g.iter_mut().enumerate() {
                // f is RSCK: tap = r * 3 + sw.
                *gv = f[(tap * ci + c) * co + k];
            }
            let ut = filter_transform(&g);
            for (pos, uv) in ut.iter().enumerate() {
                u[pos * ci * co + c * co + k] = *uv;
            }
        }
    }
    u
}

/// One `(batch, tile-row)` band: compute output rows `[r0, r1)` of batch
/// `b` into `out_band` (the band's disjoint slice of the output, row-major
/// NHWK with `r0` as its first row).  Shared verbatim by the serial and
/// parallel paths, so the two are bit-identical by construction.
#[allow(clippy::too_many_arguments)]
fn winograd_band(
    x: &[f32],
    u: &[f32],
    s: &Conv2dShape,
    b: usize,
    ty: usize,
    r0: usize,
    out_band: &mut [f32],
    vbuf: &mut [f32],
    mbuf: &mut [f32],
) {
    let (ci, co) = (s.in_c, s.out_c);
    let tiles_w = s.out_w.div_ceil(2);
    let ih0 = (2 * ty) as isize - s.pad_top as isize;
    for tx in 0..tiles_w {
        let iw0 = (2 * tx) as isize - s.pad_left as isize;
        // Input transform per channel: vbuf[pos * ci + c].
        let mut d = [0.0f32; 16];
        for c in 0..ci {
            for dy in 0..4 {
                let ih = ih0 + dy as isize;
                for dx in 0..4 {
                    let iw = iw0 + dx as isize;
                    d[4 * dy + dx] = if ih < 0
                        || ih as usize >= s.in_h
                        || iw < 0
                        || iw as usize >= s.in_w
                    {
                        0.0
                    } else {
                        x[((b * s.in_h + ih as usize) * s.in_w
                            + iw as usize)
                            * ci
                            + c]
                    };
                }
            }
            let v = input_transform(&d);
            for (pos, vv) in v.iter().enumerate() {
                vbuf[pos * ci + c] = *vv;
            }
        }
        // Channel contraction at each transform-domain position:
        // mbuf[pos * co + k] = Σ_c vbuf[pos][c] * u[pos][c][k].
        mbuf.fill(0.0);
        for pos in 0..16 {
            let urow = &u[pos * ci * co..(pos + 1) * ci * co];
            let mrow = &mut mbuf[pos * co..(pos + 1) * co];
            for c in 0..ci {
                let vv = vbuf[pos * ci + c];
                let uk = &urow[c * co..(c + 1) * co];
                for (mv, uv) in mrow.iter_mut().zip(uk) {
                    *mv += vv * uv;
                }
            }
        }
        // Inverse transform per output channel, clipped to the ragged
        // bottom/right edge.
        let mut m = [0.0f32; 16];
        for k in 0..co {
            for (pos, mv) in m.iter_mut().enumerate() {
                *mv = mbuf[pos * co + k];
            }
            let y = output_transform(&m);
            for dy in 0..2 {
                let oh = 2 * ty + dy;
                if oh >= s.out_h {
                    break;
                }
                for dx in 0..2 {
                    let ow = 2 * tx + dx;
                    if ow >= s.out_w {
                        break;
                    }
                    out_band[((oh - r0) * s.out_w + ow) * co + k] =
                        y[2 * dy + dx];
                }
            }
        }
    }
}

/// Convolution by Winograd F(2×2, 3×3).  Panics unless
/// [`winograd_supports`] accepts the shape — callers wanting automatic
/// fallback go through [`conv2d_native`](super::conv2d_native).
/// `threads` follows the [`BlockedParams::threads`] convention (`0` =
/// all cores, `1` = serial); every thread count produces bit-identical
/// output.
///
/// [`BlockedParams::threads`]: super::BlockedParams::threads
pub fn conv2d_winograd(
    x: &[f32],
    f: &[f32],
    s: &Conv2dShape,
    threads: usize,
) -> Vec<f32> {
    assert_eq!(x.len(), s.input_elems(), "input shape mismatch");
    assert_eq!(f.len(), s.filter_elems(), "filter shape mismatch");
    assert!(
        winograd_supports(s),
        "winograd F(2x2,3x3) needs window 3 / stride 1, got {s:?}"
    );
    let (ci, co) = (s.in_c, s.out_c);
    let mut out = vec![0.0f32; s.output_elems()];
    if s.output_elems() == 0 || ci == 0 {
        return out;
    }
    let u = transform_filters(f, s);
    let tiles_h = s.out_h.div_ceil(2);

    // Split the output into one disjoint slice per (batch, tile-row)
    // band.  Bands are 2 output rows except the last of each batch when
    // out_h is odd, so the split is computed, not chunked.
    let mut bands: Vec<(usize, usize, usize, &mut [f32])> = Vec::new();
    {
        let mut rest: &mut [f32] = &mut out;
        for b in 0..s.batch {
            for ty in 0..tiles_h {
                let r0 = 2 * ty;
                let rows = (r0 + 2).min(s.out_h) - r0;
                let (band, tail) = std::mem::take(&mut rest)
                    .split_at_mut(rows * s.out_w * co);
                bands.push((b, ty, r0, band));
                rest = tail;
            }
        }
        debug_assert!(rest.is_empty());
    }

    let workers = pool::resolve_threads(threads);
    if workers <= 1 || bands.len() <= 1 {
        let mut vbuf = vec![0.0f32; 16 * ci];
        let mut mbuf = vec![0.0f32; 16 * co];
        for (b, ty, r0, band) in bands {
            winograd_band(x, &u, s, b, ty, r0, band, &mut vbuf, &mut mbuf);
        }
    } else {
        pool::run_parallel(workers, bands, |_, (b, ty, r0, band)| {
            let mut vbuf = vec![0.0f32; 16 * ci];
            let mut mbuf = vec![0.0f32; 16 * co];
            winograd_band(x, &u, s, b, ty, r0, band, &mut vbuf, &mut mbuf);
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blas::{conv2d_direct, max_abs_diff};
    use crate::util::rng::XorShift;

    fn rand(n: usize, seed: u64) -> Vec<f32> {
        XorShift::new(seed).f32_vec(n)
    }

    fn check_against_direct(s: &Conv2dShape, seed: u64) {
        let x = rand(s.input_elems(), seed);
        let f = rand(s.filter_elems(), seed + 1);
        let direct = conv2d_direct(&x, &f, s);
        let wino = conv2d_winograd(&x, &f, s, 1);
        assert!(max_abs_diff(&direct, &wino) < 1e-3, "{s:?}");
    }

    #[test]
    fn matches_direct_on_same_padding() {
        for &(b, h, w, c, k) in &[
            (1usize, 8usize, 8usize, 3usize, 4usize),
            (2, 9, 7, 2, 5),  // odd spatial: ragged bottom/right tiles
            (1, 4, 4, 8, 8),
            (3, 6, 10, 1, 1), // degenerate channels
        ] {
            check_against_direct(&Conv2dShape::same(b, h, w, c, k, 3, 1), 1);
        }
    }

    #[test]
    fn matches_direct_on_valid_padding() {
        // No padding: interior tiles only, plus ragged edges.
        check_against_direct(&Conv2dShape::valid(2, 11, 9, 3, 4, 3, 1), 5);
        check_against_direct(&Conv2dShape::valid(1, 3, 3, 2, 3, 3, 1), 6);
    }

    #[test]
    fn single_pixel_output_works() {
        // VALID 3x3 on a 3x3 input: one output pixel (ragged 2x2 tile).
        let s = Conv2dShape::valid(1, 3, 3, 4, 2, 3, 1);
        assert_eq!((s.out_h, s.out_w), (1, 1));
        check_against_direct(&s, 9);
    }

    #[test]
    fn threaded_is_bit_identical_to_serial() {
        for &(b, h, w, c, k) in &[
            (2usize, 9usize, 7usize, 3usize, 4usize),
            (1, 1, 5, 2, 3), // out_h 1: one ragged tile row per batch
            (3, 4, 4, 1, 2),
        ] {
            let s = Conv2dShape::same(b, h, w, c, k, 3, 1);
            let x = rand(s.input_elems(), 11);
            let f = rand(s.filter_elems(), 12);
            let serial = conv2d_winograd(&x, &f, &s, 1);
            for threads in [0usize, 2, 3, 8, 64] {
                let par = conv2d_winograd(&x, &f, &s, threads);
                assert!(serial == par, "threads={threads} diverged on {s:?}");
            }
        }
    }

    #[test]
    fn support_predicate_matches_the_kernel_domain() {
        assert!(winograd_supports(&Conv2dShape::same(1, 8, 8, 2, 2, 3, 1)));
        assert!(!winograd_supports(&Conv2dShape::same(1, 8, 8, 2, 2, 3, 2)));
        assert!(!winograd_supports(&Conv2dShape::same(1, 8, 8, 2, 2, 1, 1)));
        assert!(!winograd_supports(&Conv2dShape::same(1, 8, 8, 2, 2, 5, 1)));
    }

    #[test]
    #[should_panic(expected = "winograd F(2x2,3x3)")]
    fn unsupported_shape_is_a_loud_panic() {
        let s = Conv2dShape::same(1, 4, 4, 1, 1, 5, 1);
        let x = vec![0.0; s.input_elems()];
        let f = vec![0.0; s.filter_elems()];
        conv2d_winograd(&x, &f, &s, 1);
    }

    #[test]
    fn identity_like_filter_center_tap() {
        // A filter with only the center tap set to 1 for c==k passes the
        // input through (interior pixels exactly, borders via padding).
        let c = 3;
        let s = Conv2dShape::same(1, 6, 6, c, c, 3, 1);
        let x = rand(s.input_elems(), 21);
        let mut f = vec![0.0f32; s.filter_elems()];
        for ch in 0..c {
            // center tap index r * 3 + sw with r = sw = 1.
            f[(4 * c + ch) * c + ch] = 1.0;
        }
        let out = conv2d_winograd(&x, &f, &s, 1);
        assert!(max_abs_diff(&out, &x) < 1e-4);
    }
}
