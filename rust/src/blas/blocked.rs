//! Blocked/tiled host GEMM — the paper's §3.1.1 scheme on the CPU.
//!
//! The same parametrization as the device kernel (macro-tile, register
//! micro-tile, k-panel), instantiated for a cache hierarchy instead of
//! local memory: `bm x bn` macro-tiles sized for L2, `bk` panels for L1,
//! and a `4 x 4`-ish register micro-kernel the compiler can vectorize.
//! The `threads` knob adds the work-group dimension of the device kernel:
//! `bm`-row macro-tile bands are distributed over a scoped thread pool
//! ([`crate::util::pool`]), each worker owning a disjoint band of C rows,
//! so parallel results are bit-identical to the serial path.
//!
//! The micro-kernel additionally carries a runtime-dispatched **ISA
//! axis** ([`super::Isa`]): full registry tiles can run `#[target_feature]`
//! SIMD variants (`blas::simd`) selected per plan by the tuner, with the
//! scalar kernel as the bit-fallback for ragged edges, unregistered
//! shapes, and hosts without the feature.  [`gemm_blocked`] is the
//! scalar entry point; [`gemm_blocked_isa`] takes the axis explicitly.

use super::Isa;
use crate::util::pool;

/// Blocking parameters (the CPU analogue of `GemmConfig`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BlockedParams {
    /// Macro-tile rows (sized for L2).
    pub bm: usize,
    /// Macro-tile columns (sized for L2).
    pub bn: usize,
    /// K-panel depth (sized for L1).
    pub bk: usize,
    /// Register micro-tile rows.
    pub mr: usize,
    /// Register micro-tile columns.
    pub nr: usize,
    /// Worker threads over `bm`-row macro-tile bands: `0` = one per
    /// available core, `1` = the serial path.  Any value produces
    /// bit-identical results (each worker owns disjoint output rows and
    /// runs the exact serial per-band code), so `threads` is a pure
    /// throughput knob the tuner sweeps like any other parameter.
    pub threads: usize,
}

impl Default for BlockedParams {
    fn default() -> Self {
        Self { bm: 64, bn: 64, bk: 64, mr: 4, nr: 8, threads: 0 }
    }
}

impl BlockedParams {
    /// Compact config name for reports and the tuning DB
    /// (`bm64bn64bk64_4x8_t0` style; `t0` = auto threads).
    pub fn name(&self) -> String {
        format!(
            "bm{}bn{}bk{}_{}x{}_t{}",
            self.bm, self.bn, self.bk, self.mr, self.nr, self.threads
        )
    }

    /// Whether this `(mr, nr)` micro-tile has a monomorphized kernel in
    /// the registry (see [`MICRO_KERNEL_SHAPES`]).  Other shapes are
    /// still correct — they run the generic ragged-edge kernel for every
    /// tile — but leave register-tiling throughput on the table, so the
    /// tuner's grids stick to registry shapes.
    pub fn is_monomorphized(&self) -> bool {
        MICRO_KERNEL_SHAPES.contains(&(self.mr, self.nr))
    }
}

/// Generate the monomorphized micro-kernel registry: the public list of
/// `(mr, nr)` register-tile shapes with a fixed-trip-count kernel
/// ([`MICRO_KERNEL_SHAPES`]) and the dispatch that binds a full tile to
/// its monomorphized instantiation (ragged edges and unregistered shapes
/// take the generic kernel).  One macro invocation is the single source
/// of truth: the tuner's grids ([`crate::config::micro_kernel_shapes`])
/// and this dispatch can never disagree about which shapes are "fast".
macro_rules! micro_kernel_registry {
    ($(($mr:literal, $nr:literal)),+ $(,)?) => {
        /// Every `(mr, nr)` register micro-tile with a monomorphized
        /// kernel, in grid-sweep order.  `config::space` re-exports this
        /// as the legal fast set for tuner grids and validation.
        pub const MICRO_KERNEL_SHAPES: &[(usize, usize)] =
            &[$(($mr, $nr)),+];

        /// Dispatch one register tile: full tiles of a registered shape
        /// run their monomorphized kernel — for a SIMD `isa`, the
        /// matching `#[target_feature]` variant from `blas::simd` —
        /// everything else (ragged edges, unregistered shapes, and every
        /// tile on a non-x86-64 host) the generic scalar kernel, the
        /// bit-fallback of the ISA axis.  `il` is the row within the
        /// band slice `c`.
        #[allow(clippy::too_many_arguments)]
        #[inline]
        fn dispatch_micro_kernel(
            full: bool,
            mr: usize,
            nr: usize,
            isa: Isa,
            apack: &[f32],
            b: &[f32],
            c: &mut [f32],
            n: usize,
            il: usize,
            ie: usize,
            j: usize,
            je: usize,
            p0: usize,
            p1: usize,
        ) {
            match (full, mr, nr) {
                $(
                    (true, $mr, $nr) => match isa {
                        // SAFETY: `gemm_blocked_isa` asserted
                        // `isa.is_available()` on entry, so the CPU
                        // supports the feature each variant was compiled
                        // for.
                        #[cfg(target_arch = "x86_64")]
                        Isa::Sse2 => unsafe {
                            super::simd::micro_kernel_sse2::<$mr, $nr>(
                                apack, b, c, n, il, j, p0, p1,
                            )
                        },
                        #[cfg(target_arch = "x86_64")]
                        Isa::Avx2 => unsafe {
                            super::simd::micro_kernel_avx2::<$mr, $nr>(
                                apack, b, c, n, il, j, p0, p1,
                            )
                        },
                        // Avx512 dispatches the widest shipped f32
                        // kernel (no 512-bit-specific bodies yet);
                        // availability implies FMA support.
                        #[cfg(target_arch = "x86_64")]
                        Isa::Fma | Isa::Avx512 => unsafe {
                            super::simd::micro_kernel_fma::<$mr, $nr>(
                                apack, b, c, n, il, j, p0, p1,
                            )
                        },
                        // Scalar, Neon (portable bodies), and every
                        // value on a non-x86-64 build.
                        _ => micro_kernel_fixed::<$mr, $nr>(
                            apack, b, c, n, il, j, p0, p1,
                        ),
                    },
                )+
                _ => micro_kernel(apack, b, c, n, il, ie, j, je, p0, p1, mr),
            }
        }
    };
}

// The registry: {2, 4, 8, 16} × {4, 8, 16} — the paper's Table-2 region
// of register-tile shapes, monomorphized so LLVM keeps each accumulator
// in vector registers.
micro_kernel_registry!(
    (2, 4),
    (2, 8),
    (2, 16),
    (4, 4),
    (4, 8),
    (4, 16),
    (8, 4),
    (8, 8),
    (8, 16),
    (16, 4),
    (16, 8),
    (16, 16),
);

/// `C = A @ B`, row-major, blocked per `params`.
///
/// The A macro-panel is packed `mr`-row-interleaved before the micro
/// kernels run (EXPERIMENTS.md §Perf: the unpacked version walked A with
/// stride `k` in the innermost loop and ran *slower* than the naive
/// kernel; packing is the paper's "local memory staging" played on a
/// cache hierarchy).
///
/// With `params.threads != 1` the `bm`-row macro-tile bands are claimed
/// dynamically by a fixed worker set; each band runs `gemm_band` —
/// the same code the serial path runs — against its own disjoint slice
/// of C, so the output is bit-identical for every thread count.
pub fn gemm_blocked(
    a: &[f32],
    b: &[f32],
    m: usize,
    n: usize,
    k: usize,
    params: &BlockedParams,
) -> Vec<f32> {
    gemm_blocked_isa(a, b, m, n, k, params, Isa::Scalar)
}

/// [`gemm_blocked`] with an explicit micro-kernel [`Isa`] — the
/// runtime-dispatched SIMD axis the tuner sweeps.  `Isa::Scalar` is
/// bit-identical to [`gemm_blocked`] (it *is* that path); `Sse2`/`Avx2`
/// are bit-identical too (same operation order, wider lanes); `Fma`
/// agrees within an accumulation tolerance (fused rounding).  Ragged
/// edges and unregistered `(mr, nr)` shapes always take the scalar
/// kernel, whatever the ISA — the bit-fallback off the SIMD domain.
///
/// Panics (loudly) if `isa` is not available on the executing host:
/// dispatching a `#[target_feature]` kernel the CPU lacks would be
/// undefined behavior, so the caller — normally the plan layer, which
/// degrades unavailable ISAs to scalar — must never let one through.
pub fn gemm_blocked_isa(
    a: &[f32],
    b: &[f32],
    m: usize,
    n: usize,
    k: usize,
    params: &BlockedParams,
    isa: Isa,
) -> Vec<f32> {
    assert_eq!(a.len(), m * k, "A shape mismatch");
    assert_eq!(b.len(), k * n, "B shape mismatch");
    assert!(
        params.bm > 0
            && params.bn > 0
            && params.bk > 0
            && params.mr > 0
            && params.nr > 0,
        "BlockedParams dims must be non-zero: {params:?}"
    );
    assert!(
        params.mr <= 16 && params.nr <= 16,
        "micro-tile exceeds the 16x16 register kernel cap: {params:?}"
    );
    assert!(
        isa.is_available(),
        "micro-kernel ISA {isa} is not available on this host \
         (detected: {:?}) — resolve the plan through the engine, which \
         degrades unavailable ISAs to scalar",
        Isa::detect()
    );
    let mut c = vec![0.0f32; m * n];
    let bm = params.bm;
    let workers = pool::resolve_threads(params.threads);
    let bands = m.div_ceil(bm);
    if workers <= 1 || bands <= 1 || n == 0 {
        // Serial path: one packing buffer reused across bands (every band
        // fully rewrites the prefix it reads, so reuse is invisible).
        let mut apack = alloc_apack(params);
        let mut i0 = 0;
        while i0 < m {
            let i1 = (i0 + bm).min(m);
            gemm_band(
                a,
                b,
                &mut c[i0 * n..i1 * n],
                n,
                k,
                i0,
                i1,
                params,
                isa,
                &mut apack,
            );
            i0 = i1;
        }
    } else {
        // Parallel path: split C into disjoint bm-row bands and let the
        // pool's workers claim them; each worker packs into its own
        // buffer and runs the identical per-band code.
        let row_bands: Vec<(usize, &mut [f32])> =
            c.chunks_mut(bm * n).enumerate().collect();
        pool::run_parallel(workers, row_bands, |_, (band, cband)| {
            let i0 = band * bm;
            let i1 = (i0 + bm).min(m);
            let mut apack = alloc_apack(params);
            gemm_band(a, b, cband, n, k, i0, i1, params, isa, &mut apack);
        });
    }
    c
}

/// Batched `C[i] = A[i] @ B[i]` for `batch` independent row-major GEMMs
/// of identical shape, concatenated slice-wise in all three operands —
/// the entry point Winograd's transform-domain multiplies lower onto
/// (paper §4.1.2: one GEMM per transform-domain position).
///
/// Each slice runs [`gemm_blocked_isa`] verbatim under the same `params`
/// and `isa`, so every batch element is bit-identical to a standalone
/// [`gemm_blocked_isa`] call on that slice — including across thread
/// counts.  `params.threads` parallelizes *inside* each GEMM over its
/// macro-tile bands when a slice has several; when each slice fits a
/// single `bm` band (the Winograd transform-domain batch of small
/// GEMMs), the band path degenerates to serial and the threads are
/// spent across the *batch* dimension instead — each worker owns a
/// disjoint per-batch output slice and runs the serial per-slice code,
/// preserving the crate's disjoint-output determinism (bit-identical
/// to the sequential loop for every thread count).
///
/// Panics on operand/shape mismatch or an unavailable `isa`, exactly
/// like [`gemm_blocked_isa`].
pub fn gemm_batched_isa(
    a: &[f32],
    b: &[f32],
    batch: usize,
    m: usize,
    n: usize,
    k: usize,
    params: &BlockedParams,
    isa: Isa,
) -> Vec<f32> {
    assert_eq!(a.len(), batch * m * k, "batched A shape mismatch");
    assert_eq!(b.len(), batch * k * n, "batched B shape mismatch");
    let workers = pool::resolve_threads(params.threads);
    let bands = m.div_ceil(params.bm.max(1));
    if workers > 1 && batch > 1 && bands <= 1 && m * n > 0 {
        // Per-GEMM work is below the band-parallel threshold (a single
        // bm band), so inner parallelism would run every slice serially
        // anyway: spend the threads across the batch.  Each worker
        // computes whole slices with the serial per-GEMM path into its
        // disjoint chunk of C; gemm_blocked_isa is bit-identical across
        // thread counts, so this path is bit-identical to the
        // sequential loop below.
        let serial = BlockedParams { threads: 1, ..*params };
        let mut c = vec![0.0f32; batch * m * n];
        let slices: Vec<(usize, &mut [f32])> =
            c.chunks_mut(m * n).enumerate().collect();
        pool::run_parallel(workers, slices, |_, (i, cslice)| {
            cslice.copy_from_slice(&gemm_blocked_isa(
                &a[i * m * k..(i + 1) * m * k],
                &b[i * k * n..(i + 1) * k * n],
                m,
                n,
                k,
                &serial,
                isa,
            ));
        });
        return c;
    }
    let mut c = Vec::with_capacity(batch * m * n);
    for i in 0..batch {
        c.extend_from_slice(&gemm_blocked_isa(
            &a[i * m * k..(i + 1) * m * k],
            &b[i * k * n..(i + 1) * k * n],
            m,
            n,
            k,
            params,
            isa,
        ));
    }
    c
}

/// Packing buffer for one `bm x bk` A macro-panel: strips of `mr` rows,
/// ragged strips zero-padded, so size for the rounded-up strip count.
fn alloc_apack(params: &BlockedParams) -> Vec<f32> {
    vec![
        0.0f32;
        params.bm.max(params.mr).div_ceil(params.mr)
            * params.mr
            * params.bk.max(1)
    ]
}

/// One `bm`-row macro-tile band: `cband = A[i0..i1, :] @ B`, with
/// `cband` the band's rows of C (`(i1 - i0) x n`, row-major).  This is
/// the unit of parallelism — the serial path calls it per band in order,
/// the pool calls it per band concurrently; the code is shared so the
/// two are bit-identical by construction.
#[allow(clippy::too_many_arguments)]
fn gemm_band(
    a: &[f32],
    b: &[f32],
    cband: &mut [f32],
    n: usize,
    k: usize,
    i0: usize,
    i1: usize,
    params: &BlockedParams,
    isa: Isa,
    apack: &mut [f32],
) {
    let &BlockedParams { bn, bk, mr, nr, .. } = params;
    for p0 in (0..k).step_by(bk) {
        let p1 = (p0 + bk).min(k);
        pack_a(a, apack, k, i0, i1, p0, p1, mr);
        for j0 in (0..n).step_by(bn) {
            let j1 = (j0 + bn).min(n);
            // Macro-tile: micro-kernels over mr x nr register tiles.
            let mut i = i0;
            while i < i1 {
                let ie = (i + mr).min(i1);
                let strip = ((i - i0) / mr) * (mr * (p1 - p0));
                // Row index within the band's slice of C.
                let il = i - i0;
                let mut j = j0;
                while j < j1 {
                    let je = (j + nr).min(j1);
                    // Full tiles of a registry shape go through their
                    // monomorphized kernel, whose accumulator stays in
                    // registers (EXPERIMENTS.md §Perf blas-2); ragged
                    // edges and unregistered shapes take the generic
                    // path.
                    let full = ie - i == mr && je - j == nr;
                    dispatch_micro_kernel(
                        full, mr, nr, isa, &apack[strip..], b, cband, n,
                        il, il + (ie - i), j, je, p0, p1,
                    );
                    j = je;
                }
                i = ie;
            }
        }
    }
}

/// Pack `A[i0..i1, p0..p1]` into `mr`-row strips, k-major within each
/// strip: `apack[strip][p * mr + r] = A[i0 + strip*mr + r, p0 + p]`.
fn pack_a(
    a: &[f32],
    apack: &mut [f32],
    k: usize,
    i0: usize,
    i1: usize,
    p0: usize,
    p1: usize,
    mr: usize,
) {
    let kc = p1 - p0;
    let mut out = 0;
    let mut i = i0;
    while i < i1 {
        let rows = (i + mr).min(i1) - i;
        for p in 0..kc {
            for r in 0..rows {
                apack[out] = a[(i + r) * k + p0 + p];
                out += 1;
            }
            // Zero-fill ragged strips so the kernel stays branch-free.
            for _ in rows..mr {
                apack[out] = 0.0;
                out += 1;
            }
        }
        i += mr;
    }
}

/// Monomorphized micro-kernel for full `MR x NR` tiles: fixed trip
/// counts let LLVM keep the whole accumulator in vector registers.
/// `c` is the current band's slice of the output; `i` is the row within
/// that band.  `#[inline(always)]` so the `#[target_feature]` wrappers
/// in `blas::simd` inline this body and recompile it at their feature
/// level (the multiversioning trick — same operations, wider lanes,
/// bit-identical results).
#[inline(always)]
#[allow(clippy::too_many_arguments)]
pub(crate) fn micro_kernel_fixed<const MR: usize, const NR: usize>(
    apack: &[f32],
    b: &[f32],
    c: &mut [f32],
    n: usize,
    i: usize,
    j: usize,
    p0: usize,
    p1: usize,
) {
    let mut acc = [[0.0f32; NR]; MR];
    for p in 0..(p1 - p0) {
        let brow: &[f32] = &b[(p0 + p) * n + j..(p0 + p) * n + j + NR];
        let astrip = &apack[p * MR..(p + 1) * MR];
        for r in 0..MR {
            let aip = astrip[r];
            for s in 0..NR {
                acc[r][s] += aip * brow[s];
            }
        }
    }
    for (r, accr) in acc.iter().enumerate() {
        let crow = &mut c[(i + r) * n + j..(i + r) * n + j + NR];
        for s in 0..NR {
            crow[s] += accr[s];
        }
    }
}

/// The register micro-kernel: accumulate `C[i..ie, j..je] += Apack_strip
/// @ B[p0..p1, j..je]` with accumulators held in a fixed-size stack tile
/// (the "registers" of the device kernel).  `apack` points at the strip:
/// `apack[p * mr + r]` is the packed A value for band-local row `i + r`
/// at depth `p0 + p` — sequential in the p-loop.  `c` is the band slice;
/// `i..ie` are rows within it.
#[inline]
#[allow(clippy::too_many_arguments)]
fn micro_kernel(
    apack: &[f32],
    b: &[f32],
    c: &mut [f32],
    n: usize,
    i: usize,
    ie: usize,
    j: usize,
    je: usize,
    p0: usize,
    p1: usize,
    mr: usize,
) {
    // Max micro-tile is 16x16; callers keep mr<=16, nr<=16 (the registry
    // tops out at (16, 16)).
    let mut acc = [[0.0f32; 16]; 16];
    let (mh, nw) = (ie - i, je - j);
    debug_assert!(mh <= 16 && nw <= 16);
    for p in 0..(p1 - p0) {
        let brow = &b[(p0 + p) * n + j..(p0 + p) * n + je];
        let astrip = &apack[p * mr..p * mr + mh];
        for (r, (accr, aip)) in
            acc.iter_mut().zip(astrip.iter()).enumerate()
        {
            let _ = r;
            for (s, bv) in brow.iter().enumerate() {
                accr[s] += aip * bv;
            }
        }
    }
    for r in 0..mh {
        let crow = &mut c[(i + r) * n + j..(i + r) * n + je];
        for (s, cv) in crow.iter_mut().enumerate() {
            *cv += acc[r][s];
        }
    }
    let _ = nw;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blas::{gemm_naive, max_abs_diff};

    #[test]
    fn odd_blocking_params_still_correct() {
        let m = 37;
        let n = 29;
        let k = 23;
        let a: Vec<f32> = (0..m * k).map(|i| (i % 7) as f32 - 3.0).collect();
        let b: Vec<f32> = (0..k * n).map(|i| (i % 5) as f32 - 2.0).collect();
        let expected = gemm_naive(&a, &b, m, n, k);
        for params in [
            BlockedParams { bm: 8, bn: 8, bk: 8, mr: 2, nr: 2, threads: 1 },
            BlockedParams { bm: 16, bn: 32, bk: 5, mr: 4, nr: 8, threads: 2 },
            BlockedParams {
                bm: 64, bn: 64, bk: 64, mr: 8, nr: 16, threads: 0,
            },
        ] {
            let got = gemm_blocked(&a, &b, m, n, k, &params);
            assert!(max_abs_diff(&expected, &got) < 1e-4, "{params:?}");
        }
    }

    #[test]
    fn parallel_bands_bit_identical_to_serial() {
        // More bands than the default bm would give: force bm small so
        // every thread count actually splits the row range.
        let (m, n, k) = (53, 31, 19);
        let a: Vec<f32> = (0..m * k).map(|i| (i % 11) as f32 - 5.0).collect();
        let b: Vec<f32> = (0..k * n).map(|i| (i % 13) as f32 - 6.0).collect();
        let base =
            BlockedParams { bm: 8, bn: 16, bk: 8, mr: 4, nr: 8, threads: 1 };
        let serial = gemm_blocked(&a, &b, m, n, k, &base);
        for threads in [0usize, 2, 3, 8, 64] {
            let par = gemm_blocked(
                &a,
                &b,
                m,
                n,
                k,
                &BlockedParams { threads, ..base },
            );
            assert!(
                serial == par,
                "threads={threads} diverged from serial (max diff {})",
                max_abs_diff(&serial, &par)
            );
        }
    }

    #[test]
    fn config_name_roundtrips_the_knobs() {
        let p = BlockedParams { bm: 32, bn: 48, bk: 8, mr: 2, nr: 4, threads: 3 };
        assert_eq!(p.name(), "bm32bn48bk8_2x4_t3");
        assert_eq!(BlockedParams::default().name(), "bm64bn64bk64_4x8_t0");
    }

    #[test]
    #[should_panic(expected = "non-zero")]
    fn zero_block_dim_is_a_loud_panic() {
        let params = BlockedParams { bm: 0, ..Default::default() };
        gemm_blocked(&[1.0], &[1.0], 1, 1, 1, &params);
    }

    #[test]
    #[should_panic(expected = "register kernel cap")]
    fn oversized_micro_tile_is_a_loud_panic() {
        let params = BlockedParams { mr: 32, ..Default::default() };
        gemm_blocked(&[1.0], &[1.0], 1, 1, 1, &params);
    }

    #[test]
    fn registry_covers_the_advertised_cross() {
        // The macro invocation is the source of truth; this pins the
        // contract the tuner grids rely on: at least {2,4,8,16}x{4,8,16}.
        for mr in [2usize, 4, 8, 16] {
            for nr in [4usize, 8, 16] {
                assert!(
                    MICRO_KERNEL_SHAPES.contains(&(mr, nr)),
                    "({mr}, {nr}) missing from the registry"
                );
                let p = BlockedParams { mr, nr, ..Default::default() };
                assert!(p.is_monomorphized());
            }
        }
        assert!(!BlockedParams { mr: 3, nr: 5, ..Default::default() }
            .is_monomorphized());
        // No duplicates: dedup discipline for grid construction.
        for (i, s) in MICRO_KERNEL_SHAPES.iter().enumerate() {
            assert!(!MICRO_KERNEL_SHAPES[i + 1..].contains(s));
        }
    }

    #[test]
    fn isa_scalar_is_the_gemm_blocked_path() {
        // gemm_blocked IS gemm_blocked_isa(Scalar): bit-equal outputs.
        let (m, n, k) = (23, 17, 11);
        let a: Vec<f32> = (0..m * k).map(|i| (i % 7) as f32 - 3.0).collect();
        let b: Vec<f32> = (0..k * n).map(|i| (i % 5) as f32 - 2.0).collect();
        let params = BlockedParams { threads: 1, ..Default::default() };
        assert!(
            gemm_blocked(&a, &b, m, n, k, &params)
                == gemm_blocked_isa(&a, &b, m, n, k, &params, Isa::Scalar)
        );
    }

    #[test]
    fn detected_isa_kernels_agree_with_scalar() {
        // Ragged shape so full registry tiles (SIMD path) and ragged
        // edges (scalar bit-fallback) both run.  SSE2/AVX2 recompile the
        // same operation order, so 0 ULP; FMA fuses the rounding, so an
        // accumulation tolerance scaled by k.
        let (m, n, k) = (37, 29, 23);
        let a: Vec<f32> = (0..m * k).map(|i| (i % 7) as f32 - 3.0).collect();
        let b: Vec<f32> = (0..k * n).map(|i| (i % 5) as f32 - 2.0).collect();
        for &(mr, nr) in MICRO_KERNEL_SHAPES {
            let params = BlockedParams {
                bm: 32,
                bn: 32,
                bk: 16,
                mr,
                nr,
                threads: 1,
            };
            let scalar = gemm_blocked(&a, &b, m, n, k, &params);
            for isa in Isa::detect() {
                let got = gemm_blocked_isa(&a, &b, m, n, k, &params, isa);
                // Avx512 dispatches the FMA kernel, so it shares FMA's
                // fused-rounding tolerance contract.
                if matches!(isa, Isa::Fma | Isa::Avx512) {
                    assert!(
                        max_abs_diff(&scalar, &got)
                            <= 1e-6 * k as f32,
                        "fma beyond tolerance for ({mr}, {nr})"
                    );
                } else {
                    assert!(
                        scalar == got,
                        "{isa} not bit-identical to scalar for ({mr}, {nr})"
                    );
                }
            }
        }
    }

    #[test]
    fn isa_parallel_bands_bit_identical_to_serial() {
        // The ISA axis composes with the threads axis: every detected
        // ISA is bit-identical across thread counts (disjoint bands run
        // the same per-band code).
        let (m, n, k) = (53, 31, 19);
        let a: Vec<f32> = (0..m * k).map(|i| (i % 11) as f32 - 5.0).collect();
        let b: Vec<f32> = (0..k * n).map(|i| (i % 13) as f32 - 6.0).collect();
        let base =
            BlockedParams { bm: 8, bn: 16, bk: 8, mr: 4, nr: 8, threads: 1 };
        for isa in Isa::detect() {
            let serial = gemm_blocked_isa(&a, &b, m, n, k, &base, isa);
            for threads in [2usize, 3, 8] {
                let par = gemm_blocked_isa(
                    &a,
                    &b,
                    m,
                    n,
                    k,
                    &BlockedParams { threads, ..base },
                    isa,
                );
                assert!(serial == par, "{isa} threads={threads} diverged");
            }
        }
    }

    #[test]
    fn unavailable_isa_is_a_loud_panic_not_ub() {
        // On hosts that lack some ISA (always true off x86-64, and on
        // pre-AVX2 x86), dispatching it must panic loudly instead of
        // reaching a #[target_feature] kernel the CPU cannot run.
        if let Some(missing) =
            Isa::all().into_iter().find(|i| !i.is_available())
        {
            let params =
                BlockedParams { threads: 1, ..BlockedParams::default() };
            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                || gemm_blocked_isa(&[1.0], &[1.0], 1, 1, 1, &params, missing),
            ));
            assert!(r.is_err(), "{missing} should have panicked");
        }
    }

    #[test]
    fn batched_gemm_is_slicewise_bit_identical() {
        // Each batch element must equal a standalone gemm_blocked_isa
        // call on its slice, bit for bit, for every detected ISA and
        // across thread counts.
        let (batch, m, n, k) = (5, 13, 11, 7);
        let a: Vec<f32> =
            (0..batch * m * k).map(|i| (i % 9) as f32 - 4.0).collect();
        let b: Vec<f32> =
            (0..batch * k * n).map(|i| (i % 7) as f32 - 3.0).collect();
        let base =
            BlockedParams { bm: 8, bn: 8, bk: 4, mr: 2, nr: 4, threads: 1 };
        for isa in Isa::detect() {
            for threads in [1usize, 0, 3] {
                let params = BlockedParams { threads, ..base };
                let c = gemm_batched_isa(&a, &b, batch, m, n, k, &params, isa);
                assert_eq!(c.len(), batch * m * n);
                for i in 0..batch {
                    let solo = gemm_blocked_isa(
                        &a[i * m * k..(i + 1) * m * k],
                        &b[i * k * n..(i + 1) * k * n],
                        m,
                        n,
                        k,
                        &params,
                        isa,
                    );
                    assert!(
                        c[i * m * n..(i + 1) * m * n] == solo[..],
                        "{isa} threads={threads} batch element {i} diverged"
                    );
                }
            }
        }
    }

    #[test]
    fn batched_gemm_matches_naive_per_slice() {
        let (batch, m, n, k) = (3, 6, 5, 4);
        let a: Vec<f32> =
            (0..batch * m * k).map(|i| (i % 5) as f32 - 2.0).collect();
        let b: Vec<f32> =
            (0..batch * k * n).map(|i| (i % 3) as f32 - 1.0).collect();
        let params = BlockedParams { threads: 1, ..Default::default() };
        let c =
            gemm_batched_isa(&a, &b, batch, m, n, k, &params, Isa::Scalar);
        for i in 0..batch {
            let naive = gemm_naive(
                &a[i * m * k..(i + 1) * m * k],
                &b[i * k * n..(i + 1) * k * n],
                m,
                n,
                k,
            );
            assert!(
                max_abs_diff(&c[i * m * n..(i + 1) * m * n], &naive) < 1e-5,
                "batch element {i}"
            );
        }
    }

    #[test]
    fn batched_gemm_batch_parallel_path_bit_identical() {
        // Slices smaller than one bm band take the batch-parallel path
        // (threads spent across the batch); it must be bit-identical to
        // the sequential loop for every detected ISA and thread count.
        let (batch, m, n, k) = (7, 6, 5, 4);
        let a: Vec<f32> =
            (0..batch * m * k).map(|i| (i % 9) as f32 - 4.0).collect();
        let b: Vec<f32> =
            (0..batch * k * n).map(|i| (i % 7) as f32 - 3.0).collect();
        let base = BlockedParams {
            bm: 16, bn: 16, bk: 8, mr: 2, nr: 4, threads: 1,
        };
        assert!(m <= base.bm, "test premise: one band per slice");
        for isa in Isa::detect() {
            let serial =
                gemm_batched_isa(&a, &b, batch, m, n, k, &base, isa);
            for threads in [0usize, 2, 3, 8] {
                let par = gemm_batched_isa(
                    &a,
                    &b,
                    batch,
                    m,
                    n,
                    k,
                    &BlockedParams { threads, ..base },
                    isa,
                );
                assert!(
                    serial == par,
                    "{isa} threads={threads} batch-parallel diverged"
                );
            }
        }
    }

    #[test]
    #[should_panic(expected = "batched A shape mismatch")]
    fn batched_gemm_rejects_short_operands() {
        gemm_batched_isa(
            &[1.0; 3],
            &[1.0; 4],
            2,
            1,
            1,
            2,
            &BlockedParams::default(),
            Isa::Scalar,
        );
    }

    #[test]
    fn every_registry_shape_is_correct_on_ragged_dims() {
        // 37x29x23 leaves ragged edges for every registry shape, so both
        // the monomorphized kernel (interior) and the generic kernel
        // (edges) run for each (mr, nr).
        let (m, n, k) = (37, 29, 23);
        let a: Vec<f32> = (0..m * k).map(|i| (i % 7) as f32 - 3.0).collect();
        let b: Vec<f32> = (0..k * n).map(|i| (i % 5) as f32 - 2.0).collect();
        let expected = gemm_naive(&a, &b, m, n, k);
        for &(mr, nr) in MICRO_KERNEL_SHAPES {
            let params = BlockedParams {
                bm: 32,
                bn: 32,
                bk: 16,
                mr,
                nr,
                threads: 1,
            };
            let got = gemm_blocked(&a, &b, m, n, k, &params);
            assert!(max_abs_diff(&expected, &got) < 1e-4, "{params:?}");
        }
    }
}
