//! Blocked/tiled host GEMM — the paper's §3.1.1 scheme on the CPU.
//!
//! The same parametrization as the device kernel (macro-tile, register
//! micro-tile, k-panel), instantiated for a cache hierarchy instead of
//! local memory: `bm x bn` macro-tiles sized for L2, `bk` panels for L1,
//! and a `4 x 4`-ish register micro-kernel the compiler can vectorize.

/// Blocking parameters (the CPU analogue of `GemmConfig`).
#[derive(Debug, Clone, Copy)]
pub struct BlockedParams {
    pub bm: usize,
    pub bn: usize,
    pub bk: usize,
    /// Register micro-tile rows.
    pub mr: usize,
    /// Register micro-tile columns.
    pub nr: usize,
}

impl Default for BlockedParams {
    fn default() -> Self {
        Self { bm: 64, bn: 64, bk: 64, mr: 4, nr: 8 }
    }
}

/// `C = A @ B`, row-major, blocked per `params`.
///
/// The A macro-panel is packed `mr`-row-interleaved before the micro
/// kernels run (EXPERIMENTS.md §Perf: the unpacked version walked A with
/// stride `k` in the innermost loop and ran *slower* than the naive
/// kernel; packing is the paper's "local memory staging" played on a
/// cache hierarchy).
pub fn gemm_blocked(
    a: &[f32],
    b: &[f32],
    m: usize,
    n: usize,
    k: usize,
    params: &BlockedParams,
) -> Vec<f32> {
    assert_eq!(a.len(), m * k, "A shape mismatch");
    assert_eq!(b.len(), k * n, "B shape mismatch");
    let mut c = vec![0.0f32; m * n];
    let &BlockedParams { bm, bn, bk, mr, nr } = params;
    // Packed A panel: strips of `mr` rows, column-major within the strip
    // so the micro-kernel reads it sequentially.  Ragged strips are
    // zero-padded to `mr` rows, so size for the rounded-up strip count.
    let mut apack =
        vec![0.0f32; bm.max(mr).div_ceil(mr) * mr * bk.max(1)];

    for i0 in (0..m).step_by(bm) {
        let i1 = (i0 + bm).min(m);
        for p0 in (0..k).step_by(bk) {
            let p1 = (p0 + bk).min(k);
            pack_a(a, &mut apack, k, i0, i1, p0, p1, mr);
            for j0 in (0..n).step_by(bn) {
                let j1 = (j0 + bn).min(n);
                // Macro-tile: micro-kernels over mr x nr register tiles.
                let mut i = i0;
                while i < i1 {
                    let ie = (i + mr).min(i1);
                    let strip =
                        ((i - i0) / mr) * (mr * (p1 - p0));
                    let mut j = j0;
                    while j < j1 {
                        let je = (j + nr).min(j1);
                        // Full tiles go through a monomorphized kernel
                        // whose accumulator stays in registers
                        // (EXPERIMENTS.md §Perf blas-2); ragged edges
                        // take the generic path.
                        let full = ie - i == mr && je - j == nr;
                        match (full, mr, nr) {
                            (true, 4, 8) => micro_kernel_fixed::<4, 8>(
                                &apack[strip..], b, &mut c, n, i, j, p0, p1,
                            ),
                            (true, 8, 8) => micro_kernel_fixed::<8, 8>(
                                &apack[strip..], b, &mut c, n, i, j, p0, p1,
                            ),
                            (true, 8, 16) => micro_kernel_fixed::<8, 16>(
                                &apack[strip..], b, &mut c, n, i, j, p0, p1,
                            ),
                            (true, 4, 16) => micro_kernel_fixed::<4, 16>(
                                &apack[strip..], b, &mut c, n, i, j, p0, p1,
                            ),
                            _ => micro_kernel(
                                &apack[strip..], b, &mut c, n, i, ie, j,
                                je, p0, p1, mr,
                            ),
                        }
                        j = je;
                    }
                    i = ie;
                }
            }
        }
    }
    c
}

/// Pack `A[i0..i1, p0..p1]` into `mr`-row strips, k-major within each
/// strip: `apack[strip][p * mr + r] = A[i0 + strip*mr + r, p0 + p]`.
fn pack_a(
    a: &[f32],
    apack: &mut [f32],
    k: usize,
    i0: usize,
    i1: usize,
    p0: usize,
    p1: usize,
    mr: usize,
) {
    let kc = p1 - p0;
    let mut out = 0;
    let mut i = i0;
    while i < i1 {
        let rows = (i + mr).min(i1) - i;
        for p in 0..kc {
            for r in 0..rows {
                apack[out] = a[(i + r) * k + p0 + p];
                out += 1;
            }
            // Zero-fill ragged strips so the kernel stays branch-free.
            for _ in rows..mr {
                apack[out] = 0.0;
                out += 1;
            }
        }
        i += mr;
    }
}

/// Monomorphized micro-kernel for full `MR x NR` tiles: fixed trip
/// counts let LLVM keep the whole accumulator in vector registers.
#[inline]
#[allow(clippy::too_many_arguments)]
fn micro_kernel_fixed<const MR: usize, const NR: usize>(
    apack: &[f32],
    b: &[f32],
    c: &mut [f32],
    n: usize,
    i: usize,
    j: usize,
    p0: usize,
    p1: usize,
) {
    let mut acc = [[0.0f32; NR]; MR];
    for p in 0..(p1 - p0) {
        let brow: &[f32] = &b[(p0 + p) * n + j..(p0 + p) * n + j + NR];
        let astrip = &apack[p * MR..(p + 1) * MR];
        for r in 0..MR {
            let aip = astrip[r];
            for s in 0..NR {
                acc[r][s] += aip * brow[s];
            }
        }
    }
    for (r, accr) in acc.iter().enumerate() {
        let crow = &mut c[(i + r) * n + j..(i + r) * n + j + NR];
        for s in 0..NR {
            crow[s] += accr[s];
        }
    }
}

/// The register micro-kernel: accumulate `C[i..ie, j..je] += Apack_strip
/// @ B[p0..p1, j..je]` with accumulators held in a fixed-size stack tile
/// (the "registers" of the device kernel).  `apack` points at the strip:
/// `apack[p * mr + r]` is `A[i + r, p0 + p]` — sequential in the p-loop.
#[inline]
#[allow(clippy::too_many_arguments)]
fn micro_kernel(
    apack: &[f32],
    b: &[f32],
    c: &mut [f32],
    n: usize,
    i: usize,
    ie: usize,
    j: usize,
    je: usize,
    p0: usize,
    p1: usize,
    mr: usize,
) {
    // Max micro-tile is 8x16; callers keep mr<=8, nr<=16.
    let mut acc = [[0.0f32; 16]; 8];
    let (mh, nw) = (ie - i, je - j);
    debug_assert!(mh <= 8 && nw <= 16);
    for p in 0..(p1 - p0) {
        let brow = &b[(p0 + p) * n + j..(p0 + p) * n + je];
        let astrip = &apack[p * mr..p * mr + mh];
        for (r, (accr, aip)) in
            acc.iter_mut().zip(astrip.iter()).enumerate()
        {
            let _ = r;
            for (s, bv) in brow.iter().enumerate() {
                accr[s] += aip * bv;
            }
        }
    }
    for r in 0..mh {
        let crow = &mut c[(i + r) * n + j..(i + r) * n + je];
        for (s, cv) in crow.iter_mut().enumerate() {
            *cv += acc[r][s];
        }
    }
    let _ = nw;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blas::{gemm_naive, max_abs_diff};

    #[test]
    fn odd_blocking_params_still_correct() {
        let m = 37;
        let n = 29;
        let k = 23;
        let a: Vec<f32> = (0..m * k).map(|i| (i % 7) as f32 - 3.0).collect();
        let b: Vec<f32> = (0..k * n).map(|i| (i % 5) as f32 - 2.0).collect();
        let expected = gemm_naive(&a, &b, m, n, k);
        for params in [
            BlockedParams { bm: 8, bn: 8, bk: 8, mr: 2, nr: 2 },
            BlockedParams { bm: 16, bn: 32, bk: 5, mr: 4, nr: 8 },
            BlockedParams { bm: 64, bn: 64, bk: 64, mr: 8, nr: 16 },
        ] {
            let got = gemm_blocked(&a, &b, m, n, k, &params);
            assert!(max_abs_diff(&expected, &got) < 1e-4, "{params:?}");
        }
    }
}
