//! Blocked/tiled host GEMM — the paper's §3.1.1 scheme on the CPU.
//!
//! The same parametrization as the device kernel (macro-tile, register
//! micro-tile, k-panel), instantiated for a cache hierarchy instead of
//! local memory: `bm x bn` macro-tiles sized for L2, `bk` panels for L1,
//! and a `4 x 4`-ish register micro-kernel the compiler can vectorize.
//! The `threads` knob adds the work-group dimension of the device kernel:
//! `bm`-row macro-tile bands are distributed over a scoped thread pool
//! ([`crate::util::pool`]), each worker owning a disjoint band of C rows,
//! so parallel results are bit-identical to the serial path.
//!
//! The micro-kernel additionally carries a runtime-dispatched **ISA
//! axis** ([`super::Isa`]): full registry tiles can run `#[target_feature]`
//! SIMD variants (`blas::simd`) selected per plan by the tuner, with the
//! scalar kernel as the bit-fallback for ragged edges, unregistered
//! shapes, and hosts without the feature.  [`gemm_blocked`] is the
//! scalar entry point; [`gemm_blocked_isa`] takes the axis explicitly.
//!
//! **Operand staging is itself a tuned axis** ([`Pack`]): `pack: a`
//! stages only the A macro-panel (`mr`-row-interleaved strips — the
//! historical behavior), while `pack: ab` additionally stages B once per
//! call into BLIS-style `nr`-column-interleaved `bk×bn` panels
//! ([`pack_b`]), shared read-only across every row band, so the
//! micro-kernel's B reads become unit-stride instead of stride-`n`.
//! The packed-B micro-kernel twins read the *same values in the same
//! floating-point order* from the packed layout, so `pack: ab` is
//! bit-identical to `pack: a` for every ISA (0 ULP — proptested); which
//! one is *faster* is shape- and cache-dependent, which is exactly why
//! it is a swept axis and not a default.  Packing buffers come from a
//! caller-supplied [`Scratch`] arena ([`gemm_blocked_ex`]) so serving
//! hot paths stage operands without per-call allocation.

use super::Isa;
use crate::error::{Error, Result};
use crate::util::pool;
use crate::util::scratch::{Scratch, Workspace};

/// Blocking parameters (the CPU analogue of `GemmConfig`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BlockedParams {
    /// Macro-tile rows (sized for L2).
    pub bm: usize,
    /// Macro-tile columns (sized for L2).
    pub bn: usize,
    /// K-panel depth (sized for L1).
    pub bk: usize,
    /// Register micro-tile rows.
    pub mr: usize,
    /// Register micro-tile columns.
    pub nr: usize,
    /// Worker threads over `bm`-row macro-tile bands: `0` = one per
    /// available core, `1` = the serial path.  Any value produces
    /// bit-identical results (each worker owns disjoint output rows and
    /// runs the exact serial per-band code), so `threads` is a pure
    /// throughput knob the tuner sweeps like any other parameter.
    pub threads: usize,
}

impl Default for BlockedParams {
    fn default() -> Self {
        Self { bm: 64, bn: 64, bk: 64, mr: 4, nr: 8, threads: 0 }
    }
}

impl BlockedParams {
    /// Compact config name for reports and the tuning DB
    /// (`bm64bn64bk64_4x8_t0` style; `t0` = auto threads).
    pub fn name(&self) -> String {
        format!(
            "bm{}bn{}bk{}_{}x{}_t{}",
            self.bm, self.bn, self.bk, self.mr, self.nr, self.threads
        )
    }

    /// Whether this `(mr, nr)` micro-tile has a monomorphized kernel in
    /// the registry (see [`MICRO_KERNEL_SHAPES`]).  Other shapes are
    /// still correct — they run the generic ragged-edge kernel for every
    /// tile — but leave register-tiling throughput on the table, so the
    /// tuner's grids stick to registry shapes.
    pub fn is_monomorphized(&self) -> bool {
        MICRO_KERNEL_SHAPES.contains(&(self.mr, self.nr))
    }
}

/// The operand-staging axis of the kernel space: which GEMM operands are
/// packed into interleaved panels before the micro-kernels run.
///
/// * [`Pack::A`] — stage only A (`mr`-row strips; the historical
///   behavior and the migration default for legacy DB entries);
/// * [`Pack::Ab`] — additionally stage B once per call into
///   `nr`-column-interleaved `bk×bn` panels reused across all row bands.
///
/// Both settings compute bit-identical results (same values, same
/// floating-point order); the choice is a pure throughput knob the
/// tuner measures, like the tile shapes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord, Default)]
pub enum Pack {
    /// Pack the A macro-panel only (B read directly, stride-`n`).
    #[default]
    A,
    /// Pack A and B (`nr`-column-interleaved B panels, unit-stride
    /// micro-kernel reads).
    Ab,
}

impl Pack {
    /// Every pack value, in sweep/report order (`a` first).
    pub fn all() -> [Pack; 2] {
        [Pack::A, Pack::Ab]
    }

    /// Stable lowercase name (selection DB, reports, CLI).
    pub fn as_str(&self) -> &'static str {
        match self {
            Pack::A => "a",
            Pack::Ab => "ab",
        }
    }
}

impl std::fmt::Display for Pack {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

impl std::str::FromStr for Pack {
    type Err = Error;
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "a" => Ok(Pack::A),
            "ab" => Ok(Pack::Ab),
            other => Err(Error::Config(format!("unknown pack {other:?}"))),
        }
    }
}

/// Generate the monomorphized micro-kernel registry: the public list of
/// `(mr, nr)` register-tile shapes with a fixed-trip-count kernel
/// ([`MICRO_KERNEL_SHAPES`]) and the dispatches that bind a full tile to
/// its monomorphized instantiation (ragged edges and unregistered shapes
/// take the generic kernel) — one dispatch per B layout, unpacked
/// (`dispatch_micro_kernel`) and packed (`dispatch_micro_kernel_pb`).
/// One macro invocation is the single source of truth: the tuner's grids
/// ([`crate::config::micro_kernel_shapes`]) and these dispatches can
/// never disagree about which shapes are "fast".
macro_rules! micro_kernel_registry {
    ($(($mr:literal, $nr:literal)),+ $(,)?) => {
        /// Every `(mr, nr)` register micro-tile with a monomorphized
        /// kernel, in grid-sweep order.  `config::space` re-exports this
        /// as the legal fast set for tuner grids and validation.
        pub const MICRO_KERNEL_SHAPES: &[(usize, usize)] =
            &[$(($mr, $nr)),+];

        /// Dispatch one register tile: full tiles of a registered shape
        /// run their monomorphized kernel — for a SIMD `isa`, the
        /// matching `#[target_feature]` variant from `blas::simd` —
        /// everything else (ragged edges, unregistered shapes, and every
        /// tile on a non-x86-64 host) the generic scalar kernel, the
        /// bit-fallback of the ISA axis.  `il` is the row within the
        /// band slice `c`.
        #[allow(clippy::too_many_arguments)]
        #[inline]
        fn dispatch_micro_kernel(
            full: bool,
            mr: usize,
            nr: usize,
            isa: Isa,
            apack: &[f32],
            b: &[f32],
            c: &mut [f32],
            n: usize,
            il: usize,
            ie: usize,
            j: usize,
            je: usize,
            p0: usize,
            p1: usize,
        ) {
            match (full, mr, nr) {
                $(
                    (true, $mr, $nr) => match isa {
                        // SAFETY: `gemm_blocked_isa` asserted
                        // `isa.is_available()` on entry, so the CPU
                        // supports the feature each variant was compiled
                        // for.
                        #[cfg(target_arch = "x86_64")]
                        Isa::Sse2 => unsafe {
                            super::simd::micro_kernel_sse2::<$mr, $nr>(
                                apack, b, c, n, il, j, p0, p1,
                            )
                        },
                        #[cfg(target_arch = "x86_64")]
                        Isa::Avx2 => unsafe {
                            super::simd::micro_kernel_avx2::<$mr, $nr>(
                                apack, b, c, n, il, j, p0, p1,
                            )
                        },
                        // Avx512 dispatches the widest shipped f32
                        // kernel (no 512-bit-specific bodies yet);
                        // availability implies FMA support.
                        #[cfg(target_arch = "x86_64")]
                        Isa::Fma | Isa::Avx512 => unsafe {
                            super::simd::micro_kernel_fma::<$mr, $nr>(
                                apack, b, c, n, il, j, p0, p1,
                            )
                        },
                        // Scalar, Neon (portable bodies), and every
                        // value on a non-x86-64 build.
                        _ => micro_kernel_fixed::<$mr, $nr>(
                            apack, b, c, n, il, j, p0, p1,
                        ),
                    },
                )+
                _ => micro_kernel(apack, b, c, n, il, ie, j, je, p0, p1, mr),
            }
        }

        /// The packed-B twin of `dispatch_micro_kernel`: `bstrip` points
        /// at this register tile's `kc×nr` strip of the packed B panel
        /// (unit stride), replacing the `(b, p0, p1)` view of the
        /// unpacked dispatch.  Every variant reads the same values in
        /// the same floating-point order as its unpacked twin, so the
        /// two dispatches are bit-identical per ISA by construction.
        #[allow(clippy::too_many_arguments)]
        #[inline]
        fn dispatch_micro_kernel_pb(
            full: bool,
            mr: usize,
            nr: usize,
            isa: Isa,
            apack: &[f32],
            bstrip: &[f32],
            c: &mut [f32],
            n: usize,
            il: usize,
            ie: usize,
            j: usize,
            je: usize,
            kc: usize,
        ) {
            match (full, mr, nr) {
                $(
                    (true, $mr, $nr) => match isa {
                        // SAFETY: as for `dispatch_micro_kernel` — the
                        // entry point asserted `isa.is_available()`.
                        #[cfg(target_arch = "x86_64")]
                        Isa::Sse2 => unsafe {
                            super::simd::micro_kernel_sse2_pb::<$mr, $nr>(
                                apack, bstrip, c, n, il, j, kc,
                            )
                        },
                        #[cfg(target_arch = "x86_64")]
                        Isa::Avx2 => unsafe {
                            super::simd::micro_kernel_avx2_pb::<$mr, $nr>(
                                apack, bstrip, c, n, il, j, kc,
                            )
                        },
                        #[cfg(target_arch = "x86_64")]
                        Isa::Fma | Isa::Avx512 => unsafe {
                            super::simd::micro_kernel_fma_pb::<$mr, $nr>(
                                apack, bstrip, c, n, il, j, kc,
                            )
                        },
                        _ => micro_kernel_fixed_pb::<$mr, $nr>(
                            apack, bstrip, c, n, il, j, kc,
                        ),
                    },
                )+
                _ => micro_kernel_pb(
                    apack, bstrip, c, n, il, ie, j, je, kc, mr, nr,
                ),
            }
        }
    };
}

// The registry: {2, 4, 8, 16} × {4, 8, 16} — the paper's Table-2 region
// of register-tile shapes, monomorphized so LLVM keeps each accumulator
// in vector registers.
micro_kernel_registry!(
    (2, 4),
    (2, 8),
    (2, 16),
    (4, 4),
    (4, 8),
    (4, 16),
    (8, 4),
    (8, 8),
    (8, 16),
    (16, 4),
    (16, 8),
    (16, 16),
);

/// `C = A @ B`, row-major, blocked per `params`.
///
/// The A macro-panel is packed `mr`-row-interleaved before the micro
/// kernels run (EXPERIMENTS.md §Perf: the unpacked version walked A with
/// stride `k` in the innermost loop and ran *slower* than the naive
/// kernel; packing is the paper's "local memory staging" played on a
/// cache hierarchy).
///
/// With `params.threads != 1` the `bm`-row macro-tile bands are claimed
/// dynamically by a fixed worker set; each band runs `gemm_band` —
/// the same code the serial path runs — against its own disjoint slice
/// of C, so the output is bit-identical for every thread count.
pub fn gemm_blocked(
    a: &[f32],
    b: &[f32],
    m: usize,
    n: usize,
    k: usize,
    params: &BlockedParams,
) -> Vec<f32> {
    gemm_blocked_isa(a, b, m, n, k, params, Isa::Scalar)
}

/// [`gemm_blocked`] with an explicit micro-kernel [`Isa`] — the
/// runtime-dispatched SIMD axis the tuner sweeps.  `Isa::Scalar` is
/// bit-identical to [`gemm_blocked`] (it *is* that path); `Sse2`/`Avx2`
/// are bit-identical too (same operation order, wider lanes); `Fma`
/// agrees within an accumulation tolerance (fused rounding).  Ragged
/// edges and unregistered `(mr, nr)` shapes always take the scalar
/// kernel, whatever the ISA — the bit-fallback off the SIMD domain.
///
/// Panics (loudly) if `isa` is not available on the executing host:
/// dispatching a `#[target_feature]` kernel the CPU lacks would be
/// undefined behavior, so the caller — normally the plan layer, which
/// degrades unavailable ISAs to scalar — must never let one through.
pub fn gemm_blocked_isa(
    a: &[f32],
    b: &[f32],
    m: usize,
    n: usize,
    k: usize,
    params: &BlockedParams,
    isa: Isa,
) -> Vec<f32> {
    gemm_blocked_ex(a, b, m, n, k, params, isa, Pack::A, &Scratch::new())
}

/// [`gemm_blocked_isa`] with the full hot-path surface: the
/// operand-staging [`Pack`] axis and a caller-owned [`Scratch`] arena
/// for every packing buffer.  `Pack::A` with a throwaway arena *is*
/// [`gemm_blocked_isa`] (that function delegates here); `Pack::Ab`
/// additionally packs B once per call — shared read-only across every
/// row band — and runs the packed-B micro-kernel twins, bit-identical
/// per ISA to the unpacked path.  With a long-lived arena prewarmed via
/// [`gemm_workspace`], steady-state calls perform zero scratch
/// allocations.
///
/// Panics exactly as [`gemm_blocked_isa`] does.
#[allow(clippy::too_many_arguments)]
pub fn gemm_blocked_ex(
    a: &[f32],
    b: &[f32],
    m: usize,
    n: usize,
    k: usize,
    params: &BlockedParams,
    isa: Isa,
    pack: Pack,
    scratch: &Scratch,
) -> Vec<f32> {
    assert_eq!(a.len(), m * k, "A shape mismatch");
    assert_eq!(b.len(), k * n, "B shape mismatch");
    assert!(
        params.bm > 0
            && params.bn > 0
            && params.bk > 0
            && params.mr > 0
            && params.nr > 0,
        "BlockedParams dims must be non-zero: {params:?}"
    );
    assert!(
        params.mr <= 16 && params.nr <= 16,
        "micro-tile exceeds the 16x16 register kernel cap: {params:?}"
    );
    assert!(
        isa.is_available(),
        "micro-kernel ISA {isa} is not available on this host \
         (detected: {:?}) — resolve the plan through the engine, which \
         degrades unavailable ISAs to scalar",
        Isa::detect()
    );
    let mut c = vec![0.0f32; m * n];
    let bpack = stage_b(b, n, k, params, pack, scratch);
    gemm_into_prepacked(
        a,
        b,
        bpack.as_deref(),
        &mut c,
        m,
        n,
        k,
        params,
        isa,
        scratch,
    );
    if let Some(bp) = bpack {
        scratch.put_f32(bp);
    }
    c
}

/// Pack B per the [`Pack`] axis: `Some(panels)` from the arena for
/// `Pack::Ab` on a non-degenerate operand, `None` (read B directly)
/// otherwise.
fn stage_b(
    b: &[f32],
    n: usize,
    k: usize,
    params: &BlockedParams,
    pack: Pack,
    scratch: &Scratch,
) -> Option<Vec<f32>> {
    if pack != Pack::Ab || n == 0 || k == 0 {
        return None;
    }
    let mut bp = scratch.take_f32(bpack_len(n, k, params));
    pack_b(b, &mut bp, n, k, params);
    Some(bp)
}

/// The band driver shared by every f32 GEMM entry point: compute
/// `c = A @ B` (with `c` pre-zeroed, `m*n` row-major) under `params`,
/// reading B either directly (`bpack: None`) or from pre-packed panels
/// (`bpack: Some`).  Serial and parallel paths run the identical
/// per-band code against disjoint slices of `c`, so every thread count
/// is bit-identical; per-worker A-panel buffers come from the arena.
#[allow(clippy::too_many_arguments)]
fn gemm_into_prepacked(
    a: &[f32],
    b: &[f32],
    bpack: Option<&[f32]>,
    c: &mut [f32],
    m: usize,
    n: usize,
    k: usize,
    params: &BlockedParams,
    isa: Isa,
    scratch: &Scratch,
) {
    let bm = params.bm;
    let workers = pool::resolve_threads(params.threads);
    let bands = m.div_ceil(bm);
    if workers <= 1 || bands <= 1 || n == 0 {
        // Serial path: one packing buffer reused across bands (every band
        // fully rewrites the prefix it reads, so reuse is invisible).
        let mut apack = scratch.take_f32(apack_len(params));
        let mut i0 = 0;
        while i0 < m {
            let i1 = (i0 + bm).min(m);
            let cband = &mut c[i0 * n..i1 * n];
            match bpack {
                Some(bp) => gemm_band_packed(
                    a, bp, cband, n, k, i0, i1, params, isa, &mut apack,
                ),
                None => gemm_band(
                    a, b, cband, n, k, i0, i1, params, isa, &mut apack,
                ),
            }
            i0 = i1;
        }
        scratch.put_f32(apack);
    } else {
        // Parallel path: split C into disjoint bm-row bands and let the
        // pool's workers claim them; each worker checks its packing
        // buffer out of the shared arena and runs the identical
        // per-band code.  Packed B (when present) is shared read-only.
        let row_bands: Vec<(usize, &mut [f32])> =
            c.chunks_mut(bm * n).enumerate().collect();
        pool::run_parallel(workers, row_bands, |_, (band, cband)| {
            let i0 = band * bm;
            let i1 = (i0 + bm).min(m);
            let mut apack = scratch.take_f32(apack_len(params));
            match bpack {
                Some(bp) => gemm_band_packed(
                    a, bp, cband, n, k, i0, i1, params, isa, &mut apack,
                ),
                None => gemm_band(
                    a, b, cband, n, k, i0, i1, params, isa, &mut apack,
                ),
            }
            scratch.put_f32(apack);
        });
    }
}

/// Batched `C[i] = A[i] @ B[i]` for `batch` independent row-major GEMMs
/// of identical shape, concatenated slice-wise in all three operands —
/// the entry point Winograd's transform-domain multiplies lower onto
/// (paper §4.1.2: one GEMM per transform-domain position).
///
/// Each slice runs [`gemm_blocked_isa`] verbatim under the same `params`
/// and `isa`, so every batch element is bit-identical to a standalone
/// [`gemm_blocked_isa`] call on that slice — including across thread
/// counts.  `params.threads` parallelizes *inside* each GEMM over its
/// macro-tile bands when a slice has several; when each slice fits a
/// single `bm` band (the Winograd transform-domain batch of small
/// GEMMs), the band path degenerates to serial and the threads are
/// spent across the *batch* dimension instead — each worker owns a
/// disjoint per-batch output slice and runs the serial per-slice code,
/// preserving the crate's disjoint-output determinism (bit-identical
/// to the sequential loop for every thread count).
///
/// Panics on operand/shape mismatch or an unavailable `isa`, exactly
/// like [`gemm_blocked_isa`].
#[allow(clippy::too_many_arguments)]
pub fn gemm_batched_isa(
    a: &[f32],
    b: &[f32],
    batch: usize,
    m: usize,
    n: usize,
    k: usize,
    params: &BlockedParams,
    isa: Isa,
) -> Vec<f32> {
    gemm_batched_ex(
        a,
        b,
        batch,
        m,
        n,
        k,
        params,
        isa,
        Pack::A,
        &Scratch::new(),
    )
}

/// [`gemm_batched_isa`] with the [`Pack`] axis and a caller-owned
/// [`Scratch`] arena.  Under `Pack::Ab` every batch element's B panels
/// are packed **once, up front, in one pass** into a single arena
/// buffer and reused read-only by that element's GEMM — for Winograd
/// this is exactly "pack the U (filter-transform) panels once per call
/// and reuse them across the `(wino_m+2)²` transform-domain GEMMs",
/// instead of re-staging the operand inside each per-element GEMM.
/// Bit-identical to [`gemm_batched_isa`] per ISA (the packed twins read
/// the same values in the same order).
#[allow(clippy::too_many_arguments)]
pub fn gemm_batched_ex(
    a: &[f32],
    b: &[f32],
    batch: usize,
    m: usize,
    n: usize,
    k: usize,
    params: &BlockedParams,
    isa: Isa,
    pack: Pack,
    scratch: &Scratch,
) -> Vec<f32> {
    let mut c = vec![0.0f32; batch * m * n];
    gemm_batched_into(
        a, b, &mut c, batch, m, n, k, params, isa, pack, scratch,
    );
    c
}

/// [`gemm_batched_ex`] into a caller-supplied **pre-zeroed** output
/// buffer (the arena form Winograd's transform-domain multiply uses for
/// its M matrix).  Same validation, staging, and band driving — the
/// public entry point is this plus a `vec![0.0; batch*m*n]`.
#[allow(clippy::too_many_arguments)]
pub(crate) fn gemm_batched_into(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    batch: usize,
    m: usize,
    n: usize,
    k: usize,
    params: &BlockedParams,
    isa: Isa,
    pack: Pack,
    scratch: &Scratch,
) {
    assert_eq!(a.len(), batch * m * k, "batched A shape mismatch");
    assert_eq!(b.len(), batch * k * n, "batched B shape mismatch");
    assert!(
        params.bm > 0
            && params.bn > 0
            && params.bk > 0
            && params.mr > 0
            && params.nr > 0,
        "BlockedParams dims must be non-zero: {params:?}"
    );
    assert!(
        params.mr <= 16 && params.nr <= 16,
        "micro-tile exceeds the 16x16 register kernel cap: {params:?}"
    );
    assert!(
        isa.is_available(),
        "micro-kernel ISA {isa} is not available on this host \
         (detected: {:?}) — resolve the plan through the engine, which \
         degrades unavailable ISAs to scalar",
        Isa::detect()
    );
    debug_assert_eq!(c.len(), batch * m * n, "batched C shape mismatch");

    // Stage every element's B panels once per call (the shared-operand
    // hoist): one arena buffer, `batch` slots, packed in one pass.
    let slot = bpack_len(n, k, params);
    let bpack_all = if pack == Pack::Ab && slot > 0 && batch > 0 {
        let mut bp = scratch.take_f32(batch * slot);
        for (i, bslot) in bp.chunks_mut(slot).enumerate() {
            pack_b(&b[i * k * n..(i + 1) * k * n], bslot, n, k, params);
        }
        Some(bp)
    } else {
        None
    };

    let workers = pool::resolve_threads(params.threads);
    let bands = m.div_ceil(params.bm.max(1));
    if workers > 1 && batch > 1 && bands <= 1 && m * n > 0 {
        // Per-GEMM work is below the band-parallel threshold (a single
        // bm band), so inner parallelism would run every slice serially
        // anyway: spend the threads across the batch.  Each worker
        // computes whole slices with the serial per-GEMM path into its
        // disjoint chunk of C; the per-slice code is bit-identical
        // across thread counts, so this path is bit-identical to the
        // sequential loop below.
        let serial = BlockedParams { threads: 1, ..*params };
        let slices: Vec<(usize, &mut [f32])> =
            c.chunks_mut(m * n).enumerate().collect();
        pool::run_parallel(workers, slices, |_, (i, cslice)| {
            gemm_into_prepacked(
                &a[i * m * k..(i + 1) * m * k],
                &b[i * k * n..(i + 1) * k * n],
                bpack_all
                    .as_ref()
                    .map(|bp| &bp[i * slot..(i + 1) * slot]),
                cslice,
                m,
                n,
                k,
                &serial,
                isa,
                scratch,
            );
        });
    } else {
        for i in 0..batch {
            gemm_into_prepacked(
                &a[i * m * k..(i + 1) * m * k],
                &b[i * k * n..(i + 1) * k * n],
                bpack_all
                    .as_ref()
                    .map(|bp| &bp[i * slot..(i + 1) * slot]),
                &mut c[i * m * n..(i + 1) * m * n],
                m,
                n,
                k,
                params,
                isa,
                scratch,
            );
        }
    }
    if let Some(bp) = bpack_all {
        scratch.put_f32(bp);
    }
}

/// Length of the A macro-panel packing buffer for one `bm x bk` panel:
/// strips of `mr` rows, ragged strips zero-padded, so size for the
/// rounded-up strip count.
pub(crate) fn apack_len(params: &BlockedParams) -> usize {
    params.bm.max(params.mr).div_ceil(params.mr)
        * params.mr
        * params.bk.max(1)
}

/// Uniform packed-B panel slot: every `bk×bn` panel of an `n`-column
/// operand occupies `bk * strips * nr` elements, where `strips` is the
/// per-panel strip count of the *widest* panel (`min(bn, n)` columns
/// rounded up to whole `nr` strips).  Uniform slots make panel
/// addressing a multiply instead of a prefix sum.
pub(crate) fn bpack_panel_slot(n: usize, params: &BlockedParams) -> usize {
    params.bk * params.bn.min(n).div_ceil(params.nr) * params.nr
}

/// Total packed-B buffer length for a `k x n` operand under `params`:
/// one uniform slot per `(k-panel, column-panel)` pair.  Zero for
/// degenerate operands (nothing to pack).
pub(crate) fn bpack_len(
    n: usize,
    k: usize,
    params: &BlockedParams,
) -> usize {
    if n == 0 || k == 0 {
        return 0;
    }
    k.div_ceil(params.bk)
        * n.div_ceil(params.bn)
        * bpack_panel_slot(n, params)
}

/// Pack `B` (`k x n`, row-major) into BLIS-style panels:
/// `bpack` holds one slot per `(p0, j0)` macro-panel (see
/// [`bpack_len`]); within a panel, `nr`-column strips are contiguous —
/// strip `t` stores `B[p0 + p, j0 + t*nr + s]` at `t*(kc*nr) + p*nr +
/// s` — so a micro-kernel walks its strip with unit stride.  Ragged
/// strip columns are zero-padded; the pad is never read back (ragged
/// tiles read exactly `je - j` columns), zero just keeps the buffer
/// deterministic.
pub(crate) fn pack_b(
    b: &[f32],
    bpack: &mut [f32],
    n: usize,
    k: usize,
    params: &BlockedParams,
) {
    let &BlockedParams { bn, bk, nr, .. } = params;
    let jpanels = n.div_ceil(bn);
    let slot = bpack_panel_slot(n, params);
    for p0 in (0..k).step_by(bk) {
        let p1 = (p0 + bk).min(k);
        let kc = p1 - p0;
        for j0 in (0..n).step_by(bn) {
            let j1 = (j0 + bn).min(n);
            let base = ((p0 / bk) * jpanels + j0 / bn) * slot;
            let mut t = 0;
            let mut j = j0;
            while j < j1 {
                let je = (j + nr).min(j1);
                let off = base + t * (kc * nr);
                for p in 0..kc {
                    let row = (p0 + p) * n;
                    let dst = off + p * nr;
                    for (s, col) in (j..je).enumerate() {
                        bpack[dst + s] = b[row + col];
                    }
                    for s in (je - j)..nr {
                        bpack[dst + s] = 0.0;
                    }
                }
                t += 1;
                j = je;
            }
        }
    }
}

/// The worst-case arena take-set of one [`gemm_blocked_ex`] call: one
/// A-panel buffer per concurrently active band worker, plus the packed
/// B panels under [`Pack::Ab`].  Mirrors the execute path exactly so a
/// [`Scratch::prewarm`] with this workspace makes steady-state calls
/// allocation-free.
pub fn gemm_workspace(
    m: usize,
    n: usize,
    k: usize,
    params: &BlockedParams,
    pack: Pack,
) -> Workspace {
    let workers = pool::resolve_threads(params.threads);
    let bands = m.div_ceil(params.bm.max(1));
    let napack = if workers <= 1 || bands <= 1 || n == 0 {
        1
    } else {
        workers.min(bands)
    };
    let mut ws = Workspace::none();
    for _ in 0..napack {
        ws.f32_lens.push(apack_len(params));
    }
    if pack == Pack::Ab {
        ws.f32_lens.push(bpack_len(n, k, params));
    }
    ws
}

/// The worst-case arena take-set of one [`gemm_batched_ex`] call — the
/// batched analogue of [`gemm_workspace`] (one packed-B buffer covering
/// every element, A panels per concurrently active worker).
pub fn gemm_batched_workspace(
    batch: usize,
    m: usize,
    n: usize,
    k: usize,
    params: &BlockedParams,
    pack: Pack,
) -> Workspace {
    let workers = pool::resolve_threads(params.threads);
    let bands = m.div_ceil(params.bm.max(1));
    let napack = if workers > 1 && batch > 1 && bands <= 1 && m * n > 0 {
        workers.min(batch)
    } else if workers <= 1 || bands <= 1 || n == 0 {
        1
    } else {
        workers.min(bands)
    };
    let mut ws = Workspace::none();
    for _ in 0..napack {
        ws.f32_lens.push(apack_len(params));
    }
    if pack == Pack::Ab {
        ws.f32_lens.push(batch * bpack_len(n, k, params));
    }
    ws
}

/// One `bm`-row macro-tile band: `cband = A[i0..i1, :] @ B`, with
/// `cband` the band's rows of C (`(i1 - i0) x n`, row-major).  This is
/// the unit of parallelism — the serial path calls it per band in order,
/// the pool calls it per band concurrently; the code is shared so the
/// two are bit-identical by construction.
#[allow(clippy::too_many_arguments)]
fn gemm_band(
    a: &[f32],
    b: &[f32],
    cband: &mut [f32],
    n: usize,
    k: usize,
    i0: usize,
    i1: usize,
    params: &BlockedParams,
    isa: Isa,
    apack: &mut [f32],
) {
    let &BlockedParams { bn, bk, mr, nr, .. } = params;
    for p0 in (0..k).step_by(bk) {
        let p1 = (p0 + bk).min(k);
        pack_a(a, apack, k, i0, i1, p0, p1, mr);
        for j0 in (0..n).step_by(bn) {
            let j1 = (j0 + bn).min(n);
            // Macro-tile: micro-kernels over mr x nr register tiles.
            let mut i = i0;
            while i < i1 {
                let ie = (i + mr).min(i1);
                let strip = ((i - i0) / mr) * (mr * (p1 - p0));
                // Row index within the band's slice of C.
                let il = i - i0;
                let mut j = j0;
                while j < j1 {
                    let je = (j + nr).min(j1);
                    // Full tiles of a registry shape go through their
                    // monomorphized kernel, whose accumulator stays in
                    // registers (EXPERIMENTS.md §Perf blas-2); ragged
                    // edges and unregistered shapes take the generic
                    // path.
                    let full = ie - i == mr && je - j == nr;
                    dispatch_micro_kernel(
                        full, mr, nr, isa, &apack[strip..], b, cband, n,
                        il, il + (ie - i), j, je, p0, p1,
                    );
                    j = je;
                }
                i = ie;
            }
        }
    }
}

/// The packed-B twin of [`gemm_band`]: identical loop structure (and so
/// identical accumulation order — the bit-identity contract), but each
/// register tile reads its `kc×nr` strip of the shared packed B panels
/// ([`pack_b`] layout) instead of striding through B.  The packing was
/// done once per call; every row band of every worker reuses it
/// read-only.
#[allow(clippy::too_many_arguments)]
fn gemm_band_packed(
    a: &[f32],
    bpack: &[f32],
    cband: &mut [f32],
    n: usize,
    k: usize,
    i0: usize,
    i1: usize,
    params: &BlockedParams,
    isa: Isa,
    apack: &mut [f32],
) {
    let &BlockedParams { bn, bk, mr, nr, .. } = params;
    let jpanels = n.div_ceil(bn.max(1));
    let slot = bpack_panel_slot(n, params);
    for p0 in (0..k).step_by(bk) {
        let p1 = (p0 + bk).min(k);
        let kc = p1 - p0;
        pack_a(a, apack, k, i0, i1, p0, p1, mr);
        for j0 in (0..n).step_by(bn) {
            let j1 = (j0 + bn).min(n);
            let pbase = ((p0 / bk) * jpanels + j0 / bn) * slot;
            let mut i = i0;
            while i < i1 {
                let ie = (i + mr).min(i1);
                let strip = ((i - i0) / mr) * (mr * kc);
                let il = i - i0;
                let mut j = j0;
                while j < j1 {
                    let je = (j + nr).min(j1);
                    let full = ie - i == mr && je - j == nr;
                    let boff = pbase + ((j - j0) / nr) * (kc * nr);
                    dispatch_micro_kernel_pb(
                        full,
                        mr,
                        nr,
                        isa,
                        &apack[strip..],
                        &bpack[boff..],
                        cband,
                        n,
                        il,
                        il + (ie - i),
                        j,
                        je,
                        kc,
                    );
                    j = je;
                }
                i = ie;
            }
        }
    }
}

/// Pack `A[i0..i1, p0..p1]` into `mr`-row strips, k-major within each
/// strip: `apack[strip][p * mr + r] = A[i0 + strip*mr + r, p0 + p]`.
fn pack_a(
    a: &[f32],
    apack: &mut [f32],
    k: usize,
    i0: usize,
    i1: usize,
    p0: usize,
    p1: usize,
    mr: usize,
) {
    let kc = p1 - p0;
    let mut out = 0;
    let mut i = i0;
    while i < i1 {
        let rows = (i + mr).min(i1) - i;
        for p in 0..kc {
            for r in 0..rows {
                apack[out] = a[(i + r) * k + p0 + p];
                out += 1;
            }
            // Zero-fill ragged strips so the kernel stays branch-free.
            for _ in rows..mr {
                apack[out] = 0.0;
                out += 1;
            }
        }
        i += mr;
    }
}

/// Monomorphized micro-kernel for full `MR x NR` tiles: fixed trip
/// counts let LLVM keep the whole accumulator in vector registers.
/// `c` is the current band's slice of the output; `i` is the row within
/// that band.  `#[inline(always)]` so the `#[target_feature]` wrappers
/// in `blas::simd` inline this body and recompile it at their feature
/// level (the multiversioning trick — same operations, wider lanes,
/// bit-identical results).
#[inline(always)]
#[allow(clippy::too_many_arguments)]
pub(crate) fn micro_kernel_fixed<const MR: usize, const NR: usize>(
    apack: &[f32],
    b: &[f32],
    c: &mut [f32],
    n: usize,
    i: usize,
    j: usize,
    p0: usize,
    p1: usize,
) {
    let mut acc = [[0.0f32; NR]; MR];
    for p in 0..(p1 - p0) {
        let brow: &[f32] = &b[(p0 + p) * n + j..(p0 + p) * n + j + NR];
        let astrip = &apack[p * MR..(p + 1) * MR];
        for r in 0..MR {
            let aip = astrip[r];
            for s in 0..NR {
                acc[r][s] += aip * brow[s];
            }
        }
    }
    for (r, accr) in acc.iter().enumerate() {
        let crow = &mut c[(i + r) * n + j..(i + r) * n + j + NR];
        for s in 0..NR {
            crow[s] += accr[s];
        }
    }
}

/// The packed-B twin of [`micro_kernel_fixed`]: `bstrip` is this tile's
/// `kc×NR` strip of the packed panel (`bstrip[p*NR + s]` = `B[p0 + p,
/// j + s]`), read with unit stride.  The loop nest — `p`, then `r`,
/// then `s` — and therefore every multiply-add's order is identical to
/// the unpacked kernel, so outputs are bit-identical (0 ULP).
/// `#[inline(always)]` for the same `#[target_feature]` multiversioning
/// trick.
#[inline(always)]
#[allow(clippy::too_many_arguments)]
pub(crate) fn micro_kernel_fixed_pb<const MR: usize, const NR: usize>(
    apack: &[f32],
    bstrip: &[f32],
    c: &mut [f32],
    n: usize,
    i: usize,
    j: usize,
    kc: usize,
) {
    let mut acc = [[0.0f32; NR]; MR];
    for p in 0..kc {
        let brow: &[f32] = &bstrip[p * NR..(p + 1) * NR];
        let astrip = &apack[p * MR..(p + 1) * MR];
        for r in 0..MR {
            let aip = astrip[r];
            for s in 0..NR {
                acc[r][s] += aip * brow[s];
            }
        }
    }
    for (r, accr) in acc.iter().enumerate() {
        let crow = &mut c[(i + r) * n + j..(i + r) * n + j + NR];
        for s in 0..NR {
            crow[s] += accr[s];
        }
    }
}

/// The register micro-kernel: accumulate `C[i..ie, j..je] += Apack_strip
/// @ B[p0..p1, j..je]` with accumulators held in a fixed-size stack tile
/// (the "registers" of the device kernel).  `apack` points at the strip:
/// `apack[p * mr + r]` is the packed A value for band-local row `i + r`
/// at depth `p0 + p` — sequential in the p-loop.  `c` is the band slice;
/// `i..ie` are rows within it.
#[inline]
#[allow(clippy::too_many_arguments)]
fn micro_kernel(
    apack: &[f32],
    b: &[f32],
    c: &mut [f32],
    n: usize,
    i: usize,
    ie: usize,
    j: usize,
    je: usize,
    p0: usize,
    p1: usize,
    mr: usize,
) {
    // Max micro-tile is 16x16; callers keep mr<=16, nr<=16 (the registry
    // tops out at (16, 16)).
    let mut acc = [[0.0f32; 16]; 16];
    let (mh, nw) = (ie - i, je - j);
    debug_assert!(mh <= 16 && nw <= 16);
    for p in 0..(p1 - p0) {
        let brow = &b[(p0 + p) * n + j..(p0 + p) * n + je];
        let astrip = &apack[p * mr..p * mr + mh];
        for (r, (accr, aip)) in
            acc.iter_mut().zip(astrip.iter()).enumerate()
        {
            let _ = r;
            for (s, bv) in brow.iter().enumerate() {
                accr[s] += aip * bv;
            }
        }
    }
    for r in 0..mh {
        let crow = &mut c[(i + r) * n + j..(i + r) * n + je];
        for (s, cv) in crow.iter_mut().enumerate() {
            *cv += acc[r][s];
        }
    }
    let _ = nw;
}

/// The packed-B twin of the generic [`micro_kernel`] (ragged edges and
/// unregistered shapes): reads `je - j` columns from the strip's `nr`-
/// wide rows — the zero pad beyond a ragged edge is never touched.
/// Same accumulation order as the unpacked generic kernel: bit-identical.
#[inline]
#[allow(clippy::too_many_arguments)]
fn micro_kernel_pb(
    apack: &[f32],
    bstrip: &[f32],
    c: &mut [f32],
    n: usize,
    i: usize,
    ie: usize,
    j: usize,
    je: usize,
    kc: usize,
    mr: usize,
    nr: usize,
) {
    let mut acc = [[0.0f32; 16]; 16];
    let (mh, nw) = (ie - i, je - j);
    debug_assert!(mh <= 16 && nw <= 16);
    for p in 0..kc {
        let brow = &bstrip[p * nr..p * nr + nw];
        let astrip = &apack[p * mr..p * mr + mh];
        for (accr, aip) in acc.iter_mut().zip(astrip.iter()) {
            for (s, bv) in brow.iter().enumerate() {
                accr[s] += aip * bv;
            }
        }
    }
    for r in 0..mh {
        let crow = &mut c[(i + r) * n + j..(i + r) * n + je];
        for (s, cv) in crow.iter_mut().enumerate() {
            *cv += acc[r][s];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blas::{gemm_naive, max_abs_diff};

    #[test]
    fn odd_blocking_params_still_correct() {
        let m = 37;
        let n = 29;
        let k = 23;
        let a: Vec<f32> = (0..m * k).map(|i| (i % 7) as f32 - 3.0).collect();
        let b: Vec<f32> = (0..k * n).map(|i| (i % 5) as f32 - 2.0).collect();
        let expected = gemm_naive(&a, &b, m, n, k);
        for params in [
            BlockedParams { bm: 8, bn: 8, bk: 8, mr: 2, nr: 2, threads: 1 },
            BlockedParams { bm: 16, bn: 32, bk: 5, mr: 4, nr: 8, threads: 2 },
            BlockedParams {
                bm: 64, bn: 64, bk: 64, mr: 8, nr: 16, threads: 0,
            },
        ] {
            let got = gemm_blocked(&a, &b, m, n, k, &params);
            assert!(max_abs_diff(&expected, &got) < 1e-4, "{params:?}");
        }
    }

    #[test]
    fn parallel_bands_bit_identical_to_serial() {
        // More bands than the default bm would give: force bm small so
        // every thread count actually splits the row range.
        let (m, n, k) = (53, 31, 19);
        let a: Vec<f32> = (0..m * k).map(|i| (i % 11) as f32 - 5.0).collect();
        let b: Vec<f32> = (0..k * n).map(|i| (i % 13) as f32 - 6.0).collect();
        let base =
            BlockedParams { bm: 8, bn: 16, bk: 8, mr: 4, nr: 8, threads: 1 };
        let serial = gemm_blocked(&a, &b, m, n, k, &base);
        for threads in [0usize, 2, 3, 8, 64] {
            let par = gemm_blocked(
                &a,
                &b,
                m,
                n,
                k,
                &BlockedParams { threads, ..base },
            );
            assert!(
                serial == par,
                "threads={threads} diverged from serial (max diff {})",
                max_abs_diff(&serial, &par)
            );
        }
    }

    #[test]
    fn config_name_roundtrips_the_knobs() {
        let p = BlockedParams { bm: 32, bn: 48, bk: 8, mr: 2, nr: 4, threads: 3 };
        assert_eq!(p.name(), "bm32bn48bk8_2x4_t3");
        assert_eq!(BlockedParams::default().name(), "bm64bn64bk64_4x8_t0");
    }

    #[test]
    #[should_panic(expected = "non-zero")]
    fn zero_block_dim_is_a_loud_panic() {
        let params = BlockedParams { bm: 0, ..Default::default() };
        gemm_blocked(&[1.0], &[1.0], 1, 1, 1, &params);
    }

    #[test]
    #[should_panic(expected = "register kernel cap")]
    fn oversized_micro_tile_is_a_loud_panic() {
        let params = BlockedParams { mr: 32, ..Default::default() };
        gemm_blocked(&[1.0], &[1.0], 1, 1, 1, &params);
    }

    #[test]
    fn registry_covers_the_advertised_cross() {
        // The macro invocation is the source of truth; this pins the
        // contract the tuner grids rely on: at least {2,4,8,16}x{4,8,16}.
        for mr in [2usize, 4, 8, 16] {
            for nr in [4usize, 8, 16] {
                assert!(
                    MICRO_KERNEL_SHAPES.contains(&(mr, nr)),
                    "({mr}, {nr}) missing from the registry"
                );
                let p = BlockedParams { mr, nr, ..Default::default() };
                assert!(p.is_monomorphized());
            }
        }
        assert!(!BlockedParams { mr: 3, nr: 5, ..Default::default() }
            .is_monomorphized());
        // No duplicates: dedup discipline for grid construction.
        for (i, s) in MICRO_KERNEL_SHAPES.iter().enumerate() {
            assert!(!MICRO_KERNEL_SHAPES[i + 1..].contains(s));
        }
    }

    #[test]
    fn isa_scalar_is_the_gemm_blocked_path() {
        // gemm_blocked IS gemm_blocked_isa(Scalar): bit-equal outputs.
        let (m, n, k) = (23, 17, 11);
        let a: Vec<f32> = (0..m * k).map(|i| (i % 7) as f32 - 3.0).collect();
        let b: Vec<f32> = (0..k * n).map(|i| (i % 5) as f32 - 2.0).collect();
        let params = BlockedParams { threads: 1, ..Default::default() };
        assert!(
            gemm_blocked(&a, &b, m, n, k, &params)
                == gemm_blocked_isa(&a, &b, m, n, k, &params, Isa::Scalar)
        );
    }

    #[test]
    fn detected_isa_kernels_agree_with_scalar() {
        // Ragged shape so full registry tiles (SIMD path) and ragged
        // edges (scalar bit-fallback) both run.  SSE2/AVX2 recompile the
        // same operation order, so 0 ULP; FMA fuses the rounding, so an
        // accumulation tolerance scaled by k.
        let (m, n, k) = (37, 29, 23);
        let a: Vec<f32> = (0..m * k).map(|i| (i % 7) as f32 - 3.0).collect();
        let b: Vec<f32> = (0..k * n).map(|i| (i % 5) as f32 - 2.0).collect();
        for &(mr, nr) in MICRO_KERNEL_SHAPES {
            let params = BlockedParams {
                bm: 32,
                bn: 32,
                bk: 16,
                mr,
                nr,
                threads: 1,
            };
            let scalar = gemm_blocked(&a, &b, m, n, k, &params);
            for isa in Isa::detect() {
                let got = gemm_blocked_isa(&a, &b, m, n, k, &params, isa);
                // Avx512 dispatches the FMA kernel, so it shares FMA's
                // fused-rounding tolerance contract.
                if matches!(isa, Isa::Fma | Isa::Avx512) {
                    assert!(
                        max_abs_diff(&scalar, &got)
                            <= 1e-6 * k as f32,
                        "fma beyond tolerance for ({mr}, {nr})"
                    );
                } else {
                    assert!(
                        scalar == got,
                        "{isa} not bit-identical to scalar for ({mr}, {nr})"
                    );
                }
            }
        }
    }

    #[test]
    fn isa_parallel_bands_bit_identical_to_serial() {
        // The ISA axis composes with the threads axis: every detected
        // ISA is bit-identical across thread counts (disjoint bands run
        // the same per-band code).
        let (m, n, k) = (53, 31, 19);
        let a: Vec<f32> = (0..m * k).map(|i| (i % 11) as f32 - 5.0).collect();
        let b: Vec<f32> = (0..k * n).map(|i| (i % 13) as f32 - 6.0).collect();
        let base =
            BlockedParams { bm: 8, bn: 16, bk: 8, mr: 4, nr: 8, threads: 1 };
        for isa in Isa::detect() {
            let serial = gemm_blocked_isa(&a, &b, m, n, k, &base, isa);
            for threads in [2usize, 3, 8] {
                let par = gemm_blocked_isa(
                    &a,
                    &b,
                    m,
                    n,
                    k,
                    &BlockedParams { threads, ..base },
                    isa,
                );
                assert!(serial == par, "{isa} threads={threads} diverged");
            }
        }
    }

    #[test]
    fn unavailable_isa_is_a_loud_panic_not_ub() {
        // On hosts that lack some ISA (always true off x86-64, and on
        // pre-AVX2 x86), dispatching it must panic loudly instead of
        // reaching a #[target_feature] kernel the CPU cannot run.
        if let Some(missing) =
            Isa::all().into_iter().find(|i| !i.is_available())
        {
            let params =
                BlockedParams { threads: 1, ..BlockedParams::default() };
            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                || gemm_blocked_isa(&[1.0], &[1.0], 1, 1, 1, &params, missing),
            ));
            assert!(r.is_err(), "{missing} should have panicked");
        }
    }

    #[test]
    fn packed_b_bit_identical_to_unpacked_per_isa() {
        // The tentpole contract: pack:ab reads the same values in the
        // same floating-point order as pack:a, so outputs are 0 ULP for
        // EVERY ISA (including FMA — both pack settings run the same
        // fused kernel structure).  Ragged shape so the monomorphized,
        // generic, and edge paths all run.
        let scratch = Scratch::new();
        for &(m, n, k) in &[
            (1usize, 1usize, 1usize),
            (17, 13, 9),
            (37, 29, 23),
            (64, 64, 64),
            (5, 64, 3),
        ] {
            let a: Vec<f32> =
                (0..m * k).map(|i| (i % 7) as f32 - 3.0).collect();
            let b: Vec<f32> =
                (0..k * n).map(|i| (i % 5) as f32 - 2.0).collect();
            for &(mr, nr) in
                &[(2usize, 4usize), (4, 8), (8, 16), (3, 5), (16, 16)]
            {
                let params = BlockedParams {
                    bm: 16,
                    bn: 16,
                    bk: 8,
                    mr,
                    nr,
                    threads: 1,
                };
                for isa in Isa::detect() {
                    let unpacked = gemm_blocked_ex(
                        &a, &b, m, n, k, &params, isa, Pack::A, &scratch,
                    );
                    let packed = gemm_blocked_ex(
                        &a, &b, m, n, k, &params, isa, Pack::Ab, &scratch,
                    );
                    assert!(
                        unpacked == packed,
                        "{m}x{n}x{k} ({mr},{nr}) {isa}: pack:ab not \
                         bit-identical to pack:a"
                    );
                }
            }
        }
    }

    #[test]
    fn packed_b_threaded_bit_identical_to_serial() {
        // pack:ab composes with the threads axis: the packed panels are
        // shared read-only across bands, and every thread count is
        // bit-identical to serial.
        let scratch = Scratch::new();
        let (m, n, k) = (53, 31, 19);
        let a: Vec<f32> = (0..m * k).map(|i| (i % 11) as f32 - 5.0).collect();
        let b: Vec<f32> = (0..k * n).map(|i| (i % 13) as f32 - 6.0).collect();
        let base =
            BlockedParams { bm: 8, bn: 16, bk: 8, mr: 4, nr: 8, threads: 1 };
        for isa in Isa::detect() {
            let serial = gemm_blocked_ex(
                &a, &b, m, n, k, &base, isa, Pack::Ab, &scratch,
            );
            for threads in [0usize, 2, 3, 8] {
                let par = gemm_blocked_ex(
                    &a,
                    &b,
                    m,
                    n,
                    k,
                    &BlockedParams { threads, ..base },
                    isa,
                    Pack::Ab,
                    &scratch,
                );
                assert!(
                    serial == par,
                    "{isa} threads={threads} packed diverged"
                );
            }
        }
    }

    #[test]
    fn pack_name_roundtrip() {
        for p in Pack::all() {
            assert_eq!(p.to_string().parse::<Pack>().unwrap(), p);
        }
        assert_eq!(Pack::A.as_str(), "a");
        assert_eq!(Pack::Ab.as_str(), "ab");
        assert!("b".parse::<Pack>().is_err());
        assert_eq!(Pack::default(), Pack::A);
    }

    #[test]
    fn pack_b_layout_roundtrips_every_value() {
        // Every B element lands exactly where gemm_band_packed's strip
        // arithmetic expects it: panel (p0/bk, j0/bn), strip (j-j0)/nr,
        // offset p*nr + (j % nr within the strip).
        let (n, k) = (13usize, 11usize);
        let params =
            BlockedParams { bm: 8, bn: 8, bk: 4, mr: 2, nr: 4, threads: 1 };
        let b: Vec<f32> = (0..k * n).map(|i| i as f32).collect();
        let mut bp = vec![-1.0f32; bpack_len(n, k, &params)];
        pack_b(&b, &mut bp, n, k, &params);
        let jpanels = n.div_ceil(params.bn);
        let slot = bpack_panel_slot(n, &params);
        for p in 0..k {
            let p0 = (p / params.bk) * params.bk;
            let kc = (p0 + params.bk).min(k) - p0;
            for j in 0..n {
                let j0 = (j / params.bn) * params.bn;
                let base = ((p0 / params.bk) * jpanels + j0 / params.bn)
                    * slot;
                let t = (j - j0) / params.nr;
                let s = (j - j0) % params.nr;
                let got =
                    bp[base + t * (kc * params.nr) + (p - p0) * params.nr + s];
                assert_eq!(got, b[p * n + j], "B[{p},{j}] misplaced");
            }
        }
    }

    #[test]
    fn gemm_workspace_prewarm_makes_calls_allocation_free() {
        // Prewarming with the computed workspace must cover the real
        // take-set: subsequent calls never grow the arena.
        let (m, n, k) = (37, 29, 23);
        let a: Vec<f32> = (0..m * k).map(|i| (i % 7) as f32 - 3.0).collect();
        let b: Vec<f32> = (0..k * n).map(|i| (i % 5) as f32 - 2.0).collect();
        for params in [
            BlockedParams { bm: 8, bn: 8, bk: 8, mr: 2, nr: 4, threads: 1 },
            BlockedParams { bm: 8, bn: 16, bk: 8, mr: 4, nr: 8, threads: 3 },
        ] {
            for pack in Pack::all() {
                let scratch = Scratch::new();
                scratch
                    .prewarm(&gemm_workspace(m, n, k, &params, pack));
                let grows = scratch.stats().grows;
                for _ in 0..3 {
                    gemm_blocked_ex(
                        &a, &b, m, n, k, &params, Isa::Scalar, pack,
                        &scratch,
                    );
                }
                assert_eq!(
                    scratch.stats().grows,
                    grows,
                    "steady state grew the arena ({params:?}, {pack})"
                );
            }
        }
    }

    #[test]
    fn batched_gemm_is_slicewise_bit_identical() {
        // Each batch element must equal a standalone gemm_blocked_isa
        // call on its slice, bit for bit, for every detected ISA and
        // across thread counts.
        let (batch, m, n, k) = (5, 13, 11, 7);
        let a: Vec<f32> =
            (0..batch * m * k).map(|i| (i % 9) as f32 - 4.0).collect();
        let b: Vec<f32> =
            (0..batch * k * n).map(|i| (i % 7) as f32 - 3.0).collect();
        let base =
            BlockedParams { bm: 8, bn: 8, bk: 4, mr: 2, nr: 4, threads: 1 };
        for isa in Isa::detect() {
            for threads in [1usize, 0, 3] {
                let params = BlockedParams { threads, ..base };
                let c = gemm_batched_isa(&a, &b, batch, m, n, k, &params, isa);
                assert_eq!(c.len(), batch * m * n);
                for i in 0..batch {
                    let solo = gemm_blocked_isa(
                        &a[i * m * k..(i + 1) * m * k],
                        &b[i * k * n..(i + 1) * k * n],
                        m,
                        n,
                        k,
                        &params,
                        isa,
                    );
                    assert!(
                        c[i * m * n..(i + 1) * m * n] == solo[..],
                        "{isa} threads={threads} batch element {i} diverged"
                    );
                }
            }
        }
    }

    #[test]
    fn batched_gemm_matches_naive_per_slice() {
        let (batch, m, n, k) = (3, 6, 5, 4);
        let a: Vec<f32> =
            (0..batch * m * k).map(|i| (i % 5) as f32 - 2.0).collect();
        let b: Vec<f32> =
            (0..batch * k * n).map(|i| (i % 3) as f32 - 1.0).collect();
        let params = BlockedParams { threads: 1, ..Default::default() };
        let c =
            gemm_batched_isa(&a, &b, batch, m, n, k, &params, Isa::Scalar);
        for i in 0..batch {
            let naive = gemm_naive(
                &a[i * m * k..(i + 1) * m * k],
                &b[i * k * n..(i + 1) * k * n],
                m,
                n,
                k,
            );
            assert!(
                max_abs_diff(&c[i * m * n..(i + 1) * m * n], &naive) < 1e-5,
                "batch element {i}"
            );
        }
    }

    #[test]
    fn batched_gemm_batch_parallel_path_bit_identical() {
        // Slices smaller than one bm band take the batch-parallel path
        // (threads spent across the batch); it must be bit-identical to
        // the sequential loop for every detected ISA and thread count.
        let (batch, m, n, k) = (7, 6, 5, 4);
        let a: Vec<f32> =
            (0..batch * m * k).map(|i| (i % 9) as f32 - 4.0).collect();
        let b: Vec<f32> =
            (0..batch * k * n).map(|i| (i % 7) as f32 - 3.0).collect();
        let base = BlockedParams {
            bm: 16, bn: 16, bk: 8, mr: 2, nr: 4, threads: 1,
        };
        assert!(m <= base.bm, "test premise: one band per slice");
        for isa in Isa::detect() {
            let serial =
                gemm_batched_isa(&a, &b, batch, m, n, k, &base, isa);
            for threads in [0usize, 2, 3, 8] {
                let par = gemm_batched_isa(
                    &a,
                    &b,
                    batch,
                    m,
                    n,
                    k,
                    &BlockedParams { threads, ..base },
                    isa,
                );
                assert!(
                    serial == par,
                    "{isa} threads={threads} batch-parallel diverged"
                );
            }
        }
    }

    #[test]
    fn batched_packed_b_bit_identical_to_unpacked() {
        // pack:ab on the batched entry point: the per-element U panels
        // are staged once up front, and every (ISA, thread count) is
        // bit-identical to the unpacked batched GEMM — both the
        // sequential and the batch-parallel path.
        let scratch = Scratch::new();
        let (batch, m, n, k) = (7, 6, 5, 4);
        let a: Vec<f32> =
            (0..batch * m * k).map(|i| (i % 9) as f32 - 4.0).collect();
        let b: Vec<f32> =
            (0..batch * k * n).map(|i| (i % 7) as f32 - 3.0).collect();
        let base = BlockedParams {
            bm: 16, bn: 16, bk: 8, mr: 2, nr: 4, threads: 1,
        };
        for isa in Isa::detect() {
            for threads in [1usize, 0, 3] {
                let params = BlockedParams { threads, ..base };
                let unpacked = gemm_batched_isa(
                    &a, &b, batch, m, n, k, &params, isa,
                );
                let packed = gemm_batched_ex(
                    &a,
                    &b,
                    batch,
                    m,
                    n,
                    k,
                    &params,
                    isa,
                    Pack::Ab,
                    &scratch,
                );
                assert!(
                    unpacked == packed,
                    "{isa} threads={threads} batched pack:ab diverged"
                );
            }
        }
        // And the workspace covers the take-set.
        let fresh = Scratch::new();
        fresh.prewarm(&gemm_batched_workspace(
            batch,
            m,
            n,
            k,
            &BlockedParams { threads: 3, ..base },
            Pack::Ab,
        ));
        let grows = fresh.stats().grows;
        gemm_batched_ex(
            &a,
            &b,
            batch,
            m,
            n,
            k,
            &BlockedParams { threads: 3, ..base },
            Isa::Scalar,
            Pack::Ab,
            &fresh,
        );
        assert_eq!(fresh.stats().grows, grows, "batched call grew arena");
    }

    #[test]
    #[should_panic(expected = "batched A shape mismatch")]
    fn batched_gemm_rejects_short_operands() {
        gemm_batched_isa(
            &[1.0; 3],
            &[1.0; 4],
            2,
            1,
            1,
            2,
            &BlockedParams::default(),
            Isa::Scalar,
        );
    }

    #[test]
    fn every_registry_shape_is_correct_on_ragged_dims() {
        // 37x29x23 leaves ragged edges for every registry shape, so both
        // the monomorphized kernel (interior) and the generic kernel
        // (edges) run for each (mr, nr).
        let (m, n, k) = (37, 29, 23);
        let a: Vec<f32> = (0..m * k).map(|i| (i % 7) as f32 - 3.0).collect();
        let b: Vec<f32> = (0..k * n).map(|i| (i % 5) as f32 - 2.0).collect();
        let expected = gemm_naive(&a, &b, m, n, k);
        for &(mr, nr) in MICRO_KERNEL_SHAPES {
            let params = BlockedParams {
                bm: 32,
                bn: 32,
                bk: 16,
                mr,
                nr,
                threads: 1,
            };
            let got = gemm_blocked(&a, &b, m, n, k, &params);
            assert!(max_abs_diff(&expected, &got) < 1e-4, "{params:?}");
        }
    }
}
