//! Quantized int8 GEMM stack: the `dtype` axis of the kernel space.
//!
//! The paper's parametrization covers tile shapes, algorithms, threads,
//! and the ISA; precision is the remaining performance-critical axis.
//! This module adds it for the host: i8×i8→i32 accumulation GEMM with
//! per-tensor affine quantization (`real = scale · (q - zero_point)`),
//! riding the *same* blocked macro-tiling, packing, thread pool, and ISA
//! dispatch as the f32 stack in `blas::blocked` — the int8 kernels are a
//! second micro-kernel family behind the same knobs, not a parallel
//! implementation.
//!
//! Numerics: integer accumulation is **exact** — every kernel variant
//! (scalar widening loop, AVX2 widening dot product, any thread count)
//! computes the identical `i32` result bit for bit, because integer adds
//! are associative.  The AVX2 kernel widens `i8 → i16` with
//! `_mm256_cvtepi8_epi16` and reduces k-step *pairs* with
//! `_mm256_madd_epi16` (each 32-bit lane gets `a_p·b_p + a_{p+1}·b_{p+1}`
//! of i16 operands — products cap at 128², so the pairwise sum caps at
//! 2·2¹⁴ and can never saturate, unlike a true u8×i8 `maddubs` whose i16
//! pair sums can).  The only overflow hazard left is the `i32`
//! accumulator itself, which is why [`gemm_i8_blocked_isa`] bounds `k`
//! loudly ([`MAX_I8_GEMM_K`]).
//!
//! The dequantize epilogue applies the per-tensor zero-point corrections
//! from row/column sums:
//! `Σ (a-za)(b-zb) = Σ a·b − zb·Σa − za·Σb + k·za·zb`, then scales by
//! `scale_a · scale_b` — so the padded entries of the quantized im2col
//! patch matrix (filled with the input zero-point) contribute exactly
//! zero, matching the f32 path's zero padding.

use super::blocked::{
    apack_len, bpack_len, bpack_panel_slot, BlockedParams, Pack,
};
use super::{Conv2dShape, Isa};
use crate::error::{Error, Result};
use crate::util::pool;
use crate::util::scratch::{Scratch, Workspace};

#[cfg(target_arch = "x86_64")]
use std::arch::x86_64::{
    __m128i, __m256i, _mm256_add_epi32, _mm256_loadu_si256,
    _mm256_madd_epi16, _mm256_set1_epi32, _mm256_set_m128i,
    _mm256_setzero_si256, _mm256_storeu_si256, _mm_add_epi32,
    _mm_cvtepi8_epi16, _mm_cvtsi32_si128, _mm_loadl_epi64,
    _mm_loadu_si128, _mm_madd_epi16, _mm_set1_epi32, _mm_setzero_si128,
    _mm_storeu_si128, _mm_unpackhi_epi16, _mm_unpacklo_epi16,
};

/// The element-type axis of the kernel space: which precision the
/// GEMM/conv micro-kernels compute in.  `F32` is the historical (and
/// default) family; `I8` runs the quantized stack in this module and
/// requires quantization metadata on the artifact (the plan layer
/// degrades `I8` to `F32` when an artifact has none — the precision
/// analogue of the unavailable-ISA scalar degrade).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord, Default)]
pub enum Dtype {
    /// 32-bit float kernels (the historical family).
    #[default]
    F32,
    /// Quantized int8 kernels: i8×i8→i32 accumulation with per-tensor
    /// scale/zero-point dequantize.
    I8,
}

impl Dtype {
    /// Every dtype value, in sweep/report order (f32 first).
    pub fn all() -> [Dtype; 2] {
        [Dtype::F32, Dtype::I8]
    }

    /// Stable lowercase name (selection DB, reports, CLI).
    pub fn as_str(&self) -> &'static str {
        match self {
            Dtype::F32 => "f32",
            Dtype::I8 => "i8",
        }
    }
}

impl std::fmt::Display for Dtype {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

impl std::str::FromStr for Dtype {
    type Err = Error;
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i8" => Ok(Dtype::I8),
            other => Err(Error::Config(format!("unknown dtype {other:?}"))),
        }
    }
}

/// Per-tensor affine quantization parameters:
/// `real = scale · (q - zero_point)`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QuantParams {
    /// Step between adjacent quantized values (must be positive).
    pub scale: f32,
    /// The quantized value representing real 0 (within i8 range).
    pub zero_point: i32,
}

impl QuantParams {
    /// Parameters covering `[lo, hi]` with the full i8 range.  The range
    /// is widened to include 0 so real zero is exactly representable
    /// (the property the zero-point padding of the quantized im2col
    /// patch matrix relies on).  A degenerate (empty or single-point)
    /// range quantizes everything to the zero point with unit scale.
    pub fn from_range(lo: f32, hi: f32) -> Self {
        let lo = lo.min(0.0);
        let hi = hi.max(0.0);
        if !(hi > lo) || !lo.is_finite() || !hi.is_finite() {
            return Self { scale: 1.0, zero_point: 0 };
        }
        let scale = (hi - lo) / 255.0;
        let zp = (-128.0 - lo / scale).round();
        Self { scale, zero_point: zp.clamp(-128.0, 127.0) as i32 }
    }

    /// Parameters covering the min/max of `data` (see
    /// [`QuantParams::from_range`]).
    pub fn for_data(data: &[f32]) -> Self {
        let mut lo = 0.0f32;
        let mut hi = 0.0f32;
        for &x in data {
            lo = lo.min(x);
            hi = hi.max(x);
        }
        Self::from_range(lo, hi)
    }

    /// Quantize one value: `round(x / scale) + zero_point`, saturated to
    /// the i8 range.
    pub fn quantize(&self, x: f32) -> i8 {
        let q = (x / self.scale).round() + self.zero_point as f32;
        q.clamp(-128.0, 127.0) as i8
    }

    /// Dequantize one value: `scale · (q - zero_point)`.
    pub fn dequantize(&self, q: i8) -> f32 {
        self.scale * (q as i32 - self.zero_point) as f32
    }
}

/// Quantize a slice under `q` (element-wise [`QuantParams::quantize`]).
pub fn quantize_slice(xs: &[f32], q: &QuantParams) -> Vec<i8> {
    xs.iter().map(|&x| q.quantize(x)).collect()
}

/// [`quantize_slice`] into a caller-supplied buffer (the arena form —
/// same values, no allocation).  `out.len()` must equal `xs.len()`.
pub fn quantize_into(xs: &[f32], q: &QuantParams, out: &mut [i8]) {
    assert_eq!(xs.len(), out.len(), "quantize_into length mismatch");
    for (o, &x) in out.iter_mut().zip(xs) {
        *o = q.quantize(x);
    }
}

/// Largest `k` the int8 GEMM accepts: the i32 accumulator holds up to
/// `k · 128²` in magnitude, so `k` beyond this could overflow.  Far
/// above any registry or im2col-lowered shape in the repo; exceeding it
/// is a loud panic, never silent wraparound.
pub const MAX_I8_GEMM_K: usize = (i32::MAX as usize) / (128 * 128);

/// Generate the monomorphized int8 micro-kernel registry: the mirror of
/// `blocked::micro_kernel_registry!` for the widening i8×i8→i32 kernel
/// family.  [`INT8_MICRO_KERNEL_SHAPES`] must stay equal to
/// [`super::MICRO_KERNEL_SHAPES`] (asserted in tests) so the tuner's
/// grids mean the same thing under either dtype.
macro_rules! int8_micro_kernel_registry {
    ($(($mr:literal, $nr:literal)),+ $(,)?) => {
        /// Every `(mr, nr)` register micro-tile with a monomorphized
        /// int8 kernel — identical to the f32 registry by construction.
        pub const INT8_MICRO_KERNEL_SHAPES: &[(usize, usize)] =
            &[$(($mr, $nr)),+];

        /// Dispatch one int8 register tile: full registry tiles run the
        /// monomorphized widening kernel — the AVX2 `madd`-pair variant
        /// for the 256-bit ISAs, the scalar widening loop otherwise —
        /// ragged edges and unregistered shapes the generic widening
        /// kernel.  Every path computes the identical exact i32 result.
        #[allow(clippy::too_many_arguments)]
        #[inline]
        fn dispatch_micro_kernel_i8(
            full: bool,
            mr: usize,
            nr: usize,
            isa: Isa,
            apack: &[i8],
            b: &[i8],
            c: &mut [i32],
            n: usize,
            il: usize,
            ie: usize,
            j: usize,
            je: usize,
            p0: usize,
            p1: usize,
        ) {
            match (full, mr, nr) {
                $(
                    (true, $mr, $nr) => match isa {
                        // SAFETY: `gemm_i8_blocked_isa` asserted
                        // `isa.is_available()` on entry; Fma and Avx512
                        // availability both imply AVX2.
                        #[cfg(target_arch = "x86_64")]
                        Isa::Avx2 | Isa::Fma | Isa::Avx512 => unsafe {
                            micro_kernel_i8_avx2::<$mr, $nr>(
                                apack, b, c, n, il, j, p0, p1,
                            )
                        },
                        // Scalar, Sse2 (no i8 widening body below
                        // AVX2), Neon, and non-x86-64 builds: the
                        // portable widening loop — same exact result.
                        _ => micro_kernel_i8_fixed::<$mr, $nr>(
                            apack, b, c, n, il, j, p0, p1,
                        ),
                    },
                )+
                _ => micro_kernel_i8(
                    apack, b, c, n, il, ie, j, je, p0, p1, mr,
                ),
            }
        }

        /// The packed-B twin of `dispatch_micro_kernel_i8` (the
        /// `pack: ab` axis): `bstrip` is this tile's `kc×nr` strip of
        /// the packed B panel.  Integer arithmetic is exact, so every
        /// path — packed or unpacked, any ISA — computes the identical
        /// i32 result bit for bit.
        #[allow(clippy::too_many_arguments)]
        #[inline]
        fn dispatch_micro_kernel_i8_pb(
            full: bool,
            mr: usize,
            nr: usize,
            isa: Isa,
            apack: &[i8],
            bstrip: &[i8],
            c: &mut [i32],
            n: usize,
            il: usize,
            ie: usize,
            j: usize,
            je: usize,
            kc: usize,
        ) {
            match (full, mr, nr) {
                $(
                    (true, $mr, $nr) => match isa {
                        // SAFETY: as for `dispatch_micro_kernel_i8` —
                        // the entry point asserted `isa.is_available()`.
                        #[cfg(target_arch = "x86_64")]
                        Isa::Avx2 | Isa::Fma | Isa::Avx512 => unsafe {
                            micro_kernel_i8_avx2_pb::<$mr, $nr>(
                                apack, bstrip, c, n, il, j, kc,
                            )
                        },
                        _ => micro_kernel_i8_fixed_pb::<$mr, $nr>(
                            apack, bstrip, c, n, il, j, kc,
                        ),
                    },
                )+
                _ => micro_kernel_i8_pb(
                    apack, bstrip, c, n, il, ie, j, je, kc, mr, nr,
                ),
            }
        }
    };
}

// Keep in lockstep with `micro_kernel_registry!` in blocked.rs (test:
// `int8_registry_matches_f32_registry`).
int8_micro_kernel_registry!(
    (2, 4),
    (2, 8),
    (2, 16),
    (4, 4),
    (4, 8),
    (4, 16),
    (8, 4),
    (8, 8),
    (8, 16),
    (16, 4),
    (16, 8),
    (16, 16),
);

/// `C = A @ B` over i8 operands with exact i32 accumulation, blocked per
/// `params` — the int8 twin of
/// [`gemm_blocked_isa`](super::gemm_blocked_isa), sharing its macro-tile
/// bands, A-panel packing discipline, thread pool, and ISA dispatch.
/// Every `(params, isa, threads)` combination returns the identical i32
/// result bit for bit (integer arithmetic is exact).
///
/// Panics on shape mismatch, invalid params, an unavailable `isa`, or
/// `k > `[`MAX_I8_GEMM_K`] (i32 accumulator overflow bound).
pub fn gemm_i8_blocked_isa(
    a: &[i8],
    b: &[i8],
    m: usize,
    n: usize,
    k: usize,
    params: &BlockedParams,
    isa: Isa,
) -> Vec<i32> {
    gemm_i8_blocked_ex(a, b, m, n, k, params, isa, Pack::A, &Scratch::new())
}

/// [`gemm_i8_blocked_isa`] with the operand-staging [`Pack`] axis and a
/// caller-owned [`Scratch`] arena — the int8 twin of
/// [`gemm_blocked_ex`](super::gemm_blocked_ex).  `Pack::Ab` packs B
/// once per call into `nr`-column-interleaved panels shared read-only
/// across every band; integer arithmetic is exact, so the packed path
/// is bit-identical (not merely tolerance-equal) for every ISA and
/// thread count.
#[allow(clippy::too_many_arguments)]
pub fn gemm_i8_blocked_ex(
    a: &[i8],
    b: &[i8],
    m: usize,
    n: usize,
    k: usize,
    params: &BlockedParams,
    isa: Isa,
    pack: Pack,
    scratch: &Scratch,
) -> Vec<i32> {
    gemm_i8_validate(a, b, m, n, k, params, isa);
    let mut c = vec![0i32; m * n];
    gemm_i8_compute(a, b, &mut c, m, n, k, params, isa, pack, scratch);
    c
}

/// The shared int8 entry asserts (shape, params, k bound, ISA
/// availability) — identical messages to the historical entry point.
fn gemm_i8_validate(
    a: &[i8],
    b: &[i8],
    m: usize,
    n: usize,
    k: usize,
    params: &BlockedParams,
    isa: Isa,
) {
    assert_eq!(a.len(), m * k, "A shape mismatch");
    assert_eq!(b.len(), k * n, "B shape mismatch");
    assert!(
        params.bm > 0
            && params.bn > 0
            && params.bk > 0
            && params.mr > 0
            && params.nr > 0,
        "BlockedParams dims must be non-zero: {params:?}"
    );
    assert!(
        params.mr <= 16 && params.nr <= 16,
        "micro-tile exceeds the 16x16 register kernel cap: {params:?}"
    );
    assert!(
        k <= MAX_I8_GEMM_K,
        "int8 gemm k={k} exceeds the i32 accumulation bound {MAX_I8_GEMM_K}"
    );
    assert!(
        isa.is_available(),
        "micro-kernel ISA {isa} is not available on this host \
         (detected: {:?}) — resolve the plan through the engine, which \
         degrades unavailable ISAs to scalar",
        Isa::detect()
    );
}

/// The int8 band driver (validated inputs, `c` pre-zeroed `m*n`):
/// stages B per the pack axis, then runs the serial or band-parallel
/// path — the same structure as the f32 `gemm_into_prepacked`, with
/// every packing buffer drawn from the arena.
#[allow(clippy::too_many_arguments)]
fn gemm_i8_compute(
    a: &[i8],
    b: &[i8],
    c: &mut [i32],
    m: usize,
    n: usize,
    k: usize,
    params: &BlockedParams,
    isa: Isa,
    pack: Pack,
    scratch: &Scratch,
) {
    let bpack = if pack == Pack::Ab && n > 0 && k > 0 {
        let mut bp = scratch.take_i8(bpack_len(n, k, params));
        pack_b_i8(b, &mut bp, n, k, params);
        Some(bp)
    } else {
        None
    };
    let bpack_ref = bpack.as_deref();
    let bm = params.bm;
    let workers = pool::resolve_threads(params.threads);
    let bands = m.div_ceil(bm);
    if workers <= 1 || bands <= 1 || n == 0 {
        let mut apack = scratch.take_i8(apack_len(params));
        let mut i0 = 0;
        while i0 < m {
            let i1 = (i0 + bm).min(m);
            let cband = &mut c[i0 * n..i1 * n];
            match bpack_ref {
                Some(bp) => gemm_i8_band_packed(
                    a, bp, cband, n, k, i0, i1, params, isa, &mut apack,
                ),
                None => gemm_i8_band(
                    a, b, cband, n, k, i0, i1, params, isa, &mut apack,
                ),
            }
            i0 = i1;
        }
        scratch.put_i8(apack);
    } else {
        let row_bands: Vec<(usize, &mut [i32])> =
            c.chunks_mut(bm * n).enumerate().collect();
        pool::run_parallel(workers, row_bands, |_, (band, cband)| {
            let i0 = band * bm;
            let i1 = (i0 + bm).min(m);
            let mut apack = scratch.take_i8(apack_len(params));
            match bpack_ref {
                Some(bp) => gemm_i8_band_packed(
                    a, bp, cband, n, k, i0, i1, params, isa, &mut apack,
                ),
                None => gemm_i8_band(
                    a, b, cband, n, k, i0, i1, params, isa, &mut apack,
                ),
            }
            scratch.put_i8(apack);
        });
    }
    if let Some(bp) = bpack {
        scratch.put_i8(bp);
    }
}

/// The worst-case arena take-set of one [`gemm_i8_blocked_ex`] call
/// (the i8 twin of [`gemm_workspace`](super::gemm_workspace)).
pub fn gemm_i8_workspace(
    m: usize,
    n: usize,
    k: usize,
    params: &BlockedParams,
    pack: Pack,
) -> Workspace {
    let workers = pool::resolve_threads(params.threads);
    let bands = m.div_ceil(params.bm.max(1));
    let napack = if workers <= 1 || bands <= 1 || n == 0 {
        1
    } else {
        workers.min(bands)
    };
    let mut ws = Workspace::none();
    for _ in 0..napack {
        ws.i8_lens.push(apack_len(params));
    }
    if pack == Pack::Ab {
        ws.i8_lens.push(bpack_len(n, k, params));
    }
    ws
}

/// Quantized GEMM with the dequantize epilogue: multiply the quantized
/// operands exactly in i32, then map back to f32 applying the per-tensor
/// zero-point corrections and scales — the end-to-end int8 fast path a
/// `dtype: i8` GEMM plan executes.
///
/// `out[i,j] = sa·sb · (acc[i,j] − zb·Σ_p a[i,p] − za·Σ_p b[p,j]
///             + k·za·zb)`
/// which equals `Σ_p dequant(a[i,p]) · dequant(b[p,j])` exactly (the
/// correction arithmetic runs in i64, so it cannot overflow for any
/// `k ≤ `[`MAX_I8_GEMM_K`]).
#[allow(clippy::too_many_arguments)]
pub fn gemm_i8_dequant(
    a: &[i8],
    b: &[i8],
    m: usize,
    n: usize,
    k: usize,
    qa: &QuantParams,
    qb: &QuantParams,
    params: &BlockedParams,
    isa: Isa,
) -> Vec<f32> {
    gemm_i8_dequant_ex(
        a,
        b,
        m,
        n,
        k,
        qa,
        qb,
        params,
        isa,
        Pack::A,
        &Scratch::new(),
    )
}

/// [`gemm_i8_dequant`] with the [`Pack`] axis and a caller-owned
/// [`Scratch`] arena: the i32 accumulator and the i64 row/column
/// zero-point correction sums are arena buffers, so a prewarmed
/// steady-state call allocates only its f32 output.  Bit-identical to
/// [`gemm_i8_dequant`] (integer stages are exact; the f32 epilogue is
/// elementwise in the same order).
#[allow(clippy::too_many_arguments)]
pub fn gemm_i8_dequant_ex(
    a: &[i8],
    b: &[i8],
    m: usize,
    n: usize,
    k: usize,
    qa: &QuantParams,
    qb: &QuantParams,
    params: &BlockedParams,
    isa: Isa,
    pack: Pack,
    scratch: &Scratch,
) -> Vec<f32> {
    gemm_i8_validate(a, b, m, n, k, params, isa);
    let mut acc = scratch.take_i32(m * n);
    gemm_i8_compute(a, b, &mut acc, m, n, k, params, isa, pack, scratch);
    let za = qa.zero_point as i64;
    let zb = qb.zero_point as i64;
    let mut row_sums = scratch.take_i64(m);
    for (i, s) in row_sums.iter_mut().enumerate() {
        *s = a[i * k..(i + 1) * k].iter().map(|&v| v as i64).sum();
    }
    let mut col_sums = scratch.take_i64(n);
    for p in 0..k {
        for (j, s) in col_sums.iter_mut().enumerate() {
            *s += b[p * n + j] as i64;
        }
    }
    let scale = qa.scale * qb.scale;
    let kzz = k as i64 * za * zb;
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        let corr_row = zb * row_sums[i] - kzz;
        for j in 0..n {
            let exact = acc[i * n + j] as i64 - corr_row - za * col_sums[j];
            out[i * n + j] = scale * exact as f32;
        }
    }
    scratch.put_i64(col_sums);
    scratch.put_i64(row_sums);
    scratch.put_i32(acc);
    out
}

/// The worst-case arena take-set of one [`gemm_i8_dequant_ex`] call:
/// the GEMM stage's buffers plus the i32 accumulator and i64
/// correction-sum buffers.
pub fn gemm_i8_dequant_workspace(
    m: usize,
    n: usize,
    k: usize,
    params: &BlockedParams,
    pack: Pack,
) -> Workspace {
    let mut ws = gemm_i8_workspace(m, n, k, params, pack);
    ws.i32_lens.push(m * n);
    ws.i64_lens.push(m);
    ws.i64_lens.push(n);
    ws
}

/// Quantized im2col convolution: quantize the NHWC input and RSCK
/// filters under the given per-tensor params, build the patch matrix in
/// the quantized domain — **padding taps filled with the input
/// zero-point**, which dequantizes to exactly 0, matching the f32
/// path's zero padding — and run the lowered GEMM through
/// [`gemm_i8_dequant`].  Both stages honor `params.threads`; the
/// lowered GEMM dispatches `isa` exactly like the f32 conv.
pub fn conv2d_im2col_i8(
    x: &[f32],
    f: &[f32],
    s: &Conv2dShape,
    qx: &QuantParams,
    qf: &QuantParams,
    params: &BlockedParams,
    isa: Isa,
) -> Vec<f32> {
    conv2d_im2col_i8_ex(
        x,
        f,
        s,
        qx,
        qf,
        params,
        isa,
        Pack::A,
        &Scratch::new(),
    )
}

/// [`conv2d_im2col_i8`] with the [`Pack`] axis and a caller-owned
/// [`Scratch`] arena: the quantize staging buffers (`xq`, `fq`), the
/// quantized patch matrix, and every lowered-GEMM buffer come from the
/// arena, so a prewarmed steady-state call allocates only its f32
/// output.  Bit-identical to [`conv2d_im2col_i8`].
#[allow(clippy::too_many_arguments)]
pub fn conv2d_im2col_i8_ex(
    x: &[f32],
    f: &[f32],
    s: &Conv2dShape,
    qx: &QuantParams,
    qf: &QuantParams,
    params: &BlockedParams,
    isa: Isa,
    pack: Pack,
    scratch: &Scratch,
) -> Vec<f32> {
    assert_eq!(x.len(), s.input_elems(), "input shape mismatch");
    assert_eq!(f.len(), s.filter_elems(), "filter shape mismatch");
    let mut xq = scratch.take_i8(x.len());
    quantize_into(x, qx, &mut xq);
    let mut fq = scratch.take_i8(f.len());
    quantize_into(f, qf, &mut fq);
    let m = s.batch * s.out_h * s.out_w;
    let k = s.window * s.window * s.in_c;
    let mut patches = scratch.take_i8(m * k);
    im2col_i8_into(&xq, s, qx.zero_point, params.threads, &mut patches);
    let out = gemm_i8_dequant_ex(
        &patches, &fq, m, s.out_c, k, qx, qf, params, isa, pack, scratch,
    );
    scratch.put_i8(patches);
    scratch.put_i8(fq);
    scratch.put_i8(xq);
    out
}

/// The worst-case arena take-set of one [`conv2d_im2col_i8_ex`] call:
/// quantize staging + patch matrix + the lowered dequant GEMM's set.
pub fn conv2d_im2col_i8_workspace(
    s: &Conv2dShape,
    params: &BlockedParams,
    pack: Pack,
) -> Workspace {
    let m = s.batch * s.out_h * s.out_w;
    let k = s.window * s.window * s.in_c;
    let mut ws = gemm_i8_dequant_workspace(m, s.out_c, k, params, pack);
    ws.i8_lens.push(s.input_elems());
    ws.i8_lens.push(s.filter_elems());
    ws.i8_lens.push(m * k);
    ws
}

/// The quantized twin of `conv::im2col_threaded`, writing into a
/// caller-supplied buffer: pre-fill with `pad` (the input zero-point),
/// then build patch rows in parallel chunks writing disjoint ranges —
/// bit-identical for every thread count.
fn im2col_i8_into(
    x: &[i8],
    s: &Conv2dShape,
    pad: i32,
    threads: usize,
    patches: &mut [i8],
) {
    let kdim = s.window * s.window * s.in_c;
    let rows = s.batch * s.out_h * s.out_w;
    debug_assert_eq!(patches.len(), rows * kdim);
    let pad = pad.clamp(-128, 127) as i8;
    patches.fill(pad);
    let workers = pool::resolve_threads(threads);
    if workers <= 1 || rows <= 1 || kdim == 0 {
        im2col_i8_rows(x, s, 0, rows, patches);
        return;
    }
    let chunk_rows = rows.div_ceil(workers);
    let chunks: Vec<(usize, &mut [i8])> = patches
        .chunks_mut(chunk_rows * kdim)
        .enumerate()
        .collect();
    pool::run_parallel(workers, chunks, |_, (c, chunk)| {
        let row0 = c * chunk_rows;
        let row1 = (row0 + chunk_rows).min(rows);
        im2col_i8_rows(x, s, row0, row1, chunk);
    });
}

/// Fill rows `[row0, row1)` of the quantized patch matrix (`out` is the
/// pre-filled-with-zero-point chunk for exactly that range); padding
/// taps are skipped, leaving the zero-point fill in place.
fn im2col_i8_rows(
    x: &[i8],
    s: &Conv2dShape,
    row0: usize,
    row1: usize,
    out: &mut [i8],
) {
    let kdim = s.window * s.window * s.in_c;
    debug_assert_eq!(out.len(), (row1 - row0) * kdim);
    for row in row0..row1 {
        let ow = row % s.out_w;
        let oh = (row / s.out_w) % s.out_h;
        let b = row / (s.out_w * s.out_h);
        let base = (row - row0) * kdim;
        for r in 0..s.window {
            let ih = (oh * s.stride + r) as isize - s.pad_top as isize;
            for sw in 0..s.window {
                let iw =
                    (ow * s.stride + sw) as isize - s.pad_left as isize;
                if ih < 0
                    || ih as usize >= s.in_h
                    || iw < 0
                    || iw as usize >= s.in_w
                {
                    continue; // zero-point padding (buffer pre-filled)
                }
                let x0 = ((b * s.in_h + ih as usize) * s.in_w
                    + iw as usize)
                    * s.in_c;
                let p0 = base + (r * s.window + sw) * s.in_c;
                out[p0..p0 + s.in_c].copy_from_slice(&x[x0..x0 + s.in_c]);
            }
        }
    }
}

/// One `bm`-row macro-tile band of the int8 GEMM — the exact structure
/// of `blocked::gemm_band`, over i8 operands and i32 output.
#[allow(clippy::too_many_arguments)]
fn gemm_i8_band(
    a: &[i8],
    b: &[i8],
    cband: &mut [i32],
    n: usize,
    k: usize,
    i0: usize,
    i1: usize,
    params: &BlockedParams,
    isa: Isa,
    apack: &mut [i8],
) {
    let &BlockedParams { bn, bk, mr, nr, .. } = params;
    for p0 in (0..k).step_by(bk) {
        let p1 = (p0 + bk).min(k);
        pack_a_i8(a, apack, k, i0, i1, p0, p1, mr);
        for j0 in (0..n).step_by(bn) {
            let j1 = (j0 + bn).min(n);
            let mut i = i0;
            while i < i1 {
                let ie = (i + mr).min(i1);
                let strip = ((i - i0) / mr) * (mr * (p1 - p0));
                let il = i - i0;
                let mut j = j0;
                while j < j1 {
                    let je = (j + nr).min(j1);
                    let full = ie - i == mr && je - j == nr;
                    dispatch_micro_kernel_i8(
                        full, mr, nr, isa, &apack[strip..], b, cband, n,
                        il, il + (ie - i), j, je, p0, p1,
                    );
                    j = je;
                }
                i = ie;
            }
        }
    }
}

/// The packed-B twin of [`gemm_i8_band`]: identical loop structure over
/// the shared packed panels (`pack_b_i8` layout, identical strip
/// arithmetic to the f32 `gemm_band_packed`) — exact, so bit-identical
/// to the unpacked band for every ISA.
#[allow(clippy::too_many_arguments)]
fn gemm_i8_band_packed(
    a: &[i8],
    bpack: &[i8],
    cband: &mut [i32],
    n: usize,
    k: usize,
    i0: usize,
    i1: usize,
    params: &BlockedParams,
    isa: Isa,
    apack: &mut [i8],
) {
    let &BlockedParams { bn, bk, mr, nr, .. } = params;
    let jpanels = n.div_ceil(bn.max(1));
    let slot = bpack_panel_slot(n, params);
    for p0 in (0..k).step_by(bk) {
        let p1 = (p0 + bk).min(k);
        let kc = p1 - p0;
        pack_a_i8(a, apack, k, i0, i1, p0, p1, mr);
        for j0 in (0..n).step_by(bn) {
            let j1 = (j0 + bn).min(n);
            let pbase = ((p0 / bk) * jpanels + j0 / bn) * slot;
            let mut i = i0;
            while i < i1 {
                let ie = (i + mr).min(i1);
                let strip = ((i - i0) / mr) * (mr * kc);
                let il = i - i0;
                let mut j = j0;
                while j < j1 {
                    let je = (j + nr).min(j1);
                    let full = ie - i == mr && je - j == nr;
                    let boff = pbase + ((j - j0) / nr) * (kc * nr);
                    dispatch_micro_kernel_i8_pb(
                        full,
                        mr,
                        nr,
                        isa,
                        &apack[strip..],
                        &bpack[boff..],
                        cband,
                        n,
                        il,
                        il + (ie - i),
                        j,
                        je,
                        kc,
                    );
                    j = je;
                }
                i = ie;
            }
        }
    }
}

/// Pack an i8 `B` into BLIS-style panels — the exact layout of the f32
/// `blocked::pack_b` ([`bpack_len`] sizing, uniform panel slots,
/// contiguous `nr`-column strips, ragged columns zero-padded and never
/// read back).
fn pack_b_i8(
    b: &[i8],
    bpack: &mut [i8],
    n: usize,
    k: usize,
    params: &BlockedParams,
) {
    let &BlockedParams { bn, bk, nr, .. } = params;
    let jpanels = n.div_ceil(bn);
    let slot = bpack_panel_slot(n, params);
    for p0 in (0..k).step_by(bk) {
        let p1 = (p0 + bk).min(k);
        let kc = p1 - p0;
        for j0 in (0..n).step_by(bn) {
            let j1 = (j0 + bn).min(n);
            let base = ((p0 / bk) * jpanels + j0 / bn) * slot;
            let mut t = 0;
            let mut j = j0;
            while j < j1 {
                let je = (j + nr).min(j1);
                let off = base + t * (kc * nr);
                for p in 0..kc {
                    let row = (p0 + p) * n;
                    let dst = off + p * nr;
                    for (s, col) in (j..je).enumerate() {
                        bpack[dst + s] = b[row + col];
                    }
                    for s in (je - j)..nr {
                        bpack[dst + s] = 0;
                    }
                }
                t += 1;
                j = je;
            }
        }
    }
}

/// Pack `A[i0..i1, p0..p1]` into `mr`-row strips, k-major (the i8 twin
/// of `blocked::pack_a`).  Ragged strips are zero-padded; the pad value
/// is irrelevant to correctness because accumulator rows beyond the
/// ragged edge are never written back to C — zero just keeps the
/// buffer deterministic.
fn pack_a_i8(
    a: &[i8],
    apack: &mut [i8],
    k: usize,
    i0: usize,
    i1: usize,
    p0: usize,
    p1: usize,
    mr: usize,
) {
    let kc = p1 - p0;
    let mut out = 0;
    let mut i = i0;
    while i < i1 {
        let rows = (i + mr).min(i1) - i;
        for p in 0..kc {
            for r in 0..rows {
                apack[out] = a[(i + r) * k + p0 + p];
                out += 1;
            }
            for _ in rows..mr {
                apack[out] = 0;
                out += 1;
            }
        }
        i += mr;
    }
}

/// Monomorphized widening micro-kernel for full `MR x NR` tiles: i8
/// operands widened to i32 per multiply, exact i32 accumulation.  The
/// scalar member of the int8 kernel family and the reference every SIMD
/// variant must match bit for bit.
#[inline(always)]
#[allow(clippy::too_many_arguments)]
fn micro_kernel_i8_fixed<const MR: usize, const NR: usize>(
    apack: &[i8],
    b: &[i8],
    c: &mut [i32],
    n: usize,
    i: usize,
    j: usize,
    p0: usize,
    p1: usize,
) {
    let mut acc = [[0i32; NR]; MR];
    for p in 0..(p1 - p0) {
        let brow: &[i8] = &b[(p0 + p) * n + j..(p0 + p) * n + j + NR];
        let astrip = &apack[p * MR..(p + 1) * MR];
        for r in 0..MR {
            let aip = astrip[r] as i32;
            for s in 0..NR {
                acc[r][s] += aip * brow[s] as i32;
            }
        }
    }
    for (r, accr) in acc.iter().enumerate() {
        let crow = &mut c[(i + r) * n + j..(i + r) * n + j + NR];
        for s in 0..NR {
            crow[s] += accr[s];
        }
    }
}

/// The packed-B twin of [`micro_kernel_i8_fixed`]: B rows read from the
/// tile's `kc×NR` packed strip (`bstrip[p*NR + s]`), unit stride.
/// Exact — bit-identical to the unpacked kernel.
#[inline(always)]
#[allow(clippy::too_many_arguments)]
fn micro_kernel_i8_fixed_pb<const MR: usize, const NR: usize>(
    apack: &[i8],
    bstrip: &[i8],
    c: &mut [i32],
    n: usize,
    i: usize,
    j: usize,
    kc: usize,
) {
    let mut acc = [[0i32; NR]; MR];
    for p in 0..kc {
        let brow: &[i8] = &bstrip[p * NR..(p + 1) * NR];
        let astrip = &apack[p * MR..(p + 1) * MR];
        for r in 0..MR {
            let aip = astrip[r] as i32;
            for s in 0..NR {
                acc[r][s] += aip * brow[s] as i32;
            }
        }
    }
    for (r, accr) in acc.iter().enumerate() {
        let crow = &mut c[(i + r) * n + j..(i + r) * n + j + NR];
        for s in 0..NR {
            crow[s] += accr[s];
        }
    }
}

/// Generic widening micro-kernel for ragged edges and unregistered
/// shapes (the i8 twin of `blocked::micro_kernel`; 16×16 accumulator
/// cap).
#[inline]
#[allow(clippy::too_many_arguments)]
fn micro_kernel_i8(
    apack: &[i8],
    b: &[i8],
    c: &mut [i32],
    n: usize,
    i: usize,
    ie: usize,
    j: usize,
    je: usize,
    p0: usize,
    p1: usize,
    mr: usize,
) {
    let mut acc = [[0i32; 16]; 16];
    let (mh, nw) = (ie - i, je - j);
    debug_assert!(mh <= 16 && nw <= 16);
    for p in 0..(p1 - p0) {
        let brow = &b[(p0 + p) * n + j..(p0 + p) * n + je];
        let astrip = &apack[p * mr..p * mr + mh];
        for (accr, aip) in acc.iter_mut().zip(astrip.iter()) {
            let aw = *aip as i32;
            for (s, bv) in brow.iter().enumerate() {
                accr[s] += aw * *bv as i32;
            }
        }
    }
    for r in 0..mh {
        let crow = &mut c[(i + r) * n + j..(i + r) * n + je];
        for (s, cv) in crow.iter_mut().enumerate() {
            *cv += acc[r][s];
        }
    }
    let _ = nw;
}

/// The packed-B twin of the generic [`micro_kernel_i8`] (ragged edges
/// and unregistered shapes): reads `je - j` columns from the strip's
/// `nr`-wide rows.  Exact, so bit-identical to the unpacked generic.
#[inline]
#[allow(clippy::too_many_arguments)]
fn micro_kernel_i8_pb(
    apack: &[i8],
    bstrip: &[i8],
    c: &mut [i32],
    n: usize,
    i: usize,
    ie: usize,
    j: usize,
    je: usize,
    kc: usize,
    mr: usize,
    nr: usize,
) {
    let mut acc = [[0i32; 16]; 16];
    let (mh, nw) = (ie - i, je - j);
    debug_assert!(mh <= 16 && nw <= 16);
    for p in 0..kc {
        let brow = &bstrip[p * nr..p * nr + nw];
        let astrip = &apack[p * mr..p * mr + mh];
        for (accr, aip) in acc.iter_mut().zip(astrip.iter()) {
            let aw = *aip as i32;
            for (s, bv) in brow.iter().enumerate() {
                accr[s] += aw * *bv as i32;
            }
        }
    }
    for r in 0..mh {
        let crow = &mut c[(i + r) * n + j..(i + r) * n + je];
        for (s, cv) in crow.iter_mut().enumerate() {
            *cv += acc[r][s];
        }
    }
}

/// AVX2 widening dot-product micro-kernel: k-step *pairs* reduced with
/// `_mm256_madd_epi16` over `_mm256_cvtepi8_epi16`-widened operands —
/// 8 (256-bit) or 4 (128-bit) output columns per `madd`, 2 MACs per
/// lane per instruction.  Exact: i16 pair products cap at 2·128² <
/// 2¹⁵·2, summed in i32 lanes; bit-identical to the scalar widening
/// kernel because integer addition is associative.  Odd trailing
/// k-steps pair with an implicit zero row.  `NR % 4 != 0` shapes fall
/// back to the scalar widening body (off the SIMD lane domain, still
/// exact).
///
/// # Safety
///
/// The executing CPU must support AVX2 (`Isa::Avx2.is_available()`;
/// `Fma`/`Avx512` availability implies it).  Slice/layout
/// preconditions are those of `micro_kernel_i8_fixed`.
#[cfg(target_arch = "x86_64")]
#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "avx2")]
unsafe fn micro_kernel_i8_avx2<const MR: usize, const NR: usize>(
    apack: &[i8],
    b: &[i8],
    c: &mut [i32],
    n: usize,
    i: usize,
    j: usize,
    p0: usize,
    p1: usize,
) {
    // Broadcast the (a_p, a_{p+1}) pair for one packed-A row as the
    // 16-bit halves of every 32-bit lane, matching madd's pairing.
    #[inline(always)]
    fn pair_broadcast_val(a0: i8, a1: i8) -> i32 {
        ((a0 as i16 as u16 as u32) | ((a1 as i16 as u16 as u32) << 16))
            as i32
    }
    let kc = p1 - p0;
    if NR % 8 == 0 {
        // NR/8 ymm accumulators per row; registry caps NR at 16.
        let nv = NR / 8;
        let mut acc: [[__m256i; 2]; MR] =
            [[_mm256_setzero_si256(); 2]; MR];
        let mut p = 0;
        while p < kc {
            let pair = p + 1 < kc;
            // Interleave the two widened B rows into (row p, row p+1)
            // i16 pairs per output column, one ymm per 8 columns.
            let mut bvec = [_mm256_setzero_si256(); 2];
            for (v, bv) in bvec.iter_mut().take(nv).enumerate() {
                let bp_ptr = b.as_ptr().add((p0 + p) * n + j + 8 * v);
                let bp = _mm_cvtepi8_epi16(_mm_loadl_epi64(
                    bp_ptr as *const __m128i,
                ));
                let bq = if pair {
                    let bq_ptr =
                        b.as_ptr().add((p0 + p + 1) * n + j + 8 * v);
                    _mm_cvtepi8_epi16(_mm_loadl_epi64(
                        bq_ptr as *const __m128i,
                    ))
                } else {
                    _mm_setzero_si128()
                };
                let lo = _mm_unpacklo_epi16(bp, bq);
                let hi = _mm_unpackhi_epi16(bp, bq);
                *bv = _mm256_set_m128i(hi, lo);
            }
            let astrip = apack.as_ptr().add(p * MR);
            let astrip2 = apack.as_ptr().add((p + 1) * MR);
            for (r, accr) in acc.iter_mut().enumerate() {
                let a0 = *astrip.add(r);
                let a1 = if pair { *astrip2.add(r) } else { 0 };
                let av = _mm256_set1_epi32(pair_broadcast_val(a0, a1));
                for (v, a) in accr.iter_mut().take(nv).enumerate() {
                    *a = _mm256_add_epi32(
                        *a,
                        _mm256_madd_epi16(av, bvec[v]),
                    );
                }
            }
            p += 2;
        }
        for (r, accr) in acc.iter().enumerate() {
            let crow = c.as_mut_ptr().add((i + r) * n + j);
            for (v, a) in accr.iter().take(nv).enumerate() {
                let cp = crow.add(8 * v) as *mut __m256i;
                let sum = _mm256_add_epi32(_mm256_loadu_si256(cp), *a);
                _mm256_storeu_si256(cp, sum);
            }
        }
    } else if NR % 4 == 0 {
        // Narrow registry shapes (NR = 4): 128-bit madd lanes.
        let nv = NR / 4;
        let mut acc: [[__m128i; 4]; MR] = [[_mm_setzero_si128(); 4]; MR];
        let mut p = 0;
        while p < kc {
            let pair = p + 1 < kc;
            let mut bvec = [_mm_setzero_si128(); 4];
            for (v, bv) in bvec.iter_mut().take(nv).enumerate() {
                let bp_ptr = b.as_ptr().add((p0 + p) * n + j + 4 * v);
                let bp = _mm_cvtepi8_epi16(_mm_cvtsi32_si128(
                    (bp_ptr as *const i32).read_unaligned(),
                ));
                let bq = if pair {
                    let bq_ptr =
                        b.as_ptr().add((p0 + p + 1) * n + j + 4 * v);
                    _mm_cvtepi8_epi16(_mm_cvtsi32_si128(
                        (bq_ptr as *const i32).read_unaligned(),
                    ))
                } else {
                    _mm_setzero_si128()
                };
                *bv = _mm_unpacklo_epi16(bp, bq);
            }
            let astrip = apack.as_ptr().add(p * MR);
            let astrip2 = apack.as_ptr().add((p + 1) * MR);
            for (r, accr) in acc.iter_mut().enumerate() {
                let a0 = *astrip.add(r);
                let a1 = if pair { *astrip2.add(r) } else { 0 };
                let av = _mm_set1_epi32(pair_broadcast_val(a0, a1));
                for (v, a) in accr.iter_mut().take(nv).enumerate() {
                    *a = _mm_add_epi32(*a, _mm_madd_epi16(av, bvec[v]));
                }
            }
            p += 2;
        }
        for (r, accr) in acc.iter().enumerate() {
            let crow = c.as_mut_ptr().add((i + r) * n + j);
            for (v, a) in accr.iter().take(nv).enumerate() {
                let cp = crow.add(4 * v) as *mut __m128i;
                let sum = _mm_add_epi32(_mm_loadu_si128(cp), *a);
                _mm_storeu_si128(cp, sum);
            }
        }
    } else {
        // Off the SIMD lane domain: scalar widening fallback (exact, so
        // still bit-identical).
        micro_kernel_i8_fixed::<MR, NR>(apack, b, c, n, i, j, p0, p1);
    }
}

/// The packed-B twin of [`micro_kernel_i8_avx2`]: identical madd-pair
/// structure, but the paired B rows `p` and `p+1` load from consecutive
/// `NR`-element rows of the packed strip (`bstrip + p*NR` and
/// `bstrip + (p+1)*NR`) — adjacent in memory, so the interleave feeds
/// from one or two cache lines instead of two stride-`n` rows.  Exact,
/// hence bit-identical to every other int8 kernel.
///
/// # Safety
///
/// The executing CPU must support AVX2.  Slice/layout preconditions are
/// those of `micro_kernel_i8_fixed_pb`.
#[cfg(target_arch = "x86_64")]
#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "avx2")]
unsafe fn micro_kernel_i8_avx2_pb<const MR: usize, const NR: usize>(
    apack: &[i8],
    bstrip: &[i8],
    c: &mut [i32],
    n: usize,
    i: usize,
    j: usize,
    kc: usize,
) {
    #[inline(always)]
    fn pair_broadcast_val(a0: i8, a1: i8) -> i32 {
        ((a0 as i16 as u16 as u32) | ((a1 as i16 as u16 as u32) << 16))
            as i32
    }
    if NR % 8 == 0 {
        let nv = NR / 8;
        let mut acc: [[__m256i; 2]; MR] =
            [[_mm256_setzero_si256(); 2]; MR];
        let mut p = 0;
        while p < kc {
            let pair = p + 1 < kc;
            let mut bvec = [_mm256_setzero_si256(); 2];
            for (v, bv) in bvec.iter_mut().take(nv).enumerate() {
                let bp_ptr = bstrip.as_ptr().add(p * NR + 8 * v);
                let bp = _mm_cvtepi8_epi16(_mm_loadl_epi64(
                    bp_ptr as *const __m128i,
                ));
                let bq = if pair {
                    let bq_ptr =
                        bstrip.as_ptr().add((p + 1) * NR + 8 * v);
                    _mm_cvtepi8_epi16(_mm_loadl_epi64(
                        bq_ptr as *const __m128i,
                    ))
                } else {
                    _mm_setzero_si128()
                };
                let lo = _mm_unpacklo_epi16(bp, bq);
                let hi = _mm_unpackhi_epi16(bp, bq);
                *bv = _mm256_set_m128i(hi, lo);
            }
            let astrip = apack.as_ptr().add(p * MR);
            let astrip2 = apack.as_ptr().add((p + 1) * MR);
            for (r, accr) in acc.iter_mut().enumerate() {
                let a0 = *astrip.add(r);
                let a1 = if pair { *astrip2.add(r) } else { 0 };
                let av = _mm256_set1_epi32(pair_broadcast_val(a0, a1));
                for (v, a) in accr.iter_mut().take(nv).enumerate() {
                    *a = _mm256_add_epi32(
                        *a,
                        _mm256_madd_epi16(av, bvec[v]),
                    );
                }
            }
            p += 2;
        }
        for (r, accr) in acc.iter().enumerate() {
            let crow = c.as_mut_ptr().add((i + r) * n + j);
            for (v, a) in accr.iter().take(nv).enumerate() {
                let cp = crow.add(8 * v) as *mut __m256i;
                let sum = _mm256_add_epi32(_mm256_loadu_si256(cp), *a);
                _mm256_storeu_si256(cp, sum);
            }
        }
    } else if NR % 4 == 0 {
        let nv = NR / 4;
        let mut acc: [[__m128i; 4]; MR] = [[_mm_setzero_si128(); 4]; MR];
        let mut p = 0;
        while p < kc {
            let pair = p + 1 < kc;
            let mut bvec = [_mm_setzero_si128(); 4];
            for (v, bv) in bvec.iter_mut().take(nv).enumerate() {
                let bp_ptr = bstrip.as_ptr().add(p * NR + 4 * v);
                let bp = _mm_cvtepi8_epi16(_mm_cvtsi32_si128(
                    (bp_ptr as *const i32).read_unaligned(),
                ));
                let bq = if pair {
                    let bq_ptr =
                        bstrip.as_ptr().add((p + 1) * NR + 4 * v);
                    _mm_cvtepi8_epi16(_mm_cvtsi32_si128(
                        (bq_ptr as *const i32).read_unaligned(),
                    ))
                } else {
                    _mm_setzero_si128()
                };
                *bv = _mm_unpacklo_epi16(bp, bq);
            }
            let astrip = apack.as_ptr().add(p * MR);
            let astrip2 = apack.as_ptr().add((p + 1) * MR);
            for (r, accr) in acc.iter_mut().enumerate() {
                let a0 = *astrip.add(r);
                let a1 = if pair { *astrip2.add(r) } else { 0 };
                let av = _mm_set1_epi32(pair_broadcast_val(a0, a1));
                for (v, a) in accr.iter_mut().take(nv).enumerate() {
                    *a = _mm_add_epi32(*a, _mm_madd_epi16(av, bvec[v]));
                }
            }
            p += 2;
        }
        for (r, accr) in acc.iter().enumerate() {
            let crow = c.as_mut_ptr().add((i + r) * n + j);
            for (v, a) in accr.iter().take(nv).enumerate() {
                let cp = crow.add(4 * v) as *mut __m128i;
                let sum = _mm_add_epi32(_mm_loadu_si128(cp), *a);
                _mm_storeu_si128(cp, sum);
            }
        }
    } else {
        micro_kernel_i8_fixed_pb::<MR, NR>(apack, bstrip, c, n, i, j, kc);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blas::MICRO_KERNEL_SHAPES;
    use crate::util::rng::XorShift;

    fn rand_i8(len: usize, seed: u64) -> Vec<i8> {
        let mut rng = XorShift::new(seed);
        (0..len).map(|_| (rng.next_u64() % 256) as u8 as i8).collect()
    }

    /// Naive widening i32 oracle: the definitionally correct result.
    fn gemm_i8_naive(
        a: &[i8],
        b: &[i8],
        m: usize,
        n: usize,
        k: usize,
    ) -> Vec<i32> {
        let mut c = vec![0i32; m * n];
        for i in 0..m {
            for p in 0..k {
                let av = a[i * k + p] as i32;
                for j in 0..n {
                    c[i * n + j] += av * b[p * n + j] as i32;
                }
            }
        }
        c
    }

    #[test]
    fn int8_registry_matches_f32_registry() {
        // One grid means one thing: the int8 kernel family covers
        // exactly the same monomorphized shapes as the f32 family.
        assert_eq!(INT8_MICRO_KERNEL_SHAPES, MICRO_KERNEL_SHAPES);
    }

    #[test]
    fn blocked_i8_matches_naive_oracle_bitexact() {
        for &(m, n, k) in &[
            (1usize, 1usize, 1usize),
            (17, 13, 9),
            (37, 29, 23),
            (64, 64, 64),
        ] {
            let a = rand_i8(m * k, 7);
            let b = rand_i8(k * n, 8);
            let oracle = gemm_i8_naive(&a, &b, m, n, k);
            for &(mr, nr) in MICRO_KERNEL_SHAPES {
                let params = BlockedParams {
                    bm: 32,
                    bn: 32,
                    bk: 16,
                    mr,
                    nr,
                    threads: 1,
                };
                for isa in Isa::detect() {
                    let got =
                        gemm_i8_blocked_isa(&a, &b, m, n, k, &params, isa);
                    assert!(
                        got == oracle,
                        "{m}x{n}x{k} ({mr},{nr}) {isa} not bit-exact"
                    );
                }
            }
        }
    }

    #[test]
    fn threaded_i8_bit_identical_to_serial() {
        let (m, n, k) = (53, 31, 19);
        let a = rand_i8(m * k, 3);
        let b = rand_i8(k * n, 4);
        let base =
            BlockedParams { bm: 8, bn: 16, bk: 8, mr: 4, nr: 8, threads: 1 };
        for isa in Isa::detect() {
            let serial = gemm_i8_blocked_isa(&a, &b, m, n, k, &base, isa);
            for threads in [0usize, 2, 3, 8] {
                let par = gemm_i8_blocked_isa(
                    &a,
                    &b,
                    m,
                    n,
                    k,
                    &BlockedParams { threads, ..base },
                    isa,
                );
                assert!(serial == par, "{isa} threads={threads} diverged");
            }
        }
    }

    #[test]
    fn extreme_values_never_saturate_the_madd_path() {
        // The -128·-128 corner is the one a true maddubs kernel would
        // saturate on; the widening madd pairs cap at 2·128² and must
        // stay exact.
        let (m, n, k) = (8, 16, 33); // odd k exercises the zero-pair tail
        let a = vec![-128i8; m * k];
        let b = vec![-128i8; k * n];
        let oracle = gemm_i8_naive(&a, &b, m, n, k);
        assert_eq!(oracle[0], k as i32 * 128 * 128);
        let params =
            BlockedParams { bm: 8, bn: 16, bk: 8, mr: 4, nr: 8, threads: 1 };
        for isa in Isa::detect() {
            let got = gemm_i8_blocked_isa(&a, &b, m, n, k, &params, isa);
            assert!(got == oracle, "{isa} saturated or diverged");
        }
    }

    #[test]
    fn quantize_roundtrip_and_range() {
        let q = QuantParams::from_range(-3.0, 5.0);
        assert!(q.scale > 0.0);
        assert_eq!(q.quantize(-3.0), -128);
        assert_eq!(q.quantize(5.0), 127);
        // Real zero is exactly representable (the padding contract).
        assert_eq!(q.dequantize(q.quantize(0.0)), 0.0);
        // Round-trip error is bounded by half a step.
        for x in [-2.7f32, -0.1, 0.0, 0.4, 1.9, 4.99] {
            let back = q.dequantize(q.quantize(x));
            assert!(
                (back - x).abs() <= q.scale * 0.5 + 1e-6,
                "{x} -> {back} (scale {})",
                q.scale
            );
        }
        // Degenerate ranges quantize to the zero point.
        let d = QuantParams::from_range(0.0, 0.0);
        assert_eq!((d.scale, d.zero_point), (1.0, 0));
        assert_eq!(d.quantize(0.0), 0);
    }

    #[test]
    fn dequant_gemm_tracks_the_f32_oracle() {
        // Quantize an f32 problem, run the int8 path, and bound the
        // error against the f32 result by the quantization step sizes.
        let (m, n, k) = (24, 18, 31);
        let mut rng = XorShift::new(11);
        let a = rng.f32_vec(m * k);
        let b = rng.f32_vec(k * n);
        let qa = QuantParams::for_data(&a);
        let qb = QuantParams::for_data(&b);
        let aq = quantize_slice(&a, &qa);
        let bq = quantize_slice(&b, &qb);
        let f32_oracle = crate::blas::gemm_naive(&a, &b, m, n, k);
        let params =
            BlockedParams { bm: 16, bn: 16, bk: 8, mr: 2, nr: 4, threads: 1 };
        for isa in Isa::detect() {
            let got =
                gemm_i8_dequant(&aq, &bq, m, n, k, &qa, &qb, &params, isa);
            // Per-product error ≤ 0.5·sa·|b| + 0.5·sb·|a| + 0.25·sa·sb;
            // inputs are in [-0.5, 0.5], so a comfortable bound is
            // k · (0.5·sa·0.5 + 0.5·sb·0.5 + sa·sb).
            let bound = k as f32
                * (0.25 * qa.scale + 0.25 * qb.scale
                    + qa.scale * qb.scale)
                + 1e-5;
            for (g, o) in got.iter().zip(&f32_oracle) {
                assert!(
                    (g - o).abs() <= bound,
                    "dequant {g} vs f32 {o} beyond {bound} ({isa})"
                );
            }
        }
    }

    #[test]
    fn conv_i8_padding_contributes_zero() {
        // SAME padding in the quantized domain uses the input
        // zero-point, which dequantizes to exactly 0 — so an all-zeros
        // input convolves to exactly 0 even with a nonzero zero-point.
        let s = Conv2dShape::same(1, 5, 5, 3, 4, 3, 1);
        let x = vec![0.0f32; s.input_elems()];
        let mut rng = XorShift::new(21);
        let f = rng.f32_vec(s.filter_elems());
        let qx = QuantParams::from_range(-1.0, 3.0); // nonzero zero-point
        assert_ne!(qx.zero_point, 0);
        let qf = QuantParams::for_data(&f);
        let params = BlockedParams { threads: 1, ..Default::default() };
        let out = conv2d_im2col_i8(&x, &f, &s, &qx, &qf, &params, Isa::Scalar);
        assert!(out.iter().all(|&v| v == 0.0), "padding leaked");
    }

    #[test]
    fn conv_i8_tracks_the_direct_oracle() {
        let s = Conv2dShape::same(2, 7, 6, 3, 4, 3, 1);
        let mut rng = XorShift::new(31);
        let x = rng.f32_vec(s.input_elems());
        let f = rng.f32_vec(s.filter_elems());
        let qx = QuantParams::for_data(&x);
        let qf = QuantParams::for_data(&f);
        let oracle = crate::blas::conv2d_direct(&x, &f, &s);
        let k = s.window * s.window * s.in_c;
        let bound = k as f32
            * (0.25 * qx.scale + 0.25 * qf.scale + qx.scale * qf.scale)
            + 1e-5;
        let params =
            BlockedParams { bm: 16, bn: 16, bk: 8, mr: 2, nr: 4, threads: 1 };
        for isa in Isa::detect() {
            let got = conv2d_im2col_i8(&x, &f, &s, &qx, &qf, &params, isa);
            for (g, o) in got.iter().zip(&oracle) {
                assert!(
                    (g - o).abs() <= bound,
                    "conv i8 {g} vs direct {o} beyond {bound} ({isa})"
                );
            }
        }
        // And across thread counts the int8 conv is bit-identical.
        let serial =
            conv2d_im2col_i8(&x, &f, &s, &qx, &qf, &params, Isa::Scalar);
        for threads in [0usize, 2, 3] {
            let p = BlockedParams { threads, ..params };
            let par =
                conv2d_im2col_i8(&x, &f, &s, &qx, &qf, &p, Isa::Scalar);
            assert!(serial == par, "threads={threads} diverged");
        }
    }

    #[test]
    fn packed_b_i8_bit_exact_vs_unpacked() {
        // pack:ab on the int8 stack: integer arithmetic is exact, so the
        // packed path must be bit-identical on every shape (including
        // ragged and degenerate-ish), registry and off-registry tiles,
        // every detected ISA, serial and threaded.
        let scratch = Scratch::new();
        for &(m, n, k) in &[
            (1usize, 1usize, 1usize),
            (17, 13, 9),
            (37, 29, 23),
            (53, 31, 19),
        ] {
            let a = rand_i8(m * k, 41);
            let b = rand_i8(k * n, 42);
            for &(mr, nr) in &[(2usize, 4usize), (4, 8), (8, 16), (3, 5)] {
                for threads in [1usize, 0, 3] {
                    let params = BlockedParams {
                        bm: 16,
                        bn: 16,
                        bk: 8,
                        mr,
                        nr,
                        threads,
                    };
                    for isa in Isa::detect() {
                        let unpacked = gemm_i8_blocked_isa(
                            &a, &b, m, n, k, &params, isa,
                        );
                        let packed = gemm_i8_blocked_ex(
                            &a, &b, m, n, k, &params, isa, Pack::Ab,
                            &scratch,
                        );
                        assert!(
                            unpacked == packed,
                            "{m}x{n}x{k} ({mr},{nr}) t{threads} {isa}: \
                             i8 pack:ab not bit-exact"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn dequant_and_conv_ex_bit_identical_and_allocation_free() {
        // The _ex entry points must be bit-identical to the historical
        // ones under both pack settings, and a prewarmed arena must
        // absorb the whole per-call take-set (zero growth).
        let s = Conv2dShape::same(2, 7, 6, 3, 4, 3, 1);
        let mut rng = XorShift::new(77);
        let x = rng.f32_vec(s.input_elems());
        let f = rng.f32_vec(s.filter_elems());
        let qx = QuantParams::for_data(&x);
        let qf = QuantParams::for_data(&f);
        let params =
            BlockedParams { bm: 16, bn: 16, bk: 8, mr: 2, nr: 4, threads: 3 };
        let baseline =
            conv2d_im2col_i8(&x, &f, &s, &qx, &qf, &params, Isa::Scalar);
        for pack in Pack::all() {
            let scratch = Scratch::new();
            scratch.prewarm(&conv2d_im2col_i8_workspace(&s, &params, pack));
            let grows = scratch.stats().grows;
            for _ in 0..3 {
                let got = conv2d_im2col_i8_ex(
                    &x,
                    &f,
                    &s,
                    &qx,
                    &qf,
                    &params,
                    Isa::Scalar,
                    pack,
                    &scratch,
                );
                assert!(got == baseline, "conv _ex diverged ({pack})");
            }
            assert_eq!(
                scratch.stats().grows,
                grows,
                "steady-state conv grew the arena ({pack})"
            );
        }
        // Dequant GEMM: same contract on a raw quantized problem.
        let (m, n, k) = (24, 18, 31);
        let a = rand_i8(m * k, 51);
        let b = rand_i8(k * n, 52);
        let base = gemm_i8_dequant(&a, &b, m, n, k, &qx, &qf, &params,
            Isa::Scalar);
        let scratch = Scratch::new();
        scratch.prewarm(&gemm_i8_dequant_workspace(
            m, n, k, &params, Pack::Ab,
        ));
        let grows = scratch.stats().grows;
        let got = gemm_i8_dequant_ex(
            &a,
            &b,
            m,
            n,
            k,
            &qx,
            &qf,
            &params,
            Isa::Scalar,
            Pack::Ab,
            &scratch,
        );
        assert!(got == base, "dequant _ex diverged");
        assert_eq!(scratch.stats().grows, grows, "dequant grew the arena");
    }

    #[test]
    fn quantize_into_matches_quantize_slice() {
        let mut rng = XorShift::new(19);
        let xs = rng.f32_vec(37);
        let q = QuantParams::for_data(&xs);
        let mut out = vec![0i8; xs.len()];
        quantize_into(&xs, &q, &mut out);
        assert_eq!(out, quantize_slice(&xs, &q));
    }

    #[test]
    fn dtype_name_roundtrip() {
        for d in Dtype::all() {
            assert_eq!(d.to_string().parse::<Dtype>().unwrap(), d);
        }
        assert!("f16".parse::<Dtype>().is_err());
        assert_eq!(Dtype::default(), Dtype::F32);
    }

    #[test]
    #[should_panic(expected = "i32 accumulation bound")]
    fn oversized_k_is_a_loud_panic() {
        let k = MAX_I8_GEMM_K + 1;
        let a = vec![0i8; k];
        let b = vec![0i8; k];
        gemm_i8_blocked_isa(
            &a,
            &b,
            1,
            1,
            k,
            &BlockedParams { threads: 1, ..Default::default() },
            Isa::Scalar,
        );
    }
}
