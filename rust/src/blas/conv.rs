//! Host Rust 2D convolution references: a direct (naive) oracle and the
//! im2col+GEMM path the native engine dispatches to.
//!
//! Layouts match the Pallas kernels and the artifact manifest: NHWC
//! input, RSCK (window x window x in_c x out_c) filters, NHWK output.
//! SAME padding follows the TF/JAX convention (`out = ceil(in / stride)`,
//! deficit split low-side-first), so the native engine's numbers line up
//! with the AOT artifacts bit-for-bit in structure.

use super::blocked::{gemm_blocked, BlockedParams};

/// Fully resolved shape of one conv2d execution.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Conv2dShape {
    pub batch: usize,
    pub in_h: usize,
    pub in_w: usize,
    pub in_c: usize,
    pub out_h: usize,
    pub out_w: usize,
    pub out_c: usize,
    pub window: usize,
    pub stride: usize,
    pub pad_top: usize,
    pub pad_left: usize,
}

impl Conv2dShape {
    /// SAME-padded shape: `out = ceil(in / stride)`, padding deficit
    /// split with the smaller half on the top/left (TF/JAX convention).
    pub fn same(
        batch: usize,
        in_h: usize,
        in_w: usize,
        in_c: usize,
        out_c: usize,
        window: usize,
        stride: usize,
    ) -> Self {
        let out_h = in_h.div_ceil(stride);
        let out_w = in_w.div_ceil(stride);
        let pad_h =
            ((out_h - 1) * stride + window).saturating_sub(in_h);
        let pad_w =
            ((out_w - 1) * stride + window).saturating_sub(in_w);
        Self {
            batch,
            in_h,
            in_w,
            in_c,
            out_h,
            out_w,
            out_c,
            window,
            stride,
            pad_top: pad_h / 2,
            pad_left: pad_w / 2,
        }
    }

    /// VALID (no padding) shape: `out = (in - window) / stride + 1`.
    pub fn valid(
        batch: usize,
        in_h: usize,
        in_w: usize,
        in_c: usize,
        out_c: usize,
        window: usize,
        stride: usize,
    ) -> Self {
        Self {
            batch,
            in_h,
            in_w,
            in_c,
            out_h: (in_h - window) / stride + 1,
            out_w: (in_w - window) / stride + 1,
            out_c,
            window,
            stride,
            pad_top: 0,
            pad_left: 0,
        }
    }

    pub fn input_elems(&self) -> usize {
        self.batch * self.in_h * self.in_w * self.in_c
    }

    pub fn filter_elems(&self) -> usize {
        self.window * self.window * self.in_c * self.out_c
    }

    pub fn output_elems(&self) -> usize {
        self.batch * self.out_h * self.out_w * self.out_c
    }
}

/// Direct (quadruple-loop) convolution — the correctness oracle.
pub fn conv2d_direct(x: &[f32], f: &[f32], s: &Conv2dShape) -> Vec<f32> {
    assert_eq!(x.len(), s.input_elems(), "input shape mismatch");
    assert_eq!(f.len(), s.filter_elems(), "filter shape mismatch");
    let mut out = vec![0.0f32; s.output_elems()];
    for b in 0..s.batch {
        for oh in 0..s.out_h {
            for ow in 0..s.out_w {
                let o0 = ((b * s.out_h + oh) * s.out_w + ow) * s.out_c;
                for r in 0..s.window {
                    let ih = (oh * s.stride + r) as isize - s.pad_top as isize;
                    if ih < 0 || ih as usize >= s.in_h {
                        continue;
                    }
                    for sw in 0..s.window {
                        let iw = (ow * s.stride + sw) as isize
                            - s.pad_left as isize;
                        if iw < 0 || iw as usize >= s.in_w {
                            continue;
                        }
                        let x0 = ((b * s.in_h + ih as usize) * s.in_w
                            + iw as usize)
                            * s.in_c;
                        for c in 0..s.in_c {
                            let xv = x[x0 + c];
                            let f0 = ((r * s.window + sw) * s.in_c + c)
                                * s.out_c;
                            for k in 0..s.out_c {
                                out[o0 + k] += xv * f[f0 + k];
                            }
                        }
                    }
                }
            }
        }
    }
    out
}

/// Materialize the im2col patch matrix: `(batch*out_h*out_w) x
/// (window*window*in_c)`, rows in output-pixel order, columns in (r, s, c)
/// order — exactly the RSC-major flattening of the filters, so the
/// lowered GEMM is `patches @ filters`.
pub fn im2col(x: &[f32], s: &Conv2dShape) -> Vec<f32> {
    assert_eq!(x.len(), s.input_elems(), "input shape mismatch");
    let kdim = s.window * s.window * s.in_c;
    let mut patches =
        vec![0.0f32; s.batch * s.out_h * s.out_w * kdim];
    let mut row = 0usize;
    for b in 0..s.batch {
        for oh in 0..s.out_h {
            for ow in 0..s.out_w {
                let base = row * kdim;
                for r in 0..s.window {
                    let ih = (oh * s.stride + r) as isize - s.pad_top as isize;
                    for sw in 0..s.window {
                        let iw = (ow * s.stride + sw) as isize
                            - s.pad_left as isize;
                        if ih < 0
                            || ih as usize >= s.in_h
                            || iw < 0
                            || iw as usize >= s.in_w
                        {
                            continue; // zero padding (buffer pre-zeroed)
                        }
                        let x0 = ((b * s.in_h + ih as usize) * s.in_w
                            + iw as usize)
                            * s.in_c;
                        let p0 = base + (r * s.window + sw) * s.in_c;
                        patches[p0..p0 + s.in_c]
                            .copy_from_slice(&x[x0..x0 + s.in_c]);
                    }
                }
                row += 1;
            }
        }
    }
    patches
}

/// Convolution by im2col + blocked GEMM — the native engine's conv path
/// (the paper's §4.1 "lower onto GEMM" algorithm played on the host).
pub fn conv2d_im2col(
    x: &[f32],
    f: &[f32],
    s: &Conv2dShape,
    params: &BlockedParams,
) -> Vec<f32> {
    assert_eq!(f.len(), s.filter_elems(), "filter shape mismatch");
    let patches = im2col(x, s);
    let m = s.batch * s.out_h * s.out_w;
    let k = s.window * s.window * s.in_c;
    // Filters are RSCK row-major: already the (K x N) operand.
    gemm_blocked(&patches, f, m, s.out_c, k, params)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blas::max_abs_diff;
    use crate::util::rng::XorShift;

    fn rand(n: usize, seed: u64) -> Vec<f32> {
        XorShift::new(seed).f32_vec(n)
    }

    #[test]
    fn same_padding_geometry() {
        // 3x3/s1 SAME keeps the spatial size; pad is 1 on each side.
        let s = Conv2dShape::same(1, 14, 14, 8, 16, 3, 1);
        assert_eq!((s.out_h, s.out_w), (14, 14));
        assert_eq!((s.pad_top, s.pad_left), (1, 1));
        // 3x3/s2 SAME on even input: ceil(56/2)=28, total pad 1 -> top 0.
        let s = Conv2dShape::same(1, 56, 56, 4, 4, 3, 2);
        assert_eq!((s.out_h, s.out_w), (28, 28));
        assert_eq!(s.pad_top, 0);
        // 1x1 never pads.
        let s = Conv2dShape::same(2, 7, 7, 32, 64, 1, 1);
        assert_eq!((s.pad_top, s.pad_left), (0, 0));
    }

    #[test]
    fn valid_padding_geometry() {
        let s = Conv2dShape::valid(1, 230, 230, 3, 64, 7, 2);
        assert_eq!((s.out_h, s.out_w), (112, 112));
    }

    #[test]
    fn im2col_matches_direct() {
        for &(h, w, c, k, win, stride) in &[
            (8, 8, 3, 4, 3, 1),
            (9, 7, 2, 5, 3, 2),
            (6, 6, 4, 4, 1, 1),
            (10, 10, 2, 3, 5, 2),
        ] {
            let s = Conv2dShape::same(2, h, w, c, k, win, stride);
            let x = rand(s.input_elems(), 1);
            let f = rand(s.filter_elems(), 2);
            let direct = conv2d_direct(&x, &f, &s);
            let lowered =
                conv2d_im2col(&x, &f, &s, &BlockedParams::default());
            assert!(
                max_abs_diff(&direct, &lowered) < 1e-4,
                "{h}x{w}x{c}->{k} {win}x{win}/s{stride}"
            );
        }
    }

    #[test]
    fn valid_conv_matches_direct() {
        let s = Conv2dShape::valid(1, 12, 12, 3, 8, 5, 2);
        let x = rand(s.input_elems(), 3);
        let f = rand(s.filter_elems(), 4);
        let direct = conv2d_direct(&x, &f, &s);
        let lowered = conv2d_im2col(&x, &f, &s, &BlockedParams::default());
        assert!(max_abs_diff(&direct, &lowered) < 1e-4);
    }

    #[test]
    fn pointwise_conv_is_a_gemm() {
        // A 1x1 conv is exactly (B*H*W x C) @ (C x K).
        let s = Conv2dShape::same(2, 5, 5, 16, 8, 1, 1);
        let x = rand(s.input_elems(), 5);
        let f = rand(s.filter_elems(), 6);
        let conv = conv2d_im2col(&x, &f, &s, &BlockedParams::default());
        let gemm = crate::blas::gemm_naive(&x, &f, 2 * 5 * 5, 8, 16);
        assert!(max_abs_diff(&conv, &gemm) < 1e-4);
    }

    #[test]
    fn identity_filter_passes_input_through() {
        // 1x1, in_c == out_c, identity matrix filter.
        let c = 6;
        let s = Conv2dShape::same(1, 4, 4, c, c, 1, 1);
        let x = rand(s.input_elems(), 7);
        let mut f = vec![0.0f32; c * c];
        for i in 0..c {
            f[i * c + i] = 1.0;
        }
        let out = conv2d_direct(&x, &f, &s);
        assert!(max_abs_diff(&out, &x) < 1e-6);
    }
}
