//! Host Rust 2D convolution: the direct (naive) oracle, the im2col+GEMM
//! lowering, and [`conv2d_native`] — the algorithm dispatch the native
//! engine's plans execute (im2col / tiled / winograd, with im2col
//! fallback off an algorithm's domain).
//!
//! Layouts match the Pallas kernels and the artifact manifest: NHWC
//! input, RSCK (window x window x in_c x out_c) filters, NHWK output.
//! SAME padding follows the TF/JAX convention (`out = ceil(in / stride)`,
//! deficit split low-side-first), so the native engine's numbers line up
//! with the AOT artifacts bit-for-bit in structure.
//!
//! Parallelism: `conv2d_im2col` honors `BlockedParams::threads` twice —
//! the patch matrix is materialized in batch×output-row chunks claimed by
//! the pool workers (disjoint writes, so bit-identical to serial), and
//! the lowered GEMM parallelizes over its own macro-tile bands.

use super::blocked::{
    gemm_blocked_ex, gemm_workspace, BlockedParams, Pack,
};
use super::direct::conv2d_tiled;
use super::winograd::{conv2d_winograd_ex, conv2d_winograd_workspace};
use super::Isa;
use crate::config::{ConvAlgorithm, ConvConfig};
use crate::util::pool;
use crate::util::scratch::{Scratch, Workspace};

/// Fully resolved shape of one conv2d execution.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Conv2dShape {
    /// Batch size N.
    pub batch: usize,
    /// Input height.
    pub in_h: usize,
    /// Input width.
    pub in_w: usize,
    /// Input channels.
    pub in_c: usize,
    /// Output height.
    pub out_h: usize,
    /// Output width.
    pub out_w: usize,
    /// Output channels.
    pub out_c: usize,
    /// Square filter window size.
    pub window: usize,
    /// Spatial stride.
    pub stride: usize,
    /// Zero-padding rows above the input (SAME convention).
    pub pad_top: usize,
    /// Zero-padding columns left of the input (SAME convention).
    pub pad_left: usize,
}

impl Conv2dShape {
    /// SAME-padded shape: `out = ceil(in / stride)`, padding deficit
    /// split with the smaller half on the top/left (TF/JAX convention).
    pub fn same(
        batch: usize,
        in_h: usize,
        in_w: usize,
        in_c: usize,
        out_c: usize,
        window: usize,
        stride: usize,
    ) -> Self {
        let out_h = in_h.div_ceil(stride);
        let out_w = in_w.div_ceil(stride);
        let pad_h =
            ((out_h - 1) * stride + window).saturating_sub(in_h);
        let pad_w =
            ((out_w - 1) * stride + window).saturating_sub(in_w);
        Self {
            batch,
            in_h,
            in_w,
            in_c,
            out_h,
            out_w,
            out_c,
            window,
            stride,
            pad_top: pad_h / 2,
            pad_left: pad_w / 2,
        }
    }

    /// VALID (no padding) shape: `out = (in - window) / stride + 1`.
    pub fn valid(
        batch: usize,
        in_h: usize,
        in_w: usize,
        in_c: usize,
        out_c: usize,
        window: usize,
        stride: usize,
    ) -> Self {
        Self {
            batch,
            in_h,
            in_w,
            in_c,
            out_h: (in_h - window) / stride + 1,
            out_w: (in_w - window) / stride + 1,
            out_c,
            window,
            stride,
            pad_top: 0,
            pad_left: 0,
        }
    }

    /// Element count of the NHWC input tensor.
    pub fn input_elems(&self) -> usize {
        self.batch * self.in_h * self.in_w * self.in_c
    }

    /// Element count of the RSCK filter tensor.
    pub fn filter_elems(&self) -> usize {
        self.window * self.window * self.in_c * self.out_c
    }

    /// Element count of the NHWK output tensor.
    pub fn output_elems(&self) -> usize {
        self.batch * self.out_h * self.out_w * self.out_c
    }
}

/// Direct (quadruple-loop) convolution — the correctness oracle.
pub fn conv2d_direct(x: &[f32], f: &[f32], s: &Conv2dShape) -> Vec<f32> {
    assert_eq!(x.len(), s.input_elems(), "input shape mismatch");
    assert_eq!(f.len(), s.filter_elems(), "filter shape mismatch");
    let mut out = vec![0.0f32; s.output_elems()];
    for b in 0..s.batch {
        for oh in 0..s.out_h {
            for ow in 0..s.out_w {
                let o0 = ((b * s.out_h + oh) * s.out_w + ow) * s.out_c;
                for r in 0..s.window {
                    let ih = (oh * s.stride + r) as isize - s.pad_top as isize;
                    if ih < 0 || ih as usize >= s.in_h {
                        continue;
                    }
                    for sw in 0..s.window {
                        let iw = (ow * s.stride + sw) as isize
                            - s.pad_left as isize;
                        if iw < 0 || iw as usize >= s.in_w {
                            continue;
                        }
                        let x0 = ((b * s.in_h + ih as usize) * s.in_w
                            + iw as usize)
                            * s.in_c;
                        for c in 0..s.in_c {
                            let xv = x[x0 + c];
                            let f0 = ((r * s.window + sw) * s.in_c + c)
                                * s.out_c;
                            for k in 0..s.out_c {
                                out[o0 + k] += xv * f[f0 + k];
                            }
                        }
                    }
                }
            }
        }
    }
    out
}

/// Fill `out` with im2col patch rows `[row0, row1)` of the full patch
/// matrix (`out.len() == (row1 - row0) * window²·in_c`).  Row index
/// decomposes as `row = (b * out_h + oh) * out_w + ow`, so any contiguous
/// range is a batch×output-pixel chunk — the unit the parallel path
/// hands to each pool worker.  `out` must be pre-zeroed (padding taps are
/// skipped, not written).
fn im2col_rows(
    x: &[f32],
    s: &Conv2dShape,
    row0: usize,
    row1: usize,
    out: &mut [f32],
) {
    let kdim = s.window * s.window * s.in_c;
    debug_assert_eq!(out.len(), (row1 - row0) * kdim);
    for row in row0..row1 {
        let ow = row % s.out_w;
        let oh = (row / s.out_w) % s.out_h;
        let b = row / (s.out_w * s.out_h);
        let base = (row - row0) * kdim;
        for r in 0..s.window {
            let ih = (oh * s.stride + r) as isize - s.pad_top as isize;
            for sw in 0..s.window {
                let iw =
                    (ow * s.stride + sw) as isize - s.pad_left as isize;
                if ih < 0
                    || ih as usize >= s.in_h
                    || iw < 0
                    || iw as usize >= s.in_w
                {
                    continue; // zero padding (buffer pre-zeroed)
                }
                let x0 = ((b * s.in_h + ih as usize) * s.in_w
                    + iw as usize)
                    * s.in_c;
                let p0 = base + (r * s.window + sw) * s.in_c;
                out[p0..p0 + s.in_c].copy_from_slice(&x[x0..x0 + s.in_c]);
            }
        }
    }
}

/// Materialize the im2col patch matrix: `(batch*out_h*out_w) x
/// (window*window*in_c)`, rows in output-pixel order, columns in (r, s, c)
/// order — exactly the RSC-major flattening of the filters, so the
/// lowered GEMM is `patches @ filters`.
pub fn im2col(x: &[f32], s: &Conv2dShape) -> Vec<f32> {
    im2col_threaded(x, s, 1)
}

/// [`im2col`] with the patch rows built in parallel chunks (`threads`
/// follows the [`BlockedParams::threads`] convention).  The chunks write
/// disjoint row ranges of the pre-zeroed buffer, so the result is
/// bit-identical for every thread count.
pub fn im2col_threaded(
    x: &[f32],
    s: &Conv2dShape,
    threads: usize,
) -> Vec<f32> {
    let kdim = s.window * s.window * s.in_c;
    let rows = s.batch * s.out_h * s.out_w;
    let mut patches = vec![0.0f32; rows * kdim];
    im2col_into(x, s, threads, &mut patches);
    patches
}

/// [`im2col_threaded`] into a caller-supplied buffer (the arena form):
/// zero-fill, then build patch rows in disjoint parallel chunks — same
/// values, no allocation.
fn im2col_into(
    x: &[f32],
    s: &Conv2dShape,
    threads: usize,
    patches: &mut [f32],
) {
    assert_eq!(x.len(), s.input_elems(), "input shape mismatch");
    let kdim = s.window * s.window * s.in_c;
    let rows = s.batch * s.out_h * s.out_w;
    debug_assert_eq!(patches.len(), rows * kdim);
    patches.fill(0.0);
    let workers = pool::resolve_threads(threads);
    if workers <= 1 || rows <= 1 || kdim == 0 {
        im2col_rows(x, s, 0, rows, patches);
        return;
    }
    let chunk_rows = rows.div_ceil(workers);
    let chunks: Vec<(usize, &mut [f32])> = patches
        .chunks_mut(chunk_rows * kdim)
        .enumerate()
        .collect();
    pool::run_parallel(workers, chunks, |_, (c, chunk)| {
        let row0 = c * chunk_rows;
        let row1 = (row0 + chunk_rows).min(rows);
        im2col_rows(x, s, row0, row1, chunk);
    });
}

/// Convolution by im2col + blocked GEMM — the native engine's historical
/// conv path (the paper's §4.1 "lower onto GEMM" algorithm played on the
/// host), with the scalar micro-kernel.  See [`conv2d_im2col_isa`] for
/// the ISA-explicit form plans execute.
pub fn conv2d_im2col(
    x: &[f32],
    f: &[f32],
    s: &Conv2dShape,
    params: &BlockedParams,
) -> Vec<f32> {
    conv2d_im2col_isa(x, f, s, params, Isa::Scalar)
}

/// [`conv2d_im2col`] with an explicit micro-kernel [`Isa`] for the
/// lowered GEMM — the conv side of the runtime-dispatched SIMD axis
/// (`ConvPoint::isa`).  Both stages honor `params.threads`; `isa` must
/// be available on the executing host (the plan layer degrades off-host
/// ISAs to scalar), and `Isa::Scalar` is bit-identical to
/// [`conv2d_im2col`].
pub fn conv2d_im2col_isa(
    x: &[f32],
    f: &[f32],
    s: &Conv2dShape,
    params: &BlockedParams,
    isa: Isa,
) -> Vec<f32> {
    conv2d_im2col_ex(x, f, s, params, isa, Pack::A, &Scratch::new())
}

/// [`conv2d_im2col_isa`] with the operand-staging [`Pack`] axis for the
/// lowered GEMM and a caller-owned [`Scratch`] arena for the patch
/// matrix and every GEMM packing buffer — the conv side of the
/// zero-allocation hot path.  Bit-identical to [`conv2d_im2col_isa`]
/// per ISA (`Pack::Ab` runs the packed-B twins, which preserve the
/// floating-point order).
pub fn conv2d_im2col_ex(
    x: &[f32],
    f: &[f32],
    s: &Conv2dShape,
    params: &BlockedParams,
    isa: Isa,
    pack: Pack,
    scratch: &Scratch,
) -> Vec<f32> {
    assert_eq!(f.len(), s.filter_elems(), "filter shape mismatch");
    let m = s.batch * s.out_h * s.out_w;
    let k = s.window * s.window * s.in_c;
    let mut patches = scratch.take_f32(m * k);
    im2col_into(x, s, params.threads, &mut patches);
    // Filters are RSCK row-major: already the (K x N) operand.
    let out = gemm_blocked_ex(
        &patches, f, m, s.out_c, k, params, isa, pack, scratch,
    );
    scratch.put_f32(patches);
    out
}

/// The worst-case arena take-set of one [`conv2d_im2col_ex`] call: the
/// patch matrix plus the lowered GEMM's set.
pub fn conv2d_im2col_workspace(
    s: &Conv2dShape,
    params: &BlockedParams,
    pack: Pack,
) -> Workspace {
    let m = s.batch * s.out_h * s.out_w;
    let k = s.window * s.window * s.in_c;
    let mut ws = gemm_workspace(m, s.out_c, k, params, pack);
    ws.f32_lens.push(m * k);
    ws
}

/// Dimensions-only form of [`native_conv_algorithm`], for callers that
/// have a layer's `(window, stride)` but no fully resolved shape (the
/// tuner's sweep applicability filter).  THE single fallback rule —
/// everything else ([`native_conv_algorithm`], the sweep filter)
/// delegates here: an algorithm whose kernel cannot compute the layer
/// ([`ConvAlgorithm::supports`]), or a Winograd configuration with a
/// `wino_m` outside the native F(2×2)/F(4×4) kernels, runs
/// [`ConvAlgorithm::Im2col`] instead.
pub fn native_conv_algorithm_dims(
    cfg: &ConvConfig,
    window: u32,
    stride: u32,
) -> ConvAlgorithm {
    if cfg.algorithm.supports(window, stride)
        && (cfg.algorithm != ConvAlgorithm::Winograd
            || matches!(cfg.wino_m, 2 | 4))
    {
        cfg.algorithm
    } else {
        ConvAlgorithm::Im2col
    }
}

/// The algorithm a native conv configuration *actually* executes on a
/// shape: the requested algorithm when the kernel can compute it,
/// [`ConvAlgorithm::Im2col`] otherwise (see
/// [`native_conv_algorithm_dims`] for the rule).  `NativeEngine`
/// resolves this at plan time (so `planned_conv` reports what will
/// really run) and [`conv2d_native`] enforces it at dispatch.
pub fn native_conv_algorithm(
    cfg: &ConvConfig,
    s: &Conv2dShape,
) -> ConvAlgorithm {
    native_conv_algorithm_dims(cfg, s.window as u32, s.stride as u32)
}

/// Convolution by whichever algorithm `cfg` selects, with the scalar
/// micro-kernel — see [`conv2d_native_isa`] for the ISA-explicit form
/// the native engine's plans execute.
pub fn conv2d_native(
    x: &[f32],
    f: &[f32],
    s: &Conv2dShape,
    cfg: &ConvConfig,
    blocked: &BlockedParams,
) -> Vec<f32> {
    conv2d_native_isa(x, f, s, cfg, blocked, Isa::Scalar)
}

/// Convolution by whichever algorithm `cfg` selects — the dispatch the
/// native engine's plans execute, making the conv *algorithm* a kernel
/// parameter exactly like the tile sizes (paper §4.1):
///
/// * [`ConvAlgorithm::Im2col`] → [`conv2d_im2col_isa`] under `blocked`
///   and `isa`;
/// * [`ConvAlgorithm::Tiled`] / [`ConvAlgorithm::Naive`] →
///   [`conv2d_tiled`](super::conv2d_tiled) under `cfg`'s tile/vector
///   knobs (the naive kernel is the 1×1-tile member of the family; the
///   direct kernels have no lowered GEMM, so `isa` does not apply);
/// * [`ConvAlgorithm::Winograd`] →
///   [`conv2d_winograd`](super::conv2d_winograd) at `cfg.wino_m`, its
///   transform-domain batched GEMMs under `blocked` and `isa`, falling
///   back to im2col off its domain (see [`native_conv_algorithm`]).
///
/// All paths honor `blocked.threads` with the crate's disjoint-slice
/// discipline, so every algorithm is bit-identical across thread counts;
/// algorithms agree with each other within floating-point tolerance
/// (proptested).  `isa` must be available on the executing host — the
/// plan layer degrades off-host ISAs to scalar before dispatch.
pub fn conv2d_native_isa(
    x: &[f32],
    f: &[f32],
    s: &Conv2dShape,
    cfg: &ConvConfig,
    blocked: &BlockedParams,
    isa: Isa,
) -> Vec<f32> {
    conv2d_native_ex(x, f, s, cfg, blocked, isa, Pack::A, &Scratch::new())
}

/// [`conv2d_native_isa`] with the [`Pack`] axis and a caller-owned
/// [`Scratch`] arena — what a `NativeEngine` conv plan executes.  The
/// pack axis reaches the GEMM-lowered algorithms (im2col's lowered GEMM
/// and Winograd's transform-domain batched GEMMs); the direct kernels
/// (tiled/naive) have no GEMM operand to stage, so `pack` is inert
/// there by construction (mirrored by the sweep's applicability rule).
/// Bit-identical to [`conv2d_native_isa`] per ISA.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_native_ex(
    x: &[f32],
    f: &[f32],
    s: &Conv2dShape,
    cfg: &ConvConfig,
    blocked: &BlockedParams,
    isa: Isa,
    pack: Pack,
    scratch: &Scratch,
) -> Vec<f32> {
    match native_conv_algorithm(cfg, s) {
        ConvAlgorithm::Im2col => {
            conv2d_im2col_ex(x, f, s, blocked, isa, pack, scratch)
        }
        ConvAlgorithm::Winograd => conv2d_winograd_ex(
            x,
            f,
            s,
            cfg.wino_m as usize,
            blocked,
            isa,
            pack,
            scratch,
        ),
        ConvAlgorithm::Tiled | ConvAlgorithm::Naive => {
            conv2d_tiled(x, f, s, cfg, blocked.threads)
        }
    }
}

/// The worst-case arena take-set of one [`conv2d_native_ex`] call,
/// resolved through [`native_conv_algorithm`] exactly like the dispatch
/// (so the plan's workspace reflects what will really run).  The direct
/// kernels keep their small per-worker stack-like buffers outside the
/// arena — their take-set is empty.
pub fn conv2d_native_workspace(
    s: &Conv2dShape,
    cfg: &ConvConfig,
    blocked: &BlockedParams,
    pack: Pack,
) -> Workspace {
    match native_conv_algorithm(cfg, s) {
        ConvAlgorithm::Im2col => {
            conv2d_im2col_workspace(s, blocked, pack)
        }
        ConvAlgorithm::Winograd => conv2d_winograd_workspace(
            s,
            cfg.wino_m as usize,
            blocked,
            pack,
        ),
        ConvAlgorithm::Tiled | ConvAlgorithm::Naive => Workspace::none(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blas::max_abs_diff;
    use crate::util::rng::XorShift;

    fn rand(n: usize, seed: u64) -> Vec<f32> {
        XorShift::new(seed).f32_vec(n)
    }

    /// The parameter sets conv tests run under: the default, a small
    /// serial config, and threaded configs — so tuned (non-default) conv
    /// configurations are exercised by the suite, not just
    /// `BlockedParams::default()`.
    fn param_matrix() -> Vec<BlockedParams> {
        vec![
            BlockedParams::default(),
            BlockedParams { bm: 8, bn: 8, bk: 8, mr: 2, nr: 2, threads: 1 },
            BlockedParams { bm: 16, bn: 32, bk: 16, mr: 4, nr: 8, threads: 2 },
            BlockedParams { bm: 8, bn: 16, bk: 8, mr: 4, nr: 4, threads: 8 },
        ]
    }

    /// Assert `conv2d_im2col` matches the direct oracle for a shape,
    /// under every parameter set in the matrix.
    fn check_against_direct(s: &Conv2dShape, seed: u64) {
        let x = rand(s.input_elems(), seed);
        let f = rand(s.filter_elems(), seed + 1);
        let direct = conv2d_direct(&x, &f, s);
        for params in param_matrix() {
            let lowered = conv2d_im2col(&x, &f, s, &params);
            assert!(
                max_abs_diff(&direct, &lowered) < 1e-4,
                "{s:?} under {params:?}"
            );
        }
    }

    #[test]
    fn same_padding_geometry() {
        // 3x3/s1 SAME keeps the spatial size; pad is 1 on each side.
        let s = Conv2dShape::same(1, 14, 14, 8, 16, 3, 1);
        assert_eq!((s.out_h, s.out_w), (14, 14));
        assert_eq!((s.pad_top, s.pad_left), (1, 1));
        // 3x3/s2 SAME on even input: ceil(56/2)=28, total pad 1 -> top 0.
        let s = Conv2dShape::same(1, 56, 56, 4, 4, 3, 2);
        assert_eq!((s.out_h, s.out_w), (28, 28));
        assert_eq!(s.pad_top, 0);
        // 1x1 never pads.
        let s = Conv2dShape::same(2, 7, 7, 32, 64, 1, 1);
        assert_eq!((s.pad_top, s.pad_left), (0, 0));
    }

    #[test]
    fn valid_padding_geometry() {
        let s = Conv2dShape::valid(1, 230, 230, 3, 64, 7, 2);
        assert_eq!((s.out_h, s.out_w), (112, 112));
    }

    #[test]
    fn im2col_matches_direct() {
        for &(h, w, c, k, win, stride) in &[
            (8, 8, 3, 4, 3, 1),
            (9, 7, 2, 5, 3, 2),
            (6, 6, 4, 4, 1, 1),
            (10, 10, 2, 3, 5, 2),
        ] {
            let s = Conv2dShape::same(2, h, w, c, k, win, stride);
            check_against_direct(&s, 1);
        }
    }

    #[test]
    fn valid_conv_matches_direct() {
        let s = Conv2dShape::valid(1, 12, 12, 3, 8, 5, 2);
        check_against_direct(&s, 3);
    }

    #[test]
    fn threaded_im2col_bit_identical_to_serial() {
        for &(b, h, w, c, win, stride) in &[
            (2usize, 9usize, 7usize, 3usize, 3usize, 2usize),
            (1, 5, 5, 2, 3, 1),
            (3, 4, 4, 1, 1, 1), // pointwise: kdim == in_c
            (1, 1, 1, 4, 1, 1), // single output pixel, threads > rows
        ] {
            let s = Conv2dShape::same(b, h, w, c, 4, win, stride);
            let x = rand(s.input_elems(), 11);
            let serial = im2col(&x, &s);
            for threads in [0usize, 2, 3, 8] {
                let par = im2col_threaded(&x, &s, threads);
                assert!(
                    serial == par,
                    "im2col threads={threads} diverged on {s:?}"
                );
            }
        }
    }

    #[test]
    fn pointwise_conv_is_a_gemm() {
        // A 1x1 conv is exactly (B*H*W x C) @ (C x K).
        let s = Conv2dShape::same(2, 5, 5, 16, 8, 1, 1);
        let x = rand(s.input_elems(), 5);
        let f = rand(s.filter_elems(), 6);
        let gemm = crate::blas::gemm_naive(&x, &f, 2 * 5 * 5, 8, 16);
        for params in param_matrix() {
            let conv = conv2d_im2col(&x, &f, &s, &params);
            assert!(max_abs_diff(&conv, &gemm) < 1e-4, "{params:?}");
        }
    }

    #[test]
    fn native_dispatch_falls_back_off_the_winograd_domain() {
        // 3x3 stride 1: both native tile sizes run natively.
        let s1 = Conv2dShape::same(1, 8, 8, 2, 2, 3, 1);
        let w2 = ConvConfig::winograd(2);
        assert_eq!(
            native_conv_algorithm(&w2, &s1),
            ConvAlgorithm::Winograd
        );
        assert_eq!(
            native_conv_algorithm(&ConvConfig::winograd(4), &s1),
            ConvAlgorithm::Winograd
        );
        // Strided / non-3x3 shapes: im2col fallback.
        let s2 = Conv2dShape::same(1, 8, 8, 2, 2, 3, 2);
        assert_eq!(native_conv_algorithm(&w2, &s2), ConvAlgorithm::Im2col);
        let s3 = Conv2dShape::same(1, 8, 8, 2, 2, 1, 1);
        assert_eq!(native_conv_algorithm(&w2, &s3), ConvAlgorithm::Im2col);
        // Everything else runs what it asked for.
        let t = ConvConfig::tiled(2, 2, 1, 4);
        assert_eq!(native_conv_algorithm(&t, &s2), ConvAlgorithm::Tiled);
        assert_eq!(
            native_conv_algorithm(&ConvConfig::im2col(), &s2),
            ConvAlgorithm::Im2col
        );
    }

    #[test]
    fn native_dispatch_agrees_across_algorithms() {
        // One 3x3/s1 shape where every algorithm (and both winograd
        // tile sizes) runs natively.
        let s = Conv2dShape::same(2, 7, 9, 3, 4, 3, 1);
        let x = rand(s.input_elems(), 31);
        let f = rand(s.filter_elems(), 32);
        let direct = conv2d_direct(&x, &f, &s);
        let blocked =
            BlockedParams { bm: 16, bn: 16, bk: 8, mr: 2, nr: 4, threads: 1 };
        for cfg in [
            ConvConfig::im2col(),
            ConvConfig::tiled(2, 2, 1, 4),
            ConvConfig::naive(),
            ConvConfig::winograd(2),
            ConvConfig::winograd(4),
        ] {
            let out = conv2d_native(&x, &f, &s, &cfg, &blocked);
            // F(4×4) carries the loosest (still tight) bound of the
            // family — see tests/proptests.rs for the pinned contract.
            let tol = if cfg.algorithm == ConvAlgorithm::Winograd
                && cfg.wino_m == 4
            {
                5e-3
            } else {
                1e-3
            };
            assert!(
                max_abs_diff(&direct, &out) < tol,
                "{} disagrees with the oracle",
                cfg.name()
            );
        }
        // Off-domain winograd really is the im2col computation, bit for
        // bit (a strided shape forces the fallback).
        let s2 = Conv2dShape::same(2, 7, 9, 3, 4, 3, 2);
        let x2 = rand(s2.input_elems(), 33);
        let f2 = rand(s2.filter_elems(), 34);
        assert!(
            conv2d_native(&x2, &f2, &s2, &ConvConfig::winograd(2), &blocked)
                == conv2d_im2col(&x2, &f2, &s2, &blocked)
        );
    }

    #[test]
    fn native_isa_dispatch_agrees_with_scalar() {
        // The ISA axis reaches both GEMM-lowered algorithms (im2col and
        // winograd): SSE2/AVX2 bit-identical to scalar, FMA within an
        // accumulation tolerance; the direct kernels ignore the axis.
        let s = Conv2dShape::same(1, 9, 7, 5, 4, 3, 1);
        let x = rand(s.input_elems(), 41);
        let f = rand(s.filter_elems(), 42);
        let blocked =
            BlockedParams { bm: 8, bn: 8, bk: 4, mr: 2, nr: 4, threads: 1 };
        for cfg in [
            ConvConfig::im2col(),
            ConvConfig::winograd(2),
            ConvConfig::winograd(4),
            ConvConfig::tiled(2, 2, 1, 4),
        ] {
            let scalar = conv2d_native(&x, &f, &s, &cfg, &blocked);
            for isa in crate::blas::Isa::detect() {
                let got =
                    conv2d_native_isa(&x, &f, &s, &cfg, &blocked, isa);
                // Avx512 dispatches the FMA kernel: same tolerance.
                if matches!(
                    isa,
                    crate::blas::Isa::Fma | crate::blas::Isa::Avx512
                ) {
                    assert!(
                        max_abs_diff(&scalar, &got) <= 1e-5,
                        "{} fma beyond tolerance",
                        cfg.name()
                    );
                } else {
                    assert!(
                        scalar == got,
                        "{} {isa} not bit-identical to scalar",
                        cfg.name()
                    );
                }
            }
        }
    }

    #[test]
    fn identity_filter_passes_input_through() {
        // 1x1, in_c == out_c, identity matrix filter.
        let c = 6;
        let s = Conv2dShape::same(1, 4, 4, c, c, 1, 1);
        let x = rand(s.input_elems(), 7);
        let mut f = vec![0.0f32; c * c];
        for i in 0..c {
            f[i * c + i] = 1.0;
        }
        let out = conv2d_direct(&x, &f, &s);
        assert!(max_abs_diff(&out, &x) < 1e-6);
    }
}
