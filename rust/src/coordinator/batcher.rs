//! Request batcher: group same-artifact requests to amortize dispatch.
//!
//! AOT artifacts are compiled for fixed batch shapes, so "batching" here
//! is dispatch-level: queued requests for the same artifact run
//! back-to-back on the engine thread without interleaving compile-cache
//! churn, and the policy decides when a group is flushed.
//!
//! [`Batcher::flush_due`] connects the queue to an [`EngineClient`]:
//! each due group is submitted back-to-back, and because the
//! [`EnginePool`](super::EnginePool) routes per artifact, a whole group
//! lands on the one actor whose plan cache is already warm for it.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use crate::error::Result;
use crate::runtime::RunOutput;

use super::EngineClient;

/// When to flush a pending group.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Flush when this many requests are queued for one artifact.
    pub max_batch: usize,
    /// Flush any group older than this.
    pub max_delay: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self { max_batch: 8, max_delay: Duration::from_millis(2) }
    }
}

/// One queued request.
#[derive(Debug)]
struct Pending<T> {
    artifact: String,
    payload: T,
    enqueued: Instant,
}

/// Order-preserving, per-artifact grouping queue.
///
/// # Examples
///
/// ```
/// use std::time::Duration;
/// use portable_kernels::coordinator::{BatchPolicy, Batcher};
///
/// let policy = BatchPolicy {
///     max_batch: 8,
///     max_delay: Duration::from_secs(3600),
/// };
/// let mut b: Batcher<u32> = Batcher::new(policy);
/// b.push("gemm_512", 1);
/// b.push("gemm_512", 2);
/// b.push("conv3_1", 3);
///
/// // Consecutive same-artifact requests flush as one group.
/// let (artifact, group) = b.pop_group().unwrap();
/// assert_eq!(artifact, "gemm_512");
/// assert_eq!(group, vec![1, 2]);
/// ```
#[derive(Debug)]
pub struct Batcher<T> {
    policy: BatchPolicy,
    queue: VecDeque<Pending<T>>,
}

impl<T> Batcher<T> {
    /// Create an empty batcher under `policy`.
    pub fn new(policy: BatchPolicy) -> Self {
        Self { policy, queue: VecDeque::new() }
    }

    /// Enqueue a request for `artifact`.
    pub fn push(&mut self, artifact: &str, payload: T) {
        self.queue.push_back(Pending {
            artifact: artifact.to_string(),
            payload,
            enqueued: Instant::now(),
        });
    }

    /// Requests currently queued (across all artifacts).
    pub fn len(&self) -> usize {
        self.queue.len()
    }

    /// Whether nothing is queued.
    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Whether the head group must flush now (full batch or timeout).
    pub fn should_flush(&self, now: Instant) -> bool {
        let Some(head) = self.queue.front() else {
            return false;
        };
        if now.duration_since(head.enqueued) >= self.policy.max_delay {
            return true;
        }
        self.head_group_len() >= self.policy.max_batch
    }

    fn head_group_len(&self) -> usize {
        let Some(head) = self.queue.front() else { return 0 };
        self.queue
            .iter()
            .take_while(|p| p.artifact == head.artifact)
            .count()
    }

    /// Pop the head group: all consecutive leading requests for the same
    /// artifact, capped at `max_batch`.  Returns (artifact, payloads).
    pub fn pop_group(&mut self) -> Option<(String, Vec<T>)> {
        let head = self.queue.front()?;
        let artifact = head.artifact.clone();
        let n = self.head_group_len().min(self.policy.max_batch);
        let mut payloads = Vec::with_capacity(n);
        for _ in 0..n {
            payloads.push(self.queue.pop_front().unwrap().payload);
        }
        Some((artifact, payloads))
    }
}

/// One flushed group: the artifact plus the per-request execution
/// results, in submission order.
pub type FlushedGroup = (String, Vec<Result<RunOutput>>);

impl Batcher<Vec<Vec<f32>>> {
    /// Flush every group that is due at `now` through `client`,
    /// executing each group's requests back-to-back (same artifact →
    /// same pool actor → warm plan cache).  Per-request failures are
    /// reported in place; they never abort the rest of the flush.
    pub fn flush_due<C: EngineClient>(
        &mut self,
        client: &C,
        now: Instant,
    ) -> Vec<FlushedGroup> {
        let mut flushed = Vec::new();
        while self.should_flush(now) {
            let Some((artifact, group)) = self.pop_group() else {
                break;
            };
            let results: Vec<Result<RunOutput>> = group
                .into_iter()
                .map(|inputs| client.run(&artifact, inputs))
                .collect();
            flushed.push((artifact, results));
        }
        flushed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn batcher(max_batch: usize) -> Batcher<u32> {
        Batcher::new(BatchPolicy {
            max_batch,
            max_delay: Duration::from_secs(3600), // disable timeout
        })
    }

    #[test]
    fn groups_consecutive_same_artifact() {
        let mut b = batcher(8);
        b.push("a", 1);
        b.push("a", 2);
        b.push("b", 3);
        b.push("a", 4);
        let (art, group) = b.pop_group().unwrap();
        assert_eq!(art, "a");
        assert_eq!(group, vec![1, 2]);
        let (art, group) = b.pop_group().unwrap();
        assert_eq!(art, "b");
        assert_eq!(group, vec![3]);
        let (art, group) = b.pop_group().unwrap();
        assert_eq!(art, "a");
        assert_eq!(group, vec![4]);
        assert!(b.pop_group().is_none());
    }

    #[test]
    fn respects_max_batch() {
        let mut b = batcher(2);
        for i in 0..5 {
            b.push("a", i);
        }
        assert!(b.should_flush(Instant::now()));
        assert_eq!(b.pop_group().unwrap().1, vec![0, 1]);
        assert_eq!(b.pop_group().unwrap().1, vec![2, 3]);
        assert_eq!(b.pop_group().unwrap().1, vec![4]);
    }

    #[test]
    fn preserves_fifo_order() {
        let mut b = batcher(8);
        b.push("x", 1);
        b.push("y", 2);
        b.push("x", 3);
        // Head group is only the first "x": order across artifacts is
        // never reordered past a different artifact.
        assert_eq!(b.pop_group().unwrap().1, vec![1]);
        assert_eq!(b.pop_group().unwrap().1, vec![2]);
        assert_eq!(b.pop_group().unwrap().1, vec![3]);
    }

    #[test]
    fn timeout_forces_flush() {
        let mut b = Batcher::new(BatchPolicy {
            max_batch: 100,
            max_delay: Duration::from_millis(0),
        });
        assert!(!b.should_flush(Instant::now()));
        b.push("a", 1);
        assert!(b.should_flush(Instant::now()));
    }

    #[test]
    fn empty_behaviour() {
        let mut b = batcher(4);
        assert!(b.is_empty());
        assert!(!b.should_flush(Instant::now()));
        assert!(b.pop_group().is_none());
        b.push("a", 1);
        assert_eq!(b.len(), 1);
    }
}
