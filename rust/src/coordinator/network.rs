//! Network runner: execute a whole VGG/ResNet convolution stack through
//! the engine, one artifact per layer, reporting per-layer gigaflops —
//! the measured side of the paper's Figs. 6-9.
//!
//! The runner is generic over [`EngineClient`], so the same code drives
//! a single [`EngineHandle`](super::EngineHandle) actor or a whole
//! [`EnginePool`](super::EnginePool) — with a pool, each layer's
//! artifact routes to its owning actor and the per-layer plan/compile
//! caches stay hot there across repetitions.

use std::time::Duration;

use crate::error::{Error, Result};
use crate::runtime::ArtifactStore;

use super::EngineClient;

/// One executed layer.
#[derive(Debug, Clone)]
pub struct LayerRun {
    /// Layer name as the network tables list it (e.g. `conv3_2`).
    pub layer: String,
    /// Artifact the layer executed as.
    pub artifact: String,
    /// "pallas" | "xla".
    pub implementation: String,
    /// Useful floating-point operations of one execution.
    pub flops: u64,
    /// Best execution time over the timing repetitions, seconds.
    pub elapsed_s: f64,
    /// Measured throughput, GFLOP/s.
    pub gflops: f64,
    /// Spatial scaling note when the measured artifact is shrunk
    /// (see python/compile/manifests.py).
    pub scaled_from: Option<String>,
}

/// Full network execution report.
#[derive(Debug, Clone)]
pub struct NetworkReport {
    /// Network name ("vgg" | "resnet").
    pub network: String,
    /// Implementation the layers executed under ("pallas" | "xla").
    pub implementation: String,
    /// Per-layer measurements, in layer order.
    pub layers: Vec<LayerRun>,
    /// Sum of per-layer best times, seconds.
    pub total_time_s: f64,
    /// Sum of per-layer useful flops.
    pub total_flops: u64,
}

impl NetworkReport {
    /// Whole-network throughput (total flops over total time), GFLOP/s.
    pub fn total_gflops(&self) -> f64 {
        self.total_flops as f64 / self.total_time_s / 1e9
    }
}

/// Artifact name for a network layer under a given implementation
/// (`net_<network>_<layer>_<impl>`, see python/compile/manifests.py).
pub fn layer_artifact_name(
    network: &str,
    layer: &str,
    implementation: &str,
) -> String {
    format!("net_{network}_{layer}_{implementation}")
}

/// Which layers of `network` have an artifact for `implementation`.
pub fn available_layers(
    store: &ArtifactStore,
    network: &str,
    implementation: &str,
) -> Vec<String> {
    let prefix = format!("net_{network}_");
    let suffix = format!("_{implementation}");
    store
        .iter()
        .filter(|m| m.name.starts_with(&prefix) && m.name.ends_with(&suffix))
        .filter_map(|m| m.layer.as_ref().map(|l| l.name.clone()))
        .collect()
}

/// Runs network layer stacks via artifacts named
/// `net_<network>_<layer>_<impl>` through any [`EngineClient`].
pub struct NetworkRunner<C: EngineClient> {
    client: C,
}

impl<C: EngineClient> NetworkRunner<C> {
    /// Wrap a client ([`EngineHandle`](super::EngineHandle), a reference
    /// to an [`EnginePool`](super::EnginePool), ...).
    pub fn new(client: C) -> Self {
        Self { client }
    }

    /// Execute every available layer of `network` under `implementation`,
    /// with `iters` timing repetitions per layer (min taken).
    pub fn run_network(
        &self,
        store: &ArtifactStore,
        network: &str,
        implementation: &str,
        iters: usize,
    ) -> Result<NetworkReport> {
        let layers = available_layers(store, network, implementation);
        if layers.is_empty() {
            return Err(Error::NotFound(format!(
                "no {implementation:?} artifacts for network {network:?} \
                 (build the `network` manifest group)"
            )));
        }
        let mut runs = Vec::new();
        let mut total_time = Duration::ZERO;
        let mut total_flops = 0u64;
        for layer in &layers {
            let artifact =
                layer_artifact_name(network, layer, implementation);
            let meta = store.get(&artifact)?.clone();
            let inputs = self.client.synth_inputs(&artifact, 42)?;
            self.client.warm(&artifact)?;
            // run_timed builds the input literals once on the engine
            // thread (EXPERIMENTS.md §Perf L3-2).
            let (_, best) = self.client.run_timed(&artifact, inputs, iters)?;
            total_time += best;
            total_flops += meta.flops;
            runs.push(LayerRun {
                layer: layer.clone(),
                artifact,
                implementation: meta.implementation.clone().to_string(),
                flops: meta.flops,
                elapsed_s: best.as_secs_f64(),
                gflops: meta.flops as f64 / best.as_secs_f64() / 1e9,
                scaled_from: meta.scaled_from.clone(),
            });
        }
        Ok(NetworkReport {
            network: network.to_string(),
            implementation: implementation.to_string(),
            layers: runs,
            total_time_s: total_time.as_secs_f64(),
            total_flops,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifact_naming_matches_manifests() {
        assert_eq!(
            layer_artifact_name("resnet", "conv3_2", "xla"),
            "net_resnet_conv3_2_xla"
        );
    }
}
