//! Network runner: execute a whole VGG/ResNet convolution stack through
//! the engine, one artifact per layer, reporting per-layer gigaflops —
//! the measured side of the paper's Figs. 6-9.

use std::time::Duration;


use crate::error::{Error, Result};
use crate::runtime::ArtifactStore;

use super::scheduler::EngineHandle;

/// One executed layer.
#[derive(Debug, Clone)]
pub struct LayerRun {
    pub layer: String,
    pub artifact: String,
    /// "pallas" | "xla".
    pub implementation: String,
    pub flops: u64,
    pub elapsed_s: f64,
    pub gflops: f64,
    /// Spatial scaling note when the measured artifact is shrunk
    /// (see python/compile/manifests.py).
    pub scaled_from: Option<String>,
}

/// Full network execution report.
#[derive(Debug, Clone)]
pub struct NetworkReport {
    pub network: String,
    pub implementation: String,
    pub layers: Vec<LayerRun>,
    pub total_time_s: f64,
    pub total_flops: u64,
}

impl NetworkReport {
    pub fn total_gflops(&self) -> f64 {
        self.total_flops as f64 / self.total_time_s / 1e9
    }
}

/// Runs network layer stacks via artifacts named
/// `net_<network>_<layer>_<impl>` (see python/compile/manifests.py).
pub struct NetworkRunner {
    handle: EngineHandle,
}

impl NetworkRunner {
    pub fn new(handle: EngineHandle) -> Self {
        Self { handle }
    }

    /// Artifact name for a layer under a given implementation.
    pub fn artifact_name(network: &str, layer: &str, implementation: &str) -> String {
        format!("net_{network}_{layer}_{implementation}")
    }

    /// Which layers of `network` have an artifact for `implementation`.
    pub fn available_layers(
        store: &ArtifactStore,
        network: &str,
        implementation: &str,
    ) -> Vec<String> {
        let prefix = format!("net_{network}_");
        let suffix = format!("_{implementation}");
        store
            .iter()
            .filter(|m| m.name.starts_with(&prefix) && m.name.ends_with(&suffix))
            .filter_map(|m| m.layer.as_ref().map(|l| l.name.clone()))
            .collect()
    }

    /// Execute every available layer of `network` under `implementation`,
    /// with `iters` timing repetitions per layer (min taken).
    pub fn run_network(
        &self,
        store: &ArtifactStore,
        network: &str,
        implementation: &str,
        iters: usize,
    ) -> Result<NetworkReport> {
        let layers = Self::available_layers(store, network, implementation);
        if layers.is_empty() {
            return Err(Error::NotFound(format!(
                "no {implementation:?} artifacts for network {network:?} \
                 (build the `network` manifest group)"
            )));
        }
        let mut runs = Vec::new();
        let mut total_time = Duration::ZERO;
        let mut total_flops = 0u64;
        for layer in &layers {
            let artifact = Self::artifact_name(network, layer, implementation);
            let meta = store.get(&artifact)?.clone();
            let inputs = self.handle.synth_inputs(&artifact, 42)?;
            self.handle.warm(&artifact)?;
            // run_timed builds the input literals once on the engine
            // thread (EXPERIMENTS.md §Perf L3-2).
            let (_, best) = self.handle.run_timed(&artifact, inputs, iters)?;
            total_time += best;
            total_flops += meta.flops;
            runs.push(LayerRun {
                layer: layer.clone(),
                artifact,
                implementation: meta.implementation.clone().to_string(),
                flops: meta.flops,
                elapsed_s: best.as_secs_f64(),
                gflops: meta.flops as f64 / best.as_secs_f64() / 1e9,
                scaled_from: meta.scaled_from.clone(),
            });
        }
        Ok(NetworkReport {
            network: network.to_string(),
            implementation: implementation.to_string(),
            layers: runs,
            total_time_s: total_time.as_secs_f64(),
            total_flops,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifact_naming_matches_manifests() {
        assert_eq!(
            NetworkRunner::artifact_name("resnet", "conv3_2", "xla"),
            "net_resnet_conv3_2_xla"
        );
    }
}
