//! Multi-actor engine pool: the serving scale-out layer.
//!
//! [`EnginePool`] spawns N backend actors (each a dedicated thread owning
//! one [`Backend`], exactly like the single [`EngineHandle`] actor) and
//! routes requests to them per artifact:
//!
//! * **Consistent-hash routing** — each artifact key hashes onto a ring
//!   of virtual nodes, so the same artifact always lands on the same
//!   actor while that actor is healthy.  Plan/compile caches therefore
//!   stay hot on exactly one actor instead of being rebuilt N times, and
//!   when an actor dies only its keys move (the ring property).
//! * **Bounded queues + explicit backpressure** — every actor has a
//!   bounded request queue.  [`EnginePool::try_submit_run`] returns
//!   [`SubmitError::Busy`] instead of queueing unboundedly;
//!   [`EnginePool::submit_run`] blocks until the queue has room.
//! * **Least-loaded spill** — when an artifact's home queue reaches the
//!   configured spill depth, the request spills to the least-loaded
//!   healthy actor: affinity is a throughput optimization, never a
//!   head-of-line blocking guarantee violation.  The first spill of an
//!   artifact onto a given actor enqueues a plan-warming request ahead
//!   of it (so spilled requests do not pay the cold plan/compile the
//!   spill was meant to dodge), and every spill counts into
//!   [`EnginePool::spilled`].
//! * **Epoch-swappable tuning** — [`EnginePool::swap_tuning`] broadcasts
//!   a [`TuningSnapshot`] to every healthy actor; each actor's backend
//!   re-resolves only the cached plans whose selection actually changed
//!   ([`Backend::swap_tuning`]), so an online re-tune never cold-starts
//!   the whole pool.
//! * **Panic containment** — a backend panic poisons only its actor:
//!   the in-flight request fails loudly, the dead actor's queued
//!   requests drain onto the surviving actors, and routing stops
//!   considering the dead actor.  The pool keeps serving until no
//!   healthy actor remains.
//!
//! The interesting tension this layer exposes (and
//! `benches/serving_contention.rs` measures) is *intra*-engine
//! parallelism — the [`BlockedParams::threads`] knob each actor's kernels
//! use — competing with *inter*-request parallelism (pool width) for the
//! same cores.
//!
//! [`Backend`]: crate::runtime::Backend
//! [`BlockedParams::threads`]: crate::blas::BlockedParams
//! [`EngineHandle`]: super::EngineHandle

use std::collections::{HashSet, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::error::{Error, Result};
use crate::runtime::{
    ArtifactStore, Backend, DefaultEngine, NativeEngine, RunOutput,
};
use crate::tuner::{SelectionDb, TuningSnapshot};

use super::scheduler::{serve_request, EngineStats, Request};
use super::EngineClient;

/// Virtual ring nodes per actor: enough that key ownership is roughly
/// balanced for small pools without making ring construction costly.
const RING_VNODES: usize = 32;

/// FNV-1a 64-bit over the key bytes, then a murmur-style finalizer.
///
/// Plain FNV-1a disperses the *low* bits well but barely avalanches the
/// high bits, and ring placement is ordered by the full 64-bit value —
/// measured on 200 sequential keys, a raw-FNV ring sent 95% of them to
/// one of four actors.  The finalizer fixes the high bits.
fn hash_key(key: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in key.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0100_0000_01b3);
    }
    h ^= h >> 33;
    h = h.wrapping_mul(0xff51_afd7_ed55_8ccd);
    h ^= h >> 33;
    h = h.wrapping_mul(0xc4ce_b9fe_1a85_ec53);
    h ^= h >> 33;
    h
}

/// Consistent-hash ring: actor indices placed at [`RING_VNODES`] pseudo-
/// random points each; a key routes to the first point clockwise from
/// its own hash whose actor is still alive.
struct HashRing {
    /// (point hash, actor index), sorted by hash.
    points: Vec<(u64, usize)>,
}

impl HashRing {
    fn new(actors: usize) -> Self {
        let mut points = Vec::with_capacity(actors * RING_VNODES);
        for a in 0..actors {
            for v in 0..RING_VNODES {
                points.push((hash_key(&format!("actor-{a}/vnode-{v}")), a));
            }
        }
        points.sort_unstable();
        HashRing { points }
    }

    /// First alive actor clockwise from the key's hash, or `None` when
    /// no actor is alive.
    fn route(&self, key: &str, alive: impl Fn(usize) -> bool) -> Option<usize> {
        if self.points.is_empty() {
            return None;
        }
        let h = hash_key(key);
        let start = self.points.partition_point(|&(p, _)| p < h);
        for off in 0..self.points.len() {
            let (_, actor) = self.points[(start + off) % self.points.len()];
            if alive(actor) {
                return Some(actor);
            }
        }
        None
    }
}

/// Why a push into a bounded queue did not happen.
enum PushError<T> {
    /// The queue is at its bounded depth.
    Full(T),
    /// The queue is closed (its actor is dead or shutting down).
    Closed(T),
}

struct QueueState<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// Hand-rolled bounded MPSC queue (`Mutex` + two `Condvar`s): the
/// blocking/backpressure substrate `std::sync::mpsc` channels do not
/// expose (no `len`, no close-and-drain).
struct BoundedQueue<T> {
    depth: usize,
    state: Mutex<QueueState<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    /// Mirror of `items.len()` so the router can read load without
    /// taking the queue lock.
    len: AtomicUsize,
}

impl<T> BoundedQueue<T> {
    fn new(depth: usize) -> Self {
        BoundedQueue {
            depth,
            state: Mutex::new(QueueState {
                items: VecDeque::new(),
                closed: false,
            }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            len: AtomicUsize::new(0),
        }
    }

    fn len(&self) -> usize {
        self.len.load(Ordering::Relaxed)
    }

    /// Non-blocking push: `Full` at the bounded depth, `Closed` after
    /// [`BoundedQueue::close`]; the item is handed back either way.
    fn try_push(&self, item: T) -> std::result::Result<(), PushError<T>> {
        let mut st = self.state.lock().expect("queue lock poisoned");
        if st.closed {
            return Err(PushError::Closed(item));
        }
        if st.items.len() >= self.depth {
            return Err(PushError::Full(item));
        }
        st.items.push_back(item);
        self.len.store(st.items.len(), Ordering::Relaxed);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocking push: waits while the queue is at depth; `Err(item)`
    /// only if the queue closed while (or before) waiting.
    fn push(&self, item: T) -> std::result::Result<(), T> {
        let mut st = self.state.lock().expect("queue lock poisoned");
        while !st.closed && st.items.len() >= self.depth {
            st = self.not_full.wait(st).expect("queue lock poisoned");
        }
        if st.closed {
            return Err(item);
        }
        st.items.push_back(item);
        self.len.store(st.items.len(), Ordering::Relaxed);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocking pop: `None` only once the queue is closed *and* empty,
    /// so closing a queue still drains everything already accepted.
    fn pop(&self) -> Option<T> {
        let mut st = self.state.lock().expect("queue lock poisoned");
        loop {
            if let Some(item) = st.items.pop_front() {
                self.len.store(st.items.len(), Ordering::Relaxed);
                self.not_full.notify_one();
                return Some(item);
            }
            if st.closed {
                return None;
            }
            st = self.not_empty.wait(st).expect("queue lock poisoned");
        }
    }

    /// Close the queue: every blocked producer/consumer wakes, further
    /// pushes fail, already-queued items remain poppable/drainable.
    fn close(&self) {
        let mut st = self.state.lock().expect("queue lock poisoned");
        st.closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    /// Remove and return everything queued (used by a dying actor to
    /// hand its backlog to the survivors).
    fn drain(&self) -> Vec<T> {
        let mut st = self.state.lock().expect("queue lock poisoned");
        let items: Vec<T> = st.items.drain(..).collect();
        self.len.store(0, Ordering::Relaxed);
        self.not_full.notify_all();
        items
    }
}

/// State shared between the router (pool handle) and the actor threads.
struct Shared {
    queues: Vec<BoundedQueue<Request>>,
    healthy: Vec<AtomicBool>,
    ring: HashRing,
    spill_depth: usize,
    panics: AtomicUsize,
    /// Requests routed away from their ring home (spill metric).
    spills: AtomicUsize,
    /// Per-actor set of artifacts already warm-requested by a spill, so
    /// only the *first* spill of an artifact onto an actor enqueues a
    /// plan-warming request.
    warmed: Mutex<Vec<HashSet<String>>>,
}

impl Shared {
    fn is_healthy(&self, idx: usize) -> bool {
        self.healthy[idx].load(Ordering::Acquire)
    }

    fn healthy_count(&self) -> usize {
        self.healthy
            .iter()
            .filter(|h| h.load(Ordering::Acquire))
            .count()
    }

    fn least_loaded(&self) -> Option<usize> {
        (0..self.queues.len())
            .filter(|&i| self.is_healthy(i))
            .min_by_key(|&i| self.queues[i].len())
    }

    /// Routing decision for one request: the artifact's ring home while
    /// its queue is under the spill depth, otherwise whichever healthy
    /// actor is least loaded (if actually less loaded than home).  The
    /// flag reports whether the decision is a spill (target ≠ home).
    fn route(&self, artifact: &str) -> Option<(usize, bool)> {
        let primary = self.ring.route(artifact, |i| self.is_healthy(i))?;
        if self.queues[primary].len() < self.spill_depth {
            return Some((primary, false));
        }
        let target = match self.least_loaded() {
            Some(ll) if self.queues[ll].len() < self.queues[primary].len() => {
                ll
            }
            _ => primary,
        };
        Some((target, target != primary))
    }

    /// The first time `artifact` spills onto `actor`, enqueue a
    /// plan-warming request ahead of it — the spill-path fix: before
    /// this, a spilled request paid the cold plan/compile on an actor
    /// that had never seen the artifact, which is exactly the latency
    /// spike spilling exists to avoid.  Best-effort: a full or closed
    /// queue skips the warm and the spilled run plans inline.
    fn warm_for_spill(&self, actor: usize, artifact: &str) {
        let first = {
            let mut warmed =
                self.warmed.lock().expect("warm-set lock poisoned");
            warmed[actor].insert(artifact.to_string())
        };
        if first {
            let (reply, _rx) = mpsc::channel();
            let _ = self.queues[actor].try_push(Request::Warm {
                name: artifact.to_string(),
                reply,
            });
        }
    }

    /// Count one request actually placed off its ring home.
    fn count_spill(&self) {
        self.spills.fetch_add(1, Ordering::Relaxed);
    }
}

/// Push an orphaned request from a dead actor onto the least-loaded
/// healthy survivor.  If every survivor dies too, the request is dropped
/// — its reply channel closes and the waiting client gets a loud error
/// rather than a hang.
fn redistribute(shared: &Shared, mut req: Request) {
    for _ in 0..shared.queues.len() {
        let Some(target) = shared.least_loaded() else {
            return;
        };
        match shared.queues[target].push(req) {
            Ok(()) => return,
            Err(r) => req = r,
        }
    }
}

fn actor_main<B, F>(
    idx: usize,
    shared: Arc<Shared>,
    make: F,
    init_tx: mpsc::Sender<Result<()>>,
) where
    B: Backend,
    F: FnOnce() -> Result<B>,
{
    let mut engine = match make() {
        Ok(e) => {
            let _ = init_tx.send(Ok(()));
            e
        }
        Err(e) => {
            shared.healthy[idx].store(false, Ordering::Release);
            shared.queues[idx].close();
            let _ = init_tx.send(Err(e));
            return;
        }
    };
    let mut stats = EngineStats::default();
    loop {
        let Some(req) = shared.queues[idx].pop() else {
            // Queue closed and fully drained: graceful shutdown.
            break;
        };
        let served = catch_unwind(AssertUnwindSafe(|| {
            serve_request(&mut engine, &mut stats, req)
        }));
        match served {
            Ok(true) => {}
            Ok(false) => break,
            Err(_) => {
                // The backend panicked mid-request.  Its state may be
                // poisoned, so this actor retires: the in-flight
                // request's reply channel died with the unwind (loud
                // error on the client), and the backlog moves to the
                // survivors.
                shared.panics.fetch_add(1, Ordering::Relaxed);
                shared.healthy[idx].store(false, Ordering::Release);
                shared.queues[idx].close();
                for orphan in shared.queues[idx].drain() {
                    redistribute(&shared, orphan);
                }
                return;
            }
        }
    }
}

/// Sizing knobs for an [`EnginePool`].
#[derive(Debug, Clone, Copy)]
pub struct PoolConfig {
    /// Number of backend actors (each owns one engine on one thread).
    pub actors: usize,
    /// Bounded per-actor queue depth; at this depth `try_submit` reports
    /// [`SubmitError::Busy`] and blocking submits wait.
    pub queue_depth: usize,
    /// Queue depth at which routing abandons artifact affinity and
    /// spills to the least-loaded healthy actor.  Must be in
    /// `1..=queue_depth`.
    pub spill_depth: usize,
    /// Pre-warm every manifest artifact on its ring-home actor before
    /// `spawn` returns ([`EnginePool::prewarm`]), so first requests
    /// never pay plan/compile latency.  A plan failure during warm-up
    /// fails the spawn loudly.
    pub warm_at_spawn: bool,
}

impl Default for PoolConfig {
    fn default() -> Self {
        Self {
            actors: 2,
            queue_depth: 32,
            spill_depth: 8,
            warm_at_spawn: false,
        }
    }
}

/// Rejection from a non-blocking submit.
#[derive(Debug)]
pub enum SubmitError {
    /// Every healthy actor's queue is at its bounded depth — the
    /// caller's backpressure signal (shed load or retry later).
    Busy,
    /// The request cannot be accepted at all (e.g. no healthy actors).
    Engine(Error),
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Busy => {
                write!(f, "engine pool busy: every bounded queue is full")
            }
            SubmitError::Engine(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// A pending execution submitted to the pool.
///
/// Dropping the ticket abandons the result (the run still executes);
/// [`RunTicket::wait`] blocks for it.
pub struct RunTicket {
    rx: mpsc::Receiver<Result<RunOutput>>,
}

impl RunTicket {
    /// Block until the routed actor has executed the request.
    pub fn wait(self) -> Result<RunOutput> {
        self.rx.recv().map_err(|_| {
            Error::Runtime(
                "engine pool dropped the request (actor died)".into(),
            )
        })?
    }
}

/// N engine actors behind a consistent-hash router with bounded queues.
///
/// Semantics: the same artifact always routes to the same actor while
/// that actor is healthy (plan/compile caches build exactly once);
/// queues are bounded, with [`EnginePool::try_submit_run`] reporting
/// [`SubmitError::Busy`] at depth and blocking submits waiting; an
/// overloaded home queue spills to the least-loaded healthy actor; and
/// a backend panic retires only its actor — the in-flight request fails
/// loudly, the backlog drains onto survivors, and the ring reroutes the
/// dead actor's keys.
///
/// The pool implements [`EngineClient`], so anything written against the
/// single-actor [`EngineHandle`](super::EngineHandle) — the network
/// runner, the batcher, the benches — scales out without code changes.
///
/// # Examples
///
/// ```
/// use portable_kernels::coordinator::{EngineClient, EnginePool, PoolConfig};
/// use portable_kernels::util::tmp::TempDir;
///
/// let dir = TempDir::new("doc-pool").unwrap();
/// std::fs::write(
///     dir.path().join("manifest.json"),
///     r#"{"version": 1, "artifacts": [{
///         "name": "g4", "kind": "gemm", "impl": "pallas",
///         "file": "g4.hlo.txt", "flops": 128, "m": 4, "n": 4, "k": 4,
///         "inputs": [{"shape": [4, 4], "dtype": "float32"},
///                    {"shape": [4, 4], "dtype": "float32"}],
///         "groups": ["gemm"]}]}"#,
/// )
/// .unwrap();
///
/// let config = PoolConfig { actors: 2, ..Default::default() };
/// let pool = EnginePool::spawn(dir.path(), config).unwrap();
/// assert_eq!(pool.healthy_actors(), 2);
///
/// // "g4" always routes to the same actor, so its plan is built once.
/// let home = pool.route_of("g4").unwrap();
/// assert_eq!(pool.route_of("g4"), Some(home));
///
/// let inputs = pool.synth_inputs("g4", 7).unwrap();
/// let out = pool.run("g4", inputs).unwrap();
/// assert_eq!(out.outputs[0].len(), 16);
/// pool.shutdown();
/// ```
pub struct EnginePool {
    shared: Arc<Shared>,
    joins: Vec<JoinHandle<()>>,
}

impl EnginePool {
    /// Spawn `config.actors` actors over one artifact directory with the
    /// build's default backend, each actor opening its own engine over a
    /// shared store clone.
    pub fn spawn(artifact_dir: &Path, config: PoolConfig) -> Result<Self> {
        let store = ArtifactStore::open(artifact_dir)?;
        Self::spawn_with(config, move |_| DefaultEngine::new(store.clone()))
    }

    /// Spawn native-engine actors that all consult one shared tuning DB
    /// snapshot at plan time — the deployment shape: run the per-host
    /// sweep once, then every actor plans with the host-tuned
    /// [`BlockedParams`](crate::blas::BlockedParams).  The snapshot is
    /// not frozen forever: [`EnginePool::swap_tuning`] installs a newer
    /// epoch on every actor while the pool serves (online re-tuning,
    /// [`TuningHandle`](crate::tuner::TuningHandle)).
    pub fn native_tuned(
        store: ArtifactStore,
        tuning: Arc<SelectionDb>,
        config: PoolConfig,
    ) -> Result<Self> {
        Self::spawn_with(config, move |_| {
            Ok(NativeEngine::with_shared_tuning(
                store.clone(),
                Arc::clone(&tuning),
            ))
        })
    }

    /// Spawn the pool with an explicit per-actor backend constructor
    /// (`make(actor_index)` runs *on* that actor's thread, so non-`Send`
    /// backend internals never cross threads).
    ///
    /// Any actor failing to spawn — OS thread-spawn failure, constructor
    /// `Err`, constructor panic — is a loud, synchronous `Err`: the
    /// already-spawned actors are shut down and joined before this
    /// returns, never leaving a half-alive pool or a hung handle.
    pub fn spawn_with<B, F>(config: PoolConfig, make: F) -> Result<Self>
    where
        B: Backend + 'static,
        F: Fn(usize) -> Result<B> + Send + Clone + 'static,
    {
        if config.actors == 0 {
            return Err(Error::Config(
                "engine pool needs at least one actor".into(),
            ));
        }
        if config.queue_depth == 0 {
            return Err(Error::Config(
                "engine pool queue_depth must be >= 1".into(),
            ));
        }
        if config.spill_depth == 0 || config.spill_depth > config.queue_depth {
            return Err(Error::Config(format!(
                "engine pool spill_depth must be in 1..={} (got {})",
                config.queue_depth, config.spill_depth
            )));
        }
        let shared = Arc::new(Shared {
            queues: (0..config.actors)
                .map(|_| BoundedQueue::new(config.queue_depth))
                .collect(),
            healthy: (0..config.actors).map(|_| AtomicBool::new(true)).collect(),
            ring: HashRing::new(config.actors),
            spill_depth: config.spill_depth,
            panics: AtomicUsize::new(0),
            spills: AtomicUsize::new(0),
            warmed: Mutex::new(
                (0..config.actors).map(|_| HashSet::new()).collect(),
            ),
        });
        fn cleanup(shared: &Shared, joins: Vec<JoinHandle<()>>) {
            for q in &shared.queues {
                q.close();
            }
            for j in joins {
                let _ = j.join();
            }
        }
        let mut joins = Vec::with_capacity(config.actors);
        for idx in 0..config.actors {
            let (init_tx, init_rx) = mpsc::channel::<Result<()>>();
            let make_i = make.clone();
            let shared_i = Arc::clone(&shared);
            let spawned = std::thread::Builder::new()
                .name(format!("engine-{idx}"))
                .spawn(move || {
                    actor_main(idx, shared_i, move || make_i(idx), init_tx)
                });
            match spawned {
                Ok(j) => joins.push(j),
                Err(e) => {
                    cleanup(&shared, joins);
                    return Err(Error::Runtime(format!(
                        "cannot spawn engine actor {idx}: {e}"
                    )));
                }
            }
            match init_rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    cleanup(&shared, joins);
                    return Err(e);
                }
                Err(_) => {
                    cleanup(&shared, joins);
                    return Err(Error::Runtime(format!(
                        "engine actor {idx} died during init"
                    )));
                }
            }
        }
        let pool = EnginePool { shared, joins };
        if config.warm_at_spawn {
            // Drop on the error path shuts the actors down and joins.
            pool.prewarm()?;
        }
        Ok(pool)
    }

    /// Warm every manifest artifact on its ring-home actor: each name is
    /// routed exactly like a request, so per-actor plan caches end up
    /// holding precisely the artifacts that actor owns.  Returns the
    /// number of artifacts warmed.  Runs automatically at spawn when
    /// [`PoolConfig::warm_at_spawn`] is set; callable any time after a
    /// membership change.  A plan failure is a loud `Err` — a manifest
    /// entry the backend cannot execute should surface here, not on the
    /// first unlucky request.
    pub fn prewarm(&self) -> Result<usize> {
        // Any healthy actor can list the manifest (all actors share it).
        let Some(idx) = self.shared.least_loaded() else {
            return Err(Error::Runtime(
                "engine pool has no healthy actors left".into(),
            ));
        };
        let (reply, rx) = mpsc::channel();
        self.shared.queues[idx]
            .push(Request::Artifacts { reply })
            .map_err(|_| {
                Error::Runtime(format!("engine actor {idx} is gone"))
            })?;
        let names: Vec<String> = rx.recv().map_err(|_| {
            Error::Runtime(format!("engine actor {idx} died"))
        })?;
        for name in &names {
            EngineClient::warm(self, name)?;
        }
        Ok(names.len())
    }

    /// Number of actors the pool was built with (healthy or not).
    pub fn actors(&self) -> usize {
        self.shared.queues.len()
    }

    /// Number of actors still serving requests.
    pub fn healthy_actors(&self) -> usize {
        self.shared.healthy_count()
    }

    /// Number of actors retired by a backend panic.
    pub fn panicked_actors(&self) -> usize {
        self.shared.panics.load(Ordering::Relaxed)
    }

    /// Number of requests placed off their ring-home actor since spawn —
    /// the spill metric.  A persistently high rate means artifact
    /// affinity is lost (home queues saturate faster than the spill
    /// targets can absorb) and the pool is under-provisioned.
    pub fn spilled(&self) -> usize {
        self.shared.spills.load(Ordering::Relaxed)
    }

    /// Broadcast a tuning snapshot to every healthy actor and wait for
    /// each to answer; returns how many backends applied it
    /// ([`Backend::swap_tuning`]).  The push blocks behind queued work
    /// rather than being droppable — a published epoch must reach every
    /// actor.  Requests already queued ahead of the swap still serve
    /// from the old snapshot: the swap is per-actor atomic, and the pool
    /// converges once every queue drains past it.
    pub fn swap_tuning(&self, snap: &TuningSnapshot) -> usize {
        let mut waiting = Vec::new();
        for (idx, q) in self.shared.queues.iter().enumerate() {
            if !self.shared.is_healthy(idx) {
                continue;
            }
            let (reply, rx) = mpsc::channel();
            let pushed = q.push(Request::SwapTuning {
                db: Arc::clone(&snap.db),
                epoch: snap.epoch,
                reply,
            });
            if pushed.is_ok() {
                waiting.push(rx);
            }
        }
        waiting
            .into_iter()
            .filter(|rx| rx.recv().unwrap_or(false))
            .count()
    }

    /// The artifact's current ring home (ignoring spill), or `None` when
    /// no healthy actor remains.  Stable for a given pool while the home
    /// actor stays healthy — the routing-determinism contract.
    pub fn route_of(&self, artifact: &str) -> Option<usize> {
        self.shared.ring.route(artifact, |i| self.shared.is_healthy(i))
    }

    /// Current depth of one actor's request queue.
    pub fn queue_len(&self, idx: usize) -> usize {
        self.shared.queues[idx].len()
    }

    fn submit(&self, artifact: &str, req: Request) -> Result<()> {
        let mut req = req;
        // Each retry means the routed actor died between the routing
        // decision and the push; one attempt per actor bounds the loop.
        for _ in 0..=self.shared.queues.len() {
            let Some((target, spilled)) = self.shared.route(artifact) else {
                break;
            };
            if spilled {
                // Warm goes in *ahead* of the request, so the spilled
                // run lands on an already-built plan.
                self.shared.warm_for_spill(target, artifact);
            }
            match self.shared.queues[target].push(req) {
                Ok(()) => {
                    if spilled {
                        self.shared.count_spill();
                    }
                    return Ok(());
                }
                Err(r) => req = r,
            }
        }
        Err(Error::Runtime(
            "engine pool has no healthy actors left".into(),
        ))
    }

    fn try_submit(
        &self,
        artifact: &str,
        req: Request,
    ) -> std::result::Result<(), SubmitError> {
        let Some((primary, spilled)) = self.shared.route(artifact) else {
            return Err(SubmitError::Engine(Error::Runtime(
                "engine pool has no healthy actors left".into(),
            )));
        };
        if spilled {
            self.shared.warm_for_spill(primary, artifact);
        }
        let mut req = match self.shared.queues[primary].try_push(req) {
            Ok(()) => {
                if spilled {
                    self.shared.count_spill();
                }
                return Ok(());
            }
            Err(PushError::Full(r)) | Err(PushError::Closed(r)) => r,
        };
        // The routed target is full (or died): offer the request to the
        // remaining healthy actors, least-loaded first.  Placements off
        // the ring home count as spills too.
        let home =
            self.shared.ring.route(artifact, |i| self.shared.is_healthy(i));
        let mut others: Vec<usize> = (0..self.shared.queues.len())
            .filter(|&i| i != primary && self.shared.is_healthy(i))
            .collect();
        others.sort_by_key(|&i| self.shared.queues[i].len());
        for i in others {
            let off_home = home != Some(i);
            if off_home {
                self.shared.warm_for_spill(i, artifact);
            }
            match self.shared.queues[i].try_push(req) {
                Ok(()) => {
                    if off_home {
                        self.shared.count_spill();
                    }
                    return Ok(());
                }
                Err(PushError::Full(r)) | Err(PushError::Closed(r)) => req = r,
            }
        }
        if self.shared.healthy_count() == 0 {
            return Err(SubmitError::Engine(Error::Runtime(
                "engine pool has no healthy actors left".into(),
            )));
        }
        Err(SubmitError::Busy)
    }

    /// Submit an execution without waiting for it; blocks only while the
    /// routed queue is at its bounded depth.
    pub fn submit_run(
        &self,
        name: &str,
        inputs: Vec<Vec<f32>>,
    ) -> Result<RunTicket> {
        let (reply, rx) = mpsc::channel();
        self.submit(name, Request::Run { name: name.into(), inputs, reply })?;
        Ok(RunTicket { rx })
    }

    /// Non-blocking submit: [`SubmitError::Busy`] when every healthy
    /// queue is at its bounded depth — the pool's backpressure signal.
    pub fn try_submit_run(
        &self,
        name: &str,
        inputs: Vec<Vec<f32>>,
    ) -> std::result::Result<RunTicket, SubmitError> {
        let (reply, rx) = mpsc::channel();
        self.try_submit(
            name,
            Request::Run { name: name.into(), inputs, reply },
        )?;
        Ok(RunTicket { rx })
    }

    fn ask<T>(
        &self,
        artifact: &str,
        make: impl FnOnce(mpsc::Sender<T>) -> Request,
    ) -> Result<T> {
        let (reply, rx) = mpsc::channel();
        self.submit(artifact, make(reply))?;
        rx.recv().map_err(|_| {
            Error::Runtime(
                "engine pool dropped the request (actor died)".into(),
            )
        })
    }

    /// One actor's statistics.  Non-blocking on the submit side: fails
    /// if the actor is dead *or* its queue is at the bounded depth —
    /// observability must never park behind (or displace) a saturated
    /// work queue.
    pub fn actor_stats(&self, idx: usize) -> Result<EngineStats> {
        if idx >= self.shared.queues.len() {
            return Err(Error::NotFound(format!("pool actor {idx}")));
        }
        let (reply, rx) = mpsc::channel();
        self.shared.queues[idx]
            .try_push(Request::Stats { reply })
            .map_err(|e| match e {
                PushError::Full(_) => Error::Runtime(format!(
                    "engine actor {idx} is saturated; stats unavailable"
                )),
                PushError::Closed(_) => {
                    Error::Runtime(format!("engine actor {idx} is gone"))
                }
            })?;
        rx.recv()
            .map_err(|_| Error::Runtime(format!("engine actor {idx} died")))
    }

    /// Aggregate statistics over the surviving actors
    /// ([`EngineStats::absorb`]): counters sum, per-`(artifact,
    /// shape-class)` latency accounting merges, `tuning_epoch` is the
    /// newest epoch any actor has applied, and the kernel-scratch arena
    /// counters ([`EngineStats::scratch`]) sum across the actors' arenas
    /// — the pool-level zero-allocation signal the loadgen reports.
    pub fn stats(&self) -> EngineStats {
        let mut total = EngineStats::default();
        for idx in 0..self.shared.queues.len() {
            if let Ok(s) = self.actor_stats(idx) {
                total.absorb(&s);
            }
        }
        total
    }

    /// Graceful shutdown: close every queue (accepted requests still
    /// drain), then join every actor thread.
    pub fn shutdown(mut self) {
        self.shutdown_and_join();
    }

    fn shutdown_and_join(&mut self) {
        for q in &self.shared.queues {
            q.close();
        }
        for j in self.joins.drain(..) {
            let _ = j.join();
        }
    }
}

impl Drop for EnginePool {
    fn drop(&mut self) {
        self.shutdown_and_join();
    }
}

impl EngineClient for EnginePool {
    fn run(&self, name: &str, inputs: Vec<Vec<f32>>) -> Result<RunOutput> {
        self.ask(name, |reply| Request::Run { name: name.into(), inputs, reply })?
    }

    fn run_timed(
        &self,
        name: &str,
        inputs: Vec<Vec<f32>>,
        iters: usize,
    ) -> Result<(RunOutput, Duration)> {
        self.ask(name, |reply| Request::RunTimed {
            name: name.into(),
            inputs,
            iters,
            reply,
        })?
    }

    fn warm(&self, name: &str) -> Result<()> {
        self.ask(name, |reply| Request::Warm { name: name.into(), reply })?
    }

    fn synth_inputs(&self, name: &str, seed: u64) -> Result<Vec<Vec<f32>>> {
        self.ask(name, |reply| Request::SynthInputs {
            name: name.into(),
            seed,
            reply,
        })?
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tmp::TempDir;

    // ---- pure-logic units -------------------------------------------

    #[test]
    fn ring_balances_and_covers_every_actor() {
        let ring = HashRing::new(4);
        let mut counts = [0usize; 4];
        for i in 0..200 {
            let a = ring.route(&format!("key-{i}"), |_| true).unwrap();
            counts[a] += 1;
        }
        for (a, c) in counts.iter().enumerate() {
            assert!(*c > 0, "actor {a} owns no keys: {counts:?}");
        }
    }

    #[test]
    fn ring_death_moves_only_the_dead_actors_keys() {
        let ring = HashRing::new(4);
        let dead = 1usize;
        for i in 0..200 {
            let key = format!("key-{i}");
            let before = ring.route(&key, |_| true).unwrap();
            let after = ring.route(&key, |a| a != dead).unwrap();
            if before == dead {
                assert_ne!(after, dead);
            } else {
                assert_eq!(
                    before, after,
                    "{key} moved although its actor survived"
                );
            }
        }
    }

    #[test]
    fn ring_with_no_alive_actor_routes_nowhere() {
        let ring = HashRing::new(3);
        assert_eq!(ring.route("anything", |_| false), None);
    }

    #[test]
    fn bounded_queue_semantics() {
        let q: BoundedQueue<u32> = BoundedQueue::new(2);
        assert!(q.try_push(1).is_ok());
        assert!(q.try_push(2).is_ok());
        assert_eq!(q.len(), 2);
        match q.try_push(3) {
            Err(PushError::Full(3)) => {}
            _ => panic!("third push must report Full with the item"),
        }
        assert_eq!(q.pop(), Some(1));
        assert!(q.try_push(3).is_ok());
        q.close();
        match q.try_push(4) {
            Err(PushError::Closed(4)) => {}
            _ => panic!("push after close must report Closed"),
        }
        // Closing still drains what was accepted.
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(3));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn bounded_queue_drain_empties() {
        let q: BoundedQueue<u32> = BoundedQueue::new(8);
        for i in 0..5 {
            q.try_push(i).unwrap();
        }
        q.close();
        assert_eq!(q.drain(), vec![0, 1, 2, 3, 4]);
        assert_eq!(q.len(), 0);
        assert_eq!(q.pop(), None);
    }

    // ---- actor-level behaviour via a controllable mock backend ------

    /// Open/closed barrier: backends park in `enter_and_wait` until the
    /// test calls `open`, and the test can wait until `n` requests are
    /// parked — the determinism handle the concurrency tests need.
    struct Gate {
        state: Mutex<(usize, bool)>,
        cv: Condvar,
    }

    impl Gate {
        fn closed() -> Arc<Gate> {
            Arc::new(Gate { state: Mutex::new((0, false)), cv: Condvar::new() })
        }

        fn enter_and_wait(&self) {
            let mut st = self.state.lock().unwrap();
            st.0 += 1;
            self.cv.notify_all();
            while !st.1 {
                st = self.cv.wait(st).unwrap();
            }
        }

        fn wait_entered(&self, n: usize) {
            let mut st = self.state.lock().unwrap();
            while st.0 < n {
                st = self.cv.wait(st).unwrap();
            }
        }

        fn open(&self) {
            let mut st = self.state.lock().unwrap();
            st.1 = true;
            self.cv.notify_all();
        }
    }

    /// Backend double: `slow-*` artifacts park on the gate, `poison-*`
    /// artifacts panic, everything else returns immediately.  The pool
    /// never interprets artifact names, so none of these need manifest
    /// entries beyond an empty store.  Warm calls are logged (shared
    /// across actors) and tuning swaps are accepted, so the spill-warm
    /// and epoch-broadcast paths are observable.
    struct MockBackend {
        store: ArtifactStore,
        gate: Arc<Gate>,
        warms: Arc<Mutex<Vec<String>>>,
    }

    impl Backend for MockBackend {
        fn platform(&self) -> String {
            "mock".into()
        }

        fn store(&self) -> &ArtifactStore {
            &self.store
        }

        fn warm(&mut self, name: &str) -> Result<()> {
            self.warms.lock().unwrap().push(name.to_string());
            Ok(())
        }

        fn cached(&self) -> usize {
            0
        }

        fn run(&mut self, name: &str, _inputs: &[Vec<f32>]) -> Result<RunOutput> {
            if name.starts_with("slow") {
                self.gate.enter_and_wait();
            }
            if name.starts_with("poison") {
                panic!("poisoned artifact executed");
            }
            Ok(RunOutput {
                outputs: vec![vec![1.0]],
                elapsed: Duration::from_micros(1),
            })
        }

        fn swap_tuning(&mut self, _db: Arc<SelectionDb>) -> bool {
            true
        }
    }

    fn empty_store() -> (TempDir, ArtifactStore) {
        let dir = TempDir::new("pool-mock").unwrap();
        std::fs::write(
            dir.path().join("manifest.json"),
            r#"{"version": 1, "artifacts": []}"#,
        )
        .unwrap();
        let store = ArtifactStore::open(dir.path()).unwrap();
        (dir, store)
    }

    fn mock_pool(
        config: PoolConfig,
        gate: &Arc<Gate>,
    ) -> (TempDir, EnginePool) {
        let (dir, pool, _warms) = mock_pool_logged(config, gate);
        (dir, pool)
    }

    /// Like [`mock_pool`] but also hands back the shared warm log, for
    /// tests asserting on the spill-warm path.
    fn mock_pool_logged(
        config: PoolConfig,
        gate: &Arc<Gate>,
    ) -> (TempDir, EnginePool, Arc<Mutex<Vec<String>>>) {
        let (dir, store) = empty_store();
        let gate = Arc::clone(gate);
        let warms = Arc::new(Mutex::new(Vec::new()));
        let warms_c = Arc::clone(&warms);
        let pool = EnginePool::spawn_with(config, move |_| {
            Ok(MockBackend {
                store: store.clone(),
                gate: Arc::clone(&gate),
                warms: Arc::clone(&warms_c),
            })
        })
        .unwrap();
        (dir, pool, warms)
    }

    /// Find an artifact name with the given prefix whose ring home is
    /// `actor` (the ring spreads prefixed names across actors, so a few
    /// candidates always suffice).
    fn name_on(pool: &EnginePool, prefix: &str, actor: usize) -> String {
        for i in 0..64 {
            let name = format!("{prefix}-{i}");
            if pool.route_of(&name) == Some(actor) {
                return name;
            }
        }
        panic!("no {prefix}-* name routes to actor {actor}");
    }

    #[test]
    fn try_submit_reports_busy_at_bounded_depth() {
        let gate = Gate::closed();
        let config = PoolConfig { actors: 1, queue_depth: 2, spill_depth: 2, ..Default::default() };
        let (_dir, pool) = mock_pool(config, &gate);

        // One request in flight (parked on the gate), two filling the
        // bounded queue.
        let t0 = pool.submit_run("slow-0", vec![]).unwrap();
        gate.wait_entered(1);
        let t1 = pool.submit_run("work-1", vec![]).unwrap();
        let t2 = pool.submit_run("work-2", vec![]).unwrap();
        assert_eq!(pool.queue_len(0), 2);

        // The queue is at depth: non-blocking submission must shed load,
        // not grow the queue.
        match pool.try_submit_run("work-3", vec![]) {
            Err(SubmitError::Busy) => {}
            Ok(_) => panic!("try_submit must not exceed the bounded depth"),
            Err(e) => panic!("expected Busy, got {e}"),
        }

        gate.open();
        assert!(t0.wait().is_ok());
        assert!(t1.wait().is_ok());
        assert!(t2.wait().is_ok());
        assert_eq!(pool.stats().runs, 3);
        pool.shutdown();
    }

    #[test]
    fn overloaded_home_queue_spills_to_least_loaded() {
        let gate = Gate::closed();
        let config = PoolConfig { actors: 2, queue_depth: 8, spill_depth: 1, ..Default::default() };
        let (_dir, pool) = mock_pool(config, &gate);
        let slow = name_on(&pool, "slow", 0);

        // First submission: actor 0 parks on the gate (queue empty).
        let t0 = pool.submit_run(&slow, vec![]).unwrap();
        gate.wait_entered(1);
        // Second: queues on actor 0 (depth 1 = spill threshold).
        let t1 = pool.submit_run(&slow, vec![]).unwrap();
        assert_eq!(pool.queue_len(0), 1);
        // Third: the home queue is at the spill depth, so the router
        // must hand this to idle actor 1 — which parks on the gate too.
        let t2 = pool.submit_run(&slow, vec![]).unwrap();
        gate.wait_entered(2);

        gate.open();
        for t in [t0, t1, t2] {
            assert!(t.wait().is_ok());
        }
        pool.shutdown();
    }

    #[test]
    fn first_spill_warms_the_target_once_and_spills_are_counted() {
        let gate = Gate::closed();
        let config = PoolConfig {
            actors: 2,
            queue_depth: 8,
            spill_depth: 1,
            ..Default::default()
        };
        let (_dir, pool, warms) = mock_pool_logged(config, &gate);
        let slow = name_on(&pool, "slow", 0);

        // Park actor 0 and fill its queue to the spill depth.
        let t0 = pool.submit_run(&slow, vec![]).unwrap();
        gate.wait_entered(1);
        let t1 = pool.submit_run(&slow, vec![]).unwrap();
        assert_eq!(pool.spilled(), 0, "home placements are not spills");

        // First spill onto actor 1: a warm for the artifact must be
        // queued ahead of the run, so the spilled request lands on a
        // plan the actor already built.
        let t2 = pool.submit_run(&slow, vec![]).unwrap();
        gate.wait_entered(2);
        assert_eq!(pool.spilled(), 1);

        // Second spill of the same artifact onto the same actor: no
        // second warm, but the spill metric still counts it.
        let t3 = pool.submit_run(&slow, vec![]).unwrap();
        assert_eq!(pool.spilled(), 2);

        gate.open();
        for t in [t0, t1, t2, t3] {
            assert!(t.wait().is_ok());
        }
        pool.shutdown();
        assert_eq!(
            warms.lock().unwrap().as_slice(),
            &[slow],
            "exactly one warm, issued for the first spill only"
        );
    }

    #[test]
    fn swap_tuning_broadcasts_to_every_healthy_actor() {
        let gate = Gate::closed();
        let config = PoolConfig { actors: 2, ..Default::default() };
        let (_dir, pool) = mock_pool(config, &gate);

        let handle = crate::tuner::TuningHandle::new(SelectionDb::default());
        let next = handle.publish(SelectionDb::default());
        assert_eq!(next.epoch, 1);
        assert_eq!(
            pool.swap_tuning(&next),
            2,
            "both mock backends accept the swap"
        );
        // Aggregated stats surface the newest applied epoch.
        assert_eq!(pool.stats().tuning_epoch, 1);
        pool.shutdown();
    }

    #[test]
    fn panic_is_contained_and_backlog_drains_to_survivors() {
        let gate = Gate::closed();
        let config = PoolConfig { actors: 2, queue_depth: 8, spill_depth: 8, ..Default::default() };
        let (_dir, pool) = mock_pool(config, &gate);

        // Everything below targets whichever actor owns "poison-0".
        let victim = pool.route_of("poison-0").unwrap();
        let survivor = 1 - victim;
        let slow = name_on(&pool, "slow", victim);
        let work_a = name_on(&pool, "work", victim);
        let work_b = name_on(&pool, "work", victim);

        // Park the victim actor, then queue: poison first, real work
        // behind it.
        let t_slow = pool.submit_run(&slow, vec![]).unwrap();
        gate.wait_entered(1);
        let t_poison = pool.submit_run("poison-0", vec![]).unwrap();
        let t_a = pool.submit_run(&work_a, vec![]).unwrap();
        let t_b = pool.submit_run(&work_b, vec![]).unwrap();
        assert_eq!(pool.queue_len(victim), 3);

        // Release: the victim serves `slow`, panics on `poison`, and its
        // backlog must drain onto the survivor.
        gate.open();
        assert!(t_slow.wait().is_ok(), "pre-panic request must succeed");
        assert!(
            t_poison.wait().is_err(),
            "the panicking request must fail loudly, not hang"
        );
        assert!(t_a.wait().is_ok(), "queued work must drain to survivors");
        assert!(t_b.wait().is_ok(), "queued work must drain to survivors");

        assert_eq!(pool.healthy_actors(), 1);
        assert_eq!(pool.panicked_actors(), 1);
        // Routing now sends the victim's artifacts to the survivor.
        assert_eq!(pool.route_of(&work_a), Some(survivor));
        // And the pool keeps serving.
        assert!(pool.run("after-the-fire", vec![]).is_ok());
        pool.shutdown();
    }

    #[test]
    fn actor_construction_failure_is_a_loud_err_with_cleanup() {
        let (_dir, store) = empty_store();
        let gate = Gate::closed();
        let config = PoolConfig { actors: 3, ..Default::default() };
        let err = EnginePool::spawn_with(config, move |idx| {
            if idx == 1 {
                return Err(Error::Runtime("actor 1 refused to start".into()));
            }
            Ok(MockBackend {
                store: store.clone(),
                gate: Arc::clone(&gate),
                warms: Arc::new(Mutex::new(Vec::new())),
            })
        })
        .err()
        .expect("constructor failure must fail the whole spawn");
        assert!(err.to_string().contains("refused to start"), "got: {err}");
    }

    #[test]
    fn zero_sized_configs_rejected() {
        let (_dir, store) = empty_store();
        let gate = Gate::closed();
        for config in [
            PoolConfig { actors: 0, queue_depth: 4, spill_depth: 2, ..Default::default() },
            PoolConfig { actors: 2, queue_depth: 0, spill_depth: 1, ..Default::default() },
            PoolConfig { actors: 2, queue_depth: 4, spill_depth: 0, ..Default::default() },
            PoolConfig { actors: 2, queue_depth: 4, spill_depth: 5, ..Default::default() },
        ] {
            let store = store.clone();
            let gate = Arc::clone(&gate);
            assert!(
                EnginePool::spawn_with(config, move |_| {
                    Ok(MockBackend {
                        store: store.clone(),
                        gate: Arc::clone(&gate),
                        warms: Arc::new(Mutex::new(Vec::new())),
                    })
                })
                .is_err(),
                "{config:?} must be rejected"
            );
        }
    }

    #[test]
    fn graceful_shutdown_drains_accepted_requests() {
        let gate = Gate::closed();
        let config = PoolConfig { actors: 2, queue_depth: 16, spill_depth: 16, ..Default::default() };
        let (_dir, pool) = mock_pool(config, &gate);
        let slow = name_on(&pool, "slow", 0);

        let t_slow = pool.submit_run(&slow, vec![]).unwrap();
        gate.wait_entered(1);
        let tickets: Vec<RunTicket> = (0..10)
            .map(|i| pool.submit_run(&format!("work-{i}"), vec![]).unwrap())
            .collect();

        // Shutdown closes the queues but must serve what was accepted.
        gate.open();
        pool.shutdown();
        assert!(t_slow.wait().is_ok());
        for t in tickets {
            assert!(t.wait().is_ok(), "accepted request dropped at shutdown");
        }
    }
}
