//! Engine actor: a dedicated thread owns the execution backend; callers
//! talk to it through channels.  Backends are `&mut self` and (for PJRT)
//! hold non-`Sync` types, so the actor keeps them on one thread while any
//! number of coordinator threads submit work.
//!
//! The actor is generic over [`Backend`]: [`EngineHandle::spawn`] uses the
//! build's [`DefaultEngine`] (native offline, PJRT under `--features
//! pjrt`), and [`EngineHandle::spawn_with`] accepts any backend
//! constructor — construction happens *on the actor thread*, so backends
//! whose internals are not `Send` still work.
//!
//! (The usual tokio runtime is unavailable in this offline build; the
//! actor is pure `std::thread` + `mpsc`, which also keeps the request
//! path allocation-free apart from the payload itself.)

use std::path::Path;
use std::sync::mpsc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::error::{Error, Result};
use crate::runtime::{ArtifactStore, Backend, DefaultEngine, RunOutput};

enum Request {
    Run {
        name: String,
        inputs: Vec<Vec<f32>>,
        reply: mpsc::Sender<Result<RunOutput>>,
    },
    RunTimed {
        name: String,
        inputs: Vec<Vec<f32>>,
        iters: usize,
        reply: mpsc::Sender<Result<(RunOutput, Duration)>>,
    },
    Warm {
        name: String,
        reply: mpsc::Sender<Result<()>>,
    },
    SynthInputs {
        name: String,
        seed: u64,
        reply: mpsc::Sender<Result<Vec<Vec<f32>>>>,
    },
    Stats {
        reply: mpsc::Sender<EngineStats>,
    },
    Shutdown,
}

/// Coordinator-visible engine statistics.
#[derive(Debug, Clone, Default)]
pub struct EngineStats {
    /// Executions completed.
    pub runs: u64,
    /// Compiled/planned artifacts resident in the cache.
    pub cached_executables: usize,
    /// Total device execution time.
    pub device_time: Duration,
}

/// Cloneable handle to the engine actor.
#[derive(Clone)]
pub struct EngineHandle {
    tx: mpsc::Sender<Request>,
}

impl EngineHandle {
    /// Spawn the actor over the artifact directory with the build's
    /// default backend.  Returns the handle and the join handle of the
    /// actor thread.
    pub fn spawn(artifact_dir: &Path) -> Result<(Self, JoinHandle<()>)> {
        let store = ArtifactStore::open(artifact_dir)?;
        Self::spawn_with(move || DefaultEngine::new(store))
    }

    /// Spawn the actor with an explicit backend constructor.  The
    /// constructor runs on the actor thread (PJRT clients never cross
    /// threads); construction errors are reported synchronously.
    pub fn spawn_with<B, F>(make: F) -> Result<(Self, JoinHandle<()>)>
    where
        B: Backend + 'static,
        F: FnOnce() -> Result<B> + Send + 'static,
    {
        let (tx, rx) = mpsc::channel::<Request>();
        let (init_tx, init_rx) = mpsc::channel::<Result<()>>();
        let join = std::thread::Builder::new()
            .name("engine".into())
            .spawn(move || {
                let mut engine = match make() {
                    Ok(e) => {
                        let _ = init_tx.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = init_tx.send(Err(e));
                        return;
                    }
                };
                let mut stats = EngineStats::default();
                while let Ok(req) = rx.recv() {
                    match req {
                        Request::Run { name, inputs, reply } => {
                            let out = engine.run(&name, &inputs);
                            if let Ok(o) = &out {
                                stats.runs += 1;
                                stats.device_time += o.elapsed;
                            }
                            stats.cached_executables = engine.cached();
                            let _ = reply.send(out);
                        }
                        Request::RunTimed { name, inputs, iters, reply } => {
                            let out = engine.run_timed(&name, &inputs, iters);
                            if let Ok((o, _)) = &out {
                                stats.runs += iters as u64;
                                stats.device_time += o.elapsed * iters as u32;
                            }
                            stats.cached_executables = engine.cached();
                            let _ = reply.send(out);
                        }
                        Request::Warm { name, reply } => {
                            let r = engine.warm(&name);
                            stats.cached_executables = engine.cached();
                            let _ = reply.send(r);
                        }
                        Request::SynthInputs { name, seed, reply } => {
                            let _ = reply.send(engine.synth_inputs(&name, seed));
                        }
                        Request::Stats { reply } => {
                            let _ = reply.send(stats.clone());
                        }
                        Request::Shutdown => break,
                    }
                }
            })
            .expect("spawn engine thread");
        init_rx
            .recv()
            .map_err(|_| Error::Runtime("engine thread died during init".into()))??;
        Ok((Self { tx }, join))
    }

    fn send(&self, req: Request) -> Result<()> {
        self.tx
            .send(req)
            .map_err(|_| Error::Runtime("engine actor gone".into()))
    }

    fn ask<T>(
        &self,
        make: impl FnOnce(mpsc::Sender<T>) -> Request,
    ) -> Result<T> {
        let (reply, rx) = mpsc::channel();
        self.send(make(reply))?;
        rx.recv()
            .map_err(|_| Error::Runtime("engine dropped request".into()))
    }

    /// Execute an artifact.
    pub fn run(&self, name: &str, inputs: Vec<Vec<f32>>) -> Result<RunOutput> {
        self.ask(|reply| Request::Run { name: name.into(), inputs, reply })?
    }

    /// Execute an artifact `iters` times, per-run setup hoisted by the
    /// backend; returns the last output with the best (min) time.
    pub fn run_timed(
        &self,
        name: &str,
        inputs: Vec<Vec<f32>>,
        iters: usize,
    ) -> Result<(RunOutput, Duration)> {
        self.ask(|reply| Request::RunTimed {
            name: name.into(),
            inputs,
            iters,
            reply,
        })?
    }

    /// Pre-compile (or pre-plan) an artifact.
    pub fn warm(&self, name: &str) -> Result<()> {
        self.ask(|reply| Request::Warm { name: name.into(), reply })?
    }

    /// Deterministic synthetic inputs for an artifact.
    pub fn synth_inputs(&self, name: &str, seed: u64) -> Result<Vec<Vec<f32>>> {
        self.ask(|reply| Request::SynthInputs { name: name.into(), seed, reply })?
    }

    /// Engine statistics snapshot.
    pub fn stats(&self) -> Result<EngineStats> {
        self.ask(|reply| Request::Stats { reply })
    }

    /// Ask the actor to exit (idempotent; pending requests drain first).
    pub fn shutdown(&self) {
        let _ = self.tx.send(Request::Shutdown);
    }
}
