//! Engine actor: a dedicated thread owns the execution backend; callers
//! talk to it through channels.  Backends are `&mut self` and (for PJRT)
//! hold non-`Sync` types, so the actor keeps them on one thread while any
//! number of coordinator threads submit work.
//!
//! The actor is generic over [`Backend`]: [`EngineHandle::spawn`] uses the
//! build's [`DefaultEngine`] (native offline, PJRT under `--features
//! pjrt`), and [`EngineHandle::spawn_with`] accepts any backend
//! constructor — construction happens *on the actor thread*, so backends
//! whose internals are not `Send` still work.
//!
//! The request/serve plumbing ([`Request`], [`serve_request`]) is shared
//! with the multi-actor [`EnginePool`](super::EnginePool): one actor is
//! the degenerate pool, and both speak the same protocol.
//!
//! (The usual tokio runtime is unavailable in this offline build; the
//! actor is pure `std::thread` + `mpsc`, which also keeps the request
//! path allocation-free apart from the payload itself.)

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::error::{Error, Result};
use crate::runtime::{ArtifactStore, Backend, DefaultEngine, RunOutput};
use crate::tuner::{SelectionDb, TuningSnapshot};
use crate::util::scratch::ScratchStats;

/// One message to an engine actor.  Every variant that expects an answer
/// carries its own one-shot reply channel, so any number of clients can
/// have requests in flight against the same actor.
pub(crate) enum Request {
    /// Execute an artifact once.
    Run {
        name: String,
        inputs: Vec<Vec<f32>>,
        reply: mpsc::Sender<Result<RunOutput>>,
    },
    /// Execute an artifact `iters` times, best (min) time reported.
    RunTimed {
        name: String,
        inputs: Vec<Vec<f32>>,
        iters: usize,
        reply: mpsc::Sender<Result<(RunOutput, Duration)>>,
    },
    /// Pre-compile (or pre-plan) an artifact.
    Warm {
        name: String,
        reply: mpsc::Sender<Result<()>>,
    },
    /// Deterministic synthetic inputs for an artifact.
    SynthInputs {
        name: String,
        seed: u64,
        reply: mpsc::Sender<Result<Vec<Vec<f32>>>>,
    },
    /// List every artifact name in the actor's store (manifest order).
    /// Used by the pool's warm fan-out to enumerate what to pre-warm
    /// without opening the manifest a second time.
    Artifacts {
        reply: mpsc::Sender<Vec<String>>,
    },
    /// Snapshot the actor's statistics.
    Stats {
        reply: mpsc::Sender<EngineStats>,
    },
    /// Install a new tuning snapshot on the actor's backend
    /// ([`Backend::swap_tuning`]) — the epoch-swap rung of the online
    /// re-tuning loop.  Replies whether the backend applied it.
    SwapTuning {
        db: Arc<SelectionDb>,
        epoch: u64,
        reply: mpsc::Sender<bool>,
    },
    /// Ask the actor to exit its serve loop.
    Shutdown,
}

/// Serve one request against a backend, updating `stats`.  Returns
/// `false` when the request asks the serve loop to stop.
///
/// This is the single place requests are interpreted: the
/// [`EngineHandle`] actor and every [`EnginePool`](super::EnginePool)
/// actor run exactly this function, so the two serving shapes cannot
/// drift apart.
pub(crate) fn serve_request<B: Backend>(
    engine: &mut B,
    stats: &mut EngineStats,
    req: Request,
) -> bool {
    match req {
        Request::Run { name, inputs, reply } => {
            let out = engine.run(&name, &inputs);
            if let Ok(o) = &out {
                stats.runs += 1;
                stats.device_time += o.elapsed;
                record_latency(engine, stats, &name, o.elapsed);
            }
            stats.cached_executables = engine.cached();
            let _ = reply.send(out);
            true
        }
        Request::RunTimed { name, inputs, iters, reply } => {
            let out = engine.run_timed(&name, &inputs, iters);
            if let Ok((o, _)) = &out {
                stats.runs += iters as u64;
                stats.device_time += o.elapsed * iters as u32;
            }
            stats.cached_executables = engine.cached();
            let _ = reply.send(out);
            true
        }
        Request::Warm { name, reply } => {
            let r = engine.warm(&name);
            stats.cached_executables = engine.cached();
            let _ = reply.send(r);
            true
        }
        Request::SynthInputs { name, seed, reply } => {
            let _ = reply.send(engine.synth_inputs(&name, seed));
            true
        }
        Request::Artifacts { reply } => {
            let names =
                engine.store().iter().map(|m| m.name.clone()).collect();
            let _ = reply.send(names);
            true
        }
        Request::Stats { reply } => {
            // Refresh the arena counters at snapshot time: they live in
            // the backend (atomics inside its `Scratch`), not in the
            // per-request accounting, so the snapshot is the one place
            // they cross into `EngineStats`.
            stats.scratch = engine.scratch_stats();
            let _ = reply.send(stats.clone());
            true
        }
        Request::SwapTuning { db, epoch, reply } => {
            let applied = engine.swap_tuning(db);
            if applied {
                stats.tuning_epoch = epoch;
            }
            stats.cached_executables = engine.cached();
            let _ = reply.send(applied);
            true
        }
        Request::Shutdown => false,
    }
}

/// Fold one served execution into the per-(artifact, shape-class)
/// latency accounting.  The key is `"{artifact}::{shape_class}"`
/// ([`crate::tuner::shape_class_for`]); artifacts outside the tuned
/// kinds bucket under `unclassified`.  Only `Request::Run` traffic is
/// recorded — `RunTimed` is the measurement path, and mixing probe
/// timings into serving latency would bias the re-tuner's hot set.
fn record_latency<B: Backend>(
    engine: &B,
    stats: &mut EngineStats,
    name: &str,
    elapsed: Duration,
) {
    let class = engine
        .store()
        .get(name)
        .ok()
        .and_then(crate::tuner::shape_class_for)
        .unwrap_or_else(|| "unclassified".to_string());
    let key = format!("{name}::{class}");
    stats.latency.entry(key).or_default().record(elapsed);
}

/// Number of log2-microsecond latency buckets in a [`LatencyStats`]
/// histogram.  Bucket `i` covers roughly `[2^i, 2^(i+1))` microseconds;
/// the last bucket absorbs everything slower (~0.5 s and up), so no
/// request is ever dropped from the histogram.
pub const LATENCY_BUCKETS: usize = 20;

/// Serving-latency accounting for one `(artifact, shape-class)` key.
///
/// The histogram is log2-microsecond bucketed — coarse, allocation-free,
/// and mergeable across pool actors — which is exactly what the online
/// re-tuner needs: it ranks shape classes by *total* time, and operators
/// read approximate tail percentiles from the buckets.  Exact quantiles
/// would require retaining samples; a serving path must not.
#[derive(Debug, Clone)]
pub struct LatencyStats {
    /// Requests recorded.
    pub count: u64,
    /// Sum of recorded latencies (drives hot-class ranking).
    pub total: Duration,
    /// Fastest recorded latency (`Duration::MAX` until first record).
    pub min: Duration,
    /// Slowest recorded latency.
    pub max: Duration,
    /// Log2-microsecond histogram; see [`LATENCY_BUCKETS`].
    pub buckets: [u64; LATENCY_BUCKETS],
}

impl Default for LatencyStats {
    fn default() -> Self {
        LatencyStats {
            count: 0,
            total: Duration::ZERO,
            min: Duration::MAX,
            max: Duration::ZERO,
            buckets: [0; LATENCY_BUCKETS],
        }
    }
}

impl LatencyStats {
    /// Fold one served-request latency into the accounting.
    pub fn record(&mut self, d: Duration) {
        self.count += 1;
        self.total += d;
        self.min = self.min.min(d);
        self.max = self.max.max(d);
        self.buckets[Self::bucket_index(d)] += 1;
    }

    /// Bucket index for a latency: floor(log2(µs)), clamped to the
    /// histogram width.  Sub-microsecond latencies land in bucket 0.
    fn bucket_index(d: Duration) -> usize {
        let mut us = d.as_micros() as u64;
        let mut idx = 0usize;
        while us > 1 && idx < LATENCY_BUCKETS - 1 {
            us >>= 1;
            idx += 1;
        }
        idx
    }

    /// Fold another actor's accounting for the same key into this one.
    pub fn merge(&mut self, other: &LatencyStats) {
        self.count += other.count;
        self.total += other.total;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
        for (b, o) in self.buckets.iter_mut().zip(&other.buckets) {
            *b += o;
        }
    }

    /// Mean recorded latency ([`Duration::ZERO`] before any record).
    pub fn mean(&self) -> Duration {
        if self.count == 0 {
            return Duration::ZERO;
        }
        self.total / self.count as u32
    }

    /// Approximate `q`-quantile (`0.0..=1.0`) from the histogram: the
    /// upper bound of the bucket containing the `ceil(count * q)`-th
    /// sample.  Bucket resolution means the answer can overestimate by
    /// up to 2×, which is fine for the "did p99 recover?" reading it
    /// serves.  Returns [`Duration::ZERO`] before any record.
    pub fn approx_percentile(&self, q: f64) -> Duration {
        if self.count == 0 {
            return Duration::ZERO;
        }
        let rank = ((self.count as f64 * q).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b;
            if seen >= rank {
                if i >= LATENCY_BUCKETS - 1 {
                    return self.max;
                }
                return Duration::from_micros(1u64 << (i + 1));
            }
        }
        self.max
    }
}

/// Coordinator-visible engine statistics.
#[derive(Debug, Clone, Default)]
pub struct EngineStats {
    /// Executions completed.
    pub runs: u64,
    /// Compiled/planned artifacts resident in the cache.
    pub cached_executables: usize,
    /// Total device execution time.
    pub device_time: Duration,
    /// Per-`(artifact, shape-class)` serving latency, keyed
    /// `"{artifact}::{shape_class}"`.  Populated by `Request::Run`
    /// traffic only — the serving signal the online re-tuner ranks hot
    /// shape classes from.
    pub latency: BTreeMap<String, LatencyStats>,
    /// Epoch of the last tuning snapshot the backend applied
    /// ([`Backend::swap_tuning`]); 0 until a swap lands.
    pub tuning_epoch: u64,
    /// Kernel-scratch arena counters from [`Backend::scratch_stats`],
    /// refreshed on every stats snapshot.  `grows` flat across
    /// steady-state traffic is the zero-allocation invariant the
    /// loadgen CSVs assert; all-zero for backends without an arena.
    pub scratch: ScratchStats,
}

impl EngineStats {
    /// Fold another actor's statistics into this one: counters sum,
    /// latency accounting merges per key, and `tuning_epoch` takes the
    /// max (actors swap snapshots one at a time; the newest epoch is
    /// the pool's).  This is how [`EnginePool::stats`](super::EnginePool)
    /// aggregates across actors.
    pub fn absorb(&mut self, other: &EngineStats) {
        self.runs += other.runs;
        self.cached_executables += other.cached_executables;
        self.device_time += other.device_time;
        for (key, stats) in &other.latency {
            self.latency.entry(key.clone()).or_default().merge(stats);
        }
        self.tuning_epoch = self.tuning_epoch.max(other.tuning_epoch);
        self.scratch.absorb(&other.scratch);
    }

    /// The `top` shape classes ranked by total serving time, hottest
    /// first — the re-tuner's work list.  Keys aggregate across
    /// artifacts: two artifacts in the same class pool their time.
    pub fn hot_shape_classes(&self, top: usize) -> Vec<String> {
        let mut per_class: BTreeMap<&str, Duration> = BTreeMap::new();
        for (key, stats) in &self.latency {
            let class = key.rsplit("::").next().unwrap_or(key);
            *per_class.entry(class).or_insert(Duration::ZERO) +=
                stats.total;
        }
        let mut ranked: Vec<(&str, Duration)> =
            per_class.into_iter().collect();
        ranked.sort_by(|a, b| b.1.cmp(&a.1));
        ranked
            .into_iter()
            .take(top)
            .map(|(class, _)| class.to_string())
            .collect()
    }
}

/// Cloneable handle to a single engine actor.
///
/// The handle is the one-actor serving shape: every request funnels
/// through one backend thread.  For multi-actor serving with routing and
/// backpressure, see [`EnginePool`](super::EnginePool) — both implement
/// [`EngineClient`](super::EngineClient), so callers like
/// [`NetworkRunner`](super::NetworkRunner) work against either.
///
/// # Examples
///
/// ```
/// use portable_kernels::coordinator::EngineHandle;
/// use portable_kernels::util::tmp::TempDir;
///
/// // A synthetic manifest: the native backend plans from metadata and
/// // never opens the HLO file.
/// let dir = TempDir::new("doc-engine").unwrap();
/// std::fs::write(
///     dir.path().join("manifest.json"),
///     r#"{"version": 1, "artifacts": [{
///         "name": "g4", "kind": "gemm", "impl": "pallas",
///         "file": "g4.hlo.txt", "flops": 128, "m": 4, "n": 4, "k": 4,
///         "inputs": [{"shape": [4, 4], "dtype": "float32"},
///                    {"shape": [4, 4], "dtype": "float32"}],
///         "groups": ["gemm"]}]}"#,
/// )
/// .unwrap();
///
/// let (handle, join) = EngineHandle::spawn(dir.path()).unwrap();
/// let inputs = handle.synth_inputs("g4", 7).unwrap();
/// let out = handle.run("g4", inputs).unwrap();
/// assert_eq!(out.outputs[0].len(), 16);
/// handle.shutdown();
/// join.join().unwrap();
/// ```
#[derive(Clone)]
pub struct EngineHandle {
    tx: mpsc::Sender<Request>,
}

impl EngineHandle {
    /// Spawn the actor over the artifact directory with the build's
    /// default backend.  Returns the handle and the join handle of the
    /// actor thread.
    pub fn spawn(artifact_dir: &Path) -> Result<(Self, JoinHandle<()>)> {
        let store = ArtifactStore::open(artifact_dir)?;
        Self::spawn_with(move || DefaultEngine::new(store))
    }

    /// Spawn the actor with an explicit backend constructor.  The
    /// constructor runs on the actor thread (PJRT clients never cross
    /// threads).
    ///
    /// Spawn failure is always a loud, synchronous `Err`: an OS-level
    /// thread-spawn failure, a constructor that returns `Err`, and a
    /// constructor that panics all surface here — never as a handle
    /// whose requests silently hang.
    pub fn spawn_with<B, F>(make: F) -> Result<(Self, JoinHandle<()>)>
    where
        B: Backend + 'static,
        F: FnOnce() -> Result<B> + Send + 'static,
    {
        let (tx, rx) = mpsc::channel::<Request>();
        let (init_tx, init_rx) = mpsc::channel::<Result<()>>();
        let join = std::thread::Builder::new()
            .name("engine".into())
            .spawn(move || {
                let mut engine = match make() {
                    Ok(e) => {
                        let _ = init_tx.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = init_tx.send(Err(e));
                        return;
                    }
                };
                let mut stats = EngineStats::default();
                while let Ok(req) = rx.recv() {
                    if !serve_request(&mut engine, &mut stats, req) {
                        break;
                    }
                }
            })
            .map_err(|e| {
                Error::Runtime(format!("cannot spawn engine thread: {e}"))
            })?;
        init_rx
            .recv()
            .map_err(|_| Error::Runtime("engine thread died during init".into()))??;
        Ok((Self { tx }, join))
    }

    fn send(&self, req: Request) -> Result<()> {
        self.tx
            .send(req)
            .map_err(|_| Error::Runtime("engine actor gone".into()))
    }

    fn ask<T>(
        &self,
        make: impl FnOnce(mpsc::Sender<T>) -> Request,
    ) -> Result<T> {
        let (reply, rx) = mpsc::channel();
        self.send(make(reply))?;
        rx.recv()
            .map_err(|_| Error::Runtime("engine dropped request".into()))
    }

    /// Execute an artifact.
    pub fn run(&self, name: &str, inputs: Vec<Vec<f32>>) -> Result<RunOutput> {
        self.ask(|reply| Request::Run { name: name.into(), inputs, reply })?
    }

    /// Execute an artifact `iters` times, per-run setup hoisted by the
    /// backend; returns the last output with the best (min) time.
    pub fn run_timed(
        &self,
        name: &str,
        inputs: Vec<Vec<f32>>,
        iters: usize,
    ) -> Result<(RunOutput, Duration)> {
        self.ask(|reply| Request::RunTimed {
            name: name.into(),
            inputs,
            iters,
            reply,
        })?
    }

    /// Pre-compile (or pre-plan) an artifact.
    pub fn warm(&self, name: &str) -> Result<()> {
        self.ask(|reply| Request::Warm { name: name.into(), reply })?
    }

    /// Deterministic synthetic inputs for an artifact.
    pub fn synth_inputs(&self, name: &str, seed: u64) -> Result<Vec<Vec<f32>>> {
        self.ask(|reply| Request::SynthInputs { name: name.into(), seed, reply })?
    }

    /// Engine statistics snapshot.
    pub fn stats(&self) -> Result<EngineStats> {
        self.ask(|reply| Request::Stats { reply })
    }

    /// Install a tuning snapshot on the actor's backend
    /// ([`Backend::swap_tuning`]).  Returns whether the backend applied
    /// it (the default backend hook is a no-op `false`; the native
    /// engine re-resolves cached plans and answers `true`).
    pub fn swap_tuning(&self, snap: &TuningSnapshot) -> Result<bool> {
        self.ask(|reply| Request::SwapTuning {
            db: Arc::clone(&snap.db),
            epoch: snap.epoch,
            reply,
        })
    }

    /// Ask the actor to exit (idempotent; pending requests drain first).
    pub fn shutdown(&self) {
        let _ = self.tx.send(Request::Shutdown);
    }
}

impl super::EngineClient for EngineHandle {
    fn run(&self, name: &str, inputs: Vec<Vec<f32>>) -> Result<RunOutput> {
        EngineHandle::run(self, name, inputs)
    }

    fn run_timed(
        &self,
        name: &str,
        inputs: Vec<Vec<f32>>,
        iters: usize,
    ) -> Result<(RunOutput, Duration)> {
        EngineHandle::run_timed(self, name, inputs, iters)
    }

    fn warm(&self, name: &str) -> Result<()> {
        EngineHandle::warm(self, name)
    }

    fn synth_inputs(&self, name: &str, seed: u64) -> Result<Vec<Vec<f32>>> {
        EngineHandle::synth_inputs(self, name, seed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructor_error_is_a_loud_err_not_a_hang() {
        let err = EngineHandle::spawn_with(|| -> Result<DefaultEngine> {
            Err(Error::Runtime("backend exploded during construction".into()))
        })
        .err()
        .expect("constructor failure must surface as Err");
        assert!(err.to_string().contains("exploded"), "got: {err}");
    }

    #[test]
    fn constructor_panic_is_a_loud_err_not_a_hang() {
        let err = EngineHandle::spawn_with(|| -> Result<DefaultEngine> {
            panic!("constructor panicked");
        })
        .err()
        .expect("constructor panic must surface as Err");
        assert!(err.to_string().contains("died during init"), "got: {err}");
    }

    #[test]
    fn latency_buckets_are_log2_microseconds() {
        let mut lat = LatencyStats::default();
        lat.record(Duration::from_micros(1));
        lat.record(Duration::from_micros(3));
        lat.record(Duration::from_micros(900));
        assert_eq!(lat.count, 3);
        assert_eq!(lat.buckets[0], 1, "1us is bucket 0");
        assert_eq!(lat.buckets[1], 1, "3us is bucket 1 (floor log2)");
        assert_eq!(lat.buckets[9], 1, "900us is bucket 9 (512..1024)");
        assert_eq!(lat.min, Duration::from_micros(1));
        assert_eq!(lat.max, Duration::from_micros(900));
        // The p99 estimate lands on the slow bucket's upper bound.
        assert_eq!(lat.approx_percentile(0.99), Duration::from_micros(1024));
        assert_eq!(
            LatencyStats::default().approx_percentile(0.5),
            Duration::ZERO
        );
    }

    #[test]
    fn latency_merge_folds_both_sides() {
        let mut a = LatencyStats::default();
        a.record(Duration::from_micros(10));
        let mut b = LatencyStats::default();
        b.record(Duration::from_micros(40));
        b.record(Duration::from_micros(2));
        a.merge(&b);
        assert_eq!(a.count, 3);
        assert_eq!(a.total, Duration::from_micros(52));
        assert_eq!(a.min, Duration::from_micros(2));
        assert_eq!(a.max, Duration::from_micros(40));
    }

    #[test]
    fn absorb_sums_counters_and_ranks_hot_classes() {
        let mut a = EngineStats::default();
        a.runs = 2;
        a.device_time = Duration::from_micros(30);
        let mut hot = LatencyStats::default();
        hot.record(Duration::from_micros(20));
        a.latency.insert("g96::gemm_128x128x128".into(), hot);

        let mut b = EngineStats::default();
        b.runs = 1;
        b.tuning_epoch = 3;
        let mut warm = LatencyStats::default();
        warm.record(Duration::from_micros(5));
        b.latency.insert("g8::gemm_64x64x64".into(), warm);
        let mut more = LatencyStats::default();
        more.record(Duration::from_micros(7));
        b.latency.insert("g128::gemm_128x128x128".into(), more);
        a.scratch =
            ScratchStats { hits: 4, grows: 2, bytes: 64, high_water_bytes: 64 };
        b.scratch =
            ScratchStats { hits: 6, grows: 1, bytes: 32, high_water_bytes: 48 };

        a.absorb(&b);
        assert_eq!(a.runs, 3);
        assert_eq!(a.tuning_epoch, 3);
        assert_eq!(
            a.scratch,
            ScratchStats {
                hits: 10,
                grows: 3,
                bytes: 96,
                high_water_bytes: 112
            },
            "arena counters fold across actors"
        );
        assert_eq!(a.latency.len(), 3);
        // 27us total in gemm_128x128x128 vs 5us in gemm_64x64x64.
        assert_eq!(
            a.hot_shape_classes(1),
            vec!["gemm_128x128x128".to_string()]
        );
        assert_eq!(a.hot_shape_classes(8).len(), 2);
    }

    #[test]
    fn run_traffic_is_recorded_per_shape_class() {
        use crate::util::tmp::TempDir;
        let dir = TempDir::new("sched-latency").unwrap();
        std::fs::write(
            dir.path().join("manifest.json"),
            r#"{"version": 1, "artifacts": [{
                "name": "g4", "kind": "gemm", "impl": "pallas",
                "file": "g4.hlo.txt", "flops": 128,
                "m": 4, "n": 4, "k": 4,
                "inputs": [{"shape": [4, 4], "dtype": "float32"},
                           {"shape": [4, 4], "dtype": "float32"}],
                "groups": ["gemm"]}]}"#,
        )
        .unwrap();
        let (handle, join) = EngineHandle::spawn(dir.path()).unwrap();
        let inputs = handle.synth_inputs("g4", 7).unwrap();
        handle.run("g4", inputs).unwrap();
        let stats = handle.stats().unwrap();
        assert_eq!(stats.runs, 1);
        let lat = stats
            .latency
            .get("g4::gemm_64x64x64")
            .expect("run recorded under its shape class");
        assert_eq!(lat.count, 1);
        assert_eq!(
            stats.hot_shape_classes(4),
            vec!["gemm_64x64x64".to_string()]
        );
        // The native backend routes kernel scratch through its arena;
        // the stats snapshot must surface those counters.
        assert!(
            stats.scratch.high_water_bytes > 0,
            "arena counters surface through the stats snapshot: {:?}",
            stats.scratch
        );
        handle.shutdown();
        join.join().unwrap();
    }
}
