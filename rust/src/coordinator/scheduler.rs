//! Engine actor: a dedicated thread owns the execution backend; callers
//! talk to it through channels.  Backends are `&mut self` and (for PJRT)
//! hold non-`Sync` types, so the actor keeps them on one thread while any
//! number of coordinator threads submit work.
//!
//! The actor is generic over [`Backend`]: [`EngineHandle::spawn`] uses the
//! build's [`DefaultEngine`] (native offline, PJRT under `--features
//! pjrt`), and [`EngineHandle::spawn_with`] accepts any backend
//! constructor — construction happens *on the actor thread*, so backends
//! whose internals are not `Send` still work.
//!
//! The request/serve plumbing ([`Request`], [`serve_request`]) is shared
//! with the multi-actor [`EnginePool`](super::EnginePool): one actor is
//! the degenerate pool, and both speak the same protocol.
//!
//! (The usual tokio runtime is unavailable in this offline build; the
//! actor is pure `std::thread` + `mpsc`, which also keeps the request
//! path allocation-free apart from the payload itself.)

use std::path::Path;
use std::sync::mpsc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::error::{Error, Result};
use crate::runtime::{ArtifactStore, Backend, DefaultEngine, RunOutput};

/// One message to an engine actor.  Every variant that expects an answer
/// carries its own one-shot reply channel, so any number of clients can
/// have requests in flight against the same actor.
pub(crate) enum Request {
    /// Execute an artifact once.
    Run {
        name: String,
        inputs: Vec<Vec<f32>>,
        reply: mpsc::Sender<Result<RunOutput>>,
    },
    /// Execute an artifact `iters` times, best (min) time reported.
    RunTimed {
        name: String,
        inputs: Vec<Vec<f32>>,
        iters: usize,
        reply: mpsc::Sender<Result<(RunOutput, Duration)>>,
    },
    /// Pre-compile (or pre-plan) an artifact.
    Warm {
        name: String,
        reply: mpsc::Sender<Result<()>>,
    },
    /// Deterministic synthetic inputs for an artifact.
    SynthInputs {
        name: String,
        seed: u64,
        reply: mpsc::Sender<Result<Vec<Vec<f32>>>>,
    },
    /// List every artifact name in the actor's store (manifest order).
    /// Used by the pool's warm fan-out to enumerate what to pre-warm
    /// without opening the manifest a second time.
    Artifacts {
        reply: mpsc::Sender<Vec<String>>,
    },
    /// Snapshot the actor's statistics.
    Stats {
        reply: mpsc::Sender<EngineStats>,
    },
    /// Ask the actor to exit its serve loop.
    Shutdown,
}

/// Serve one request against a backend, updating `stats`.  Returns
/// `false` when the request asks the serve loop to stop.
///
/// This is the single place requests are interpreted: the
/// [`EngineHandle`] actor and every [`EnginePool`](super::EnginePool)
/// actor run exactly this function, so the two serving shapes cannot
/// drift apart.
pub(crate) fn serve_request<B: Backend>(
    engine: &mut B,
    stats: &mut EngineStats,
    req: Request,
) -> bool {
    match req {
        Request::Run { name, inputs, reply } => {
            let out = engine.run(&name, &inputs);
            if let Ok(o) = &out {
                stats.runs += 1;
                stats.device_time += o.elapsed;
            }
            stats.cached_executables = engine.cached();
            let _ = reply.send(out);
            true
        }
        Request::RunTimed { name, inputs, iters, reply } => {
            let out = engine.run_timed(&name, &inputs, iters);
            if let Ok((o, _)) = &out {
                stats.runs += iters as u64;
                stats.device_time += o.elapsed * iters as u32;
            }
            stats.cached_executables = engine.cached();
            let _ = reply.send(out);
            true
        }
        Request::Warm { name, reply } => {
            let r = engine.warm(&name);
            stats.cached_executables = engine.cached();
            let _ = reply.send(r);
            true
        }
        Request::SynthInputs { name, seed, reply } => {
            let _ = reply.send(engine.synth_inputs(&name, seed));
            true
        }
        Request::Artifacts { reply } => {
            let names =
                engine.store().iter().map(|m| m.name.clone()).collect();
            let _ = reply.send(names);
            true
        }
        Request::Stats { reply } => {
            let _ = reply.send(stats.clone());
            true
        }
        Request::Shutdown => false,
    }
}

/// Coordinator-visible engine statistics.
#[derive(Debug, Clone, Default)]
pub struct EngineStats {
    /// Executions completed.
    pub runs: u64,
    /// Compiled/planned artifacts resident in the cache.
    pub cached_executables: usize,
    /// Total device execution time.
    pub device_time: Duration,
}

/// Cloneable handle to a single engine actor.
///
/// The handle is the one-actor serving shape: every request funnels
/// through one backend thread.  For multi-actor serving with routing and
/// backpressure, see [`EnginePool`](super::EnginePool) — both implement
/// [`EngineClient`](super::EngineClient), so callers like
/// [`NetworkRunner`](super::NetworkRunner) work against either.
///
/// # Examples
///
/// ```
/// use portable_kernels::coordinator::EngineHandle;
/// use portable_kernels::util::tmp::TempDir;
///
/// // A synthetic manifest: the native backend plans from metadata and
/// // never opens the HLO file.
/// let dir = TempDir::new("doc-engine").unwrap();
/// std::fs::write(
///     dir.path().join("manifest.json"),
///     r#"{"version": 1, "artifacts": [{
///         "name": "g4", "kind": "gemm", "impl": "pallas",
///         "file": "g4.hlo.txt", "flops": 128, "m": 4, "n": 4, "k": 4,
///         "inputs": [{"shape": [4, 4], "dtype": "float32"},
///                    {"shape": [4, 4], "dtype": "float32"}],
///         "groups": ["gemm"]}]}"#,
/// )
/// .unwrap();
///
/// let (handle, join) = EngineHandle::spawn(dir.path()).unwrap();
/// let inputs = handle.synth_inputs("g4", 7).unwrap();
/// let out = handle.run("g4", inputs).unwrap();
/// assert_eq!(out.outputs[0].len(), 16);
/// handle.shutdown();
/// join.join().unwrap();
/// ```
#[derive(Clone)]
pub struct EngineHandle {
    tx: mpsc::Sender<Request>,
}

impl EngineHandle {
    /// Spawn the actor over the artifact directory with the build's
    /// default backend.  Returns the handle and the join handle of the
    /// actor thread.
    pub fn spawn(artifact_dir: &Path) -> Result<(Self, JoinHandle<()>)> {
        let store = ArtifactStore::open(artifact_dir)?;
        Self::spawn_with(move || DefaultEngine::new(store))
    }

    /// Spawn the actor with an explicit backend constructor.  The
    /// constructor runs on the actor thread (PJRT clients never cross
    /// threads).
    ///
    /// Spawn failure is always a loud, synchronous `Err`: an OS-level
    /// thread-spawn failure, a constructor that returns `Err`, and a
    /// constructor that panics all surface here — never as a handle
    /// whose requests silently hang.
    pub fn spawn_with<B, F>(make: F) -> Result<(Self, JoinHandle<()>)>
    where
        B: Backend + 'static,
        F: FnOnce() -> Result<B> + Send + 'static,
    {
        let (tx, rx) = mpsc::channel::<Request>();
        let (init_tx, init_rx) = mpsc::channel::<Result<()>>();
        let join = std::thread::Builder::new()
            .name("engine".into())
            .spawn(move || {
                let mut engine = match make() {
                    Ok(e) => {
                        let _ = init_tx.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = init_tx.send(Err(e));
                        return;
                    }
                };
                let mut stats = EngineStats::default();
                while let Ok(req) = rx.recv() {
                    if !serve_request(&mut engine, &mut stats, req) {
                        break;
                    }
                }
            })
            .map_err(|e| {
                Error::Runtime(format!("cannot spawn engine thread: {e}"))
            })?;
        init_rx
            .recv()
            .map_err(|_| Error::Runtime("engine thread died during init".into()))??;
        Ok((Self { tx }, join))
    }

    fn send(&self, req: Request) -> Result<()> {
        self.tx
            .send(req)
            .map_err(|_| Error::Runtime("engine actor gone".into()))
    }

    fn ask<T>(
        &self,
        make: impl FnOnce(mpsc::Sender<T>) -> Request,
    ) -> Result<T> {
        let (reply, rx) = mpsc::channel();
        self.send(make(reply))?;
        rx.recv()
            .map_err(|_| Error::Runtime("engine dropped request".into()))
    }

    /// Execute an artifact.
    pub fn run(&self, name: &str, inputs: Vec<Vec<f32>>) -> Result<RunOutput> {
        self.ask(|reply| Request::Run { name: name.into(), inputs, reply })?
    }

    /// Execute an artifact `iters` times, per-run setup hoisted by the
    /// backend; returns the last output with the best (min) time.
    pub fn run_timed(
        &self,
        name: &str,
        inputs: Vec<Vec<f32>>,
        iters: usize,
    ) -> Result<(RunOutput, Duration)> {
        self.ask(|reply| Request::RunTimed {
            name: name.into(),
            inputs,
            iters,
            reply,
        })?
    }

    /// Pre-compile (or pre-plan) an artifact.
    pub fn warm(&self, name: &str) -> Result<()> {
        self.ask(|reply| Request::Warm { name: name.into(), reply })?
    }

    /// Deterministic synthetic inputs for an artifact.
    pub fn synth_inputs(&self, name: &str, seed: u64) -> Result<Vec<Vec<f32>>> {
        self.ask(|reply| Request::SynthInputs { name: name.into(), seed, reply })?
    }

    /// Engine statistics snapshot.
    pub fn stats(&self) -> Result<EngineStats> {
        self.ask(|reply| Request::Stats { reply })
    }

    /// Ask the actor to exit (idempotent; pending requests drain first).
    pub fn shutdown(&self) {
        let _ = self.tx.send(Request::Shutdown);
    }
}

impl super::EngineClient for EngineHandle {
    fn run(&self, name: &str, inputs: Vec<Vec<f32>>) -> Result<RunOutput> {
        EngineHandle::run(self, name, inputs)
    }

    fn run_timed(
        &self,
        name: &str,
        inputs: Vec<Vec<f32>>,
        iters: usize,
    ) -> Result<(RunOutput, Duration)> {
        EngineHandle::run_timed(self, name, inputs, iters)
    }

    fn warm(&self, name: &str) -> Result<()> {
        EngineHandle::warm(self, name)
    }

    fn synth_inputs(&self, name: &str, seed: u64) -> Result<Vec<Vec<f32>>> {
        EngineHandle::synth_inputs(self, name, seed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructor_error_is_a_loud_err_not_a_hang() {
        let err = EngineHandle::spawn_with(|| -> Result<DefaultEngine> {
            Err(Error::Runtime("backend exploded during construction".into()))
        })
        .err()
        .expect("constructor failure must surface as Err");
        assert!(err.to_string().contains("exploded"), "got: {err}");
    }

    #[test]
    fn constructor_panic_is_a_loud_err_not_a_hang() {
        let err = EngineHandle::spawn_with(|| -> Result<DefaultEngine> {
            panic!("constructor panicked");
        })
        .err()
        .expect("constructor panic must surface as Err");
        assert!(err.to_string().contains("died during init"), "got: {err}");
    }
}
