//! Layer-3 coordinator: the Rust-owned serving layer around the
//! execution backend.
//!
//! The paper's contribution lives at the kernel layer, so the coordinator
//! is the thin-but-real serving scaffold a library like SYCL-DNN needs in
//! deployment (see `docs/ARCHITECTURE.md` for the end-to-end narrative):
//!
//! * [`EngineHandle`] — an actor thread owning any (`&mut self`, possibly
//!   non-`Sync`) [`Backend`]; all execution funnels through it, so the
//!   request path is channel-send + hash-lookup + execute.
//! * [`EnginePool`] — the scale-out shape: N backend actors behind a
//!   consistent-hash router with bounded queues, explicit backpressure
//!   ([`EnginePool::try_submit_run`] returns [`SubmitError::Busy`]),
//!   least-loaded spill (warm-on-first-spill, counted by
//!   [`EnginePool::spilled`]), panic containment, and epoch-swappable
//!   tuning ([`EnginePool::swap_tuning`] broadcasts a
//!   [`TuningSnapshot`](crate::tuner::TuningSnapshot) so an online
//!   re-tune lands without a restart).  Per-`(artifact, shape-class)`
//!   serving latency ([`LatencyStats`]) folds into [`EngineStats`] and
//!   feeds the re-tuner's hot-class ranking.
//! * [`Batcher`] — groups same-artifact requests to amortize dispatch;
//!   flushing a group through a pool keeps it on one actor's warm cache.
//! * [`NetworkRunner`] — runs a whole VGG/ResNet convolution stack
//!   through any [`EngineClient`], selecting each layer's artifact per
//!   the tuned selection DB.
//!
//! [`Backend`]: crate::runtime::Backend

mod batcher;
mod network;
mod pool;
mod scheduler;

use std::time::Duration;

use crate::error::Result;
use crate::runtime::RunOutput;

pub use batcher::{BatchPolicy, Batcher, FlushedGroup};
pub use network::{
    available_layers, layer_artifact_name, LayerRun, NetworkReport,
    NetworkRunner,
};
pub use pool::{EnginePool, PoolConfig, RunTicket, SubmitError};
pub use scheduler::{EngineHandle, EngineStats, LatencyStats, LATENCY_BUCKETS};

/// Client-side surface shared by the one-actor [`EngineHandle`] and the
/// multi-actor [`EnginePool`]: everything above the coordinator (the
/// network runner, the batcher, benches, load generators) is written
/// against this trait, so the serving shape — like the backend — is a
/// deployment decision, not an architectural one.
pub trait EngineClient {
    /// Execute an artifact with flattened f32 inputs.
    fn run(&self, name: &str, inputs: Vec<Vec<f32>>) -> Result<RunOutput>;

    /// Execute an artifact `iters` times; returns the last output with
    /// the best (minimum) execution time.
    fn run_timed(
        &self,
        name: &str,
        inputs: Vec<Vec<f32>>,
        iters: usize,
    ) -> Result<(RunOutput, Duration)>;

    /// Pre-compile (or pre-plan) an artifact, filling the owning
    /// engine's cache.
    fn warm(&self, name: &str) -> Result<()>;

    /// Deterministic synthetic inputs for an artifact.
    fn synth_inputs(&self, name: &str, seed: u64) -> Result<Vec<Vec<f32>>>;
}

impl<C: EngineClient> EngineClient for &C {
    fn run(&self, name: &str, inputs: Vec<Vec<f32>>) -> Result<RunOutput> {
        (**self).run(name, inputs)
    }

    fn run_timed(
        &self,
        name: &str,
        inputs: Vec<Vec<f32>>,
        iters: usize,
    ) -> Result<(RunOutput, Duration)> {
        (**self).run_timed(name, inputs, iters)
    }

    fn warm(&self, name: &str) -> Result<()> {
        (**self).warm(name)
    }

    fn synth_inputs(&self, name: &str, seed: u64) -> Result<Vec<Vec<f32>>> {
        (**self).synth_inputs(name, seed)
    }
}
