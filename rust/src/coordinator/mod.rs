//! Layer-3 coordinator: the Rust-owned event loop around the PJRT engine.
//!
//! The paper's contribution lives at the kernel layer, so the coordinator
//! is the thin-but-real serving scaffold a library like SYCL-DNN needs in
//! deployment:
//!
//! * [`scheduler`] — an actor thread owning the (non-`Sync`) [`Engine`],
//!   with an async handle for tokio callers; all execution funnels
//!   through it, so the request path is channel-send + hash-lookup +
//!   execute.
//! * [`batcher`] — groups same-artifact requests to amortize dispatch.
//! * [`network`] — runs a whole VGG/ResNet convolution stack through the
//!   engine, selecting each layer's artifact per the tuned selection DB.
//!
//! [`Engine`]: crate::runtime::Engine

mod batcher;
mod network;
mod scheduler;

pub use batcher::{BatchPolicy, Batcher};
pub use network::{LayerRun, NetworkReport, NetworkRunner};
pub use scheduler::{EngineHandle, EngineStats};
