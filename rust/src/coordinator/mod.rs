//! Layer-3 coordinator: the Rust-owned event loop around the execution
//! backend.
//!
//! The paper's contribution lives at the kernel layer, so the coordinator
//! is the thin-but-real serving scaffold a library like SYCL-DNN needs in
//! deployment:
//!
//! * [`scheduler`] — an actor thread owning any (`&mut self`, possibly
//!   non-`Sync`) [`Backend`]; all execution funnels through it, so the
//!   request path is channel-send + hash-lookup + execute.
//! * [`batcher`] — groups same-artifact requests to amortize dispatch.
//! * [`network`] — runs a whole VGG/ResNet convolution stack through the
//!   engine, selecting each layer's artifact per the tuned selection DB.
//!
//! [`Backend`]: crate::runtime::Backend

mod batcher;
mod network;
mod scheduler;

pub use batcher::{BatchPolicy, Batcher};
pub use network::{LayerRun, NetworkReport, NetworkRunner};
pub use scheduler::{EngineHandle, EngineStats};
