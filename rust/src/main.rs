//! `repro` — the portable-kernels coordinator CLI.
//!
//! Subcommands mirror the paper's workflow: inspect the device zoo, tune
//! kernels per device, regenerate the evaluation figures, and run the
//! measured network benchmarks through the execution backend (the native
//! reference engine by default; PJRT under `--features pjrt`).
//!
//! (Arg parsing and error plumbing are hand-rolled: the offline build
//! environment has no clap/anyhow.)

use std::collections::HashMap;
use std::path::PathBuf;
use std::process::ExitCode;

use portable_kernels::coordinator::{
    EngineHandle, EnginePool, NetworkRunner, PoolConfig,
};
use portable_kernels::device::{all_devices, device_by_name};
use portable_kernels::harness::{
    fig_conv, fig_gemm, fig_network, fig_registers, tables, Report,
};
use portable_kernels::perfmodel::GemmProblem;
use portable_kernels::runtime::{ArtifactStore, DefaultEngine};
use portable_kernels::tuner::{
    tune_conv, tune_gemm, ExhaustiveSearch, GuidedSearch, HillClimb,
    RandomSearch, SearchStrategy, SelectionDb, SelectionKey,
};

/// CLI-level error: any library error or an ad-hoc message.
type CliError = Box<dyn std::error::Error>;
type CliResult<T> = std::result::Result<T, CliError>;

/// Build an ad-hoc CLI error from a message.
fn cli(msg: String) -> CliError {
    msg.into()
}

const USAGE: &str = "\
repro — cross-platform performance portability via parametrized kernels
        (reproduction of Lawson et al., 2019)

USAGE: repro [--artifacts DIR] [--reports DIR] <command> [options]

COMMANDS:
  devices                      list the modeled device zoo (paper Table 1)
  figures [--id ID] [--csv]    regenerate a paper table/figure:
                               t1 t2 t3 t4 f2 f3 f4a f4b f4c f5 f6 f7 f8 f9 | all
  tune --device ID [--gemm MxNxK]... [--networks]
       [--strategy exhaustive|random|hillclimb|guided] [--db PATH]
                               tune kernels for a device, write selection DB
  network [--network vgg|resnet] [--impl xla|pallas] [--iters N]
          [--pool N] [--queue-depth D]
                               run a conv stack through the backend (measured);
                               --pool N > 1 serves it from an N-actor engine
                               pool with per-artifact routing
  run NAME [--iters N]         execute one artifact, report GFLOP/s
  tune-measured [--group gemm|conv] [--iters N]
                               measurement-driven tuning: execute every
                               artifact in the group, report winners
  artifacts                    list artifacts in the manifest
";

/// Tiny argv parser: flags (`--x val` / `--x`) + positionals.
struct Args {
    flags: HashMap<String, Vec<String>>,
    positional: Vec<String>,
}

/// Flags that never take a value.
const BOOL_FLAGS: &[&str] = &["csv", "networks", "help"];

impl Args {
    fn parse(argv: &[String]) -> Self {
        let mut flags: HashMap<String, Vec<String>> = HashMap::new();
        let mut positional = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    flags.entry(k.to_string()).or_default().push(v.to_string());
                } else if !BOOL_FLAGS.contains(&name)
                    && i + 1 < argv.len()
                    && !argv[i + 1].starts_with("--")
                {
                    flags
                        .entry(name.to_string())
                        .or_default()
                        .push(argv[i + 1].clone());
                    i += 1;
                } else {
                    flags.entry(name.to_string()).or_default().push(String::new());
                }
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        Self { flags, positional }
    }

    fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).and_then(|v| v.last()).map(|s| s.as_str())
    }

    fn get_all(&self, name: &str) -> Vec<String> {
        self.flags.get(name).cloned().unwrap_or_default()
    }

    fn has(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }

    fn usize_or(&self, name: &str, default: usize) -> CliResult<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| {
                cli(format!("--{name} wants a number, got {v:?}"))
            }),
        }
    }
}

fn strategy_by_name(name: &str) -> CliResult<Box<dyn SearchStrategy>> {
    match name {
        "exhaustive" => Ok(Box::new(ExhaustiveSearch)),
        "random" => Ok(Box::new(RandomSearch { samples: 64, seed: 42 })),
        "hillclimb" => Ok(Box::new(HillClimb { restarts: 8, seed: 42 })),
        "guided" => Ok(Box::new(GuidedSearch { budget: 8 })),
        other => Err(cli(format!("unknown strategy {other:?}"))),
    }
}

fn emit(report: &Report, reports_dir: &PathBuf, csv: bool) -> CliResult<()> {
    println!("{}", report.render());
    if csv {
        let slug: String = report
            .title
            .chars()
            .take_while(|c| *c != ':')
            .filter(|c| c.is_alphanumeric())
            .collect::<String>()
            .to_lowercase();
        let path = reports_dir.join(format!("{slug}.csv"));
        report.save_csv(&path)?;
        eprintln!("wrote {}", path.display());
    }
    Ok(())
}

fn cmd_figures(id: &str, reports: &PathBuf, csv: bool) -> CliResult<()> {
    let all = id == "all";
    let want = |x: &str| all || id == x;
    let mut matched = all;
    if want("t1") {
        emit(&tables::table1(), reports, csv)?;
        matched = true;
    }
    if want("t2") {
        emit(&tables::table2(), reports, csv)?;
        matched = true;
    }
    if want("t3") {
        emit(&tables::table3(), reports, csv)?;
        matched = true;
    }
    if want("t4") {
        emit(&tables::table4(), reports, csv)?;
        matched = true;
    }
    if want("f2") {
        emit(&fig_registers::fig2(), reports, csv)?;
        matched = true;
    }
    if want("f3") {
        emit(&fig_conv::fig3(), reports, csv)?;
        matched = true;
    }
    if want("f4a") {
        emit(&fig_gemm::fig4a(), reports, csv)?;
        println!("{}", fig_gemm::roofline_plot("uhd630")?);
        matched = true;
    }
    if want("f4b") {
        emit(&fig_gemm::fig4b(), reports, csv)?;
        matched = true;
    }
    if want("f4c") {
        emit(&fig_gemm::fig4c(), reports, csv)?;
        matched = true;
    }
    if want("f5") {
        emit(&fig_gemm::fig5a(), reports, csv)?;
        println!("{}", fig_gemm::roofline_plot("mali-g71")?);
        emit(&fig_gemm::fig5_regions(), reports, csv)?;
        matched = true;
    }
    for (fid, net, bed) in [
        ("f6", "resnet", "hikey960"),
        ("f7", "resnet", "i7-6700k"),
        ("f8", "vgg", "hikey960"),
        ("f9", "vgg", "i7-6700k"),
    ] {
        if want(fid) {
            emit(&fig_network::fig_network(net, bed)?, reports, csv)?;
            matched = true;
        }
    }
    if !matched {
        return Err(cli(format!("unknown figure id {id:?} (see --help)")));
    }
    Ok(())
}

fn cmd_tune(args: &Args) -> CliResult<()> {
    let device = args
        .get("device")
        .ok_or_else(|| cli("tune needs --device (see `repro devices`)".into()))?;
    let dev = device_by_name(device)?;
    let strat = strategy_by_name(args.get("strategy").unwrap_or("exhaustive"))?;
    let db_path =
        PathBuf::from(args.get("db").unwrap_or("reports/selections.json"));
    let mut db = if db_path.exists() {
        SelectionDb::load(&db_path)?
    } else {
        SelectionDb::new()
    };

    for g in args.get_all("gemm") {
        let dims: Vec<u64> = g
            .split('x')
            .map(|s| {
                s.parse()
                    .map_err(|_| cli(format!("bad gemm spec {g:?}")))
            })
            .collect::<CliResult<_>>()?;
        let (m, n, k) = match dims[..] {
            [m, n, k] => (m, n, k),
            _ => {
                return Err(cli(format!("gemm spec must be MxNxK, got {g:?}")))
            }
        };
        let r = tune_gemm(&dev, GemmProblem::new(m, n, k), strat.as_ref())
            .ok_or_else(|| {
                cli(format!("no feasible gemm config on {device}"))
            })?;
        println!(
            "gemm {m}x{n}x{k} on {device}: {} @ {:.1} GF ({} evals, {} infeasible)",
            r.config.name(),
            r.gflops,
            r.evaluated,
            r.infeasible
        );
        db.put(SelectionKey::gemm(device, m, n, k), r.config, r.gflops);
    }

    if args.has("networks") {
        for net in ["vgg", "resnet"] {
            for layer in portable_kernels::nn::network_layers(net)? {
                let batch = 1;
                let r = tune_conv(&dev, &layer, batch, strat.as_ref())
                    .ok_or_else(|| cli("no feasible conv config".into()))?;
                println!(
                    "{net}/{}: {} @ {:.1} GF",
                    layer.name,
                    r.config.name(),
                    r.gflops
                );
                db.put(
                    SelectionKey::conv(
                        device,
                        layer.window,
                        layer.stride,
                        layer.in_h,
                        layer.in_w,
                        layer.in_c,
                        layer.out_c,
                        batch,
                    ),
                    r.config,
                    r.gflops,
                );
            }
        }
    }
    if let Some(parent) = db_path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    db.save(&db_path)?;
    println!("selection DB ({} entries) -> {}", db.len(), db_path.display());
    Ok(())
}

fn cmd_network(artifacts: &PathBuf, args: &Args) -> CliResult<()> {
    let net = args.get("network").unwrap_or("resnet").to_string();
    let implementation = args.get("impl").unwrap_or("xla").to_string();
    let iters = args.usize_or("iters", 3)?;
    let pool_size = args.usize_or("pool", 1)?;

    let store = ArtifactStore::open(artifacts)?;
    let mut pool_note = None;
    let report = if pool_size > 1 {
        let queue_depth = args.usize_or("queue-depth", 32)?;
        let config = PoolConfig {
            actors: pool_size,
            queue_depth,
            spill_depth: (queue_depth / 2).max(1),
            ..Default::default()
        };
        let pool = EnginePool::spawn(artifacts, config)?;
        let runner = NetworkRunner::new(&pool);
        let report = runner.run_network(&store, &net, &implementation, iters)?;
        let per_actor: Vec<String> = (0..pool.actors())
            .map(|i| {
                pool.actor_stats(i)
                    .map(|s| format!("actor {i}: {} runs", s.runs))
                    .unwrap_or_else(|_| format!("actor {i}: dead"))
            })
            .collect();
        pool_note = Some(format!(
            "pool: {} actors ({})",
            pool.actors(),
            per_actor.join(", ")
        ));
        pool.shutdown();
        report
    } else {
        let (handle, join) = EngineHandle::spawn(artifacts)?;
        let runner = NetworkRunner::new(handle.clone());
        let report = runner.run_network(&store, &net, &implementation, iters)?;
        handle.shutdown();
        let _ = join.join();
        report
    };
    let mut table = Report::new(
        &format!("{net} via {implementation} (measured)"),
        &["layer", "GFLOP", "time (ms)", "gflops", "scaled"],
    );
    for l in &report.layers {
        table.row(vec![
            l.layer.clone(),
            format!("{:.3}", l.flops as f64 / 1e9),
            format!("{:.2}", l.elapsed_s * 1e3),
            format!("{:.2}", l.gflops),
            l.scaled_from.clone().unwrap_or_default(),
        ]);
    }
    table.note(format!(
        "total: {:.1} ms, {:.2} GFLOP/s over {} layers",
        report.total_time_s * 1e3,
        report.total_gflops(),
        report.layers.len()
    ));
    if let Some(note) = pool_note {
        table.note(note);
    }
    println!("{}", table.render());
    Ok(())
}

fn cmd_run(artifacts: &PathBuf, args: &Args) -> CliResult<()> {
    let name = args
        .positional
        .get(1)
        .ok_or_else(|| cli("run needs an artifact name".into()))?
        .clone();
    let iters = args.usize_or("iters", 5)?;
    let store = ArtifactStore::open(artifacts)?;
    let meta = store.get(&name)?.clone();
    let (handle, join) = EngineHandle::spawn(artifacts)?;
    let inputs = handle.synth_inputs(&name, 7)?;
    handle.warm(&name)?;
    let (out, best) = handle.run_timed(&name, inputs, iters)?;
    println!(
        "{name}: {:.3} ms best of {iters}, {:.2} GFLOP/s ({} flops)",
        best.as_secs_f64() * 1e3,
        out.gflops(meta.flops),
        meta.flops
    );
    handle.shutdown();
    let _ = join.join();
    Ok(())
}

fn real_main() -> CliResult<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv);
    if args.has("help") || args.positional.is_empty() {
        print!("{USAGE}");
        return Ok(());
    }
    let artifacts = PathBuf::from(args.get("artifacts").unwrap_or("artifacts"));
    let reports = PathBuf::from(args.get("reports").unwrap_or("reports"));

    match args.positional[0].as_str() {
        "devices" => {
            for d in all_devices() {
                println!("{:>14}  {d}", d.id);
            }
            Ok(())
        }
        "figures" => {
            cmd_figures(args.get("id").unwrap_or("all"), &reports, args.has("csv"))
        }
        "tune" => cmd_tune(&args),
        "network" => cmd_network(&artifacts, &args),
        "run" => cmd_run(&artifacts, &args),
        "tune-measured" => {
            let group = args.get("group").unwrap_or("gemm").to_string();
            let iters = args.usize_or("iters", 3)?;
            let store = ArtifactStore::open(&artifacts)?;
            let mut engine = DefaultEngine::new(store)?;
            let tuning = portable_kernels::tuner::tune_measured(
                &mut engine, &group, iters)?;
            let mut table = Report::new(
                &format!("measured winners, group {group:?} (best of {iters})"),
                &["problem", "winner", "config", "ms", "GF/s"],
            );
            for problem in tuning.problems().cloned().collect::<Vec<_>>() {
                let w = tuning.winner(&problem).expect("non-empty");
                table.row(vec![
                    problem.clone(),
                    w.artifact.clone(),
                    w.config.clone().unwrap_or_else(|| w.implementation.clone()),
                    format!("{:.3}", w.best.as_secs_f64() * 1e3),
                    format!("{:.2}", w.gflops),
                ]);
            }
            println!("{}", table.render());
            Ok(())
        }
        "artifacts" => {
            let store = ArtifactStore::open(&artifacts)?;
            for m in store.iter() {
                println!(
                    "{:>40}  {:5}  {:6}  {:.3} GFLOP",
                    m.name,
                    m.kind,
                    m.implementation,
                    m.flops as f64 / 1e9
                );
            }
            Ok(())
        }
        other => Err(cli(format!("unknown command {other:?}\n{USAGE}"))),
    }
}

fn main() -> ExitCode {
    match real_main() {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}
