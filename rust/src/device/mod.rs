//! Device specifications — the paper's Table 1, extended with the
//! microarchitectural parameters the performance model needs.

mod presets;
mod spec;

pub use presets::{all_devices, device_by_name, host_cpu};
pub use spec::{DeviceClass, DeviceSpec};
