//! Device specification type.


/// Broad device class; drives the coalescing/vectorization assumptions of
/// the performance model (paper §2.2.4: SIMD GPUs favour coalesced access,
/// CPUs favour blocked access).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DeviceClass {
    /// Multi-core CPU (cache hierarchy, wide SIMD units, few threads).
    Cpu,
    /// SIMT GPU with programmer-managed local memory.
    Gpu,
    /// Embedded accelerator (few compute units, large scratchpad).
    Accelerator,
}

/// One compute device — the paper's Table-1 rows plus the
/// microarchitectural parameters needed to model §2.2's four performance
/// metrics (thread reuse, memory transactions, data reuse, vectorization).
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceSpec {
    /// Marketing name, e.g. "ARM Mali G71 GPU".
    pub name: String,
    /// Short identifier used on the CLI, e.g. "mali-g71".
    pub id: String,
    /// Broad device class (CPU / GPU / accelerator).
    pub class: DeviceClass,

    // ---- Table 1 columns ----
    /// Cache-line size in bytes (64 or 128 in the paper's zoo).
    pub cache_line_bytes: u32,
    /// Programmer-managed local memory per compute unit, bytes (0 = none;
    /// Mali G-71 and the CPU rely on the cache instead).
    pub local_mem_bytes: u32,
    /// Number of compute units.
    pub compute_units: u32,

    // ---- extended parameters ----
    /// Register file per compute unit, in f32 registers.
    pub reg_file_per_cu: u32,
    /// Architectural per-thread register budget before spilling.
    pub max_regs_per_thread: u32,
    /// Maximum resident threads per compute unit.
    pub max_threads_per_cu: u32,
    /// Maximum work-group size the device can launch.
    pub max_wg_size: u32,
    /// Resident threads per CU needed to fully hide memory latency.
    pub latency_hiding_threads: u32,
    /// Native vector width for loads/stores, in f32 elements
    /// (paper §2.2.4: many GPUs have 4-element load/store units).
    pub native_vector_width: u32,
    /// Whether the ALUs execute vector math (vs scalar ALUs + ILP).
    pub has_vector_math: bool,
    /// Peak f32 throughput, GFLOP/s.
    pub peak_gflops: f64,
    /// Peak global-memory bandwidth, GB/s.
    pub mem_bw_gbps: f64,
    /// Local-memory bandwidth advantage over the (global) cache path.
    /// >1 means explicit local memory is faster than relying on cache;
    /// Mali-like devices with no local memory use 1.0.
    pub local_mem_speedup: f64,
}

impl DeviceSpec {
    /// Cache-line size in f32 elements — the paper's `X`.
    pub fn cache_line_elems(&self) -> u32 {
        self.cache_line_bytes / 4
    }

    /// Machine balance: flops per byte at the roofline ridge point.
    pub fn ridge_intensity(&self) -> f64 {
        self.peak_gflops / self.mem_bw_gbps
    }

    /// Roofline-attainable GFLOP/s at a given operational intensity
    /// (flop/byte) — paper §5.2's comparison frame (Williams et al.).
    pub fn roofline_gflops(&self, intensity: f64) -> f64 {
        (self.mem_bw_gbps * intensity).min(self.peak_gflops)
    }

    /// Total resident threads across the device.
    pub fn max_threads(&self) -> u64 {
        self.max_threads_per_cu as u64 * self.compute_units as u64
    }
}

impl std::fmt::Display for DeviceSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} ({} CUs, {}B line, {} KiB local, {:.0} GF, {:.0} GB/s)",
            self.name,
            self.compute_units,
            self.cache_line_bytes,
            self.local_mem_bytes / 1024,
            self.peak_gflops,
            self.mem_bw_gbps
        )
    }
}

#[cfg(test)]
mod tests {
    use super::super::presets::all_devices;

    #[test]
    fn roofline_is_min_of_two_ceilings() {
        for d in all_devices() {
            let ridge = d.ridge_intensity();
            assert!(d.roofline_gflops(ridge * 0.5) < d.peak_gflops);
            assert!((d.roofline_gflops(ridge * 100.0) - d.peak_gflops).abs() < 1e-9);
            // Monotone in intensity.
            assert!(d.roofline_gflops(1.0) <= d.roofline_gflops(2.0));
        }
    }

    #[test]
    fn cache_line_elems() {
        for d in all_devices() {
            assert_eq!(d.cache_line_elems() * 4, d.cache_line_bytes);
        }
    }
}
