//! The paper's device zoo (Table 1) plus the benchmark hosts of §5.1.
//!
//! Table-1 columns (cache line, local memory, compute units) are taken
//! verbatim from the paper.  The extended microarchitectural parameters
//! (register files, peak flops, bandwidth) come from public vendor
//! documentation for each part; they feed the analytic model that stands
//! in for the hardware we do not have (see DESIGN.md §2, substitution 1).

use super::spec::{DeviceClass, DeviceSpec};
use crate::error::{Error, Result};

/// Intel Core i7-6700K CPU (Table 1 row 1; §5.1.2 benchmark host).
/// 4C/8T Skylake @ 4.0-4.2 GHz, AVX2: 32 f32 FLOP/cycle/core.
pub fn intel_i7_6700k_cpu() -> DeviceSpec {
    DeviceSpec {
        name: "Intel Core i7-6700K CPU".into(),
        id: "i7-6700k-cpu".into(),
        class: DeviceClass::Cpu,
        cache_line_bytes: 64,
        local_mem_bytes: 0,
        compute_units: 8, // paper counts hyperthreads
        reg_file_per_cu: 16 * 8, // 16 YMM x 8 f32 lanes
        max_regs_per_thread: 128,
        max_threads_per_cu: 1,
        max_wg_size: 1024, // CPU work-groups are loops
        latency_hiding_threads: 1,
        native_vector_width: 8, // AVX2
        has_vector_math: true,
        peak_gflops: 537.0, // 4 cores x 4.2 GHz x 32 flop/cy
        mem_bw_gbps: 34.1,  // 2ch DDR4-2133
        local_mem_speedup: 1.0,
    }
}

/// Intel HD Graphics 530 (i7-6700K iGPU, Table 1 row 2; §5.1.2).
/// Gen9 GT2: 24 EUs x 2 SIMD-4 FPUs @ 1.15 GHz.
pub fn intel_hd530_gpu() -> DeviceSpec {
    DeviceSpec {
        name: "Intel Core i7-6700K GPU (HD 530)".into(),
        id: "hd530".into(),
        class: DeviceClass::Gpu,
        cache_line_bytes: 64,
        local_mem_bytes: 64 * 1024,
        compute_units: 24,
        reg_file_per_cu: 28 * 1024 / 4, // 28 KiB GRF per EU
        max_regs_per_thread: 128,
        max_threads_per_cu: 112, // 7 HW threads x SIMD-16 work-items per EU
        max_wg_size: 256,
        latency_hiding_threads: 56,
        native_vector_width: 4,
        has_vector_math: true,
        peak_gflops: 441.6, // 24 EU x 16 flop/cy x 1.15 GHz
        mem_bw_gbps: 34.1,  // shared DDR4
        local_mem_speedup: 1.15,
    }
}

/// Intel UHD Graphics 630 (i7-9700K iGPU; §5.1.3, Fig. 4 device).
pub fn intel_uhd630_gpu() -> DeviceSpec {
    DeviceSpec {
        name: "Intel UHD Graphics 630".into(),
        id: "uhd630".into(),
        class: DeviceClass::Gpu,
        cache_line_bytes: 64,
        local_mem_bytes: 64 * 1024,
        compute_units: 24,
        reg_file_per_cu: 28 * 1024 / 4,
        max_regs_per_thread: 128,
        max_threads_per_cu: 112,
        max_wg_size: 256,
        latency_hiding_threads: 56,
        native_vector_width: 4,
        has_vector_math: true,
        peak_gflops: 460.8, // 24 EU x 16 flop/cy x 1.2 GHz
        mem_bw_gbps: 41.6,  // 2ch DDR4-2666
        local_mem_speedup: 1.15,
    }
}

/// ARM Mali G-71 MP8 (HiKey 960, Table 1 row 3; §5.1.1, Fig. 5 device).
/// No programmer local memory — it is emulated in the cache (paper §2.2.3).
pub fn arm_mali_g71() -> DeviceSpec {
    DeviceSpec {
        name: "ARM Mali G71 GPU".into(),
        id: "mali-g71".into(),
        class: DeviceClass::Gpu,
        cache_line_bytes: 64,
        local_mem_bytes: 0,
        compute_units: 8,
        reg_file_per_cu: 16 * 1024, // 64 KiB register file per core
        max_regs_per_thread: 64,
        max_threads_per_cu: 384,
        max_wg_size: 384,
        latency_hiding_threads: 128,
        native_vector_width: 4,
        has_vector_math: true,
        peak_gflops: 122.0, // MP8 @ ~870 MHz, 2x FMA SIMD-4 x 2 pipes
        mem_bw_gbps: 14.9,  // LPDDR4 on HiKey 960
        local_mem_speedup: 0.85, // using "local" memory on Mali hurts
    }
}

/// HiKey 960 big CPU cluster (4x Cortex-A73; §5.1.1 NEON baseline host).
pub fn hikey960_cpu() -> DeviceSpec {
    DeviceSpec {
        name: "HiKey 960 CPU (4x A73, NEON)".into(),
        id: "hikey960-cpu".into(),
        class: DeviceClass::Cpu,
        cache_line_bytes: 64,
        local_mem_bytes: 0,
        compute_units: 4,
        reg_file_per_cu: 32 * 4, // 32 NEON Q-regs x 4 lanes
        max_regs_per_thread: 128,
        max_threads_per_cu: 1,
        max_wg_size: 1024,
        latency_hiding_threads: 1,
        native_vector_width: 4, // NEON 128-bit
        has_vector_math: true,
        peak_gflops: 75.0, // 4 x 2.36 GHz x 8 flop/cy (2x FMA NEON)
        mem_bw_gbps: 14.9,
        local_mem_speedup: 1.0,
    }
}

/// Renesas V3M (Table 1 row 4): 2 CUs, huge scratchpad, tiny bandwidth.
pub fn renesas_v3m() -> DeviceSpec {
    DeviceSpec {
        name: "Renesas V3M".into(),
        id: "v3m".into(),
        class: DeviceClass::Accelerator,
        cache_line_bytes: 128,
        local_mem_bytes: 447 * 1024,
        compute_units: 2,
        reg_file_per_cu: 8 * 1024,
        max_regs_per_thread: 64,
        max_threads_per_cu: 64,
        max_wg_size: 256,
        latency_hiding_threads: 32,
        native_vector_width: 4,
        has_vector_math: true,
        peak_gflops: 32.0,
        mem_bw_gbps: 3.2,
        local_mem_speedup: 2.0, // scratchpad much faster than DRAM path
    }
}

/// Renesas V3H (Table 1 row 5).
pub fn renesas_v3h() -> DeviceSpec {
    DeviceSpec {
        name: "Renesas V3H".into(),
        id: "v3h".into(),
        class: DeviceClass::Accelerator,
        cache_line_bytes: 128,
        local_mem_bytes: 409 * 1024,
        compute_units: 5,
        reg_file_per_cu: 8 * 1024,
        max_regs_per_thread: 64,
        max_threads_per_cu: 64,
        max_wg_size: 256,
        latency_hiding_threads: 32,
        native_vector_width: 4,
        has_vector_math: true,
        peak_gflops: 76.8,
        mem_bw_gbps: 6.4,
        local_mem_speedup: 2.0,
    }
}

/// AMD R9 Nano (Table 1 row 6; Fig. 3 device).  Fiji: 64 CUs @ 1.0 GHz,
/// 8.19 TFLOP/s, 512 GB/s HBM, 256 KiB VGPR file per CU, 32 KiB LDS
/// usable per work-group (the paper's Table-1 figure).
pub fn amd_r9_nano() -> DeviceSpec {
    DeviceSpec {
        name: "AMD R9 Nano".into(),
        id: "r9-nano".into(),
        class: DeviceClass::Gpu,
        cache_line_bytes: 128,
        local_mem_bytes: 32 * 1024,
        compute_units: 64,
        reg_file_per_cu: 64 * 1024, // 256 KiB / 4 B
        max_regs_per_thread: 256,   // GCN VGPR budget
        max_threads_per_cu: 2560,   // 40 waves x 64 lanes
        max_wg_size: 1024,
        latency_hiding_threads: 640, // ~10 waves needed to hide HBM latency
        native_vector_width: 4,
        has_vector_math: false, // GCN is scalar-per-lane; vectors give ILP
        peak_gflops: 8192.0,
        mem_bw_gbps: 512.0,
        local_mem_speedup: 1.3,
    }
}

/// The host this reproduction actually measures on (PJRT CPU backend).
/// Peak/bandwidth are conservative figures for a modern x86 server core
/// set; the measured benches anchor the model on this device.
pub fn host_cpu() -> DeviceSpec {
    DeviceSpec {
        name: "Host CPU (PJRT)".into(),
        id: "host".into(),
        class: DeviceClass::Cpu,
        cache_line_bytes: 64,
        local_mem_bytes: 0,
        compute_units: std::thread::available_parallelism()
            .map(|n| n.get() as u32)
            .unwrap_or(8),
        reg_file_per_cu: 32 * 16,
        max_regs_per_thread: 512,
        max_threads_per_cu: 1,
        max_wg_size: 1024,
        latency_hiding_threads: 1,
        native_vector_width: 16, // AVX-512-class
        has_vector_math: true,
        peak_gflops: 2000.0,
        mem_bw_gbps: 80.0,
        local_mem_speedup: 1.0,
    }
}

/// Every modeled device, Table-1 rows first (in the paper's order).
pub fn all_devices() -> Vec<DeviceSpec> {
    vec![
        intel_i7_6700k_cpu(),
        intel_hd530_gpu(),
        arm_mali_g71(),
        renesas_v3m(),
        renesas_v3h(),
        amd_r9_nano(),
        intel_uhd630_gpu(),
        hikey960_cpu(),
        host_cpu(),
    ]
}

/// Look a device up by its CLI id (e.g. `mali-g71`).
pub fn device_by_name(id: &str) -> Result<DeviceSpec> {
    all_devices()
        .into_iter()
        .find(|d| d.id == id)
        .ok_or_else(|| {
            let ids: Vec<String> =
                all_devices().into_iter().map(|d| d.id).collect();
            Error::NotFound(format!(
                "device {id:?}; known devices: {}",
                ids.join(", ")
            ))
        })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Table 1, verbatim.
    #[test]
    fn table1_values() {
        let t = |d: DeviceSpec| (d.cache_line_bytes, d.local_mem_bytes / 1024, d.compute_units);
        assert_eq!(t(intel_i7_6700k_cpu()), (64, 0, 8));
        assert_eq!(t(intel_hd530_gpu()), (64, 64, 24));
        assert_eq!(t(arm_mali_g71()), (64, 0, 8));
        assert_eq!(t(renesas_v3m()), (128, 447, 2));
        assert_eq!(t(renesas_v3h()), (128, 409, 5));
        assert_eq!(t(amd_r9_nano()), (128, 32, 64));
    }

    #[test]
    fn lookup_by_id() {
        assert_eq!(device_by_name("mali-g71").unwrap().compute_units, 8);
        assert!(device_by_name("gtx-9090").is_err());
    }

    #[test]
    fn unique_ids() {
        let devs = all_devices();
        let ids: std::collections::HashSet<_> =
            devs.iter().map(|d| &d.id).collect();
        assert_eq!(ids.len(), devs.len());
    }

    #[test]
    fn r9_nano_is_the_fig3_device() {
        let d = amd_r9_nano();
        // Fig. 3's peak tuned kernel hits 2.57 TF on an 8.19 TF device —
        // the model must be able to express >2.57 TF.
        assert!(d.peak_gflops > 2570.0);
    }
}
