//! The execution-backend abstraction.
//!
//! Every engine — the pure-Rust [`NativeEngine`](super::NativeEngine) and
//! the feature-gated PJRT `Engine` — exposes the same
//! load→compile→execute surface over an [`ArtifactStore`].  Everything
//! above the runtime (the coordinator actors, the network runner, the
//! measured tuner, the benches) is written against this trait, so the
//! backend is a deployment decision, not an architectural one.
//! Concurrency lives one layer up: the coordinator wraps a backend in an
//! actor thread (`coordinator::EngineHandle`) or a whole pool of them
//! (`coordinator::EnginePool`).

use std::time::Duration;

use crate::error::Result;
use crate::util::rng::XorShift;

use super::artifact::ArtifactStore;

/// Output of one artifact execution.
#[derive(Debug, Clone)]
pub struct RunOutput {
    /// Flattened f32 outputs, one per tuple element.
    pub outputs: Vec<Vec<f32>>,
    /// Device execution wall time (compile excluded).
    pub elapsed: Duration,
}

impl RunOutput {
    /// Effective throughput for a run of `flops` useful operations.
    ///
    /// A zero-duration run (possible on coarse clocks for tiny kernels)
    /// reports 0.0 rather than dividing by zero: "no measurable
    /// throughput" is what downstream `> 0.0` sanity checks should see,
    /// not `inf`.
    pub fn gflops(&self, flops: u64) -> f64 {
        let secs = self.elapsed.as_secs_f64();
        if secs <= 0.0 {
            return 0.0;
        }
        flops as f64 / secs / 1e9
    }
}

/// An execution backend: compiles (or plans) artifacts once, caches the
/// result, and executes them with concrete inputs.
///
/// Backends are deliberately `&mut self` + single-threaded — PJRT buffers
/// are not `Sync`, and the native engine keeps the same shape so the two
/// are interchangeable.  Concurrency is the coordinator's job: it wraps
/// any backend in an actor thread (`coordinator::EngineHandle`) or a
/// routed pool of them (`coordinator::EnginePool`).
pub trait Backend {
    /// Human-readable platform name (diagnostics).
    fn platform(&self) -> String;

    /// The artifact store this backend serves.
    fn store(&self) -> &ArtifactStore;

    /// Compile (or plan) an artifact ahead of time, filling the cache.
    fn warm(&mut self, name: &str) -> Result<()>;

    /// Number of compiled/planned artifacts currently cached.
    fn cached(&self) -> usize;

    /// Execute an artifact with flattened f32 inputs (shapes taken from
    /// the manifest).  Returns flattened outputs + execution time.
    fn run(&mut self, name: &str, inputs: &[Vec<f32>]) -> Result<RunOutput>;

    /// Execute `name` `iters` times and return the last output with the
    /// best (minimum) execution time — the measurement discipline of the
    /// benches and the steady-state shape of the network runner.
    ///
    /// Backends with an expensive per-run input setup (PJRT literal
    /// construction) override this to hoist that setup out of the loop.
    fn run_timed(
        &mut self,
        name: &str,
        inputs: &[Vec<f32>],
        iters: usize,
    ) -> Result<(RunOutput, Duration)> {
        let mut best = Duration::MAX;
        let mut last = None;
        for _ in 0..iters.max(1) {
            let out = self.run(name, inputs)?;
            best = best.min(out.elapsed);
            last = Some(out);
        }
        let mut out = last.expect("iters >= 1");
        out.elapsed = best;
        Ok((out, best))
    }

    /// Install a new tuning-selection snapshot, for backends that
    /// consult a [`SelectionDb`](crate::tuner::SelectionDb) at plan
    /// time.  Returns `true` when the snapshot was applied.
    ///
    /// The contract for implementors: plans built after this call must
    /// resolve from the new snapshot, but plans whose resolved point is
    /// *unchanged* should stay cached — the epoch-swap path exists so a
    /// serving actor re-plans only the shape classes an online re-tune
    /// actually promoted.  The default is a no-op (`false`): backends
    /// without plan-time tuning (PJRT compiles ahead of time) simply
    /// report that the swap did not apply.
    fn swap_tuning(
        &mut self,
        db: std::sync::Arc<crate::tuner::SelectionDb>,
    ) -> bool {
        let _ = db;
        false
    }

    /// Snapshot of the backend's kernel-scratch arena counters, for
    /// backends that route kernel temporaries through a
    /// [`Scratch`](crate::util::scratch::Scratch) arena.  The serving
    /// layer aggregates these per pool into its CSV columns; a flat
    /// `grows` counter across requests is the zero-allocation
    /// steady-state invariant.  The default (all-zero stats) is for
    /// backends without an arena (PJRT manages device buffers itself).
    fn scratch_stats(&self) -> crate::util::scratch::ScratchStats {
        crate::util::scratch::ScratchStats::default()
    }

    /// Deterministic pseudo-random input vectors for an artifact (used by
    /// examples, benches, and the measured tuner; values in [-0.5, 0.5)).
    fn synth_inputs(&self, name: &str, seed: u64) -> Result<Vec<Vec<f32>>> {
        let meta = self.store().get(name)?;
        let mut rng = XorShift::new(seed);
        Ok(meta
            .inputs
            .iter()
            .map(|spec| rng.f32_vec(spec.elems()))
            .collect())
    }
}

/// Validate a request's inputs against an artifact's manifest entry.
/// Shared by every backend so error messages match.
pub(super) fn check_inputs(
    meta: &super::artifact::ArtifactMeta,
    inputs: &[Vec<f32>],
) -> Result<()> {
    if inputs.len() != meta.inputs.len() {
        return Err(crate::error::Error::Runtime(format!(
            "{}: expected {} inputs, got {}",
            meta.name,
            meta.inputs.len(),
            inputs.len()
        )));
    }
    for (i, (data, spec)) in inputs.iter().zip(&meta.inputs).enumerate() {
        if data.len() != spec.elems() {
            return Err(crate::error::Error::Runtime(format!(
                "{}: input {i} expected {} elems (shape {:?}), got {}",
                meta.name,
                spec.elems(),
                spec.shape,
                data.len()
            )));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gflops_guards_zero_duration() {
        let out = RunOutput { outputs: vec![], elapsed: Duration::ZERO };
        assert_eq!(out.gflops(1_000_000_000), 0.0);
    }

    #[test]
    fn gflops_normal_case() {
        let out = RunOutput {
            outputs: vec![],
            elapsed: Duration::from_secs(2),
        };
        assert_eq!(out.gflops(4_000_000_000), 2.0);
    }
}
