//! Runtime: load AOT artifacts and execute them through a pluggable
//! [`Backend`].
//!
//! `make artifacts` (Python, build time) writes `artifacts/*.hlo.txt`
//! plus `manifest.json`; this module parses the manifest into an
//! [`ArtifactStore`] and executes its entries through one of two
//! backends:
//!
//! * [`NativeEngine`] (default) — plans each artifact from its manifest
//!   metadata and dispatches to the pure-Rust reference kernels in
//!   [`crate::blas`] (blocked GEMM with the α/β epilogue; the conv
//!   algorithm family — im2col / tiled / winograd — keyed on
//!   [`LayerMeta`] with the algorithm resolved per plan).  Runs
//!   everywhere, including the offline build, with no external
//!   dependencies.
//! * `Engine` (`--features pjrt`) — compiles each artifact's HLO text
//!   once on the PJRT CPU client and caches the executable.
//!
//! Both implement [`Backend`]; [`DefaultEngine`] names whichever one the
//! build selected, so callers stay backend-agnostic.  No Python anywhere.

mod artifact;
mod backend;
#[cfg(feature = "pjrt")]
mod executor;
mod native;

pub use artifact::{ArtifactMeta, ArtifactStore, IoSpec, LayerMeta};
pub use backend::{Backend, RunOutput};
#[cfg(feature = "pjrt")]
pub use executor::Engine;
pub use native::{NativeEngine, HOST_DEVICE, SMALL_PROBLEM_FLOP_CUTOFF};

/// The backend the build defaults to: PJRT when the `pjrt` feature is
/// enabled, the pure-Rust native engine otherwise.
#[cfg(feature = "pjrt")]
pub type DefaultEngine = executor::Engine;
/// The backend the build defaults to: PJRT when the `pjrt` feature is
/// enabled, the pure-Rust native engine otherwise.
#[cfg(not(feature = "pjrt"))]
pub type DefaultEngine = native::NativeEngine;
