//! PJRT runtime: load AOT artifacts (HLO text) and execute them.
//!
//! The request-path half of the AOT bridge.  `make artifacts` (Python,
//! build time) writes `artifacts/*.hlo.txt` plus `manifest.json`; this
//! module parses the manifest ([`artifact`]), compiles each HLO module
//! once on the PJRT CPU client, caches the executable, and runs it with
//! concrete inputs ([`executor`]).  No Python anywhere.

mod artifact;
mod executor;

pub use artifact::{ArtifactMeta, ArtifactStore, IoSpec, LayerMeta};
pub use executor::{Engine, RunOutput};
