//! Compiled-executable cache + typed execution over the PJRT CPU client.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::error::{Error, Result};

use super::artifact::ArtifactStore;

/// Output of one artifact execution.
#[derive(Debug, Clone)]
pub struct RunOutput {
    /// Flattened f32 outputs, one per tuple element.
    pub outputs: Vec<Vec<f32>>,
    /// Device execution wall time (compile excluded).
    pub elapsed: Duration,
}

impl RunOutput {
    /// Effective throughput for a run of `flops` useful operations.
    pub fn gflops(&self, flops: u64) -> f64 {
        flops as f64 / self.elapsed.as_secs_f64() / 1e9
    }
}

/// The execution engine: one PJRT CPU client plus a compile cache.
///
/// Compilation happens once per artifact (first use or [`Engine::warm`]);
/// the request path is hash-lookup + execute.  The engine is deliberately
/// single-threaded (PJRT buffers are not `Sync`); the coordinator wraps it
/// in an actor thread (see `coordinator::scheduler`).
pub struct Engine {
    client: xla::PjRtClient,
    store: ArtifactStore,
    cache: HashMap<String, Arc<xla::PjRtLoadedExecutable>>,
}

impl Engine {
    /// Create a CPU engine over an artifact store.
    pub fn new(store: ArtifactStore) -> Result<Self> {
        let client = xla::PjRtClient::cpu()?;
        Ok(Self { client, store, cache: HashMap::new() })
    }

    /// The artifact store this engine serves.
    pub fn store(&self) -> &ArtifactStore {
        &self.store
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) an artifact's executable.
    pub fn warm(&mut self, name: &str) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.get(name) {
            return Ok(exe.clone());
        }
        let path = self.store.hlo_path(name)?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| {
                Error::Artifact(format!("non-utf8 path {}", path.display()))
            })?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Arc::new(self.client.compile(&comp)?);
        self.cache.insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Number of compiled executables currently cached.
    pub fn cached(&self) -> usize {
        self.cache.len()
    }

    /// Build input literals for an artifact, validating shapes.  One copy
    /// per input (EXPERIMENTS.md §Perf L3-1: the obvious
    /// `vec1(data).reshape(dims)` costs two copies and dominated
    /// large-input requests — 24 ms build vs 10.6 ms execute on resnet
    /// conv5_2).
    pub fn build_literals(
        &self,
        name: &str,
        inputs: &[Vec<f32>],
    ) -> Result<Vec<xla::Literal>> {
        let meta = self.store.get(name)?;
        if inputs.len() != meta.inputs.len() {
            return Err(Error::Runtime(format!(
                "{name}: expected {} inputs, got {}",
                meta.inputs.len(),
                inputs.len()
            )));
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, spec) in inputs.iter().zip(&meta.inputs) {
            if data.len() != spec.elems() {
                return Err(Error::Runtime(format!(
                    "{name}: input expected {} elems (shape {:?}), got {}",
                    spec.elems(),
                    spec.shape,
                    data.len()
                )));
            }
            let dims: Vec<usize> =
                spec.shape.iter().map(|d| *d as usize).collect();
            let bytes: &[u8] = unsafe {
                std::slice::from_raw_parts(
                    data.as_ptr() as *const u8,
                    data.len() * 4,
                )
            };
            literals.push(xla::Literal::create_from_shape_and_untyped_data(
                xla::ElementType::F32,
                &dims,
                bytes,
            )?);
        }
        Ok(literals)
    }

    fn execute_literals(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        literals: &[xla::Literal],
    ) -> Result<RunOutput> {
        let start = Instant::now();
        let result = exe.execute::<xla::Literal>(literals)?;
        let literal = result[0][0].to_literal_sync()?;
        let elapsed = start.elapsed();

        // aot.py lowers with return_tuple=True: unpack the tuple.
        let tuple = literal.to_tuple()?;
        let mut outputs = Vec::with_capacity(tuple.len());
        for l in tuple {
            outputs.push(l.to_vec::<f32>()?);
        }
        Ok(RunOutput { outputs, elapsed })
    }

    /// Execute an artifact with flattened f32 inputs (shapes taken from
    /// the manifest).  Returns flattened outputs + execution time.
    pub fn run(&mut self, name: &str, inputs: &[Vec<f32>]) -> Result<RunOutput> {
        let exe = self.warm(name)?;
        let literals = self.build_literals(name, inputs)?;
        self.execute_literals(&exe, &literals)
    }

    /// Execute `name` `iters` times with the input literals built ONCE
    /// and return the best (minimum) execution time — the measurement
    /// discipline of the benches and the steady-state shape of the
    /// network runner (EXPERIMENTS.md §Perf L3-2).
    pub fn run_timed(
        &mut self,
        name: &str,
        inputs: &[Vec<f32>],
        iters: usize,
    ) -> Result<(RunOutput, Duration)> {
        let exe = self.warm(name)?;
        let literals = self.build_literals(name, inputs)?;
        let mut best = Duration::MAX;
        let mut last = None;
        for _ in 0..iters.max(1) {
            let out = self.execute_literals(&exe, &literals)?;
            best = best.min(out.elapsed);
            last = Some(out);
        }
        let mut out = last.expect("iters >= 1");
        out.elapsed = best;
        Ok((out.clone(), best))
    }

    /// Deterministic pseudo-random input vectors for an artifact (used by
    /// examples and benches; xorshift, values in [-0.5, 0.5)).
    pub fn synth_inputs(&self, name: &str, seed: u64) -> Result<Vec<Vec<f32>>> {
        let meta = self.store.get(name)?;
        let mut state = seed.wrapping_mul(0x9E3779B97F4A7C15) | 1;
        let mut next = move || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            ((state >> 40) as f32 / (1u64 << 24) as f32) - 0.5
        };
        Ok(meta
            .inputs
            .iter()
            .map(|spec| (0..spec.elems()).map(|_| next()).collect())
            .collect())
    }
}
