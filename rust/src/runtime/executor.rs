//! Compiled-executable cache + typed execution over the PJRT CPU client.
//!
//! Feature-gated (`--features pjrt`): the `xla` crate this backend drives
//! is unavailable in the offline build, where [`super::NativeEngine`]
//! serves the same [`Backend`] surface through the pure-Rust kernels.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::error::{Error, Result};

use super::artifact::ArtifactStore;
use super::backend::{check_inputs, Backend, RunOutput};

/// The execution engine: one PJRT CPU client plus a compile cache.
///
/// Compilation happens once per artifact (first use or [`Engine::warm`]);
/// the request path is hash-lookup + execute.  The engine is deliberately
/// single-threaded (PJRT buffers are not `Sync`); the coordinator wraps it
/// in an actor thread (`coordinator::EngineHandle`) or a pool of them
/// (`coordinator::EnginePool`).
pub struct Engine {
    client: xla::PjRtClient,
    store: ArtifactStore,
    cache: HashMap<String, Arc<xla::PjRtLoadedExecutable>>,
}

impl Engine {
    /// Create a CPU engine over an artifact store.
    pub fn new(store: ArtifactStore) -> Result<Self> {
        let client = xla::PjRtClient::cpu()?;
        Ok(Self { client, store, cache: HashMap::new() })
    }

    /// Compile (or fetch from cache) an artifact's executable.
    pub fn warm_executable(
        &mut self,
        name: &str,
    ) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.get(name) {
            return Ok(exe.clone());
        }
        let path = self.store.hlo_path(name)?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| {
                Error::Artifact(format!("non-utf8 path {}", path.display()))
            })?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Arc::new(self.client.compile(&comp)?);
        self.cache.insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Build input literals for an artifact, validating shapes.  One copy
    /// per input (EXPERIMENTS.md §Perf L3-1: the obvious
    /// `vec1(data).reshape(dims)` costs two copies and dominated
    /// large-input requests — 24 ms build vs 10.6 ms execute on resnet
    /// conv5_2).
    pub fn build_literals(
        &self,
        name: &str,
        inputs: &[Vec<f32>],
    ) -> Result<Vec<xla::Literal>> {
        let meta = self.store.get(name)?;
        check_inputs(meta, inputs)?;
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, spec) in inputs.iter().zip(&meta.inputs) {
            let dims: Vec<usize> =
                spec.shape.iter().map(|d| *d as usize).collect();
            let bytes: &[u8] = unsafe {
                std::slice::from_raw_parts(
                    data.as_ptr() as *const u8,
                    data.len() * 4,
                )
            };
            literals.push(xla::Literal::create_from_shape_and_untyped_data(
                xla::ElementType::F32,
                &dims,
                bytes,
            )?);
        }
        Ok(literals)
    }

    fn execute_literals(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        literals: &[xla::Literal],
    ) -> Result<RunOutput> {
        let start = Instant::now();
        let result = exe.execute::<xla::Literal>(literals)?;
        let literal = result[0][0].to_literal_sync()?;
        let elapsed = start.elapsed();

        // aot.py lowers with return_tuple=True: unpack the tuple.
        let tuple = literal.to_tuple()?;
        let mut outputs = Vec::with_capacity(tuple.len());
        for l in tuple {
            outputs.push(l.to_vec::<f32>()?);
        }
        Ok(RunOutput { outputs, elapsed })
    }
}

impl Backend for Engine {
    fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn store(&self) -> &ArtifactStore {
        &self.store
    }

    fn warm(&mut self, name: &str) -> Result<()> {
        self.warm_executable(name).map(|_| ())
    }

    fn cached(&self) -> usize {
        self.cache.len()
    }

    fn run(&mut self, name: &str, inputs: &[Vec<f32>]) -> Result<RunOutput> {
        let exe = self.warm_executable(name)?;
        let literals = self.build_literals(name, inputs)?;
        self.execute_literals(&exe, &literals)
    }

    /// Input literals are built ONCE for all `iters` repetitions
    /// (EXPERIMENTS.md §Perf L3-2).
    fn run_timed(
        &mut self,
        name: &str,
        inputs: &[Vec<f32>],
        iters: usize,
    ) -> Result<(RunOutput, Duration)> {
        let exe = self.warm_executable(name)?;
        let literals = self.build_literals(name, inputs)?;
        let mut best = Duration::MAX;
        let mut last = None;
        for _ in 0..iters.max(1) {
            let out = self.execute_literals(&exe, &literals)?;
            best = best.min(out.elapsed);
            last = Some(out);
        }
        let mut out = last.expect("iters >= 1");
        out.elapsed = best;
        Ok((out, best))
    }
}
