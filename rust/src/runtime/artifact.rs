//! Artifact manifest: the contract between `python/compile/aot.py` and
//! the Rust runtime.  Field names mirror the JSON that `aot.py` writes;
//! parsing uses the from-scratch [`crate::util::json`] module.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::blas::QuantParams;
use crate::error::{Error, Result};
use crate::util::json::{self, Value};

/// Shape + dtype of one input or output.
#[derive(Debug, Clone)]
pub struct IoSpec {
    /// Dimension sizes, outermost first.
    pub shape: Vec<i64>,
    /// Element type name as the manifest spells it (e.g. `float32`).
    pub dtype: String,
}

impl IoSpec {
    /// Total element count.
    pub fn elems(&self) -> usize {
        self.shape.iter().product::<i64>() as usize
    }

    fn from_json(v: &Value) -> Result<Self> {
        let shape = v
            .get("shape")
            .and_then(|s| s.as_array())
            .ok_or_else(|| Error::Artifact("io spec missing shape".into()))?
            .iter()
            .map(|d| {
                d.as_i64()
                    .ok_or_else(|| Error::Artifact("bad shape dim".into()))
            })
            .collect::<Result<Vec<i64>>>()?;
        Ok(IoSpec {
            shape,
            dtype: v
                .get("dtype")
                .and_then(|d| d.as_str())
                .unwrap_or("float32")
                .to_string(),
        })
    }
}

/// Layer metadata recorded for conv artifacts (mirrors
/// `configs.layer_dict`).
#[derive(Debug, Clone)]
pub struct LayerMeta {
    /// Layer name as the paper's tables list it (e.g. `conv3_2`).
    pub name: String,
    /// Square filter window size.
    pub window: u32,
    /// Spatial stride.
    pub stride: u32,
    /// Input height.
    pub in_h: u32,
    /// Input width.
    pub in_w: u32,
    /// Input channels.
    pub in_c: u32,
    /// Output channels.
    pub out_c: u32,
    /// Output height the layer was lowered with.
    pub out_h: u32,
    /// Output width the layer was lowered with.
    pub out_w: u32,
    /// Padding convention, `SAME` or `VALID`.
    pub padding: String,
    /// Useful floating-point operations of one execution.
    pub flops: u64,
}

impl LayerMeta {
    fn from_json(v: &Value) -> Result<Self> {
        let u = |k: &str| -> Result<u32> {
            v.get(k)
                .and_then(|x| x.as_u64())
                .map(|x| x as u32)
                .ok_or_else(|| Error::Artifact(format!("layer missing {k}")))
        };
        Ok(LayerMeta {
            name: v
                .get("name")
                .and_then(|x| x.as_str())
                .unwrap_or("?")
                .to_string(),
            window: u("window")?,
            stride: u("stride")?,
            in_h: u("in_h")?,
            in_w: u("in_w")?,
            in_c: u("in_c")?,
            out_c: u("out_c")?,
            out_h: u("out_h")?,
            out_w: u("out_w")?,
            padding: v
                .get("padding")
                .and_then(|x| x.as_str())
                .unwrap_or("SAME")
                .to_string(),
            flops: v.get("flops").and_then(|x| x.as_u64()).unwrap_or(0),
        })
    }
}

/// Per-tensor quantization metadata for the int8 fast path: affine
/// scale/zero-point for both GEMM operands (`a` = LHS / conv input,
/// `b` = RHS / conv filters).  An artifact without a `quant` block
/// cannot run `dtype: i8` plans — the engine degrades them to `f32` at
/// plan time (the precision analogue of the unavailable-ISA degrade).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QuantMeta {
    /// LHS / conv-input quantization.
    pub a: QuantParams,
    /// RHS / conv-filter quantization.
    pub b: QuantParams,
}

impl QuantMeta {
    fn params_from_json(v: &Value, which: &str) -> Result<QuantParams> {
        let scale = v
            .get("scale")
            .and_then(|x| x.as_f64())
            .ok_or_else(|| {
                Error::Artifact(format!("quant.{which} missing scale"))
            })? as f32;
        if !(scale > 0.0 && scale.is_finite()) {
            return Err(Error::Artifact(format!(
                "quant.{which} scale must be positive and finite: {scale}"
            )));
        }
        let zero_point = v
            .get("zero_point")
            .and_then(|x| x.as_i64())
            .ok_or_else(|| {
                Error::Artifact(format!("quant.{which} missing zero_point"))
            })?;
        if !(-128..=127).contains(&zero_point) {
            return Err(Error::Artifact(format!(
                "quant.{which} zero_point out of i8 range: {zero_point}"
            )));
        }
        Ok(QuantParams { scale, zero_point: zero_point as i32 })
    }

    fn from_json(v: &Value) -> Result<Self> {
        let side = |which: &str| -> Result<QuantParams> {
            Self::params_from_json(
                v.get(which).ok_or_else(|| {
                    Error::Artifact(format!("quant missing {which}"))
                })?,
                which,
            )
        };
        Ok(QuantMeta { a: side("a")?, b: side("b")? })
    }
}

/// One artifact's metadata.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    /// Unique artifact name (the key every runtime request uses).
    pub name: String,
    /// "gemm" | "conv".
    pub kind: String,
    /// "pallas" | "xla".
    pub implementation: String,
    /// Kernel configuration name (None for vendor-baseline artifacts).
    pub config: Option<String>,
    /// HLO file name, relative to the artifact directory.
    pub file: String,
    /// Useful flops of one execution.
    pub flops: u64,
    /// Bytes touched at least once.
    pub bytes: Option<u64>,
    /// Input specs, in call order.
    pub inputs: Vec<IoSpec>,
    /// Output specs, in tuple order.
    pub outputs: Vec<IoSpec>,
    /// Manifest groups the artifact belongs to (e.g. `gemm`, `network`).
    pub groups: Vec<String>,
    /// GEMM rows of A/C.
    pub m: Option<u64>,
    /// GEMM columns of B/C.
    pub n: Option<u64>,
    /// GEMM inner (contraction) dimension.
    pub k: Option<u64>,
    /// GEMM epilogue scale on A@B (aot.py records 1.0 when unused).
    pub alpha: Option<f64>,
    /// GEMM epilogue scale on the C operand.
    pub beta: Option<f64>,
    /// Conv layer geometry (conv artifacts only).
    pub layer: Option<LayerMeta>,
    /// Conv algorithm the artifact was lowered with (e.g. `im2col`).
    pub algorithm: Option<String>,
    /// Conv batch size (defaults to 1 when absent).
    pub batch: Option<u32>,
    /// Conv artifact was lowered with the fused bias+ReLU epilogue
    /// (third input is the bias vector).
    pub fuse_relu: bool,
    /// Spatial scaling note when the measured artifact is shrunk
    /// (see python/compile/manifests.py).
    pub scaled_from: Option<String>,
    /// Per-tensor quantization params (present iff the artifact may
    /// run the int8 fast path).
    pub quant: Option<QuantMeta>,
}

impl ArtifactMeta {
    fn from_json(v: &Value) -> Result<Self> {
        let s = |k: &str| -> Result<String> {
            v.get(k)
                .and_then(|x| x.as_str())
                .map(|x| x.to_string())
                .ok_or_else(|| Error::Artifact(format!("artifact missing {k}")))
        };
        let io_list = |k: &str| -> Result<Vec<IoSpec>> {
            v.get(k)
                .and_then(|x| x.as_array())
                .map(|items| items.iter().map(IoSpec::from_json).collect())
                .unwrap_or_else(|| Ok(Vec::new()))
        };
        Ok(ArtifactMeta {
            name: s("name")?,
            kind: s("kind")?,
            implementation: v
                .get("impl")
                .and_then(|x| x.as_str())
                .unwrap_or("pallas")
                .to_string(),
            config: v.get("config").and_then(|x| x.as_str()).map(String::from),
            file: s("file")?,
            flops: v
                .get("flops")
                .and_then(|x| x.as_u64())
                .ok_or_else(|| Error::Artifact("artifact missing flops".into()))?,
            bytes: v.get("bytes").and_then(|x| x.as_u64()),
            inputs: io_list("inputs")?,
            outputs: io_list("outputs")?,
            groups: v
                .get("groups")
                .and_then(|x| x.as_array())
                .map(|items| {
                    items
                        .iter()
                        .filter_map(|g| g.as_str().map(String::from))
                        .collect()
                })
                .unwrap_or_default(),
            m: v.get("m").and_then(|x| x.as_u64()),
            n: v.get("n").and_then(|x| x.as_u64()),
            k: v.get("k").and_then(|x| x.as_u64()),
            alpha: v.get("alpha").and_then(|x| x.as_f64()),
            beta: v.get("beta").and_then(|x| x.as_f64()),
            layer: v.get("layer").map(LayerMeta::from_json).transpose()?,
            algorithm: v
                .get("algorithm")
                .and_then(|x| x.as_str())
                .map(String::from),
            batch: v.get("batch").and_then(|x| x.as_u64()).map(|b| b as u32),
            fuse_relu: v
                .get("fuse_relu")
                .and_then(|x| x.as_bool())
                .unwrap_or(false),
            scaled_from: v
                .get("scaled_from")
                .and_then(|x| x.as_str())
                .map(String::from),
            quant: v.get("quant").map(QuantMeta::from_json).transpose()?,
        })
    }
}

/// The artifact directory + parsed manifest.
#[derive(Debug, Clone)]
pub struct ArtifactStore {
    dir: PathBuf,
    by_name: HashMap<String, ArtifactMeta>,
    order: Vec<String>,
}

impl ArtifactStore {
    /// Open `dir/manifest.json`.
    pub fn open(dir: &Path) -> Result<Self> {
        let manifest_path = dir.join("manifest.json");
        let data = std::fs::read_to_string(&manifest_path).map_err(|e| {
            Error::Artifact(format!(
                "cannot read {}: {e}; run `make artifacts` first",
                manifest_path.display()
            ))
        })?;
        let root = json::parse(&data).map_err(|e| Error::Json(e.to_string()))?;
        let version = root
            .get("version")
            .and_then(|v| v.as_u64())
            .ok_or_else(|| Error::Artifact("manifest missing version".into()))?;
        if version != 1 {
            return Err(Error::Artifact(format!(
                "manifest version {version} unsupported (want 1)"
            )));
        }
        let artifacts = root
            .get("artifacts")
            .and_then(|v| v.as_array())
            .ok_or_else(|| Error::Artifact("manifest missing artifacts".into()))?;
        let mut by_name = HashMap::new();
        let mut order = Vec::new();
        for v in artifacts {
            let meta = ArtifactMeta::from_json(v)?;
            order.push(meta.name.clone());
            by_name.insert(meta.name.clone(), meta);
        }
        Ok(Self { dir: dir.to_path_buf(), by_name, order })
    }

    /// Artifact metadata by name.
    pub fn get(&self, name: &str) -> Result<&ArtifactMeta> {
        self.by_name
            .get(name)
            .ok_or_else(|| Error::NotFound(format!("artifact {name:?}")))
    }

    /// Absolute path of an artifact's HLO file.
    pub fn hlo_path(&self, name: &str) -> Result<PathBuf> {
        let meta = self.get(name)?;
        let path = self.dir.join(&meta.file);
        if !path.exists() {
            return Err(Error::Artifact(format!(
                "HLO file missing for {name:?}: {}",
                path.display()
            )));
        }
        Ok(path)
    }

    /// All artifacts, in manifest order.
    pub fn iter(&self) -> impl Iterator<Item = &ArtifactMeta> {
        self.order.iter().map(|n| &self.by_name[n])
    }

    /// Artifacts in a group (e.g. "gemm", "network").
    pub fn in_group<'a>(
        &'a self,
        group: &'a str,
    ) -> impl Iterator<Item = &'a ArtifactMeta> {
        self.iter().filter(move |m| m.groups.iter().any(|g| g == group))
    }

    /// Number of artifacts in the manifest.
    pub fn len(&self) -> usize {
        self.order.len()
    }

    /// Whether the manifest lists no artifacts.
    pub fn is_empty(&self) -> bool {
        self.order.is_empty()
    }

    /// The artifact directory this store was opened over.
    pub fn dir(&self) -> &Path {
        &self.dir
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tmp::TempDir;

    fn write_manifest(dir: &Path, artifacts: &str) {
        std::fs::write(
            dir.join("manifest.json"),
            format!(r#"{{"version": 1, "groups": ["core"], "artifacts": {artifacts}}}"#),
        )
        .unwrap();
    }

    #[test]
    fn parses_minimal_manifest() {
        let dir = TempDir::new("arts").unwrap();
        write_manifest(
            dir.path(),
            r#"[{"name": "g1", "kind": "gemm", "impl": "pallas",
                 "config": "4x4_8x8_loc", "file": "g1.hlo.txt",
                 "flops": 1000, "m": 64, "n": 64, "k": 64,
                 "alpha": 1.5, "beta": 0.5,
                 "inputs": [{"shape": [64, 64], "dtype": "float32"}],
                 "groups": ["core", "gemm"], "scaled_from": null}]"#,
        );
        std::fs::write(dir.path().join("g1.hlo.txt"), "HloModule x").unwrap();
        let store = ArtifactStore::open(dir.path()).unwrap();
        assert_eq!(store.len(), 1);
        let meta = store.get("g1").unwrap();
        assert_eq!(meta.implementation, "pallas");
        assert_eq!(meta.m, Some(64));
        assert_eq!(meta.alpha, Some(1.5));
        assert_eq!(meta.beta, Some(0.5));
        assert_eq!(meta.inputs[0].elems(), 4096);
        assert!(meta.scaled_from.is_none());
        assert!(store.hlo_path("g1").is_ok());
        assert_eq!(store.in_group("gemm").count(), 1);
        assert_eq!(store.in_group("conv").count(), 0);
    }

    #[test]
    fn parses_conv_layer_meta() {
        let dir = TempDir::new("arts").unwrap();
        write_manifest(
            dir.path(),
            r#"[{"name": "c1", "kind": "conv", "impl": "xla",
                 "file": "c1.hlo.txt", "flops": 99, "batch": 2,
                 "algorithm": "xla", "fuse_relu": true,
                 "layer": {"name": "conv1_1", "window": 3, "stride": 1,
                           "in_h": 14, "in_w": 14, "in_c": 8, "out_c": 16,
                           "out_h": 14, "out_w": 14, "padding": "SAME",
                           "flops": 99},
                 "inputs": []}]"#,
        );
        let store = ArtifactStore::open(dir.path()).unwrap();
        let meta = store.get("c1").unwrap();
        let layer = meta.layer.as_ref().unwrap();
        assert_eq!(layer.window, 3);
        assert_eq!(layer.out_c, 16);
        assert_eq!(meta.batch, Some(2));
        assert!(meta.fuse_relu);
    }

    #[test]
    fn parses_quant_metadata() {
        let dir = TempDir::new("arts").unwrap();
        write_manifest(
            dir.path(),
            r#"[{"name": "q1", "kind": "gemm", "file": "q1.hlo.txt",
                 "flops": 1, "m": 8, "n": 8, "k": 8, "inputs": [],
                 "quant": {"a": {"scale": 0.02, "zero_point": -3},
                           "b": {"scale": 0.5, "zero_point": 0}}},
                {"name": "f1", "kind": "gemm", "file": "f1.hlo.txt",
                 "flops": 1, "m": 8, "n": 8, "k": 8, "inputs": []}]"#,
        );
        let store = ArtifactStore::open(dir.path()).unwrap();
        let q = store.get("q1").unwrap().quant.unwrap();
        assert!((q.a.scale - 0.02).abs() < 1e-9);
        assert_eq!(q.a.zero_point, -3);
        assert_eq!(q.b.zero_point, 0);
        // Artifacts without the block simply have no quant metadata
        // (their i8 plans degrade to f32 at plan time).
        assert!(store.get("f1").unwrap().quant.is_none());
    }

    #[test]
    fn bad_quant_metadata_rejected() {
        for quant in [
            // zero_point outside the i8 range
            r#"{"a": {"scale": 0.1, "zero_point": 300},
                "b": {"scale": 0.1, "zero_point": 0}}"#,
            // non-positive scale
            r#"{"a": {"scale": 0.0, "zero_point": 0},
                "b": {"scale": 0.1, "zero_point": 0}}"#,
            // missing side
            r#"{"a": {"scale": 0.1, "zero_point": 0}}"#,
        ] {
            let dir = TempDir::new("arts").unwrap();
            write_manifest(
                dir.path(),
                &format!(
                    r#"[{{"name": "q", "kind": "gemm", "file": "q.hlo.txt",
                         "flops": 1, "inputs": [], "quant": {quant}}}]"#
                ),
            );
            assert!(ArtifactStore::open(dir.path()).is_err(), "{quant}");
        }
    }

    #[test]
    fn missing_manifest_is_helpful() {
        let dir = TempDir::new("arts").unwrap();
        let err = ArtifactStore::open(dir.path()).unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }

    #[test]
    fn missing_hlo_file_reported() {
        let dir = TempDir::new("arts").unwrap();
        write_manifest(
            dir.path(),
            r#"[{"name": "g1", "kind": "gemm", "file": "absent.hlo.txt",
                 "flops": 1, "inputs": []}]"#,
        );
        let store = ArtifactStore::open(dir.path()).unwrap();
        assert!(store.hlo_path("g1").is_err());
        assert!(store.get("nope").is_err());
    }

    #[test]
    fn wrong_version_rejected() {
        let dir = TempDir::new("arts").unwrap();
        std::fs::write(
            dir.path().join("manifest.json"),
            r#"{"version": 99, "artifacts": []}"#,
        )
        .unwrap();
        assert!(ArtifactStore::open(dir.path()).is_err());
    }
}
