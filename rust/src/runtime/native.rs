//! The native execution backend: run manifest artifacts through the
//! pure-Rust reference kernels instead of PJRT.
//!
//! This is what makes the whole load→plan→execute→verify pipeline work in
//! the offline build: `NativeEngine` reads the same `manifest.json` the
//! AOT bridge writes, but instead of compiling HLO text it *plans* each
//! artifact — keying on the manifest's GEMM dims or conv [`LayerMeta`] —
//! and dispatches to [`blas::gemm_blocked`](crate::blas::gemm_blocked)
//! (GEMM, with the α/β epilogue) or the im2col conv path
//! ([`blas::conv2d_im2col`](crate::blas::conv2d_im2col)).  The HLO files
//! referenced by the manifest are never opened, so synthetic manifests
//! (tests) and real AOT output both execute.
//!
//! Each plan resolves the [`BlockedParams`] it will execute with: when a
//! per-host tuning DB is attached ([`NativeEngine::with_tuning`]), the
//! measured winner for the artifact's problem class is used; otherwise
//! the engine-wide params (default: auto-threaded over all cores).  The
//! kernels parallelize over macro-tile bands per the params' `threads`
//! knob, bit-identically to the serial path.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

use crate::blas::{conv2d_im2col, gemm_blocked, BlockedParams, Conv2dShape};
use crate::error::{Error, Result};
use crate::tuner::{selection_key_for, SelectionDb};

use super::artifact::{ArtifactMeta, ArtifactStore, LayerMeta};
use super::backend::{check_inputs, Backend, RunOutput};

/// The device string host selections are keyed under in the tuning DB.
/// The sweep (`tuner::tune_blocked_sweep`) and the engine's plan-time
/// lookup must agree on it, or tuned entries are never found.
pub const HOST_DEVICE: &str = "host";

/// One planned artifact: everything `run` needs, resolved once at warm
/// time (the native analogue of the PJRT compile cache).  The blocking
/// parameters are part of the plan: tuned entries resolve from the
/// attached [`SelectionDb`], everything else falls back to the engine's
/// configured params.
#[derive(Debug, Clone)]
enum Plan {
    Gemm {
        m: usize,
        n: usize,
        k: usize,
        alpha: f32,
        beta: f32,
        /// Third input is a C operand for the β epilogue.
        with_c: bool,
        params: BlockedParams,
    },
    Conv {
        shape: Conv2dShape,
        /// Apply the fused bias+ReLU epilogue (third input is the bias
        /// vector over output channels), matching how `aot.py` lowers
        /// `network`-group artifacts.
        fuse_relu: bool,
        params: BlockedParams,
    },
}

impl Plan {
    fn params(&self) -> BlockedParams {
        match self {
            Plan::Gemm { params, .. } | Plan::Conv { params, .. } => *params,
        }
    }
}

fn gemm_plan(meta: &ArtifactMeta, params: BlockedParams) -> Result<Plan> {
    let dim = |v: Option<u64>, what: &str| -> Result<usize> {
        v.map(|x| x as usize).ok_or_else(|| {
            Error::Artifact(format!(
                "{}: gemm artifact missing {what}",
                meta.name
            ))
        })
    };
    let (m, n, k) = (dim(meta.m, "m")?, dim(meta.n, "n")?, dim(meta.k, "k")?);
    let with_c = meta.inputs.len() >= 3;
    // The declared input specs must agree with the dims we will execute
    // with: check_inputs later enforces data == spec, so spec == dims
    // here makes a kernel-side shape panic unreachable.
    let mut expect = vec![m * k, k * n];
    if with_c {
        expect.push(m * n);
    }
    if meta.inputs.len() < 2
        || meta
            .inputs
            .iter()
            .zip(&expect)
            .any(|(spec, want)| spec.elems() != *want)
    {
        return Err(Error::Artifact(format!(
            "{}: gemm input specs {:?} inconsistent with m/n/k {m}x{n}x{k}",
            meta.name,
            meta.inputs.iter().map(|s| s.elems()).collect::<Vec<_>>()
        )));
    }
    Ok(Plan::Gemm {
        m,
        n,
        k,
        alpha: meta.alpha.unwrap_or(1.0) as f32,
        beta: meta.beta.unwrap_or(0.0) as f32,
        with_c,
        params,
    })
}

fn conv_plan(meta: &ArtifactMeta, params: BlockedParams) -> Result<Plan> {
    let layer: &LayerMeta = meta.layer.as_ref().ok_or_else(|| {
        Error::Artifact(format!(
            "{}: conv artifact missing layer metadata",
            meta.name
        ))
    })?;
    let batch = meta.batch.unwrap_or(1) as usize;
    // Validate the geometry before any unchecked shape arithmetic: a
    // malformed manifest must be a loud error, never a panic/overflow.
    if layer.window == 0
        || layer.stride == 0
        || layer.in_h == 0
        || layer.in_w == 0
        || layer.in_c == 0
        || layer.out_c == 0
    {
        return Err(Error::Artifact(format!(
            "{}: conv layer has a zero dimension ({}x{}x{} window {} stride {})",
            meta.name, layer.in_h, layer.in_w, layer.in_c, layer.window,
            layer.stride
        )));
    }
    if layer.padding == "VALID"
        && (layer.window > layer.in_h || layer.window > layer.in_w)
    {
        return Err(Error::Artifact(format!(
            "{}: VALID padding needs window <= input ({} > {}x{})",
            meta.name, layer.window, layer.in_h, layer.in_w
        )));
    }
    let shape = match layer.padding.as_str() {
        "SAME" => Conv2dShape::same(
            batch,
            layer.in_h as usize,
            layer.in_w as usize,
            layer.in_c as usize,
            layer.out_c as usize,
            layer.window as usize,
            layer.stride as usize,
        ),
        "VALID" => Conv2dShape::valid(
            batch,
            layer.in_h as usize,
            layer.in_w as usize,
            layer.in_c as usize,
            layer.out_c as usize,
            layer.window as usize,
            layer.stride as usize,
        ),
        other => {
            return Err(Error::Artifact(format!(
                "{}: unsupported padding {other:?}",
                meta.name
            )))
        }
    };
    // The manifest records the output size the kernel was lowered with;
    // refuse to run if our padding arithmetic disagrees rather than
    // silently producing a differently shaped output.
    if (shape.out_h, shape.out_w)
        != (layer.out_h as usize, layer.out_w as usize)
    {
        return Err(Error::Artifact(format!(
            "{}: manifest says {}x{} output, padding arithmetic gives {}x{}",
            meta.name, layer.out_h, layer.out_w, shape.out_h, shape.out_w
        )));
    }
    // The declared x/filter specs must agree with the layer geometry the
    // kernels will execute with (same rationale as the GEMM plan check).
    let want_x = shape.input_elems();
    let want_f = shape.filter_elems();
    if meta.inputs.len() < 2
        || meta.inputs[0].elems() != want_x
        || meta.inputs[1].elems() != want_f
    {
        return Err(Error::Artifact(format!(
            "{}: conv input specs {:?} inconsistent with layer geometry \
             (want {want_x} input + {want_f} filter elems)",
            meta.name,
            meta.inputs.iter().map(|s| s.elems()).collect::<Vec<_>>()
        )));
    }
    if meta.fuse_relu {
        let bias_ok = meta
            .inputs
            .get(2)
            .map(|b| b.elems() == shape.out_c)
            .unwrap_or(false);
        if !bias_ok {
            return Err(Error::Artifact(format!(
                "{}: fuse_relu artifact needs a third (bias) input of {} \
                 elements",
                meta.name, shape.out_c
            )));
        }
    }
    Ok(Plan::Conv { shape, fuse_relu: meta.fuse_relu, params })
}

/// Resolve the blocking parameters an artifact will execute with: a
/// tuned entry from the selection DB when one exists for this problem
/// class on this platform, the engine's configured params otherwise.
fn resolve_params(
    meta: &ArtifactMeta,
    fallback: BlockedParams,
    tuning: Option<&SelectionDb>,
    device: &str,
) -> BlockedParams {
    tuning
        .and_then(|db| {
            selection_key_for(meta, device)
                .and_then(|key| db.get_blocked(&key))
        })
        .map(|(params, _gflops)| params)
        .unwrap_or(fallback)
}

fn build_plan(
    meta: &ArtifactMeta,
    fallback: BlockedParams,
    tuning: Option<&SelectionDb>,
    device: &str,
) -> Result<Plan> {
    let params = resolve_params(meta, fallback, tuning, device);
    match meta.kind.as_str() {
        "gemm" => gemm_plan(meta, params),
        "conv" => conv_plan(meta, params),
        other => Err(Error::Runtime(format!(
            "{}: unknown op kind {other:?} — the native backend executes \
             \"gemm\" and \"conv\" artifacts only",
            meta.name
        ))),
    }
}

/// The pure-Rust execution engine: an artifact store plus a plan cache.
///
/// Planning happens once per artifact (first use or [`Backend::warm`]);
/// the request path is hash-lookup + kernel dispatch, mirroring the PJRT
/// engine's compile-once/execute-many shape.
pub struct NativeEngine {
    store: ArtifactStore,
    plans: HashMap<String, Plan>,
    params: BlockedParams,
    /// Per-host tuning DB (`tuner::tune_blocked_sweep` output).  When
    /// present, plans resolve their blocking parameters from it.  Held
    /// behind an `Arc` so every actor of an engine pool shares one
    /// read-only copy instead of cloning the DB per actor.
    tuning: Option<Arc<SelectionDb>>,
    /// Platform string tuned selections are keyed under.
    device: String,
}

impl NativeEngine {
    /// Create a native engine over an artifact store.
    pub fn new(store: ArtifactStore) -> Result<Self> {
        Ok(Self {
            store,
            plans: HashMap::new(),
            params: BlockedParams::default(),
            tuning: None,
            device: HOST_DEVICE.to_string(),
        })
    }

    /// Create an engine with explicit host blocking parameters (the CPU
    /// analogue of picking a kernel configuration per device).
    pub fn with_params(store: ArtifactStore, params: BlockedParams) -> Self {
        Self {
            store,
            plans: HashMap::new(),
            params,
            tuning: None,
            device: HOST_DEVICE.to_string(),
        }
    }

    /// Create an engine that consults a per-host tuning DB at plan time:
    /// artifacts whose problem class has a measured winner execute with
    /// the tuned `BlockedParams`, the rest with the defaults.  This is
    /// the deployment shape: run the sweep once per host, ship the DB.
    pub fn with_tuning(store: ArtifactStore, tuning: SelectionDb) -> Self {
        Self::with_shared_tuning(store, Arc::new(tuning))
    }

    /// Like [`NativeEngine::with_tuning`], but sharing an existing
    /// reference-counted DB.  This is how an engine pool gives all of
    /// its actors one read-only copy of the host selections, so every
    /// actor plans with the same tuned `BlockedParams` at zero
    /// per-actor memory cost.
    pub fn with_shared_tuning(
        store: ArtifactStore,
        tuning: Arc<SelectionDb>,
    ) -> Self {
        Self {
            store,
            plans: HashMap::new(),
            params: BlockedParams::default(),
            tuning: Some(tuning),
            device: HOST_DEVICE.to_string(),
        }
    }

    /// Replace the fallback blocking parameters.  Invalidates the plan
    /// cache — plans embed the params they resolved.
    pub fn set_params(&mut self, params: BlockedParams) {
        self.params = params;
        self.plans.clear();
    }

    /// Attach (or replace) the tuning DB.  Invalidates the plan cache.
    pub fn set_tuning(&mut self, tuning: SelectionDb) {
        self.tuning = Some(Arc::new(tuning));
        self.plans.clear();
    }

    /// The fallback blocking parameters currently configured.
    pub fn params(&self) -> BlockedParams {
        self.params
    }

    /// The blocking parameters artifact `name` will execute with —
    /// plans it if needed.  This is how tests and reports demonstrate
    /// that a tuned selection is actually consulted.
    pub fn planned_params(&mut self, name: &str) -> Result<BlockedParams> {
        Ok(self.plan(name)?.params())
    }

    /// Plan (or fetch the cached plan for) an artifact.
    fn plan(&mut self, name: &str) -> Result<Plan> {
        if let Some(plan) = self.plans.get(name) {
            return Ok(plan.clone());
        }
        let meta = self.store.get(name)?;
        let plan =
            build_plan(meta, self.params, self.tuning.as_deref(), &self.device)?;
        self.plans.insert(name.to_string(), plan.clone());
        Ok(plan)
    }

    fn execute(&self, plan: &Plan, inputs: &[Vec<f32>]) -> Vec<Vec<f32>> {
        match plan {
            Plan::Gemm { m, n, k, alpha, beta, with_c, params } => {
                let mut out = gemm_blocked(
                    &inputs[0],
                    &inputs[1],
                    *m,
                    *n,
                    *k,
                    params,
                );
                if *with_c {
                    for (o, c) in out.iter_mut().zip(&inputs[2]) {
                        *o = alpha * *o + beta * c;
                    }
                } else if *alpha != 1.0 {
                    for o in out.iter_mut() {
                        *o *= alpha;
                    }
                }
                vec![out]
            }
            Plan::Conv { shape, fuse_relu, params } => {
                let mut out = conv2d_im2col(
                    &inputs[0],
                    &inputs[1],
                    shape,
                    params,
                );
                if *fuse_relu {
                    let bias = &inputs[2];
                    for (i, o) in out.iter_mut().enumerate() {
                        *o = (*o + bias[i % shape.out_c]).max(0.0);
                    }
                }
                vec![out]
            }
        }
    }
}

impl Backend for NativeEngine {
    fn platform(&self) -> String {
        "native-cpu (pure-Rust reference kernels)".to_string()
    }

    fn store(&self) -> &ArtifactStore {
        &self.store
    }

    fn warm(&mut self, name: &str) -> Result<()> {
        self.plan(name).map(|_| ())
    }

    fn cached(&self) -> usize {
        self.plans.len()
    }

    fn run(&mut self, name: &str, inputs: &[Vec<f32>]) -> Result<RunOutput> {
        let plan = self.plan(name)?;
        check_inputs(self.store.get(name)?, inputs)?;
        let start = Instant::now();
        let outputs = self.execute(&plan, inputs);
        let elapsed = start.elapsed();
        Ok(RunOutput { outputs, elapsed })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blas::{conv2d_direct, gemm_naive, max_abs_diff};
    use crate::util::rng::XorShift;
    use crate::util::tmp::TempDir;
    use std::path::Path;

    fn write_manifest(dir: &Path, artifacts: &str) {
        std::fs::write(
            dir.join("manifest.json"),
            format!(r#"{{"version": 1, "artifacts": {artifacts}}}"#),
        )
        .unwrap();
    }

    fn engine_with(artifacts: &str) -> (TempDir, NativeEngine) {
        let dir = TempDir::new("native").unwrap();
        write_manifest(dir.path(), artifacts);
        let store = ArtifactStore::open(dir.path()).unwrap();
        let engine = NativeEngine::new(store).unwrap();
        (dir, engine)
    }

    const GEMM_8: &str = r#"[{
        "name": "g8", "kind": "gemm", "impl": "pallas",
        "file": "g8.hlo.txt", "flops": 1024,
        "m": 8, "n": 8, "k": 8,
        "inputs": [{"shape": [8, 8], "dtype": "float32"},
                   {"shape": [8, 8], "dtype": "float32"}],
        "groups": ["gemm"]}]"#;

    #[test]
    fn plan_cache_hit_and_miss() {
        let (_dir, mut e) = engine_with(GEMM_8);
        assert_eq!(e.cached(), 0, "fresh engine has an empty cache");
        e.warm("g8").unwrap();
        assert_eq!(e.cached(), 1, "first warm is a miss that fills");
        e.warm("g8").unwrap();
        assert_eq!(e.cached(), 1, "second warm must hit the cache");
        let inputs = e.synth_inputs("g8", 1).unwrap();
        e.run("g8", &inputs).unwrap();
        assert_eq!(e.cached(), 1, "run reuses the cached plan");
        assert!(e.warm("missing").is_err());
        assert_eq!(e.cached(), 1);
    }

    #[test]
    fn gemm_matches_naive_oracle() {
        let (_dir, mut e) = engine_with(GEMM_8);
        let mut rng = XorShift::new(3);
        let a = rng.f32_vec(64);
        let b = rng.f32_vec(64);
        let out = e.run("g8", &[a.clone(), b.clone()]).unwrap();
        let expected = gemm_naive(&a, &b, 8, 8, 8);
        assert!(max_abs_diff(&out.outputs[0], &expected) < 1e-4);
    }

    #[test]
    fn gemm_alpha_beta_epilogue() {
        let (_dir, mut e) = engine_with(
            r#"[{
            "name": "gab", "kind": "gemm", "impl": "pallas",
            "file": "gab.hlo.txt", "flops": 100,
            "m": 4, "n": 6, "k": 5, "alpha": 1.5, "beta": 0.5,
            "inputs": [{"shape": [4, 5], "dtype": "float32"},
                       {"shape": [5, 6], "dtype": "float32"},
                       {"shape": [4, 6], "dtype": "float32"}],
            "groups": ["gemm"]}]"#,
        );
        let mut rng = XorShift::new(4);
        let a = rng.f32_vec(20);
        let b = rng.f32_vec(30);
        let c = rng.f32_vec(24);
        let out = e.run("gab", &[a.clone(), b.clone(), c.clone()]).unwrap();
        let ab = gemm_naive(&a, &b, 4, 6, 5);
        let expected: Vec<f32> =
            ab.iter().zip(&c).map(|(x, y)| 1.5 * x + 0.5 * y).collect();
        assert!(max_abs_diff(&out.outputs[0], &expected) < 1e-4);
    }

    #[test]
    fn conv_matches_direct_oracle() {
        let (_dir, mut e) = engine_with(
            r#"[{
            "name": "c1", "kind": "conv", "impl": "pallas",
            "file": "c1.hlo.txt", "flops": 99, "batch": 2,
            "algorithm": "im2col",
            "layer": {"name": "smoke", "window": 3, "stride": 1,
                      "in_h": 6, "in_w": 6, "in_c": 3, "out_c": 4,
                      "out_h": 6, "out_w": 6, "padding": "SAME",
                      "flops": 99},
            "inputs": [{"shape": [2, 6, 6, 3], "dtype": "float32"},
                       {"shape": [3, 3, 3, 4], "dtype": "float32"}],
            "groups": ["conv"]}]"#,
        );
        let inputs = e.synth_inputs("c1", 7).unwrap();
        let out = e.run("c1", &inputs).unwrap();
        let shape = Conv2dShape::same(2, 6, 6, 3, 4, 3, 1);
        let expected = conv2d_direct(&inputs[0], &inputs[1], &shape);
        assert!(max_abs_diff(&out.outputs[0], &expected) < 1e-4);
        assert_eq!(out.outputs[0].len(), 2 * 6 * 6 * 4);
    }

    #[test]
    fn conv_fused_bias_relu_epilogue() {
        // Mirrors aot.py's `network`-group lowering: conv + bias + ReLU,
        // bias as a third input over output channels.
        let (_dir, mut e) = engine_with(
            r#"[{
            "name": "cf", "kind": "conv", "impl": "pallas",
            "file": "cf.hlo.txt", "flops": 10, "batch": 1,
            "algorithm": "im2col", "fuse_relu": true,
            "layer": {"name": "fused", "window": 1, "stride": 1,
                      "in_h": 4, "in_w": 4, "in_c": 2, "out_c": 3,
                      "out_h": 4, "out_w": 4, "padding": "SAME",
                      "flops": 10},
            "inputs": [{"shape": [1, 4, 4, 2], "dtype": "float32"},
                       {"shape": [1, 1, 2, 3], "dtype": "float32"},
                       {"shape": [3], "dtype": "float32"}],
            "groups": ["network"]}]"#,
        );
        let inputs = e.synth_inputs("cf", 21).unwrap();
        let out = e.run("cf", &inputs).unwrap();
        let shape = Conv2dShape::same(1, 4, 4, 2, 3, 1, 1);
        let conv = conv2d_direct(&inputs[0], &inputs[1], &shape);
        let expected: Vec<f32> = conv
            .iter()
            .enumerate()
            .map(|(i, v)| (v + inputs[2][i % 3]).max(0.0))
            .collect();
        assert!(max_abs_diff(&out.outputs[0], &expected) < 1e-4);
        // ReLU actually clamps something (inputs are centered, so some
        // outputs go negative pre-clamp).
        assert!(out.outputs[0].iter().any(|v| *v == 0.0));
    }

    #[test]
    fn unknown_op_kind_is_a_loud_error_not_a_panic() {
        let (_dir, mut e) = engine_with(
            r#"[{
            "name": "mystery", "kind": "fft", "impl": "pallas",
            "file": "mystery.hlo.txt", "flops": 1,
            "inputs": [], "groups": []}]"#,
        );
        let err = e.run("mystery", &[]).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("unknown op kind"), "got: {msg}");
        assert!(msg.contains("fft"), "names the offending kind: {msg}");
        assert!(matches!(err, Error::Runtime(_)));
        assert_eq!(e.cached(), 0, "failed plans are not cached");
    }

    #[test]
    fn input_validation_mirrors_pjrt() {
        let (_dir, mut e) = engine_with(GEMM_8);
        // Wrong arity.
        assert!(e.run("g8", &[vec![0.0; 64]]).is_err());
        // Wrong element count.
        assert!(e.run("g8", &[vec![0.0; 7], vec![0.0; 64]]).is_err());
        // Unknown artifact.
        assert!(e.run("no_such_artifact", &[]).is_err());
    }

    #[test]
    fn malformed_conv_geometry_is_an_error_not_a_panic() {
        // VALID window larger than the input used to underflow in
        // Conv2dShape::valid; it must surface as Error::Artifact.
        let (_dir, mut e) = engine_with(
            r#"[{
            "name": "cbad", "kind": "conv", "impl": "pallas",
            "file": "cbad.hlo.txt", "flops": 1, "batch": 1,
            "layer": {"name": "bad", "window": 5, "stride": 1,
                      "in_h": 3, "in_w": 3, "in_c": 1, "out_c": 1,
                      "out_h": 1, "out_w": 1, "padding": "VALID",
                      "flops": 1},
            "inputs": [], "groups": []}]"#,
        );
        let msg = e.warm("cbad").unwrap_err().to_string();
        assert!(msg.contains("VALID padding needs"), "got: {msg}");
        // Zero dimensions are rejected the same way.
        let (_dir2, mut e2) = engine_with(
            r#"[{
            "name": "czero", "kind": "conv", "impl": "pallas",
            "file": "czero.hlo.txt", "flops": 1, "batch": 1,
            "layer": {"name": "z", "window": 3, "stride": 0,
                      "in_h": 8, "in_w": 8, "in_c": 4, "out_c": 4,
                      "out_h": 8, "out_w": 8, "padding": "SAME",
                      "flops": 1},
            "inputs": [], "groups": []}]"#,
        );
        assert!(e2.warm("czero").is_err());
    }

    #[test]
    fn fused_conv_with_wrong_bias_shape_rejected_at_plan_time() {
        let (_dir, mut e) = engine_with(
            r#"[{
            "name": "cfbad", "kind": "conv", "impl": "pallas",
            "file": "cfbad.hlo.txt", "flops": 1, "batch": 1,
            "fuse_relu": true,
            "layer": {"name": "fb", "window": 1, "stride": 1,
                      "in_h": 4, "in_w": 4, "in_c": 2, "out_c": 3,
                      "out_h": 4, "out_w": 4, "padding": "SAME",
                      "flops": 1},
            "inputs": [{"shape": [1, 4, 4, 2], "dtype": "float32"},
                       {"shape": [1, 1, 2, 3], "dtype": "float32"},
                       {"shape": [2], "dtype": "float32"}],
            "groups": []}]"#,
        );
        let msg = e.warm("cfbad").unwrap_err().to_string();
        assert!(msg.contains("bias"), "got: {msg}");
    }

    #[test]
    fn planned_entries_use_tuned_params_over_defaults() {
        use crate::tuner::{SelectionDb, SelectionKey};

        // A tuning DB holding a distinctive winner for g8's problem
        // class (8^3 buckets to the 64^3 class).
        let tuned =
            BlockedParams { bm: 8, bn: 8, bk: 8, mr: 2, nr: 2, threads: 1 };
        let mut db = SelectionDb::new();
        db.put_blocked(SelectionKey::gemm(HOST_DEVICE, 8, 8, 8), tuned, 9.0);
        let (_dir, plain) = engine_with(GEMM_8);
        let mut e = NativeEngine::with_tuning(plain.store.clone(), db);
        assert_eq!(
            e.planned_params("g8").unwrap(),
            tuned,
            "plan must consult the tuning DB"
        );
        assert_ne!(tuned, BlockedParams::default());
        // The tuned plan still computes the right answer.
        let mut rng = XorShift::new(12);
        let a = rng.f32_vec(64);
        let b = rng.f32_vec(64);
        let out = e.run("g8", &[a.clone(), b.clone()]).unwrap();
        let expected = gemm_naive(&a, &b, 8, 8, 8);
        assert!(max_abs_diff(&out.outputs[0], &expected) < 1e-4);
    }

    #[test]
    fn shared_tuning_db_is_consulted_by_every_engine() {
        use crate::tuner::{SelectionDb, SelectionKey};

        // One Arc'd DB, many engines — the engine-pool sharing shape.
        let tuned =
            BlockedParams { bm: 8, bn: 8, bk: 8, mr: 2, nr: 2, threads: 1 };
        let mut db = SelectionDb::new();
        db.put_blocked(SelectionKey::gemm(HOST_DEVICE, 8, 8, 8), tuned, 9.0);
        let shared = Arc::new(db);
        let (_dir, plain) = engine_with(GEMM_8);
        let mut a = NativeEngine::with_shared_tuning(
            plain.store.clone(),
            Arc::clone(&shared),
        );
        let mut b = NativeEngine::with_shared_tuning(
            plain.store.clone(),
            Arc::clone(&shared),
        );
        assert_eq!(a.planned_params("g8").unwrap(), tuned);
        assert_eq!(b.planned_params("g8").unwrap(), tuned);
        assert_eq!(Arc::strong_count(&shared), 3, "one DB, shared by all");
    }

    #[test]
    fn untuned_entries_fall_back_to_engine_params() {
        use crate::tuner::{SelectionDb, SelectionKey};

        // DB tuned for a *different* problem class: g8 must fall back.
        let mut db = SelectionDb::new();
        db.put_blocked(
            SelectionKey::gemm(HOST_DEVICE, 512, 512, 512),
            BlockedParams { bm: 128, bn: 128, bk: 64, mr: 8, nr: 16, threads: 4 },
            20.0,
        );
        let (_dir, plain) = engine_with(GEMM_8);
        let mut e = NativeEngine::with_tuning(plain.store.clone(), db);
        assert_eq!(e.planned_params("g8").unwrap(), BlockedParams::default());
    }

    #[test]
    fn set_params_invalidates_cached_plans() {
        let (_dir, mut e) = engine_with(GEMM_8);
        e.warm("g8").unwrap();
        assert_eq!(e.planned_params("g8").unwrap(), BlockedParams::default());
        let small =
            BlockedParams { bm: 4, bn: 4, bk: 4, mr: 2, nr: 2, threads: 2 };
        e.set_params(small);
        assert_eq!(e.cached(), 0, "set_params must drop stale plans");
        assert_eq!(
            e.planned_params("g8").unwrap(),
            small,
            "re-planned entries must use the new params"
        );
        assert_eq!(e.params(), small);
    }

    #[test]
    fn gemm_artifact_missing_dims_reported() {
        let (_dir, mut e) = engine_with(
            r#"[{
            "name": "gx", "kind": "gemm", "impl": "pallas",
            "file": "gx.hlo.txt", "flops": 1,
            "inputs": [], "groups": []}]"#,
        );
        let msg = e.warm("gx").unwrap_err().to_string();
        assert!(msg.contains("missing m"), "got: {msg}");
    }
}
