//! The native execution backend: run manifest artifacts through the
//! pure-Rust reference kernels instead of PJRT.
//!
//! This is what makes the whole load→plan→execute→verify pipeline work in
//! the offline build: `NativeEngine` reads the same `manifest.json` the
//! AOT bridge writes, but instead of compiling HLO text it *plans* each
//! artifact — keying on the manifest's GEMM dims or conv [`LayerMeta`] —
//! and dispatches to [`blas::gemm_blocked_ex`](crate::blas::gemm_blocked_ex)
//! (GEMM, with the α/β epilogue) or the native conv algorithm family
//! ([`blas::conv2d_native_ex`](crate::blas::conv2d_native_ex): im2col,
//! tiled direct, or Winograd).  The HLO files referenced by the manifest are
//! never opened, so synthetic manifests (tests) and real AOT output both
//! execute.
//!
//! Every kernel temporary rides the engine's [`Scratch`] workspace arena:
//! each plan records its worst-case [`Workspace`] (the analytic
//! `blas::*_workspace` take-set under the resolved point, `pack` axis
//! included) and prewarms the arena at plan time, so steady-state
//! serving performs **zero** kernel-scratch allocations per request —
//! [`NativeEngine::scratch_stats`] makes that observable per engine (and
//! per pool actor, since each actor owns its engine).
//!
//! Each plan resolves the [`crate::config::KernelSpace`] point it will
//! execute with — for GEMM a [`GemmPoint`] (blocking × threads ×
//! micro-kernel ISA), for conv a [`ConvPoint`] (which *algorithm* runs,
//! its knobs — including the Winograd `wino_m` tile size — the lowered-
//! GEMM blocking, and the micro-kernel ISA that lowered GEMM
//! dispatches).  **One generic resolution ladder** serves every space,
//! first hit wins:
//!
//! 1. a tuned entry for the artifact's problem class in the attached
//!    tuning DB ([`NativeEngine::with_tuning`]) — unified
//!    `gemm_point`/`conv_point` entries and legacy `blocked` /
//!    `conv_native` entries alike (the DB's per-space migration shims
//!    decode both);
//! 2. engine-wide overrides ([`NativeEngine::set_gemm_point`] /
//!    [`NativeEngine::set_conv_point`], with
//!    [`NativeEngine::set_params`] / [`NativeEngine::set_conv_params`]
//!    as the legacy typed views — what the tuner's sweeps drive);
//! 3. the defaults: scalar ISA, im2col, auto threads — except that
//!    *small* problems (below [`SMALL_PROBLEM_FLOP_CUTOFF`] manifest
//!    flops) plan `threads: 1`, because thread fan-out costs more than
//!    it buys on sub-millisecond kernels.  A tuned DB entry always
//!    overrides the heuristic.
//!
//! Four plan-time safety rules keep every resolved point executable on
//! *this* host and *this* artifact: Winograd selections fall back to
//! im2col on shapes outside the F(m×m, 3×3) domain, GEMM points whose
//! ISA the executing CPU lacks degrade to the scalar micro-kernel (same
//! blocking), conv points do the same for the ISA their lowered GEMMs
//! dispatch, and `i8` points degrade to `f32` (same blocking, same ISA)
//! when the artifact's manifest carries no quantization metadata — so a
//! DB tuned on a bigger host is always safe to ship, and
//! [`NativeEngine::planned_conv`] / [`NativeEngine::planned_gemm`]
//! always report what will really run.  `i8` plans quantize their f32
//! operands with the manifest's per-tensor [`QuantMeta`], run the
//! widening i8×i8→i32 kernels, and dequantize in the epilogue.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

use crate::blas::{
    conv2d_im2col_i8_ex, conv2d_im2col_i8_workspace, conv2d_native_ex,
    conv2d_native_workspace,
    gemm_blocked_ex, gemm_i8_dequant_ex, gemm_i8_dequant_workspace,
    gemm_workspace, native_conv_algorithm, quantize_into, BlockedParams,
    Conv2dShape, Dtype, Isa, Pack,
};
use crate::config::{
    ConvAlgorithm, ConvConfig, ConvPoint, GemmPoint, KernelSpace,
};
use crate::error::{Error, Result};
use crate::tuner::{selection_key_for, SelectionDb};
use crate::util::scratch::{Scratch, ScratchStats, Workspace};

use super::artifact::{ArtifactMeta, ArtifactStore, LayerMeta, QuantMeta};
use super::backend::{check_inputs, Backend, RunOutput};

/// The device string host selections are keyed under in the tuning DB.
/// The sweep (`tuner::tune_space_sweep`) and the engine's plan-time
/// lookup must agree on it, or tuned entries are never found.
pub const HOST_DEVICE: &str = "host";

/// Problems below this many manifest flops plan `threads: 1` by default:
/// on sub-millisecond kernels the pool fan-out/join overhead exceeds the
/// parallel win, so small shapes want the serial path unless a measured
/// selection says otherwise.  The cutoff sits between the serving zoo's
/// small GEMMs (≤ 2·192³ ≈ 14 MFlop is already borderline; 96³ ≈ 1.8
/// MFlop clearly serial) and the first shapes where band parallelism
/// reliably pays (≥ 256³ ≈ 34 MFlop).  Applies only to the *fallback*
/// resolution — tuned DB entries and explicitly set engine params are
/// used verbatim, so the tuner can always override it.
pub const SMALL_PROBLEM_FLOP_CUTOFF: u64 = 8_000_000;

/// One planned artifact: everything `run` needs, resolved once at warm
/// time (the native analogue of the PJRT compile cache).  The blocking
/// parameters are part of the plan: tuned entries resolve from the
/// attached [`SelectionDb`], everything else falls back to the engine's
/// configured params.
#[derive(Debug, Clone)]
enum Plan {
    Gemm {
        m: usize,
        n: usize,
        k: usize,
        alpha: f32,
        beta: f32,
        /// Third input is a C operand for the β epilogue.
        with_c: bool,
        /// The resolved GEMM space point — blocking, threads, the
        /// micro-kernel ISA, and the dtype, already degraded to what
        /// this host (and this artifact's metadata) can run.
        point: GemmPoint,
        /// Per-tensor quantization parameters from the manifest.  Always
        /// `Some` when `point.dtype` is `i8` — [`build_plan`] degrades
        /// `i8` points to `f32` on artifacts without quant metadata.
        quant: Option<QuantMeta>,
        /// Worst-case kernel-scratch take-set of one execution under the
        /// resolved point, computed analytically at plan time.  Feeding
        /// it to [`Scratch::prewarm`] makes steady-state execution
        /// allocation-free.
        workspace: Workspace,
    },
    Conv {
        shape: Conv2dShape,
        /// Apply the fused bias+ReLU epilogue (third input is the bias
        /// vector over output channels), matching how `aot.py` lowers
        /// `network`-group artifacts.
        fuse_relu: bool,
        /// The resolved conv space point — the algorithm + tile/vector
        /// knobs (already resolved through the fallback rule, so
        /// `point.config.algorithm` is what will actually execute), the
        /// lowered-GEMM blocking + `threads`, and the micro-kernel ISA
        /// (already degraded to what this host can run).
        point: ConvPoint,
        /// Per-tensor quantization parameters (input, filter) from the
        /// manifest; same `Some`-iff-`i8` invariant as the GEMM plan.
        quant: Option<QuantMeta>,
        /// Worst-case kernel-scratch take-set (same contract as the GEMM
        /// plan's field).
        workspace: Workspace,
    },
}

impl Plan {
    fn params(&self) -> BlockedParams {
        match self {
            Plan::Gemm { point, .. } => point.params,
            Plan::Conv { point, .. } => point.blocked,
        }
    }

    fn gemm_point(&self) -> Option<GemmPoint> {
        match self {
            Plan::Gemm { point, .. } => Some(*point),
            Plan::Conv { .. } => None,
        }
    }

    fn conv_config(&self) -> Option<ConvConfig> {
        self.conv_point().map(|p| p.config)
    }

    fn conv_point(&self) -> Option<ConvPoint> {
        match self {
            Plan::Gemm { .. } => None,
            Plan::Conv { point, .. } => Some(*point),
        }
    }

    fn workspace(&self) -> &Workspace {
        match self {
            Plan::Gemm { workspace, .. } => workspace,
            Plan::Conv { workspace, .. } => workspace,
        }
    }
}

fn gemm_plan(meta: &ArtifactMeta, point: GemmPoint) -> Result<Plan> {
    let dim = |v: Option<u64>, what: &str| -> Result<usize> {
        v.map(|x| x as usize).ok_or_else(|| {
            Error::Artifact(format!(
                "{}: gemm artifact missing {what}",
                meta.name
            ))
        })
    };
    let (m, n, k) = (dim(meta.m, "m")?, dim(meta.n, "n")?, dim(meta.k, "k")?);
    let with_c = meta.inputs.len() >= 3;
    // The declared input specs must agree with the dims we will execute
    // with: check_inputs later enforces data == spec, so spec == dims
    // here makes a kernel-side shape panic unreachable.
    let mut expect = vec![m * k, k * n];
    if with_c {
        expect.push(m * n);
    }
    if meta.inputs.len() < 2
        || meta
            .inputs
            .iter()
            .zip(&expect)
            .any(|(spec, want)| spec.elems() != *want)
    {
        return Err(Error::Artifact(format!(
            "{}: gemm input specs {:?} inconsistent with m/n/k {m}x{n}x{k}",
            meta.name,
            meta.inputs.iter().map(|s| s.elems()).collect::<Vec<_>>()
        )));
    }
    // The worst-case kernel take-set under the resolved point: the i8
    // path stages two quantized operands in this module on top of the
    // dequant kernel's own workspace; the f32 path is the blocked GEMM's
    // packing buffers (pack-dependent).
    let workspace = if point.dtype == Dtype::I8 {
        let mut ws =
            gemm_i8_dequant_workspace(m, n, k, &point.params, point.pack);
        ws.i8_lens.push(m * k);
        ws.i8_lens.push(k * n);
        ws
    } else {
        gemm_workspace(m, n, k, &point.params, point.pack)
    };
    Ok(Plan::Gemm {
        m,
        n,
        k,
        alpha: meta.alpha.unwrap_or(1.0) as f32,
        beta: meta.beta.unwrap_or(0.0) as f32,
        with_c,
        point,
        quant: meta.quant,
        workspace,
    })
}

fn conv_plan(meta: &ArtifactMeta, point: ConvPoint) -> Result<Plan> {
    let layer: &LayerMeta = meta.layer.as_ref().ok_or_else(|| {
        Error::Artifact(format!(
            "{}: conv artifact missing layer metadata",
            meta.name
        ))
    })?;
    let batch = meta.batch.unwrap_or(1) as usize;
    // Validate the geometry before any unchecked shape arithmetic: a
    // malformed manifest must be a loud error, never a panic/overflow.
    if layer.window == 0
        || layer.stride == 0
        || layer.in_h == 0
        || layer.in_w == 0
        || layer.in_c == 0
        || layer.out_c == 0
    {
        return Err(Error::Artifact(format!(
            "{}: conv layer has a zero dimension ({}x{}x{} window {} stride {})",
            meta.name, layer.in_h, layer.in_w, layer.in_c, layer.window,
            layer.stride
        )));
    }
    if layer.padding == "VALID"
        && (layer.window > layer.in_h || layer.window > layer.in_w)
    {
        return Err(Error::Artifact(format!(
            "{}: VALID padding needs window <= input ({} > {}x{})",
            meta.name, layer.window, layer.in_h, layer.in_w
        )));
    }
    let shape = match layer.padding.as_str() {
        "SAME" => Conv2dShape::same(
            batch,
            layer.in_h as usize,
            layer.in_w as usize,
            layer.in_c as usize,
            layer.out_c as usize,
            layer.window as usize,
            layer.stride as usize,
        ),
        "VALID" => Conv2dShape::valid(
            batch,
            layer.in_h as usize,
            layer.in_w as usize,
            layer.in_c as usize,
            layer.out_c as usize,
            layer.window as usize,
            layer.stride as usize,
        ),
        other => {
            return Err(Error::Artifact(format!(
                "{}: unsupported padding {other:?}",
                meta.name
            )))
        }
    };
    // The manifest records the output size the kernel was lowered with;
    // refuse to run if our padding arithmetic disagrees rather than
    // silently producing a differently shaped output.
    if (shape.out_h, shape.out_w)
        != (layer.out_h as usize, layer.out_w as usize)
    {
        return Err(Error::Artifact(format!(
            "{}: manifest says {}x{} output, padding arithmetic gives {}x{}",
            meta.name, layer.out_h, layer.out_w, shape.out_h, shape.out_w
        )));
    }
    // The declared x/filter specs must agree with the layer geometry the
    // kernels will execute with (same rationale as the GEMM plan check).
    let want_x = shape.input_elems();
    let want_f = shape.filter_elems();
    if meta.inputs.len() < 2
        || meta.inputs[0].elems() != want_x
        || meta.inputs[1].elems() != want_f
    {
        return Err(Error::Artifact(format!(
            "{}: conv input specs {:?} inconsistent with layer geometry \
             (want {want_x} input + {want_f} filter elems)",
            meta.name,
            meta.inputs.iter().map(|s| s.elems()).collect::<Vec<_>>()
        )));
    }
    if meta.fuse_relu {
        let bias_ok = meta
            .inputs
            .get(2)
            .map(|b| b.elems() == shape.out_c)
            .unwrap_or(false);
        if !bias_ok {
            return Err(Error::Artifact(format!(
                "{}: fuse_relu artifact needs a third (bias) input of {} \
                 elements",
                meta.name, shape.out_c
            )));
        }
    }
    // Resolve the fallback rule *now*, so the plan (and everything that
    // reports it: `planned_conv`, tuning reports) names the algorithm
    // that will really execute.
    let point = ConvPoint {
        config: ConvConfig {
            algorithm: native_conv_algorithm(&point.config, &shape),
            ..point.config
        },
        ..point
    };
    // Defensive companion to [`ConvPoint::validate`]'s i8-implies-im2col
    // rule: if an engine-wide override paired `i8` with an algorithm
    // that has no quantized body, the dtype (not the algorithm) yields.
    let point = if point.dtype == Dtype::I8
        && point.config.algorithm != ConvAlgorithm::Im2col
    {
        ConvPoint { dtype: Dtype::F32, ..point }
    } else {
        point
    };
    // Pack companion of the same rule: the direct/tiled kernels have no
    // B panel to pack, so a `pack: ab` selection landing on a
    // non-GEMM-lowered algorithm (via the im2col fallback's inverse — an
    // engine-wide tiled override) plans, reports, and executes as `a`.
    let point = if point.pack == Pack::Ab
        && !matches!(
            point.config.algorithm,
            ConvAlgorithm::Im2col | ConvAlgorithm::Winograd
        ) {
        ConvPoint { pack: Pack::A, ..point }
    } else {
        point
    };
    let workspace = if point.dtype == Dtype::I8 {
        conv2d_im2col_i8_workspace(&shape, &point.blocked, point.pack)
    } else {
        conv2d_native_workspace(
            &shape,
            &point.config,
            &point.blocked,
            point.pack,
        )
    };
    Ok(Plan::Conv {
        shape,
        fuse_relu: meta.fuse_relu,
        point,
        quant: meta.quant,
        workspace,
    })
}

/// What the engine falls back to when the tuning DB has no entry for a
/// problem class.
#[derive(Debug, Clone, Copy)]
struct Fallback {
    /// Engine-wide GEMM point (blocking + ISA).
    gemm: GemmPoint,
    /// Whether `gemm` was set explicitly ([`NativeEngine::with_params`]
    /// / [`NativeEngine::set_params`] / [`NativeEngine::set_gemm_point`]);
    /// explicit points bypass the small-problem threads heuristic.
    explicit: bool,
    /// Engine-wide conv override ([`NativeEngine::set_conv_point`]):
    /// algorithm + knobs + blocking, used verbatim for conv plans.
    conv: Option<ConvPoint>,
}

/// The small-problem threads heuristic: auto-threaded (`threads: 0`)
/// fallback params plan serially below the flop cutoff.
fn heuristic_params(params: BlockedParams, flops: u64) -> BlockedParams {
    if params.threads == 0 && flops < SMALL_PROBLEM_FLOP_CUTOFF {
        BlockedParams { threads: 1, ..params }
    } else {
        params
    }
}

impl Fallback {
    fn gemm_point(&self, meta: &ArtifactMeta) -> GemmPoint {
        if self.explicit {
            self.gemm
        } else {
            GemmPoint {
                params: heuristic_params(self.gemm.params, meta.flops),
                ..self.gemm
            }
        }
    }

    fn conv_point(&self, meta: &ArtifactMeta) -> ConvPoint {
        self.conv
            .unwrap_or_else(|| ConvPoint::im2col(self.gemm_point(meta).params))
    }
}

/// The one generic rung of the resolution ladder: the tuned point of
/// space `P` for this artifact's problem class, when the attached DB has
/// one.  Unified and legacy entry kinds both answer — the DB's
/// per-space migration shims decode `blocked` entries for [`GemmPoint`]
/// lookups and `conv_native`/`blocked` entries for [`ConvPoint`]
/// lookups — so one ladder serves every space, old DBs included.
fn resolve_point<P: KernelSpace>(
    meta: &ArtifactMeta,
    tuning: Option<&SelectionDb>,
    device: &str,
) -> Option<(P, bool)> {
    let db = tuning?;
    let key = selection_key_for(meta, device)?;
    let (point, _gflops) = db.get::<P>(&key)?;
    // A *migrated* entry decoded through a legacy kind: absent knobs
    // were filled with defaults by the shim, not tuned — the plan layer
    // clamps those defaults where a measured value would not be.
    let legacy = db
        .stored(&key)
        .map(|s| s.kind() != P::KIND)
        .unwrap_or(false);
    Some((point, legacy))
}

/// The migrated-entry clamp: legacy `blocked`/`conv_native` entries
/// written before the `threads` axis existed decode as `threads: 0`
/// (auto).  A *tuned* auto is honored verbatim, but a migration-filled
/// auto on a problem under [`SMALL_PROBLEM_FLOP_CUTOFF`] would silently
/// bypass the small-problem serial heuristic and pay the fan-out/join
/// overhead the cutoff exists to avoid — so it clamps to 1.
fn clamp_migrated_auto(
    params: BlockedParams,
    legacy: bool,
    flops: u64,
) -> BlockedParams {
    if legacy && params.threads == 0 && flops < SMALL_PROBLEM_FLOP_CUTOFF {
        BlockedParams { threads: 1, ..params }
    } else {
        params
    }
}

/// Whether two plans for the *same artifact* resolve to the same kernel.
/// The shape halves come from manifest metadata (identical for one
/// artifact), so plan identity reduces to the resolved space point —
/// including the conv algorithm, which [`conv_plan`] resolves into
/// `point.config`.
fn plans_equivalent(a: &Plan, b: &Plan) -> bool {
    match (a, b) {
        (Plan::Gemm { point: pa, .. }, Plan::Gemm { point: pb, .. }) => {
            pa == pb
        }
        (Plan::Conv { point: pa, .. }, Plan::Conv { point: pb, .. }) => {
            pa == pb
        }
        _ => false,
    }
}

fn build_plan(
    meta: &ArtifactMeta,
    fallback: &Fallback,
    tuning: Option<&SelectionDb>,
    device: &str,
) -> Result<Plan> {
    match meta.kind.as_str() {
        "gemm" => {
            let point = resolve_point::<GemmPoint>(meta, tuning, device)
                .map(|(p, legacy)| GemmPoint {
                    params: clamp_migrated_auto(p.params, legacy, meta.flops),
                    ..p
                })
                .unwrap_or_else(|| fallback.gemm_point(meta))
                // Plan-time safety: an ISA this host lacks (an off-host
                // DB entry) degrades to the scalar micro-kernel, same
                // blocking, so what the plan reports is executable.
                .host_degraded();
            // The precision analogue of the ISA degrade: an `i8` point
            // needs the artifact's quantization metadata (scales +
            // zero-points) to execute; without it the plan keeps the
            // tuned blocking/ISA and falls back to the f32 kernels.
            let point = if point.dtype == Dtype::I8 && meta.quant.is_none()
            {
                GemmPoint { dtype: Dtype::F32, ..point }
            } else {
                point
            };
            gemm_plan(meta, point)
        }
        "conv" => {
            let point = resolve_point::<ConvPoint>(meta, tuning, device)
                .map(|(p, legacy)| ConvPoint {
                    blocked: clamp_migrated_auto(p.blocked, legacy, meta.flops),
                    ..p
                })
                .unwrap_or_else(|| fallback.conv_point(meta))
                // Plan-time safety: an ISA this host lacks degrades the
                // lowered-GEMM micro-kernel to scalar, same blocking and
                // algorithm, so what the plan reports is executable.
                .host_degraded();
            // Precision degrade, same rule as the GEMM arm: no quant
            // metadata on the artifact → `i8` points plan as `f32`.
            let point = if point.dtype == Dtype::I8 && meta.quant.is_none()
            {
                ConvPoint { dtype: Dtype::F32, ..point }
            } else {
                point
            };
            conv_plan(meta, point)
        }
        other => Err(Error::Runtime(format!(
            "{}: unknown op kind {other:?} — the native backend executes \
             \"gemm\" and \"conv\" artifacts only",
            meta.name
        ))),
    }
}

/// The pure-Rust execution engine: an artifact store plus a plan cache.
///
/// Planning happens once per artifact (first use or [`Backend::warm`]);
/// the request path is hash-lookup + kernel dispatch, mirroring the PJRT
/// engine's compile-once/execute-many shape.
pub struct NativeEngine {
    store: ArtifactStore,
    plans: HashMap<String, Plan>,
    fallback: Fallback,
    /// Per-host tuning DB (`tuner::tune_space_sweep` output; legacy
    /// sweep DBs load too).  When present, plans resolve their space
    /// point — including the conv algorithm and the GEMM ISA — from it.
    /// Held behind an `Arc` so every actor of an engine pool shares one
    /// read-only copy instead of cloning the DB per actor.
    tuning: Option<Arc<SelectionDb>>,
    /// Platform string tuned selections are keyed under.
    device: String,
    /// The engine's workspace arena: every kernel temporary (packing
    /// panels, im2col matrices, Winograd transform buffers, i8 quantize
    /// staging) is checked out of here.  [`NativeEngine::plan`] prewarms
    /// it with each new plan's worst-case [`Workspace`], so steady-state
    /// execution performs zero kernel-scratch allocations per request.
    /// One arena per engine means one arena per pool actor.
    scratch: Scratch,
}

impl NativeEngine {
    /// Create a native engine over an artifact store.
    pub fn new(store: ArtifactStore) -> Result<Self> {
        Ok(Self {
            store,
            plans: HashMap::new(),
            fallback: Fallback {
                gemm: GemmPoint::default(),
                explicit: false,
                conv: None,
            },
            tuning: None,
            device: HOST_DEVICE.to_string(),
            scratch: Scratch::new(),
        })
    }

    /// Create an engine with explicit host blocking parameters (the CPU
    /// analogue of picking a kernel configuration per device).  Explicit
    /// params are used verbatim — the small-problem threads heuristic
    /// only shapes the built-in defaults.
    pub fn with_params(store: ArtifactStore, params: BlockedParams) -> Self {
        Self {
            store,
            plans: HashMap::new(),
            fallback: Fallback {
                gemm: GemmPoint::scalar(params),
                explicit: true,
                conv: None,
            },
            tuning: None,
            device: HOST_DEVICE.to_string(),
            scratch: Scratch::new(),
        }
    }

    /// Create an engine that consults a per-host tuning DB at plan time:
    /// artifacts whose problem class has a measured winner execute with
    /// the tuned parameters — for conv problems including the winning
    /// *algorithm* — the rest with the defaults.  This is the deployment
    /// shape: run the sweep once per host, ship the DB.
    pub fn with_tuning(store: ArtifactStore, tuning: SelectionDb) -> Self {
        Self::with_shared_tuning(store, Arc::new(tuning))
    }

    /// Like [`NativeEngine::with_tuning`], but sharing an existing
    /// reference-counted DB.  This is how an engine pool gives all of
    /// its actors one read-only copy of the host selections, so every
    /// actor plans with the same tuned parameters at zero per-actor
    /// memory cost.
    pub fn with_shared_tuning(
        store: ArtifactStore,
        tuning: Arc<SelectionDb>,
    ) -> Self {
        Self {
            store,
            plans: HashMap::new(),
            fallback: Fallback {
                gemm: GemmPoint::default(),
                explicit: false,
                conv: None,
            },
            tuning: Some(tuning),
            device: HOST_DEVICE.to_string(),
            scratch: Scratch::new(),
        }
    }

    /// Replace the fallback GEMM space point (blocking + ISA).
    /// Invalidates the plan cache — plans embed the point they resolved.
    /// Explicitly set points bypass the small-problem threads heuristic
    /// (this is what lets the tuner measure `threads: 0` and SIMD grid
    /// points on small shapes).
    pub fn set_gemm_point(&mut self, point: GemmPoint) {
        self.fallback.gemm = point;
        self.fallback.explicit = true;
        self.plans.clear();
    }

    /// Legacy typed view of [`NativeEngine::set_gemm_point`]: replace
    /// the fallback blocking parameters with a scalar-ISA point.
    pub fn set_params(&mut self, params: BlockedParams) {
        self.set_gemm_point(GemmPoint::scalar(params));
    }

    /// Set the engine-wide conv override: the full conv space point
    /// (algorithm + tile/vector knobs + lowered-GEMM blocking + ISA)
    /// every conv plan without a tuned DB entry resolves to.  Invalidates the plan
    /// cache.  This is the handle the measured conv sweep drives
    /// (`tuner::tune_space_sweep`); shapes an algorithm cannot compute
    /// still fall back to im2col at plan time.
    pub fn set_conv_point(&mut self, point: ConvPoint) {
        self.fallback.conv = Some(point);
        self.plans.clear();
    }

    /// Legacy typed view of [`NativeEngine::set_conv_point`]: a
    /// scalar-ISA conv point.
    pub fn set_conv_params(
        &mut self,
        config: ConvConfig,
        blocked: BlockedParams,
    ) {
        self.set_conv_point(ConvPoint {
            config,
            blocked,
            isa: Isa::Scalar,
            dtype: Dtype::F32,
            pack: Pack::A,
        });
    }

    /// Attach (or replace) the tuning DB.  Invalidates the plan cache.
    pub fn set_tuning(&mut self, tuning: SelectionDb) {
        self.tuning = Some(Arc::new(tuning));
        self.plans.clear();
    }

    /// Install a new tuning snapshot *selectively*: every cached plan is
    /// re-resolved under the incoming DB and only the entries whose
    /// resolved point actually changed are dropped — the epoch-swap
    /// contract.  An online re-tune that promotes one hot shape class
    /// must not force a serving actor to re-plan its whole working set.
    /// Returns the number of plans invalidated.
    pub fn swap_tuning_selective(&mut self, next: Arc<SelectionDb>) -> usize {
        let mut dropped: Vec<String> = Vec::new();
        for (name, plan) in &self.plans {
            let unchanged = match self.store.get(name) {
                Ok(meta) => build_plan(
                    meta,
                    &self.fallback,
                    Some(&next),
                    &self.device,
                )
                .map(|fresh| plans_equivalent(plan, &fresh))
                .unwrap_or(false),
                Err(_) => false,
            };
            if !unchanged {
                dropped.push(name.clone());
            }
        }
        for name in &dropped {
            self.plans.remove(name);
        }
        self.tuning = Some(next);
        dropped.len()
    }

    /// The fallback GEMM space point currently configured.
    pub fn gemm_point(&self) -> GemmPoint {
        self.fallback.gemm
    }

    /// The fallback blocking parameters currently configured (the
    /// blocking half of [`NativeEngine::gemm_point`]).
    pub fn params(&self) -> BlockedParams {
        self.fallback.gemm.params
    }

    /// The engine-wide conv override, if one was set (legacy tuple view
    /// of the stored [`ConvPoint`]).
    pub fn conv_params(&self) -> Option<(ConvConfig, BlockedParams)> {
        self.fallback.conv.map(|p| (p.config, p.blocked))
    }

    /// The blocking parameters artifact `name` will execute with —
    /// plans it if needed.  This is how tests and reports demonstrate
    /// that a tuned selection is actually consulted.  (Thin typed view:
    /// for GEMM artifacts this is the blocking half of
    /// [`NativeEngine::planned_gemm`], for conv artifacts the blocking
    /// half of the resolved conv point.)
    pub fn planned_params(&mut self, name: &str) -> Result<BlockedParams> {
        Ok(self.plan(name)?.params())
    }

    /// The full GEMM space point artifact `name` will execute with —
    /// `None` for non-GEMM artifacts.  The ISA field is post-degrade:
    /// it names the micro-kernel variant that will *really* run on this
    /// host, even when the tuned DB entry asked for one the CPU lacks.
    pub fn planned_gemm(&mut self, name: &str) -> Result<Option<GemmPoint>> {
        Ok(self.plan(name)?.gemm_point())
    }

    /// The conv configuration artifact `name` will execute with —
    /// `None` for non-conv artifacts.  The `algorithm` field is the
    /// *resolved* one (post im2col fallback), so this is the ground
    /// truth for "which algorithm won" in tests and tuning reports.
    pub fn planned_conv(&mut self, name: &str) -> Result<Option<ConvConfig>> {
        Ok(self.plan(name)?.conv_config())
    }

    /// The full conv space point artifact `name` will execute with —
    /// `None` for non-conv artifacts.  Like
    /// [`NativeEngine::planned_gemm`], every field is post-degrade: the
    /// ISA and dtype name what will really run on this host against
    /// this artifact's metadata.
    pub fn planned_conv_point(
        &mut self,
        name: &str,
    ) -> Result<Option<ConvPoint>> {
        Ok(self.plan(name)?.conv_point())
    }

    /// The worst-case kernel-scratch footprint (bytes) of one execution
    /// of artifact `name` under its resolved plan — what the plan-time
    /// prewarm sized the arena for.  Zero for kernels that stage nothing
    /// (e.g. the tiled direct conv).
    pub fn planned_workspace_bytes(&mut self, name: &str) -> Result<usize> {
        Ok(self.plan(name)?.workspace().bytes())
    }

    /// Snapshot of this engine's arena counters (checkout hits, growth
    /// reallocations, bytes high-water) — the serving observability
    /// surface.  A flat `grows` across requests is the zero-alloc
    /// steady-state invariant.
    pub fn scratch_stats(&self) -> ScratchStats {
        self.scratch.stats()
    }

    /// Plan (or fetch the cached plan for) an artifact.
    fn plan(&mut self, name: &str) -> Result<Plan> {
        if let Some(plan) = self.plans.get(name) {
            return Ok(plan.clone());
        }
        let meta = self.store.get(name)?;
        let plan = build_plan(
            meta,
            &self.fallback,
            self.tuning.as_deref(),
            &self.device,
        )?;
        // Grow the arena to the new plan's worst case *now* (warm time),
        // so the request path never pays a kernel-scratch allocation.
        self.scratch.prewarm(plan.workspace());
        self.plans.insert(name.to_string(), plan.clone());
        Ok(plan)
    }

    fn execute(&self, plan: &Plan, inputs: &[Vec<f32>]) -> Vec<Vec<f32>> {
        match plan {
            Plan::Gemm {
                m, n, k, alpha, beta, with_c, point, quant, ..
            } => {
                // The i8 fast path: quantize the f32 operands with the
                // artifact's per-tensor params (staging the quantized
                // copies in the arena), run the widening-kernel GEMM,
                // dequantize in the epilogue.  `build_plan` guarantees
                // `quant` is present for i8 plans.
                let mut out = if point.dtype == Dtype::I8 {
                    let q = quant.expect("i8 plan carries quant metadata");
                    let mut aq = self.scratch.take_i8(inputs[0].len());
                    quantize_into(&inputs[0], &q.a, &mut aq);
                    let mut bq = self.scratch.take_i8(inputs[1].len());
                    quantize_into(&inputs[1], &q.b, &mut bq);
                    let out = gemm_i8_dequant_ex(
                        &aq,
                        &bq,
                        *m,
                        *n,
                        *k,
                        &q.a,
                        &q.b,
                        &point.params,
                        point.isa,
                        point.pack,
                        &self.scratch,
                    );
                    self.scratch.put_i8(bq);
                    self.scratch.put_i8(aq);
                    out
                } else {
                    gemm_blocked_ex(
                        &inputs[0],
                        &inputs[1],
                        *m,
                        *n,
                        *k,
                        &point.params,
                        point.isa,
                        point.pack,
                        &self.scratch,
                    )
                };
                if *with_c {
                    for (o, c) in out.iter_mut().zip(&inputs[2]) {
                        *o = alpha * *o + beta * c;
                    }
                } else if *alpha != 1.0 {
                    for o in out.iter_mut() {
                        *o *= alpha;
                    }
                }
                vec![out]
            }
            Plan::Conv { shape, fuse_relu, point, quant, .. } => {
                let mut out = if point.dtype == Dtype::I8 {
                    let q = quant.expect("i8 plan carries quant metadata");
                    conv2d_im2col_i8_ex(
                        &inputs[0],
                        &inputs[1],
                        shape,
                        &q.a,
                        &q.b,
                        &point.blocked,
                        point.isa,
                        point.pack,
                        &self.scratch,
                    )
                } else {
                    conv2d_native_ex(
                        &inputs[0],
                        &inputs[1],
                        shape,
                        &point.config,
                        &point.blocked,
                        point.isa,
                        point.pack,
                        &self.scratch,
                    )
                };
                if *fuse_relu {
                    let bias = &inputs[2];
                    for (i, o) in out.iter_mut().enumerate() {
                        *o = (*o + bias[i % shape.out_c]).max(0.0);
                    }
                }
                vec![out]
            }
        }
    }
}

impl Backend for NativeEngine {
    fn platform(&self) -> String {
        "native-cpu (pure-Rust reference kernels)".to_string()
    }

    fn store(&self) -> &ArtifactStore {
        &self.store
    }

    fn warm(&mut self, name: &str) -> Result<()> {
        self.plan(name).map(|_| ())
    }

    fn cached(&self) -> usize {
        self.plans.len()
    }

    fn run(&mut self, name: &str, inputs: &[Vec<f32>]) -> Result<RunOutput> {
        let plan = self.plan(name)?;
        check_inputs(self.store.get(name)?, inputs)?;
        let start = Instant::now();
        let outputs = self.execute(&plan, inputs);
        let elapsed = start.elapsed();
        Ok(RunOutput { outputs, elapsed })
    }

    fn swap_tuning(&mut self, db: Arc<SelectionDb>) -> bool {
        self.swap_tuning_selective(db);
        true
    }

    fn scratch_stats(&self) -> ScratchStats {
        NativeEngine::scratch_stats(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blas::{conv2d_direct, gemm_naive, max_abs_diff};
    use crate::util::rng::XorShift;
    use crate::util::tmp::TempDir;
    use std::path::Path;

    fn write_manifest(dir: &Path, artifacts: &str) {
        std::fs::write(
            dir.join("manifest.json"),
            format!(r#"{{"version": 1, "artifacts": {artifacts}}}"#),
        )
        .unwrap();
    }

    fn engine_with(artifacts: &str) -> (TempDir, NativeEngine) {
        let dir = TempDir::new("native").unwrap();
        write_manifest(dir.path(), artifacts);
        let store = ArtifactStore::open(dir.path()).unwrap();
        let engine = NativeEngine::new(store).unwrap();
        (dir, engine)
    }

    const GEMM_8: &str = r#"[{
        "name": "g8", "kind": "gemm", "impl": "pallas",
        "file": "g8.hlo.txt", "flops": 1024,
        "m": 8, "n": 8, "k": 8,
        "inputs": [{"shape": [8, 8], "dtype": "float32"},
                   {"shape": [8, 8], "dtype": "float32"}],
        "groups": ["gemm"]}]"#;

    #[test]
    fn plan_cache_hit_and_miss() {
        let (_dir, mut e) = engine_with(GEMM_8);
        assert_eq!(e.cached(), 0, "fresh engine has an empty cache");
        e.warm("g8").unwrap();
        assert_eq!(e.cached(), 1, "first warm is a miss that fills");
        e.warm("g8").unwrap();
        assert_eq!(e.cached(), 1, "second warm must hit the cache");
        let inputs = e.synth_inputs("g8", 1).unwrap();
        e.run("g8", &inputs).unwrap();
        assert_eq!(e.cached(), 1, "run reuses the cached plan");
        assert!(e.warm("missing").is_err());
        assert_eq!(e.cached(), 1);
    }

    #[test]
    fn gemm_matches_naive_oracle() {
        let (_dir, mut e) = engine_with(GEMM_8);
        let mut rng = XorShift::new(3);
        let a = rng.f32_vec(64);
        let b = rng.f32_vec(64);
        let out = e.run("g8", &[a.clone(), b.clone()]).unwrap();
        let expected = gemm_naive(&a, &b, 8, 8, 8);
        assert!(max_abs_diff(&out.outputs[0], &expected) < 1e-4);
    }

    #[test]
    fn gemm_alpha_beta_epilogue() {
        let (_dir, mut e) = engine_with(
            r#"[{
            "name": "gab", "kind": "gemm", "impl": "pallas",
            "file": "gab.hlo.txt", "flops": 100,
            "m": 4, "n": 6, "k": 5, "alpha": 1.5, "beta": 0.5,
            "inputs": [{"shape": [4, 5], "dtype": "float32"},
                       {"shape": [5, 6], "dtype": "float32"},
                       {"shape": [4, 6], "dtype": "float32"}],
            "groups": ["gemm"]}]"#,
        );
        let mut rng = XorShift::new(4);
        let a = rng.f32_vec(20);
        let b = rng.f32_vec(30);
        let c = rng.f32_vec(24);
        let out = e.run("gab", &[a.clone(), b.clone(), c.clone()]).unwrap();
        let ab = gemm_naive(&a, &b, 4, 6, 5);
        let expected: Vec<f32> =
            ab.iter().zip(&c).map(|(x, y)| 1.5 * x + 0.5 * y).collect();
        assert!(max_abs_diff(&out.outputs[0], &expected) < 1e-4);
    }

    #[test]
    fn conv_matches_direct_oracle() {
        let (_dir, mut e) = engine_with(
            r#"[{
            "name": "c1", "kind": "conv", "impl": "pallas",
            "file": "c1.hlo.txt", "flops": 99, "batch": 2,
            "algorithm": "im2col",
            "layer": {"name": "smoke", "window": 3, "stride": 1,
                      "in_h": 6, "in_w": 6, "in_c": 3, "out_c": 4,
                      "out_h": 6, "out_w": 6, "padding": "SAME",
                      "flops": 99},
            "inputs": [{"shape": [2, 6, 6, 3], "dtype": "float32"},
                       {"shape": [3, 3, 3, 4], "dtype": "float32"}],
            "groups": ["conv"]}]"#,
        );
        let inputs = e.synth_inputs("c1", 7).unwrap();
        let out = e.run("c1", &inputs).unwrap();
        let shape = Conv2dShape::same(2, 6, 6, 3, 4, 3, 1);
        let expected = conv2d_direct(&inputs[0], &inputs[1], &shape);
        assert!(max_abs_diff(&out.outputs[0], &expected) < 1e-4);
        assert_eq!(out.outputs[0].len(), 2 * 6 * 6 * 4);
    }

    #[test]
    fn conv_fused_bias_relu_epilogue() {
        // Mirrors aot.py's `network`-group lowering: conv + bias + ReLU,
        // bias as a third input over output channels.
        let (_dir, mut e) = engine_with(
            r#"[{
            "name": "cf", "kind": "conv", "impl": "pallas",
            "file": "cf.hlo.txt", "flops": 10, "batch": 1,
            "algorithm": "im2col", "fuse_relu": true,
            "layer": {"name": "fused", "window": 1, "stride": 1,
                      "in_h": 4, "in_w": 4, "in_c": 2, "out_c": 3,
                      "out_h": 4, "out_w": 4, "padding": "SAME",
                      "flops": 10},
            "inputs": [{"shape": [1, 4, 4, 2], "dtype": "float32"},
                       {"shape": [1, 1, 2, 3], "dtype": "float32"},
                       {"shape": [3], "dtype": "float32"}],
            "groups": ["network"]}]"#,
        );
        let inputs = e.synth_inputs("cf", 21).unwrap();
        let out = e.run("cf", &inputs).unwrap();
        let shape = Conv2dShape::same(1, 4, 4, 2, 3, 1, 1);
        let conv = conv2d_direct(&inputs[0], &inputs[1], &shape);
        let expected: Vec<f32> = conv
            .iter()
            .enumerate()
            .map(|(i, v)| (v + inputs[2][i % 3]).max(0.0))
            .collect();
        assert!(max_abs_diff(&out.outputs[0], &expected) < 1e-4);
        // ReLU actually clamps something (inputs are centered, so some
        // outputs go negative pre-clamp).
        assert!(out.outputs[0].iter().any(|v| *v == 0.0));
    }

    #[test]
    fn unknown_op_kind_is_a_loud_error_not_a_panic() {
        let (_dir, mut e) = engine_with(
            r#"[{
            "name": "mystery", "kind": "fft", "impl": "pallas",
            "file": "mystery.hlo.txt", "flops": 1,
            "inputs": [], "groups": []}]"#,
        );
        let err = e.run("mystery", &[]).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("unknown op kind"), "got: {msg}");
        assert!(msg.contains("fft"), "names the offending kind: {msg}");
        assert!(matches!(err, Error::Runtime(_)));
        assert_eq!(e.cached(), 0, "failed plans are not cached");
    }

    #[test]
    fn input_validation_mirrors_pjrt() {
        let (_dir, mut e) = engine_with(GEMM_8);
        // Wrong arity.
        assert!(e.run("g8", &[vec![0.0; 64]]).is_err());
        // Wrong element count.
        assert!(e.run("g8", &[vec![0.0; 7], vec![0.0; 64]]).is_err());
        // Unknown artifact.
        assert!(e.run("no_such_artifact", &[]).is_err());
    }

    #[test]
    fn malformed_conv_geometry_is_an_error_not_a_panic() {
        // VALID window larger than the input used to underflow in
        // Conv2dShape::valid; it must surface as Error::Artifact.
        let (_dir, mut e) = engine_with(
            r#"[{
            "name": "cbad", "kind": "conv", "impl": "pallas",
            "file": "cbad.hlo.txt", "flops": 1, "batch": 1,
            "layer": {"name": "bad", "window": 5, "stride": 1,
                      "in_h": 3, "in_w": 3, "in_c": 1, "out_c": 1,
                      "out_h": 1, "out_w": 1, "padding": "VALID",
                      "flops": 1},
            "inputs": [], "groups": []}]"#,
        );
        let msg = e.warm("cbad").unwrap_err().to_string();
        assert!(msg.contains("VALID padding needs"), "got: {msg}");
        // Zero dimensions are rejected the same way.
        let (_dir2, mut e2) = engine_with(
            r#"[{
            "name": "czero", "kind": "conv", "impl": "pallas",
            "file": "czero.hlo.txt", "flops": 1, "batch": 1,
            "layer": {"name": "z", "window": 3, "stride": 0,
                      "in_h": 8, "in_w": 8, "in_c": 4, "out_c": 4,
                      "out_h": 8, "out_w": 8, "padding": "SAME",
                      "flops": 1},
            "inputs": [], "groups": []}]"#,
        );
        assert!(e2.warm("czero").is_err());
    }

    #[test]
    fn fused_conv_with_wrong_bias_shape_rejected_at_plan_time() {
        let (_dir, mut e) = engine_with(
            r#"[{
            "name": "cfbad", "kind": "conv", "impl": "pallas",
            "file": "cfbad.hlo.txt", "flops": 1, "batch": 1,
            "fuse_relu": true,
            "layer": {"name": "fb", "window": 1, "stride": 1,
                      "in_h": 4, "in_w": 4, "in_c": 2, "out_c": 3,
                      "out_h": 4, "out_w": 4, "padding": "SAME",
                      "flops": 1},
            "inputs": [{"shape": [1, 4, 4, 2], "dtype": "float32"},
                       {"shape": [1, 1, 2, 3], "dtype": "float32"},
                       {"shape": [2], "dtype": "float32"}],
            "groups": []}]"#,
        );
        let msg = e.warm("cfbad").unwrap_err().to_string();
        assert!(msg.contains("bias"), "got: {msg}");
    }

    #[test]
    fn planned_entries_use_tuned_params_over_defaults() {
        use crate::tuner::{SelectionDb, SelectionKey};

        // A tuning DB holding a distinctive winner for g8's problem
        // class (8^3 buckets to the 64^3 class).
        let tuned =
            BlockedParams { bm: 8, bn: 8, bk: 8, mr: 2, nr: 2, threads: 1 };
        let mut db = SelectionDb::new();
        db.put(
            SelectionKey::gemm(HOST_DEVICE, 8, 8, 8),
            crate::config::GemmPoint::scalar(tuned),
            9.0,
        );
        let (_dir, plain) = engine_with(GEMM_8);
        let mut e = NativeEngine::with_tuning(plain.store.clone(), db);
        assert_eq!(
            e.planned_params("g8").unwrap(),
            tuned,
            "plan must consult the tuning DB"
        );
        assert_ne!(tuned, BlockedParams::default());
        // The tuned plan still computes the right answer.
        let mut rng = XorShift::new(12);
        let a = rng.f32_vec(64);
        let b = rng.f32_vec(64);
        let out = e.run("g8", &[a.clone(), b.clone()]).unwrap();
        let expected = gemm_naive(&a, &b, 8, 8, 8);
        assert!(max_abs_diff(&out.outputs[0], &expected) < 1e-4);
    }

    #[test]
    fn shared_tuning_db_is_consulted_by_every_engine() {
        use crate::tuner::{SelectionDb, SelectionKey};

        // One Arc'd DB, many engines — the engine-pool sharing shape.
        let tuned =
            BlockedParams { bm: 8, bn: 8, bk: 8, mr: 2, nr: 2, threads: 1 };
        let mut db = SelectionDb::new();
        db.put(
            SelectionKey::gemm(HOST_DEVICE, 8, 8, 8),
            crate::config::GemmPoint::scalar(tuned),
            9.0,
        );
        let shared = Arc::new(db);
        let (_dir, plain) = engine_with(GEMM_8);
        let mut a = NativeEngine::with_shared_tuning(
            plain.store.clone(),
            Arc::clone(&shared),
        );
        let mut b = NativeEngine::with_shared_tuning(
            plain.store.clone(),
            Arc::clone(&shared),
        );
        assert_eq!(a.planned_params("g8").unwrap(), tuned);
        assert_eq!(b.planned_params("g8").unwrap(), tuned);
        assert_eq!(Arc::strong_count(&shared), 3, "one DB, shared by all");
    }

    #[test]
    fn untuned_entries_fall_back_to_engine_params() {
        use crate::tuner::{SelectionDb, SelectionKey};

        // DB tuned for a *different* problem class: g8 must fall back.
        // g8 is tiny (1024 flops), so the fallback is the default params
        // shaped by the small-problem heuristic: serial threads.
        let mut db = SelectionDb::new();
        db.put(
            SelectionKey::gemm(HOST_DEVICE, 512, 512, 512),
            crate::config::GemmPoint::scalar(BlockedParams {
                bm: 128, bn: 128, bk: 64, mr: 8, nr: 16, threads: 4,
            }),
            20.0,
        );
        let (_dir, plain) = engine_with(GEMM_8);
        let mut e = NativeEngine::with_tuning(plain.store.clone(), db);
        assert_eq!(
            e.planned_params("g8").unwrap(),
            BlockedParams { threads: 1, ..Default::default() }
        );
    }

    #[test]
    fn set_params_invalidates_cached_plans() {
        let (_dir, mut e) = engine_with(GEMM_8);
        e.warm("g8").unwrap();
        // Default fallback on a tiny problem: heuristic serial threads.
        assert_eq!(
            e.planned_params("g8").unwrap(),
            BlockedParams { threads: 1, ..Default::default() }
        );
        let small =
            BlockedParams { bm: 4, bn: 4, bk: 4, mr: 2, nr: 2, threads: 2 };
        e.set_params(small);
        assert_eq!(e.cached(), 0, "set_params must drop stale plans");
        assert_eq!(
            e.planned_params("g8").unwrap(),
            small,
            "re-planned entries must use the new params"
        );
        assert_eq!(e.params(), small);
    }

    #[test]
    fn small_problems_default_to_serial_threads() {
        // The heuristic cutoff: a tiny GEMM plans threads: 1, a big one
        // keeps auto threads — and the boundary is the manifest flops.
        let (_dir, mut e) = engine_with(
            r#"[{
            "name": "big", "kind": "gemm", "impl": "pallas",
            "file": "big.hlo.txt", "flops": 33554432,
            "m": 256, "n": 256, "k": 256,
            "inputs": [{"shape": [256, 256], "dtype": "float32"},
                       {"shape": [256, 256], "dtype": "float32"}],
            "groups": ["gemm"]},
           {"name": "tiny", "kind": "gemm", "impl": "pallas",
            "file": "tiny.hlo.txt", "flops": 1024,
            "m": 8, "n": 8, "k": 8,
            "inputs": [{"shape": [8, 8], "dtype": "float32"},
                       {"shape": [8, 8], "dtype": "float32"}],
            "groups": ["gemm"]}]"#,
        );
        let big_flops = e.store().get("big").unwrap().flops;
        assert!(big_flops >= SMALL_PROBLEM_FLOP_CUTOFF);
        assert_eq!(e.planned_params("tiny").unwrap().threads, 1);
        assert_eq!(
            e.planned_params("big").unwrap().threads,
            0,
            "above the cutoff the auto-threads default stands"
        );
    }

    #[test]
    fn explicit_params_bypass_the_small_problem_heuristic() {
        // with_params / set_params mean "I chose this": the heuristic
        // must not rewrite an explicit threads: 0 on a small problem
        // (this is how the tuner measures auto-threaded grid points).
        let (_dir, plain) = engine_with(GEMM_8);
        let mut e = NativeEngine::with_params(
            plain.store.clone(),
            BlockedParams::default(),
        );
        assert_eq!(e.planned_params("g8").unwrap().threads, 0);
        let (_dir2, mut e2) = engine_with(GEMM_8);
        e2.set_params(BlockedParams::default());
        assert_eq!(e2.planned_params("g8").unwrap().threads, 0);
    }

    #[test]
    fn tuner_selection_overrides_the_threads_heuristic() {
        use crate::tuner::{SelectionDb, SelectionKey};

        // A measured winner with threads: 4 on a problem the heuristic
        // would plan serially — the DB wins, verbatim.
        let tuned =
            BlockedParams { bm: 8, bn: 8, bk: 8, mr: 2, nr: 4, threads: 4 };
        let mut db = SelectionDb::new();
        db.put(
            SelectionKey::gemm(HOST_DEVICE, 8, 8, 8),
            crate::config::GemmPoint::scalar(tuned),
            2.0,
        );
        let (_dir, plain) = engine_with(GEMM_8);
        let mut e = NativeEngine::with_tuning(plain.store.clone(), db);
        assert_eq!(e.planned_params("g8").unwrap(), tuned);
    }

    /// A 3x3/stride-1 conv artifact (the winograd-eligible shape).
    const CONV_3X3: &str = r#"[{
        "name": "c33", "kind": "conv", "impl": "pallas",
        "file": "c33.hlo.txt", "flops": 55296, "batch": 1,
        "algorithm": "im2col", "groups": ["conv"],
        "layer": {"name": "c33", "window": 3, "stride": 1,
                  "in_h": 8, "in_w": 8, "in_c": 3, "out_c": 4,
                  "out_h": 8, "out_w": 8, "padding": "SAME",
                  "flops": 55296},
        "inputs": [{"shape": [1, 8, 8, 3], "dtype": "float32"},
                   {"shape": [3, 3, 3, 4], "dtype": "float32"}]}]"#;

    #[test]
    fn conv_plans_resolve_the_algorithm_from_the_db() {
        use crate::config::ConvAlgorithm;
        use crate::tuner::{SelectionDb, SelectionKey};

        let winner = ConvConfig::winograd(2);
        let blocked =
            BlockedParams { bm: 16, bn: 16, bk: 8, mr: 2, nr: 4, threads: 1 };
        let mut db = SelectionDb::new();
        db.put(
            SelectionKey::conv(HOST_DEVICE, 3, 1, 8, 8, 3, 4, 1),
            crate::config::ConvPoint {
                config: winner,
                blocked,
                isa: Isa::Scalar,
                dtype: Dtype::F32,
                pack: Pack::A,
            },
            4.0,
        );
        let (_dir, plain) = engine_with(CONV_3X3);
        let mut e = NativeEngine::with_tuning(plain.store.clone(), db);
        let planned = e.planned_conv("c33").unwrap().unwrap();
        assert_eq!(planned.algorithm, ConvAlgorithm::Winograd);
        assert_eq!(planned, winner);
        assert_eq!(e.planned_params("c33").unwrap(), blocked);
        // The winograd plan still computes the right answer.
        let inputs = e.synth_inputs("c33", 13).unwrap();
        let out = e.run("c33", &inputs).unwrap();
        let shape = Conv2dShape::same(1, 8, 8, 3, 4, 3, 1);
        let expected = conv2d_direct(&inputs[0], &inputs[1], &shape);
        assert!(max_abs_diff(&out.outputs[0], &expected) < 1e-3);
        // GEMM artifacts report no conv config.
        let (_dir2, mut g) = engine_with(GEMM_8);
        assert!(g.planned_conv("g8").unwrap().is_none());
    }

    #[test]
    fn legacy_blocked_conv_selection_resolves_as_im2col() {
        use crate::config::ConvAlgorithm;
        use crate::tuner::{SelectionDb, SelectionKey};

        // Pre-algorithm DBs stored conv winners as plain Blocked
        // entries; they must keep planning as im2col under those params.
        let params =
            BlockedParams { bm: 8, bn: 8, bk: 8, mr: 2, nr: 2, threads: 2 };
        let mut db = SelectionDb::new();
        db.put(
            SelectionKey::conv(HOST_DEVICE, 3, 1, 8, 8, 3, 4, 1),
            crate::config::GemmPoint::scalar(params),
            3.0,
        );
        let (_dir, plain) = engine_with(CONV_3X3);
        let mut e = NativeEngine::with_tuning(plain.store.clone(), db);
        let planned = e.planned_conv("c33").unwrap().unwrap();
        assert_eq!(planned.algorithm, ConvAlgorithm::Im2col);
        assert_eq!(e.planned_params("c33").unwrap(), params);
    }

    #[test]
    fn winograd_selection_falls_back_to_im2col_off_its_domain() {
        use crate::config::ConvAlgorithm;
        use crate::tuner::{SelectionDb, SelectionKey};

        // A strided conv with a (bogus) winograd selection: the plan
        // must resolve the fallback so what planned_conv reports is what
        // executes.
        let (_dir, plain) = engine_with(
            r#"[{
            "name": "cs2", "kind": "conv", "impl": "pallas",
            "file": "cs2.hlo.txt", "flops": 9216, "batch": 1,
            "layer": {"name": "s2", "window": 3, "stride": 2,
                      "in_h": 8, "in_w": 8, "in_c": 2, "out_c": 4,
                      "out_h": 4, "out_w": 4, "padding": "SAME",
                      "flops": 9216},
            "inputs": [{"shape": [1, 8, 8, 2], "dtype": "float32"},
                       {"shape": [3, 3, 2, 4], "dtype": "float32"}],
            "groups": ["conv"]}]"#,
        );
        let mut db = SelectionDb::new();
        db.put(
            SelectionKey::conv(HOST_DEVICE, 3, 2, 8, 8, 2, 4, 1),
            crate::config::ConvPoint {
                config: ConvConfig::winograd(2),
                blocked: BlockedParams::default(),
                isa: Isa::Scalar,
                dtype: Dtype::F32,
                pack: Pack::A,
            },
            1.0,
        );
        let mut e = NativeEngine::with_tuning(plain.store.clone(), db);
        let planned = e.planned_conv("cs2").unwrap().unwrap();
        assert_eq!(planned.algorithm, ConvAlgorithm::Im2col);
        let inputs = e.synth_inputs("cs2", 5).unwrap();
        let out = e.run("cs2", &inputs).unwrap();
        let shape = Conv2dShape::same(1, 8, 8, 2, 4, 3, 2);
        let expected = conv2d_direct(&inputs[0], &inputs[1], &shape);
        assert!(max_abs_diff(&out.outputs[0], &expected) < 1e-3);
    }

    #[test]
    fn set_conv_params_drives_the_dispatch() {
        use crate::config::ConvAlgorithm;

        let (_dir, mut e) = engine_with(CONV_3X3);
        // Default: im2col.
        assert_eq!(
            e.planned_conv("c33").unwrap().unwrap().algorithm,
            ConvAlgorithm::Im2col
        );
        // Engine-wide override: the tiled family.
        let cfg = ConvConfig::tiled(2, 2, 1, 4);
        let blocked =
            BlockedParams { threads: 1, ..BlockedParams::default() };
        e.set_conv_params(cfg, blocked);
        assert_eq!(e.cached(), 0, "set_conv_params must drop stale plans");
        assert_eq!(e.planned_conv("c33").unwrap().unwrap(), cfg);
        assert_eq!(e.conv_params(), Some((cfg, blocked)));
        let inputs = e.synth_inputs("c33", 23).unwrap();
        let out = e.run("c33", &inputs).unwrap();
        let shape = Conv2dShape::same(1, 8, 8, 3, 4, 3, 1);
        let expected = conv2d_direct(&inputs[0], &inputs[1], &shape);
        // The tiled path is bit-identical to the direct oracle.
        assert_eq!(out.outputs[0], expected);
    }

    #[test]
    fn tuned_gemm_point_resolves_isa_and_degrades_off_host() {
        use crate::blas::Isa;
        use crate::tuner::{SelectionDb, SelectionKey};

        let params =
            BlockedParams { bm: 8, bn: 8, bk: 8, mr: 2, nr: 4, threads: 1 };
        let key = SelectionKey::gemm(HOST_DEVICE, 8, 8, 8);

        // A selection with a host-supported SIMD ISA plans verbatim and
        // computes the right answer through the SIMD micro-kernel.
        if let Some(&simd) =
            Isa::detect().iter().find(|i| **i != Isa::Scalar)
        {
            let mut db = SelectionDb::new();
            let point = GemmPoint {
                params,
                isa: simd,
                dtype: Dtype::F32,
                pack: Pack::Ab,
            };
            db.put(key.clone(), point, 9.0);
            let (_dir, plain) = engine_with(GEMM_8);
            let mut e = NativeEngine::with_tuning(plain.store.clone(), db);
            let planned = e.planned_gemm("g8").unwrap().unwrap();
            assert_eq!(planned, point);
            assert_eq!(e.planned_params("g8").unwrap(), params);
            let mut rng = XorShift::new(31);
            let a = rng.f32_vec(64);
            let b = rng.f32_vec(64);
            let out = e.run("g8", &[a.clone(), b.clone()]).unwrap();
            let expected = gemm_naive(&a, &b, 8, 8, 8);
            assert!(max_abs_diff(&out.outputs[0], &expected) < 1e-4);
        }

        // A selection whose ISA this host lacks (an off-host DB entry)
        // degrades to scalar at plan time — same blocking, and the run
        // cannot hit the unavailable-ISA panic.
        if let Some(missing) =
            Isa::all().into_iter().find(|i| !i.is_available())
        {
            let mut db = SelectionDb::new();
            db.put(
                key.clone(),
                GemmPoint {
                    params,
                    isa: missing,
                    dtype: Dtype::F32,
                    pack: Pack::Ab,
                },
                9.0,
            );
            let (_dir, plain) = engine_with(GEMM_8);
            let mut e = NativeEngine::with_tuning(plain.store.clone(), db);
            let planned = e.planned_gemm("g8").unwrap().unwrap();
            assert_eq!(planned.isa, Isa::Scalar, "degraded at plan time");
            assert_eq!(planned.params, params, "blocking survives");
            let inputs = e.synth_inputs("g8", 3).unwrap();
            e.run("g8", &inputs).unwrap();
        }

        // Conv artifacts report no GEMM point.
        let (_dir, mut c) = engine_with(CONV_3X3);
        assert!(c.planned_gemm("c33").unwrap().is_none());
    }

    #[test]
    fn tuned_conv_point_resolves_isa_and_degrades_off_host() {
        use crate::tuner::{SelectionDb, SelectionKey};

        let blocked =
            BlockedParams { bm: 16, bn: 16, bk: 8, mr: 2, nr: 4, threads: 1 };
        let key = SelectionKey::conv(HOST_DEVICE, 3, 1, 8, 8, 3, 4, 1);
        let shape = Conv2dShape::same(1, 8, 8, 3, 4, 3, 1);

        // A conv selection with a host-supported SIMD ISA plans verbatim
        // and the lowered GEMM computes the right answer through the
        // SIMD micro-kernel.
        if let Some(&simd) =
            Isa::detect().iter().find(|i| **i != Isa::Scalar)
        {
            let point = ConvPoint {
                config: ConvConfig::im2col(),
                blocked,
                isa: simd,
                dtype: Dtype::F32,
                pack: Pack::Ab,
            };
            let mut db = SelectionDb::new();
            db.put(key.clone(), point, 9.0);
            let (_dir, plain) = engine_with(CONV_3X3);
            let mut e = NativeEngine::with_tuning(plain.store.clone(), db);
            assert_eq!(e.planned_params("c33").unwrap(), blocked);
            let inputs = e.synth_inputs("c33", 9).unwrap();
            let out = e.run("c33", &inputs).unwrap();
            let expected = conv2d_direct(&inputs[0], &inputs[1], &shape);
            assert!(max_abs_diff(&out.outputs[0], &expected) < 1e-3);
        }

        // A conv selection whose ISA this host lacks (an off-host DB
        // entry) degrades to scalar at plan time — the algorithm and
        // blocking survive, and the run cannot hit the unavailable-ISA
        // panic.
        if let Some(missing) =
            Isa::all().into_iter().find(|i| !i.is_available())
        {
            let point = ConvPoint {
                config: ConvConfig::winograd(2),
                blocked,
                isa: missing,
                dtype: Dtype::F32,
                pack: Pack::A,
            };
            let mut db = SelectionDb::new();
            db.put(key.clone(), point, 9.0);
            let (_dir, plain) = engine_with(CONV_3X3);
            let mut e = NativeEngine::with_tuning(plain.store.clone(), db);
            let planned = e.planned_conv("c33").unwrap().unwrap();
            assert_eq!(
                planned.algorithm,
                crate::config::ConvAlgorithm::Winograd,
                "the algorithm survives the ISA degrade"
            );
            assert_eq!(e.planned_params("c33").unwrap(), blocked);
            let inputs = e.synth_inputs("c33", 11).unwrap();
            let out = e.run("c33", &inputs).unwrap();
            let expected = conv2d_direct(&inputs[0], &inputs[1], &shape);
            assert!(max_abs_diff(&out.outputs[0], &expected) < 1e-3);
        }
    }

    #[test]
    fn tuned_wino4_selection_plans_and_computes() {
        use crate::config::ConvAlgorithm;
        use crate::tuner::{SelectionDb, SelectionKey};

        // An F(4×4, 3×3) winner on an in-domain shape plans as Winograd
        // with wino_m = 4 and matches the direct oracle within the
        // looser F(4×4) tolerance.
        let winner = ConvConfig::winograd(4);
        let mut db = SelectionDb::new();
        db.put(
            SelectionKey::conv(HOST_DEVICE, 3, 1, 8, 8, 3, 4, 1),
            ConvPoint {
                config: winner,
                blocked: BlockedParams::default(),
                isa: Isa::Scalar,
                dtype: Dtype::F32,
                pack: Pack::A,
            },
            6.0,
        );
        let (_dir, plain) = engine_with(CONV_3X3);
        let mut e = NativeEngine::with_tuning(plain.store.clone(), db);
        let planned = e.planned_conv("c33").unwrap().unwrap();
        assert_eq!(planned.algorithm, ConvAlgorithm::Winograd);
        assert_eq!(planned.wino_m, 4);
        let inputs = e.synth_inputs("c33", 17).unwrap();
        let out = e.run("c33", &inputs).unwrap();
        let shape = Conv2dShape::same(1, 8, 8, 3, 4, 3, 1);
        let expected = conv2d_direct(&inputs[0], &inputs[1], &shape);
        assert!(max_abs_diff(&out.outputs[0], &expected) < 5e-3);
    }

    #[test]
    fn legacy_blocked_db_fixture_plans_identically() {
        use crate::blas::Isa;
        use crate::tuner::SelectionDb;
        use crate::util::tmp::TempDir;

        // A byte-for-byte pre-unification DB file: the blocked entry
        // must plan exactly as it always did — those params, scalar
        // micro-kernel.
        let dir = TempDir::new("legacy-db").unwrap();
        let path = dir.path().join("old.json");
        std::fs::write(
            &path,
            r#"{"host::gemm_64x64x64": {"kind": "blocked", "gflops": 5.0,
                "config": {"bm": 8, "bn": 8, "bk": 8, "mr": 2, "nr": 2,
                           "threads": 2},
                "name": "bm8bn8bk8_2x2_t2"}}"#,
        )
        .unwrap();
        let db = SelectionDb::load(&path).unwrap();
        let (_dir2, plain) = engine_with(GEMM_8);
        let mut e = NativeEngine::with_tuning(plain.store.clone(), db);
        let want =
            BlockedParams { bm: 8, bn: 8, bk: 8, mr: 2, nr: 2, threads: 2 };
        assert_eq!(e.planned_params("g8").unwrap(), want);
        let planned = e.planned_gemm("g8").unwrap().unwrap();
        assert_eq!(
            planned,
            GemmPoint {
                params: want,
                isa: Isa::Scalar,
                dtype: Dtype::F32,
                pack: Pack::A,
            },
            "legacy entries decode as unpacked-B"
        );
    }

    #[test]
    fn set_gemm_point_drives_the_isa_dispatch() {
        use crate::blas::Isa;

        let (_dir, mut e) = engine_with(GEMM_8);
        // Default fallback: scalar.
        assert_eq!(
            e.planned_gemm("g8").unwrap().unwrap().isa,
            Isa::Scalar
        );
        // Engine-wide override with a detected ISA (scalar always
        // qualifies, so this runs on every host).
        let isa = *Isa::detect().last().unwrap();
        let point = GemmPoint {
            params: BlockedParams {
                bm: 8, bn: 8, bk: 8, mr: 2, nr: 4, threads: 1,
            },
            isa,
            dtype: Dtype::F32,
            pack: Pack::Ab,
        };
        e.set_gemm_point(point);
        assert_eq!(e.cached(), 0, "set_gemm_point must drop stale plans");
        assert_eq!(e.planned_gemm("g8").unwrap().unwrap(), point);
        assert_eq!(e.gemm_point(), point);
        assert_eq!(e.params(), point.params);
        let mut rng = XorShift::new(44);
        let a = rng.f32_vec(64);
        let b = rng.f32_vec(64);
        let out = e.run("g8", &[a.clone(), b.clone()]).unwrap();
        let expected = gemm_naive(&a, &b, 8, 8, 8);
        assert!(max_abs_diff(&out.outputs[0], &expected) < 1e-4);
    }

    #[test]
    fn gemm_artifact_missing_dims_reported() {
        let (_dir, mut e) = engine_with(
            r#"[{
            "name": "gx", "kind": "gemm", "impl": "pallas",
            "file": "gx.hlo.txt", "flops": 1,
            "inputs": [], "groups": []}]"#,
        );
        let msg = e.warm("gx").unwrap_err().to_string();
        assert!(msg.contains("missing m"), "got: {msg}");
    }

    /// Two GEMM artifacts in *different* problem classes, one under and
    /// one over the small-problem cutoff.
    const GEMM_SMALL_AND_BIG: &str = r#"[{
        "name": "g8", "kind": "gemm", "impl": "pallas",
        "file": "g8.hlo.txt", "flops": 1024,
        "m": 8, "n": 8, "k": 8,
        "inputs": [{"shape": [8, 8], "dtype": "float32"},
                   {"shape": [8, 8], "dtype": "float32"}],
        "groups": ["gemm"]},
       {"name": "g256", "kind": "gemm", "impl": "pallas",
        "file": "g256.hlo.txt", "flops": 33554432,
        "m": 256, "n": 256, "k": 256,
        "inputs": [{"shape": [256, 256], "dtype": "float32"},
                   {"shape": [256, 256], "dtype": "float32"}],
        "groups": ["gemm"]}]"#;

    #[test]
    fn swap_tuning_invalidates_only_changed_plans() {
        use crate::tuner::{SelectionDb, SelectionKey};

        let (_dir, mut e) = engine_with(GEMM_SMALL_AND_BIG);
        e.warm("g8").unwrap();
        e.warm("g256").unwrap();
        assert_eq!(e.cached(), 2);

        // A snapshot that promotes a new point only for g8's class.
        let tuned =
            BlockedParams { bm: 8, bn: 8, bk: 8, mr: 2, nr: 2, threads: 1 };
        let mut next = SelectionDb::new();
        next.put(
            SelectionKey::gemm(HOST_DEVICE, 8, 8, 8),
            GemmPoint::scalar(tuned),
            9.0,
        );
        let dropped = e.swap_tuning_selective(Arc::new(next));
        assert_eq!(dropped, 1, "only the promoted class re-plans");
        assert_eq!(e.cached(), 1, "g256's plan must survive the swap");
        assert_eq!(e.planned_params("g8").unwrap(), tuned);
        // A second swap to an identical DB drops nothing.
        let mut same = SelectionDb::new();
        same.put(
            SelectionKey::gemm(HOST_DEVICE, 8, 8, 8),
            GemmPoint::scalar(tuned),
            9.5,
        );
        let dropped = e.swap_tuning_selective(Arc::new(same));
        assert_eq!(dropped, 0, "same selections, no invalidation");
        assert_eq!(e.cached(), 2);
    }

    #[test]
    fn swap_tuning_via_backend_trait_applies() {
        use crate::tuner::SelectionDb;

        let (_dir, mut e) = engine_with(GEMM_8);
        let applied =
            Backend::swap_tuning(&mut e, Arc::new(SelectionDb::new()));
        assert!(applied, "the native engine consumes tuning snapshots");
    }

    #[test]
    fn migrated_auto_threads_clamp_below_cutoff() {
        use crate::tuner::SelectionDb;

        // A pre-unification `blocked` entry written before the `threads`
        // axis existed: the migration shim decodes absent threads as 0
        // (auto).  Below the cutoff that must clamp to serial — the
        // value was never measured, so it does not outrank the
        // small-problem heuristic.
        let dir = TempDir::new("legacy-clamp").unwrap();
        let path = dir.path().join("old.json");
        std::fs::write(
            &path,
            r#"{"host::gemm_64x64x64": {"kind": "blocked", "gflops": 5.0,
                "config": {"bm": 8, "bn": 8, "bk": 8, "mr": 2, "nr": 2}},
               "host::gemm_256x256x256": {"kind": "blocked", "gflops": 7.0,
                "config": {"bm": 32, "bn": 32, "bk": 32, "mr": 4, "nr": 8}}}"#,
        )
        .unwrap();
        let db = SelectionDb::load(&path).unwrap();
        let (_dir2, plain) = engine_with(GEMM_SMALL_AND_BIG);
        let mut e = NativeEngine::with_tuning(plain.store.clone(), db);
        let small = e.planned_params("g8").unwrap();
        assert_eq!(
            small,
            BlockedParams { bm: 8, bn: 8, bk: 8, mr: 2, nr: 2, threads: 1 },
            "migrated auto-threads under the cutoff clamps to serial"
        );
        // Above the cutoff the migrated auto stands — parallel is the
        // right default for big problems.
        let big = e.planned_params("g256").unwrap();
        assert_eq!(
            big,
            BlockedParams { bm: 32, bn: 32, bk: 32, mr: 4, nr: 8, threads: 0 },
            "migrated auto-threads above the cutoff stays auto"
        );
    }

    #[test]
    fn tuned_auto_threads_is_not_clamped() {
        use crate::tuner::{SelectionDb, SelectionKey};

        // A *unified* gemm_point entry with threads: 0 was measured that
        // way — the clamp applies to migration-filled defaults only.
        let tuned =
            BlockedParams { bm: 8, bn: 8, bk: 8, mr: 2, nr: 2, threads: 0 };
        let mut db = SelectionDb::new();
        db.put(
            SelectionKey::gemm(HOST_DEVICE, 8, 8, 8),
            GemmPoint::scalar(tuned),
            3.0,
        );
        let (_dir, plain) = engine_with(GEMM_8);
        let mut e = NativeEngine::with_tuning(plain.store.clone(), db);
        assert_eq!(
            e.planned_params("g8").unwrap().threads,
            0,
            "a measured auto-threads selection is honored verbatim"
        );
    }

    /// GEMM_8 with per-tensor quantization metadata: symmetric 1/256
    /// scales sized for the centered synthetic inputs.
    const GEMM_8_QUANT: &str = r#"[{
        "name": "g8q", "kind": "gemm", "impl": "pallas",
        "file": "g8q.hlo.txt", "flops": 1024,
        "m": 8, "n": 8, "k": 8,
        "quant": {"a": {"scale": 0.00390625, "zero_point": 0},
                  "b": {"scale": 0.00390625, "zero_point": -2}},
        "inputs": [{"shape": [8, 8], "dtype": "float32"},
                   {"shape": [8, 8], "dtype": "float32"}],
        "groups": ["gemm"]}]"#;

    #[test]
    fn i8_gemm_plan_degrades_to_f32_without_quant_metadata() {
        use crate::tuner::{SelectionDb, SelectionKey};

        // A tuned i8 winner against an artifact that carries no quant
        // metadata: the dtype degrades at plan time, the blocking and
        // ISA survive, and the run produces exact f32 results.
        let params =
            BlockedParams { bm: 8, bn: 8, bk: 8, mr: 2, nr: 4, threads: 1 };
        let mut db = SelectionDb::new();
        db.put(
            SelectionKey::gemm(HOST_DEVICE, 8, 8, 8),
            GemmPoint {
                params,
                isa: Isa::Scalar,
                dtype: Dtype::I8,
                pack: Pack::A,
            },
            9.0,
        );
        let (_dir, plain) = engine_with(GEMM_8);
        let mut e = NativeEngine::with_tuning(plain.store.clone(), db);
        let planned = e.planned_gemm("g8").unwrap().unwrap();
        assert_eq!(planned.dtype, Dtype::F32, "degraded at plan time");
        assert_eq!(planned.params, params, "blocking survives");
        let mut rng = XorShift::new(71);
        let a = rng.f32_vec(64);
        let b = rng.f32_vec(64);
        let out = e.run("g8", &[a.clone(), b.clone()]).unwrap();
        let expected = gemm_naive(&a, &b, 8, 8, 8);
        assert!(max_abs_diff(&out.outputs[0], &expected) < 1e-4);
    }

    #[test]
    fn i8_gemm_plan_executes_within_the_quantization_bound() {
        use crate::tuner::{SelectionDb, SelectionKey};

        let params =
            BlockedParams { bm: 8, bn: 8, bk: 8, mr: 2, nr: 4, threads: 1 };
        let mut db = SelectionDb::new();
        db.put(
            SelectionKey::gemm(HOST_DEVICE, 8, 8, 8),
            GemmPoint {
                params,
                isa: Isa::Scalar,
                dtype: Dtype::I8,
                pack: Pack::A,
            },
            9.0,
        );
        let (_dir, plain) = engine_with(GEMM_8_QUANT);
        let mut e = NativeEngine::with_tuning(plain.store.clone(), db);
        let planned = e.planned_gemm("g8q").unwrap().unwrap();
        assert_eq!(planned.dtype, Dtype::I8, "quant metadata present");
        let mut rng = XorShift::new(72);
        let a = rng.f32_vec(64);
        let b = rng.f32_vec(64);
        let out = e.run("g8q", &[a.clone(), b.clone()]).unwrap();
        let expected = gemm_naive(&a, &b, 8, 8, 8);
        // Quantization error bound: each product contributes up to
        // half-step rounding on each operand (inputs are in [-0.5, 0.5),
        // so |a|,|b| <= 0.5), summed over k = 8.
        let (sa, sb) = (0.00390625_f32, 0.00390625_f32);
        let bound = 8.0 * (0.25 * sa + 0.25 * sb + sa * sb) + 1e-5;
        assert!(
            max_abs_diff(&out.outputs[0], &expected) < bound,
            "i8 plan tracks the f32 oracle within the quant bound"
        );
    }

    #[test]
    fn i8_conv_plan_executes_and_degrades_without_quant() {
        use crate::tuner::{SelectionDb, SelectionKey};

        // CONV_3X3 plus quant metadata (zero-point'd input side so the
        // SAME-padding path is exercised in quantized space).
        let quantized = r#"[{
            "name": "c33q", "kind": "conv", "impl": "pallas",
            "file": "c33q.hlo.txt", "flops": 55296, "batch": 1,
            "algorithm": "im2col", "groups": ["conv"],
            "quant": {"a": {"scale": 0.00390625, "zero_point": 3},
                      "b": {"scale": 0.00390625, "zero_point": 0}},
            "layer": {"name": "c33q", "window": 3, "stride": 1,
                      "in_h": 8, "in_w": 8, "in_c": 3, "out_c": 4,
                      "out_h": 8, "out_w": 8, "padding": "SAME",
                      "flops": 55296},
            "inputs": [{"shape": [1, 8, 8, 3], "dtype": "float32"},
                       {"shape": [3, 3, 3, 4], "dtype": "float32"}]}]"#;
        let point = ConvPoint {
            config: ConvConfig::im2col(),
            blocked: BlockedParams {
                bm: 16, bn: 16, bk: 8, mr: 2, nr: 4, threads: 1,
            },
            isa: Isa::Scalar,
            dtype: Dtype::I8,
            pack: Pack::Ab,
        };
        let key = SelectionKey::conv(HOST_DEVICE, 3, 1, 8, 8, 3, 4, 1);

        let mut db = SelectionDb::new();
        db.put(key.clone(), point, 9.0);
        let (_dir, plain) = engine_with(quantized);
        let mut e = NativeEngine::with_tuning(plain.store.clone(), db);
        let planned = e.planned_conv_point("c33q").unwrap().unwrap();
        assert_eq!(planned.dtype, Dtype::I8);
        let inputs = e.synth_inputs("c33q", 29).unwrap();
        let out = e.run("c33q", &inputs).unwrap();
        let shape = Conv2dShape::same(1, 8, 8, 3, 4, 3, 1);
        let expected = conv2d_direct(&inputs[0], &inputs[1], &shape);
        // k_eff = 3·3·3 = 27 accumulated products per output.
        let (sa, sb) = (0.00390625_f32, 0.00390625_f32);
        let bound = 27.0 * (0.25 * sa + 0.25 * sb + sa * sb) + 1e-5;
        assert!(
            max_abs_diff(&out.outputs[0], &expected) < bound,
            "i8 conv plan tracks the direct oracle within the quant bound"
        );

        // The same i8 selection against the quant-less CONV_3X3 artifact
        // degrades to f32 — algorithm, blocking, and ISA survive.
        let mut db2 = SelectionDb::new();
        db2.put(key, point, 9.0);
        let (_dir2, plain2) = engine_with(CONV_3X3);
        let mut e2 = NativeEngine::with_tuning(plain2.store.clone(), db2);
        let planned2 = e2.planned_conv_point("c33").unwrap().unwrap();
        assert_eq!(planned2.dtype, Dtype::F32, "degraded at plan time");
        assert_eq!(planned2.blocked, point.blocked, "blocking survives");
        let inputs2 = e2.synth_inputs("c33", 31).unwrap();
        let out2 = e2.run("c33", &inputs2).unwrap();
        let expected2 = conv2d_direct(&inputs2[0], &inputs2[1], &shape);
        assert!(max_abs_diff(&out2.outputs[0], &expected2) < 1e-3);
    }

    #[test]
    fn plans_prewarm_the_arena_so_steady_state_is_allocation_free() {
        use crate::tuner::{SelectionDb, SelectionKey};

        // A packed-B winograd selection — the deepest take-set (U/V/M
        // transform buffers + batched-GEMM packing panels).
        let mut db = SelectionDb::new();
        db.put(
            SelectionKey::conv(HOST_DEVICE, 3, 1, 8, 8, 3, 4, 1),
            ConvPoint {
                config: ConvConfig::winograd(2),
                blocked: BlockedParams {
                    bm: 16, bn: 16, bk: 8, mr: 2, nr: 4, threads: 1,
                },
                isa: Isa::Scalar,
                dtype: Dtype::F32,
                pack: Pack::Ab,
            },
            4.0,
        );
        let (_dir, plain) = engine_with(CONV_3X3);
        let mut e = NativeEngine::with_tuning(plain.store.clone(), db);
        assert_eq!(e.scratch_stats().bytes, 0, "fresh engine, empty arena");
        e.warm("c33").unwrap();
        let ws_bytes = e.planned_workspace_bytes("c33").unwrap();
        assert!(ws_bytes > 0, "winograd plans a non-trivial workspace");
        let warmed = e.scratch_stats();
        assert!(
            warmed.bytes as usize >= ws_bytes,
            "prewarm sizes the arena to the plan's worst case \
             ({} < {ws_bytes})",
            warmed.bytes
        );
        let inputs = e.synth_inputs("c33", 37).unwrap();
        for _ in 0..3 {
            e.run("c33", &inputs).unwrap();
        }
        let after = e.scratch_stats();
        assert_eq!(
            after.grows, warmed.grows,
            "steady-state requests must not grow the arena"
        );
        assert!(after.hits > warmed.hits, "requests draw from the pool");
        assert_eq!(after.high_water_bytes, warmed.high_water_bytes);
    }

    #[test]
    fn i8_plans_are_allocation_free_after_warm() {
        use crate::tuner::{SelectionDb, SelectionKey};

        let mut db = SelectionDb::new();
        db.put(
            SelectionKey::gemm(HOST_DEVICE, 8, 8, 8),
            GemmPoint {
                params: BlockedParams {
                    bm: 8, bn: 8, bk: 8, mr: 2, nr: 4, threads: 1,
                },
                isa: Isa::Scalar,
                dtype: Dtype::I8,
                pack: Pack::Ab,
            },
            9.0,
        );
        let (_dir, plain) = engine_with(GEMM_8_QUANT);
        let mut e = NativeEngine::with_tuning(plain.store.clone(), db);
        e.warm("g8q").unwrap();
        let warmed = e.scratch_stats();
        let inputs = e.synth_inputs("g8q", 41).unwrap();
        for _ in 0..3 {
            e.run("g8q", &inputs).unwrap();
        }
        assert_eq!(
            e.scratch_stats().grows,
            warmed.grows,
            "quantize staging + packed i8 GEMM all ride the prewarmed arena"
        );
    }

    #[test]
    fn conv_pack_ab_normalizes_to_a_off_the_gemm_lowered_algorithms() {
        let (_dir, mut e) = engine_with(CONV_3X3);
        // An engine-wide tiled override carrying pack: ab — the tiled
        // kernel has no B panel, so the plan must report (and record a
        // workspace for) pack: a.
        e.set_conv_point(ConvPoint {
            config: ConvConfig::tiled(2, 2, 1, 4),
            blocked: BlockedParams { threads: 1, ..Default::default() },
            isa: Isa::Scalar,
            dtype: Dtype::F32,
            pack: Pack::Ab,
        });
        let planned = e.planned_conv_point("c33").unwrap().unwrap();
        assert_eq!(planned.pack, Pack::A, "no B panel to pack");
        assert_eq!(
            e.planned_workspace_bytes("c33").unwrap(),
            0,
            "the tiled direct conv stages nothing"
        );
        // A GEMM-lowered override keeps its measured pack.
        e.set_conv_point(ConvPoint {
            config: ConvConfig::im2col(),
            blocked: BlockedParams { threads: 1, ..Default::default() },
            isa: Isa::Scalar,
            dtype: Dtype::F32,
            pack: Pack::Ab,
        });
        let planned = e.planned_conv_point("c33").unwrap().unwrap();
        assert_eq!(planned.pack, Pack::Ab, "im2col keeps packed-B");
    }
}
