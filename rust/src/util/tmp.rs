//! RAII temporary directories for tests (the tempfile stand-in).

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

static COUNTER: AtomicU64 = AtomicU64::new(0);

/// A uniquely named directory under the system temp dir, removed on drop.
#[derive(Debug)]
pub struct TempDir {
    path: PathBuf,
}

impl TempDir {
    /// Create a fresh directory whose name starts with `prefix`.
    pub fn new(prefix: &str) -> std::io::Result<Self> {
        let n = COUNTER.fetch_add(1, Ordering::Relaxed);
        let path = std::env::temp_dir().join(format!(
            "{prefix}-{}-{}-{n}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .map(|d| d.as_nanos() as u64)
                .unwrap_or(0),
        ));
        std::fs::create_dir_all(&path)?;
        Ok(Self { path })
    }

    /// The directory's path (valid until drop).
    pub fn path(&self) -> &Path {
        &self.path
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn creates_and_cleans_up() {
        let kept;
        {
            let t = TempDir::new("pk-test").unwrap();
            kept = t.path().to_path_buf();
            std::fs::write(t.path().join("f.txt"), "x").unwrap();
            assert!(kept.exists());
        }
        assert!(!kept.exists());
    }

    #[test]
    fn unique_paths() {
        let a = TempDir::new("pk-test").unwrap();
        let b = TempDir::new("pk-test").unwrap();
        assert_ne!(a.path(), b.path());
    }
}
