//! Seeded xorshift64* PRNG — deterministic synthetic data everywhere
//! (inputs, random search, property tests).

/// xorshift64* (Vigna): tiny, fast, good enough for test data and search.
#[derive(Debug, Clone)]
pub struct XorShift {
    state: u64,
}

impl XorShift {
    /// Seeded generator; identical seeds reproduce identical streams.
    pub fn new(seed: u64) -> Self {
        // Avoid the all-zero fixed point; mix the seed.
        Self { state: seed.wrapping_mul(0x9E3779B97F4A7C15) | 1 }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in [0, n).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        self.next_u64() % n
    }

    /// Uniform in [lo, hi] inclusive.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform f32 in [-0.5, 0.5).
    pub fn f32_centered(&mut self) -> f32 {
        ((self.next_u64() >> 40) as f32 / (1u64 << 24) as f32) - 0.5
    }

    /// A vector of centered f32s.
    pub fn f32_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.f32_centered()).collect()
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let a: Vec<u64> = {
            let mut r = XorShift::new(7);
            (0..10).map(|_| r.next_u64()).collect()
        };
        let b: Vec<u64> = {
            let mut r = XorShift::new(7);
            (0..10).map(|_| r.next_u64()).collect()
        };
        assert_eq!(a, b);
        let c: Vec<u64> = {
            let mut r = XorShift::new(8);
            (0..10).map(|_| r.next_u64()).collect()
        };
        assert_ne!(a, c);
    }

    #[test]
    fn ranges_respected() {
        let mut r = XorShift::new(1);
        for _ in 0..1000 {
            let v = r.range(3, 9);
            assert!((3..=9).contains(&v));
            let f = r.f32_centered();
            assert!((-0.5..0.5).contains(&f));
        }
    }

    #[test]
    fn roughly_uniform() {
        let mut r = XorShift::new(2);
        let mut counts = [0usize; 8];
        for _ in 0..8000 {
            counts[r.below(8) as usize] += 1;
        }
        for c in counts {
            assert!((700..1300).contains(&c), "bucket {c}");
        }
    }
}
