//! Minimal measurement harness (the criterion stand-in).
//!
//! Warmup + N timed repetitions, reporting min / median / mean.  The
//! benches under `rust/benches/` are plain binaries built on this.

use std::time::{Duration, Instant};

use crate::error::{Error, Result};

/// Statistics over a set of timed repetitions.
#[derive(Debug, Clone)]
pub struct BenchStats {
    /// Label the measurement was taken under.
    pub name: String,
    /// Timed repetitions recorded.
    pub samples: usize,
    /// Fastest repetition.
    pub min: Duration,
    /// Median repetition.
    pub median: Duration,
    /// Arithmetic mean over all repetitions.
    pub mean: Duration,
    /// Slowest repetition.
    pub max: Duration,
}

impl BenchStats {
    /// Build stats from raw timed repetitions.  An empty sample set is a
    /// loud [`Error::Runtime`] — silently fabricating statistics (or
    /// panicking on an `unwrap`) would let a broken measurement loop
    /// masquerade as a result.
    pub fn from_times(name: &str, mut times: Vec<Duration>) -> Result<Self> {
        if times.is_empty() {
            return Err(Error::Runtime(format!(
                "bench {name:?}: no timed samples recorded — cannot form \
                 statistics from an empty sample set"
            )));
        }
        times.sort();
        let sum: Duration = times.iter().sum();
        Ok(BenchStats {
            name: name.to_string(),
            samples: times.len(),
            min: times[0],
            median: times[times.len() / 2],
            mean: sum / times.len() as u32,
            max: *times.last().expect("non-empty checked above"),
        })
    }

    /// Throughput in GFLOP/s given useful flops per iteration.
    ///
    /// A zero-duration minimum (possible on coarse clocks for tiny
    /// kernels) reports 0.0 rather than dividing through to `inf` — an
    /// infinite throughput would win every tuner argmax and poison any
    /// selection DB it is persisted into.
    pub fn gflops(&self, flops: u64) -> f64 {
        let secs = self.min.as_secs_f64();
        if secs <= 0.0 {
            return 0.0;
        }
        flops as f64 / secs / 1e9
    }

    /// Throughput in GOP/s — the honest unit for integer kernels, where
    /// "flops" would be a misnomer: `ops` counts useful multiply-adds
    /// (×2) per iteration exactly as `flops` does for f32, only the
    /// arithmetic is i8×i8→i32.  Numerically identical to
    /// [`BenchStats::gflops`]; the separate name keeps reports from
    /// labeling integer throughput as floating-point.
    pub fn gops(&self, ops: u64) -> f64 {
        self.gflops(ops)
    }

    /// One-line rendering.
    pub fn line(&self, flops: Option<u64>) -> String {
        let gf = flops
            .map(|f| format!("  {:>9.3} GF/s", self.gflops(f)))
            .unwrap_or_default();
        format!(
            "{:<44} min {:>10.3?}  med {:>10.3?}  mean {:>10.3?}{gf}",
            self.name, self.min, self.median, self.mean
        )
    }

    /// One-line rendering for integer kernels: like [`BenchStats::line`]
    /// but labeled GOP/s via [`BenchStats::gops`].
    pub fn line_int(&self, ops: Option<u64>) -> String {
        let go = ops
            .map(|o| format!("  {:>9.3} GOP/s", self.gops(o)))
            .unwrap_or_default();
        format!(
            "{:<44} min {:>10.3?}  med {:>10.3?}  mean {:>10.3?}{go}",
            self.name, self.min, self.median, self.mean
        )
    }
}

/// Measure `f` with `warmup` untimed and `samples` timed repetitions.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, samples: usize, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut times: Vec<Duration> = Vec::with_capacity(samples.max(1));
    for _ in 0..samples.max(1) {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed());
    }
    BenchStats::from_times(name, times)
        .expect("samples.max(1) guarantees at least one timed repetition")
}

/// Prevent the optimizer from discarding a value (std::hint::black_box
/// wrapper kept for symmetry with criterion's API).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_ordering() {
        let s = bench("spin", 1, 9, || {
            black_box((0..1000).sum::<u64>());
        });
        assert_eq!(s.samples, 9);
        assert!(s.min <= s.median && s.median <= s.max);
        assert!(s.mean >= s.min && s.mean <= s.max);
    }

    #[test]
    fn gflops_math() {
        let s = BenchStats {
            name: "x".into(),
            samples: 1,
            min: Duration::from_secs(1),
            median: Duration::from_secs(1),
            mean: Duration::from_secs(1),
            max: Duration::from_secs(1),
        };
        assert_eq!(s.gflops(2_000_000_000), 2.0);
        assert!(s.line(Some(1_000_000_000)).contains("GF/s"));
        // The integer-kernel twin: same math, honest unit label.
        assert_eq!(s.gops(2_000_000_000), 2.0);
        let li = s.line_int(Some(1_000_000_000));
        assert!(li.contains("GOP/s") && !li.contains("GF/s"), "{li}");
    }

    #[test]
    fn gflops_zero_duration_is_zero_not_inf() {
        let s = BenchStats {
            name: "coarse-clock".into(),
            samples: 3,
            min: Duration::ZERO,
            median: Duration::ZERO,
            mean: Duration::ZERO,
            max: Duration::from_nanos(1),
        };
        let g = s.gflops(1_000_000_000);
        assert_eq!(g, 0.0, "zero-duration min must not divide to inf");
        assert!(g.is_finite());
    }

    #[test]
    fn from_times_empty_is_a_loud_error() {
        let err = BenchStats::from_times("empty", Vec::new())
            .err()
            .expect("empty sample set must be an error, not a panic");
        assert!(err.to_string().contains("no timed samples"), "got: {err}");
    }

    #[test]
    fn from_times_sorts_and_aggregates() {
        let s = BenchStats::from_times(
            "sorted",
            vec![
                Duration::from_millis(3),
                Duration::from_millis(1),
                Duration::from_millis(2),
            ],
        )
        .unwrap();
        assert_eq!(s.min, Duration::from_millis(1));
        assert_eq!(s.median, Duration::from_millis(2));
        assert_eq!(s.max, Duration::from_millis(3));
        assert_eq!(s.samples, 3);
    }
}
