//! `Scratch` — the zero-allocation workspace arena the kernel hot paths
//! draw their temporaries from.
//!
//! Every kernel in `blas` needs per-call staging memory: A/B packing
//! panels, the im2col patch matrix, the Winograd V/U/M transform
//! buffers, int8 quantize staging.  Allocating those per call is cheap
//! once and ruinous at serving rates, so each `NativeEngine` owns one
//! `Scratch` (one arena per pool actor, since each actor owns its
//! engine) and threads it through the `*_ex` kernel entry points.  A
//! buffer is checked out with `take_*` and returned with `put_*`;
//! parallel band workers inside a kernel check out their own buffers
//! concurrently (the arena is `Sync`), so worker-local scratch rides the
//! same pool.
//!
//! Semantics contract: `take_f32(len)` returns a vector observationally
//! identical to `vec![0.0; len]` — exact length, every element zero —
//! so routing a kernel's temporaries through the arena can never change
//! a result bit (the arena-reuse hygiene proptests pin this).  Recycled
//! buffers are `clear()`ed and re-zeroed on checkout; stale data from a
//! previous shape cannot bleed through.
//!
//! Sizing: plans know their shapes, so the blas layer exposes
//! `*_workspace` functions that mirror each kernel's exact take-set as a
//! [`Workspace`] (one entry per buffer that can be outstanding at once,
//! worker copies included).  `NativeEngine` computes the worst case at
//! plan time and [`Scratch::prewarm`]s the arena, after which steady
//! state performs **zero** kernel-scratch allocations per request — the
//! counters ([`ScratchStats`]: checkout hits vs growth reallocations,
//! bytes high-water) make that observable, and serve-smoke asserts the
//! growth counter is flat after warmup.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Typed free lists behind the arena's mutex.  Buffers retain their
/// capacity while pooled; checkout picks the best (smallest sufficient)
/// fit so a large panel buffer is not burned on a tiny transform tile.
#[derive(Default)]
struct Pools {
    f32s: Vec<Vec<f32>>,
    i8s: Vec<Vec<i8>>,
    i32s: Vec<Vec<i32>>,
    i64s: Vec<Vec<i64>>,
}

/// Counter snapshot of one arena — the observability surface the
/// loadgen/serving CSVs report per engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ScratchStats {
    /// Checkouts satisfied by a pooled buffer (no allocation).
    pub hits: u64,
    /// Checkouts that had to allocate (pool empty or every pooled
    /// buffer too small).  Flat after warmup == zero-alloc steady state.
    pub grows: u64,
    /// Bytes currently owned by the arena (pooled + checked out).
    pub bytes: u64,
    /// High-water mark of `bytes` over the arena's lifetime.
    pub high_water_bytes: u64,
}

impl ScratchStats {
    /// Fold another arena's counters into this one (pool-level
    /// aggregation across actors).
    pub fn absorb(&mut self, other: &ScratchStats) {
        self.hits += other.hits;
        self.grows += other.grows;
        self.bytes += other.bytes;
        self.high_water_bytes += other.high_water_bytes;
    }
}

/// The workspace arena.  `Sync`: checkouts lock a mutex around the free
/// lists (uncontended in steady state — a handful of lock/unlock pairs
/// per kernel call), counters are atomics.
pub struct Scratch {
    pools: Mutex<Pools>,
    hits: AtomicU64,
    grows: AtomicU64,
    bytes: AtomicU64,
    high_water: AtomicU64,
}

impl Default for Scratch {
    fn default() -> Self {
        Self::new()
    }
}

macro_rules! typed_pool {
    ($take:ident, $put:ident, $field:ident, $ty:ty, $zero:expr) => {
        /// Check out a zero-filled buffer of exactly `len` elements —
        /// observationally identical to `vec![zero; len]`.  Return it
        /// with the matching `put_*` when done so steady state recycles
        /// instead of allocating.
        pub fn $take(&self, len: usize) -> Vec<$ty> {
            if len == 0 {
                // Length-zero vectors never allocate; count as a hit so
                // degenerate shapes don't read as arena growth.
                self.hits.fetch_add(1, Ordering::Relaxed);
                return Vec::new();
            }
            let reused = {
                let mut pools =
                    self.pools.lock().expect("scratch arena poisoned");
                let pool = &mut pools.$field;
                // Best fit: the smallest pooled capacity that suffices.
                let mut best: Option<usize> = None;
                for idx in 0..pool.len() {
                    let cap = pool[idx].capacity();
                    let better = match best {
                        None => true,
                        Some(b) => cap < pool[b].capacity(),
                    };
                    if cap >= len && better {
                        best = Some(idx);
                    }
                }
                best.map(|idx| pool.swap_remove(idx))
            };
            match reused {
                Some(mut buf) => {
                    self.hits.fetch_add(1, Ordering::Relaxed);
                    // clear + resize re-zeroes every element without
                    // touching capacity: the vec![zero; len] contract.
                    buf.clear();
                    buf.resize(len, $zero);
                    buf
                }
                None => {
                    self.grows.fetch_add(1, Ordering::Relaxed);
                    let added = (len * std::mem::size_of::<$ty>()) as u64;
                    let now =
                        self.bytes.fetch_add(added, Ordering::Relaxed)
                            + added;
                    self.high_water.fetch_max(now, Ordering::Relaxed);
                    vec![$zero; len]
                }
            }
        }

        /// Return a buffer checked out with the matching `take_*`.
        pub fn $put(&self, buf: Vec<$ty>) {
            if buf.capacity() == 0 {
                return; // nothing to recycle
            }
            self.pools
                .lock()
                .expect("scratch arena poisoned")
                .$field
                .push(buf);
        }
    };
}

impl Scratch {
    /// An empty arena: no buffers owned, all counters zero.  `const`, so
    /// wrapper entry points can keep a throwaway arena on the stack for
    /// callers that don't manage one.
    pub const fn new() -> Self {
        Scratch {
            pools: Mutex::new(Pools {
                f32s: Vec::new(),
                i8s: Vec::new(),
                i32s: Vec::new(),
                i64s: Vec::new(),
            }),
            hits: AtomicU64::new(0),
            grows: AtomicU64::new(0),
            bytes: AtomicU64::new(0),
            high_water: AtomicU64::new(0),
        }
    }

    typed_pool!(take_f32, put_f32, f32s, f32, 0.0f32);
    typed_pool!(take_i8, put_i8, i8s, i8, 0i8);
    typed_pool!(take_i32, put_i32, i32s, i32, 0i32);
    typed_pool!(take_i64, put_i64, i64s, i64, 0i64);

    /// Snapshot the counters.
    pub fn stats(&self) -> ScratchStats {
        ScratchStats {
            hits: self.hits.load(Ordering::Relaxed),
            grows: self.grows.load(Ordering::Relaxed),
            bytes: self.bytes.load(Ordering::Relaxed),
            high_water_bytes: self.high_water.load(Ordering::Relaxed),
        }
    }

    /// Grow the arena to cover a workspace up front: check out every
    /// buffer the workspace lists (forcing any allocation to happen
    /// *now*), then return them all to the pool.  After prewarming with
    /// a plan's worst-case workspace, executing that plan hits the pool
    /// on every checkout — zero allocations in steady state.
    pub fn prewarm(&self, ws: &Workspace) {
        let f: Vec<_> =
            ws.f32_lens.iter().map(|&l| self.take_f32(l)).collect();
        let b: Vec<_> =
            ws.i8_lens.iter().map(|&l| self.take_i8(l)).collect();
        let w: Vec<_> =
            ws.i32_lens.iter().map(|&l| self.take_i32(l)).collect();
        let d: Vec<_> =
            ws.i64_lens.iter().map(|&l| self.take_i64(l)).collect();
        f.into_iter().for_each(|v| self.put_f32(v));
        b.into_iter().for_each(|v| self.put_i8(v));
        w.into_iter().for_each(|v| self.put_i32(v));
        d.into_iter().for_each(|v| self.put_i64(v));
    }
}

/// The worst-case take-set of one kernel execution: one entry per buffer
/// that can be outstanding simultaneously (worker-local copies listed
/// once per worker).  Computed analytically at plan time by the blas
/// `*_workspace` functions, recorded on the plan, and fed to
/// [`Scratch::prewarm`].
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Workspace {
    /// Lengths (elements) of the f32 buffers.
    pub f32_lens: Vec<usize>,
    /// Lengths (elements) of the i8 buffers.
    pub i8_lens: Vec<usize>,
    /// Lengths (elements) of the i32 buffers.
    pub i32_lens: Vec<usize>,
    /// Lengths (elements) of the i64 buffers.
    pub i64_lens: Vec<usize>,
}

impl Workspace {
    /// An empty workspace (kernels that stage nothing).
    pub fn none() -> Self {
        Self::default()
    }

    /// Total worst-case bytes across every listed buffer — the number a
    /// plan records as its workspace footprint.
    pub fn bytes(&self) -> usize {
        self.f32_lens.iter().sum::<usize>() * std::mem::size_of::<f32>()
            + self.i8_lens.iter().sum::<usize>()
            + self.i32_lens.iter().sum::<usize>()
                * std::mem::size_of::<i32>()
            + self.i64_lens.iter().sum::<usize>()
                * std::mem::size_of::<i64>()
    }

    /// Append another take-set (a kernel composed of stages sums its
    /// stages' workspaces; concatenation is the conservative union).
    pub fn extend(&mut self, other: Workspace) {
        self.f32_lens.extend(other.f32_lens);
        self.i8_lens.extend(other.i8_lens);
        self.i32_lens.extend(other.i32_lens);
        self.i64_lens.extend(other.i64_lens);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_matches_fresh_vec_semantics() {
        let s = Scratch::new();
        for len in [0usize, 1, 7, 64] {
            let v = s.take_f32(len);
            assert_eq!(v, vec![0.0f32; len], "len={len}");
            s.put_f32(v);
        }
        let v = s.take_i8(5);
        assert_eq!(v, vec![0i8; 5]);
        s.put_i8(v);
        let v = s.take_i32(5);
        assert_eq!(v, vec![0i32; 5]);
        s.put_i32(v);
        let v = s.take_i64(5);
        assert_eq!(v, vec![0i64; 5]);
        s.put_i64(v);
    }

    #[test]
    fn recycled_buffers_are_rezeroed() {
        let s = Scratch::new();
        let mut v = s.take_f32(8);
        v.iter_mut().for_each(|x| *x = 3.5);
        s.put_f32(v);
        // Same size comes back from the pool — and must be zero again.
        let v2 = s.take_f32(8);
        assert_eq!(v2, vec![0.0f32; 8]);
        // Smaller asks reuse the same capacity, still exact-length zero.
        s.put_f32(v2);
        let v3 = s.take_f32(3);
        assert_eq!(v3, vec![0.0f32; 3]);
    }

    #[test]
    fn counters_track_hits_and_growth() {
        let s = Scratch::new();
        let v = s.take_f32(16); // grow
        s.put_f32(v);
        let v = s.take_f32(16); // hit
        s.put_f32(v);
        let v = s.take_f32(4); // hit (fits in the 16-cap buffer)
        s.put_f32(v);
        let v = s.take_f32(32); // grow (nothing big enough)
        s.put_f32(v);
        let st = s.stats();
        assert_eq!((st.hits, st.grows), (2, 2));
        assert_eq!(st.bytes, (16 + 32) * 4);
        assert_eq!(st.high_water_bytes, st.bytes);
    }

    #[test]
    fn best_fit_prefers_the_smallest_sufficient_buffer() {
        let s = Scratch::new();
        let big = s.take_f32(100);
        let small = s.take_f32(10);
        s.put_f32(big);
        s.put_f32(small);
        // A 10-element ask must come from the 10-cap buffer, leaving
        // the 100-cap one pooled for the next big ask.
        let v = s.take_f32(10);
        assert_eq!(v.capacity(), 10);
        let v100 = s.take_f32(100);
        assert_eq!(v100.capacity(), 100);
        assert_eq!(s.stats().grows, 2, "both asks must be pool hits");
    }

    #[test]
    fn prewarm_makes_steady_state_allocation_free() {
        let s = Scratch::new();
        let ws = Workspace {
            f32_lens: vec![64, 64, 128],
            i8_lens: vec![256],
            i32_lens: vec![32],
            i64_lens: vec![],
        };
        s.prewarm(&ws);
        let grows_after_warmup = s.stats().grows;
        // Simulate steady-state execution: the same take-set, twice.
        for _ in 0..2 {
            let a = s.take_f32(64);
            let b = s.take_f32(64);
            let c = s.take_f32(128);
            let q = s.take_i8(256);
            let w = s.take_i32(32);
            s.put_f32(a);
            s.put_f32(b);
            s.put_f32(c);
            s.put_i8(q);
            s.put_i32(w);
        }
        assert_eq!(
            s.stats().grows,
            grows_after_warmup,
            "steady state must not grow the arena"
        );
    }

    #[test]
    fn workspace_bytes_and_extend() {
        let mut ws = Workspace {
            f32_lens: vec![10],
            i8_lens: vec![10],
            i32_lens: vec![10],
            i64_lens: vec![10],
        };
        assert_eq!(ws.bytes(), 10 * 4 + 10 + 10 * 4 + 10 * 8);
        ws.extend(Workspace {
            f32_lens: vec![5],
            ..Workspace::none()
        });
        assert_eq!(ws.f32_lens, vec![10, 5]);
        assert_eq!(Workspace::none().bytes(), 0);
    }

    #[test]
    fn arena_is_usable_across_threads() {
        let s = Scratch::new();
        std::thread::scope(|scope| {
            for _ in 0..4 {
                scope.spawn(|| {
                    for _ in 0..8 {
                        let v = s.take_f32(64);
                        assert_eq!(v.len(), 64);
                        s.put_f32(v);
                    }
                });
            }
        });
        let st = s.stats();
        assert_eq!(st.hits + st.grows, 32);
        assert!(st.grows <= 4, "at most one growth per worker");
    }
}
