//! Hand-rolled work-stealing-lite thread pool over `std::thread::scope`.
//!
//! No rayon in the offline build, so this is the minimal substrate the
//! parallel kernels need: a fixed worker set spawned per call (scoped, so
//! borrowed inputs work and panics propagate on join), self-scheduling
//! over an atomic chunk counter — the "lite" half of work stealing: every
//! worker steals from one shared queue of chunk indices, so a slow chunk
//! never serializes the rest of the range behind it.
//!
//! Determinism note: parallelism here never changes *results*.  Callers
//! hand each chunk a disjoint `&mut` slice of the output (macro-tile row
//! bands for GEMM, patch-row ranges for im2col), and each chunk runs the
//! exact serial per-chunk code, so outputs are bit-identical to the
//! serial path by construction — only the order chunks *start* in varies.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Resolve a `threads` knob: `0` means "one worker per available core"
/// (`std::thread::available_parallelism`, falling back to 1 when the OS
/// refuses to say), any other value is taken literally.
pub fn resolve_threads(threads: usize) -> usize {
    if threads == 0 {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    } else {
        threads
    }
}

/// Run `f(index, item)` for every item, on up to `threads` workers.
///
/// Each item is claimed exactly once (atomic counter + one-shot slot), so
/// `f` may own per-chunk `&mut` output slices.  With `threads <= 1` or a
/// single item everything runs inline on the caller's thread — that *is*
/// the serial path, not a simulation of it.  A panic in any worker
/// propagates to the caller when the scope joins.
pub fn run_parallel<T, F>(threads: usize, items: Vec<T>, f: F)
where
    T: Send,
    F: Fn(usize, T) + Sync,
{
    let n = items.len();
    let workers = match threads.min(n) {
        0 => 1,
        w => w,
    };
    if workers <= 1 {
        for (i, item) in items.into_iter().enumerate() {
            f(i, item);
        }
        return;
    }
    // One-shot slots: claiming is the uncontended fetch_add; the per-slot
    // mutex only transfers ownership of the item to the claiming worker.
    let slots: Vec<Mutex<Option<T>>> =
        items.into_iter().map(|t| Mutex::new(Some(t))).collect();
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let item = slots[i]
                    .lock()
                    .expect("pool slot poisoned")
                    .take()
                    .expect("chunk claimed twice");
                f(i, item);
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn every_chunk_runs_exactly_once() {
        for threads in [1usize, 2, 3, 8] {
            let mut out = vec![0u64; 37];
            let chunks: Vec<(usize, &mut u64)> =
                out.iter_mut().enumerate().collect();
            run_parallel(threads, chunks, |i, (j, slot)| {
                assert_eq!(i, j);
                *slot += i as u64 + 1;
            });
            let expect: Vec<u64> = (0..37).map(|i| i + 1).collect();
            assert_eq!(out, expect, "threads={threads}");
        }
    }

    #[test]
    fn more_threads_than_chunks_is_fine() {
        let hits = AtomicU64::new(0);
        run_parallel(16, vec![(), ()], |_, ()| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 2);
        run_parallel(8, Vec::<()>::new(), |_, ()| unreachable!());
    }

    #[test]
    fn disjoint_mut_slices_compose() {
        // The exact shape the kernels use: split one output buffer into
        // row bands and let workers fill them concurrently.
        let mut c = vec![0.0f32; 6 * 10];
        let bands: Vec<(usize, &mut [f32])> =
            c.chunks_mut(2 * 10).enumerate().collect();
        run_parallel(3, bands, |_, (b, band)| {
            for (i, v) in band.iter_mut().enumerate() {
                *v = (b * 20 + i) as f32;
            }
        });
        for (i, v) in c.iter().enumerate() {
            assert_eq!(*v, i as f32);
        }
    }

    #[test]
    fn worker_panic_propagates() {
        let caught = std::panic::catch_unwind(|| {
            run_parallel(2, vec![0, 1, 2, 3], |_, x| {
                if x == 2 {
                    panic!("chunk failure must not be swallowed");
                }
            });
        });
        assert!(caught.is_err());
    }

    #[test]
    fn resolve_threads_contract() {
        assert!(resolve_threads(0) >= 1);
        assert_eq!(resolve_threads(1), 1);
        assert_eq!(resolve_threads(5), 5);
    }
}
