//! From-scratch substrates.
//!
//! This build environment is offline; the usual ecosystem crates (serde,
//! serde_json, criterion, proptest, tempfile, clap, tokio) are not
//! available, so this module provides the minimal substrates the library
//! needs, built from scratch and tested like everything else:
//!
//! * [`json`] — a complete JSON parser + serializer (the artifact
//!   manifest and the selection DB wire format);
//! * [`rng`] — a seeded xorshift64* generator (deterministic synthetic
//!   data and random search);
//! * [`bench`] — a small measurement harness with warmup, repetitions and
//!   robust statistics (the criterion stand-in the benches use);
//! * [`pool`] — a scoped, work-stealing-lite thread pool (the rayon
//!   stand-in the parallel kernels use);
//! * [`scratch`] — the zero-allocation workspace arena the kernel hot
//!   paths draw packing/transform/staging buffers from;
//! * [`tmp`] — RAII temporary directories for tests.

pub mod bench;
pub mod json;
pub mod pool;
pub mod rng;
pub mod scratch;
pub mod tmp;
