//! Minimal, complete JSON: parse + serialize (RFC 8259).
//!
//! Supports everything `python/compile/aot.py` emits: objects, arrays,
//! strings with escapes (incl. `\uXXXX`), integers, floats, booleans,
//! null.  Numbers are held as `f64` with an `i64` fast path, which is
//! lossless for every value the manifests contain.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// JSON `null`.
    Null,
    /// JSON boolean.
    Bool(bool),
    /// Integer-valued number (fits i64 exactly).
    Int(i64),
    /// Any other number.
    Float(f64),
    /// JSON string.
    Str(String),
    /// JSON array.
    Array(Vec<Value>),
    /// Ordered map for deterministic serialization.
    Object(BTreeMap<String, Value>),
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseError {
    /// Byte offset into the input where parsing failed.
    pub offset: usize,
    /// What the parser expected or found.
    pub message: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for ParseError {}

impl Value {
    // ---- accessors ----

    /// The contained string, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The contained number as i64 (integers, plus floats that are
    /// exactly integral).
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            Value::Float(f) if f.fract() == 0.0 && f.abs() < 9e15 => {
                Some(*f as i64)
            }
            _ => None,
        }
    }

    /// The contained number as u64 (non-negative integers only).
    pub fn as_u64(&self) -> Option<u64> {
        self.as_i64().and_then(|i| u64::try_from(i).ok())
    }

    /// The contained number as f64 (integers widen losslessly).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Int(i) => Some(*i as f64),
            Value::Float(f) => Some(*f),
            _ => None,
        }
    }

    /// The contained boolean, if this is a boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The contained elements, if this is an array.
    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }

    /// The contained map, if this is an object.
    pub fn as_object(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Object(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup (None for non-objects/missing/null).
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Object(o) => match o.get(key) {
                Some(Value::Null) | None => None,
                Some(v) => Some(v),
            },
            _ => None,
        }
    }

    /// Whether this is JSON `null`.
    pub fn is_null(&self) -> bool {
        matches!(self, Value::Null)
    }

    // ---- construction helpers ----

    /// An empty JSON object.
    pub fn object() -> Value {
        Value::Object(BTreeMap::new())
    }

    /// Set a field on an object (no-op on non-objects); chainable.
    pub fn set(&mut self, key: &str, v: impl Into<Value>) -> &mut Self {
        if let Value::Object(o) = self {
            o.insert(key.to_string(), v.into());
        }
        self
    }

    /// Serialize compactly.
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Serialize with 1-space indentation (matches `json.dumps(indent=1)`
    /// closely enough for diffing).
    pub fn to_json_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(1), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(true) => out.push_str("true"),
            Value::Bool(false) => out.push_str("false"),
            Value::Int(i) => {
                let _ = write!(out, "{i}");
            }
            Value::Float(f) => {
                if f.is_finite() {
                    let _ = write!(out, "{f}");
                    // Ensure a float marker so round-trips stay floats.
                    if f.fract() == 0.0 && !out.ends_with(|c: char| c == '.' || c == 'e') {
                        let tail: String = out
                            .chars()
                            .rev()
                            .take_while(|c| !c.is_whitespace() && *c != ',' && *c != '[')
                            .collect();
                        if !tail.contains('.') && !tail.contains('e') {
                            out.push_str(".0");
                        }
                    }
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            Value::Str(s) => write_escaped(out, s),
            Value::Array(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(w) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat(w * (depth + 1)));
                    }
                    item.write(out, indent, depth + 1);
                }
                if indent.is_some() && !items.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent.unwrap() * depth));
                }
                out.push(']');
            }
            Value::Object(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(w) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat(w * (depth + 1)));
                    }
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if indent.is_some() && !map.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent.unwrap() * depth));
                }
                out.push('}');
            }
        }
    }
}

impl From<&str> for Value {
    fn from(s: &str) -> Self {
        Value::Str(s.to_string())
    }
}
impl From<String> for Value {
    fn from(s: String) -> Self {
        Value::Str(s)
    }
}
impl From<bool> for Value {
    fn from(b: bool) -> Self {
        Value::Bool(b)
    }
}
impl From<i64> for Value {
    fn from(i: i64) -> Self {
        Value::Int(i)
    }
}
impl From<u64> for Value {
    fn from(i: u64) -> Self {
        i64::try_from(i).map(Value::Int).unwrap_or(Value::Float(i as f64))
    }
}
impl From<u32> for Value {
    fn from(i: u32) -> Self {
        Value::Int(i as i64)
    }
}
impl From<usize> for Value {
    fn from(i: usize) -> Self {
        Value::from(i as u64)
    }
}
impl From<f64> for Value {
    fn from(f: f64) -> Self {
        Value::Float(f)
    }
}
impl From<Vec<Value>> for Value {
    fn from(v: Vec<Value>) -> Self {
        Value::Array(v)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// A duplicate object key found while parsing.  JSON objects
/// last-write-wins on duplicates; callers that treat a duplicate as
/// corruption (e.g. the tuner's selection DB, where two entries under
/// one key with different kinds are ambiguous) can inspect these and
/// reject.
#[derive(Debug, Clone, PartialEq)]
pub struct DuplicateKey {
    /// The repeated key.
    pub key: String,
    /// The value the later occurrence overwrote.
    pub overwritten: Value,
    /// Object nesting depth of the owning object (`0` = the document's
    /// top-level object).
    pub depth: usize,
}

/// Parse a complete JSON document (trailing whitespace allowed).
pub fn parse(input: &str) -> Result<Value, ParseError> {
    parse_tracking_duplicates(input).map(|(v, _)| v)
}

/// Like [`parse`], additionally reporting every duplicate object key the
/// document contained (the kept value is the last occurrence, exactly as
/// [`parse`] resolves it).
pub fn parse_tracking_duplicates(
    input: &str,
) -> Result<(Value, Vec<DuplicateKey>), ParseError> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
        depth: 0,
        dups: Vec::new(),
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok((v, p.dups))
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
    /// Current object nesting depth (for duplicate-key reporting).
    depth: usize,
    /// Duplicate object keys seen so far.
    dups: Vec<DuplicateKey>,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { offset: self.pos, message: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Value) -> Result<Value, ParseError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {lit}")))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(b'-' | b'0'..=b'9') => self.number(),
            Some(c) => Err(self.err(&format!("unexpected {:?}", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let obj_depth = self.depth;
        self.depth += 1;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Value::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            if map.contains_key(&key) {
                let overwritten = map
                    .insert(key.clone(), val)
                    .expect("contains_key said present");
                self.dups.push(DuplicateKey {
                    key,
                    overwritten,
                    depth: obj_depth,
                });
            } else {
                map.insert(key, val);
            }
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => {
                    self.depth -= 1;
                    return Ok(Value::Object(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Array(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Array(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Surrogate pairs.
                        let c = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\')
                                || self.bump() != Some(b'u')
                            {
                                return Err(self.err("lone high surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("bad low surrogate"));
                            }
                            let c = 0x10000
                                + ((cp - 0xD800) << 10)
                                + (lo - 0xDC00);
                            char::from_u32(c)
                        } else {
                            char::from_u32(cp)
                        };
                        out.push(c.ok_or_else(|| self.err("bad codepoint"))?);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => {
                    return Err(self.err("control char in string"))
                }
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        let end = start + len;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let s = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("bad utf-8"))?;
                        out.push_str(s);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("eof in \\u"))?;
            let d = (c as char)
                .to_digit(16)
                .ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        let mut is_float = false;
        if self.peek() == Some(b'.') {
            is_float = true;
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            is_float = true;
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("bad number bytes"))?;
        if text.is_empty() || text == "-" {
            return Err(self.err("bad number"));
        }
        if !is_float {
            if let Ok(i) = text.parse::<i64>() {
                return Ok(Value::Int(i));
            }
        }
        text.parse::<f64>()
            .map(Value::Float)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse(" false ").unwrap(), Value::Bool(false));
        assert_eq!(parse("42").unwrap(), Value::Int(42));
        assert_eq!(parse("-7").unwrap(), Value::Int(-7));
        assert_eq!(parse("2.5").unwrap(), Value::Float(2.5));
        assert_eq!(parse("1e3").unwrap(), Value::Float(1000.0));
        assert_eq!(parse("-1.5e-2").unwrap(), Value::Float(-0.015));
        assert_eq!(parse(r#""hi""#).unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn parses_structures() {
        let v = parse(r#"{"a": [1, 2.0, "x"], "b": {"c": null}}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_array().unwrap().len(), 3);
        assert_eq!(v.get("a").unwrap().as_array().unwrap()[0].as_i64(), Some(1));
        assert!(v.get("b").unwrap().get("c").is_none()); // null -> None
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let v = parse(r#""a\n\t\"\\ é 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\t\"\\ é 😀");
        // Raw multibyte UTF-8 passes through.
        let v = parse("\"héllo — ok\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo — ok");
    }

    #[test]
    fn rejects_garbage() {
        for bad in [
            "", "{", "[1,", "{\"a\" 1}", "tru", "1.2.3", "\"\\q\"",
            "{\"a\":1} x", "[01x]", "\"unterminated",
        ] {
            assert!(parse(bad).is_err(), "{bad:?} should fail");
        }
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"arr":[1,2.5,"s"],"flag":true,"n":null,"nested":{"x":-3}}"#;
        let v = parse(src).unwrap();
        let compact = v.to_json();
        assert_eq!(parse(&compact).unwrap(), v);
        let pretty = v.to_json_pretty();
        assert_eq!(parse(&pretty).unwrap(), v);
        assert!(pretty.contains('\n'));
    }

    #[test]
    fn floats_keep_float_marker() {
        let v = Value::Float(2.0);
        assert_eq!(v.to_json(), "2.0");
        assert_eq!(parse("2.0").unwrap(), Value::Float(2.0));
    }

    #[test]
    fn real_manifest_fragment() {
        // A fragment in exactly the shape aot.py writes.
        let src = r#"{
  "version": 1,
  "artifacts": [
   {
    "name": "gemm_64x64x64_4x4_8x8_loc",
    "kind": "gemm",
    "impl": "pallas",
    "config": "4x4_8x8_loc",
    "flops": 524288,
    "m": 64, "n": 64, "k": 64,
    "alpha": 1.0, "beta": 0.0,
    "inputs": [{"shape": [64, 64], "dtype": "float32"}],
    "file": "gemm_64x64x64_4x4_8x8_loc.hlo.txt",
    "groups": ["gemm"],
    "scaled_from": null
   }
  ]
 }"#;
        let v = parse(src).unwrap();
        let arts = v.get("artifacts").unwrap().as_array().unwrap();
        assert_eq!(arts[0].get("flops").unwrap().as_u64(), Some(524288));
        assert_eq!(
            arts[0].get("inputs").unwrap().as_array().unwrap()[0]
                .get("shape")
                .unwrap()
                .as_array()
                .unwrap()
                .len(),
            2
        );
        assert!(arts[0].get("scaled_from").is_none());
    }

    #[test]
    fn duplicate_keys_are_tracked_with_depth() {
        // Last write wins (the parse result), but the overwritten value
        // and its owning object's depth are reported.
        let (v, dups) = parse_tracking_duplicates(
            r#"{"a": 1, "a": 2, "nested": {"b": 3, "b": 4}}"#,
        )
        .unwrap();
        assert_eq!(v.get("a").unwrap().as_i64(), Some(2));
        assert_eq!(v.get("nested").unwrap().get("b").unwrap().as_i64(), Some(4));
        assert_eq!(dups.len(), 2);
        assert_eq!(dups[0], DuplicateKey {
            key: "a".into(),
            overwritten: Value::Int(1),
            depth: 0,
        });
        assert_eq!(dups[1].key, "b");
        assert_eq!(dups[1].depth, 1);
        // Clean documents report none.
        let (_, dups) = parse_tracking_duplicates(r#"{"a": 1, "b": 1}"#).unwrap();
        assert!(dups.is_empty());
    }

    #[test]
    fn object_builder() {
        let mut o = Value::object();
        o.set("a", 1i64).set("b", "x").set("c", 2.5);
        assert_eq!(o.to_json(), r#"{"a":1,"b":"x","c":2.5}"#);
    }
}
