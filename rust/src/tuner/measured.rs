//! Measurement-driven tuning: pick configurations by *executing* the AOT
//! artifacts on the real runtime instead of consulting the analytic
//! model.
//!
//! This is exactly the paper's methodology on hardware we do own (the
//! host): every artifact in the `gemm`/`conv` manifest groups is one
//! kernel instantiation; running them and keeping the fastest per problem
//! is the measured counterpart of `tune_gemm`/`tune_conv`.
//!
//! [`tune_measured`] races *artifacts* against each other for a fixed
//! engine configuration; its sibling [`super::tune_space_sweep`] races
//! *host configurations* (kernel-space points) against each other per
//! artifact and persists the winners — together they close the paper's
//! parametrize → measure → select loop on the host.

use std::collections::BTreeMap;
use std::time::Duration;

use crate::error::Result;
use crate::runtime::Backend;

/// One measured candidate.
#[derive(Debug, Clone)]
pub struct MeasuredCandidate {
    /// Artifact that was executed.
    pub artifact: String,
    /// Kernel configuration name, when the manifest records one.
    pub config: Option<String>,
    /// "pallas" | "xla" (which lowering produced the artifact).
    pub implementation: String,
    /// Best (minimum) execution time over the repetitions.
    pub best: Duration,
    /// Measured throughput, GFLOP/s.
    pub gflops: f64,
}

/// Measured winners per problem key (e.g. `gemm_512x512x512` or a layer
/// name), with all candidates retained for reporting.
#[derive(Debug, Default)]
pub struct MeasuredTuning {
    /// Every candidate measured, grouped by the problem it competes in.
    pub problems: BTreeMap<String, Vec<MeasuredCandidate>>,
}

impl MeasuredTuning {
    /// The fastest candidate for a problem.
    pub fn winner(&self, problem: &str) -> Option<&MeasuredCandidate> {
        self.problems.get(problem)?.iter().min_by_key(|c| c.best)
    }

    /// Problems measured.
    pub fn problems(&self) -> impl Iterator<Item = &String> {
        self.problems.keys()
    }
}

/// Derive the problem key for a manifest artifact: GEMMs bucket by shape,
/// convs by (kind, layer, batch) — so artifacts differing only in their
/// configuration compete.
fn problem_key(meta: &crate::runtime::ArtifactMeta) -> Option<String> {
    match meta.kind.as_str() {
        "gemm" => Some(format!(
            "gemm_{}x{}x{}",
            meta.m?, meta.n?, meta.k?
        )),
        "conv" => {
            let l = meta.layer.as_ref()?;
            Some(format!(
                "conv_{}_{}x{}x{}_b{}",
                l.name,
                l.in_h,
                l.in_w,
                l.in_c,
                meta.batch.unwrap_or(1)
            ))
        }
        _ => None,
    }
}

/// Measure every artifact in `group`, `iters` repetitions each (min
/// taken), grouped into competing problems.  Works against any
/// [`Backend`] — the native engine measures the host reference kernels,
/// the PJRT engine measures the AOT artifacts.
pub fn tune_measured<B: Backend>(
    engine: &mut B,
    group: &str,
    iters: usize,
) -> Result<MeasuredTuning> {
    let names: Vec<(String, u64, Option<String>)> = engine
        .store()
        .in_group(group)
        .filter_map(|m| {
            problem_key(m).map(|k| (m.name.clone(), m.flops, Some(k)))
        })
        .collect();

    let mut tuning = MeasuredTuning::default();
    for (name, flops, key) in names {
        let key = key.expect("filtered above");
        let meta = engine.store().get(&name)?.clone();
        let inputs = engine.synth_inputs(&name, 17)?;
        engine.warm(&name)?;
        let (out, best) = engine.run_timed(&name, &inputs, iters)?;
        tuning.problems.entry(key).or_default().push(MeasuredCandidate {
            artifact: name,
            config: meta.config.clone(),
            implementation: meta.implementation.clone(),
            best,
            // RunOutput::gflops guards zero-duration runs (reports 0.0,
            // not inf); such candidates still compete on `best`.
            gflops: out.gflops(flops),
        });
    }
    Ok(tuning)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{ArtifactMeta, IoSpec};

    fn meta(kind: &str, m: Option<u64>) -> ArtifactMeta {
        ArtifactMeta {
            name: "x".into(),
            kind: kind.into(),
            implementation: "pallas".into(),
            config: None,
            file: "x.hlo.txt".into(),
            flops: 1,
            bytes: None,
            inputs: Vec::<IoSpec>::new(),
            outputs: Vec::new(),
            groups: vec![],
            m,
            n: m,
            k: m,
            alpha: None,
            beta: None,
            layer: None,
            algorithm: None,
            batch: None,
            fuse_relu: false,
            scaled_from: None,
        }
    }

    #[test]
    fn gemm_artifacts_bucket_by_shape() {
        let a = problem_key(&meta("gemm", Some(64))).unwrap();
        assert_eq!(a, "gemm_64x64x64");
        // Missing dims -> no key (never competes).
        assert!(problem_key(&meta("gemm", None)).is_none());
        assert!(problem_key(&meta("mystery", Some(4))).is_none());
    }

    #[test]
    fn winner_is_min_duration() {
        let mut t = MeasuredTuning::default();
        let c = |n: &str, ms: u64| MeasuredCandidate {
            artifact: n.into(),
            config: None,
            implementation: "pallas".into(),
            best: Duration::from_millis(ms),
            gflops: 0.0,
        };
        t.problems
            .insert("p".into(), vec![c("slow", 30), c("fast", 10), c("mid", 20)]);
        assert_eq!(t.winner("p").unwrap().artifact, "fast");
        assert!(t.winner("q").is_none());
    }

    #[test]
    fn tune_measured_runs_on_native_backend() {
        use crate::runtime::{ArtifactStore, NativeEngine};
        use crate::util::tmp::TempDir;

        let dir = TempDir::new("measured").unwrap();
        std::fs::write(
            dir.path().join("manifest.json"),
            r#"{"version": 1, "artifacts": [
              {"name": "g16_a", "kind": "gemm", "impl": "pallas",
               "config": "4x4_8x8_loc", "file": "a.hlo.txt", "flops": 8192,
               "m": 16, "n": 16, "k": 16, "groups": ["gemm"],
               "inputs": [{"shape": [16, 16], "dtype": "float32"},
                          {"shape": [16, 16], "dtype": "float32"}]},
              {"name": "g16_b", "kind": "gemm", "impl": "xla",
               "file": "b.hlo.txt", "flops": 8192,
               "m": 16, "n": 16, "k": 16, "groups": ["gemm"],
               "inputs": [{"shape": [16, 16], "dtype": "float32"},
                          {"shape": [16, 16], "dtype": "float32"}]}
            ]}"#,
        )
        .unwrap();
        let store = ArtifactStore::open(dir.path()).unwrap();
        let mut engine = NativeEngine::new(store).unwrap();
        let t = tune_measured(&mut engine, "gemm", 2).unwrap();
        // Both artifacts share the shape, so they compete in one problem.
        assert_eq!(t.problems.len(), 1);
        let cands = &t.problems["gemm_16x16x16"];
        assert_eq!(cands.len(), 2);
        let w = t.winner("gemm_16x16x16").unwrap();
        assert!(cands.iter().all(|c| c.best >= w.best));
    }
}
