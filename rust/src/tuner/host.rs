//! Measured per-host sweeps: the `BlockedParams` × `threads` grid for
//! GEMM and the `ConvAlgorithm × ConvConfig × threads` grid for
//! convolutions.
//!
//! This is the paper's headline workflow run end-to-end on hardware we
//! actually own: enumerate kernel parameter combinations — including
//! *which algorithm* runs, the §4.1 axis — *measure* each one through a
//! [`Backend`] (no model in the loop), and persist the winner per
//! (platform, problem class) into the [`SelectionDb`] that
//! `NativeEngine` consults at plan time.  Measured — not modeled — sweeps
//! are what make the portability claim credible (cf. Reguly,
//! arXiv:2309.10075); CI runs the quick variant on every merge via
//! `cargo run --release --example tune_device -- --quick`.

use std::collections::BTreeMap;
use std::time::Duration;

use crate::blas::{native_conv_algorithm_dims, BlockedParams};
use crate::config::{micro_kernel_shapes, ConvAlgorithm, ConvConfig};
use crate::error::Result;
use crate::runtime::{ArtifactMeta, Backend};

use super::db::{SelectionDb, SelectionKey};
use super::search::{ExhaustiveSearch, SearchStrategy};

/// One timed grid point: artifact × parameter combination.
#[derive(Debug, Clone)]
pub struct SweepMeasurement {
    /// Problem-class op key (the `SelectionKey::op` the winner persists
    /// under, e.g. `gemm_128x128x128`).
    pub problem: String,
    /// Artifact the measurement executed.
    pub artifact: String,
    /// Parameter combination this grid point timed.
    pub params: BlockedParams,
    /// Best (minimum) execution time over the repetitions.
    pub best: Duration,
    /// Measured throughput, GFLOP/s (from the artifact's manifest flops).
    pub gflops: f64,
}

/// A finished sweep: every measurement plus the per-problem winners that
/// were persisted.
#[derive(Debug, Default)]
pub struct BlockedSweep {
    /// Every timed grid point, in measurement order.
    pub rows: Vec<SweepMeasurement>,
    /// Winner per problem-class op key.
    pub winners: BTreeMap<String, (BlockedParams, f64)>,
}

impl BlockedSweep {
    /// Best measured gflops for a problem under exactly `params`
    /// (e.g. the default config, for tuned-vs-default reporting).
    pub fn gflops_for(
        &self,
        problem: &str,
        params: &BlockedParams,
    ) -> Option<f64> {
        self.rows
            .iter()
            .filter(|r| r.problem == problem && r.params == *params)
            .map(|r| r.gflops)
            .reduce(f64::max)
    }
}

/// The base `BlockedParams` candidate sets — the same serial candidates
/// the `blocked.rs` tests and the `rust_blas` bench exercise, widened
/// over the monomorphized `(mr, nr)` registry
/// ([`crate::config::micro_kernel_shapes`]) so the sweep measures the
/// whole fast micro-tile set, not a hand-picked subset.
pub fn blocked_candidates(quick: bool) -> Vec<BlockedParams> {
    let p = |bm, bn, bk, mr, nr| BlockedParams {
        bm,
        bn,
        bk,
        mr,
        nr,
        threads: 1,
    };
    let mut out = if quick {
        // Tiny grid for the CI smoke sweep, plus registry shapes beyond
        // the historical hand-written set so the widened axis is always
        // exercised.
        vec![
            BlockedParams { threads: 1, ..Default::default() },
            p(32, 32, 32, 4, 8),
            p(16, 32, 16, 4, 8),
            p(32, 32, 32, 2, 16),
            p(32, 32, 32, 16, 8),
        ]
    } else {
        let mut v = vec![
            BlockedParams { threads: 1, ..Default::default() },
            p(8, 8, 8, 2, 2),
            p(16, 32, 5, 4, 8),
            p(64, 64, 64, 8, 16),
            p(32, 32, 32, 4, 8),
            p(128, 128, 64, 8, 16),
        ];
        // The full mr × nr registry at one representative blocking.
        for &(mr, nr) in micro_kernel_shapes() {
            v.push(p(64, 64, 64, mr, nr));
        }
        v
    };
    // Order-preserving dedup (the registry cross re-generates a couple
    // of the hand-written entries).
    let mut seen: Vec<BlockedParams> = Vec::with_capacity(out.len());
    out.retain(|c| {
        if seen.contains(c) {
            false
        } else {
            seen.push(*c);
            true
        }
    });
    out
}

/// The full sweep grid: [`blocked_candidates`] × `threads`, deduplicated,
/// with [`BlockedParams::default`] always present so every sweep measures
/// the untuned baseline it is compared against.
pub fn blocked_grid(quick: bool, threads: &[usize]) -> Vec<BlockedParams> {
    let mut grid: Vec<BlockedParams> = Vec::new();
    for base in blocked_candidates(quick) {
        for &t in threads {
            let cand = BlockedParams { threads: t, ..base };
            if !grid.contains(&cand) {
                grid.push(cand);
            }
        }
    }
    let default = BlockedParams::default();
    if !grid.contains(&default) {
        grid.insert(0, default);
    }
    grid
}

/// One native conv sweep candidate: an algorithm + its knobs.  The
/// [`ConvConfig`] names the algorithm and tile/vector parameters; the
/// [`BlockedParams`] carry the im2col GEMM blocking and the `threads`
/// knob every algorithm honors.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ConvCandidate {
    /// Algorithm + tile/vector configuration.
    pub config: ConvConfig,
    /// im2col GEMM blocking + `threads`.
    pub blocked: BlockedParams,
}

impl ConvCandidate {
    /// Compact name for reports (`wino2_v1x1+bm64bn64bk64_4x8_t2` style).
    pub fn name(&self) -> String {
        format!("{}+{}", self.config.name(), self.blocked.name())
    }
}

/// The base [`ConvConfig`] candidates the native conv sweep measures:
/// im2col, a handful of tiled tile/vector shapes, and Winograd m=2 —
/// all three §4.1 algorithm families, deliberately much smaller than
/// the modeled `config::conv_space` (these get *measured*, every point
/// costs wall time).
pub fn conv_candidates(quick: bool) -> Vec<ConvConfig> {
    let mut out = vec![ConvConfig::im2col()];
    if quick {
        out.push(ConvConfig::tiled(1, 1, 1, 4));
        out.push(ConvConfig::tiled(2, 2, 1, 4));
        out.push(ConvConfig::winograd(2));
    } else {
        for (th, tw, vc, vk) in
            [(1, 1, 1, 4), (2, 2, 1, 4), (4, 4, 4, 4), (2, 4, 1, 8)]
        {
            out.push(ConvConfig::tiled(th, tw, vc, vk));
        }
        out.push(ConvConfig::winograd(2));
    }
    out
}

/// The full native conv grid: [`conv_candidates`] × `threads`, im2col
/// additionally crossed with the [`blocked_candidates`] GEMM blockings,
/// deduplicated, with the plain default im2col candidate always present
/// as the untuned baseline.
pub fn conv_native_grid(
    quick: bool,
    threads: &[usize],
) -> Vec<ConvCandidate> {
    let mut grid: Vec<ConvCandidate> = Vec::new();
    let push = |grid: &mut Vec<ConvCandidate>, cand: ConvCandidate| {
        if !grid.contains(&cand) {
            grid.push(cand);
        }
    };
    for config in conv_candidates(quick) {
        // Only the im2col path uses the GEMM blocking; other algorithms
        // read just `threads` from it, so sweeping blockings for them
        // would time the same kernel repeatedly.
        let bases: Vec<BlockedParams> =
            if config.algorithm == ConvAlgorithm::Im2col {
                blocked_candidates(quick)
            } else {
                vec![BlockedParams { threads: 1, ..Default::default() }]
            };
        for base in bases {
            for &t in threads {
                push(
                    &mut grid,
                    ConvCandidate {
                        config,
                        blocked: BlockedParams { threads: t, ..base },
                    },
                );
            }
        }
    }
    let default = ConvCandidate {
        config: ConvConfig::im2col(),
        blocked: BlockedParams::default(),
    };
    if !grid.contains(&default) {
        grid.insert(0, default);
    }
    grid
}

/// One timed conv grid point.
#[derive(Debug, Clone)]
pub struct ConvSweepMeasurement {
    /// Problem-class op key the winner persists under.
    pub problem: String,
    /// Artifact the measurement executed.
    pub artifact: String,
    /// Candidate this grid point timed.
    pub candidate: ConvCandidate,
    /// Best (minimum) execution time over the repetitions.
    pub best: Duration,
    /// Measured throughput, GFLOP/s.
    pub gflops: f64,
}

/// A finished native conv sweep: every measurement plus the per-problem
/// winners that were persisted as [`super::Selection::ConvNative`].
#[derive(Debug, Default)]
pub struct ConvNativeSweep {
    /// Every timed grid point, in measurement order.
    pub rows: Vec<ConvSweepMeasurement>,
    /// Winner per problem-class op key.
    pub winners: BTreeMap<String, (ConvCandidate, f64)>,
}

impl ConvNativeSweep {
    /// Best measured gflops for a problem under exactly `candidate`.
    pub fn gflops_for(
        &self,
        problem: &str,
        candidate: &ConvCandidate,
    ) -> Option<f64> {
        self.rows
            .iter()
            .filter(|r| r.problem == problem && r.candidate == *candidate)
            .map(|r| r.gflops)
            .reduce(f64::max)
    }

    /// The distinct algorithms measured for a problem — the sweep's
    /// proof that the algorithm axis was actually swept, not collapsed.
    pub fn algorithms_for(&self, problem: &str) -> Vec<ConvAlgorithm> {
        let mut algs: Vec<ConvAlgorithm> = Vec::new();
        for r in self.rows.iter().filter(|r| r.problem == problem) {
            if !algs.contains(&r.candidate.config.algorithm) {
                algs.push(r.candidate.config.algorithm);
            }
        }
        algs
    }
}

/// Measure every conv artifact in `group` under every applicable grid
/// point and persist the per-problem winner into `db` as a
/// [`super::Selection::ConvNative`] entry.
///
/// "Applicable" applies the native fallback rule per artifact shape:
/// candidates whose algorithm would fall back (e.g. Winograd on a
/// strided layer) are skipped rather than timed as im2col duplicates.
/// `apply` installs a candidate on the engine before timing — for
/// `NativeEngine` that is `|e, c| e.set_conv_params(c.config,
/// c.blocked)`.
pub fn tune_conv_native_sweep<B: Backend>(
    engine: &mut B,
    group: &str,
    grid: &[ConvCandidate],
    iters: usize,
    device: &str,
    apply: &mut dyn FnMut(&mut B, &ConvCandidate),
    db: &mut SelectionDb,
) -> Result<ConvNativeSweep> {
    let metas: Vec<ArtifactMeta> = engine
        .store()
        .in_group(group)
        .filter(|m| m.kind == "conv")
        .cloned()
        .collect();
    let mut sweep = ConvNativeSweep::default();
    for meta in metas {
        let Some(key) = selection_key_for(&meta, device) else {
            continue;
        };
        let Some(layer) = meta.layer.as_ref() else {
            continue;
        };
        // Keep only candidates that run their own algorithm on this
        // shape — the engine's plan-time fallback rule, verbatim, so
        // the sweep can never time a fallback duplicate the plan would
        // resolve differently.
        let applicable: Vec<&ConvCandidate> = grid
            .iter()
            .filter(|c| {
                native_conv_algorithm_dims(
                    &c.config,
                    layer.window,
                    layer.stride,
                ) == c.config.algorithm
            })
            .collect();
        if applicable.is_empty() {
            continue;
        }
        let inputs = engine.synth_inputs(&meta.name, 17)?;
        let mut run_err = None;
        let mut score = |i: usize| -> Option<f64> {
            apply(engine, applicable[i]);
            match engine.run_timed(&meta.name, &inputs, iters) {
                Ok((out, best)) => {
                    let gflops = out.gflops(meta.flops);
                    sweep.rows.push(ConvSweepMeasurement {
                        problem: key.op.clone(),
                        artifact: meta.name.clone(),
                        candidate: *applicable[i],
                        best,
                        gflops,
                    });
                    Some(gflops)
                }
                Err(e) => {
                    run_err = Some(e);
                    None
                }
            }
        };
        let found = ExhaustiveSearch.search(applicable.len(), &mut score);
        if let Some(e) = run_err {
            return Err(e);
        }
        if let Some((idx, _evals, gflops)) = found {
            let better = db
                .get_conv_native(&key)
                .map(|(_, _, g)| gflops > g)
                .unwrap_or(true);
            if better {
                let win = *applicable[idx];
                db.put_conv_native(
                    key.clone(),
                    win.config,
                    win.blocked,
                    gflops,
                );
                sweep.winners.insert(key.op.clone(), (win, gflops));
            }
        }
    }
    Ok(sweep)
}

/// Derive the tuning-DB key for an artifact on `device` (the platform
/// string the host sweep and `NativeEngine`'s plan-time lookup share —
/// both must produce identical keys or tuned entries are never found).
pub fn selection_key_for(
    meta: &ArtifactMeta,
    device: &str,
) -> Option<SelectionKey> {
    match meta.kind.as_str() {
        "gemm" => {
            Some(SelectionKey::gemm(device, meta.m?, meta.n?, meta.k?))
        }
        "conv" => {
            let l = meta.layer.as_ref()?;
            Some(SelectionKey::conv(
                device,
                l.window,
                l.stride,
                l.in_h,
                l.in_w,
                l.in_c,
                l.out_c,
                meta.batch.unwrap_or(1),
            ))
        }
        _ => None,
    }
}

/// Measure every artifact in `group` under every grid point and persist
/// the per-problem winner into `db`, keyed by (device, problem class).
///
/// Generic over [`Backend`]; `apply` installs a candidate on the engine
/// before it is timed (for `NativeEngine` that is
/// `|e, p| e.set_params(*p)`).  The per-problem argmax runs through
/// [`ExhaustiveSearch`] — the measured counterpart of the modeled
/// `tune_gemm`/`tune_conv`, and the same discipline as `tune_measured`:
/// `iters` repetitions, minimum taken, throughput from manifest flops.
///
/// # Examples
///
/// ```
/// use portable_kernels::blas::BlockedParams;
/// use portable_kernels::runtime::{ArtifactStore, NativeEngine, HOST_DEVICE};
/// use portable_kernels::tuner::{
///     tune_blocked_sweep, SelectionDb, SelectionKey,
/// };
/// use portable_kernels::util::tmp::TempDir;
///
/// let dir = TempDir::new("doc-sweep").unwrap();
/// std::fs::write(
///     dir.path().join("manifest.json"),
///     r#"{"version": 1, "artifacts": [{
///         "name": "g16", "kind": "gemm", "impl": "pallas",
///         "file": "g16.hlo.txt", "flops": 8192,
///         "m": 16, "n": 16, "k": 16,
///         "inputs": [{"shape": [16, 16], "dtype": "float32"},
///                    {"shape": [16, 16], "dtype": "float32"}],
///         "groups": ["gemm"]}]}"#,
/// )
/// .unwrap();
/// let store = ArtifactStore::open(dir.path()).unwrap();
/// let mut engine = NativeEngine::new(store).unwrap();
///
/// let grid = [
///     BlockedParams { threads: 1, ..BlockedParams::default() },
///     BlockedParams { bm: 8, bn: 8, bk: 8, mr: 2, nr: 2, threads: 1 },
/// ];
/// let mut db = SelectionDb::new();
/// let sweep = tune_blocked_sweep(
///     &mut engine,
///     "gemm",
///     &grid,
///     1,
///     HOST_DEVICE,
///     &mut |e, p| e.set_params(*p),
///     &mut db,
/// )
/// .unwrap();
/// assert_eq!(sweep.rows.len(), grid.len());
/// let key = SelectionKey::gemm(HOST_DEVICE, 16, 16, 16);
/// assert!(db.get_blocked(&key).is_some(), "winner persisted");
/// ```
pub fn tune_blocked_sweep<B: Backend>(
    engine: &mut B,
    group: &str,
    grid: &[BlockedParams],
    iters: usize,
    device: &str,
    apply: &mut dyn FnMut(&mut B, &BlockedParams),
    db: &mut SelectionDb,
) -> Result<BlockedSweep> {
    let metas: Vec<ArtifactMeta> =
        engine.store().in_group(group).cloned().collect();
    let mut sweep = BlockedSweep::default();
    for meta in metas {
        let Some(key) = selection_key_for(&meta, device) else {
            continue;
        };
        let inputs = engine.synth_inputs(&meta.name, 17)?;
        let mut run_err = None;
        let mut score = |i: usize| -> Option<f64> {
            apply(engine, &grid[i]);
            match engine.run_timed(&meta.name, &inputs, iters) {
                Ok((out, best)) => {
                    let gflops = out.gflops(meta.flops);
                    sweep.rows.push(SweepMeasurement {
                        problem: key.op.clone(),
                        artifact: meta.name.clone(),
                        params: grid[i],
                        best,
                        gflops,
                    });
                    Some(gflops)
                }
                Err(e) => {
                    run_err = Some(e);
                    None
                }
            }
        };
        let found = ExhaustiveSearch.search(grid.len(), &mut score);
        if let Some(e) = run_err {
            return Err(e);
        }
        if let Some((idx, _evals, gflops)) = found {
            // Several artifacts can share a problem class (same shape,
            // different lowering); keep the best selection seen.
            let better = db
                .get_blocked(&key)
                .map(|(_, g)| gflops > g)
                .unwrap_or(true);
            if better {
                db.put_blocked(key.clone(), grid[idx], gflops);
                sweep.winners.insert(key.op.clone(), (grid[idx], gflops));
            }
        }
    }
    Ok(sweep)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{ArtifactStore, NativeEngine, HOST_DEVICE};
    use crate::util::tmp::TempDir;

    fn sweep_fixture() -> (TempDir, NativeEngine) {
        let dir = TempDir::new("hostsweep").unwrap();
        std::fs::write(
            dir.path().join("manifest.json"),
            r#"{"version": 1, "artifacts": [
              {"name": "g96", "kind": "gemm", "impl": "pallas",
               "file": "g96.hlo.txt", "flops": 1769472,
               "m": 96, "n": 96, "k": 96, "groups": ["gemm"],
               "inputs": [{"shape": [96, 96], "dtype": "float32"},
                          {"shape": [96, 96], "dtype": "float32"}]},
              {"name": "c16", "kind": "conv", "impl": "pallas",
               "file": "c16.hlo.txt", "flops": 1179648, "batch": 2,
               "algorithm": "im2col", "groups": ["conv"],
               "layer": {"name": "sweep", "window": 3, "stride": 1,
                         "in_h": 16, "in_w": 16, "in_c": 8, "out_c": 16,
                         "out_h": 16, "out_w": 16, "padding": "SAME",
                         "flops": 1179648},
               "inputs": [{"shape": [2, 16, 16, 8], "dtype": "float32"},
                          {"shape": [3, 3, 8, 16], "dtype": "float32"}]}
            ]}"#,
        )
        .unwrap();
        let store = ArtifactStore::open(dir.path()).unwrap();
        let engine = NativeEngine::new(store).unwrap();
        (dir, engine)
    }

    #[test]
    fn grid_always_contains_the_default() {
        for quick in [true, false] {
            let grid = blocked_grid(quick, &[1, 2]);
            assert!(grid.contains(&BlockedParams::default()), "quick={quick}");
            // Dedup: no candidate appears twice.
            for (i, a) in grid.iter().enumerate() {
                assert!(!grid[i + 1..].contains(a), "{a:?} duplicated");
            }
            // The threads axis is actually crossed in.
            assert!(grid.iter().any(|p| p.threads == 2));
        }
    }

    #[test]
    fn sweep_measures_grid_and_persists_winners() {
        let (_dir, mut engine) = sweep_fixture();
        let grid = blocked_grid(true, &[1, 2]);
        let mut db = SelectionDb::new();
        let gemm = tune_blocked_sweep(
            &mut engine,
            "gemm",
            &grid,
            2,
            HOST_DEVICE,
            &mut |e, p| e.set_params(*p),
            &mut db,
        )
        .unwrap();
        let conv = tune_blocked_sweep(
            &mut engine,
            "conv",
            &grid,
            2,
            HOST_DEVICE,
            &mut |e, p| e.set_params(*p),
            &mut db,
        )
        .unwrap();
        // Every grid point was measured for every artifact.
        assert_eq!(gemm.rows.len(), grid.len());
        assert_eq!(conv.rows.len(), grid.len());
        assert_eq!(db.len(), 2, "one selection per problem class");
        // The persisted winner is the row argmax, and it comes from the
        // grid.
        for sweep in [&gemm, &conv] {
            for (op, (params, gflops)) in &sweep.winners {
                assert!(grid.contains(params));
                let max = sweep
                    .rows
                    .iter()
                    .filter(|r| &r.problem == op)
                    .map(|r| r.gflops)
                    .fold(f64::MIN, f64::max);
                assert!(*gflops >= max - 1e-12, "{op}: {gflops} < {max}");
            }
        }
        // Tuned >= default by construction: the default is in the grid,
        // so the argmax can never score below it.  Note the key op is
        // the *bucketed* problem class (96^3 -> the 128^3 bucket), and
        // sweep rows carry the same bucketed op.
        let key = SelectionKey::gemm(HOST_DEVICE, 96, 96, 96);
        assert_eq!(key.op, "gemm_128x128x128");
        let (_, tuned) = db.get_blocked(&key).unwrap();
        let dflt = gemm
            .gflops_for(&key.op, &BlockedParams::default())
            .unwrap();
        assert!(tuned >= dflt);
    }

    #[test]
    fn conv_grid_sweeps_all_three_algorithms() {
        for quick in [true, false] {
            let grid = conv_native_grid(quick, &[1, 2]);
            for alg in [
                ConvAlgorithm::Im2col,
                ConvAlgorithm::Tiled,
                ConvAlgorithm::Winograd,
            ] {
                assert!(
                    grid.iter().any(|c| c.config.algorithm == alg),
                    "quick={quick}: {alg} missing from the grid"
                );
            }
            // Dedup + the untuned baseline is always present.
            for (i, c) in grid.iter().enumerate() {
                assert!(!grid[i + 1..].contains(c), "{} duplicated", c.name());
            }
            assert!(grid.contains(&ConvCandidate {
                config: ConvConfig::im2col(),
                blocked: BlockedParams::default(),
            }));
            // The threads axis is crossed into every algorithm family.
            for alg in [ConvAlgorithm::Tiled, ConvAlgorithm::Winograd] {
                assert!(grid
                    .iter()
                    .any(|c| c.config.algorithm == alg
                        && c.blocked.threads == 2));
            }
        }
    }

    #[test]
    fn conv_sweep_measures_algorithms_and_persists_conv_native() {
        let (_dir, mut engine) = sweep_fixture();
        let grid = conv_native_grid(true, &[1, 2]);
        let mut db = SelectionDb::new();
        let sweep = tune_conv_native_sweep(
            &mut engine,
            "conv",
            &grid,
            2,
            HOST_DEVICE,
            &mut |e, c| e.set_conv_params(c.config, c.blocked),
            &mut db,
        )
        .unwrap();
        // c16 is 3x3/s1: every candidate applies, so the whole grid was
        // measured and all three algorithms ran natively.
        assert_eq!(sweep.rows.len(), grid.len());
        let key = SelectionKey::conv(HOST_DEVICE, 3, 1, 16, 16, 8, 16, 2);
        let algs = sweep.algorithms_for(&key.op);
        for alg in [
            ConvAlgorithm::Im2col,
            ConvAlgorithm::Tiled,
            ConvAlgorithm::Winograd,
        ] {
            assert!(algs.contains(&alg), "{alg} never measured: {algs:?}");
        }
        // The persisted winner is the argmax and beats (or ties) the
        // untuned default, which is in the grid by construction.
        let (wc, wb, wg) = db.get_conv_native(&key).unwrap();
        let (win, win_g) = &sweep.winners[&key.op];
        assert_eq!((wc, wb), (win.config, win.blocked));
        assert_eq!(wg, *win_g);
        let default = ConvCandidate {
            config: ConvConfig::im2col(),
            blocked: BlockedParams::default(),
        };
        let dflt = sweep.gflops_for(&key.op, &default).unwrap();
        assert!(wg >= dflt);
        // GEMM artifacts are untouched by the conv sweep.
        assert!(db
            .get_conv_native(&SelectionKey::gemm(HOST_DEVICE, 96, 96, 96))
            .is_none());
    }

    #[test]
    fn conv_sweep_skips_winograd_off_its_domain() {
        // A strided conv: winograd candidates must be skipped, not timed
        // as im2col duplicates.
        let dir = TempDir::new("hostsweep").unwrap();
        std::fs::write(
            dir.path().join("manifest.json"),
            r#"{"version": 1, "artifacts": [
              {"name": "cs2", "kind": "conv", "impl": "pallas",
               "file": "cs2.hlo.txt", "flops": 294912, "batch": 1,
               "algorithm": "im2col", "groups": ["conv"],
               "layer": {"name": "s2", "window": 3, "stride": 2,
                         "in_h": 16, "in_w": 16, "in_c": 8, "out_c": 16,
                         "out_h": 8, "out_w": 8, "padding": "SAME",
                         "flops": 294912},
               "inputs": [{"shape": [1, 16, 16, 8], "dtype": "float32"},
                          {"shape": [3, 3, 8, 16], "dtype": "float32"}]}
            ]}"#,
        )
        .unwrap();
        let store = ArtifactStore::open(dir.path()).unwrap();
        let mut engine = NativeEngine::new(store).unwrap();
        let grid = conv_native_grid(true, &[1]);
        let n_wino = grid
            .iter()
            .filter(|c| c.config.algorithm == ConvAlgorithm::Winograd)
            .count();
        assert!(n_wino > 0);
        let mut db = SelectionDb::new();
        let sweep = tune_conv_native_sweep(
            &mut engine,
            "conv",
            &grid,
            1,
            HOST_DEVICE,
            &mut |e, c| e.set_conv_params(c.config, c.blocked),
            &mut db,
        )
        .unwrap();
        assert_eq!(sweep.rows.len(), grid.len() - n_wino);
        let key = SelectionKey::conv(HOST_DEVICE, 3, 2, 16, 16, 8, 16, 1);
        assert!(!sweep
            .algorithms_for(&key.op)
            .contains(&ConvAlgorithm::Winograd));
        assert!(db.get_conv_native(&key).is_some());
    }

    #[test]
    fn widened_gemm_candidates_cover_the_registry() {
        // Full mode sweeps every monomorphized (mr, nr); quick mode
        // reaches beyond the historical {4x8, 8x16} hand-set.
        let full = blocked_candidates(false);
        for &(mr, nr) in micro_kernel_shapes() {
            assert!(
                full.iter().any(|p| p.mr == mr && p.nr == nr),
                "({mr}, {nr}) missing from the full candidate set"
            );
        }
        let quick = blocked_candidates(true);
        assert!(quick.iter().any(|p| (p.mr, p.nr) == (2, 16)));
        assert!(quick.iter().any(|p| (p.mr, p.nr) == (16, 8)));
        for set in [&full, &quick] {
            for (i, c) in set.iter().enumerate() {
                assert!(!set[i + 1..].contains(c), "{c:?} duplicated");
            }
        }
    }

    #[test]
    fn artifacts_without_keys_are_skipped() {
        let dir = TempDir::new("hostsweep").unwrap();
        std::fs::write(
            dir.path().join("manifest.json"),
            r#"{"version": 1, "artifacts": [
              {"name": "odd", "kind": "fft", "impl": "pallas",
               "file": "odd.hlo.txt", "flops": 1, "inputs": [],
               "groups": ["gemm"]}]}"#,
        )
        .unwrap();
        let store = ArtifactStore::open(dir.path()).unwrap();
        let mut engine = NativeEngine::new(store).unwrap();
        let mut db = SelectionDb::new();
        let sweep = tune_blocked_sweep(
            &mut engine,
            "gemm",
            &blocked_grid(true, &[1]),
            1,
            HOST_DEVICE,
            &mut |e, p| e.set_params(*p),
            &mut db,
        )
        .unwrap();
        assert!(sweep.rows.is_empty());
        assert!(db.is_empty());
    }
}
