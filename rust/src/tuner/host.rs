//! Measured per-host sweeps over any [`KernelSpace`].
//!
//! This is the paper's headline workflow run end-to-end on hardware we
//! actually own: enumerate kernel parameter combinations — the blocking,
//! the `threads` knob, *which algorithm* runs (§4.1), and the
//! runtime-detected micro-kernel **ISA** — *measure* each one through a
//! [`Backend`] (no model in the loop), and persist the winner per
//! (platform, problem class) into the [`SelectionDb`] that
//! `NativeEngine` consults at plan time.  Measured — not modeled — sweeps
//! are what make the portability claim credible (cf. Reguly,
//! arXiv:2309.10075); CI runs the quick variant on every merge via
//! `cargo run --release --example tune_device -- --quick`.
//!
//! One generic function, [`tune_space_sweep`], does all of it: the space
//! point type supplies applicability (shape domain + host capability)
//! and the DB codec, so a new tunable axis never needs a new sweep.  The
//! historical entry points [`tune_blocked_sweep`] and
//! [`tune_conv_native_sweep`] survive as thin wrappers over the generic
//! (scalar-ISA GEMM grid, conv grid respectively).

use std::collections::BTreeMap;
use std::time::Duration;

use crate::blas::{BlockedParams, Isa};
use crate::config::{
    micro_kernel_shapes, ConvAlgorithm, ConvConfig, ConvPoint, GemmPoint,
    KernelSpace, Problem,
};
use crate::error::Result;
use crate::runtime::{ArtifactMeta, Backend};

use super::db::{SelectionDb, SelectionKey};
use super::search::{ExhaustiveSearch, SearchStrategy};

/// One timed grid point of a generic space sweep.
#[derive(Debug, Clone)]
pub struct SpaceMeasurement<P: KernelSpace> {
    /// Problem-class op key (the `SelectionKey::op` the winner persists
    /// under, e.g. `gemm_128x128x128`).
    pub problem: String,
    /// Artifact the measurement executed.
    pub artifact: String,
    /// The space point this grid point timed.
    pub point: P,
    /// Best (minimum) execution time over the repetitions.
    pub best: Duration,
    /// Measured throughput, GFLOP/s (from the artifact's manifest flops).
    pub gflops: f64,
}

/// A finished generic sweep: every measurement plus the per-problem
/// winners that were persisted.
#[derive(Debug)]
pub struct SpaceSweep<P: KernelSpace> {
    /// Every timed grid point, in measurement order.
    pub rows: Vec<SpaceMeasurement<P>>,
    /// Winner per problem-class op key.
    pub winners: BTreeMap<String, (P, f64)>,
}

impl<P: KernelSpace> Default for SpaceSweep<P> {
    fn default() -> Self {
        Self { rows: Vec::new(), winners: BTreeMap::new() }
    }
}

impl<P: KernelSpace> SpaceSweep<P> {
    /// Best measured gflops for a problem under exactly `point`
    /// (e.g. the default point, for tuned-vs-default reporting).
    pub fn gflops_for(&self, problem: &str, point: &P) -> Option<f64> {
        self.rows
            .iter()
            .filter(|r| r.problem == problem && r.point == *point)
            .map(|r| r.gflops)
            .reduce(f64::max)
    }

    /// The distinct values of some axis measured for a problem, in
    /// measurement order — the proof an axis was actually swept, not
    /// collapsed (`axis` projects the axis out of a point, e.g.
    /// `|p| p.isa` or `|p| p.config.algorithm`).
    pub fn axis_values_for<A: PartialEq>(
        &self,
        problem: &str,
        axis: impl Fn(&P) -> A,
    ) -> Vec<A> {
        let mut values: Vec<A> = Vec::new();
        for r in self.rows.iter().filter(|r| r.problem == problem) {
            let v = axis(&r.point);
            if !values.contains(&v) {
                values.push(v);
            }
        }
        values
    }
}

/// The problem facts applicability depends on, derived from an
/// artifact's manifest metadata (`None` for kinds no space tunes).
pub fn problem_for(meta: &ArtifactMeta) -> Option<Problem> {
    match meta.kind.as_str() {
        "gemm" => Some(Problem::Gemm {
            m: meta.m?,
            n: meta.n?,
            k: meta.k?,
        }),
        "conv" => {
            let l = meta.layer.as_ref()?;
            Some(Problem::Conv { window: l.window, stride: l.stride })
        }
        _ => None,
    }
}

/// Derive the tuning-DB key for an artifact on `device` (the platform
/// string the host sweep and `NativeEngine`'s plan-time lookup share —
/// both must produce identical keys or tuned entries are never found).
pub fn selection_key_for(
    meta: &ArtifactMeta,
    device: &str,
) -> Option<SelectionKey> {
    match meta.kind.as_str() {
        "gemm" => {
            Some(SelectionKey::gemm(device, meta.m?, meta.n?, meta.k?))
        }
        "conv" => {
            let l = meta.layer.as_ref()?;
            Some(SelectionKey::conv(
                device,
                l.window,
                l.stride,
                l.in_h,
                l.in_w,
                l.in_c,
                l.out_c,
                meta.batch.unwrap_or(1),
            ))
        }
        _ => None,
    }
}

/// The device-independent problem-class label for an artifact — the
/// `op` half of its [`SelectionKey`] (e.g. `gemm_128x128x128`,
/// `conv_3x3s1_16x16x8k16b2`).  The serving layer buckets its
/// per-request latency accounting under this label, so the hot classes
/// a re-tune pass should probe line up exactly with the keys the
/// selection DB stores winners under.  `None` for artifacts outside the
/// tuned kinds.
pub fn shape_class_for(meta: &ArtifactMeta) -> Option<String> {
    selection_key_for(meta, "").map(|key| key.op)
}

/// Measure every artifact in `group` under every *applicable* grid point
/// of space `P` and persist the per-problem winner into `db` under
/// `P::KIND` — the one generic measure→persist loop behind every host
/// sweep.
///
/// "Applicable" is the space's own rule ([`KernelSpace::applicable`]):
/// shape-domain fallbacks (a Winograd point on a strided layer) and
/// host capability (an ISA this CPU lacks) are *skipped*, never timed as
/// fallback duplicates.  Artifacts with no applicable points (e.g. GEMM
/// artifacts under the conv space) are skipped entirely.  `apply`
/// installs a point on the engine before timing — for `NativeEngine`
/// that is `|e, p| e.set_gemm_point(*p)` / `|e, p| e.set_conv_point(*p)`.
/// The per-problem argmax runs through [`ExhaustiveSearch`]; `iters`
/// repetitions, minimum taken, throughput from manifest flops.
///
/// # Examples
///
/// ```
/// use portable_kernels::blas::BlockedParams;
/// use portable_kernels::config::GemmPoint;
/// use portable_kernels::runtime::{ArtifactStore, NativeEngine, HOST_DEVICE};
/// use portable_kernels::tuner::{
///     tune_space_sweep, SelectionDb, SelectionKey,
/// };
/// use portable_kernels::util::tmp::TempDir;
///
/// let dir = TempDir::new("doc-sweep").unwrap();
/// std::fs::write(
///     dir.path().join("manifest.json"),
///     r#"{"version": 1, "artifacts": [{
///         "name": "g16", "kind": "gemm", "impl": "pallas",
///         "file": "g16.hlo.txt", "flops": 8192,
///         "m": 16, "n": 16, "k": 16,
///         "inputs": [{"shape": [16, 16], "dtype": "float32"},
///                    {"shape": [16, 16], "dtype": "float32"}],
///         "groups": ["gemm"]}]}"#,
/// )
/// .unwrap();
/// let store = ArtifactStore::open(dir.path()).unwrap();
/// let mut engine = NativeEngine::new(store).unwrap();
///
/// let grid = [
///     GemmPoint::default(),
///     GemmPoint::scalar(BlockedParams {
///         bm: 8, bn: 8, bk: 8, mr: 2, nr: 2, threads: 1,
///     }),
/// ];
/// let mut db = SelectionDb::new();
/// let sweep = tune_space_sweep(
///     &mut engine,
///     "gemm",
///     &grid,
///     1,
///     HOST_DEVICE,
///     &mut |e, p: &GemmPoint| e.set_gemm_point(*p),
///     &mut db,
/// )
/// .unwrap();
/// assert_eq!(sweep.rows.len(), grid.len());
/// let key = SelectionKey::gemm(HOST_DEVICE, 16, 16, 16);
/// assert!(db.get::<GemmPoint>(&key).is_some(), "winner persisted");
/// ```
#[allow(clippy::too_many_arguments)]
pub fn tune_space_sweep<B: Backend, P: KernelSpace>(
    engine: &mut B,
    group: &str,
    grid: &[P],
    iters: usize,
    device: &str,
    apply: &mut dyn FnMut(&mut B, &P),
    db: &mut SelectionDb,
) -> Result<SpaceSweep<P>> {
    tune_space_sweep_filtered(
        engine,
        group,
        grid,
        iters,
        device,
        apply,
        db,
        &|_| true,
    )
}

/// [`tune_space_sweep`] restricted to the artifacts `filter` accepts —
/// the *targeted* probe shape the online re-tuner uses: instead of
/// re-measuring the whole group, it probes only the artifacts the
/// serving latency accounting marked hot, so a re-tune pass costs
/// seconds, not a full offline sweep.
#[allow(clippy::too_many_arguments)]
pub fn tune_space_sweep_filtered<B: Backend, P: KernelSpace>(
    engine: &mut B,
    group: &str,
    grid: &[P],
    iters: usize,
    device: &str,
    apply: &mut dyn FnMut(&mut B, &P),
    db: &mut SelectionDb,
    filter: &dyn Fn(&ArtifactMeta) -> bool,
) -> Result<SpaceSweep<P>> {
    let metas: Vec<ArtifactMeta> = engine
        .store()
        .in_group(group)
        .filter(|m| filter(m))
        .cloned()
        .collect();
    let mut sweep = SpaceSweep::default();
    for meta in metas {
        let Some(key) = selection_key_for(&meta, device) else {
            continue;
        };
        let Some(problem) = problem_for(&meta) else {
            continue;
        };
        let applicable: Vec<&P> =
            grid.iter().filter(|p| p.applicable(&problem)).collect();
        if applicable.is_empty() {
            continue;
        }
        let inputs = engine.synth_inputs(&meta.name, 17)?;
        let mut run_err = None;
        let mut score = |i: usize| -> Option<f64> {
            apply(engine, applicable[i]);
            match engine.run_timed(&meta.name, &inputs, iters) {
                Ok((out, best)) => {
                    let gflops = out.gflops(meta.flops);
                    sweep.rows.push(SpaceMeasurement {
                        problem: key.op.clone(),
                        artifact: meta.name.clone(),
                        point: *applicable[i],
                        best,
                        gflops,
                    });
                    Some(gflops)
                }
                Err(e) => {
                    run_err = Some(e);
                    None
                }
            }
        };
        let found = ExhaustiveSearch.search(applicable.len(), &mut score);
        if let Some(e) = run_err {
            return Err(e);
        }
        if let Some((idx, _evals, gflops)) = found {
            // Several artifacts can share a problem class (same shape,
            // different lowering); keep the best selection seen.
            let better = db
                .get::<P>(&key)
                .map(|(_, g)| gflops > g)
                .unwrap_or(true);
            if better {
                db.put(key.clone(), *applicable[idx], gflops);
                sweep.winners.insert(key.op.clone(), (*applicable[idx], gflops));
            }
        }
    }
    Ok(sweep)
}

// ---- grids ----

/// The base `BlockedParams` candidate sets — the same serial candidates
/// the `blocked.rs` tests and the `rust_blas` bench exercise, widened
/// over the monomorphized `(mr, nr)` registry
/// ([`crate::config::micro_kernel_shapes`]) so the sweep measures the
/// whole fast micro-tile set, not a hand-picked subset.
pub fn blocked_candidates(quick: bool) -> Vec<BlockedParams> {
    let p = |bm, bn, bk, mr, nr| BlockedParams {
        bm,
        bn,
        bk,
        mr,
        nr,
        threads: 1,
    };
    let mut out = if quick {
        // Tiny grid for the CI smoke sweep, plus registry shapes beyond
        // the historical hand-written set so the widened axis is always
        // exercised.
        vec![
            BlockedParams { threads: 1, ..Default::default() },
            p(32, 32, 32, 4, 8),
            p(16, 32, 16, 4, 8),
            p(32, 32, 32, 2, 16),
            p(32, 32, 32, 16, 8),
        ]
    } else {
        let mut v = vec![
            BlockedParams { threads: 1, ..Default::default() },
            p(8, 8, 8, 2, 2),
            p(16, 32, 5, 4, 8),
            p(64, 64, 64, 8, 16),
            p(32, 32, 32, 4, 8),
            p(128, 128, 64, 8, 16),
        ];
        // The full mr × nr registry at one representative blocking.
        for &(mr, nr) in micro_kernel_shapes() {
            v.push(p(64, 64, 64, mr, nr));
        }
        v
    };
    // Order-preserving dedup (the registry cross re-generates a couple
    // of the hand-written entries).
    let mut seen: Vec<BlockedParams> = Vec::with_capacity(out.len());
    out.retain(|c| {
        if seen.contains(c) {
            false
        } else {
            seen.push(*c);
            true
        }
    });
    out
}

/// The blocking-only grid: [`blocked_candidates`] × `threads`,
/// deduplicated, with [`BlockedParams::default`] always present so every
/// sweep measures the untuned baseline it is compared against.
pub fn blocked_grid(quick: bool, threads: &[usize]) -> Vec<BlockedParams> {
    let mut grid: Vec<BlockedParams> = Vec::new();
    for base in blocked_candidates(quick) {
        for &t in threads {
            let cand = BlockedParams { threads: t, ..base };
            if !grid.contains(&cand) {
                grid.push(cand);
            }
        }
    }
    let default = BlockedParams::default();
    if !grid.contains(&default) {
        grid.insert(0, default);
    }
    grid
}

/// The full measured GEMM grid: [`blocked_grid`] × the given ISAs
/// (normally [`Isa::detect`]), deduplicated, with the default scalar
/// point always present as the untuned baseline.  Non-scalar ISAs are
/// crossed only with *monomorphized* registry micro-tiles — off-registry
/// shapes run the generic scalar kernel whatever the ISA, so timing them
/// per-ISA would measure the same kernel repeatedly.
pub fn gemm_point_grid(
    quick: bool,
    threads: &[usize],
    isas: &[Isa],
) -> Vec<GemmPoint> {
    let mut grid: Vec<GemmPoint> = Vec::new();
    for params in blocked_grid(quick, threads) {
        for &isa in isas {
            if isa != Isa::Scalar && !params.is_monomorphized() {
                continue;
            }
            let cand = GemmPoint { params, isa };
            if !grid.contains(&cand) {
                grid.push(cand);
            }
        }
    }
    let default = GemmPoint::default();
    if !grid.contains(&default) {
        grid.insert(0, default);
    }
    grid
}

/// One native conv sweep candidate: an algorithm + its knobs — since the
/// space unification this *is* the conv kernel-space point
/// ([`ConvPoint`]: the [`ConvConfig`] names the algorithm and
/// tile/vector parameters, the [`BlockedParams`] carry the im2col GEMM
/// blocking and the `threads` knob every algorithm honors).
pub type ConvCandidate = ConvPoint;

/// The base [`ConvConfig`] candidates the native conv sweep measures:
/// im2col, a handful of tiled tile/vector shapes, and Winograd m=2 —
/// all three §4.1 algorithm families, deliberately much smaller than
/// the modeled `config::conv_space` (these get *measured*, every point
/// costs wall time).
pub fn conv_candidates(quick: bool) -> Vec<ConvConfig> {
    let mut out = vec![ConvConfig::im2col()];
    if quick {
        out.push(ConvConfig::tiled(1, 1, 1, 4));
        out.push(ConvConfig::tiled(2, 2, 1, 4));
        out.push(ConvConfig::winograd(2));
    } else {
        for (th, tw, vc, vk) in
            [(1, 1, 1, 4), (2, 2, 1, 4), (4, 4, 4, 4), (2, 4, 1, 8)]
        {
            out.push(ConvConfig::tiled(th, tw, vc, vk));
        }
        out.push(ConvConfig::winograd(2));
    }
    out
}

/// The full native conv grid: [`conv_candidates`] × `threads`, im2col
/// additionally crossed with the [`blocked_candidates`] GEMM blockings,
/// deduplicated, with the plain default im2col candidate always present
/// as the untuned baseline.
pub fn conv_native_grid(
    quick: bool,
    threads: &[usize],
) -> Vec<ConvCandidate> {
    let mut grid: Vec<ConvCandidate> = Vec::new();
    let push = |grid: &mut Vec<ConvCandidate>, cand: ConvCandidate| {
        if !grid.contains(&cand) {
            grid.push(cand);
        }
    };
    for config in conv_candidates(quick) {
        // Only the im2col path uses the GEMM blocking; other algorithms
        // read just `threads` from it, so sweeping blockings for them
        // would time the same kernel repeatedly.
        let bases: Vec<BlockedParams> =
            if config.algorithm == ConvAlgorithm::Im2col {
                blocked_candidates(quick)
            } else {
                vec![BlockedParams { threads: 1, ..Default::default() }]
            };
        for base in bases {
            for &t in threads {
                push(
                    &mut grid,
                    ConvCandidate {
                        config,
                        blocked: BlockedParams { threads: t, ..base },
                    },
                );
            }
        }
    }
    let default = ConvCandidate::default();
    if !grid.contains(&default) {
        grid.insert(0, default);
    }
    grid
}

// ---- legacy typed wrappers over the generic sweep ----

/// One timed grid point of the legacy blocking-only sweep view.
#[derive(Debug, Clone)]
pub struct SweepMeasurement {
    /// Problem-class op key the winner persists under.
    pub problem: String,
    /// Artifact the measurement executed.
    pub artifact: String,
    /// Parameter combination this grid point timed.
    pub params: BlockedParams,
    /// Best (minimum) execution time over the repetitions.
    pub best: Duration,
    /// Measured throughput, GFLOP/s.
    pub gflops: f64,
}

/// A finished legacy blocking sweep — the scalar-ISA view of a
/// [`SpaceSweep<GemmPoint>`].
#[derive(Debug, Default)]
pub struct BlockedSweep {
    /// Every timed grid point, in measurement order.
    pub rows: Vec<SweepMeasurement>,
    /// Winner per problem-class op key.
    pub winners: BTreeMap<String, (BlockedParams, f64)>,
}

impl BlockedSweep {
    /// Best measured gflops for a problem under exactly `params`
    /// (e.g. the default config, for tuned-vs-default reporting).
    pub fn gflops_for(
        &self,
        problem: &str,
        params: &BlockedParams,
    ) -> Option<f64> {
        self.rows
            .iter()
            .filter(|r| r.problem == problem && r.params == *params)
            .map(|r| r.gflops)
            .reduce(f64::max)
    }
}

/// One timed conv grid point (legacy view; the candidate *is* the conv
/// space point).
#[derive(Debug, Clone)]
pub struct ConvSweepMeasurement {
    /// Problem-class op key the winner persists under.
    pub problem: String,
    /// Artifact the measurement executed.
    pub artifact: String,
    /// Candidate this grid point timed.
    pub candidate: ConvCandidate,
    /// Best (minimum) execution time over the repetitions.
    pub best: Duration,
    /// Measured throughput, GFLOP/s.
    pub gflops: f64,
}

/// A finished native conv sweep (legacy view of a
/// [`SpaceSweep<ConvPoint>`]).
#[derive(Debug, Default)]
pub struct ConvNativeSweep {
    /// Every timed grid point, in measurement order.
    pub rows: Vec<ConvSweepMeasurement>,
    /// Winner per problem-class op key.
    pub winners: BTreeMap<String, (ConvCandidate, f64)>,
}

impl ConvNativeSweep {
    /// Best measured gflops for a problem under exactly `candidate`.
    pub fn gflops_for(
        &self,
        problem: &str,
        candidate: &ConvCandidate,
    ) -> Option<f64> {
        self.rows
            .iter()
            .filter(|r| r.problem == problem && r.candidate == *candidate)
            .map(|r| r.gflops)
            .reduce(f64::max)
    }

    /// The distinct algorithms measured for a problem — the sweep's
    /// proof that the algorithm axis was actually swept, not collapsed.
    pub fn algorithms_for(&self, problem: &str) -> Vec<ConvAlgorithm> {
        let mut algs: Vec<ConvAlgorithm> = Vec::new();
        for r in self.rows.iter().filter(|r| r.problem == problem) {
            if !algs.contains(&r.candidate.config.algorithm) {
                algs.push(r.candidate.config.algorithm);
            }
        }
        algs
    }
}

impl From<SpaceSweep<GemmPoint>> for BlockedSweep {
    fn from(s: SpaceSweep<GemmPoint>) -> Self {
        BlockedSweep {
            rows: s
                .rows
                .into_iter()
                .map(|r| SweepMeasurement {
                    problem: r.problem,
                    artifact: r.artifact,
                    params: r.point.params,
                    best: r.best,
                    gflops: r.gflops,
                })
                .collect(),
            winners: s
                .winners
                .into_iter()
                .map(|(op, (p, g))| (op, (p.params, g)))
                .collect(),
        }
    }
}

impl From<SpaceSweep<ConvPoint>> for ConvNativeSweep {
    fn from(s: SpaceSweep<ConvPoint>) -> Self {
        ConvNativeSweep {
            rows: s
                .rows
                .into_iter()
                .map(|r| ConvSweepMeasurement {
                    problem: r.problem,
                    artifact: r.artifact,
                    candidate: r.point,
                    best: r.best,
                    gflops: r.gflops,
                })
                .collect(),
            winners: s.winners.into_iter().collect(),
        }
    }
}

/// Legacy shim (deprecated): the blocking-only measured sweep.  A thin
/// wrapper over [`tune_space_sweep`] with a scalar-ISA [`GemmPoint`]
/// grid — winners persist in the unified schema (kind `gemm_point`,
/// `isa: scalar`), which the engine resolves exactly like the old
/// `blocked` entries.
pub fn tune_blocked_sweep<B: Backend>(
    engine: &mut B,
    group: &str,
    grid: &[BlockedParams],
    iters: usize,
    device: &str,
    apply: &mut dyn FnMut(&mut B, &BlockedParams),
    db: &mut SelectionDb,
) -> Result<BlockedSweep> {
    let points: Vec<GemmPoint> =
        grid.iter().map(|&params| GemmPoint::scalar(params)).collect();
    let sweep = tune_space_sweep::<B, GemmPoint>(
        engine,
        group,
        &points,
        iters,
        device,
        &mut |e, p| apply(e, &p.params),
        db,
    )?;
    Ok(sweep.into())
}

/// Legacy shim (deprecated): the native conv sweep.  A thin wrapper
/// over [`tune_space_sweep`] — the candidate type *is* [`ConvPoint`]
/// now, winners persist as kind `conv_point`.
pub fn tune_conv_native_sweep<B: Backend>(
    engine: &mut B,
    group: &str,
    grid: &[ConvCandidate],
    iters: usize,
    device: &str,
    apply: &mut dyn FnMut(&mut B, &ConvCandidate),
    db: &mut SelectionDb,
) -> Result<ConvNativeSweep> {
    let sweep = tune_space_sweep::<B, ConvPoint>(
        engine, group, grid, iters, device, apply, db,
    )?;
    Ok(sweep.into())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{ArtifactStore, NativeEngine, HOST_DEVICE};
    use crate::util::tmp::TempDir;

    fn sweep_fixture() -> (TempDir, NativeEngine) {
        let dir = TempDir::new("hostsweep").unwrap();
        std::fs::write(
            dir.path().join("manifest.json"),
            r#"{"version": 1, "artifacts": [
              {"name": "g96", "kind": "gemm", "impl": "pallas",
               "file": "g96.hlo.txt", "flops": 1769472,
               "m": 96, "n": 96, "k": 96, "groups": ["gemm"],
               "inputs": [{"shape": [96, 96], "dtype": "float32"},
                          {"shape": [96, 96], "dtype": "float32"}]},
              {"name": "c16", "kind": "conv", "impl": "pallas",
               "file": "c16.hlo.txt", "flops": 1179648, "batch": 2,
               "algorithm": "im2col", "groups": ["conv"],
               "layer": {"name": "sweep", "window": 3, "stride": 1,
                         "in_h": 16, "in_w": 16, "in_c": 8, "out_c": 16,
                         "out_h": 16, "out_w": 16, "padding": "SAME",
                         "flops": 1179648},
               "inputs": [{"shape": [2, 16, 16, 8], "dtype": "float32"},
                          {"shape": [3, 3, 8, 16], "dtype": "float32"}]}
            ]}"#,
        )
        .unwrap();
        let store = ArtifactStore::open(dir.path()).unwrap();
        let engine = NativeEngine::new(store).unwrap();
        (dir, engine)
    }

    #[test]
    fn grid_always_contains_the_default() {
        for quick in [true, false] {
            let grid = blocked_grid(quick, &[1, 2]);
            assert!(grid.contains(&BlockedParams::default()), "quick={quick}");
            // Dedup: no candidate appears twice.
            for (i, a) in grid.iter().enumerate() {
                assert!(!grid[i + 1..].contains(a), "{a:?} duplicated");
            }
            // The threads axis is actually crossed in.
            assert!(grid.iter().any(|p| p.threads == 2));
        }
    }

    #[test]
    fn gemm_point_grid_crosses_detected_isas() {
        let isas = Isa::detect();
        for quick in [true, false] {
            let grid = gemm_point_grid(quick, &[1, 2], &isas);
            assert!(grid.contains(&GemmPoint::default()), "quick={quick}");
            // Dedup discipline.
            for (i, a) in grid.iter().enumerate() {
                assert!(!grid[i + 1..].contains(a), "{a:?} duplicated");
            }
            // Every detected ISA appears, crossed with the threads axis.
            for &isa in &isas {
                assert!(
                    grid.iter().any(|p| p.isa == isa),
                    "quick={quick}: {isa} missing from the grid"
                );
            }
            // Non-scalar ISAs only ride monomorphized micro-tiles (the
            // SIMD variants exist per registry shape only).
            for p in &grid {
                assert!(
                    p.isa == Isa::Scalar || p.params.is_monomorphized(),
                    "{p:?} pairs a SIMD ISA with an off-registry tile"
                );
            }
            // Every point is applicable on this host by construction.
            let problem = Problem::Gemm { m: 96, n: 96, k: 96 };
            assert!(grid.iter().all(|p| p.applicable(&problem)));
        }
    }

    #[test]
    fn generic_gemm_sweep_measures_isa_axis_and_persists_points() {
        let (_dir, mut engine) = sweep_fixture();
        let isas = Isa::detect();
        let grid = gemm_point_grid(true, &[1], &isas);
        let mut db = SelectionDb::new();
        let sweep = tune_space_sweep(
            &mut engine,
            "gemm",
            &grid,
            1,
            HOST_DEVICE,
            &mut |e, p: &GemmPoint| e.set_gemm_point(*p),
            &mut db,
        )
        .unwrap();
        // Every grid point is applicable on the host that built the
        // grid, so the whole grid was measured.
        assert_eq!(sweep.rows.len(), grid.len());
        let key = SelectionKey::gemm(HOST_DEVICE, 96, 96, 96);
        // Every detected ISA was actually measured.
        let swept = sweep.axis_values_for(&key.op, |p| p.isa);
        for &isa in &isas {
            assert!(swept.contains(&isa), "{isa} never measured");
        }
        // The persisted winner is the argmax, stored as a unified point.
        let (win, win_g) = db.get::<GemmPoint>(&key).unwrap();
        assert_eq!(sweep.winners[&key.op], (win, win_g));
        let max = sweep
            .rows
            .iter()
            .filter(|r| r.problem == key.op)
            .map(|r| r.gflops)
            .fold(f64::MIN, f64::max);
        assert!(win_g >= max - 1e-12);
        // Tuned >= the best *scalar* point: the scalar points are in the
        // grid, so this is an argmax invariant, not a timing assertion.
        let scalar_best = sweep
            .rows
            .iter()
            .filter(|r| r.problem == key.op && r.point.isa == Isa::Scalar)
            .map(|r| r.gflops)
            .fold(f64::MIN, f64::max);
        assert!(win_g >= scalar_best);
    }

    #[test]
    fn sweep_measures_grid_and_persists_winners() {
        let (_dir, mut engine) = sweep_fixture();
        let grid = blocked_grid(true, &[1, 2]);
        let mut db = SelectionDb::new();
        let gemm = tune_blocked_sweep(
            &mut engine,
            "gemm",
            &grid,
            2,
            HOST_DEVICE,
            &mut |e, p| e.set_params(*p),
            &mut db,
        )
        .unwrap();
        let conv = tune_blocked_sweep(
            &mut engine,
            "conv",
            &grid,
            2,
            HOST_DEVICE,
            &mut |e, p| e.set_params(*p),
            &mut db,
        )
        .unwrap();
        // Every grid point was measured for every artifact.
        assert_eq!(gemm.rows.len(), grid.len());
        assert_eq!(conv.rows.len(), grid.len());
        assert_eq!(db.len(), 2, "one selection per problem class");
        // The persisted winner is the row argmax, and it comes from the
        // grid.
        for sweep in [&gemm, &conv] {
            for (op, (params, gflops)) in &sweep.winners {
                assert!(grid.contains(params));
                let max = sweep
                    .rows
                    .iter()
                    .filter(|r| &r.problem == op)
                    .map(|r| r.gflops)
                    .fold(f64::MIN, f64::max);
                assert!(*gflops >= max - 1e-12, "{op}: {gflops} < {max}");
            }
        }
        // Tuned >= default by construction: the default is in the grid,
        // so the argmax can never score below it.  Note the key op is
        // the *bucketed* problem class (96^3 -> the 128^3 bucket), and
        // sweep rows carry the same bucketed op.
        let key = SelectionKey::gemm(HOST_DEVICE, 96, 96, 96);
        assert_eq!(key.op, "gemm_128x128x128");
        let (_, tuned) = db.get_blocked(&key).unwrap();
        let dflt = gemm
            .gflops_for(&key.op, &BlockedParams::default())
            .unwrap();
        assert!(tuned >= dflt);
        // The legacy wrapper persists unified scalar points — including
        // under the conv key, where the conv space migrates them to
        // im2col.
        let ckey = SelectionKey::conv(HOST_DEVICE, 3, 1, 16, 16, 8, 16, 2);
        let (gp, _) = db.get::<GemmPoint>(&ckey).unwrap();
        assert_eq!(gp.isa, Isa::Scalar);
        let (cp, _) = db.get::<ConvPoint>(&ckey).unwrap();
        assert_eq!(cp.config.algorithm, ConvAlgorithm::Im2col);
        assert_eq!(cp.blocked, gp.params);
    }

    #[test]
    fn conv_grid_sweeps_all_three_algorithms() {
        for quick in [true, false] {
            let grid = conv_native_grid(quick, &[1, 2]);
            for alg in [
                ConvAlgorithm::Im2col,
                ConvAlgorithm::Tiled,
                ConvAlgorithm::Winograd,
            ] {
                assert!(
                    grid.iter().any(|c| c.config.algorithm == alg),
                    "quick={quick}: {alg} missing from the grid"
                );
            }
            // Dedup + the untuned baseline is always present.
            for (i, c) in grid.iter().enumerate() {
                assert!(!grid[i + 1..].contains(c), "{} duplicated", c.name());
            }
            assert!(grid.contains(&ConvCandidate::default()));
            // The threads axis is crossed into every algorithm family.
            for alg in [ConvAlgorithm::Tiled, ConvAlgorithm::Winograd] {
                assert!(grid
                    .iter()
                    .any(|c| c.config.algorithm == alg
                        && c.blocked.threads == 2));
            }
        }
    }

    #[test]
    fn conv_sweep_measures_algorithms_and_persists_conv_points() {
        let (_dir, mut engine) = sweep_fixture();
        let grid = conv_native_grid(true, &[1, 2]);
        let mut db = SelectionDb::new();
        let sweep = tune_conv_native_sweep(
            &mut engine,
            "conv",
            &grid,
            2,
            HOST_DEVICE,
            &mut |e, c| e.set_conv_params(c.config, c.blocked),
            &mut db,
        )
        .unwrap();
        // c16 is 3x3/s1: every candidate applies, so the whole grid was
        // measured and all three algorithms ran natively.
        assert_eq!(sweep.rows.len(), grid.len());
        let key = SelectionKey::conv(HOST_DEVICE, 3, 1, 16, 16, 8, 16, 2);
        let algs = sweep.algorithms_for(&key.op);
        for alg in [
            ConvAlgorithm::Im2col,
            ConvAlgorithm::Tiled,
            ConvAlgorithm::Winograd,
        ] {
            assert!(algs.contains(&alg), "{alg} never measured: {algs:?}");
        }
        // The persisted winner is the argmax and beats (or ties) the
        // untuned default, which is in the grid by construction.
        let (wc, wb, wg) = db.get_conv_native(&key).unwrap();
        let (win, win_g) = &sweep.winners[&key.op];
        assert_eq!((wc, wb), (win.config, win.blocked));
        assert_eq!(wg, *win_g);
        let dflt = sweep.gflops_for(&key.op, &ConvCandidate::default()).unwrap();
        assert!(wg >= dflt);
        // GEMM artifacts are untouched by the conv sweep.
        assert!(db
            .get_conv_native(&SelectionKey::gemm(HOST_DEVICE, 96, 96, 96))
            .is_none());
    }

    #[test]
    fn conv_sweep_skips_winograd_off_its_domain() {
        // A strided conv: winograd candidates must be skipped, not timed
        // as im2col duplicates.
        let dir = TempDir::new("hostsweep").unwrap();
        std::fs::write(
            dir.path().join("manifest.json"),
            r#"{"version": 1, "artifacts": [
              {"name": "cs2", "kind": "conv", "impl": "pallas",
               "file": "cs2.hlo.txt", "flops": 294912, "batch": 1,
               "algorithm": "im2col", "groups": ["conv"],
               "layer": {"name": "s2", "window": 3, "stride": 2,
                         "in_h": 16, "in_w": 16, "in_c": 8, "out_c": 16,
                         "out_h": 8, "out_w": 8, "padding": "SAME",
                         "flops": 294912},
               "inputs": [{"shape": [1, 16, 16, 8], "dtype": "float32"},
                          {"shape": [3, 3, 8, 16], "dtype": "float32"}]}
            ]}"#,
        )
        .unwrap();
        let store = ArtifactStore::open(dir.path()).unwrap();
        let mut engine = NativeEngine::new(store).unwrap();
        let grid = conv_native_grid(true, &[1]);
        let n_wino = grid
            .iter()
            .filter(|c| c.config.algorithm == ConvAlgorithm::Winograd)
            .count();
        assert!(n_wino > 0);
        let mut db = SelectionDb::new();
        let sweep = tune_conv_native_sweep(
            &mut engine,
            "conv",
            &grid,
            1,
            HOST_DEVICE,
            &mut |e, c| e.set_conv_params(c.config, c.blocked),
            &mut db,
        )
        .unwrap();
        assert_eq!(sweep.rows.len(), grid.len() - n_wino);
        let key = SelectionKey::conv(HOST_DEVICE, 3, 2, 16, 16, 8, 16, 1);
        assert!(!sweep
            .algorithms_for(&key.op)
            .contains(&ConvAlgorithm::Winograd));
        assert!(db.get_conv_native(&key).is_some());
    }

    #[test]
    fn widened_gemm_candidates_cover_the_registry() {
        // Full mode sweeps every monomorphized (mr, nr); quick mode
        // reaches beyond the historical {4x8, 8x16} hand-set.
        let full = blocked_candidates(false);
        for &(mr, nr) in micro_kernel_shapes() {
            assert!(
                full.iter().any(|p| p.mr == mr && p.nr == nr),
                "({mr}, {nr}) missing from the full candidate set"
            );
        }
        let quick = blocked_candidates(true);
        assert!(quick.iter().any(|p| (p.mr, p.nr) == (2, 16)));
        assert!(quick.iter().any(|p| (p.mr, p.nr) == (16, 8)));
        for set in [&full, &quick] {
            for (i, c) in set.iter().enumerate() {
                assert!(!set[i + 1..].contains(c), "{c:?} duplicated");
            }
        }
    }

    #[test]
    fn artifacts_without_keys_are_skipped() {
        let dir = TempDir::new("hostsweep").unwrap();
        std::fs::write(
            dir.path().join("manifest.json"),
            r#"{"version": 1, "artifacts": [
              {"name": "odd", "kind": "fft", "impl": "pallas",
               "file": "odd.hlo.txt", "flops": 1, "inputs": [],
               "groups": ["gemm"]}]}"#,
        )
        .unwrap();
        let store = ArtifactStore::open(dir.path()).unwrap();
        let mut engine = NativeEngine::new(store).unwrap();
        let mut db = SelectionDb::new();
        let sweep = tune_blocked_sweep(
            &mut engine,
            "gemm",
            &blocked_grid(true, &[1]),
            1,
            HOST_DEVICE,
            &mut |e, p| e.set_params(*p),
            &mut db,
        )
        .unwrap();
        assert!(sweep.rows.is_empty());
        assert!(db.is_empty());
    }
}
