//! Measured per-host sweeps over any [`KernelSpace`].
//!
//! This is the paper's headline workflow run end-to-end on hardware we
//! actually own: enumerate kernel parameter combinations — the blocking,
//! the `threads` knob, *which algorithm* runs (§4.1), and the
//! runtime-detected micro-kernel **ISA** — *measure* each one through a
//! [`Backend`] (no model in the loop), and persist the winner per
//! (platform, problem class) into the [`SelectionDb`] that
//! `NativeEngine` consults at plan time.  Measured — not modeled — sweeps
//! are what make the portability claim credible (cf. Reguly,
//! arXiv:2309.10075); CI runs the quick variant on every merge via
//! `cargo run --release --example tune_device -- --quick`.
//!
//! One generic function, [`tune_space_sweep`], does all of it,
//! parameterized by a [`SearchStrategy`]: the space point type supplies
//! applicability (shape domain + host capability), the DB codec, and a
//! per-point cost hint ([`KernelSpace::rank_hint`]); the strategy
//! decides which points actually get timed.  [`ExhaustiveSearch`]
//! measures the whole grid; [`tune_space_guided`] ([`GuidedSearch`])
//! measures only the cost model's top-ranked candidates plus the
//! *pinned* incumbents — the untuned default, the stored winner, and
//! [`warm_start_seeds`] transferred from already-tuned neighbour shape
//! classes — then hill-climbs around the measured winner under a hard
//! per-class budget.

use std::collections::BTreeMap;
use std::time::Duration;

use crate::blas::{BlockedParams, Dtype, Isa, Pack};
use crate::config::{
    micro_kernel_shapes, ConvAlgorithm, ConvConfig, ConvPoint, GemmPoint,
    KernelSpace, Problem,
};
use crate::error::Result;
use crate::runtime::{ArtifactMeta, Backend};

use super::db::{SelectionDb, SelectionKey};
use super::search::{CostRanker, GuidedSearch, ModelRanker, SearchStrategy};

/// One timed grid point of a generic space sweep.
#[derive(Debug, Clone)]
pub struct SpaceMeasurement<P: KernelSpace> {
    /// Problem-class op key (the `SelectionKey::op` the winner persists
    /// under, e.g. `gemm_128x128x128`).
    pub problem: String,
    /// Artifact the measurement executed.
    pub artifact: String,
    /// The space point this grid point timed.
    pub point: P,
    /// Best (minimum) execution time over the repetitions.
    pub best: Duration,
    /// Measured throughput, GFLOP/s (from the artifact's manifest flops).
    pub gflops: f64,
}

/// A finished generic sweep: every measurement plus the per-problem
/// winners that were persisted.
#[derive(Debug)]
pub struct SpaceSweep<P: KernelSpace> {
    /// Every timed grid point, in measurement order.
    pub rows: Vec<SpaceMeasurement<P>>,
    /// Winner per problem-class op key.
    pub winners: BTreeMap<String, (P, f64)>,
}

impl<P: KernelSpace> Default for SpaceSweep<P> {
    fn default() -> Self {
        Self { rows: Vec::new(), winners: BTreeMap::new() }
    }
}

impl<P: KernelSpace> SpaceSweep<P> {
    /// Best measured gflops for a problem under exactly `point`
    /// (e.g. the default point, for tuned-vs-default reporting).
    pub fn gflops_for(&self, problem: &str, point: &P) -> Option<f64> {
        self.rows
            .iter()
            .filter(|r| r.problem == problem && r.point == *point)
            .map(|r| r.gflops)
            .reduce(f64::max)
    }

    /// How many points were actually measured for a problem — the
    /// `points_measured` column of reports, and the number guided
    /// search keeps ≥10× below the exhaustive grid.
    pub fn points_measured_for(&self, problem: &str) -> usize {
        self.rows.iter().filter(|r| r.problem == problem).count()
    }

    /// The distinct values of some axis measured for a problem, in
    /// measurement order — the proof an axis was actually swept, not
    /// collapsed (`axis` projects the axis out of a point, e.g.
    /// `|p| p.isa` or `|p| p.config.algorithm`).
    pub fn axis_values_for<A: PartialEq>(
        &self,
        problem: &str,
        axis: impl Fn(&P) -> A,
    ) -> Vec<A> {
        let mut values: Vec<A> = Vec::new();
        for r in self.rows.iter().filter(|r| r.problem == problem) {
            let v = axis(&r.point);
            if !values.contains(&v) {
                values.push(v);
            }
        }
        values
    }
}

/// The problem facts applicability depends on, derived from an
/// artifact's manifest metadata (`None` for kinds no space tunes).
pub fn problem_for(meta: &ArtifactMeta) -> Option<Problem> {
    match meta.kind.as_str() {
        "gemm" => Some(Problem::Gemm {
            m: meta.m?,
            n: meta.n?,
            k: meta.k?,
        }),
        "conv" => {
            let l = meta.layer.as_ref()?;
            Some(Problem::Conv { window: l.window, stride: l.stride })
        }
        _ => None,
    }
}

/// Derive the tuning-DB key for an artifact on `device` (the platform
/// string the host sweep and `NativeEngine`'s plan-time lookup share —
/// both must produce identical keys or tuned entries are never found).
pub fn selection_key_for(
    meta: &ArtifactMeta,
    device: &str,
) -> Option<SelectionKey> {
    match meta.kind.as_str() {
        "gemm" => {
            Some(SelectionKey::gemm(device, meta.m?, meta.n?, meta.k?))
        }
        "conv" => {
            let l = meta.layer.as_ref()?;
            Some(SelectionKey::conv(
                device,
                l.window,
                l.stride,
                l.in_h,
                l.in_w,
                l.in_c,
                l.out_c,
                meta.batch.unwrap_or(1),
            ))
        }
        _ => None,
    }
}

/// The device-independent problem-class label for an artifact — the
/// `op` half of its [`SelectionKey`] (e.g. `gemm_128x128x128`,
/// `conv_3x3s1_16x16x8k16b2`).  The serving layer buckets its
/// per-request latency accounting under this label, so the hot classes
/// a re-tune pass should probe line up exactly with the keys the
/// selection DB stores winners under.  `None` for artifacts outside the
/// tuned kinds.
pub fn shape_class_for(meta: &ArtifactMeta) -> Option<String> {
    selection_key_for(meta, "").map(|key| key.op)
}

// ---- warm-start transfer ----

/// The bucketed `gemm_{M}x{N}x{K}` dims of a problem-class op.
fn gemm_dims(op: &str) -> Option<[u64; 3]> {
    let rest = op.strip_prefix("gemm_")?;
    let mut it = rest.split('x');
    let m = it.next()?.parse().ok()?;
    let n = it.next()?.parse().ok()?;
    let k = it.next()?.parse().ok()?;
    if it.next().is_some() {
        return None;
    }
    Some([m, n, k])
}

/// The `{window}x{window}s{stride}` signature of a conv problem-class
/// op.
fn conv_sig(op: &str) -> Option<&str> {
    op.strip_prefix("conv_")?.split('_').next()
}

/// Whether two problem-class ops are *adjacent* shape classes — close
/// enough that one class's tuned winner is a plausible seed for the
/// other: GEMM buckets within one power-of-two step per dimension,
/// conv layers sharing the window/stride signature.
fn ops_adjacent(a: &str, b: &str) -> bool {
    if let (Some(x), Some(y)) = (gemm_dims(a), gemm_dims(b)) {
        return x
            .iter()
            .zip(y.iter())
            .all(|(&p, &q)| p * 2 >= q && q * 2 >= p);
    }
    match (conv_sig(a), conv_sig(b)) {
        (Some(x), Some(y)) => x == y,
        _ => false,
    }
}

/// Warm-start transfer: the winning points of *adjacent* already-tuned
/// shape classes on the same device — the tuned neighbours' winners
/// seed this class's candidate list (pinned, so a budget can never
/// drop them).  Because the sweep's DB accumulates winners as it runs,
/// later classes of one sweep warm-start from earlier ones
/// automatically.
pub fn warm_start_seeds<P: KernelSpace>(
    db: &SelectionDb,
    key: &SelectionKey,
) -> Vec<P> {
    let mut seeds: Vec<P> = Vec::new();
    for (stored_key, _) in db.iter() {
        let Some((device, op)) = stored_key.split_once("::") else {
            continue;
        };
        if device != key.device || op == key.op || !ops_adjacent(&key.op, op)
        {
            continue;
        }
        let neighbour = SelectionKey {
            device: device.to_string(),
            op: op.to_string(),
        };
        if let Some((p, _)) = db.get::<P>(&neighbour) {
            if !seeds.contains(&p) {
                seeds.push(p);
            }
        }
    }
    seeds
}

/// Measure artifacts in `group` under the *applicable* grid points of
/// space `P` — which ones is the `strategy`'s call — and persist the
/// per-problem winner into `db` under `P::KIND`: the one generic
/// measure→persist loop behind every host sweep.
///
/// "Applicable" is the space's own rule ([`KernelSpace::applicable`]):
/// shape-domain fallbacks (a Winograd point on a strided layer) and
/// host capability (an ISA this CPU lacks) are *skipped*, never timed as
/// fallback duplicates.  Artifacts with no applicable points (e.g. GEMM
/// artifacts under the conv space) are skipped entirely.  `apply`
/// installs a point on the engine before timing — for `NativeEngine`
/// that is `|e, p| e.set_gemm_point(*p)` / `|e, p| e.set_conv_point(*p)`.
///
/// Three kinds of candidates are **pinned** (always proposed first,
/// appended to the candidate list if the grid lacks them): the space's
/// default point (so tuned-vs-default is always measurable), the
/// incumbent already stored for the class, and [`warm_start_seeds`]
/// from adjacent tuned classes.  The per-problem argmax then runs
/// through `strategy.search_ranked` with [`KernelSpace::rank_hint`] as
/// the cost model; `iters` repetitions, minimum taken, throughput from
/// manifest flops.  The winning entry is annotated with the strategy
/// name and the class's measured point count
/// ([`SelectionDb::annotate_search`]).
///
/// # Examples
///
/// ```
/// use portable_kernels::blas::BlockedParams;
/// use portable_kernels::config::GemmPoint;
/// use portable_kernels::runtime::{ArtifactStore, NativeEngine, HOST_DEVICE};
/// use portable_kernels::tuner::{
///     tune_space_sweep, ExhaustiveSearch, SelectionDb, SelectionKey,
/// };
/// use portable_kernels::util::tmp::TempDir;
///
/// let dir = TempDir::new("doc-sweep").unwrap();
/// std::fs::write(
///     dir.path().join("manifest.json"),
///     r#"{"version": 1, "artifacts": [{
///         "name": "g16", "kind": "gemm", "impl": "pallas",
///         "file": "g16.hlo.txt", "flops": 8192,
///         "m": 16, "n": 16, "k": 16,
///         "inputs": [{"shape": [16, 16], "dtype": "float32"},
///                    {"shape": [16, 16], "dtype": "float32"}],
///         "groups": ["gemm"]}]}"#,
/// )
/// .unwrap();
/// let store = ArtifactStore::open(dir.path()).unwrap();
/// let mut engine = NativeEngine::new(store).unwrap();
///
/// let grid = [
///     GemmPoint::default(),
///     GemmPoint::scalar(BlockedParams {
///         bm: 8, bn: 8, bk: 8, mr: 2, nr: 2, threads: 1,
///     }),
/// ];
/// let mut db = SelectionDb::new();
/// let sweep = tune_space_sweep(
///     &mut engine,
///     "gemm",
///     &grid,
///     1,
///     HOST_DEVICE,
///     &ExhaustiveSearch,
///     &mut |e, p: &GemmPoint| e.set_gemm_point(*p),
///     &mut db,
/// )
/// .unwrap();
/// assert_eq!(sweep.rows.len(), grid.len());
/// let key = SelectionKey::gemm(HOST_DEVICE, 16, 16, 16);
/// assert!(db.get::<GemmPoint>(&key).is_some(), "winner persisted");
/// ```
#[allow(clippy::too_many_arguments)]
pub fn tune_space_sweep<B: Backend, P: KernelSpace>(
    engine: &mut B,
    group: &str,
    grid: &[P],
    iters: usize,
    device: &str,
    strategy: &dyn SearchStrategy,
    apply: &mut dyn FnMut(&mut B, &P),
    db: &mut SelectionDb,
) -> Result<SpaceSweep<P>> {
    tune_space_sweep_filtered(
        engine,
        group,
        grid,
        iters,
        device,
        strategy,
        apply,
        db,
        &|_| true,
    )
}

/// [`tune_space_sweep`] with [`GuidedSearch`] capped at `budget`
/// measured points per shape class — the cheap sweep `tune_device`
/// defaults to and `tune-smoke` holds to ≥10× fewer measured points
/// than the exhaustive grid at equal-or-better tuned GFLOP/s.
#[allow(clippy::too_many_arguments)]
pub fn tune_space_guided<B: Backend, P: KernelSpace>(
    engine: &mut B,
    group: &str,
    grid: &[P],
    iters: usize,
    device: &str,
    budget: usize,
    apply: &mut dyn FnMut(&mut B, &P),
    db: &mut SelectionDb,
) -> Result<SpaceSweep<P>> {
    tune_space_sweep(
        engine,
        group,
        grid,
        iters,
        device,
        &GuidedSearch { budget },
        apply,
        db,
    )
}

/// [`tune_space_sweep`] restricted to the artifacts `filter` accepts —
/// the *targeted* probe shape the online re-tuner uses: instead of
/// re-measuring the whole group, it probes only the artifacts the
/// serving latency accounting marked hot, so a re-tune pass costs
/// seconds, not a full offline sweep.
#[allow(clippy::too_many_arguments)]
pub fn tune_space_sweep_filtered<B: Backend, P: KernelSpace>(
    engine: &mut B,
    group: &str,
    grid: &[P],
    iters: usize,
    device: &str,
    strategy: &dyn SearchStrategy,
    apply: &mut dyn FnMut(&mut B, &P),
    db: &mut SelectionDb,
    filter: &dyn Fn(&ArtifactMeta) -> bool,
) -> Result<SpaceSweep<P>> {
    let metas: Vec<ArtifactMeta> = engine
        .store()
        .in_group(group)
        .filter(|m| filter(m))
        .cloned()
        .collect();
    let mut sweep = SpaceSweep::default();
    for meta in metas {
        let Some(key) = selection_key_for(&meta, device) else {
            continue;
        };
        let Some(problem) = problem_for(&meta) else {
            continue;
        };
        let mut candidates: Vec<P> = grid
            .iter()
            .filter(|p| p.applicable(&problem))
            .copied()
            .collect();
        if candidates.is_empty() {
            continue;
        }
        // Pin the untuned default, the stored incumbent, and the
        // warm-start seeds from adjacent tuned classes: proposed first,
        // appended if the grid lacks them, so no budget drops them.
        let mut pinned: Vec<usize> = Vec::new();
        {
            let mut pin = |p: P| {
                if !p.applicable(&problem) {
                    return;
                }
                let i = match candidates.iter().position(|c| *c == p) {
                    Some(i) => i,
                    None => {
                        candidates.push(p);
                        candidates.len() - 1
                    }
                };
                if !pinned.contains(&i) {
                    pinned.push(i);
                }
            };
            pin(P::default_point());
            if let Some((incumbent, _)) = db.get::<P>(&key) {
                pin(incumbent);
            }
            for seed in warm_start_seeds::<P>(db, &key) {
                pin(seed);
            }
        }
        let inputs = engine.synth_inputs(&meta.name, 17)?;
        let mut run_err = None;
        let mut score = |i: usize| -> Option<f64> {
            apply(engine, &candidates[i]);
            match engine.run_timed(&meta.name, &inputs, iters) {
                Ok((out, best)) => {
                    let gflops = out.gflops(meta.flops);
                    sweep.rows.push(SpaceMeasurement {
                        problem: key.op.clone(),
                        artifact: meta.name.clone(),
                        point: candidates[i],
                        best,
                        gflops,
                    });
                    Some(gflops)
                }
                Err(e) => {
                    run_err = Some(e);
                    None
                }
            }
        };
        let rank =
            |i: usize| ModelRanker.rank(&candidates[i], &problem);
        let found = strategy.search_ranked(
            candidates.len(),
            &pinned,
            &rank,
            &mut score,
        );
        if let Some(e) = run_err {
            return Err(e);
        }
        if let Some((idx, _evals, gflops)) = found {
            // Several artifacts can share a problem class (same shape,
            // different lowering); keep the best selection seen.
            let better = db
                .get::<P>(&key)
                .map(|(_, g)| gflops > g)
                .unwrap_or(true);
            if better {
                db.put(key.clone(), candidates[idx], gflops);
                sweep
                    .winners
                    .insert(key.op.clone(), (candidates[idx], gflops));
            }
            db.annotate_search(
                &key,
                strategy.name(),
                sweep.points_measured_for(&key.op),
            );
        }
    }
    Ok(sweep)
}

// ---- grids ----

/// The base `BlockedParams` candidate sets — the same serial candidates
/// the `blocked.rs` tests and the `rust_blas` bench exercise, widened
/// over the monomorphized `(mr, nr)` registry
/// ([`crate::config::micro_kernel_shapes`]) so the sweep measures the
/// whole fast micro-tile set, not a hand-picked subset.
pub fn blocked_candidates(quick: bool) -> Vec<BlockedParams> {
    let p = |bm, bn, bk, mr, nr| BlockedParams {
        bm,
        bn,
        bk,
        mr,
        nr,
        threads: 1,
    };
    let mut out = if quick {
        // The CI smoke grid: registry micro-tile shapes at a handful of
        // blockings.  Deliberately large enough that the guided-vs-
        // exhaustive measured-point ratio tune-smoke asserts (≥10×) has
        // headroom, while still sweeping in seconds.
        vec![
            BlockedParams { threads: 1, ..Default::default() },
            p(32, 32, 32, 4, 8),
            p(16, 32, 16, 4, 8),
            p(32, 32, 32, 2, 16),
            p(32, 32, 32, 16, 8),
            p(32, 32, 32, 8, 8),
            p(32, 32, 32, 4, 16),
            p(32, 32, 32, 8, 4),
            p(32, 32, 32, 2, 8),
            p(64, 64, 32, 8, 16),
            p(16, 16, 16, 2, 4),
            p(64, 32, 32, 16, 16),
        ]
    } else {
        let mut v = vec![
            BlockedParams { threads: 1, ..Default::default() },
            p(8, 8, 8, 2, 2),
            p(16, 32, 5, 4, 8),
            p(64, 64, 64, 8, 16),
            p(32, 32, 32, 4, 8),
            p(128, 128, 64, 8, 16),
        ];
        // The full mr × nr registry at one representative blocking.
        for &(mr, nr) in micro_kernel_shapes() {
            v.push(p(64, 64, 64, mr, nr));
        }
        v
    };
    // Order-preserving dedup (the registry cross re-generates a couple
    // of the hand-written entries).
    let mut seen: Vec<BlockedParams> = Vec::with_capacity(out.len());
    out.retain(|c| {
        if seen.contains(c) {
            false
        } else {
            seen.push(*c);
            true
        }
    });
    out
}

/// The blocking-only grid: [`blocked_candidates`] × `threads`,
/// deduplicated, with [`BlockedParams::default`] always present so every
/// sweep measures the untuned baseline it is compared against.
pub fn blocked_grid(quick: bool, threads: &[usize]) -> Vec<BlockedParams> {
    let mut grid: Vec<BlockedParams> = Vec::new();
    for base in blocked_candidates(quick) {
        for &t in threads {
            let cand = BlockedParams { threads: t, ..base };
            if !grid.contains(&cand) {
                grid.push(cand);
            }
        }
    }
    let default = BlockedParams::default();
    if !grid.contains(&default) {
        grid.insert(0, default);
    }
    grid
}

/// The full measured GEMM grid: [`blocked_grid`] × the given ISAs
/// (normally [`Isa::detect`]) × both [`Dtype`]s × both [`Pack`]
/// strategies, deduplicated, with the default scalar point always
/// present as the untuned baseline.
/// Non-scalar ISAs are crossed only with *monomorphized* registry
/// micro-tiles — off-registry shapes run the generic scalar kernel
/// whatever the ISA, so timing them per-ISA would measure the same
/// kernel repeatedly.  The same rule bounds the `i8` half of the grid:
/// the widening-kernel registry mirrors the f32 one shape-for-shape.
/// The `pack` axis is crossed everywhere: whether B-panel packing pays
/// is exactly the shape-dependent question the sweep answers.
pub fn gemm_point_grid(
    quick: bool,
    threads: &[usize],
    isas: &[Isa],
) -> Vec<GemmPoint> {
    let mut grid: Vec<GemmPoint> = Vec::new();
    for params in blocked_grid(quick, threads) {
        for &isa in isas {
            if isa != Isa::Scalar && !params.is_monomorphized() {
                continue;
            }
            for dtype in Dtype::all() {
                for pack in Pack::all() {
                    let cand = GemmPoint { params, isa, dtype, pack };
                    if !grid.contains(&cand) {
                        grid.push(cand);
                    }
                }
            }
        }
    }
    let default = GemmPoint::default();
    if !grid.contains(&default) {
        grid.insert(0, default);
    }
    grid
}

/// One native conv sweep candidate: an algorithm + its knobs — since the
/// space unification this *is* the conv kernel-space point
/// ([`ConvPoint`]: the [`ConvConfig`] names the algorithm,
/// tile/vector parameters and the Winograd `wino_m` tile size, the
/// [`BlockedParams`] carry the lowered-GEMM blocking and the `threads`
/// knob every algorithm honors, and the [`Isa`] picks the SIMD
/// micro-kernel the lowered GEMMs dispatch).
pub type ConvCandidate = ConvPoint;

/// The base [`ConvConfig`] candidates the native conv sweep measures:
/// im2col, a handful of tiled tile/vector shapes, and both Winograd
/// tile sizes (`wino_m ∈ {2, 4}`) — all three §4.1 algorithm families
/// with the F(m×m, 3×3) reduction as a measured axis, deliberately much
/// smaller than the modeled `config::conv_space` (these get *measured*,
/// every point costs wall time).
pub fn conv_candidates(quick: bool) -> Vec<ConvConfig> {
    let mut out = vec![ConvConfig::im2col()];
    if quick {
        out.push(ConvConfig::tiled(1, 1, 1, 4));
        out.push(ConvConfig::tiled(2, 2, 1, 4));
    } else {
        for (th, tw, vc, vk) in
            [(1, 1, 1, 4), (2, 2, 1, 4), (4, 4, 4, 4), (2, 4, 1, 8)]
        {
            out.push(ConvConfig::tiled(th, tw, vc, vk));
        }
    }
    out.push(ConvConfig::winograd(2));
    out.push(ConvConfig::winograd(4));
    out
}

/// The full native conv grid: [`conv_candidates`] × `threads`, the
/// GEMM-lowered algorithms (im2col *and* Winograd, whose transform-domain
/// multiplies run as batched GEMMs) additionally crossed with the
/// [`blocked_candidates`] GEMM blockings and — at the default
/// monomorphized blocking — the given micro-kernel ISAs (normally
/// [`Isa::detect`]), deduplicated, with the plain default im2col
/// candidate always present as the untuned baseline.  The im2col
/// candidates (the one family with a quantized body) are additionally
/// crossed with the `i8` [`Dtype`], and the GEMM-lowered candidates with
/// both [`Pack`] strategies (`ab` needs a lowered B panel to pack, so
/// the direct kernels stay `a`).
pub fn conv_native_grid(
    quick: bool,
    threads: &[usize],
    isas: &[Isa],
) -> Vec<ConvCandidate> {
    let mut grid: Vec<ConvCandidate> = Vec::new();
    let push = |grid: &mut Vec<ConvCandidate>, cand: ConvCandidate| {
        if !grid.contains(&cand) {
            grid.push(cand);
        }
    };
    for config in conv_candidates(quick) {
        let lowered = matches!(
            config.algorithm,
            ConvAlgorithm::Im2col | ConvAlgorithm::Winograd
        );
        // Only the GEMM-lowered paths read the blocking and the ISA;
        // the direct kernels read just `threads`, so sweeping either
        // axis for them would time the same kernel repeatedly.
        let bases: Vec<BlockedParams> = if lowered {
            blocked_candidates(quick)
        } else {
            vec![BlockedParams { threads: 1, ..Default::default() }]
        };
        // The dtype axis: `i8` has a quantized body for the im2col
        // lowering only ([`ConvPoint::validate`]), so only im2col
        // candidates are crossed with it.
        let dtypes: &[Dtype] = if config.algorithm == ConvAlgorithm::Im2col
        {
            &[Dtype::F32, Dtype::I8]
        } else {
            &[Dtype::F32]
        };
        // The pack axis rides the GEMM-lowered algorithms only: the
        // direct kernels have no B panel ([`ConvPoint::validate`]).
        let packs: &[Pack] =
            if lowered { &[Pack::A, Pack::Ab] } else { &[Pack::A] };
        for base in bases {
            for &t in threads {
                for &dtype in dtypes {
                    for &pack in packs {
                        push(
                            &mut grid,
                            ConvCandidate {
                                config,
                                blocked: BlockedParams {
                                    threads: t,
                                    ..base
                                },
                                isa: Isa::Scalar,
                                dtype,
                                pack,
                            },
                        );
                    }
                }
            }
        }
        if lowered {
            // Non-scalar ISAs ride the default blocking only: the SIMD
            // micro-kernel variants exist per monomorphized registry
            // shape, and the default 4×8 tile is in the registry —
            // crossing every blocking with every ISA would square the
            // measured grid for little ranking information.
            for &isa in isas {
                if isa == Isa::Scalar {
                    continue;
                }
                for &t in threads {
                    for &dtype in dtypes {
                        for &pack in packs {
                            push(
                                &mut grid,
                                ConvCandidate {
                                    config,
                                    blocked: BlockedParams {
                                        threads: t,
                                        ..Default::default()
                                    },
                                    isa,
                                    dtype,
                                    pack,
                                },
                            );
                        }
                    }
                }
            }
        }
    }
    let default = ConvCandidate::default();
    if !grid.contains(&default) {
        grid.insert(0, default);
    }
    grid
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{ArtifactStore, NativeEngine, HOST_DEVICE};
    use crate::tuner::search::ExhaustiveSearch;
    use crate::util::tmp::TempDir;

    fn sweep_fixture() -> (TempDir, NativeEngine) {
        let dir = TempDir::new("hostsweep").unwrap();
        std::fs::write(
            dir.path().join("manifest.json"),
            r#"{"version": 1, "artifacts": [
              {"name": "g96", "kind": "gemm", "impl": "pallas",
               "file": "g96.hlo.txt", "flops": 1769472,
               "m": 96, "n": 96, "k": 96, "groups": ["gemm"],
               "inputs": [{"shape": [96, 96], "dtype": "float32"},
                          {"shape": [96, 96], "dtype": "float32"}]},
              {"name": "c16", "kind": "conv", "impl": "pallas",
               "file": "c16.hlo.txt", "flops": 1179648, "batch": 2,
               "algorithm": "im2col", "groups": ["conv"],
               "layer": {"name": "sweep", "window": 3, "stride": 1,
                         "in_h": 16, "in_w": 16, "in_c": 8, "out_c": 16,
                         "out_h": 16, "out_w": 16, "padding": "SAME",
                         "flops": 1179648},
               "inputs": [{"shape": [2, 16, 16, 8], "dtype": "float32"},
                          {"shape": [3, 3, 8, 16], "dtype": "float32"}]}
            ]}"#,
        )
        .unwrap();
        let store = ArtifactStore::open(dir.path()).unwrap();
        let engine = NativeEngine::new(store).unwrap();
        (dir, engine)
    }

    fn scalar_grid(quick: bool, threads: &[usize]) -> Vec<GemmPoint> {
        blocked_grid(quick, threads)
            .into_iter()
            .map(GemmPoint::scalar)
            .collect()
    }

    #[test]
    fn grid_always_contains_the_default() {
        for quick in [true, false] {
            let grid = blocked_grid(quick, &[1, 2]);
            assert!(grid.contains(&BlockedParams::default()), "quick={quick}");
            // Dedup: no candidate appears twice.
            for (i, a) in grid.iter().enumerate() {
                assert!(!grid[i + 1..].contains(a), "{a:?} duplicated");
            }
            // The threads axis is actually crossed in.
            assert!(grid.iter().any(|p| p.threads == 2));
        }
    }

    #[test]
    fn gemm_point_grid_crosses_detected_isas() {
        let isas = Isa::detect();
        for quick in [true, false] {
            let grid = gemm_point_grid(quick, &[1, 2], &isas);
            assert!(grid.contains(&GemmPoint::default()), "quick={quick}");
            // Dedup discipline.
            for (i, a) in grid.iter().enumerate() {
                assert!(!grid[i + 1..].contains(a), "{a:?} duplicated");
            }
            // Every detected ISA appears, crossed with the threads axis.
            for &isa in &isas {
                assert!(
                    grid.iter().any(|p| p.isa == isa),
                    "quick={quick}: {isa} missing from the grid"
                );
            }
            // Non-scalar ISAs only ride monomorphized micro-tiles (the
            // SIMD variants exist per registry shape only).
            for p in &grid {
                assert!(
                    p.isa == Isa::Scalar || p.params.is_monomorphized(),
                    "{p:?} pairs a SIMD ISA with an off-registry tile"
                );
            }
            // Both dtypes are swept, each crossed with every detected
            // ISA — the quantized fast path is a measured axis.
            for dtype in Dtype::all() {
                for &isa in &isas {
                    assert!(
                        grid.iter().any(|p| p.dtype == dtype
                            && p.isa == isa),
                        "quick={quick}: {dtype} never crossed with {isa}"
                    );
                }
            }
            // Both pack strategies are swept, crossed with every dtype
            // — packed-B is a measured axis, not a hardwired default.
            for dtype in Dtype::all() {
                for pack in Pack::all() {
                    assert!(
                        grid.iter()
                            .any(|p| p.dtype == dtype && p.pack == pack),
                        "quick={quick}: {dtype} never crossed with {pack}"
                    );
                }
            }
            // Every point is applicable on this host by construction.
            let problem = Problem::Gemm { m: 96, n: 96, k: 96 };
            assert!(grid.iter().all(|p| p.applicable(&problem)));
        }
    }

    #[test]
    fn generic_gemm_sweep_measures_isa_axis_and_persists_points() {
        let (_dir, mut engine) = sweep_fixture();
        let isas = Isa::detect();
        let grid = gemm_point_grid(true, &[1], &isas);
        let mut db = SelectionDb::new();
        let sweep = tune_space_sweep(
            &mut engine,
            "gemm",
            &grid,
            1,
            HOST_DEVICE,
            &ExhaustiveSearch,
            &mut |e, p: &GemmPoint| e.set_gemm_point(*p),
            &mut db,
        )
        .unwrap();
        // Every grid point is applicable on the host that built the
        // grid, so the whole grid was measured.
        assert_eq!(sweep.rows.len(), grid.len());
        let key = SelectionKey::gemm(HOST_DEVICE, 96, 96, 96);
        assert_eq!(sweep.points_measured_for(&key.op), grid.len());
        // Every detected ISA was actually measured.
        let swept = sweep.axis_values_for(&key.op, |p| p.isa);
        for &isa in &isas {
            assert!(swept.contains(&isa), "{isa} never measured");
        }
        // Both pack strategies were actually measured.
        let packs = sweep.axis_values_for(&key.op, |p| p.pack);
        for pack in Pack::all() {
            assert!(packs.contains(&pack), "{pack} never measured");
        }
        // The persisted winner is the argmax, stored as a unified point.
        let (win, win_g) = db.get::<GemmPoint>(&key).unwrap();
        assert_eq!(sweep.winners[&key.op], (win, win_g));
        let max = sweep
            .rows
            .iter()
            .filter(|r| r.problem == key.op)
            .map(|r| r.gflops)
            .fold(f64::MIN, f64::max);
        assert!(win_g >= max - 1e-12);
        // Tuned >= the best *scalar* point: the scalar points are in the
        // grid, so this is an argmax invariant, not a timing assertion.
        let scalar_best = sweep
            .rows
            .iter()
            .filter(|r| r.problem == key.op && r.point.isa == Isa::Scalar)
            .map(|r| r.gflops)
            .fold(f64::MIN, f64::max);
        assert!(win_g >= scalar_best);
        // The entry carries the search provenance columns.
        let entry = db.stored(&key).unwrap().entry().clone();
        assert_eq!(
            entry.get("search").and_then(|v| v.as_str()),
            Some("exhaustive")
        );
        assert_eq!(
            entry.get("points_measured").and_then(|v| v.as_u64()),
            Some(grid.len() as u64)
        );
    }

    #[test]
    fn sweep_measures_grid_and_persists_winners() {
        let (_dir, mut engine) = sweep_fixture();
        let grid = scalar_grid(true, &[1, 2]);
        let mut db = SelectionDb::new();
        let mut apply =
            |e: &mut NativeEngine, p: &GemmPoint| e.set_params(p.params);
        let gemm = tune_space_sweep(
            &mut engine,
            "gemm",
            &grid,
            2,
            HOST_DEVICE,
            &ExhaustiveSearch,
            &mut apply,
            &mut db,
        )
        .unwrap();
        let conv = tune_space_sweep(
            &mut engine,
            "conv",
            &grid,
            2,
            HOST_DEVICE,
            &ExhaustiveSearch,
            &mut apply,
            &mut db,
        )
        .unwrap();
        // Every grid point was measured for every artifact.
        assert_eq!(gemm.rows.len(), grid.len());
        assert_eq!(conv.rows.len(), grid.len());
        assert_eq!(db.len(), 2, "one selection per problem class");
        // The persisted winner is the row argmax, and it comes from the
        // grid.
        for sweep in [&gemm, &conv] {
            for (op, (point, gflops)) in &sweep.winners {
                assert!(grid.contains(point));
                let max = sweep
                    .rows
                    .iter()
                    .filter(|r| &r.problem == op)
                    .map(|r| r.gflops)
                    .fold(f64::MIN, f64::max);
                assert!(*gflops >= max - 1e-12, "{op}: {gflops} < {max}");
            }
        }
        // Tuned >= default by construction: the default is in the grid,
        // so the argmax can never score below it.  Note the key op is
        // the *bucketed* problem class (96^3 -> the 128^3 bucket), and
        // sweep rows carry the same bucketed op.
        let key = SelectionKey::gemm(HOST_DEVICE, 96, 96, 96);
        assert_eq!(key.op, "gemm_128x128x128");
        let (_, tuned) = db.get::<GemmPoint>(&key).unwrap();
        let dflt = gemm
            .gflops_for(&key.op, &GemmPoint::default())
            .unwrap();
        assert!(tuned >= dflt);
        // Scalar points persist in the unified schema — including under
        // the conv key, where the conv space migrates them to im2col.
        let ckey = SelectionKey::conv(HOST_DEVICE, 3, 1, 16, 16, 8, 16, 2);
        let (gp, _) = db.get::<GemmPoint>(&ckey).unwrap();
        assert_eq!(gp.isa, Isa::Scalar);
        let (cp, _) = db.get::<ConvPoint>(&ckey).unwrap();
        assert_eq!(cp.config.algorithm, ConvAlgorithm::Im2col);
        assert_eq!(cp.blocked, gp.params);
    }

    #[test]
    fn guided_sweep_stays_in_budget_and_measures_the_pinned_default() {
        let (_dir, mut engine) = sweep_fixture();
        let isas = Isa::detect();
        let grid = gemm_point_grid(true, &[1, 2], &isas);
        let budget = 5usize;
        assert!(grid.len() > budget, "fixture grid too small to prune");
        let mut db = SelectionDb::new();
        let sweep = tune_space_guided(
            &mut engine,
            "gemm",
            &grid,
            1,
            HOST_DEVICE,
            budget,
            &mut |e, p: &GemmPoint| e.set_gemm_point(*p),
            &mut db,
        )
        .unwrap();
        let key = SelectionKey::gemm(HOST_DEVICE, 96, 96, 96);
        let measured = sweep.points_measured_for(&key.op);
        assert!(measured <= budget, "{measured} > budget {budget}");
        assert!(measured >= 1);
        // The untuned default was measured (pinned), so tuned >= default
        // holds by argmax even under a tiny budget.
        let dflt = sweep.gflops_for(&key.op, &GemmPoint::default()).unwrap();
        let (_, tuned) = db.get::<GemmPoint>(&key).unwrap();
        assert!(tuned >= dflt);
        // Search provenance columns name the guided strategy.
        let entry = db.stored(&key).unwrap().entry().clone();
        assert_eq!(
            entry.get("search").and_then(|v| v.as_str()),
            Some("guided")
        );
        assert_eq!(
            entry.get("points_measured").and_then(|v| v.as_u64()),
            Some(measured as u64)
        );
    }

    #[test]
    fn guided_sweep_warm_starts_from_adjacent_tuned_classes() {
        let (_dir, mut engine) = sweep_fixture();
        // A neighbour class (one power-of-two step away per dim) was
        // already tuned to a distinctive blocking the quick grid lacks.
        let seed_params = BlockedParams {
            bm: 24, bn: 24, bk: 12, mr: 2, nr: 4, threads: 1,
        };
        let seed = GemmPoint::scalar(seed_params);
        let mut db = SelectionDb::new();
        db.put(
            SelectionKey::gemm(HOST_DEVICE, 256, 128, 128),
            seed,
            99.0,
        );
        let grid = scalar_grid(true, &[1]);
        assert!(!grid.contains(&seed), "seed must come from transfer");
        let sweep = tune_space_guided(
            &mut engine,
            "gemm",
            &grid,
            1,
            HOST_DEVICE,
            4,
            &mut |e, p: &GemmPoint| e.set_params(p.params),
            &mut db,
        )
        .unwrap();
        let key = SelectionKey::gemm(HOST_DEVICE, 96, 96, 96);
        // The transferred seed was actually measured for the new class.
        assert!(
            sweep
                .rows
                .iter()
                .any(|r| r.problem == key.op && r.point == seed),
            "warm-start seed never measured"
        );
    }

    #[test]
    fn warm_start_seeds_come_from_adjacent_same_device_classes_only() {
        let mut db = SelectionDb::new();
        let here = SelectionKey::gemm(HOST_DEVICE, 128, 128, 128);
        let neighbour = GemmPoint::scalar(BlockedParams {
            bm: 24, bn: 24, bk: 12, mr: 2, nr: 4, threads: 1,
        });
        // Adjacent class, same device: transfers.
        db.put(SelectionKey::gemm(HOST_DEVICE, 256, 128, 128), neighbour, 1.0);
        // Far class (two bucket steps on m): does not.
        db.put(
            SelectionKey::gemm(HOST_DEVICE, 512, 128, 128),
            GemmPoint::default(),
            1.0,
        );
        // Adjacent class, *other* device: does not.
        db.put(
            SelectionKey::gemm("other-box", 256, 128, 128),
            GemmPoint::default(),
            1.0,
        );
        // Conv classes never seed a gemm class.
        db.put(
            SelectionKey::conv(HOST_DEVICE, 3, 1, 16, 16, 8, 16, 2),
            ConvPoint::default(),
            1.0,
        );
        let seeds = warm_start_seeds::<GemmPoint>(&db, &here);
        assert_eq!(seeds, vec![neighbour]);

        // Conv adjacency is the window/stride signature.
        let chere = SelectionKey::conv(HOST_DEVICE, 3, 1, 32, 32, 8, 16, 2);
        let cseeds = warm_start_seeds::<ConvPoint>(&db, &chere);
        assert_eq!(cseeds, vec![ConvPoint::default()]);
        // A strided conv class is not adjacent to the s1 signature.
        let strided = SelectionKey::conv(HOST_DEVICE, 3, 2, 32, 32, 8, 16, 2);
        assert!(warm_start_seeds::<ConvPoint>(&db, &strided).is_empty());
    }

    #[test]
    fn conv_grid_sweeps_all_three_algorithms() {
        let isas = Isa::detect();
        for quick in [true, false] {
            let grid = conv_native_grid(quick, &[1, 2], &isas);
            for alg in [
                ConvAlgorithm::Im2col,
                ConvAlgorithm::Tiled,
                ConvAlgorithm::Winograd,
            ] {
                assert!(
                    grid.iter().any(|c| c.config.algorithm == alg),
                    "quick={quick}: {alg} missing from the grid"
                );
            }
            // Both Winograd tile sizes are candidate axes, each crossed
            // with the GEMM blockings (> 1 blocking per wino_m).
            for m in [2u32, 4] {
                let blockings: Vec<BlockedParams> = grid
                    .iter()
                    .filter(|c| {
                        c.config.algorithm == ConvAlgorithm::Winograd
                            && c.config.wino_m == m
                    })
                    .map(|c| BlockedParams { threads: 1, ..c.blocked })
                    .collect();
                assert!(
                    blockings.iter().any(|b| *b != blockings[0]),
                    "quick={quick}: wino_m={m} not crossed with blockings"
                );
            }
            // Every detected ISA rides both GEMM-lowered algorithms; the
            // direct kernels stay scalar (no lowered GEMM to dispatch).
            for &isa in &isas {
                for alg in [ConvAlgorithm::Im2col, ConvAlgorithm::Winograd] {
                    assert!(
                        grid.iter().any(|c| c.config.algorithm == alg
                            && c.isa == isa),
                        "quick={quick}: {alg} never paired with {isa}"
                    );
                }
            }
            assert!(grid
                .iter()
                .all(|c| c.config.algorithm != ConvAlgorithm::Tiled
                    || c.isa == Isa::Scalar));
            // Packed-B rides both GEMM-lowered algorithms and never the
            // direct kernels (which have no B panel to pack).
            for alg in [ConvAlgorithm::Im2col, ConvAlgorithm::Winograd] {
                assert!(
                    grid.iter().any(|c| c.config.algorithm == alg
                        && c.pack == Pack::Ab),
                    "quick={quick}: {alg} never crossed with pack ab"
                );
            }
            assert!(
                grid.iter()
                    .all(|c| c.config.algorithm != ConvAlgorithm::Tiled
                        || c.pack == Pack::A),
                "quick={quick}: a tiled candidate carries pack ab"
            );
            // The i8 dtype rides im2col candidates only (the one conv
            // lowering with a quantized body) — and it does ride them.
            assert!(
                grid.iter().any(|c| c.dtype == Dtype::I8
                    && c.config.algorithm == ConvAlgorithm::Im2col),
                "quick={quick}: no i8 im2col candidates"
            );
            for c in &grid {
                assert!(
                    c.dtype == Dtype::F32
                        || c.config.algorithm == ConvAlgorithm::Im2col,
                    "{} pairs i8 with a non-im2col algorithm",
                    c.name()
                );
                assert!(c.validate().is_ok(), "{} invalid", c.name());
            }
            // Dedup + the untuned baseline is always present.
            for (i, c) in grid.iter().enumerate() {
                assert!(!grid[i + 1..].contains(c), "{} duplicated", c.name());
            }
            assert!(grid.contains(&ConvCandidate::default()));
            // The threads axis is crossed into every algorithm family.
            for alg in [ConvAlgorithm::Tiled, ConvAlgorithm::Winograd] {
                assert!(grid
                    .iter()
                    .any(|c| c.config.algorithm == alg
                        && c.blocked.threads == 2));
            }
        }
    }

    #[test]
    fn conv_sweep_measures_algorithms_and_persists_conv_points() {
        let (_dir, mut engine) = sweep_fixture();
        let isas = Isa::detect();
        let grid = conv_native_grid(true, &[1, 2], &isas);
        let mut db = SelectionDb::new();
        let sweep = tune_space_sweep(
            &mut engine,
            "conv",
            &grid,
            2,
            HOST_DEVICE,
            &ExhaustiveSearch,
            &mut |e, c: &ConvCandidate| e.set_conv_point(*c),
            &mut db,
        )
        .unwrap();
        // c16 is 3x3/s1: every candidate applies, so the whole grid was
        // measured and all three algorithms ran natively.
        assert_eq!(sweep.rows.len(), grid.len());
        let key = SelectionKey::conv(HOST_DEVICE, 3, 1, 16, 16, 8, 16, 2);
        let algs = sweep.axis_values_for(&key.op, |c| c.config.algorithm);
        for alg in [
            ConvAlgorithm::Im2col,
            ConvAlgorithm::Tiled,
            ConvAlgorithm::Winograd,
        ] {
            assert!(algs.contains(&alg), "{alg} never measured: {algs:?}");
        }
        // Both Winograd tile sizes and every detected micro-kernel ISA
        // were actually timed — the new axes are measured, not collapsed.
        let wino_ms = sweep.axis_values_for(&key.op, |c| {
            (c.config.algorithm == ConvAlgorithm::Winograd)
                .then_some(c.config.wino_m)
        });
        for m in [2u32, 4] {
            assert!(wino_ms.contains(&Some(m)), "wino_m={m} never measured");
        }
        let swept_isas = sweep.axis_values_for(&key.op, |c| c.isa);
        for &isa in &isas {
            assert!(swept_isas.contains(&isa), "{isa} never measured");
        }
        let swept_packs = sweep.axis_values_for(&key.op, |c| c.pack);
        for pack in Pack::all() {
            assert!(swept_packs.contains(&pack), "{pack} never measured");
        }
        // The persisted winner is the argmax and beats (or ties) the
        // untuned default, which is in the grid by construction.
        let (wp, wg) = db.get::<ConvPoint>(&key).unwrap();
        let (win, win_g) = &sweep.winners[&key.op];
        assert_eq!(wp, *win);
        assert_eq!(wg, *win_g);
        let dflt = sweep.gflops_for(&key.op, &ConvCandidate::default()).unwrap();
        assert!(wg >= dflt);
        // GEMM artifacts are untouched by the conv sweep.
        assert!(db
            .get::<ConvPoint>(&SelectionKey::gemm(HOST_DEVICE, 96, 96, 96))
            .is_none());
    }

    #[test]
    fn conv_sweep_skips_winograd_off_its_domain() {
        // A strided conv: winograd candidates must be skipped, not timed
        // as im2col duplicates.
        let dir = TempDir::new("hostsweep").unwrap();
        std::fs::write(
            dir.path().join("manifest.json"),
            r#"{"version": 1, "artifacts": [
              {"name": "cs2", "kind": "conv", "impl": "pallas",
               "file": "cs2.hlo.txt", "flops": 294912, "batch": 1,
               "algorithm": "im2col", "groups": ["conv"],
               "layer": {"name": "s2", "window": 3, "stride": 2,
                         "in_h": 16, "in_w": 16, "in_c": 8, "out_c": 16,
                         "out_h": 8, "out_w": 8, "padding": "SAME",
                         "flops": 294912},
               "inputs": [{"shape": [1, 16, 16, 8], "dtype": "float32"},
                          {"shape": [3, 3, 8, 16], "dtype": "float32"}]}
            ]}"#,
        )
        .unwrap();
        let store = ArtifactStore::open(dir.path()).unwrap();
        let mut engine = NativeEngine::new(store).unwrap();
        let grid = conv_native_grid(true, &[1], &Isa::detect());
        let n_wino = grid
            .iter()
            .filter(|c| c.config.algorithm == ConvAlgorithm::Winograd)
            .count();
        assert!(n_wino > 0);
        let mut db = SelectionDb::new();
        let sweep = tune_space_sweep(
            &mut engine,
            "conv",
            &grid,
            1,
            HOST_DEVICE,
            &ExhaustiveSearch,
            &mut |e, c: &ConvCandidate| e.set_conv_point(*c),
            &mut db,
        )
        .unwrap();
        assert_eq!(sweep.rows.len(), grid.len() - n_wino);
        let key = SelectionKey::conv(HOST_DEVICE, 3, 2, 16, 16, 8, 16, 1);
        assert!(!sweep
            .axis_values_for(&key.op, |c| c.config.algorithm)
            .contains(&ConvAlgorithm::Winograd));
        assert!(db.get::<ConvPoint>(&key).is_some());
    }

    #[test]
    fn widened_gemm_candidates_cover_the_registry() {
        // Full mode sweeps every monomorphized (mr, nr); quick mode
        // reaches beyond the historical {4x8, 8x16} hand-set.
        let full = blocked_candidates(false);
        for &(mr, nr) in micro_kernel_shapes() {
            assert!(
                full.iter().any(|p| p.mr == mr && p.nr == nr),
                "({mr}, {nr}) missing from the full candidate set"
            );
        }
        let quick = blocked_candidates(true);
        assert!(quick.iter().any(|p| (p.mr, p.nr) == (2, 16)));
        assert!(quick.iter().any(|p| (p.mr, p.nr) == (16, 8)));
        for set in [&full, &quick] {
            for (i, c) in set.iter().enumerate() {
                assert!(!set[i + 1..].contains(c), "{c:?} duplicated");
            }
        }
    }

    #[test]
    fn artifacts_without_keys_are_skipped() {
        let dir = TempDir::new("hostsweep").unwrap();
        std::fs::write(
            dir.path().join("manifest.json"),
            r#"{"version": 1, "artifacts": [
              {"name": "odd", "kind": "fft", "impl": "pallas",
               "file": "odd.hlo.txt", "flops": 1, "inputs": [],
               "groups": ["gemm"]}]}"#,
        )
        .unwrap();
        let store = ArtifactStore::open(dir.path()).unwrap();
        let mut engine = NativeEngine::new(store).unwrap();
        let mut db = SelectionDb::new();
        let sweep = tune_space_sweep(
            &mut engine,
            "gemm",
            &scalar_grid(true, &[1]),
            1,
            HOST_DEVICE,
            &ExhaustiveSearch,
            &mut |e, p: &GemmPoint| e.set_params(p.params),
            &mut db,
        )
        .unwrap();
        assert!(sweep.rows.is_empty());
        assert!(db.is_empty());
    }
}
