//! Measured per-host sweep over the `BlockedParams` × `threads` grid.
//!
//! This is the paper's headline workflow run end-to-end on hardware we
//! actually own: enumerate kernel parameter combinations, *measure* each
//! one through a [`Backend`] (no model in the loop), and persist the
//! winner per (platform, problem class) into the [`SelectionDb`] that
//! `NativeEngine` consults at plan time.  Measured — not modeled — sweeps
//! are what make the portability claim credible (cf. Reguly,
//! arXiv:2309.10075); CI runs the quick variant on every merge via
//! `cargo run --release --example tune_device -- --quick`.

use std::collections::BTreeMap;
use std::time::Duration;

use crate::blas::BlockedParams;
use crate::error::Result;
use crate::runtime::{ArtifactMeta, Backend};

use super::db::{SelectionDb, SelectionKey};
use super::search::{ExhaustiveSearch, SearchStrategy};

/// One timed grid point: artifact × parameter combination.
#[derive(Debug, Clone)]
pub struct SweepMeasurement {
    /// Problem-class op key (the `SelectionKey::op` the winner persists
    /// under, e.g. `gemm_128x128x128`).
    pub problem: String,
    /// Artifact the measurement executed.
    pub artifact: String,
    /// Parameter combination this grid point timed.
    pub params: BlockedParams,
    /// Best (minimum) execution time over the repetitions.
    pub best: Duration,
    /// Measured throughput, GFLOP/s (from the artifact's manifest flops).
    pub gflops: f64,
}

/// A finished sweep: every measurement plus the per-problem winners that
/// were persisted.
#[derive(Debug, Default)]
pub struct BlockedSweep {
    /// Every timed grid point, in measurement order.
    pub rows: Vec<SweepMeasurement>,
    /// Winner per problem-class op key.
    pub winners: BTreeMap<String, (BlockedParams, f64)>,
}

impl BlockedSweep {
    /// Best measured gflops for a problem under exactly `params`
    /// (e.g. the default config, for tuned-vs-default reporting).
    pub fn gflops_for(
        &self,
        problem: &str,
        params: &BlockedParams,
    ) -> Option<f64> {
        self.rows
            .iter()
            .filter(|r| r.problem == problem && r.params == *params)
            .map(|r| r.gflops)
            .reduce(f64::max)
    }
}

/// The base `BlockedParams` candidate sets — the same serial candidates
/// the `blocked.rs` tests and the `rust_blas` bench exercise, so the
/// sweep measures configurations the suite already proves correct.
pub fn blocked_candidates(quick: bool) -> Vec<BlockedParams> {
    let p = |bm, bn, bk, mr, nr| BlockedParams {
        bm,
        bn,
        bk,
        mr,
        nr,
        threads: 1,
    };
    if quick {
        // Tiny grid for the CI smoke sweep.
        vec![
            BlockedParams { threads: 1, ..Default::default() },
            p(32, 32, 32, 4, 8),
            p(16, 32, 16, 4, 8),
        ]
    } else {
        vec![
            BlockedParams { threads: 1, ..Default::default() },
            p(8, 8, 8, 2, 2),
            p(16, 32, 5, 4, 8),
            p(64, 64, 64, 8, 16),
            p(32, 32, 32, 4, 8),
            p(128, 128, 64, 8, 16),
        ]
    }
}

/// The full sweep grid: [`blocked_candidates`] × `threads`, deduplicated,
/// with [`BlockedParams::default`] always present so every sweep measures
/// the untuned baseline it is compared against.
pub fn blocked_grid(quick: bool, threads: &[usize]) -> Vec<BlockedParams> {
    let mut grid: Vec<BlockedParams> = Vec::new();
    for base in blocked_candidates(quick) {
        for &t in threads {
            let cand = BlockedParams { threads: t, ..base };
            if !grid.contains(&cand) {
                grid.push(cand);
            }
        }
    }
    let default = BlockedParams::default();
    if !grid.contains(&default) {
        grid.insert(0, default);
    }
    grid
}

/// Derive the tuning-DB key for an artifact on `device` (the platform
/// string the host sweep and `NativeEngine`'s plan-time lookup share —
/// both must produce identical keys or tuned entries are never found).
pub fn selection_key_for(
    meta: &ArtifactMeta,
    device: &str,
) -> Option<SelectionKey> {
    match meta.kind.as_str() {
        "gemm" => {
            Some(SelectionKey::gemm(device, meta.m?, meta.n?, meta.k?))
        }
        "conv" => {
            let l = meta.layer.as_ref()?;
            Some(SelectionKey::conv(
                device,
                l.window,
                l.stride,
                l.in_h,
                l.in_w,
                l.in_c,
                l.out_c,
                meta.batch.unwrap_or(1),
            ))
        }
        _ => None,
    }
}

/// Measure every artifact in `group` under every grid point and persist
/// the per-problem winner into `db`, keyed by (device, problem class).
///
/// Generic over [`Backend`]; `apply` installs a candidate on the engine
/// before it is timed (for `NativeEngine` that is
/// `|e, p| e.set_params(*p)`).  The per-problem argmax runs through
/// [`ExhaustiveSearch`] — the measured counterpart of the modeled
/// `tune_gemm`/`tune_conv`, and the same discipline as `tune_measured`:
/// `iters` repetitions, minimum taken, throughput from manifest flops.
///
/// # Examples
///
/// ```
/// use portable_kernels::blas::BlockedParams;
/// use portable_kernels::runtime::{ArtifactStore, NativeEngine, HOST_DEVICE};
/// use portable_kernels::tuner::{
///     tune_blocked_sweep, SelectionDb, SelectionKey,
/// };
/// use portable_kernels::util::tmp::TempDir;
///
/// let dir = TempDir::new("doc-sweep").unwrap();
/// std::fs::write(
///     dir.path().join("manifest.json"),
///     r#"{"version": 1, "artifacts": [{
///         "name": "g16", "kind": "gemm", "impl": "pallas",
///         "file": "g16.hlo.txt", "flops": 8192,
///         "m": 16, "n": 16, "k": 16,
///         "inputs": [{"shape": [16, 16], "dtype": "float32"},
///                    {"shape": [16, 16], "dtype": "float32"}],
///         "groups": ["gemm"]}]}"#,
/// )
/// .unwrap();
/// let store = ArtifactStore::open(dir.path()).unwrap();
/// let mut engine = NativeEngine::new(store).unwrap();
///
/// let grid = [
///     BlockedParams { threads: 1, ..BlockedParams::default() },
///     BlockedParams { bm: 8, bn: 8, bk: 8, mr: 2, nr: 2, threads: 1 },
/// ];
/// let mut db = SelectionDb::new();
/// let sweep = tune_blocked_sweep(
///     &mut engine,
///     "gemm",
///     &grid,
///     1,
///     HOST_DEVICE,
///     &mut |e, p| e.set_params(*p),
///     &mut db,
/// )
/// .unwrap();
/// assert_eq!(sweep.rows.len(), grid.len());
/// let key = SelectionKey::gemm(HOST_DEVICE, 16, 16, 16);
/// assert!(db.get_blocked(&key).is_some(), "winner persisted");
/// ```
pub fn tune_blocked_sweep<B: Backend>(
    engine: &mut B,
    group: &str,
    grid: &[BlockedParams],
    iters: usize,
    device: &str,
    apply: &mut dyn FnMut(&mut B, &BlockedParams),
    db: &mut SelectionDb,
) -> Result<BlockedSweep> {
    let metas: Vec<ArtifactMeta> =
        engine.store().in_group(group).cloned().collect();
    let mut sweep = BlockedSweep::default();
    for meta in metas {
        let Some(key) = selection_key_for(&meta, device) else {
            continue;
        };
        let inputs = engine.synth_inputs(&meta.name, 17)?;
        let mut run_err = None;
        let mut score = |i: usize| -> Option<f64> {
            apply(engine, &grid[i]);
            match engine.run_timed(&meta.name, &inputs, iters) {
                Ok((out, best)) => {
                    let gflops = out.gflops(meta.flops);
                    sweep.rows.push(SweepMeasurement {
                        problem: key.op.clone(),
                        artifact: meta.name.clone(),
                        params: grid[i],
                        best,
                        gflops,
                    });
                    Some(gflops)
                }
                Err(e) => {
                    run_err = Some(e);
                    None
                }
            }
        };
        let found = ExhaustiveSearch.search(grid.len(), &mut score);
        if let Some(e) = run_err {
            return Err(e);
        }
        if let Some((idx, _evals, gflops)) = found {
            // Several artifacts can share a problem class (same shape,
            // different lowering); keep the best selection seen.
            let better = db
                .get_blocked(&key)
                .map(|(_, g)| gflops > g)
                .unwrap_or(true);
            if better {
                db.put_blocked(key.clone(), grid[idx], gflops);
                sweep.winners.insert(key.op.clone(), (grid[idx], gflops));
            }
        }
    }
    Ok(sweep)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{ArtifactStore, NativeEngine, HOST_DEVICE};
    use crate::util::tmp::TempDir;

    fn sweep_fixture() -> (TempDir, NativeEngine) {
        let dir = TempDir::new("hostsweep").unwrap();
        std::fs::write(
            dir.path().join("manifest.json"),
            r#"{"version": 1, "artifacts": [
              {"name": "g96", "kind": "gemm", "impl": "pallas",
               "file": "g96.hlo.txt", "flops": 1769472,
               "m": 96, "n": 96, "k": 96, "groups": ["gemm"],
               "inputs": [{"shape": [96, 96], "dtype": "float32"},
                          {"shape": [96, 96], "dtype": "float32"}]},
              {"name": "c16", "kind": "conv", "impl": "pallas",
               "file": "c16.hlo.txt", "flops": 1179648, "batch": 2,
               "algorithm": "im2col", "groups": ["conv"],
               "layer": {"name": "sweep", "window": 3, "stride": 1,
                         "in_h": 16, "in_w": 16, "in_c": 8, "out_c": 16,
                         "out_h": 16, "out_w": 16, "padding": "SAME",
                         "flops": 1179648},
               "inputs": [{"shape": [2, 16, 16, 8], "dtype": "float32"},
                          {"shape": [3, 3, 8, 16], "dtype": "float32"}]}
            ]}"#,
        )
        .unwrap();
        let store = ArtifactStore::open(dir.path()).unwrap();
        let engine = NativeEngine::new(store).unwrap();
        (dir, engine)
    }

    #[test]
    fn grid_always_contains_the_default() {
        for quick in [true, false] {
            let grid = blocked_grid(quick, &[1, 2]);
            assert!(grid.contains(&BlockedParams::default()), "quick={quick}");
            // Dedup: no candidate appears twice.
            for (i, a) in grid.iter().enumerate() {
                assert!(!grid[i + 1..].contains(a), "{a:?} duplicated");
            }
            // The threads axis is actually crossed in.
            assert!(grid.iter().any(|p| p.threads == 2));
        }
    }

    #[test]
    fn sweep_measures_grid_and_persists_winners() {
        let (_dir, mut engine) = sweep_fixture();
        let grid = blocked_grid(true, &[1, 2]);
        let mut db = SelectionDb::new();
        let gemm = tune_blocked_sweep(
            &mut engine,
            "gemm",
            &grid,
            2,
            HOST_DEVICE,
            &mut |e, p| e.set_params(*p),
            &mut db,
        )
        .unwrap();
        let conv = tune_blocked_sweep(
            &mut engine,
            "conv",
            &grid,
            2,
            HOST_DEVICE,
            &mut |e, p| e.set_params(*p),
            &mut db,
        )
        .unwrap();
        // Every grid point was measured for every artifact.
        assert_eq!(gemm.rows.len(), grid.len());
        assert_eq!(conv.rows.len(), grid.len());
        assert_eq!(db.len(), 2, "one selection per problem class");
        // The persisted winner is the row argmax, and it comes from the
        // grid.
        for sweep in [&gemm, &conv] {
            for (op, (params, gflops)) in &sweep.winners {
                assert!(grid.contains(params));
                let max = sweep
                    .rows
                    .iter()
                    .filter(|r| &r.problem == op)
                    .map(|r| r.gflops)
                    .fold(f64::MIN, f64::max);
                assert!(*gflops >= max - 1e-12, "{op}: {gflops} < {max}");
            }
        }
        // Tuned >= default by construction: the default is in the grid,
        // so the argmax can never score below it.  Note the key op is
        // the *bucketed* problem class (96^3 -> the 128^3 bucket), and
        // sweep rows carry the same bucketed op.
        let key = SelectionKey::gemm(HOST_DEVICE, 96, 96, 96);
        assert_eq!(key.op, "gemm_128x128x128");
        let (_, tuned) = db.get_blocked(&key).unwrap();
        let dflt = gemm
            .gflops_for(&key.op, &BlockedParams::default())
            .unwrap();
        assert!(tuned >= dflt);
    }

    #[test]
    fn artifacts_without_keys_are_skipped() {
        let dir = TempDir::new("hostsweep").unwrap();
        std::fs::write(
            dir.path().join("manifest.json"),
            r#"{"version": 1, "artifacts": [
              {"name": "odd", "kind": "fft", "impl": "pallas",
               "file": "odd.hlo.txt", "flops": 1, "inputs": [],
               "groups": ["gemm"]}]}"#,
        )
        .unwrap();
        let store = ArtifactStore::open(dir.path()).unwrap();
        let mut engine = NativeEngine::new(store).unwrap();
        let mut db = SelectionDb::new();
        let sweep = tune_blocked_sweep(
            &mut engine,
            "gemm",
            &blocked_grid(true, &[1]),
            1,
            HOST_DEVICE,
            &mut |e, p| e.set_params(*p),
            &mut db,
        )
        .unwrap();
        assert!(sweep.rows.is_empty());
        assert!(db.is_empty());
    }
}
