//! Auto-tuner: search the kernel parameter space per device.
//!
//! The paper's headline workflow — "tuning for new devices amounts to
//! choosing the combinations of kernel parameters that perform best on
//! the hardware" — plus its stated future work ("plans to develop a
//! machine learning system to tune these libraries"), realized as:
//!
//! * [`search`] — exhaustive, random, and hill-climbing strategies over a
//!   cost function (modeled throughput or measured wall time);
//! * [`db`] — a persisted selection database mapping (device, problem
//!   class) to the winning configuration, the artifact the coordinator
//!   consults at request time.

mod db;
mod measured;
mod search;

pub use db::{SelectionDb, SelectionKey};
pub use measured::{tune_measured, MeasuredCandidate, MeasuredTuning};
pub use search::{
    tune_conv, tune_gemm, ExhaustiveSearch, HillClimb, RandomSearch,
    SearchStrategy, TuneResult,
};
