//! Auto-tuner: search the kernel parameter space per device.
//!
//! The paper's headline workflow — "tuning for new devices amounts to
//! choosing the combinations of kernel parameters that perform best on
//! the hardware" — plus its stated future work ("plans to develop a
//! machine learning system to tune these libraries"), realized as:
//!
//! * search strategies ([`ExhaustiveSearch`], [`RandomSearch`],
//!   [`HillClimb`]) over a cost function (modeled throughput or measured
//!   wall time);
//! * [`tune_measured`] — run competing artifacts through a backend and
//!   keep the fastest per problem;
//! * [`tune_blocked_sweep`] — the measured per-host GEMM sweep:
//!   enumerate the `BlockedParams` × `threads` grid (micro-tiles drawn
//!   from the monomorphized registry), time every point through a
//!   [`crate::runtime::Backend`], and persist the winners — the
//!   parametrize → measure → select loop CI runs on every merge
//!   (`docs/TUNING.md` documents the workflow end to end);
//! * [`tune_conv_native_sweep`] — the same loop over the convolution
//!   *algorithm* axis: `ConvAlgorithm × ConvConfig × threads`
//!   ([`conv_native_grid`]), persisting per-layer algorithm winners as
//!   [`Selection::ConvNative`] entries;
//! * [`SelectionDb`] — a persisted selection database mapping (device,
//!   problem class) to the winning configuration, the artifact the
//!   coordinator and `NativeEngine` consult at request/plan time — and
//!   which an engine pool shares read-only across all of its actors.

mod db;
mod host;
mod measured;
mod search;

pub use db::{Selection, SelectionDb, SelectionKey};
pub use host::{
    blocked_candidates, blocked_grid, conv_candidates, conv_native_grid,
    selection_key_for, tune_blocked_sweep, tune_conv_native_sweep,
    BlockedSweep, ConvCandidate, ConvNativeSweep, ConvSweepMeasurement,
    SweepMeasurement,
};
pub use measured::{tune_measured, MeasuredCandidate, MeasuredTuning};
pub use search::{
    tune_conv, tune_gemm, ExhaustiveSearch, HillClimb, RandomSearch,
    SearchStrategy, TuneResult,
};
