//! Auto-tuner: search the kernel parameter space per device.
//!
//! The paper's headline workflow — "tuning for new devices amounts to
//! choosing the combinations of kernel parameters that perform best on
//! the hardware" — plus its stated future work ("plans to develop a
//! machine learning system to tune these libraries"), realized as:
//!
//! * one [`SearchStrategy`] trait (propose → measure → refine) behind
//!   every search entry point, with four implementations:
//!   [`ExhaustiveSearch`], [`RandomSearch`], [`HillClimb`], and the
//!   model-guided [`GuidedSearch`], which ranks candidates by the
//!   `perfmodel` cost hints ([`CostRanker`] / [`ModelRanker`] over
//!   [`crate::config::KernelSpace::rank_hint`]) and measures only the
//!   top of the ranking plus the pinned incumbents, under a hard
//!   per-class budget;
//! * [`tune_measured`] — run competing artifacts through a backend and
//!   keep the fastest per problem;
//! * [`tune_space_sweep`] — **the** measured per-host sweep, generic
//!   over any [`crate::config::KernelSpace`] and parameterized by
//!   strategy: enumerate a space's grid (for GEMM,
//!   [`gemm_point_grid`]: `BlockedParams` × `threads` ×
//!   runtime-detected ISA; for conv, [`conv_native_grid`]:
//!   `ConvAlgorithm × ConvConfig × threads × ISA`, the config axis
//!   carrying the Winograd `wino_m ∈ {2, 4}` tile size), let the
//!   strategy pick
//!   which *applicable* points to time through a
//!   [`crate::runtime::Backend`], and persist the winners — the
//!   parametrize → measure → select loop CI runs on every merge
//!   (`docs/TUNING.md` documents the workflow end to end).
//!   [`tune_space_guided`] is the budgeted model-guided variant, with
//!   [`warm_start_seeds`] transferring winners across adjacent shape
//!   classes;
//! * [`SelectionDb`] — a persisted selection database mapping (device,
//!   problem class) to the winning point of any space
//!   ([`SelectionDb::put`] / [`SelectionDb::get`]; legacy `blocked` /
//!   `conv_native` entries migrate on lookup, [`SelectionDb::merge`]
//!   folds whole legacy DBs into the unified schema), the artifact the
//!   coordinator and `NativeEngine` consult at request/plan time — and
//!   which an engine pool shares read-only across all of its actors;
//! * online re-tuning ([`TuningHandle`] / [`retune_pass`] /
//!   [`OnlineTuner`]) — the epoch-swappable serving loop: pool actors
//!   plan from cheap [`TuningSnapshot`]s, a background tuner probes the
//!   hot shape classes ([`tune_space_sweep_filtered`]) and publishes a
//!   new epoch only for candidates that *measured* strictly faster than
//!   the incumbent in a head-to-head verification probe — a promotion
//!   never installs a worse-measured point.

mod db;
mod host;
mod measured;
mod online;
mod search;

pub use db::{MergeStats, SelectionDb, SelectionKey, StoredSelection};
pub use host::{
    blocked_candidates, blocked_grid, conv_candidates, conv_native_grid,
    gemm_point_grid, problem_for, selection_key_for, shape_class_for,
    tune_space_guided, tune_space_sweep, tune_space_sweep_filtered,
    warm_start_seeds, ConvCandidate, SpaceMeasurement, SpaceSweep,
};
pub use online::{
    retune_native, retune_pass, OnlineTuner, Promotion, RetuneConfig,
    RetunePass, TuningHandle, TuningSnapshot,
};
pub use measured::{tune_measured, MeasuredCandidate, MeasuredTuning};
pub use search::{
    tune_conv, tune_gemm, CostRanker, ExhaustiveSearch, GuidedSearch,
    HillClimb, ModelRanker, RandomSearch, SearchStrategy, TuneResult,
};
