//! Auto-tuner: search the kernel parameter space per device.
//!
//! The paper's headline workflow — "tuning for new devices amounts to
//! choosing the combinations of kernel parameters that perform best on
//! the hardware" — plus its stated future work ("plans to develop a
//! machine learning system to tune these libraries"), realized as:
//!
//! * [`search`] — exhaustive, random, and hill-climbing strategies over a
//!   cost function (modeled throughput or measured wall time);
//! * [`measured`] — run competing artifacts through a backend and keep
//!   the fastest per problem;
//! * [`host`] — the measured per-host sweep: enumerate the
//!   `BlockedParams` × `threads` grid, time every point through a
//!   [`crate::runtime::Backend`], and persist the winners — the
//!   parametrize → measure → select loop CI runs on every merge;
//! * [`db`] — a persisted selection database mapping (device, problem
//!   class) to the winning configuration, the artifact the coordinator
//!   and `NativeEngine` consult at request/plan time.

mod db;
mod host;
mod measured;
mod search;

pub use db::{Selection, SelectionDb, SelectionKey};
pub use host::{
    blocked_candidates, blocked_grid, selection_key_for, tune_blocked_sweep,
    BlockedSweep, SweepMeasurement,
};
pub use measured::{tune_measured, MeasuredCandidate, MeasuredTuning};
pub use search::{
    tune_conv, tune_gemm, ExhaustiveSearch, HillClimb, RandomSearch,
    SearchStrategy, TuneResult,
};
